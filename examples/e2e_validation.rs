//! END-TO-END VALIDATION DRIVER — the full system on a real workload.
//!
//! Proves all layers compose: VCL/CUDA sources → VOLT front-end →
//! centralized SIMT middle-end (full ladder) → Vortex back-end → SimX-style
//! simulator → host runtime, with results validated against BOTH
//! (a) host-side Rust references (every benchmark) and
//! (b) the JAX/Pallas AOT reference kernels executed via PJRT from Rust
//!     (the dense kernels) — Python never runs here; the HLO text in
//!     `artifacts/` is the build product of `make artifacts`.
//!
//! Prints the paper's headline-style summary (coverage + ladder geomeans)
//! and is the run recorded in EXPERIMENTS.md.
//!
//! Run: make artifacts && cargo run --release --example e2e_validation

use volt::backend::emit::SharedMemMapping;
use volt::coordinator::{benchmarks, experiments, Rng};
use volt::driver::{Session, VoltOptions};
use volt::runtime::{default_artifacts_dir, ArgValue, PjrtReference, VoltDevice};
use volt::sim::SimConfig;
use volt::transform::OptLevel;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let t0 = std::time::Instant::now();
    // ---- 1. §5.1-style coverage: full suite at the ladder extremes ----
    let mut pass = 0;
    let mut total = 0;
    let mut base_instrs = 0u64;
    let mut full_instrs = 0u64;
    let mut base_cycles = 0u64;
    let mut full_cycles = 0u64;
    println!("== benchmark coverage (suite x {{Base, Recon}}) ==");
    for b in benchmarks::registry() {
        let mut line = format!("{:>14} [{:>8}]", b.name, b.suite);
        for lvl in [OptLevel::Base, OptLevel::Recon] {
            total += 1;
            match experiments::run_bench(
                &b,
                lvl,
                true,
                SharedMemMapping::Local,
                SimConfig::default(),
            ) {
                Ok(r) => {
                    pass += 1;
                    line.push_str(&format!(
                        "  {}={}i/{}c",
                        lvl.name(),
                        r.stats.instrs,
                        r.stats.cycles
                    ));
                    if lvl == OptLevel::Base {
                        base_instrs += r.stats.instrs;
                        base_cycles += r.stats.cycles;
                    } else {
                        full_instrs += r.stats.instrs;
                        full_cycles += r.stats.cycles;
                    }
                }
                Err(e) => line.push_str(&format!("  {}=FAIL({e})", lvl.name())),
            }
        }
        println!("{line}");
    }
    println!(
        "\n{pass}/{total} runs validated; suite instruction reduction {:.3}x, speedup {:.3}x (Recon vs Base)",
        base_instrs as f64 / full_instrs as f64,
        base_cycles as f64 / full_cycles as f64
    );

    // ---- 2. PJRT cross-validation of the device against JAX/Pallas ----
    println!("\n== device vs JAX/Pallas PJRT reference ==");
    match PjrtReference::load(&default_artifacts_dir()) {
        Err(e) => println!("(skipped — run `make artifacts`): {e}"),
        Ok(pjrt) => {
            println!("PJRT platform: {}", pjrt.platform());
            // SGEMM on device vs the Pallas tiled matmul.
            let n = 24usize;
            let src = std::fs::read_to_string(
                std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("benchmarks/sgemm.cl"),
            )?;
            let mut session = Session::new(VoltOptions::builder().build()?);
            let out = session.compile(&src)?;
            let mut dev = VoltDevice::new(out.image.clone(), SimConfig::default());
            let mut rng = Rng(2024);
            let a: Vec<f32> = (0..n * n).map(|_| rng.f32_01() * 2.0 - 1.0).collect();
            let b: Vec<f32> = (0..n * n).map(|_| rng.f32_01() * 2.0 - 1.0).collect();
            let (pa, pb, pc) = (
                dev.malloc((n * n * 4) as u32),
                dev.malloc((n * n * 4) as u32),
                dev.malloc((n * n * 4) as u32),
            );
            dev.write_f32(pa, &a)?;
            dev.write_f32(pb, &b)?;
            let stats = dev.launch(
                "sgemm",
                [3, 3, 1],
                [8, 8, 1],
                &[
                    ArgValue::Ptr(pa),
                    ArgValue::Ptr(pb),
                    ArgValue::Ptr(pc),
                    ArgValue::I32(n as i32),
                    ArgValue::I32(n as i32),
                    ArgValue::I32(n as i32),
                ],
            )?;
            let device = dev.read_f32(pc, n * n)?;
            let pallas = pjrt.run_f32("matmul24", &[a.clone(), b.clone()])?;
            let mut max_err = 0f32;
            for i in 0..n * n {
                max_err = max_err.max((device[i] - pallas[i]).abs());
            }
            println!(
                "sgemm 24x24: device {} cycles; max |device - pallas| = {max_err:.2e}  {}",
                stats.cycles,
                if max_err < 1e-3 { "OK" } else { "MISMATCH" }
            );
            assert!(max_err < 1e-3);

            // Elementwise + reduction cross-checks.
            let va: Vec<f32> = (0..1000).map(|i| i as f32 * 0.25).collect();
            let vb: Vec<f32> = (0..1000).map(|i| 1000.0 - i as f32).collect();
            let vr = pjrt.run_f32("vecadd1000", &[va.clone(), vb.clone()])?;
            for i in 0..1000 {
                assert!((vr[i] - (va[i] + vb[i])).abs() < 1e-4);
            }
            let xs: Vec<f32> = (0..512).map(|i| (i as f32 * 0.01).cos()).collect();
            let sums = pjrt.run_f32("blocksum512", &[xs.clone()])?;
            for (g, chunk) in sums.iter().zip(xs.chunks(64)) {
                let want: f32 = chunk.iter().sum();
                assert!((g - want).abs() < 1e-3);
            }
            println!("vecadd1000 + blocksum512 PJRT references: OK");
        }
    }

    // ---- 3. Case-study spot checks ----
    println!("\n== case studies ==");
    let fig9 = experiments::isa_extension_sweep()?;
    let g9 = experiments::geomean(fig9.iter().map(|r| r.speedup()));
    println!("Fig 9 (ISA extensions): geomean HW/SW speedup {g9:.2}x over {} kernels", fig9.len());
    let fig10 = experiments::memory_config_sweep()?;
    println!("Fig 10 (memory configs): {} kernels x {} configs", fig10.len(), fig10[0].cells.len());

    println!("\ntotal e2e wall time: {:.1}s", t0.elapsed().as_secs_f64());
    if pass != total {
        std::process::exit(1);
    }
    Ok(())
}
