//! Quickstart: the session-based driver API end to end.
//!
//! One source file with two kernels is compiled into a single multi-kernel
//! program through a `Session` (content-addressed binary cache included),
//! then both kernels run on a `Stream` — enqueue uploads, launches and
//! reads, `synchronize()`, inspect per-command events with sim-cycle
//! timestamps.
//!
//! Run: cargo run --release --example quickstart

use volt::driver::{Session, VoltOptions};
use volt::runtime::ArgValue;

const SRC: &str = r#"
kernel void ramp(global float* x, float step, int n) {
    int i = get_global_id(0);
    if (i < n) { x[i] = (float)i * step; }
}
kernel void saxpy(global float* x, global float* y, float a, int n) {
    int i = get_global_id(0);
    if (i < n) { y[i] = a * x[i] + y[i]; }
}
"#;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. A session: unified options, validated once, binary cache inside.
    let mut session = Session::new(VoltOptions::builder().build()?);

    // 2. Compile. The program exposes a launchable entry for EVERY kernel
    //    in the source — one image serves both.
    let program = session.compile(SRC)?;
    println!(
        "compiled {} kernels {:?} in {:.2} ms ({} instructions)",
        program.kernels.len(),
        program.kernel_names(),
        program.timings.total_ms(),
        program.image.code.len()
    );

    // Recompiling identical source is a cache hit (near-free).
    let again = session.compile(SRC)?;
    assert_eq!(program.fingerprint, again.fingerprint);
    let stats = session.cache_stats();
    println!(
        "binary cache: {} hit(s), {} miss(es)",
        stats.hits, stats.misses
    );

    // 3. A stream: CUDA/OpenCL-style command queue on a fresh device.
    let n = 1000usize;
    let mut stream = session.create_stream(&program);
    let px = stream.malloc((n * 4) as u32);
    let py = stream.malloc((n * 4) as u32);
    stream.enqueue_write_f32(py, &vec![1.0f32; n])?;
    stream.enqueue_launch(
        "ramp",
        [8, 1, 1],
        [128, 1, 1],
        &[ArgValue::Ptr(px), ArgValue::F32(1.0), ArgValue::I32(n as i32)],
    )?;
    stream.enqueue_launch(
        "saxpy",
        [8, 1, 1],
        [128, 1, 1],
        &[
            ArgValue::Ptr(px),
            ArgValue::Ptr(py),
            ArgValue::F32(2.0),
            ArgValue::I32(n as i32),
        ],
    )?;
    let result = stream.enqueue_read_f32(py, n);

    // 4. Everything executes, in order, here.
    stream.synchronize()?;

    // 5. Validate: y = 2*i + 1.
    let got = stream.take_f32(result)?;
    for (i, v) in got.iter().enumerate() {
        assert_eq!(*v, 2.0 * i as f32 + 1.0, "element {i}");
    }

    // 6. Events carry device sim-cycle timestamps per command.
    for e in stream.events() {
        println!(
            "  [{:>10} .. {:>10}] {:?} {} ({} warp instrs)",
            e.start_cycles, e.end_cycles, e.kind, e.label, e.instrs
        );
    }
    let s = stream.stats();
    println!(
        "OK: {} launches, {} warp instructions in {} cycles (IPC {:.2})",
        stream.events().iter().filter(|e| e.instrs > 0).count(),
        s.instrs,
        s.cycles,
        s.ipc()
    );
    Ok(())
}
