//! Quickstart: compile an OpenCL kernel with the full VOLT pipeline, run
//! it on the SimX-style simulator through the host runtime, and read back
//! the results.
//!
//! Run: cargo run --release --example quickstart

use volt::backend::emit::BackendOptions;
use volt::coordinator::compile_source;
use volt::frontend::FrontendOptions;
use volt::runtime::{ArgValue, VoltDevice};
use volt::sim::SimConfig;
use volt::transform::OptLevel;

const SRC: &str = r#"
kernel void saxpy(global float* x, global float* y, float a, int n) {
    int i = get_global_id(0);
    if (i < n) { y[i] = a * x[i] + y[i]; }
}
"#;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Compile: front-end -> middle-end ladder -> Vortex binary.
    let out = compile_source(
        SRC,
        &FrontendOptions::default(),
        OptLevel::Recon,
        &BackendOptions::default(),
    )?;
    println!(
        "compiled saxpy: {} instructions, {:.2} ms total ({} splits, {} managed loops)",
        out.image.code.len(),
        out.total_ms(),
        out.middle.total_splits(),
        out.middle.total_pred_loops()
    );

    // 2. Create a device (paper §5 config: 4 cores x 16 warps x 32 threads).
    let mut dev = VoltDevice::new(out.image.clone(), SimConfig::default());

    // 3. Host API: allocate, upload, launch, download.
    let n = 1000usize;
    let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let y: Vec<f32> = vec![1.0; n];
    let px = dev.malloc((n * 4) as u32);
    let py = dev.malloc((n * 4) as u32);
    dev.write_f32(px, &x)?;
    dev.write_f32(py, &y)?;
    let stats = dev.launch(
        "saxpy",
        [8, 1, 1],
        [128, 1, 1],
        &[
            ArgValue::Ptr(px),
            ArgValue::Ptr(py),
            ArgValue::F32(2.0),
            ArgValue::I32(n as i32),
        ],
    )?;

    // 4. Validate.
    let got = dev.read_f32(py, n)?;
    for i in 0..n {
        assert_eq!(got[i], 2.0 * i as f32 + 1.0, "element {i}");
    }
    println!(
        "OK: {} warp-instructions in {} cycles (IPC {:.2}), {} L1 hits / {} misses",
        stats.instrs,
        stats.cycles,
        stats.ipc(),
        stats.l1_hits,
        stats.l1_misses
    );
    Ok(())
}
