//! Case Study 2 (paper §5.4): extending host memory APIs.
//!
//! * `cudaMemcpyToSymbol` onto the Vortex memory model: constant symbols
//!   live in global memory; host writes are enqueued on the stream and
//!   materialized just before launch, after device addresses resolve.
//! * Shared-memory mapping choice (Fig. 10): `__shared__` onto the
//!   per-core scratchpad vs emulated in global memory — identical results,
//!   different performance.
//!
//! Run: cargo run --release --example cuda_host_memory

use volt::backend::emit::SharedMemMapping;
use volt::driver::{CommandKind, Session, VoltOptions};
use volt::frontend::Dialect;
use volt::runtime::ArgValue;

const SRC: &str = r#"
__constant__ float coeffs[4] = { 0.0f, 0.0f, 0.0f, 0.0f };
__global__ void filter(float* data, float* out, int n) {
    __shared__ float tile[64];
    int l = threadIdx.x;
    int g = blockIdx.x * blockDim.x + threadIdx.x;
    tile[l] = g < n ? data[g] : 0.0f;
    __syncthreads();
    float acc = 0.0f;
    for (int k = 0; k < 4; k++) {
        int j = l + k - 2;
        float v = (j >= 0 && j < 64) ? tile[j] : 0.0f;
        acc += coeffs[k] * v;
    }
    if (g < n) { out[g] = acc; }
}
"#;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n = 256usize;
    let data: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).sin()).collect();
    let coeffs = [0.1f32, 0.4, 0.4, 0.1];
    let coeff_bytes: Vec<u8> = coeffs
        .iter()
        .flat_map(|v| v.to_bits().to_le_bytes())
        .collect();

    let mut results = vec![];
    for smem in [SharedMemMapping::Local, SharedMemMapping::Global] {
        let mut session = Session::new(
            VoltOptions::builder()
                .dialect(Dialect::Cuda)
                .smem(smem)
                .build()?,
        );
        let program = session.compile(SRC)?;
        let mut stream = session.create_stream(&program);

        // cudaMemcpyToSymbol: enqueued now, materialized by the runtime
        // just before the launch executes, once device addresses are final.
        stream.enqueue_write_symbol("coeffs", &coeff_bytes, 0)?;
        let pd = stream.malloc((n * 4) as u32);
        let po = stream.malloc((n * 4) as u32);
        stream.enqueue_write_f32(pd, &data)?;
        stream.enqueue_launch(
            "filter",
            [4, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(pd), ArgValue::Ptr(po), ArgValue::I32(n as i32)],
        )?;
        let out = stream.enqueue_read_f32(po, n);
        stream.synchronize()?;
        let got = stream.take_f32(out)?;

        let launch = stream
            .events()
            .iter()
            .find(|e| e.kind == CommandKind::Launch)
            .expect("launch event");
        let cycles = launch.end_cycles - launch.start_cycles;
        println!(
            "smem={smem:?}: {} cycles, {} local accesses, {} mem requests",
            cycles,
            stream.stats().local_accesses,
            stream.stats().mem_requests
        );
        results.push((smem, cycles, got));
    }

    // Same numerics under both mappings; scratchpad is faster.
    let (m0, c0, r0) = &results[0];
    let (m1, c1, r1) = &results[1];
    for i in 0..n {
        assert!((r0[i] - r1[i]).abs() < 1e-6, "mapping changed results at {i}");
    }
    println!(
        "\nidentical results; {m0:?} = {c0} cycles vs {m1:?} = {c1} cycles ({:.2}x)",
        *c1 as f64 / *c0 as f64
    );
    Ok(())
}
