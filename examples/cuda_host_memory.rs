//! Case Study 2 (paper §5.4): extending host memory APIs.
//!
//! * `cudaMemcpyToSymbol` onto the Vortex memory model: constant symbols
//!   live in global memory; host writes are buffered and materialized just
//!   before launch, after device addresses resolve.
//! * Shared-memory mapping choice (Fig. 10): `__shared__` onto the
//!   per-core scratchpad vs emulated in global memory — identical results,
//!   different performance.
//!
//! Run: cargo run --release --example cuda_host_memory

use volt::backend::emit::{BackendOptions, SharedMemMapping};
use volt::coordinator::compile_source;
use volt::frontend::{Dialect, FrontendOptions};
use volt::runtime::{ArgValue, VoltDevice};
use volt::sim::SimConfig;
use volt::transform::OptLevel;

const SRC: &str = r#"
__constant__ float coeffs[4] = { 0.0f, 0.0f, 0.0f, 0.0f };
__global__ void filter(float* data, float* out, int n) {
    __shared__ float tile[64];
    int l = threadIdx.x;
    int g = blockIdx.x * blockDim.x + threadIdx.x;
    tile[l] = g < n ? data[g] : 0.0f;
    __syncthreads();
    float acc = 0.0f;
    for (int k = 0; k < 4; k++) {
        int j = l + k - 2;
        float v = (j >= 0 && j < 64) ? tile[j] : 0.0f;
        acc += coeffs[k] * v;
    }
    if (g < n) { out[g] = acc; }
}
"#;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let fe = FrontendOptions {
        dialect: Dialect::Cuda,
        warp_hw: true,
    };
    let n = 256usize;
    let data: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).sin()).collect();
    let coeffs = [0.1f32, 0.4, 0.4, 0.1];

    let mut results = vec![];
    for smem in [SharedMemMapping::Local, SharedMemMapping::Global] {
        let out = compile_source(
            SRC,
            &fe,
            OptLevel::Recon,
            &BackendOptions {
                smem,
                ..Default::default()
            },
        )?;
        let mut dev = VoltDevice::new(out.image.clone(), SimConfig::default());
        // cudaMemcpyToSymbol: buffered now...
        let bytes: Vec<u8> = coeffs
            .iter()
            .flat_map(|v| v.to_bits().to_le_bytes())
            .collect();
        dev.memcpy_to_symbol("coeffs", &bytes, 0)?;
        println!(
            "smem={smem:?}: {} symbol write(s) buffered (deferred until launch)",
            dev.pending_symbol_writes()
        );
        let pd = dev.malloc((n * 4) as u32);
        let po = dev.malloc((n * 4) as u32);
        dev.write_f32(pd, &data)?;
        // ...materialized here, after device addresses are final.
        let stats = dev.launch(
            "filter",
            [4, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(pd), ArgValue::Ptr(po), ArgValue::I32(n as i32)],
        )?;
        assert_eq!(dev.pending_symbol_writes(), 0);
        let got = dev.read_f32(po, n)?;
        println!(
            "smem={smem:?}: {} cycles, {} local accesses, {} mem requests",
            stats.cycles, stats.local_accesses, stats.mem_requests
        );
        results.push((smem, stats.cycles, got));
    }
    // Same numerics under both mappings; scratchpad is faster.
    let (m0, c0, r0) = &results[0];
    let (m1, c1, r1) = &results[1];
    for i in 0..n {
        assert!((r0[i] - r1[i]).abs() < 1e-6, "mapping changed results at {i}");
    }
    println!(
        "\nidentical results; {m0:?} = {c0} cycles vs {m1:?} = {c1} cycles ({:.2}x)",
        *c1 as f64 / *c0 as f64
    );
    Ok(())
}
