//! Case Study 1 (paper §5.3): extending ISA support.
//!
//! The same CUDA warp-level source compiled two ways:
//! * **builtin-library path** — warp intrinsics replaced by software
//!   emulation through per-core shared memory (the CuPBoP-runtime
//!   fallback);
//! * **ISA-table path** — the back-end table knows `vx_shfl`/`vx_vote`, so
//!   the intrinsics lower to single instructions.
//!
//! Prints the Fig. 9 rows for the whole warp-feature suite.
//!
//! Run: cargo run --release --example isa_extension_study

use volt::backend::emit::SharedMemMapping;
use volt::coordinator::{experiments, report};
use volt::sim::SimConfig;
use volt::transform::OptLevel;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Per-benchmark Fig. 9 sweep.
    let rows = experiments::isa_extension_sweep()?;
    print!("{}", report::render_fig9(&rows));
    let g = experiments::geomean(rows.iter().map(|r| r.speedup()));
    println!("geomean HW/SW speedup: {g:.2}x");

    // Zoom in on one kernel: what the two lowering modes cost.
    let b = volt::coordinator::find("bscan").unwrap();
    for (label, hw) in [("software emulation", false), ("vx_* ISA", true)] {
        let r = experiments::run_bench(
            &b,
            OptLevel::Recon,
            hw,
            SharedMemMapping::Local,
            SimConfig::default(),
        )?;
        println!(
            "bscan [{label}]: {} instrs, {} cycles, {} warp-op instructions, {} local accesses",
            r.stats.instrs, r.stats.cycles, r.stats.warp_ops, r.stats.local_accesses
        );
    }
    Ok(())
}
