//! Divergence explorer: shows what the centralized middle-end does to a
//! divergent kernel — the IR before/after divergence-management insertion
//! (paper Fig. 2 / Algorithm 2) and the final Vortex machine code, at two
//! ladder points.
//!
//! Run: cargo run --release --example divergence_explorer

use volt::driver::{Session, VoltOptions};
use volt::frontend::{compile_kernels, FrontendOptions};
use volt::ir::printer::print_function;
use volt::transform::{run_middle_end, OptLevel};

const SRC: &str = r#"
kernel void divergent(global int* out, int n) {
    int i = get_global_id(0);
    int acc = 0;
    // divergent loop: per-lane trip count (vx_pred territory)
    for (int k = 0; k < (i % 7); k++) { acc += k; }
    // divergent branch: split/join territory
    if (i % 2 == 0) { acc = acc * 3; } else { acc = acc + 100; }
    // uniform loop: no management needed once n is known uniform
    for (int q = 0; q < n; q++) { acc += 1; }
    out[i] = acc;
}
"#;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let fe = FrontendOptions::default();
    println!("=== front-end IR (before the middle-end) ===");
    let (m0, infos) = compile_kernels(SRC, &fe)?;
    let disp = infos[0].dispatcher;
    println!("{}", print_function(m0.func(disp)));

    for lvl in [OptLevel::Base, OptLevel::Recon] {
        let mut m = m0.clone();
        let mut cfg = lvl.config();
        cfg.verify = true;
        let rep = run_middle_end(&mut m, &cfg);
        println!(
            "=== after middle-end @ {} : {} splits, {} managed loops, {} selects formed ===",
            lvl.name(),
            rep.total_splits(),
            rep.total_pred_loops(),
            rep.selects_formed
        );
        let f = m.func(disp);
        let text = print_function(f);
        // Print just the divergence-relevant lines.
        for line in text.lines() {
            if line.contains("splitbr")
                || line.contains("predbr")
                || line.contains("intr.join")
                || line.contains("intr.mask")
                || line.starts_with('b')
            {
                println!("{line}");
            }
        }
        println!();
    }

    println!("=== final machine code (Recon, Fig. 2-style) ===");
    let mut session = Session::new(VoltOptions::builder().opt_level(OptLevel::Recon).build()?);
    let out = session.compile(SRC)?;
    let dis = out.image.disassemble();
    let mut shown = 0;
    for line in dis.lines() {
        if line.contains("vx_") {
            println!("{line}");
            shown += 1;
        }
    }
    println!("({shown} Vortex divergence/warp instructions in the binary)");
    Ok(())
}
