//! Simulation-speed acceptance gate: host threads and the warp JIT must
//! change wall clock only, never results.
//!
//! Runs sgemm, sgemm_tiled, reduce and bfs at O3 on `vortex` over the
//! full jit × threads matrix — the trace-caching warp JIT off and on,
//! each with the simulator's host worker pool at 1, 2 and 4 threads
//! (vortex has four cores, so 4 threads fully engages the cycle-barrier
//! engine). Each configuration takes the best wall time over several
//! repeats and reports throughput as warp-instructions per second.
//!
//! Gates (non-zero exit on failure):
//! * identity — the full `SimStats` of every configuration is
//!   bit-identical to the jit-off 1-thread run of the same kernel
//!   (cycles, instruction counts, cache counters, prints, everything);
//! * jit throughput — jit-on sgemm at 1 thread is at least as fast as
//!   jit-off at 1 thread (best-of-repeats on both sides);
//! * parallel throughput — jit-on 4-thread sgemm is at least as fast
//!   as jit-on 1-thread.
//!
//! Writes BENCH_sim_throughput.json (schema-checked by the in-tree JSON
//! validator) for the CI artifact.
//!
//! Run: cargo bench --bench sim_throughput

use std::time::Instant;
use volt::coordinator::benchmarks;
use volt::coordinator::experiments::run_bench_on_configured;
use volt::target::TargetDesc;
use volt::transform::OptLevel;

const KERNELS: [&str; 4] = ["sgemm", "sgemm_tiled", "reduce", "bfs"];
const JITS: [bool; 2] = [false, true];
const THREADS: [usize; 3] = [1, 2, 4];
const REPEATS: u32 = 3;

struct Row {
    jit: bool,
    threads: usize,
    best_wall_s: f64,
    wall_ms: f64,
    cycles: u64,
    warp_instrs: u64,
    instrs_per_sec: f64,
    identical: bool,
}

fn main() {
    let target = TargetDesc::vortex();
    let mut failed = false;
    let mut sgemm_jit_speedup = 0.0f64;
    let mut sgemm_speedup_4t = 0.0f64;
    let mut kernels_json = String::new();

    for (ki, &name) in KERNELS.iter().enumerate() {
        let b = benchmarks::find(name).expect(name);
        let mut baseline_sig = String::new();
        let mut sgemm_off_wall = f64::INFINITY;
        let mut sgemm_on_1t_tput = 0.0f64;
        let mut rows: Vec<Row> = vec![];

        for &jit in &JITS {
            for &threads in &THREADS {
                let mut best_wall = f64::INFINITY;
                let mut sig = String::new();
                let mut cycles = 0u64;
                let mut instrs = 0u64;
                for _ in 0..REPEATS {
                    let t0 = Instant::now();
                    let r = run_bench_on_configured(&b, &target, OptLevel::O3, threads, jit)
                        .unwrap_or_else(|e| panic!("{name} jit={jit} @ {threads} threads: {e}"));
                    let wall = t0.elapsed().as_secs_f64();
                    best_wall = best_wall.min(wall);
                    // The Debug rendering covers every SimStats field, the
                    // print log and the sanitizer report list — a one-bit
                    // divergence anywhere shows up here.
                    sig = format!("{:?}", r.stats);
                    cycles = r.stats.cycles;
                    instrs = r.stats.instrs;
                }
                let tput = instrs as f64 / best_wall.max(1e-12);
                // Baseline configuration: jit off, 1 thread (first in
                // iteration order) — the pure interpreter.
                let identical = if !jit && threads == 1 {
                    baseline_sig = sig;
                    true
                } else {
                    sig == baseline_sig
                };
                if !identical {
                    eprintln!(
                        "FAIL: {name} diverged at jit={jit} threads={threads} \
                         vs jit-off 1-thread"
                    );
                    failed = true;
                }
                if name == "sgemm" && threads == 1 {
                    if jit {
                        sgemm_jit_speedup = sgemm_off_wall / best_wall.max(1e-12);
                        sgemm_on_1t_tput = tput;
                    } else {
                        sgemm_off_wall = best_wall;
                    }
                }
                if name == "sgemm" && jit && threads == 4 {
                    sgemm_speedup_4t = tput / sgemm_on_1t_tput.max(1e-12);
                }
                println!(
                    "{name:<12} jit {} threads {threads}: {cycles:>9} cycles, \
                     {instrs:>9} warp-instrs, best {best_wall:.4}s, {tput:>12.0} winstrs/s{}",
                    if jit { "on " } else { "off" },
                    if identical { "" } else { "  << DIVERGED" }
                );
                rows.push(Row {
                    jit,
                    threads,
                    best_wall_s: best_wall,
                    wall_ms: best_wall * 1e3,
                    cycles,
                    warp_instrs: instrs,
                    instrs_per_sec: tput,
                    identical,
                });
            }
        }

        kernels_json.push_str(&format!("    {{\"name\": \"{name}\", \"rows\": [\n"));
        for (i, r) in rows.iter().enumerate() {
            kernels_json.push_str(&format!(
                "      {{\"jit\": {}, \"threads\": {}, \"best_wall_s\": {:.6}, \
                 \"wall_ms\": {:.3}, \"cycles\": {}, \"warp_instrs\": {}, \
                 \"instrs_per_sec\": {:.1}, \"identical\": {}}}{}\n",
                r.jit,
                r.threads,
                r.best_wall_s,
                r.wall_ms,
                r.cycles,
                r.warp_instrs,
                r.instrs_per_sec,
                r.identical,
                if i + 1 == rows.len() { "" } else { "," }
            ));
        }
        kernels_json.push_str(&format!(
            "    ]}}{}\n",
            if ki + 1 == KERNELS.len() { "" } else { "," }
        ));
    }

    let json = format!(
        "{{\n  \"bench\": \"sim_throughput\",\n  \"target\": \"{}\",\n  \"repeats\": {},\n  \
         \"sgemm_jit_speedup\": {:.4},\n  \"sgemm_speedup_4t\": {:.4},\n  \"kernels\": [\n{}  ]\n}}\n",
        target.name, REPEATS, sgemm_jit_speedup, sgemm_speedup_4t, kernels_json
    );
    volt::prof::validate_json(&json).expect("BENCH_sim_throughput.json must be valid JSON");
    std::fs::write("BENCH_sim_throughput.json", &json).expect("write BENCH_sim_throughput.json");
    println!(
        "wrote BENCH_sim_throughput.json ({} kernels x jit {:?} x threads {:?})",
        KERNELS.len(),
        JITS,
        THREADS
    );

    if sgemm_jit_speedup < 1.0 {
        eprintln!(
            "FAIL: jit-on sgemm wall is {sgemm_jit_speedup:.3}x the jit-off run at 1 thread \
             (gate: >= 1.0x best-of-{REPEATS})"
        );
        failed = true;
    }
    if sgemm_speedup_4t < 1.0 {
        eprintln!(
            "FAIL: 4-thread jit-on sgemm throughput is {sgemm_speedup_4t:.3}x the 1-thread run \
             (gate: >= 1.0x best-of-{REPEATS})"
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!(
        "PASS: all jit/thread configurations bit-identical; sgemm jit speedup {:.2}x, \
         4-thread scaling {:.2}x",
        sgemm_jit_speedup, sgemm_speedup_4t
    );
}
