//! Bench harness regenerating Figure 8 (speedups across the ladder, plus
//! the memory-request-density series behind the ZiCond discussion).
//! Run: cargo bench --bench fig8_speedup

use std::time::Instant;
use volt::coordinator::{experiments, report};

fn main() {
    let t0 = Instant::now();
    let rows = experiments::ladder_sweep(None).expect("sweep");
    print!("{}", report::render_ladder_fig8(&rows));
    let g = experiments::geomean(rows.iter().map(|r| r.speedup(5)));
    println!("\ngeomean speedup (Recon vs Base): {g:.3}x");
    let g3 = experiments::geomean(rows.iter().map(|r| r.speedup(6)));
    println!("geomean speedup (O3 vs Base): {g3:.3}x");
    println!("sweep wall time: {:.2}s", t0.elapsed().as_secs_f64());
}
