//! Bench harness regenerating Figure 10 (shared-memory mapping x cache
//! configuration sweep over the shared-memory kernels).
//! Run: cargo bench --bench fig10_memory_config

use volt::coordinator::{experiments, report};

fn main() {
    let rows = experiments::memory_config_sweep().expect("sweep");
    print!("{}", report::render_fig10(&rows));
}
