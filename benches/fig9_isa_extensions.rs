//! Bench harness regenerating Figure 9 (vote/shuffle/bscan/atomic-agg/gc
//! with hardware warp ISA vs software emulation).
//! Run: cargo bench --bench fig9_isa_extensions

use volt::coordinator::{experiments, report};

fn main() {
    let rows = experiments::isa_extension_sweep().expect("sweep");
    print!("{}", report::render_fig9(&rows));
    let g = experiments::geomean(rows.iter().map(|r| r.speedup()));
    println!("geomean HW-vs-SW speedup: {g:.2}x");
}
