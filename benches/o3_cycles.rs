//! O3 acceptance gate: run the full 28-kernel corpus through the simulator
//! at Recon and at O3 on the target named by `VOLT_TARGET` (default
//! `vortex`), write the per-target BENCH_cycles artifact, and fail
//! (non-zero exit) on any validation failure. Every run executes the
//! kernel's host-side validator, so a miscompiling optimization cannot
//! trade correctness for cycles.
//!
//! Gates:
//! * every target — all 28 kernels compile, run, and validate at both
//!   levels (this is the cross-target acceptance: on `vortex-min` the
//!   images are additionally audited to contain no zicond/shfl/vote op);
//! * `vortex` only — O3 achieves a >= 3% geomean cycle reduction with
//!   ZERO kernels regressing (the original single-target perf gate,
//!   unchanged). Other targets report their numbers without a perf gate:
//!   vortex-min has no ZiCond rung to harvest, so its Recon/O3 delta is
//!   a different (smaller) quantity.
//!
//! Run: cargo bench --bench o3_cycles
//!      VOLT_TARGET=vortex-min cargo bench --bench o3_cycles

use volt::coordinator::experiments::{geomean, o3_cycle_sweep_on};
use volt::coordinator::report;
use volt::target::TargetDesc;

fn main() {
    let target_name = std::env::var("VOLT_TARGET").unwrap_or_else(|_| "vortex".into());
    let target = TargetDesc::by_name(&target_name).unwrap_or_else(|| {
        eprintln!(
            "unknown VOLT_TARGET '{target_name}' (built-in: {})",
            TargetDesc::BUILTIN_NAMES.join(", ")
        );
        std::process::exit(2);
    });
    let rows = o3_cycle_sweep_on(&target)
        .unwrap_or_else(|e| panic!("o3 sweep on {} (includes per-kernel validators): {e}", target.name));
    print!("{}", report::render_o3_cycles(&rows));

    let json = report::json_o3_cycles(&rows, target.name);
    let path = if target.name == "vortex" {
        "BENCH_cycles.json".to_string()
    } else {
        format!("BENCH_cycles.{}.json", target.name)
    };
    std::fs::write(&path, &json).expect("write BENCH_cycles artifact");
    println!("wrote {path} ({} kernels, target {})", rows.len(), target.name);

    let g = geomean(rows.iter().map(|r| r.cycle_reduction()));
    if target.name != "vortex" {
        println!(
            "PASS: {} kernels validated at Recon and O3 on {} (geomean {:.3}x, no perf gate)",
            rows.len(),
            target.name,
            g
        );
        return;
    }
    let regressions: Vec<&str> = rows
        .iter()
        .filter(|r| r.regressed())
        .map(|r| r.name)
        .collect();
    let mut failed = false;
    if !regressions.is_empty() {
        eprintln!("FAIL: O3 regressed vs Recon on: {}", regressions.join(", "));
        failed = true;
    }
    if g < 1.03 {
        eprintln!(
            "FAIL: geomean cycle reduction {:.3}x is below the 1.03x gate",
            g
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!("PASS: geomean {:.3}x, no regressions", g);
}
