//! O3 acceptance gate: run the full 28-kernel corpus through the simulator
//! at Recon and at O3, write BENCH_cycles.json, and fail (non-zero exit)
//! unless O3 achieves a >= 3% geomean cycle reduction with ZERO kernels
//! regressing. Every run also executes the kernel's host-side validator,
//! so a miscompiling optimization cannot trade correctness for cycles.
//! Run: cargo bench --bench o3_cycles

use volt::coordinator::experiments::{geomean, o3_cycle_sweep};
use volt::coordinator::report;

fn main() {
    let rows = o3_cycle_sweep().expect("o3 sweep (includes per-kernel validators)");
    print!("{}", report::render_o3_cycles(&rows));

    let json = report::json_o3_cycles(&rows);
    std::fs::write("BENCH_cycles.json", &json).expect("write BENCH_cycles.json");
    println!("wrote BENCH_cycles.json ({} kernels)", rows.len());

    let regressions: Vec<&str> = rows
        .iter()
        .filter(|r| r.regressed())
        .map(|r| r.name)
        .collect();
    let g = geomean(rows.iter().map(|r| r.cycle_reduction()));
    let mut failed = false;
    if !regressions.is_empty() {
        eprintln!("FAIL: O3 regressed vs Recon on: {}", regressions.join(", "));
        failed = true;
    }
    if g < 1.03 {
        eprintln!(
            "FAIL: geomean cycle reduction {:.3}x is below the 1.03x gate",
            g
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!("PASS: geomean {:.3}x, no regressions", g);
}
