//! O3 acceptance gate: run the full 28-kernel corpus through the simulator
//! at Recon and at O3 on the target named by `VOLT_TARGET` (default
//! `vortex`), write the per-target BENCH_cycles artifact, and fail
//! (non-zero exit) on any validation failure. Every run executes the
//! kernel's host-side validator, so a miscompiling optimization cannot
//! trade correctness for cycles.
//!
//! Gates:
//! * every target — all 28 kernels compile, run, and validate at both
//!   levels (this is the cross-target acceptance: on `vortex-min` the
//!   images are additionally audited to contain no zicond/shfl/vote op);
//! * `vortex` only — O3 achieves a >= 5% geomean cycle reduction with
//!   ZERO kernels regressing. The bar was 3% when O3 was the middle-end
//!   rung alone; the backend codegen rung (MIR combine, coalescing
//!   spill-aware regalloc) also rides the O3 ladder point, and its
//!   harvest raises the gate. Other targets gate on validators plus
//!   zero per-kernel regressions (no geomean bar: vortex-min has no
//!   ZiCond rung to harvest, so its Recon/O3 delta is a different,
//!   smaller quantity).
//!
//! Per-kernel rows carry dynamic-instruction and static spill-traffic
//! columns (recon_spills / o3_spills in the JSON) so instruction-count
//! and spill regressions are visible even when cycles hide them.
//!
//! Run: cargo bench --bench o3_cycles
//!      VOLT_TARGET=vortex-min cargo bench --bench o3_cycles

use volt::coordinator::experiments::{geomean, o3_cycle_sweep_on};
use volt::coordinator::report;
use volt::target::TargetDesc;

fn main() {
    let target_name = std::env::var("VOLT_TARGET").unwrap_or_else(|_| "vortex".into());
    let target = TargetDesc::by_name(&target_name).unwrap_or_else(|| {
        eprintln!(
            "unknown VOLT_TARGET '{target_name}' (built-in: {})",
            TargetDesc::BUILTIN_NAMES.join(", ")
        );
        std::process::exit(2);
    });
    let rows = o3_cycle_sweep_on(&target)
        .unwrap_or_else(|e| panic!("o3 sweep on {} (includes per-kernel validators): {e}", target.name));
    print!("{}", report::render_o3_cycles(&rows));

    let json = report::json_o3_cycles(&rows, target.name);
    let path = if target.name == "vortex" {
        "BENCH_cycles.json".to_string()
    } else {
        format!("BENCH_cycles.{}.json", target.name)
    };
    std::fs::write(&path, &json).expect("write BENCH_cycles artifact");
    println!("wrote {path} ({} kernels, target {})", rows.len(), target.name);

    let g = geomean(rows.iter().map(|r| r.cycle_reduction()));
    let regressions: Vec<&str> = rows
        .iter()
        .filter(|r| r.regressed())
        .map(|r| r.name)
        .collect();
    if target.name != "vortex" {
        // Non-vortex targets: validators + zero per-kernel regressions
        // (the backend rung rides O3 on every target; no geomean bar —
        // vortex-min has no ZiCond rung to harvest).
        if !regressions.is_empty() {
            eprintln!(
                "FAIL: O3 regressed vs Recon on {}: {}",
                target.name,
                regressions.join(", ")
            );
            std::process::exit(1);
        }
        println!(
            "PASS: {} kernels validated at Recon and O3 on {} (geomean {:.3}x, no regressions, \
             no geomean gate)",
            rows.len(),
            target.name,
            g
        );
        return;
    }
    let mut failed = false;
    if !regressions.is_empty() {
        eprintln!("FAIL: O3 regressed vs Recon on: {}", regressions.join(", "));
        failed = true;
    }
    if g < 1.05 {
        eprintln!(
            "FAIL: geomean cycle reduction {:.3}x is below the 1.05x gate \
             (middle-end O3 + backend codegen rung)",
            g
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!("PASS: geomean {:.3}x, no regressions", g);
}
