//! Bench: the session binary cache makes recompiles near-free.
//!
//! Compiles the whole benchmark-suite source set cold (fresh session per
//! pass) and warm (one session, repeated compiles), and reports the
//! speedup. The ISSUE-1 acceptance bar is >= 10x on identical-source
//! recompiles; in practice a hit is a hash + HashMap lookup and lands
//! orders of magnitude beyond that.
//!
//! A third section gates the persistent tier (ISSUE 8): fresh sessions
//! pointed at a warm cache directory must serve every compile from disk
//! (no recompiles, no mem hits) at >= 5x over cold.
//!
//! Run: cargo bench --bench recompile_cache

use std::time::Instant;
use volt::coordinator::benchmarks;
use volt::driver::{Session, VoltOptions};

fn main() {
    let sources: Vec<(&str, &str)> = benchmarks::registry()
        .into_iter()
        .map(|b| (b.name, b.source))
        .collect();
    let opts_for = |b: &str| {
        let bench = benchmarks::find(b).unwrap();
        VoltOptions {
            dialect: bench.dialect,
            ..VoltOptions::default()
        }
    };

    // Cold: a fresh session per compile — every compile is a miss.
    let passes = 3u32;
    let t0 = Instant::now();
    for _ in 0..passes {
        for (name, src) in &sources {
            let s = Session::new(opts_for(name));
            s.compile(src).expect(name);
        }
    }
    let cold = t0.elapsed().as_secs_f64();

    // Warm: one session per kernel source, compile once to populate, then
    // time the repeated compiles (all hits).
    let sessions: Vec<Session> = sources
        .iter()
        .map(|(name, src)| {
            let s = Session::new(opts_for(name));
            s.compile(src).expect(name);
            s
        })
        .collect();
    let t1 = Instant::now();
    for _ in 0..passes {
        for (s, (name, src)) in sessions.iter().zip(&sources) {
            s.compile(src).expect(name);
        }
    }
    let warm = t1.elapsed().as_secs_f64();

    let n = sources.len() as u32 * passes;
    println!(
        "cold: {n} compiles in {:.3}s ({:.2} ms each)",
        cold,
        cold * 1e3 / n as f64
    );
    println!(
        "warm: {n} cache hits in {:.6}s ({:.4} ms each)",
        warm,
        warm * 1e3 / n as f64
    );
    let speedup = cold / warm.max(1e-9);
    println!("cached-recompile speedup: {speedup:.0}x");
    assert!(
        speedup >= 10.0,
        "cache must be at least 10x faster than cold compiles (got {speedup:.1}x)"
    );
    for s in &sessions {
        let st = s.cache_stats();
        assert_eq!(st.hits, passes as u64);
        assert_eq!(st.misses, 1);
    }
    println!("OK: every warm compile was a cache hit");

    // Disk tier: warm the persistent cache once, then time fresh
    // sessions (empty mem tier) against the same directory — every
    // compile must come back from disk, never the pipeline.
    let dir = std::env::temp_dir().join(format!("volt-bench-dc-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    for (name, src) in &sources {
        let s = Session::with_disk_cache(opts_for(name), &dir, 0);
        s.compile(src).expect(name);
    }
    let t2 = Instant::now();
    for _ in 0..passes {
        for (name, src) in &sources {
            let s = Session::with_disk_cache(opts_for(name), &dir, 0);
            s.compile(src).expect(name);
            let st = s.cache_stats();
            assert_eq!(st.disk_hits, 1, "{name}: expected a disk hit");
            assert_eq!(st.misses, 0, "{name}: warm disk tier must not recompile");
            assert_eq!(st.hits, 0, "{name}: fresh session has no mem tier to hit");
        }
    }
    let disk = t2.elapsed().as_secs_f64();
    let _ = std::fs::remove_dir_all(&dir);
    println!(
        "disk: {n} disk hits in {:.3}s ({:.3} ms each)",
        disk,
        disk * 1e3 / n as f64
    );
    let disk_speedup = cold / disk.max(1e-9);
    println!("disk-tier speedup: {disk_speedup:.1}x");
    assert!(
        disk_speedup >= 5.0,
        "disk tier must be at least 5x faster than cold compiles (got {disk_speedup:.1}x)"
    );
    println!("OK: every disk-tier compile was served from the persistent cache");
}
