//! Bench harness for the compile-time-overhead claim (§5.2: "0.18%
//! compile-time geomean slowdown"). Best-of-5 per benchmark per config.
//! Run: cargo bench --bench compile_time

use volt::coordinator::{experiments, report};

fn main() {
    let rows = experiments::compile_time_sweep(5).expect("sweep");
    print!("{}", report::render_compile_time(&rows));
}
