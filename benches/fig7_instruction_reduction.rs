//! Bench harness regenerating Figure 7 (instruction-reduction factors
//! across the optimization ladder) and timing the sweep.
//! Run: cargo bench --bench fig7_instruction_reduction

use std::time::Instant;
use volt::coordinator::{experiments, report};

fn main() {
    let t0 = Instant::now();
    let rows = experiments::ladder_sweep(None).expect("sweep");
    let dt = t0.elapsed();
    print!("{}", report::render_ladder_fig7(&rows));
    println!(
        "\nsweep wall time: {:.2}s ({} benchmarks x {} levels)",
        dt.as_secs_f64(),
        rows.len(),
        volt::transform::OptLevel::LADDER.len()
    );
    let g = experiments::geomean(rows.iter().map(|r| r.reduction(5)));
    println!("geomean instruction-reduction (Recon vs Base): {g:.3}x");
    let g3 = experiments::geomean(rows.iter().map(|r| r.reduction(6)));
    println!("geomean instruction-reduction (O3 vs Base): {g3:.3}x");
}
