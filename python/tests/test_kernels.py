"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle, swept over
shapes with hypothesis. This is the build-time gate for the AOT artifacts
the Rust runtime executes."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import elementwise, matmul, reduce as red, ref, transpose

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")


def rnd(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


def assert_close(a, b, tol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    a = rnd(seed, m, k)
    b = rnd(seed + 1, k, n)
    assert_close(matmul.matmul(a, b), ref.matmul(a, b), tol=1e-4)


@given(st.sampled_from([8, 16, 64, 128, 256]), st.integers(0, 2**16))
def test_matmul_block_shapes(n, seed):
    a = rnd(seed, n, n)
    b = rnd(seed + 1, n, n)
    for bm in (8, 16, 128):
        assert_close(matmul.matmul(a, b, bm=bm, bn=bm), ref.matmul(a, b), tol=1e-4)


@given(n=st.integers(1, 2048), seed=st.integers(0, 2**16))
def test_vecadd(n, seed):
    a = rnd(seed, n)
    b = rnd(seed + 1, n)
    assert_close(elementwise.vecadd(a, b), ref.vecadd(a, b))


@given(n=st.integers(1, 2048), a=st.floats(-8, 8), seed=st.integers(0, 2**16))
def test_saxpy(n, a, seed):
    av = jnp.array([a], dtype=jnp.float32)
    x = rnd(seed, n)
    y = rnd(seed + 1, n)
    assert_close(elementwise.saxpy(av, x, y), ref.saxpy(av, x, y), tol=1e-4)


@given(n=st.integers(1, 1024), s=st.floats(-4, 4), seed=st.integers(0, 2**16))
def test_scale(n, s, seed):
    x = rnd(seed, n)
    sv = jnp.array([s], dtype=jnp.float32)
    assert_close(elementwise.scale(x, sv), ref.scale(x, sv))


@given(m=st.integers(1, 96), n=st.integers(1, 96), seed=st.integers(0, 2**16))
def test_transpose(m, n, seed):
    x = rnd(seed, m, n)
    assert_close(transpose.transpose(x), ref.transpose(x))


@given(
    blocks=st.integers(1, 32),
    block=st.sampled_from([8, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_block_sums(blocks, block, seed):
    x = rnd(seed, blocks * block)
    assert_close(
        red.block_sums(x, block=block), ref.block_sums(x, block=block), tol=1e-4
    )
    assert_close(
        red.total_sum(x, block=block), ref.total_sum(x, block=block), tol=1e-3
    )


def test_model_registry_shapes():
    from compile.model import REGISTRY

    for name, (fn, specs) in REGISTRY.items():
        out = jax.eval_shape(fn, *specs)
        assert out.dtype == jnp.float32, name
        # Executing with zeros must succeed in interpret mode.
        args = [jnp.zeros(s.shape, s.dtype) for s in specs]
        val = fn(*args)
        assert val.shape == out.shape, name


def test_gemm_bias_relu_composition():
    from compile.model import gemm_bias_relu

    a = rnd(3, 16, 16)
    b = rnd(4, 16, 16)
    bias = rnd(5, 16)
    got = gemm_bias_relu(a, b, bias)
    want = jnp.maximum(jnp.dot(a, b) + bias[None, :], 0.0)
    assert_close(got, want, tol=1e-4)
    assert float(jnp.min(got)) >= 0.0


def test_hlo_text_is_parseable_form():
    """The interchange contract: HLO text (not serialized protos)."""
    from compile.aot import to_hlo_text
    from compile.model import REGISTRY

    fn, specs = REGISTRY["matmul16"]
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    assert "HloModule" in text
    assert "f32[16,16]" in text
