"""Layer-2 JAX reference-executor suite.

Composes the Layer-1 Pallas kernels into the reference functions the Rust
coordinator validates device results against (the "reference CPU
implementations" of paper §5). `aot.py` lowers each entry once to HLO
text; the Rust runtime (rust/src/runtime/pjrt.rs) loads and executes them
via PJRT. Python never runs on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import elementwise, matmul as mm, reduce as red, transpose as tp


def matmul(a, b):
    return mm.matmul(a, b)


def vecadd(a, b):
    return elementwise.vecadd(a, b)


def saxpy(a, x, y):
    return elementwise.saxpy(a, x, y)


def scale(x, s):
    return elementwise.scale(x, s)


def transpose(x):
    return tp.transpose(x)


def block_sums(x):
    return red.block_sums(x, block=64)


@jax.jit
def gemm_bias_relu(a, b, bias):
    """L2 composition: Pallas matmul fused with jnp epilogue — the kind of
    model-level graph the paper's §6.2 GEMM/FlashAttention generation
    produces."""
    return jnp.maximum(mm.matmul(a, b) + bias[None, :], 0.0)


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# name -> (fn, example input specs). Shapes match the Rust-side benchmark
# workloads so e2e validation can compare directly.
REGISTRY = {
    "matmul16": (matmul, [f32(16, 16), f32(16, 16)]),
    "matmul24": (matmul, [f32(24, 24), f32(24, 24)]),
    "matmul128": (matmul, [f32(128, 128), f32(128, 128)]),
    "vecadd1000": (vecadd, [f32(1000), f32(1000)]),
    "saxpy777": (saxpy, [f32(1), f32(777), f32(777)]),
    "scale512": (scale, [f32(512), f32(1)]),
    "transpose24": (transpose, [f32(24, 24)]),
    "blocksum512": (block_sums, [f32(512)]),
    "gemm_bias_relu16": (gemm_bias_relu, [f32(16, 16), f32(16, 16), f32(16)]),
}
