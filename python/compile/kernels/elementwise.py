"""Layer-1 Pallas kernels: element-wise reference ops (vecadd, saxpy,
scale) with 1-D BlockSpec tiling."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, want: int) -> int:
    b = min(n, want)
    while n % b != 0:
        b -= 1
    return b


def _vecadd_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


@jax.jit
def vecadd(a, b):
    n = a.shape[0]
    bs = _pick_block(n, 512)
    return pl.pallas_call(
        _vecadd_kernel,
        grid=(n // bs,),
        in_specs=[
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(a, b)


def _saxpy_kernel(a_ref, x_ref, y_ref, o_ref):
    o_ref[...] = a_ref[0] * x_ref[...] + y_ref[...]


@jax.jit
def saxpy(a, x, y):
    """a is a (1,)-shaped array (scalar broadcast through VMEM)."""
    n = x.shape[0]
    bs = _pick_block(n, 512)
    return pl.pallas_call(
        _saxpy_kernel,
        grid=(n // bs,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(a, x, y)


def _scale_kernel(x_ref, s_ref, o_ref):
    o_ref[...] = x_ref[...] * s_ref[0]


@jax.jit
def scale(x, s):
    n = x.shape[0]
    bs = _pick_block(n, 512)
    return pl.pallas_call(
        _scale_kernel,
        grid=(n // bs,),
        in_specs=[
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, s)
