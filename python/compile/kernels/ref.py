"""Pure-jnp correctness oracles for the Pallas kernels (the pytest
comparison target — the CORE build-time correctness signal)."""

import jax.numpy as jnp


def matmul(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def vecadd(a, b):
    return a + b


def saxpy(a, x, y):
    return a[0] * x + y


def scale(x, s):
    return x * s[0]


def transpose(x):
    return x.T


def block_sums(x, block=64):
    return x.reshape(-1, block).sum(axis=1)


def total_sum(x, block=64):
    return jnp.sum(x, keepdims=True)


def gemm_bias_relu(a, b, bias):
    return jnp.maximum(jnp.dot(a, b) + bias, 0.0)
