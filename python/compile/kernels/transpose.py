"""Layer-1 Pallas kernel: blocked 2-D transpose."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, want: int) -> int:
    b = min(n, want)
    while n % b != 0:
        b -= 1
    return b


def _transpose_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


@jax.jit
def transpose(x):
    m, n = x.shape
    bm = _pick_block(m, 128)
    bn = _pick_block(n, 128)
    return pl.pallas_call(
        _transpose_kernel,
        grid=(n // bn, m // bm),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (j, i))],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(x)
