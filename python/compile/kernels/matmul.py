"""Layer-1 Pallas kernel: tiled matrix multiply.

The compute hot-spot of the reference-executor suite. Written
TPU-idiomatically: BlockSpec expresses the HBM->VMEM tile schedule, the
inner contraction hits the MXU via `jnp.dot` with an f32 accumulator.
`interpret=True` everywhere — the CPU PJRT plugin cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md); real-TPU performance is
estimated structurally in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    # One (bm, K) x (K, bn) tile product per grid step, accumulated in f32.
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block(dim: int, want: int) -> int:
    """Largest divisor of dim that is <= want (TPU-friendly when possible)."""
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(a, b, bm: int = 128, bn: int = 128):
    """C = A @ B via a Pallas grid over output tiles.

    VMEM per grid step: bm*K + K*bn + bm*bn floats — sized for the 16 MiB
    VMEM budget at the default 128x128 tiles up to K=8192.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"shape mismatch {a.shape} @ {b.shape}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
