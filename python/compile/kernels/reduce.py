"""Layer-1 Pallas kernel: per-block sums (the reduce0 reference)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _blocksum_kernel(x_ref, o_ref):
    o_ref[...] = jnp.sum(x_ref[...], keepdims=True)


@functools.partial(jax.jit, static_argnames=("block",))
def block_sums(x, block: int = 64):
    n = x.shape[0]
    assert n % block == 0, f"{n} not divisible by block {block}"
    return pl.pallas_call(
        _blocksum_kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n // block,), jnp.float32),
        interpret=True,
    )(x)


@functools.partial(jax.jit, static_argnames=("block",))
def total_sum(x, block: int = 64):
    """L2 composition: Pallas partials + jnp final reduction."""
    return jnp.sum(block_sums(x, block=block), keepdims=True)
