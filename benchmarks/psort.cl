// Odd-even transposition sort: one phase per launch, each work-item
// compares-and-swaps one adjacent pair.
kernel void psort(global uint* d, int n, int phase) {
    int t = get_global_id(0);
    int i = 2 * t + (phase % 2);
    if (i + 1 < n) {
        uint x = d[i];
        uint y = d[i + 1];
        if (x > y) {
            d[i] = y;
            d[i + 1] = x;
        }
    }
}
