// NVIDIA SDK style vector addition.
kernel void vecadd(global float* a, global float* b, global float* c, int n) {
    int i = get_global_id(0);
    if (i < n) {
        c[i] = a[i] + b[i];
    }
}
