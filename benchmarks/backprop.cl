// Rodinia backprop forward layer: weighted sum per output unit followed
// by the logistic activation.
kernel void backprop(global float* w, global float* in, global float* out,
                     global int* dims) {
    int o = get_global_id(0);
    int in_n = dims[0];
    int out_n = dims[1];
    if (o < out_n) {
        float s = 0.0f;
        for (int i = 0; i < in_n; i++) {
            s += w[o * in_n + i] * in[i];
        }
        out[o] = 1.0f / (1.0f + exp(-s));
    }
}
