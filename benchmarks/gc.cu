// HeCBench-style graph-coloring conflict detection over a CSR graph; the
// warp votes so conflict-free warps take the cheap uniform path.
__global__ void gc(unsigned* row_off, unsigned* cols, unsigned* color,
                   unsigned* conflict, int n) {
    int u = blockIdx.x * blockDim.x + threadIdx.x;
    if (u < n) {
        int c = 0;
        for (int e = (int)row_off[u]; e < (int)row_off[u + 1]; e++) {
            int v = (int)cols[e];
            if (v < u && color[v] == color[u]) {
                c = 1;
            }
        }
        int w = __any(c);
        if (w != 0) {
            conflict[u] = c;
        } else {
            conflict[u] = 0;
        }
    }
}
