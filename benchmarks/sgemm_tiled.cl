// Parboil-style tiled matrix multiply with 8x8 shared-memory tiles.
// C[n x n] = A[n x n] * B[n x n]; n must be a multiple of 8.
kernel void sgemm_tiled(global float* a, global float* b, global float* c,
                        int n) {
    local float ta[64];
    local float tb[64];
    int lx = get_local_id(0);
    int ly = get_local_id(1);
    int col = get_global_id(0);
    int row = get_global_id(1);
    float s = 0.0f;
    for (int t = 0; t < n; t += 8) {
        ta[ly * 8 + lx] = a[row * n + (t + lx)];
        tb[ly * 8 + lx] = b[(t + ly) * n + col];
        barrier(0);
        for (int kk = 0; kk < 8; kk++) {
            s += ta[ly * 8 + kk] * tb[kk * 8 + lx];
        }
        barrier(0);
    }
    c[row * n + col] = s;
}
