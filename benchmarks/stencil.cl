// Parboil-style 1D 3-point stencil staged through shared memory with
// halo cells; zero boundary condition.
kernel void stencil(global float* in, global float* out, int n) {
    local float tile[66];
    int l = get_local_id(0);
    int i = get_global_id(0);
    tile[l + 1] = (i < n) ? in[i] : 0.0f;
    if (l == 0) {
        tile[0] = (i > 0) ? in[i - 1] : 0.0f;
    }
    if (l == 63) {
        tile[65] = (i + 1 < n) ? in[i + 1] : 0.0f;
    }
    barrier(0);
    if (i < n) {
        out[i] = 0.25f * tile[l] + 0.5f * tile[l + 1] + 0.25f * tile[l + 2];
    }
}
