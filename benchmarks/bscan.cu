// HeCBench-style binary warp scan: each lane counts how many lower lanes
// of its warp have the flag set (ballot + mask + popcount).
__global__ void bscan(unsigned* flags, unsigned* r, int n) {
    int i = blockIdx.x * blockDim.x + threadIdx.x;
    if (i < n) {
        int p = flags[i] != 0;
        unsigned b = __ballot(p);
        unsigned mask = (1u << lane_id()) - 1u;
        unsigned low = b & mask;
        int cnt = 0;
        for (int k = 0; k < 32; k++) {
            cnt += (int)((low >> k) & 1u);
        }
        r[i] = cnt;
    }
}
