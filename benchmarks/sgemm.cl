// Parboil-style dense matrix multiply: C[n x m] = A[n x k] * B[k x m].
kernel void sgemm(global float* a, global float* b, global float* c,
                  int n, int m, int k) {
    int col = get_global_id(0);
    int row = get_global_id(1);
    if (row < n && col < m) {
        float s = 0.0f;
        for (int t = 0; t < k; t++) {
            s += a[row * k + t] * b[t * m + col];
        }
        c[row * m + col] = s;
    }
}
