// Rodinia nearest-neighbor: Euclidean distance from every record to the
// query point.
kernel void nearn(global float* lat, global float* lon, global float* d,
                  int n, float qlat, float qlon) {
    int i = get_global_id(0);
    if (i < n) {
        float dy = lat[i] - qlat;
        float dx = lon[i] - qlon;
        d[i] = sqrt(dy * dy + dx * dx);
    }
}
