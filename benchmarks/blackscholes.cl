// SDK Black-Scholes call pricing with the Abramowitz-Stegun polynomial
// approximation of the cumulative normal distribution.
float cnd(float d) {
    float k = 1.0f / (1.0f + 0.2316419f * fabs(d));
    float w = ((((1.330274429f * k - 1.821255978f) * k + 1.781477937f) * k
                - 0.356563782f) * k + 0.31938153f) * k;
    float p = 1.0f - 0.3989422804f * exp(-0.5f * d * d) * w;
    return d < 0.0f ? 1.0f - p : p;
}

kernel void blackscholes(global float* s, global float* x, global float* t,
                         global float* c, int n, float r, float v) {
    int i = get_global_id(0);
    if (i < n) {
        float sq = sqrt(t[i]);
        float d1 = (log(s[i] / x[i]) + (r + 0.5f * v * v) * t[i]) / (v * sq);
        float d2 = d1 - v * sq;
        c[i] = s[i] * cnd(d1) - x[i] * exp(-r * t[i]) * cnd(d2);
    }
}
