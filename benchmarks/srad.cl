// Rodinia SRAD (speckle-reducing anisotropic diffusion), simplified to
// its per-pixel update: exponential diffusion coefficient times the
// directional derivative.
kernel void srad(global float* img, global float* out, int n, float lambda) {
    int i = get_global_id(0);
    if (i < n) {
        float v = img[i];
        float g = exp(-fabs(v) * lambda);
        out[i] = v + 0.25f * g * (v * 0.5f - v);
    }
}
