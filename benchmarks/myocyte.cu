// Rodinia myocyte, reduced to its per-cell ODE step: exponential rate
// damping plus linear leak, integrated with forward Euler in place.
__global__ void myocyte(float* state, float* rate, int n, float dt) {
    int i = blockIdx.x * blockDim.x + threadIdx.x;
    if (i < n) {
        float s = state[i];
        float dv = rate[i] * expf(-fabsf(s) * 0.1f) - s * 0.05f;
        state[i] = s + dt * dv;
    }
}
