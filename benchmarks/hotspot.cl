// Rodinia hotspot: one explicit-Euler step of the thermal simulation on
// an n x n grid with clamped boundaries.
kernel void hotspot(global float* temp, global float* power,
                    global float* out, int n, float cap) {
    int x = get_global_id(0);
    int y = get_global_id(1);
    if (x < n && y < n) {
        int idx = y * n + x;
        float c = temp[idx];
        float l = (x > 0) ? temp[idx - 1] : c;
        float r = (x < n - 1) ? temp[idx + 1] : c;
        float u = (y > 0) ? temp[idx - n] : c;
        float d = (y < n - 1) ? temp[idx + n] : c;
        out[idx] = c + cap * (power[idx] + (l + r + u + d - 4.0f * c));
    }
}
