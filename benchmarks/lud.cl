// Rodinia LU decomposition (Doolittle, in place): one pivot column per
// launch; each work-item eliminates one row below the pivot and stores
// the multiplier in the L part.
kernel void lud(global float* m, int n, int k) {
    int r = get_global_id(0);
    if (r > k && r < n) {
        float f = m[r * n + k] / m[k * n + k];
        m[r * n + k] = f;
        for (int c = k + 1; c < n; c++) {
            m[r * n + c] -= f * m[k * n + c];
        }
    }
}
