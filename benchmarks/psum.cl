// Per-workgroup inclusive prefix sum (Hillis-Steele) through shared
// memory; each group of 64 work-items scans its contiguous slice.
kernel void psum(global uint* in, global uint* out, int n) {
    local uint buf[64];
    int l = get_local_id(0);
    int base = get_group_id(0) * 64;
    buf[l] = in[base + l];
    barrier(0);
    for (int off = 1; off < 64; off = off * 2) {
        uint v = 0;
        if (l >= off) {
            v = buf[l - off];
        }
        barrier(0);
        buf[l] = buf[l] + v;
        barrier(0);
    }
    out[base + l] = buf[l];
}
