// Rodinia pathfinder: dynamic-programming row relaxation; one row of the
// cost grid per launch.
kernel void pathfinder(global int* prev, global int* cur, global int* wall,
                       int cols, int row) {
    int c = get_global_id(0);
    if (c < cols) {
        int left = (c > 0) ? prev[c - 1] : prev[c];
        int up = prev[c];
        int right = (c < cols - 1) ? prev[c + 1] : prev[c];
        int m = min(left, up);
        m = min(m, right);
        cur[c] = wall[row * cols + c] + m;
    }
}
