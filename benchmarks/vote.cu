// HeCBench-style warp-vote microkernel: every lane publishes whether its
// whole warp is / has any positive element (Fig. 9 ISA-extension axis).
__global__ void vote(unsigned* d, unsigned* o, int n) {
    int i = blockIdx.x * blockDim.x + threadIdx.x;
    if (i < n) {
        int p = d[i] > 0;
        int all = __all(p);
        int any = __any(p);
        o[i] = all * 2 + any;
    }
}
