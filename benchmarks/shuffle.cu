// HeCBench-style warp reduction via butterfly shuffles: lane 0 of each
// warp writes the warp's sum.
__global__ void shuffle(float* in, float* out, int n) {
    int i = blockIdx.x * blockDim.x + threadIdx.x;
    float v = (i < n) ? in[i] : 0.0f;
    for (int off = 16; off > 0; off = off / 2) {
        int src = lane_id() ^ off;
        v += __shfl(v, src);
    }
    if (i % 32 == 0) {
        out[i / 32] = v;
    }
}
