// Rodinia-style level-synchronous BFS over a CSR graph: one launch per
// frontier level; the host loops until no vertex is newly visited.
kernel void bfs(global uint* row_off, global uint* cols, global int* levels,
                global int* flag, int level, int n) {
    int u = get_global_id(0);
    if (u < n && levels[u] == level) {
        for (int e = (int)row_off[u]; e < (int)row_off[u + 1]; e++) {
            int v = (int)cols[e];
            if (levels[v] == -1) {
                levels[v] = level + 1;
                flag[0] = 1;
            }
        }
    }
}
