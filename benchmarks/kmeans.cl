// Rodinia-style k-means assignment step: each point picks the nearest
// center by squared Euclidean distance (first-wins on ties).
kernel void kmeans(global float* pts, global float* centers,
                   global int* assign, global int* params, int n) {
    int i = get_global_id(0);
    if (i < n) {
        int k = params[0];
        int d = params[1];
        float bestd = 1e30f;
        int best = 0;
        for (int c = 0; c < k; c++) {
            float acc = 0.0f;
            for (int j = 0; j < d; j++) {
                float diff = pts[i * d + j] - centers[c * d + j];
                acc += diff * diff;
            }
            if (acc < bestd) {
                bestd = acc;
                best = c;
            }
        }
        assign[i] = best;
    }
}
