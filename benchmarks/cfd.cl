// Rodinia-CFD-flavored flux accumulation written as the original's goto
// state machine — deliberately irreducible control flow the middle-end
// must structurize.
kernel void cfd(global float* flux, global uint* mode, global float* out,
                int n) {
    int i = get_global_id(0);
    float f = 0.0f;
    int m = 0;
    float acc = 0.0f;
    int iter = 0;
    if (i >= n) goto done;
    f = flux[i];
    m = (int)(mode[i] % 4);
    if (m == 0) goto fast;
slow:
    acc = acc + f * 0.5f;
    iter = iter + 1;
    if (iter < m) goto slow;
    if (acc > 4.0f) goto finish;
    goto fast;
fast:
    acc = acc + f;
    iter = iter + 1;
    if (iter < 3 && acc < 8.0f) goto slow;
finish:
    out[i] = acc;
done:
}
