// BUG: the loop trip count is the thread id, so different lanes execute
// the in-loop barrier a different number of times and desynchronize.
// volt-check: barrier.divergent-loop
kernel void barrier_divergent_loop(global float* in, global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[l] = in[l];
    for (int i = 0; i < l; i++) {
        barrier(0);
        buf[l] += 1.0f;
    }
    out[l] = buf[l];
}
