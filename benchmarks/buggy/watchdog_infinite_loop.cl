// BUG: the loop condition reads a zero-initialized buffer that the loop
// never writes, so every thread spins forever. No barrier, no shared
// memory, no out-of-bounds access — the static checker rightly finds
// nothing; only the runtime watchdog (LaunchPolicy.watchdog_max_cycles /
// SimConfig.max_cycles) can catch it, naming the kernel and dumping
// per-warp state.
// volt-check: clean (runtime watchdog trap)
kernel void watchdog_infinite_loop(global int* out, int n) {
    int i = get_global_id(0);
    if (i < n) {
        int acc = 0;
        while (out[i] >= 0) {
            acc += 1;
        }
        out[i] = acc;
    }
}
