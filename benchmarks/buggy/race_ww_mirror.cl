// BUG: thread l writes buf[l] and buf[63-l] in the same phase, so
// threads l and 63-l both write each word — write-write race.
// volt-check: race.write-write
kernel void race_ww_mirror(global float* in, global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[l] = in[l];
    buf[63 - l] = in[l];
    barrier(0);
    out[l] = buf[l];
}
