// BUG: only the lower half of the workgroup initializes the tile, but
// every thread reads it back — threads 32..63 read uninitialized local
// memory (which is not zeroed on real hardware).
// volt-check: uninit.local-read
kernel void uninit_read(global float* in, global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    if (l < 32) {
        buf[l] = in[l];
    }
    barrier(0);
    out[l] = buf[l];
}
