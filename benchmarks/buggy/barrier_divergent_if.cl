// BUG: the barrier sits under a thread-id-dependent branch, so only half
// the workgroup reaches it — deadlock on hardware.
// volt-check: barrier.divergence
kernel void barrier_divergent_if(global float* in, global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[l] = in[l];
    if (l < 32) {
        barrier(0);
    }
    out[l] = buf[l];
}
