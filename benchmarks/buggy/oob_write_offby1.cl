// BUG: classic off-by-one halo indexing — thread 63 touches buf[64] of a
// 64-element array.
// volt-check: bounds.local-oob
kernel void oob_write_offby1(global float* in, global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[l + 1] = in[l];
    barrier(0);
    out[l] = buf[l + 1];
}
