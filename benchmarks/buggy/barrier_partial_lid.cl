// BUG: the upper half of the workgroup returns early, so the barrier
// only ever sees the lower half.
// volt-check: barrier.divergence
kernel void barrier_partial_lid(global float* in, global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[l] = in[l];
    if (l >= 32) {
        out[l] = 0.0f;
        return;
    }
    barrier(0);
    out[l] = buf[l];
}
