// BUG: the mirrored read happens in the same barrier phase as the
// writes — thread l reads the word thread 63-l is writing.
// volt-check: race.read-write
kernel void race_rw_missing_barrier(global float* in, global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[l] = in[l];
    out[l] = buf[63 - l];
}
