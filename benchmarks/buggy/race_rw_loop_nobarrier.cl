// BUG: a Hillis-Steele scan missing the second barrier of each round:
// the update write of round i races the gather read of round i+1.
// volt-check: race.read-write
kernel void race_rw_loop_nobarrier(global uint* in, global uint* out) {
    local uint buf[64];
    int l = get_local_id(0);
    buf[l] = in[l];
    barrier(0);
    for (int off = 1; off < 64; off = off * 2) {
        uint v = 0;
        if (l >= off) {
            v = buf[l - off];
        }
        barrier(0);
        buf[l] = buf[l] + v;
    }
    out[l] = buf[l];
}
