// BUG: every thread of the workgroup writes word 0 of the tile in the
// same barrier phase — a classic write-write race.
// volt-check: race.write-write
kernel void race_ww_same_word(global float* in, global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[0] = in[l];
    barrier(0);
    out[l] = buf[0];
}
