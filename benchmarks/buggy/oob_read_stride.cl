// BUG: strided read walks off the end of the tile — threads 32..63 read
// buf[64..126] of a 64-element array.
// volt-check: bounds.local-oob
kernel void oob_read_stride(global float* in, global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[l] = in[l];
    barrier(0);
    out[l] = buf[l * 2];
}
