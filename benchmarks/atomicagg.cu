// HeCBench-style warp-aggregated atomics: the warp elects a leader that
// performs one atomicAdd for all selected lanes, then broadcasts the base
// index with a shuffle; each selected lane adds its intra-warp rank.
__global__ void atomicagg(unsigned* d, unsigned* counter, unsigned* idx,
                          int n) {
    int i = blockIdx.x * blockDim.x + threadIdx.x;
    if (i < n) {
        int p = d[i] > 0;
        unsigned b = __ballot(p);
        int lane = lane_id();
        int rank = 0;
        int total = 0;
        int leader = 0;
        for (int k = 31; k >= 0; k--) {
            if (((b >> k) & 1u) != 0u) {
                total = total + 1;
                if (k < lane) {
                    rank = rank + 1;
                }
                leader = k;
            }
        }
        int base = 0;
        if (p != 0 && lane == leader) {
            base = atomicAdd(counter, total);
        }
        base = __shfl(base, leader);
        if (p != 0) {
            idx[i] = base + rank;
        }
    }
}
