// SDK-style matrix transpose with a fused smoothing term so the access
// pattern is not a pure permutation: out[i][j] = in[j][i] + eps*in[j][i+1].
kernel void transpose(global float* in, global float* out, int n, int mode) {
    int i = get_global_id(0);
    int j = get_global_id(1);
    if (i < n && j < n) {
        int src = j * n + i;
        float v = in[src];
        float w = (i + 1 < n) ? in[src + 1] : v;
        out[i * n + j] = v + w * 0.0001f;
    }
}
