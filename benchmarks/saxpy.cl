// NVIDIA SDK style single-precision a*x + y.
kernel void saxpy(global float* x, global float* y, float a, int n) {
    int i = get_global_id(0);
    if (i < n) {
        y[i] = a * x[i] + y[i];
    }
}
