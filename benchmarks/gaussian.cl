// Rodinia-style Gaussian elimination: one pivot per launch, one row per
// work-item (rows below the pivot are eliminated in parallel).
kernel void gaussian(global float* m, global float* v, int n, int pivot) {
    int r = get_global_id(0);
    if (r > pivot && r < n) {
        float f = m[r * n + pivot] / m[pivot * n + pivot];
        for (int c = pivot; c < n; c++) {
            m[r * n + c] -= f * m[pivot * n + c];
        }
        v[r] -= f * v[pivot];
    }
}
