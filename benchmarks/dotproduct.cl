// SDK-style dot product: fixed-point partial products accumulated with a
// global atomic (the host checks the saturating fcvt.w.s semantics).
kernel void dotproduct(global float* a, global float* b, global int* acc,
                       int n) {
    int i = get_global_id(0);
    if (i < n) {
        atomic_add(acc, (int)(a[i] * b[i] * 256.0f));
    }
}
