// Rodinia Needleman-Wunsch: anti-diagonal wavefront, one diagonal per
// launch; each work-item relaxes one cell.
__global__ void nw(int* score, unsigned* r, int n, int diag, int penalty) {
    int t = blockIdx.x * blockDim.x + threadIdx.x;
    int i = t + 1;
    int j = diag - i;
    if (i < n && j >= 1 && j < n) {
        int up = score[(i - 1) * n + j] - penalty;
        int left = score[i * n + (j - 1)] - penalty;
        int d = score[(i - 1) * n + (j - 1)] + (int)r[i * n + j];
        int m = max(up, left);
        m = max(m, d);
        score[i * n + j] = m;
    }
}
