// SDK-style per-workgroup tree reduction through shared memory.
// Each group of 64 work-items sums its contiguous 64-element slice.
kernel void reduce(global float* in, global float* out, int n) {
    local float buf[64];
    int l = get_local_id(0);
    int g = get_group_id(0);
    int i = g * 64 + l;
    buf[l] = (i < n) ? in[i] : 0.0f;
    barrier(0);
    for (int s = 32; s > 0; s = s / 2) {
        if (l < s) {
            buf[l] += buf[l + s];
        }
        barrier(0);
    }
    if (l == 0) {
        out[g] = buf[0];
    }
}
