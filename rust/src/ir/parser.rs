//! Parser for the textual IR emitted by [`super::printer`]. Round-trips the
//! printer's output; used by tests, golden files, and the `volt ir` CLI.

use super::*;
use std::collections::HashMap;

pub fn parse_module(src: &str) -> Result<Module, String> {
    let mut m = Module::new("parsed");
    let mut lines = src.lines().map(str::trim).filter(|l| !l.is_empty() && !l.starts_with('#')).peekable();
    while let Some(&line) = lines.peek() {
        if let Some(rest) = line.strip_prefix("module ") {
            m.name = rest.trim().trim_matches('"').to_string();
            lines.next();
        } else if line.starts_with("global ") {
            m.globals.push(parse_global(line)?);
            lines.next();
        } else if line.starts_with("func ") {
            let mut body: Vec<String> = vec![lines.next().unwrap().to_string()];
            for l in lines.by_ref() {
                body.push(l.to_string());
                if l == "}" {
                    break;
                }
            }
            m.funcs.push(parse_function(&body)?);
        } else {
            return Err(format!("unexpected line: {line}"));
        }
    }
    Ok(m)
}

fn parse_global(line: &str) -> Result<Global, String> {
    // global @name space size=N align=N [init=hex]
    let toks: Vec<&str> = line.split_whitespace().collect();
    let name = toks
        .get(1)
        .and_then(|t| t.strip_prefix('@'))
        .ok_or("bad global name")?
        .to_string();
    let space = parse_space(toks.get(2).copied().unwrap_or(""))?;
    let mut size = 0;
    let mut align = 4;
    let mut init = None;
    for t in &toks[3..] {
        if let Some(v) = t.strip_prefix("size=") {
            size = v.parse().map_err(|_| "bad size")?;
        } else if let Some(v) = t.strip_prefix("align=") {
            align = v.parse().map_err(|_| "bad align")?;
        } else if let Some(v) = t.strip_prefix("init=") {
            let mut bytes = vec![];
            let chars: Vec<char> = v.chars().collect();
            for ch in chars.chunks(2) {
                let s: String = ch.iter().collect();
                bytes.push(u8::from_str_radix(&s, 16).map_err(|_| "bad init hex")?);
            }
            init = Some(bytes);
        }
    }
    Ok(Global {
        name,
        space,
        size,
        align,
        init,
    })
}

fn parse_space(s: &str) -> Result<AddrSpace, String> {
    match s {
        "global" => Ok(AddrSpace::Global),
        "local" => Ok(AddrSpace::Local),
        "const" => Ok(AddrSpace::Const),
        "private" => Ok(AddrSpace::Private),
        _ => Err(format!("bad address space: {s}")),
    }
}

fn parse_type(s: &str) -> Result<Type, String> {
    match s {
        "void" => Ok(Type::Void),
        "i1" => Ok(Type::I1),
        "i32" => Ok(Type::I32),
        "f32" => Ok(Type::F32),
        _ => {
            if let Some(sp) = s.strip_prefix("ptr.") {
                Ok(Type::Ptr(parse_space(sp)?))
            } else {
                Err(format!("bad type: {s}"))
            }
        }
    }
}

struct FuncParser {
    inst_map: HashMap<u32, InstId>,
    params: Vec<Param>,
}

impl FuncParser {
    fn val(&self, s: &str) -> Result<Val, String> {
        let s = s.trim().trim_end_matches(',');
        if let Some(rest) = s.strip_prefix("%i") {
            let n: u32 = rest.parse().map_err(|_| format!("bad inst ref {s}"))?;
            return self
                .inst_map
                .get(&n)
                .map(|&i| Val::Inst(i))
                .ok_or(format!("undefined %i{n}"));
        }
        if let Some(name) = s.strip_prefix('%') {
            let idx = self
                .params
                .iter()
                .position(|p| p.name == name)
                .ok_or(format!("unknown arg %{name}"))?;
            return Ok(Val::Arg(idx as u32));
        }
        if s == "true" {
            return Ok(Val::cb(true));
        }
        if s == "false" {
            return Ok(Val::cb(false));
        }
        if let Some(hexs) = s.strip_prefix("f0x") {
            let b = u32::from_str_radix(hexs, 16).map_err(|_| format!("bad float {s}"))?;
            return Ok(Val::F(b));
        }
        if let Some(g) = s.strip_prefix("@g") {
            let n: u32 = g.parse().map_err(|_| format!("bad global ref {s}"))?;
            return Ok(Val::G(GlobalId(n)));
        }
        s.parse::<i64>()
            .map(Val::ci)
            .map_err(|_| format!("bad value: {s}"))
    }
}

pub fn parse_function(lines: &[String]) -> Result<Function, String> {
    let header = &lines[0];
    // func @name(params) -> ty [kernel] [internal] [retuniform] [localmem=N] {
    let open = header.find('(').ok_or("missing (")?;
    let close = header.rfind(')').ok_or("missing )")?;
    let name = header[..open]
        .trim()
        .strip_prefix("func @")
        .ok_or("bad func header")?
        .to_string();
    let mut params = vec![];
    let ps = header[open + 1..close].trim();
    if !ps.is_empty() {
        for p in ps.split(',') {
            let toks: Vec<&str> = p.trim().split_whitespace().collect();
            let ty = parse_type(toks[0])?;
            let pname = toks
                .get(1)
                .and_then(|t| t.strip_prefix('%'))
                .ok_or("bad param")?
                .to_string();
            let uniform = toks.contains(&"uniform");
            params.push(Param {
                name: pname,
                ty,
                uniform,
            });
        }
    }
    let tail = &header[close + 1..];
    let tail = tail.trim().strip_prefix("->").ok_or("missing ->")?.trim();
    let ttoks: Vec<&str> = tail.trim_end_matches('{').split_whitespace().collect();
    let ret = parse_type(ttoks[0])?;
    let is_kernel = ttoks.contains(&"kernel");
    let internal = ttoks.contains(&"internal");
    let ret_uniform = ttoks.contains(&"retuniform");
    let local_mem_size = ttoks
        .iter()
        .find_map(|t| t.strip_prefix("localmem="))
        .map(|v| v.parse().unwrap_or(0))
        .unwrap_or(0);

    // Pre-scan: block labels and instruction result labels, in order.
    let body = &lines[1..lines.len() - 1]; // strip trailing '}'
    let mut max_block = 0u32;
    for l in body {
        if let Some(label) = l.strip_suffix(':') {
            if let Some(n) = label.strip_prefix('b') {
                let n: u32 = n.parse().map_err(|_| format!("bad block label {l}"))?;
                max_block = max_block.max(n);
            }
        }
    }
    let mut f = Function {
        name,
        params: params.clone(),
        ret,
        ret_uniform,
        is_kernel,
        linkage: if internal {
            Linkage::Internal
        } else {
            Linkage::External
        },
        blocks: (0..=max_block)
            .map(|i| Block {
                insts: vec![],
                name: format!("b{i}"),
                dead: true, // resurrected when the label appears
            })
            .collect(),
        insts: vec![],
        entry: BlockId(0),
        local_mem_size,
        src_line: 0,
        cfg_version: 0,
        dom_cache: None,
        pdom_cache: None,
    };
    let mut fp = FuncParser {
        inst_map: HashMap::new(),
        params,
    };
    // First pass: create placeholder instructions in block order.
    let mut cur = BlockId(0);
    let mut inst_lines: Vec<(InstId, String)> = vec![];
    for l in body {
        if let Some(label) = l.strip_suffix(':') {
            let n: u32 = label
                .strip_prefix('b')
                .ok_or("bad label")?
                .parse()
                .map_err(|_| "bad label")?;
            cur = BlockId(n);
            f.blocks[cur.idx()].dead = false;
            continue;
        }
        // result label?
        let (label, ty, rest) = if l.starts_with("%i") {
            let eq = l.find('=').ok_or("missing =")?;
            let lhs = l[..eq].trim();
            let colon = lhs.find(':').ok_or("missing result type")?;
            let n: u32 = lhs[2..colon].parse().map_err(|_| "bad result label")?;
            let ty = parse_type(&lhs[colon + 1..])?;
            (Some(n), ty, l[eq + 1..].trim().to_string())
        } else {
            (None, Type::Void, l.to_string())
        };
        let id = f.push_inst(cur, InstKind::Unreachable, ty);
        if let Some(n) = label {
            fp.inst_map.insert(n, id);
        }
        inst_lines.push((id, rest));
    }
    // Second pass: parse kinds.
    for (id, rest) in inst_lines {
        // Suffix annotations, last first: `!loc L:C` then `!uniform`.
        let mut rest = rest.as_str();
        let mut loc = None;
        if let Some(pos) = rest.rfind(" !loc ") {
            let lc = rest[pos + 6..].trim();
            let colon = lc.find(':').ok_or(format!("bad !loc '{lc}'"))?;
            let line: u32 = lc[..colon].parse().map_err(|_| format!("bad !loc '{lc}'"))?;
            let col: u32 = lc[colon + 1..].parse().map_err(|_| format!("bad !loc '{lc}'"))?;
            loc = Some(Loc { line, col });
            rest = rest[..pos].trim_end();
        }
        let uniform_ann = rest.ends_with("!uniform");
        let rest = rest.trim_end_matches("!uniform").trim();
        let kind = parse_kind(&fp, rest)?;
        let inst = f.inst_mut(id);
        inst.kind = kind;
        inst.uniform_ann = uniform_ann;
        inst.loc = loc;
    }
    Ok(f)
}

fn parse_block_ref(s: &str) -> Result<BlockId, String> {
    s.trim()
        .trim_end_matches(',')
        .strip_prefix('b')
        .and_then(|n| n.parse().ok())
        .map(BlockId)
        .ok_or(format!("bad block ref {s}"))
}

fn parse_kind(fp: &FuncParser, s: &str) -> Result<InstKind, String> {
    let (op, rest) = match s.find(' ') {
        Some(i) => (&s[..i], s[i + 1..].trim()),
        None => (s, ""),
    };
    let args = |rest: &str| -> Vec<String> {
        if rest.is_empty() {
            vec![]
        } else {
            rest.split(',').map(|t| t.trim().to_string()).collect()
        }
    };
    if let Some(bop) = op.strip_prefix("bin.") {
        let a = args(rest);
        let opk = match bop {
            "add" => BinOp::Add,
            "sub" => BinOp::Sub,
            "mul" => BinOp::Mul,
            "sdiv" => BinOp::SDiv,
            "srem" => BinOp::SRem,
            "udiv" => BinOp::UDiv,
            "urem" => BinOp::URem,
            "and" => BinOp::And,
            "or" => BinOp::Or,
            "xor" => BinOp::Xor,
            "shl" => BinOp::Shl,
            "lshr" => BinOp::LShr,
            "ashr" => BinOp::AShr,
            "smin" => BinOp::SMin,
            "smax" => BinOp::SMax,
            "fadd" => BinOp::FAdd,
            "fsub" => BinOp::FSub,
            "fmul" => BinOp::FMul,
            "fdiv" => BinOp::FDiv,
            "fmin" => BinOp::FMin,
            "fmax" => BinOp::FMax,
            _ => return Err(format!("bad binop {bop}")),
        };
        return Ok(InstKind::Bin {
            op: opk,
            a: fp.val(&a[0])?,
            b: fp.val(&a[1])?,
        });
    }
    if let Some(uop) = op.strip_prefix("un.") {
        let opk = match uop {
            "not" => UnOp::Not,
            "fneg" => UnOp::FNeg,
            "fsqrt" => UnOp::FSqrt,
            "fabs" => UnOp::FAbs,
            "fexp" => UnOp::FExp,
            "flog" => UnOp::FLog,
            "ffloor" => UnOp::FFloor,
            "sitofp" => UnOp::SiToFp,
            "fptosi" => UnOp::FpToSi,
            "zext" => UnOp::ZExt,
            "trunc" => UnOp::Trunc,
            "ftobits" => UnOp::FToBits,
            "bitstof" => UnOp::BitsToF,
            _ => return Err(format!("bad unop {uop}")),
        };
        return Ok(InstKind::Un {
            op: opk,
            a: fp.val(rest)?,
        });
    }
    if let Some(p) = op.strip_prefix("icmp.") {
        let a = args(rest);
        let pred = match p {
            "eq" => ICmp::Eq,
            "ne" => ICmp::Ne,
            "slt" => ICmp::Slt,
            "sle" => ICmp::Sle,
            "sgt" => ICmp::Sgt,
            "sge" => ICmp::Sge,
            "ult" => ICmp::Ult,
            "uge" => ICmp::Uge,
            _ => return Err(format!("bad icmp {p}")),
        };
        return Ok(InstKind::ICmp {
            pred,
            a: fp.val(&a[0])?,
            b: fp.val(&a[1])?,
        });
    }
    if let Some(p) = op.strip_prefix("fcmp.") {
        let a = args(rest);
        let pred = match p {
            "oeq" => FCmp::Oeq,
            "one" => FCmp::One,
            "olt" => FCmp::Olt,
            "ole" => FCmp::Ole,
            "ogt" => FCmp::Ogt,
            "oge" => FCmp::Oge,
            _ => return Err(format!("bad fcmp {p}")),
        };
        return Ok(InstKind::FCmp {
            pred,
            a: fp.val(&a[0])?,
            b: fp.val(&a[1])?,
        });
    }
    match op {
        "select" => {
            let a = args(rest);
            Ok(InstKind::Select {
                cond: fp.val(&a[0])?,
                t: fp.val(&a[1])?,
                f: fp.val(&a[2])?,
            })
        }
        "alloca" => Ok(InstKind::Alloca {
            size: rest.parse().map_err(|_| "bad alloca size")?,
        }),
        "load" => Ok(InstKind::Load { ptr: fp.val(rest)? }),
        "store" => {
            let a = args(rest);
            Ok(InstKind::Store {
                ptr: fp.val(&a[0])?,
                val: fp.val(&a[1])?,
            })
        }
        "gep" => {
            let a = args(rest);
            Ok(InstKind::Gep {
                base: fp.val(&a[0])?,
                index: fp.val(&a[1])?,
                scale: a[2].parse().map_err(|_| "bad scale")?,
                disp: a[3].parse().map_err(|_| "bad disp")?,
            })
        }
        "call" => {
            let open = rest.find('(').ok_or("bad call")?;
            let close = rest.rfind(')').ok_or("bad call")?;
            let fid: u32 = rest[..open]
                .trim()
                .strip_prefix("@f")
                .ok_or("bad callee")?
                .parse()
                .map_err(|_| "bad callee id")?;
            let inner = rest[open + 1..close].trim();
            let mut vargs = vec![];
            if !inner.is_empty() {
                for a in inner.split(',') {
                    vargs.push(fp.val(a)?);
                }
            }
            Ok(InstKind::Call {
                callee: FuncId(fid),
                args: vargs,
            })
        }
        "phi" => {
            // phi [b0: v], [b1: v]
            let mut incs = vec![];
            for part in rest.split("],") {
                let part = part.trim().trim_start_matches('[').trim_end_matches(']');
                let colon = part.find(':').ok_or("bad phi")?;
                let b = parse_block_ref(&part[..colon])?;
                let v = fp.val(&part[colon + 1..])?;
                incs.push((b, v));
            }
            Ok(InstKind::Phi { incs })
        }
        "br" => Ok(InstKind::Br {
            target: parse_block_ref(rest)?,
        }),
        "condbr" => {
            let a = args(rest);
            Ok(InstKind::CondBr {
                cond: fp.val(&a[0])?,
                t: parse_block_ref(&a[1])?,
                f: parse_block_ref(&a[2])?,
            })
        }
        "splitbr" => {
            let a = args(rest);
            Ok(InstKind::SplitBr {
                cond: fp.val(&a[0])?,
                neg: a[1] == "neg",
                then_b: parse_block_ref(&a[2])?,
                else_b: parse_block_ref(&a[3])?,
                ipdom: parse_block_ref(&a[4])?,
            })
        }
        "predbr" => {
            let a = args(rest);
            Ok(InstKind::PredBr {
                cond: fp.val(&a[0])?,
                mask: fp.val(&a[1])?,
                body: parse_block_ref(&a[2])?,
                exit: parse_block_ref(&a[3])?,
            })
        }
        "ret" => {
            if rest.is_empty() {
                Ok(InstKind::Ret { val: None })
            } else {
                Ok(InstKind::Ret {
                    val: Some(fp.val(rest)?),
                })
            }
        }
        "unreachable" => Ok(InstKind::Unreachable),
        _ => {
            if let Some(iname) = op.strip_prefix("intr.") {
                let a = args(rest);
                let mut vargs = vec![];
                for x in &a {
                    vargs.push(fp.val(x)?);
                }
                let intr = match iname {
                    "barrier" => Intr::Barrier,
                    "atomic.cas" => Intr::AtomicCas,
                    "vote.all" => Intr::VoteAll,
                    "vote.any" => Intr::VoteAny,
                    "ballot" => Intr::Ballot,
                    "shfl" => Intr::Shfl,
                    "join" => Intr::Join,
                    "tmc" => Intr::Tmc,
                    "mask" => Intr::Mask,
                    "printi" => Intr::PrintI,
                    "printf" => Intr::PrintF,
                    _ => {
                        if let Some(w) = iname.strip_prefix("workitem.") {
                            Intr::WorkItem(match w {
                                "global_id" => WorkItem::GlobalId,
                                "local_id" => WorkItem::LocalId,
                                "group_id" => WorkItem::GroupId,
                                "local_size" => WorkItem::LocalSize,
                                "global_size" => WorkItem::GlobalSize,
                                "num_groups" => WorkItem::NumGroups,
                                _ => return Err(format!("bad workitem {w}")),
                            })
                        } else if let Some(c) = iname.strip_prefix("csr.") {
                            Intr::Csr(match c {
                                "lane_id" => Csr::LaneId,
                                "warp_id" => Csr::WarpId,
                                "core_id" => Csr::CoreId,
                                "num_threads" => Csr::NumThreads,
                                "num_warps" => Csr::NumWarps,
                                "num_cores" => Csr::NumCores,
                                _ => return Err(format!("bad csr {c}")),
                            })
                        } else if let Some(at) = iname.strip_prefix("atomic.") {
                            Intr::Atomic(match at {
                                "add" => AtomOp::Add,
                                "and" => AtomOp::And,
                                "or" => AtomOp::Or,
                                "xor" => AtomOp::Xor,
                                "min" => AtomOp::Min,
                                "max" => AtomOp::Max,
                                "exch" => AtomOp::Exch,
                                _ => return Err(format!("bad atomic {at}")),
                            })
                        } else {
                            return Err(format!("bad intrinsic {iname}"));
                        }
                    }
                };
                return Ok(InstKind::Intr { intr, args: vargs });
            }
            Err(format!("unknown instruction: {s}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::printer::{print_function, print_module};

    #[test]
    fn round_trip_function() {
        let src = r#"
func @k(ptr.global %x uniform, i32 %n) -> void kernel {
b0:
  %i0:i32 = intr.workitem.global_id 0
  %i1:i1 = icmp.slt %i0, %n
  condbr %i1, b1, b2
b1:
  %i3:ptr.global = gep %x, %i0, 4, 0
  %i4:f32 = load %i3
  %i5:f32 = bin.fmul %i4, f0x40000000
  store %i3, %i5
  br b2
b2:
  ret
}
"#;
        let m = parse_module(src).unwrap();
        assert_eq!(m.funcs.len(), 1);
        let f = &m.funcs[0];
        assert!(f.is_kernel);
        assert_eq!(f.params.len(), 2);
        assert!(f.params[0].uniform);
        let printed = print_function(f);
        // Re-parse the printed form and print again: must be identical.
        let m2 = parse_module(&printed).unwrap();
        assert_eq!(print_function(&m2.funcs[0]), printed);
    }

    #[test]
    fn round_trip_module_with_globals() {
        let src = r#"
module "test"
global @lut const size=8 align=4 init=0102030405060708
func @f(i32 %a) -> i32 internal {
b0:
  %i0:i32 = bin.add %a, 1
  ret %i0
}
"#;
        let m = parse_module(src).unwrap();
        assert_eq!(m.globals.len(), 1);
        assert_eq!(m.globals[0].init.as_ref().unwrap().len(), 8);
        let printed = print_module(&m);
        let m2 = parse_module(&printed).unwrap();
        assert_eq!(print_module(&m2), printed);
    }

    #[test]
    fn parses_divergence_ops() {
        let src = r#"
func @d(i32 %n) -> void {
b0:
  %i0:i1 = icmp.slt 1, %n
  splitbr %i0, pos, b1, b2, b3
b1:
  br b3
b2:
  br b3
b3:
  intr.join
  ret
}
"#;
        let m = parse_module(src).unwrap();
        let f = &m.funcs[0];
        let printed = print_function(f);
        assert!(printed.contains("splitbr %i0, pos, b1, b2, b3"));
        assert!(printed.contains("intr.join"));
    }

    #[test]
    fn round_trips_loc_annotations() {
        let src = r#"
func @k(i32 %n) -> i32 {
b0:
  %i0:i32 = bin.add %n, 1 !loc 12:5
  %i1:i32 = bin.mul %i0, %i0 !uniform !loc 13:9
  ret %i1
}
"#;
        let m = parse_module(src).unwrap();
        let f = &m.funcs[0];
        assert_eq!(f.insts[0].loc, Some(Loc { line: 12, col: 5 }));
        assert_eq!(f.insts[1].loc, Some(Loc { line: 13, col: 9 }));
        assert!(f.insts[1].uniform_ann);
        assert_eq!(f.insts[2].loc, None);
        let printed = print_function(f);
        assert!(printed.contains("!loc 12:5"));
        assert!(printed.contains("!uniform !loc 13:9"));
        let m2 = parse_module(&printed).unwrap();
        assert_eq!(print_function(&m2.funcs[0]), printed);
    }

    #[test]
    fn parses_phi_and_loop() {
        let src = r#"
func @l(i32 %n) -> i32 {
b0:
  br b1
b1:
  %i1:i32 = phi [b0: 0], [b2: %i3]
  %i2:i1 = icmp.slt %i1, %n
  condbr %i2, b2, b3
b2:
  %i3:i32 = bin.add %i1, 1
  br b1
b3:
  ret %i1
}
"#;
        let m = parse_module(src).unwrap();
        let printed = print_function(&m.funcs[0]);
        let m2 = parse_module(&printed).unwrap();
        assert_eq!(print_function(&m2.funcs[0]), printed);
    }
}
