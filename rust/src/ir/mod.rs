//! The VOLT intermediate representation.
//!
//! A compact SSA IR in the style of LLVM-IR, specialized for SIMT kernel
//! compilation. Values are produced by instructions (`Val::Inst`), function
//! arguments (`Val::Arg`) or constants; instructions live in basic blocks
//! which form an explicit CFG. Divergence-management operations
//! ([`InstKind::SplitBr`], [`InstKind::PredBr`], [`Intr::Join`], …) are
//! first-class so the middle-end can plan divergence at the IR level — the
//! paper's central design decision (§4.3).

pub mod cdg;
pub mod cfg;
pub mod dom;
pub mod interp;
pub mod loops;
pub mod parser;
pub mod printer;
pub mod verify;

use std::collections::HashMap;
use std::sync::Arc;

// ---------------------------------------------------------------------------
// Ids
// ---------------------------------------------------------------------------

/// Identifier of a basic block within a function.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct BlockId(pub u32);

/// Identifier of an instruction within a function (arena index).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct InstId(pub u32);

/// Identifier of a function within a module.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct FuncId(pub u32);

/// Identifier of a module-level global variable.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct GlobalId(pub u32);

impl BlockId {
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}
impl InstId {
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}
impl FuncId {
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}
impl GlobalId {
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

// ---------------------------------------------------------------------------
// Source locations
// ---------------------------------------------------------------------------

/// A kernel-source location carried from the front-end through every
/// middle-end pass onto MIR and finally into the per-PC line table of the
/// linked image ([`crate::backend::emit::ProgramImage::pc_loc`]) — the
/// substrate of the `volt::prof` cycle-attribution profiler. Lines and
/// columns are 1-based; `col == 0` means "line known, column not".
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct Loc {
    pub line: u32,
    pub col: u32,
}

impl Loc {
    pub fn line(line: u32) -> Loc {
        Loc { line, col: 0 }
    }
}

// ---------------------------------------------------------------------------
// Types
// ---------------------------------------------------------------------------

/// GPU address spaces, mirroring OpenCL/CUDA semantics on Vortex.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum AddrSpace {
    /// Device global memory.
    Global,
    /// Per-workgroup local (CUDA `__shared__`) memory. May be mapped to the
    /// per-core scratchpad or aliased onto global memory (paper Fig. 10).
    Local,
    /// Read-only constant memory (lowered onto global memory on Vortex,
    /// paper §5.4).
    Const,
    /// Per-thread private (stack) memory.
    Private,
}

/// IR value types. The machine is ILP32 (RV32IMF), so a single 32-bit
/// integer type plus f32 suffices; pointers are opaque per address space.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Type {
    Void,
    /// Boolean / predicate.
    I1,
    /// 32-bit integer (signed ops distinguish signedness).
    I32,
    /// 32-bit IEEE float.
    F32,
    Ptr(AddrSpace),
}

impl Type {
    pub fn is_ptr(self) -> bool {
        matches!(self, Type::Ptr(_))
    }
    /// Size in bytes when stored in memory.
    pub fn size(self) -> u32 {
        match self {
            Type::Void => 0,
            Type::I1 => 4, // stored as a word
            Type::I32 | Type::F32 | Type::Ptr(_) => 4,
        }
    }
}

// ---------------------------------------------------------------------------
// Values
// ---------------------------------------------------------------------------

/// An SSA value operand.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Val {
    /// Result of an instruction.
    Inst(InstId),
    /// Function argument (by index).
    Arg(u32),
    /// Integer (or boolean) constant with its type.
    I(i64, Type),
    /// f32 constant (bit pattern, for Eq/Hash).
    F(u32),
    /// Address of a module global.
    G(GlobalId),
}

impl Val {
    pub fn ci(v: i64) -> Val {
        Val::I(v, Type::I32)
    }
    pub fn cb(v: bool) -> Val {
        Val::I(v as i64, Type::I1)
    }
    pub fn cf(v: f32) -> Val {
        Val::F(v.to_bits())
    }
    pub fn as_f32(self) -> Option<f32> {
        match self {
            Val::F(b) => Some(f32::from_bits(b)),
            _ => None,
        }
    }
    pub fn as_int(self) -> Option<i64> {
        match self {
            Val::I(v, _) => Some(v),
            _ => None,
        }
    }
    pub fn is_const(self) -> bool {
        matches!(self, Val::I(..) | Val::F(..))
    }
}

// ---------------------------------------------------------------------------
// Operations
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    SDiv,
    SRem,
    UDiv,
    URem,
    And,
    Or,
    Xor,
    Shl,
    LShr,
    AShr,
    SMin,
    SMax,
    FAdd,
    FSub,
    FMul,
    FDiv,
    FMin,
    FMax,
}

impl BinOp {
    pub fn is_float(self) -> bool {
        matches!(
            self,
            BinOp::FAdd | BinOp::FSub | BinOp::FMul | BinOp::FDiv | BinOp::FMin | BinOp::FMax
        )
    }
    pub fn is_commutative(self) -> bool {
        matches!(
            self,
            BinOp::Add
                | BinOp::Mul
                | BinOp::And
                | BinOp::Or
                | BinOp::Xor
                | BinOp::SMin
                | BinOp::SMax
                | BinOp::FAdd
                | BinOp::FMul
                | BinOp::FMin
                | BinOp::FMax
        )
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum UnOp {
    /// Integer bitwise not.
    Not,
    FNeg,
    FSqrt,
    FAbs,
    FExp,
    FLog,
    FFloor,
    /// signed i32 -> f32
    SiToFp,
    /// f32 -> signed i32 (truncating)
    FpToSi,
    /// i1 -> i32 zero-extension
    ZExt,
    /// i32 -> i1 (icmp ne 0)
    Trunc,
    /// f32 -> i32 bit pattern (fmv.x.w)
    FToBits,
    /// i32 bit pattern -> f32 (fmv.w.x)
    BitsToF,
}

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum ICmp {
    Eq,
    Ne,
    Slt,
    Sle,
    Sgt,
    Sge,
    Ult,
    Uge,
}

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum FCmp {
    Oeq,
    One,
    Olt,
    Ole,
    Ogt,
    Oge,
}

/// Atomic read-modify-write operations (map to RV32A `amo*.w`).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum AtomOp {
    Add,
    And,
    Or,
    Xor,
    Min,
    Max,
    Exch,
}

/// Pre-scheduling work-item queries (OpenCL surface; CUDA maps onto these).
/// Eliminated by the thread-schedule insertion pass (paper §4.2).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum WorkItem {
    GlobalId,
    LocalId,
    GroupId,
    LocalSize,
    GlobalSize,
    NumGroups,
}

/// Hardware control/status registers. Machine-level CSRs are
/// always-uniform; `LaneId` is the canonical source of divergence
/// (paper §4.3.1 "VOLT Divergence Tracker").
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Csr {
    /// Lane index within the warp — divergent by definition.
    LaneId,
    WarpId,
    CoreId,
    /// Threads per warp.
    NumThreads,
    /// Warps per core.
    NumWarps,
    NumCores,
}

/// IR-level intrinsics (non-terminator).
#[derive(Clone, PartialEq, Debug)]
pub enum Intr {
    /// Work-item query; args: [dim:i32 const].
    WorkItem(WorkItem),
    /// CSR read; no args.
    Csr(Csr),
    /// Workgroup barrier; args: [] (count resolved at schedule time).
    Barrier,
    /// Atomic RMW; args: [ptr, val] -> old value.
    Atomic(AtomOp),
    /// Atomic compare-and-swap; args: [ptr, cmp, new] -> old.
    AtomicCas,
    /// Warp vote; args: [pred:i1] -> i1.
    VoteAll,
    VoteAny,
    /// Warp ballot; args: [pred:i1] -> i32 mask.
    Ballot,
    /// Warp shuffle (indexed); args: [val, src_lane:i32] -> val.
    Shfl,
    /// Reconvergence point; no args. Must be the first instruction (after
    /// phis) of the immediate-post-dominator block of its paired
    /// `SplitBr`s. Semantics: pop/redirect every IPDOM-stack entry whose
    /// recorded reconvergence block is this block (see DESIGN.md — this is
    /// the NVIDIA-SSY-style "reconvergence PC recorded at push" variant of
    /// the Vortex join).
    Join,
    /// Set thread mask; args: [mask:i32]. (`vx_tmc`)
    Tmc,
    /// Read active thread mask; -> i32. (`vx_active_threads`)
    Mask,
    /// Debug print of an i32/f32 (simulator hook; lowered to a nop-cost op).
    PrintI,
    PrintF,
}

impl Intr {
    /// Does this intrinsic write memory or act as a synchronization point
    /// across which other lanes' writes become visible? This is the
    /// clobber rule the redundancy passes (GVN load-CSE, LICM) share;
    /// keep it in sync with [`InstKind::has_side_effects`] when adding
    /// intrinsics.
    pub fn clobbers_memory(&self) -> bool {
        matches!(
            self,
            Intr::Barrier | Intr::Atomic(_) | Intr::AtomicCas | Intr::Tmc
        )
    }

    /// Result type, given arg types where needed.
    pub fn ret_type(&self, args: &[Type]) -> Type {
        match self {
            Intr::WorkItem(_) | Intr::Csr(_) => Type::I32,
            Intr::Barrier | Intr::Tmc | Intr::PrintI | Intr::PrintF => Type::Void,
            Intr::Atomic(_) | Intr::AtomicCas => Type::I32,
            Intr::VoteAll | Intr::VoteAny => Type::I1,
            Intr::Ballot | Intr::Mask => Type::I32,
            Intr::Shfl => args.first().copied().unwrap_or(Type::I32),
            Intr::Join => Type::Void,
        }
    }
}

// ---------------------------------------------------------------------------
// Instructions
// ---------------------------------------------------------------------------

#[derive(Clone, PartialEq, Debug)]
pub enum InstKind {
    Bin {
        op: BinOp,
        a: Val,
        b: Val,
    },
    Un {
        op: UnOp,
        a: Val,
    },
    ICmp {
        pred: ICmp,
        a: Val,
        b: Val,
    },
    FCmp {
        pred: FCmp,
        a: Val,
        b: Val,
    },
    Select {
        cond: Val,
        t: Val,
        f: Val,
    },
    /// Stack allocation of `size` bytes in Private space; value is the
    /// per-thread pointer.
    Alloca {
        size: u32,
    },
    Load {
        ptr: Val,
    },
    Store {
        ptr: Val,
        val: Val,
    },
    /// `base + index*scale + disp` pointer arithmetic.
    Gep {
        base: Val,
        index: Val,
        scale: u32,
        disp: i32,
    },
    Call {
        callee: FuncId,
        args: Vec<Val>,
    },
    Intr {
        intr: Intr,
        args: Vec<Val>,
    },
    Phi {
        incs: Vec<(BlockId, Val)>,
    },
    // ---- terminators ----
    Br {
        target: BlockId,
    },
    CondBr {
        cond: Val,
        t: BlockId,
        f: BlockId,
    },
    /// Divergence split (`vx_split` + fused branch, see DESIGN.md):
    /// take `then_b` with lanes where cond≠neg, queue `else_b` on the IPDOM
    /// stack together with the reconvergence block `ipdom` (where the
    /// matching `Intr::Join` lives).
    SplitBr {
        cond: Val,
        neg: bool,
        then_b: BlockId,
        else_b: BlockId,
        ipdom: BlockId,
    },
    /// Divergent-loop predicate (`vx_pred`): continue into `body` with
    /// tmask &= cond; when the mask empties, restore `mask` and branch to
    /// `exit`.
    PredBr {
        cond: Val,
        mask: Val,
        body: BlockId,
        exit: BlockId,
    },
    Ret {
        val: Option<Val>,
    },
    Unreachable,
}

impl InstKind {
    pub fn is_terminator(&self) -> bool {
        matches!(
            self,
            InstKind::Br { .. }
                | InstKind::CondBr { .. }
                | InstKind::SplitBr { .. }
                | InstKind::PredBr { .. }
                | InstKind::Ret { .. }
                | InstKind::Unreachable
        )
    }

    /// Whether this instruction may read or write memory or have other side
    /// effects (and must not be removed by DCE even if unused).
    pub fn has_side_effects(&self) -> bool {
        match self {
            InstKind::Store { .. } | InstKind::Call { .. } => true,
            InstKind::Load { .. } => false, // loads are removable if unused
            InstKind::Intr { intr, .. } => matches!(
                intr,
                Intr::Barrier
                    | Intr::Atomic(_)
                    | Intr::AtomicCas
                    | Intr::Join
                    | Intr::Tmc
                    | Intr::PrintI
                    | Intr::PrintF
            ),
            k => k.is_terminator(),
        }
    }

    /// Operand values (for generic traversal).
    pub fn operands(&self) -> Vec<Val> {
        match self {
            InstKind::Bin { a, b, .. } | InstKind::ICmp { a, b, .. } | InstKind::FCmp { a, b, .. } => {
                vec![*a, *b]
            }
            InstKind::Un { a, .. } => vec![*a],
            InstKind::Select { cond, t, f } => vec![*cond, *t, *f],
            InstKind::Alloca { .. } => vec![],
            InstKind::Load { ptr } => vec![*ptr],
            InstKind::Store { ptr, val } => vec![*ptr, *val],
            InstKind::Gep { base, index, .. } => vec![*base, *index],
            InstKind::Call { args, .. } | InstKind::Intr { args, .. } => args.clone(),
            InstKind::Phi { incs } => incs.iter().map(|(_, v)| *v).collect(),
            InstKind::Br { .. } => vec![],
            InstKind::CondBr { cond, .. } => vec![*cond],
            InstKind::SplitBr { cond, .. } => vec![*cond],
            InstKind::PredBr { cond, mask, .. } => vec![*cond, *mask],
            InstKind::Ret { val } => val.iter().copied().collect(),
            InstKind::Unreachable => vec![],
        }
    }

    /// Apply `f` to every operand in place.
    pub fn map_operands(&mut self, mut f: impl FnMut(Val) -> Val) {
        match self {
            InstKind::Bin { a, b, .. } | InstKind::ICmp { a, b, .. } | InstKind::FCmp { a, b, .. } => {
                *a = f(*a);
                *b = f(*b);
            }
            InstKind::Un { a, .. } => *a = f(*a),
            InstKind::Select { cond, t, f: fv } => {
                *cond = f(*cond);
                *t = f(*t);
                *fv = f(*fv);
            }
            InstKind::Alloca { .. } => {}
            InstKind::Load { ptr } => *ptr = f(*ptr),
            InstKind::Store { ptr, val } => {
                *ptr = f(*ptr);
                *val = f(*val);
            }
            InstKind::Gep { base, index, .. } => {
                *base = f(*base);
                *index = f(*index);
            }
            InstKind::Call { args, .. } | InstKind::Intr { args, .. } => {
                for a in args.iter_mut() {
                    *a = f(*a);
                }
            }
            InstKind::Phi { incs } => {
                for (_, v) in incs.iter_mut() {
                    *v = f(*v);
                }
            }
            InstKind::Br { .. } => {}
            InstKind::CondBr { cond, .. } => *cond = f(*cond),
            InstKind::SplitBr { cond, .. } => *cond = f(*cond),
            InstKind::PredBr { cond, mask, .. } => {
                *cond = f(*cond);
                *mask = f(*mask);
            }
            InstKind::Ret { val } => {
                if let Some(v) = val {
                    *v = f(*v);
                }
            }
            InstKind::Unreachable => {}
        }
    }

    /// Successor blocks if this is a terminator.
    pub fn successors(&self) -> Vec<BlockId> {
        match self {
            InstKind::Br { target } => vec![*target],
            InstKind::CondBr { t, f, .. } => vec![*t, *f],
            InstKind::SplitBr { then_b, else_b, .. } => vec![*then_b, *else_b],
            InstKind::PredBr { body, exit, .. } => vec![*body, *exit],
            _ => vec![],
        }
    }

    /// Replace successor `from` with `to` (all occurrences).
    pub fn replace_successor(&mut self, from: BlockId, to: BlockId) {
        let repl = |b: &mut BlockId| {
            if *b == from {
                *b = to;
            }
        };
        match self {
            InstKind::Br { target } => repl(target),
            InstKind::CondBr { t, f, .. } => {
                repl(t);
                repl(f);
            }
            InstKind::SplitBr {
                then_b,
                else_b,
                ipdom,
                ..
            } => {
                repl(then_b);
                repl(else_b);
                repl(ipdom);
            }
            InstKind::PredBr { body, exit, .. } => {
                repl(body);
                repl(exit);
            }
            _ => {}
        }
    }
}

/// An instruction in the arena.
#[derive(Clone, Debug)]
pub struct InstData {
    pub kind: InstKind,
    pub ty: Type,
    pub block: BlockId,
    /// `vortex.uniform` annotation (paper §4.3.1 "Annotation Analysis").
    pub uniform_ann: bool,
    /// Source-level name hint (for printing and debugging).
    pub name: Option<String>,
    /// Source location this instruction was lowered from (`None` for
    /// compiler-synthesized code). Transforms that move or rewrite
    /// instructions in place preserve it for free since it lives on the
    /// arena entry; passes that *clone* instructions (inlining) copy it
    /// explicitly.
    pub loc: Option<Loc>,
    /// Tombstone: true once removed.
    pub dead: bool,
}

// ---------------------------------------------------------------------------
// Blocks / functions / module
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, Default)]
pub struct Block {
    pub insts: Vec<InstId>,
    pub name: String,
    pub dead: bool,
}

/// A function parameter.
#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub ty: Type,
    /// Declared or inferred uniform (paper Algorithm 1 / `uniform` keyword).
    pub uniform: bool,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Linkage {
    /// Visible entry point (kernels).
    External,
    /// Module-internal device function — eligible for Algorithm-1 argument
    /// refinement.
    Internal,
}

#[derive(Clone, Debug)]
pub struct Function {
    pub name: String,
    pub params: Vec<Param>,
    pub ret: Type,
    /// Inferred: return value is uniform across the warp.
    pub ret_uniform: bool,
    pub is_kernel: bool,
    pub linkage: Linkage,
    pub blocks: Vec<Block>,
    pub insts: Vec<InstData>,
    pub entry: BlockId,
    /// Bytes of `__shared__`/`local` memory statically required.
    pub local_mem_size: u32,
    /// Source line of the declaration this function was lowered from
    /// (0 = synthesized). Dispatchers inherit their kernel's line so their
    /// schedule arithmetic attributes to the kernel signature.
    pub src_line: u32,
    /// Monotonic CFG version: bumped by every mutation that can change the
    /// block structure or edge set. Cached dominator trees are tagged with
    /// the version they were built at and rebuilt lazily on mismatch, so
    /// passes that only touch straight-line code keep the cache warm.
    pub cfg_version: u64,
    pub(crate) dom_cache: Option<(u64, Arc<dom::DomTree>)>,
    pub(crate) pdom_cache: Option<(u64, Arc<dom::PostDomTree>)>,
}

impl Function {
    pub fn new(name: &str, params: Vec<Param>, ret: Type) -> Function {
        let mut f = Function {
            name: name.to_string(),
            params,
            ret,
            ret_uniform: false,
            is_kernel: false,
            linkage: Linkage::Internal,
            blocks: vec![],
            insts: vec![],
            entry: BlockId(0),
            local_mem_size: 0,
            src_line: 0,
            cfg_version: 0,
            dom_cache: None,
            pdom_cache: None,
        };
        f.entry = f.add_block("entry");
        f
    }

    /// Declare the CFG changed: bump the version and drop cached trees.
    /// CFG-mutating helpers call this automatically; passes that rewrite
    /// terminators in place (via [`Function::inst_mut`]) must call it
    /// themselves once they are done.
    pub fn invalidate_cfg_cache(&mut self) {
        self.cfg_version += 1;
        self.dom_cache = None;
        self.pdom_cache = None;
    }

    /// Dominator tree for the current CFG, cached per [`Self::cfg_version`].
    pub fn dom_tree(&mut self) -> Arc<dom::DomTree> {
        if let Some((v, t)) = &self.dom_cache {
            if *v == self.cfg_version {
                return t.clone();
            }
        }
        let t = Arc::new(dom::DomTree::build(self));
        self.dom_cache = Some((self.cfg_version, t.clone()));
        t
    }

    /// Post-dominator tree, cached per [`Self::cfg_version`].
    pub fn pdom_tree(&mut self) -> Arc<dom::PostDomTree> {
        if let Some((v, t)) = &self.pdom_cache {
            if *v == self.cfg_version {
                return t.clone();
            }
        }
        let t = Arc::new(dom::PostDomTree::build(self));
        self.pdom_cache = Some((self.cfg_version, t.clone()));
        t
    }

    pub fn add_block(&mut self, name: &str) -> BlockId {
        let id = BlockId(self.blocks.len() as u32);
        self.blocks.push(Block {
            insts: vec![],
            name: format!("{}{}", name, id.0),
            dead: false,
        });
        self.invalidate_cfg_cache();
        id
    }

    pub fn inst(&self, id: InstId) -> &InstData {
        &self.insts[id.idx()]
    }
    pub fn inst_mut(&mut self, id: InstId) -> &mut InstData {
        &mut self.insts[id.idx()]
    }
    pub fn block(&self, id: BlockId) -> &Block {
        &self.blocks[id.idx()]
    }
    pub fn block_mut(&mut self, id: BlockId) -> &mut Block {
        &mut self.blocks[id.idx()]
    }

    /// Ids of all live blocks, in arena order.
    pub fn block_ids(&self) -> Vec<BlockId> {
        (0..self.blocks.len() as u32)
            .map(BlockId)
            .filter(|b| !self.blocks[b.idx()].dead)
            .collect()
    }

    /// Terminator of a block (panics if missing — verifier enforces).
    pub fn term(&self, b: BlockId) -> InstId {
        *self.blocks[b.idx()]
            .insts
            .last()
            .unwrap_or_else(|| panic!("block {} has no terminator", b.0))
    }

    pub fn succs(&self, b: BlockId) -> Vec<BlockId> {
        if self.blocks[b.idx()].insts.is_empty() {
            return vec![];
        }
        self.inst(self.term(b)).kind.successors()
    }

    /// Predecessor map for all blocks.
    pub fn preds(&self) -> Vec<Vec<BlockId>> {
        let mut preds = vec![vec![]; self.blocks.len()];
        for b in self.block_ids() {
            for s in self.succs(b) {
                preds[s.idx()].push(b);
            }
        }
        preds
    }

    /// Append a new instruction to a block. Terminators allowed only at the
    /// end (caller responsibility; verifier checks).
    pub fn push_inst(&mut self, b: BlockId, kind: InstKind, ty: Type) -> InstId {
        let is_term = kind.is_terminator();
        let id = InstId(self.insts.len() as u32);
        self.insts.push(InstData {
            kind,
            ty,
            block: b,
            uniform_ann: false,
            name: None,
            loc: None,
            dead: false,
        });
        self.blocks[b.idx()].insts.push(id);
        if is_term {
            self.invalidate_cfg_cache();
        }
        id
    }

    /// Insert an instruction at position `pos` within block `b`.
    pub fn insert_inst(&mut self, b: BlockId, pos: usize, kind: InstKind, ty: Type) -> InstId {
        let is_term = kind.is_terminator();
        let id = InstId(self.insts.len() as u32);
        self.insts.push(InstData {
            kind,
            ty,
            block: b,
            uniform_ann: false,
            name: None,
            loc: None,
            dead: false,
        });
        self.blocks[b.idx()].insts.insert(pos, id);
        if is_term {
            self.invalidate_cfg_cache();
        }
        id
    }

    /// Remove an instruction (tombstone + unlink from its block).
    pub fn remove_inst(&mut self, id: InstId) {
        let b = self.insts[id.idx()].block;
        let is_term = self.insts[id.idx()].kind.is_terminator();
        self.blocks[b.idx()].insts.retain(|&i| i != id);
        self.insts[id.idx()].dead = true;
        if is_term {
            self.invalidate_cfg_cache();
        }
    }

    /// Replace every use of value `from` with `to` across the function.
    pub fn replace_uses(&mut self, from: Val, to: Val) {
        for inst in self.insts.iter_mut() {
            if inst.dead {
                continue;
            }
            inst.kind.map_operands(|v| if v == from { to } else { v });
        }
    }

    /// Value type of an operand.
    pub fn val_type(&self, v: Val) -> Type {
        match v {
            Val::Inst(i) => self.inst(i).ty,
            Val::Arg(i) => self.params[i as usize].ty,
            Val::I(_, t) => t,
            Val::F(_) => Type::F32,
            Val::G(_) => Type::Ptr(AddrSpace::Global), // refined via module
        }
    }

    /// Reverse post-order over live, reachable blocks starting at entry.
    pub fn rpo(&self) -> Vec<BlockId> {
        let mut visited = vec![false; self.blocks.len()];
        let mut post = vec![];
        // Iterative DFS with explicit stack of (block, next-successor-index).
        let mut stack: Vec<(BlockId, usize)> = vec![(self.entry, 0)];
        visited[self.entry.idx()] = true;
        while let Some((b, i)) = stack.pop() {
            let succs = self.succs(b);
            if i < succs.len() {
                stack.push((b, i + 1));
                let s = succs[i];
                if !visited[s.idx()] {
                    visited[s.idx()] = true;
                    stack.push((s, 0));
                }
            } else {
                post.push(b);
            }
        }
        post.reverse();
        post
    }

    /// Mark blocks unreachable from entry as dead; drop their instructions.
    pub fn remove_unreachable(&mut self) {
        let reach: Vec<BlockId> = self.rpo();
        let mut live = vec![false; self.blocks.len()];
        for b in &reach {
            live[b.idx()] = true;
        }
        let dead_blocks: Vec<BlockId> = self
            .block_ids()
            .into_iter()
            .filter(|b| !live[b.idx()])
            .collect();
        if !dead_blocks.is_empty() {
            self.invalidate_cfg_cache();
        }
        for b in &dead_blocks {
            let insts = std::mem::take(&mut self.blocks[b.idx()].insts);
            for i in insts {
                self.insts[i.idx()].dead = true;
            }
            self.blocks[b.idx()].dead = true;
        }
        // Remove phi incomings from now-dead predecessors.
        if !dead_blocks.is_empty() {
            let deadset: std::collections::HashSet<BlockId> = dead_blocks.into_iter().collect();
            for inst in self.insts.iter_mut() {
                if inst.dead {
                    continue;
                }
                if let InstKind::Phi { incs } = &mut inst.kind {
                    incs.retain(|(p, _)| !deadset.contains(p));
                }
            }
        }
    }

    /// Split the edge `a -> b`, inserting a fresh block containing a single
    /// `Br b`. Phi incomings in `b` from `a` are rewritten to the new block.
    pub fn split_edge(&mut self, a: BlockId, b: BlockId) -> BlockId {
        let nb = self.add_block("crit");
        self.push_inst(nb, InstKind::Br { target: b }, Type::Void);
        let t = self.term(a);
        self.inst_mut(t).kind.replace_successor(b, nb);
        self.invalidate_cfg_cache();
        // Fix phis in b.
        let insts = self.blocks[b.idx()].insts.clone();
        for i in insts {
            if let InstKind::Phi { incs } = &mut self.insts[i.idx()].kind {
                for (p, _) in incs.iter_mut() {
                    if *p == a {
                        *p = nb;
                    }
                }
            } else {
                break; // phis are a prefix of the block
            }
        }
        nb
    }

    /// Number of live instructions.
    pub fn num_insts(&self) -> usize {
        self.insts.iter().filter(|i| !i.dead).count()
    }

    /// Build use lists: for every inst id, the list of (user inst id).
    pub fn uses(&self) -> HashMap<InstId, Vec<InstId>> {
        let mut map: HashMap<InstId, Vec<InstId>> = HashMap::new();
        for (idx, inst) in self.insts.iter().enumerate() {
            if inst.dead {
                continue;
            }
            let user = InstId(idx as u32);
            for op in inst.kind.operands() {
                if let Val::Inst(def) = op {
                    map.entry(def).or_default().push(user);
                }
            }
        }
        map
    }
}

// ---------------------------------------------------------------------------
// Globals and module
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Global {
    pub name: String,
    pub space: AddrSpace,
    pub size: u32,
    pub align: u32,
    /// Optional initializer bytes (Const/Global space only).
    pub init: Option<Vec<u8>>,
}

#[derive(Clone, Debug, Default)]
pub struct Module {
    pub name: String,
    pub funcs: Vec<Function>,
    pub globals: Vec<Global>,
}

impl Module {
    pub fn new(name: &str) -> Module {
        Module {
            name: name.to_string(),
            funcs: vec![],
            globals: vec![],
        }
    }

    pub fn add_func(&mut self, f: Function) -> FuncId {
        self.funcs.push(f);
        FuncId(self.funcs.len() as u32 - 1)
    }

    pub fn add_global(&mut self, g: Global) -> GlobalId {
        self.globals.push(g);
        GlobalId(self.globals.len() as u32 - 1)
    }

    pub fn func(&self, id: FuncId) -> &Function {
        &self.funcs[id.idx()]
    }
    pub fn func_mut(&mut self, id: FuncId) -> &mut Function {
        &mut self.funcs[id.idx()]
    }

    pub fn find_func(&self, name: &str) -> Option<FuncId> {
        self.funcs
            .iter()
            .position(|f| f.name == name)
            .map(|i| FuncId(i as u32))
    }

    pub fn kernels(&self) -> Vec<FuncId> {
        (0..self.funcs.len() as u32)
            .map(FuncId)
            .filter(|f| self.funcs[f.idx()].is_kernel)
            .collect()
    }

    pub fn global_ptr_type(&self, g: GlobalId) -> Type {
        Type::Ptr(self.globals[g.idx()].space)
    }
}

// ---------------------------------------------------------------------------
// Builder
// ---------------------------------------------------------------------------

/// Convenience builder that appends instructions to a current block.
pub struct Builder<'a> {
    pub f: &'a mut Function,
    pub cur: BlockId,
}

impl<'a> Builder<'a> {
    pub fn new(f: &'a mut Function) -> Builder<'a> {
        let entry = f.entry;
        Builder { f, cur: entry }
    }

    pub fn at(f: &'a mut Function, b: BlockId) -> Builder<'a> {
        Builder { f, cur: b }
    }

    pub fn set_block(&mut self, b: BlockId) {
        self.cur = b;
    }

    pub fn block(&mut self, name: &str) -> BlockId {
        self.f.add_block(name)
    }

    fn push(&mut self, kind: InstKind, ty: Type) -> Val {
        Val::Inst(self.f.push_inst(self.cur, kind, ty))
    }

    pub fn bin(&mut self, op: BinOp, a: Val, b: Val) -> Val {
        let ty = if op.is_float() { Type::F32 } else { self.f.val_type(a) };
        self.push(InstKind::Bin { op, a, b }, ty)
    }
    pub fn add(&mut self, a: Val, b: Val) -> Val {
        self.bin(BinOp::Add, a, b)
    }
    pub fn sub(&mut self, a: Val, b: Val) -> Val {
        self.bin(BinOp::Sub, a, b)
    }
    pub fn mul(&mut self, a: Val, b: Val) -> Val {
        self.bin(BinOp::Mul, a, b)
    }
    pub fn un(&mut self, op: UnOp, a: Val) -> Val {
        let ty = match op {
            UnOp::SiToFp | UnOp::FNeg | UnOp::FSqrt | UnOp::FAbs | UnOp::FExp | UnOp::FLog
            | UnOp::FFloor | UnOp::BitsToF => Type::F32,
            UnOp::FpToSi | UnOp::ZExt | UnOp::Not | UnOp::FToBits => Type::I32,
            UnOp::Trunc => Type::I1,
        };
        self.push(InstKind::Un { op, a }, ty)
    }
    pub fn icmp(&mut self, pred: ICmp, a: Val, b: Val) -> Val {
        self.push(InstKind::ICmp { pred, a, b }, Type::I1)
    }
    pub fn fcmp(&mut self, pred: FCmp, a: Val, b: Val) -> Val {
        self.push(InstKind::FCmp { pred, a, b }, Type::I1)
    }
    pub fn select(&mut self, cond: Val, t: Val, f: Val) -> Val {
        let ty = self.f.val_type(t);
        self.push(InstKind::Select { cond, t, f }, ty)
    }
    pub fn alloca(&mut self, size: u32) -> Val {
        self.push(InstKind::Alloca { size }, Type::Ptr(AddrSpace::Private))
    }
    pub fn load(&mut self, ptr: Val, ty: Type) -> Val {
        self.push(InstKind::Load { ptr }, ty)
    }
    pub fn store(&mut self, ptr: Val, val: Val) {
        self.push(InstKind::Store { ptr, val }, Type::Void);
    }
    pub fn gep(&mut self, base: Val, index: Val, scale: u32) -> Val {
        let ty = self.f.val_type(base);
        self.push(
            InstKind::Gep {
                base,
                index,
                scale,
                disp: 0,
            },
            ty,
        )
    }
    pub fn call(&mut self, callee: FuncId, args: Vec<Val>, ret: Type) -> Val {
        self.push(InstKind::Call { callee, args }, ret)
    }
    pub fn intr(&mut self, intr: Intr, args: Vec<Val>) -> Val {
        let at: Vec<Type> = args.iter().map(|&a| self.f.val_type(a)).collect();
        let ty = intr.ret_type(&at);
        self.push(InstKind::Intr { intr, args }, ty)
    }
    pub fn phi(&mut self, ty: Type, incs: Vec<(BlockId, Val)>) -> Val {
        // Phis must be at the head of the block.
        let id = self.f.insert_inst(self.cur, 0, InstKind::Phi { incs }, ty);
        Val::Inst(id)
    }
    pub fn br(&mut self, target: BlockId) {
        self.push(InstKind::Br { target }, Type::Void);
    }
    pub fn cond_br(&mut self, cond: Val, t: BlockId, f: BlockId) {
        self.push(InstKind::CondBr { cond, t, f }, Type::Void);
    }
    pub fn split_br(&mut self, cond: Val, then_b: BlockId, else_b: BlockId, ipdom: BlockId) {
        self.push(
            InstKind::SplitBr {
                cond,
                neg: false,
                then_b,
                else_b,
                ipdom,
            },
            Type::Void,
        );
    }
    pub fn pred_br(&mut self, cond: Val, mask: Val, body: BlockId, exit: BlockId) {
        self.push(
            InstKind::PredBr {
                cond,
                mask,
                body,
                exit,
            },
            Type::Void,
        );
    }
    pub fn ret(&mut self, val: Option<Val>) {
        self.push(InstKind::Ret { val }, Type::Void);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_query_cfg() {
        let mut f = Function::new("t", vec![], Type::Void);
        let entry = f.entry;
        let then_b;
        let else_b;
        let join;
        {
            let mut b = Builder::new(&mut f);
            then_b = b.block("then");
            else_b = b.block("else");
            join = b.block("join");
            let c = b.icmp(ICmp::Slt, Val::ci(1), Val::ci(2));
            b.cond_br(c, then_b, else_b);
            b.set_block(then_b);
            b.br(join);
            b.set_block(else_b);
            b.br(join);
            b.set_block(join);
            b.ret(None);
        }
        assert_eq!(f.succs(entry), vec![then_b, else_b]);
        let preds = f.preds();
        assert_eq!(preds[join.idx()].len(), 2);
        let rpo = f.rpo();
        assert_eq!(rpo[0], entry);
        assert_eq!(*rpo.last().unwrap(), join);
    }

    #[test]
    fn replace_uses_and_removal() {
        let mut f = Function::new("t", vec![Param { name: "x".into(), ty: Type::I32, uniform: false }], Type::I32);
        let (v, w);
        {
            let mut b = Builder::new(&mut f);
            v = b.add(Val::Arg(0), Val::ci(1));
            w = b.mul(v, v);
            b.ret(Some(w));
        }
        f.replace_uses(v, Val::ci(7));
        if let Val::Inst(wi) = w {
            assert_eq!(f.inst(wi).kind.operands(), vec![Val::ci(7), Val::ci(7)]);
        } else {
            panic!()
        }
        if let Val::Inst(vi) = v {
            f.remove_inst(vi);
            assert!(f.inst(vi).dead);
        }
        assert_eq!(f.num_insts(), 2);
    }

    #[test]
    fn dom_cache_invalidated_by_cfg_mutation() {
        let mut f = Function::new("t", vec![], Type::Void);
        let entry = f.entry;
        let a = f.add_block("a");
        {
            let mut b = Builder::at(&mut f, entry);
            b.br(a);
            b.set_block(a);
            b.ret(None);
        }
        let d1 = f.dom_tree();
        let d2 = f.dom_tree();
        // Same version: the Arc is shared, not rebuilt.
        assert!(std::sync::Arc::ptr_eq(&d1, &d2));
        assert_eq!(d1.idom[a.idx()], Some(entry));
        // Splitting the edge bumps the version and rebuilds.
        let v = f.cfg_version;
        let nb = f.split_edge(entry, a);
        assert!(f.cfg_version > v);
        let d3 = f.dom_tree();
        assert!(!std::sync::Arc::ptr_eq(&d1, &d3));
        assert_eq!(d3.idom[a.idx()], Some(nb));
        // Post-dominator cache follows the same protocol.
        let p1 = f.pdom_tree();
        let p2 = f.pdom_tree();
        assert!(std::sync::Arc::ptr_eq(&p1, &p2));
    }

    #[test]
    fn split_edge_fixes_phis() {
        let mut f = Function::new("t", vec![], Type::I32);
        let entry = f.entry;
        let a = f.add_block("a");
        let join = f.add_block("j");
        {
            let mut b = Builder::at(&mut f, entry);
            let c = b.icmp(ICmp::Eq, Val::ci(0), Val::ci(0));
            b.cond_br(c, a, join);
            b.set_block(a);
            b.br(join);
            b.set_block(join);
            let p = b.phi(Type::I32, vec![(entry, Val::ci(1)), (a, Val::ci(2))]);
            b.ret(Some(p));
        }
        let nb = f.split_edge(entry, join);
        assert!(f.succs(entry).contains(&nb));
        // Phi incoming from entry now comes from nb.
        let phi_id = f.blocks[join.idx()].insts[0];
        if let InstKind::Phi { incs } = &f.inst(phi_id).kind {
            assert!(incs.iter().any(|(p, _)| *p == nb));
            assert!(!incs.iter().any(|(p, _)| *p == entry));
        } else {
            panic!()
        }
    }
}
