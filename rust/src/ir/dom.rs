//! Dominator and post-dominator trees (Cooper–Harvey–Kennedy).
//!
//! The post-dominator tree supplies the IPDOM reconvergence points that the
//! divergence-management insertion (paper Algorithm 2) and the IPDOM-stack
//! hardware contract (paper §2.3) rely on.

use super::{BlockId, Function};
use std::collections::HashMap;

/// Generic CHK dominator computation over an indexed graph.
///
/// `order` must be a reverse post-order of reachable nodes starting with the
/// root; `preds` gives predecessors in the same index space.
fn compute_idoms(order: &[usize], preds: &[Vec<usize>], n: usize) -> Vec<Option<usize>> {
    let mut rpo_num = vec![usize::MAX; n];
    for (i, &b) in order.iter().enumerate() {
        rpo_num[b] = i;
    }
    let mut idom: Vec<Option<usize>> = vec![None; n];
    let root = order[0];
    idom[root] = Some(root);
    let intersect = |idom: &Vec<Option<usize>>, mut a: usize, mut b: usize| -> usize {
        while a != b {
            while rpo_num[a] > rpo_num[b] {
                a = idom[a].unwrap();
            }
            while rpo_num[b] > rpo_num[a] {
                b = idom[b].unwrap();
            }
        }
        a
    };
    let mut changed = true;
    while changed {
        changed = false;
        for &b in order.iter().skip(1) {
            let mut new_idom: Option<usize> = None;
            for &p in &preds[b] {
                if rpo_num[p] == usize::MAX {
                    continue; // unreachable predecessor
                }
                if idom[p].is_some() {
                    new_idom = Some(match new_idom {
                        None => p,
                        Some(cur) => intersect(&idom, p, cur),
                    });
                }
            }
            if let Some(ni) = new_idom {
                if idom[b] != Some(ni) {
                    idom[b] = Some(ni);
                    changed = true;
                }
            }
        }
    }
    idom[root] = None; // root has no idom
    idom
}

/// Dominator tree over a function's CFG.
#[derive(Clone, Debug)]
pub struct DomTree {
    /// Immediate dominator per block (None for entry / unreachable blocks).
    pub idom: Vec<Option<BlockId>>,
    /// Whether the block is reachable from entry.
    pub reachable: Vec<bool>,
}

impl DomTree {
    pub fn build(f: &Function) -> DomTree {
        let n = f.blocks.len();
        let rpo = f.rpo();
        let order: Vec<usize> = rpo.iter().map(|b| b.idx()).collect();
        let preds_b = f.preds();
        let preds: Vec<Vec<usize>> = preds_b
            .iter()
            .map(|ps| ps.iter().map(|p| p.idx()).collect())
            .collect();
        let idom_raw = compute_idoms(&order, &preds, n);
        let mut reachable = vec![false; n];
        for b in &rpo {
            reachable[b.idx()] = true;
        }
        DomTree {
            idom: idom_raw
                .into_iter()
                .map(|o| o.map(|i| BlockId(i as u32)))
                .collect(),
            reachable,
        }
    }

    /// Does `a` dominate `b`? (reflexive)
    pub fn dominates(&self, a: BlockId, b: BlockId) -> bool {
        if !self.reachable[b.idx()] {
            return false;
        }
        let mut cur = b;
        loop {
            if cur == a {
                return true;
            }
            match self.idom[cur.idx()] {
                Some(d) => cur = d,
                None => return false,
            }
        }
    }

    /// Children in the dominator tree.
    pub fn children(&self) -> Vec<Vec<BlockId>> {
        let mut ch = vec![vec![]; self.idom.len()];
        for (i, d) in self.idom.iter().enumerate() {
            if let Some(d) = d {
                ch[d.idx()].push(BlockId(i as u32));
            }
        }
        ch
    }

    /// Dominance frontier (Cytron et al.) — used by mem2reg phi placement.
    pub fn frontiers(&self, f: &Function) -> Vec<Vec<BlockId>> {
        let n = f.blocks.len();
        let mut df: Vec<Vec<BlockId>> = vec![vec![]; n];
        let preds = f.preds();
        for b in f.block_ids() {
            if preds[b.idx()].len() >= 2 {
                for &p in &preds[b.idx()] {
                    if !self.reachable[p.idx()] {
                        continue;
                    }
                    let mut runner = p;
                    while Some(runner) != self.idom[b.idx()] && self.reachable[runner.idx()] {
                        if !df[runner.idx()].contains(&b) {
                            df[runner.idx()].push(b);
                        }
                        match self.idom[runner.idx()] {
                            Some(r) => runner = r,
                            None => break,
                        }
                    }
                }
            }
        }
        df
    }
}

/// Post-dominator tree. Built on the reverse CFG with a virtual exit that
/// post-dominates every Ret/Unreachable block.
#[derive(Clone, Debug)]
pub struct PostDomTree {
    /// Immediate post-dominator; None means the virtual exit (or
    /// unreachable-in-reverse).
    pub ipdom: Vec<Option<BlockId>>,
    pub reachable_rev: Vec<bool>,
}

impl PostDomTree {
    pub fn build(f: &Function) -> PostDomTree {
        let n = f.blocks.len();
        // Virtual exit gets index n.
        let exits = super::cfg::exit_blocks(f);
        // Reverse-graph preds(x) = successors of x in forward graph;
        // virtual exit's reverse-preds = nothing; each exit block has the
        // virtual exit as a reverse-predecessor... careful: in the REVERSE
        // graph, edges are reversed: forward a->b becomes b->a. The reverse
        // graph's root is the virtual exit with edges to every exit block.
        let mut rev_succ: Vec<Vec<usize>> = vec![vec![]; n + 1]; // edges of reverse graph
        let mut rev_pred: Vec<Vec<usize>> = vec![vec![]; n + 1];
        for b in f.block_ids() {
            for s in f.succs(b) {
                // forward edge b->s: reverse edge s->b
                rev_succ[s.idx()].push(b.idx());
                rev_pred[b.idx()].push(s.idx());
            }
        }
        for e in &exits {
            rev_succ[n].push(e.idx());
            rev_pred[e.idx()].push(n);
        }
        // RPO of reverse graph from virtual exit.
        let mut visited = vec![false; n + 1];
        let mut post: Vec<usize> = vec![];
        let mut stack: Vec<(usize, usize)> = vec![(n, 0)];
        visited[n] = true;
        while let Some((b, i)) = stack.pop() {
            if i < rev_succ[b].len() {
                stack.push((b, i + 1));
                let s = rev_succ[b][i];
                if !visited[s] {
                    visited[s] = true;
                    stack.push((s, 0));
                }
            } else {
                post.push(b);
            }
        }
        post.reverse();
        let idom_raw = compute_idoms(&post, &rev_pred, n + 1);
        let mut reachable_rev = vec![false; n];
        for &b in &post {
            if b < n {
                reachable_rev[b] = true;
            }
        }
        PostDomTree {
            ipdom: (0..n)
                .map(|i| match idom_raw[i] {
                    Some(d) if d < n => Some(BlockId(d as u32)),
                    _ => None,
                })
                .collect(),
            reachable_rev,
        }
    }

    /// Does `a` post-dominate `b`? (reflexive; virtual exit handled)
    pub fn post_dominates(&self, a: BlockId, b: BlockId) -> bool {
        if !self.reachable_rev[b.idx()] {
            return false;
        }
        let mut cur = b;
        loop {
            if cur == a {
                return true;
            }
            match self.ipdom[cur.idx()] {
                Some(d) => cur = d,
                None => return false,
            }
        }
    }

    /// Immediate post-dominator of a block (None = function exit).
    pub fn ipdom_of(&self, b: BlockId) -> Option<BlockId> {
        self.ipdom[b.idx()]
    }
}

/// Convenience: both trees plus a preds map, built together.
pub struct DomInfo {
    pub dom: DomTree,
    pub pdom: PostDomTree,
    pub preds: Vec<Vec<BlockId>>,
}

impl DomInfo {
    pub fn build(f: &Function) -> DomInfo {
        DomInfo {
            dom: DomTree::build(f),
            pdom: PostDomTree::build(f),
            preds: f.preds(),
        }
    }
}

/// Cache of per-function block orderings used by analyses.
pub fn block_order_map(f: &Function) -> HashMap<BlockId, usize> {
    super::cfg::rpo_index(f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Builder, Type, Val};

    fn diamond() -> (Function, BlockId, BlockId, BlockId, BlockId) {
        let mut f = Function::new("t", vec![], Type::Void);
        let entry = f.entry;
        let a = f.add_block("a");
        let b = f.add_block("b");
        let j = f.add_block("j");
        let mut bl = Builder::at(&mut f, entry);
        bl.cond_br(Val::cb(true), a, b);
        bl.set_block(a);
        bl.br(j);
        bl.set_block(b);
        bl.br(j);
        bl.set_block(j);
        bl.ret(None);
        (f, entry, a, b, j)
    }

    #[test]
    fn dom_diamond() {
        let (f, entry, a, b, j) = diamond();
        let dom = DomTree::build(&f);
        assert!(dom.dominates(entry, j));
        assert!(dom.dominates(entry, a));
        assert!(!dom.dominates(a, j));
        assert_eq!(dom.idom[j.idx()], Some(entry));
        assert_eq!(dom.idom[a.idx()], Some(entry));
        let _ = b;
    }

    #[test]
    fn postdom_diamond() {
        let (f, entry, a, b, j) = diamond();
        let pdom = PostDomTree::build(&f);
        assert_eq!(pdom.ipdom_of(entry), Some(j));
        assert_eq!(pdom.ipdom_of(a), Some(j));
        assert_eq!(pdom.ipdom_of(b), Some(j));
        assert_eq!(pdom.ipdom_of(j), None);
        assert!(pdom.post_dominates(j, entry));
        assert!(!pdom.post_dominates(a, entry));
    }

    #[test]
    fn frontiers_diamond() {
        let (f, _entry, a, b, j) = diamond();
        let dom = DomTree::build(&f);
        let df = dom.frontiers(&f);
        assert_eq!(df[a.idx()], vec![j]);
        assert_eq!(df[b.idx()], vec![j]);
        assert!(df[j.idx()].is_empty());
    }

    #[test]
    fn postdom_multiple_exits() {
        // entry -> (a: ret) / (b: ret) — ipdom(entry) = virtual exit = None.
        let mut f = Function::new("t", vec![], Type::Void);
        let entry = f.entry;
        let a = f.add_block("a");
        let b = f.add_block("b");
        let mut bl = Builder::at(&mut f, entry);
        bl.cond_br(Val::cb(true), a, b);
        bl.set_block(a);
        bl.ret(None);
        bl.set_block(b);
        bl.ret(None);
        let pdom = PostDomTree::build(&f);
        assert_eq!(pdom.ipdom_of(entry), None);
    }
}
