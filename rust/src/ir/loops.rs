//! Natural-loop detection (back edges on the dominator tree) and loop
//! canonicalization helpers (preheader / single-latch / dedicated exits),
//! prerequisites for the TRANSFORM_LOOP divergence handling (paper §4.3.3).

use super::dom::DomTree;
use super::{BlockId, Builder, Function, InstKind};
use std::collections::HashSet;

#[derive(Debug, Clone)]
pub struct Loop {
    pub header: BlockId,
    /// Blocks with a back edge to the header.
    pub latches: Vec<BlockId>,
    /// All blocks in the loop body (header included).
    pub blocks: HashSet<BlockId>,
    /// Parent loop index in `LoopInfo::loops`, if nested.
    pub parent: Option<usize>,
    pub depth: u32,
}

impl Loop {
    /// Blocks outside the loop that are targets of edges leaving the loop.
    pub fn exit_targets(&self, f: &Function) -> Vec<BlockId> {
        let mut out = vec![];
        for &b in &self.blocks {
            for s in f.succs(b) {
                if !self.blocks.contains(&s) && !out.contains(&s) {
                    out.push(s);
                }
            }
        }
        out
    }

    /// In-loop blocks with an edge leaving the loop.
    pub fn exiting_blocks(&self, f: &Function) -> Vec<BlockId> {
        let mut out = vec![];
        for &b in &self.blocks {
            if f.succs(b).iter().any(|s| !self.blocks.contains(s)) && !out.contains(&b) {
                out.push(b);
            }
        }
        out
    }

    /// The unique preheader: the single non-latch predecessor of the header,
    /// if it exists and has the header as its only successor.
    pub fn preheader(&self, f: &Function) -> Option<BlockId> {
        let preds = f.preds();
        let outside: Vec<BlockId> = preds[self.header.idx()]
            .iter()
            .copied()
            .filter(|p| !self.blocks.contains(p))
            .collect();
        match outside.as_slice() {
            [p] if f.succs(*p).len() == 1 => Some(*p),
            _ => None,
        }
    }
}

#[derive(Debug, Default)]
pub struct LoopInfo {
    pub loops: Vec<Loop>,
    /// Innermost loop index per block.
    pub loop_of: Vec<Option<usize>>,
}

impl LoopInfo {
    pub fn build(f: &Function) -> LoopInfo {
        LoopInfo::build_with(f, &DomTree::build(f))
    }

    /// [`LoopInfo::build`] against a caller-supplied (cached) tree.
    pub fn build_with(f: &Function, dom: &DomTree) -> LoopInfo {
        let mut loops: Vec<Loop> = vec![];
        // Find back edges n->h with h dominating n; group by header.
        let mut by_header: Vec<(BlockId, Vec<BlockId>)> = vec![];
        for b in f.block_ids() {
            for s in f.succs(b) {
                if dom.dominates(s, b) {
                    match by_header.iter_mut().find(|(h, _)| *h == s) {
                        Some((_, l)) => l.push(b),
                        None => by_header.push((s, vec![b])),
                    }
                }
            }
        }
        let preds = f.preds();
        for (header, latches) in by_header {
            // Natural loop body: header + all blocks that reach a latch
            // without passing through the header.
            let mut blocks: HashSet<BlockId> = HashSet::new();
            blocks.insert(header);
            let mut work: Vec<BlockId> = latches.clone();
            while let Some(b) = work.pop() {
                if blocks.insert(b) {
                    for &p in &preds[b.idx()] {
                        if !blocks.contains(&p) {
                            work.push(p);
                        }
                    }
                }
            }
            loops.push(Loop {
                header,
                latches,
                blocks,
                parent: None,
                depth: 1,
            });
        }
        // Establish nesting: loop A is parent of B if A != B and A.blocks ⊇ B.blocks.
        // Sort by size so parents come later; pick the smallest strict superset.
        let mut order: Vec<usize> = (0..loops.len()).collect();
        order.sort_by_key(|&i| loops[i].blocks.len());
        for oi in 0..order.len() {
            let i = order[oi];
            let mut best: Option<usize> = None;
            for &j in order.iter().skip(oi + 1) {
                if loops[j].blocks.is_superset(&loops[i].blocks) && loops[j].header != loops[i].header
                {
                    best = match best {
                        None => Some(j),
                        Some(b) if loops[j].blocks.len() < loops[b].blocks.len() => Some(j),
                        b => b,
                    };
                }
            }
            loops[i].parent = best;
        }
        // Depths.
        for i in 0..loops.len() {
            let mut d = 1;
            let mut p = loops[i].parent;
            while let Some(pi) = p {
                d += 1;
                p = loops[pi].parent;
            }
            loops[i].depth = d;
        }
        // Innermost loop per block = the smallest loop containing it.
        let mut loop_of: Vec<Option<usize>> = vec![None; f.blocks.len()];
        for (i, l) in loops.iter().enumerate() {
            for &b in &l.blocks {
                loop_of[b.idx()] = match loop_of[b.idx()] {
                    None => Some(i),
                    Some(j) if l.blocks.len() < loops[j].blocks.len() => Some(i),
                    j => j,
                };
            }
        }
        LoopInfo { loops, loop_of }
    }

    pub fn is_header(&self, b: BlockId) -> bool {
        self.loops.iter().any(|l| l.header == b)
    }

    /// The loop (innermost) containing block `b`, if any.
    pub fn innermost(&self, b: BlockId) -> Option<&Loop> {
        self.loop_of[b.idx()].map(|i| &self.loops[i])
    }

    /// Is the terminator of `b` a "loop branch" — i.e. an exiting or
    /// latch branch of the loop containing `b`? (paper Algorithm 2,
    /// IS_LOOP_BRANCH)
    pub fn is_loop_branch(&self, f: &Function, b: BlockId) -> bool {
        if let Some(l) = self.innermost(b) {
            let succs = f.succs(b);
            let is_latch = succs.contains(&l.header) && l.latches.contains(&b);
            let is_exiting = succs.iter().any(|s| !l.blocks.contains(s));
            is_latch || is_exiting
        } else {
            false
        }
    }
}

/// Ensure the loop with header `header` has a preheader; create one if
/// needed. Returns the preheader block. Rebuild analyses afterwards.
pub fn ensure_preheader(f: &mut Function, li_header: BlockId, body: &HashSet<BlockId>) -> BlockId {
    let preds = f.preds();
    let outside: Vec<BlockId> = preds[li_header.idx()]
        .iter()
        .copied()
        .filter(|p| !body.contains(p))
        .collect();
    if let [p] = outside.as_slice() {
        if f.succs(*p).len() == 1 {
            return *p;
        }
    }
    // Create preheader: all outside preds retarget to it.
    let ph = f.add_block("preheader");
    {
        let mut b = Builder::at(f, ph);
        b.br(li_header);
    }
    for p in &outside {
        let t = f.term(*p);
        f.inst_mut(t).kind.replace_successor(li_header, ph);
    }
    f.invalidate_cfg_cache();
    // Rewrite header phis: merge the outside incomings into one via-ph
    // incoming. Since multiple outside preds may exist with different
    // values, we must build a phi in the preheader.
    let header_insts = f.blocks[li_header.idx()].insts.clone();
    for i in header_insts {
        let is_phi = matches!(f.inst(i).kind, InstKind::Phi { .. });
        if !is_phi {
            break;
        }
        let ty = f.inst(i).ty;
        let (mut outside_incs, inside_incs): (Vec<_>, Vec<_>) =
            if let InstKind::Phi { incs } = &f.inst(i).kind {
                incs.iter()
                    .cloned()
                    .partition(|(p, _)| outside.contains(p))
            } else {
                unreachable!()
            };
        if outside_incs.is_empty() {
            continue;
        }
        let merged = if outside_incs.len() == 1 {
            outside_incs.pop().unwrap().1
        } else {
            // Insert a phi in the preheader merging the outside values.
            let id = f.insert_inst(
                ph,
                0,
                InstKind::Phi {
                    incs: outside_incs,
                },
                ty,
            );
            super::Val::Inst(id)
        };
        let mut incs = inside_incs;
        incs.push((ph, merged));
        if let InstKind::Phi { incs: pincs } = &mut f.inst_mut(i).kind {
            *pincs = incs;
        }
    }
    ph
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Type, Val};

    /// while-loop shape: entry -> header; header -> body|exit; body -> header.
    fn simple_loop() -> (Function, BlockId, BlockId, BlockId) {
        let mut f = Function::new("t", vec![], Type::Void);
        let entry = f.entry;
        let h = f.add_block("h");
        let body = f.add_block("body");
        let exit = f.add_block("exit");
        let mut b = Builder::at(&mut f, entry);
        b.br(h);
        b.set_block(h);
        b.cond_br(Val::cb(true), body, exit);
        b.set_block(body);
        b.br(h);
        b.set_block(exit);
        b.ret(None);
        (f, h, body, exit)
    }

    #[test]
    fn detects_loop() {
        let (f, h, body, exit) = simple_loop();
        let li = LoopInfo::build(&f);
        assert_eq!(li.loops.len(), 1);
        let l = &li.loops[0];
        assert_eq!(l.header, h);
        assert_eq!(l.latches, vec![body]);
        assert!(l.blocks.contains(&h) && l.blocks.contains(&body));
        assert!(!l.blocks.contains(&exit));
        assert_eq!(l.exit_targets(&f), vec![exit]);
        assert!(li.is_loop_branch(&f, h));
        assert!(li.is_loop_branch(&f, body)); // latch
    }

    #[test]
    fn preheader_detection_and_creation() {
        let (mut f, h, _body, _exit) = simple_loop();
        let li = LoopInfo::build(&f);
        // entry is a valid preheader already (single succ).
        assert_eq!(li.loops[0].preheader(&f), Some(f.entry));
        let body = li.loops[0].blocks.clone();
        let ph = ensure_preheader(&mut f, h, &body);
        assert_eq!(ph, f.entry);
    }

    #[test]
    fn nested_loops_depth() {
        let mut f = Function::new("t", vec![], Type::Void);
        let entry = f.entry;
        let oh = f.add_block("oh");
        let ih = f.add_block("ih");
        let ib = f.add_block("ib");
        let ol = f.add_block("ol");
        let exit = f.add_block("exit");
        let mut b = Builder::at(&mut f, entry);
        b.br(oh);
        b.set_block(oh);
        b.br(ih);
        b.set_block(ih);
        b.cond_br(Val::cb(true), ib, ol);
        b.set_block(ib);
        b.br(ih);
        b.set_block(ol);
        b.cond_br(Val::cb(true), oh, exit);
        b.set_block(exit);
        b.ret(None);
        let li = LoopInfo::build(&f);
        assert_eq!(li.loops.len(), 2);
        let inner = li.innermost(ib).unwrap();
        assert_eq!(inner.header, ih);
        assert_eq!(inner.depth, 2);
        let outer_idx = li.loop_of[ol.idx()].unwrap();
        assert_eq!(li.loops[outer_idx].header, oh);
        assert_eq!(li.loops[outer_idx].depth, 1);
    }
}
