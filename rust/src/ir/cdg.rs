//! Control-dependence graph (Ferrante–Ottenstein–Warren, via the
//! post-dominator tree).
//!
//! Used by the uniformity analysis (divergent branches taint
//! control-dependent values, paper §4.3.1) and by the CFG-reconstruction
//! pass, which duplicates *divergent CDG leaf nodes* to reduce
//! linearization predicate complexity (paper §4.3.2, Fig. 6).

use super::dom::PostDomTree;
use super::{BlockId, Function};

#[derive(Debug)]
pub struct Cdg {
    /// For each block b: the branch blocks that b is control-dependent on.
    pub deps: Vec<Vec<BlockId>>,
    /// For each branch block a: the blocks control-dependent on a.
    pub dependents: Vec<Vec<BlockId>>,
}

impl Cdg {
    pub fn build(f: &Function) -> Cdg {
        let pdom = PostDomTree::build(f);
        Cdg::build_with(f, &pdom)
    }

    pub fn build_with(f: &Function, pdom: &PostDomTree) -> Cdg {
        let n = f.blocks.len();
        let mut deps: Vec<Vec<BlockId>> = vec![vec![]; n];
        let mut dependents: Vec<Vec<BlockId>> = vec![vec![]; n];
        for a in f.block_ids() {
            let succs = f.succs(a);
            if succs.len() < 2 {
                continue;
            }
            let stop = pdom.ipdom_of(a);
            for s in succs {
                // Walk the postdom tree from s up to (exclusive) ipdom(a);
                // every visited node is control-dependent on a.
                let mut cur = Some(s);
                while let Some(c) = cur {
                    if Some(c) == stop {
                        break;
                    }
                    if !deps[c.idx()].contains(&a) {
                        deps[c.idx()].push(a);
                        dependents[a.idx()].push(c);
                    }
                    cur = pdom.ipdom_of(c);
                }
            }
        }
        Cdg { deps, dependents }
    }

    /// Depth of the control-dependence chain for block `b` (number of
    /// distinct branch blocks it transitively depends on). A proxy for
    /// linearization predicate cost (paper: "the OpenCL cfd benchmark's CDG
    /// exhibits substantial depth").
    pub fn dep_depth(&self, b: BlockId) -> usize {
        let mut seen: Vec<BlockId> = vec![];
        let mut work = vec![b];
        while let Some(x) = work.pop() {
            for &d in &self.deps[x.idx()] {
                if !seen.contains(&d) {
                    seen.push(d);
                    work.push(d);
                }
            }
        }
        seen.len()
    }

    /// A CDG *leaf* node: a block that nothing is control-dependent on
    /// (no dependents), but which itself has control dependences.
    pub fn is_leaf(&self, b: BlockId) -> bool {
        self.dependents[b.idx()].is_empty() && !self.deps[b.idx()].is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Builder, Type, Val};

    #[test]
    fn diamond_cdg() {
        let mut f = Function::new("t", vec![], Type::Void);
        let entry = f.entry;
        let a = f.add_block("a");
        let b = f.add_block("b");
        let j = f.add_block("j");
        let mut bl = Builder::at(&mut f, entry);
        bl.cond_br(Val::cb(true), a, b);
        bl.set_block(a);
        bl.br(j);
        bl.set_block(b);
        bl.br(j);
        bl.set_block(j);
        bl.ret(None);
        let cdg = Cdg::build(&f);
        assert_eq!(cdg.deps[a.idx()], vec![entry]);
        assert_eq!(cdg.deps[b.idx()], vec![entry]);
        assert!(cdg.deps[j.idx()].is_empty());
        assert_eq!(cdg.dependents[entry.idx()].len(), 2);
        assert!(cdg.is_leaf(a));
        assert_eq!(cdg.dep_depth(a), 1);
    }

    #[test]
    fn loop_header_self_dependence() {
        // while loop: header is control-dependent on itself (via latch path).
        let mut f = Function::new("t", vec![], Type::Void);
        let entry = f.entry;
        let h = f.add_block("h");
        let body = f.add_block("body");
        let exit = f.add_block("exit");
        let mut bl = Builder::at(&mut f, entry);
        bl.br(h);
        bl.set_block(h);
        bl.cond_br(Val::cb(true), body, exit);
        bl.set_block(body);
        bl.br(h);
        bl.set_block(exit);
        bl.ret(None);
        let cdg = Cdg::build(&f);
        // body and h are control dependent on h.
        assert!(cdg.deps[body.idx()].contains(&h));
        assert!(cdg.deps[h.idx()].contains(&h));
        assert!(cdg.deps[exit.idx()].is_empty());
    }

    #[test]
    fn nested_depth() {
        // entry -> (c1 ? m : j); m -> (c2 ? x : j2)... x depends on 2 branches.
        let mut f = Function::new("t", vec![], Type::Void);
        let entry = f.entry;
        let m = f.add_block("m");
        let x = f.add_block("x");
        let j2 = f.add_block("j2");
        let j = f.add_block("j");
        let mut bl = Builder::at(&mut f, entry);
        bl.cond_br(Val::cb(true), m, j);
        bl.set_block(m);
        bl.cond_br(Val::cb(true), x, j2);
        bl.set_block(x);
        bl.br(j2);
        bl.set_block(j2);
        bl.br(j);
        bl.set_block(j);
        bl.ret(None);
        let cdg = Cdg::build(&f);
        assert_eq!(cdg.dep_depth(x), 2);
        assert!(cdg.is_leaf(x));
    }
}
