//! IR verifier: structural and SSA invariants. Run between every pass in
//! debug pipelines; the pass manager asserts it in tests.

use super::dom::DomTree;
use super::*;
use std::collections::HashSet;

#[derive(Debug)]
pub struct VerifyError {
    pub func: String,
    pub msg: String,
}

impl std::fmt::Display for VerifyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "verify({}): {}", self.func, self.msg)
    }
}

pub fn verify_module(m: &Module) -> Result<(), VerifyError> {
    for f in &m.funcs {
        verify_function(f).map_err(|msg| VerifyError {
            func: f.name.clone(),
            msg,
        })?;
        // Call signatures.
        for inst in f.insts.iter().filter(|i| !i.dead) {
            if let InstKind::Call { callee, args } = &inst.kind {
                let cf = m
                    .funcs
                    .get(callee.idx())
                    .ok_or_else(|| VerifyError {
                        func: f.name.clone(),
                        msg: format!("call to unknown function f{}", callee.0),
                    })?;
                if cf.params.len() != args.len() {
                    return Err(VerifyError {
                        func: f.name.clone(),
                        msg: format!(
                            "call to @{} with {} args, expected {}",
                            cf.name,
                            args.len(),
                            cf.params.len()
                        ),
                    });
                }
            }
        }
    }
    Ok(())
}

pub fn verify_function(f: &Function) -> Result<(), String> {
    let preds = f.preds();
    // Block structure.
    for b in f.block_ids() {
        let insts = &f.blocks[b.idx()].insts;
        if insts.is_empty() {
            return Err(format!("block b{} is empty", b.0));
        }
        for (i, &id) in insts.iter().enumerate() {
            let inst = f.inst(id);
            if inst.dead {
                return Err(format!("block b{} references dead inst %i{}", b.0, id.0));
            }
            if inst.block != b {
                return Err(format!(
                    "inst %i{} thinks it is in b{} but listed in b{}",
                    id.0, inst.block.0, b.0
                ));
            }
            let is_last = i + 1 == insts.len();
            if inst.kind.is_terminator() != is_last {
                return Err(format!(
                    "terminator placement error at %i{} in b{}",
                    id.0, b.0
                ));
            }
            // Phis must form a prefix of the block.
            if matches!(inst.kind, InstKind::Phi { .. }) {
                let all_phi_before = insts[..i]
                    .iter()
                    .all(|&p| matches!(f.inst(p).kind, InstKind::Phi { .. }));
                if !all_phi_before {
                    return Err(format!("phi %i{} not at head of b{}", id.0, b.0));
                }
            }
            // Join must be the first non-phi instruction.
            if matches!(
                inst.kind,
                InstKind::Intr {
                    intr: Intr::Join,
                    ..
                }
            ) {
                let pre_ok = insts[..i].iter().all(|&p| {
                    matches!(
                        f.inst(p).kind,
                        InstKind::Phi { .. }
                            | InstKind::Intr {
                                intr: Intr::Join,
                                ..
                            }
                    )
                });
                if !pre_ok {
                    return Err(format!("join %i{} not at head of b{}", id.0, b.0));
                }
            }
        }
        // Successors must be live.
        for s in f.succs(b) {
            if f.blocks[s.idx()].dead {
                return Err(format!("b{} branches to dead block b{}", b.0, s.0));
            }
        }
    }
    // Phi incoming sets match predecessors (for reachable blocks).
    let reachable: HashSet<BlockId> = f.rpo().into_iter().collect();
    for b in f.block_ids() {
        if !reachable.contains(&b) {
            continue;
        }
        for &id in &f.blocks[b.idx()].insts {
            if let InstKind::Phi { incs } = &f.inst(id).kind {
                let inc_blocks: HashSet<BlockId> = incs.iter().map(|(p, _)| *p).collect();
                let pred_set: HashSet<BlockId> = preds[b.idx()]
                    .iter()
                    .copied()
                    .filter(|p| reachable.contains(p))
                    .collect();
                if inc_blocks != pred_set {
                    return Err(format!(
                        "phi %i{} in b{} incoming blocks {:?} != preds {:?}",
                        id.0, b.0, inc_blocks, pred_set
                    ));
                }
                if incs.len() != inc_blocks.len() {
                    return Err(format!("phi %i{} has duplicate incoming blocks", id.0));
                }
            }
        }
    }
    // SSA dominance: every use is dominated by its def.
    let dom = DomTree::build(f);
    let pos_of = |id: InstId| -> (BlockId, usize) {
        let b = f.inst(id).block;
        let i = f.blocks[b.idx()].insts.iter().position(|&x| x == id).unwrap();
        (b, i)
    };
    for b in f.block_ids() {
        if !reachable.contains(&b) {
            continue;
        }
        for (use_pos, &id) in f.blocks[b.idx()].insts.iter().enumerate() {
            let inst = f.inst(id);
            let check = |def: InstId, at_block: BlockId, at_pos: usize| -> Result<(), String> {
                if f.inst(def).dead {
                    return Err(format!("%i{} uses dead value %i{}", id.0, def.0));
                }
                let (db, dp) = pos_of(def);
                let ok = if db == at_block {
                    dp < at_pos
                        || matches!(f.inst(id).kind, InstKind::Phi { .. }) && db != at_block
                } else {
                    dom.dominates(db, at_block)
                };
                if !ok && !matches!(f.inst(def).kind, InstKind::SplitBr { .. }) {
                    return Err(format!(
                        "use of %i{} in %i{} (b{}) not dominated by def (b{})",
                        def.0, id.0, at_block.0, db.0
                    ));
                }
                Ok(())
            };
            match &inst.kind {
                InstKind::Phi { incs } => {
                    for (p, v) in incs {
                        if let Val::Inst(def) = v {
                            // Use point is the end of predecessor p.
                            if reachable.contains(p) {
                                check(*def, *p, f.blocks[p.idx()].insts.len())?;
                            }
                        }
                    }
                }
                k => {
                    for op in k.operands() {
                        if let Val::Inst(def) = op {
                            check(def, b, use_pos)?;
                        }
                    }
                }
            }
        }
    }
    // Joins take no arguments (stack-popping semantics); every SplitBr's
    // recorded ipdom block must contain a Join.
    for inst in f.insts.iter().filter(|i| !i.dead) {
        if let InstKind::Intr {
            intr: Intr::Join,
            args,
        } = &inst.kind
        {
            if !args.is_empty() {
                return Err("join takes no arguments".into());
            }
        }
        if let InstKind::SplitBr { ipdom, .. } = &inst.kind {
            let has_join = f.blocks[ipdom.idx()].insts.iter().any(|&i| {
                matches!(
                    f.inst(i).kind,
                    InstKind::Intr {
                        intr: Intr::Join,
                        ..
                    }
                )
            });
            if !has_join {
                return Err(format!(
                    "splitbr reconvergence block b{} has no join",
                    ipdom.0
                ));
            }
        }
    }
    verify_split_join_nesting(f)?;
    Ok(())
}

/// `vx_split` / `vx_join` well-nesting (meaningful after
/// `divergence_insert`; vacuous before, when no SplitBr/Join exists).
///
/// Models the hardware IPDOM stack along every static path: a `SplitBr`
/// pushes its reconvergence block, a `Join` pops the top entry when it
/// names the current block (the hardware no-ops otherwise, so stray
/// joins are tolerated exactly as silicon tolerates them). Two
/// invariants must hold or runtime masks corrupt:
///
/// * every block must be reached with the same pending-reconvergence
///   stack on all paths (otherwise stack depth is path-dependent), and
/// * a `Ret` must retire with an empty stack (otherwise the warp dies
///   holding queued else-sides whose lanes never run).
fn verify_split_join_nesting(f: &Function) -> Result<(), String> {
    let managed = f.insts.iter().any(|i| {
        !i.dead
            && matches!(
                i.kind,
                InstKind::SplitBr { .. }
                    | InstKind::Intr {
                        intr: Intr::Join,
                        ..
                    }
            )
    });
    if !managed {
        return Ok(());
    }
    let mut states: Vec<Option<Vec<BlockId>>> = vec![None; f.blocks.len()];
    states[f.entry.idx()] = Some(vec![]);
    let mut work = vec![f.entry];
    while let Some(b) = work.pop() {
        let mut stack = states[b.idx()].clone().expect("enqueued with a state");
        for &id in &f.blocks[b.idx()].insts {
            match &f.inst(id).kind {
                InstKind::Intr {
                    intr: Intr::Join, ..
                } => {
                    // Pop only a matching top — hardware join semantics.
                    if stack.last() == Some(&b) {
                        stack.pop();
                    }
                }
                InstKind::SplitBr { ipdom, .. } => stack.push(*ipdom),
                InstKind::Ret { .. } => {
                    if !stack.is_empty() {
                        return Err(format!(
                            "ret in b{} retires with pending vx_split reconvergence \
                             {:?} (unbalanced vx_split/vx_join nesting)",
                            b.0,
                            stack.iter().map(|x| x.0).collect::<Vec<_>>()
                        ));
                    }
                }
                _ => {}
            }
        }
        for s in f.succs(b) {
            match &states[s.idx()] {
                Some(prev) if *prev != stack => {
                    return Err(format!(
                        "b{} is reached with vx_split reconvergence stack {:?} on one \
                         path and {:?} on another (unbalanced vx_split/vx_join nesting)",
                        s.0,
                        stack.iter().map(|x| x.0).collect::<Vec<_>>(),
                        prev.iter().map(|x| x.0).collect::<Vec<_>>()
                    ));
                }
                Some(_) => {}
                None => {
                    states[s.idx()] = Some(stack.clone());
                    work.push(s);
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Builder, Param};

    #[test]
    fn accepts_valid_function() {
        let mut f = Function::new(
            "ok",
            vec![Param {
                name: "n".into(),
                ty: Type::I32,
                uniform: false,
            }],
            Type::I32,
        );
        let exit = f.add_block("exit");
        let body = f.add_block("body");
        let mut b = Builder::new(&mut f);
        let c = b.icmp(ICmp::Slt, Val::Arg(0), Val::ci(10));
        b.cond_br(c, body, exit);
        b.set_block(body);
        b.br(exit);
        b.set_block(exit);
        b.ret(Some(Val::Arg(0)));
        verify_function(&f).unwrap();
    }

    #[test]
    fn rejects_missing_terminator() {
        let mut f = Function::new("bad", vec![], Type::Void);
        let e = f.entry;
        f.push_inst(
            e,
            InstKind::Bin {
                op: BinOp::Add,
                a: Val::ci(1),
                b: Val::ci(2),
            },
            Type::I32,
        );
        assert!(verify_function(&f).is_err());
    }

    #[test]
    fn rejects_phi_pred_mismatch() {
        let mut f = Function::new("bad", vec![], Type::I32);
        let e = f.entry;
        let x = f.add_block("x");
        let mut b = Builder::at(&mut f, e);
        b.br(x);
        b.set_block(x);
        // Phi claims an incoming from x itself, which is not a pred.
        let p = b.phi(Type::I32, vec![(x, Val::ci(1))]);
        b.ret(Some(p));
        assert!(verify_function(&f).is_err());
    }

    #[test]
    fn split_join_nesting_enforced() {
        // ret inside the split region (before the join at the
        // reconvergence block runs) — the warp would retire holding a
        // queued else-side. Must be rejected.
        let build = |early_ret: bool| {
            let mut f = Function::new(
                "t",
                vec![Param {
                    name: "c".into(),
                    ty: Type::I32,
                    uniform: false,
                }],
                Type::Void,
            );
            let e = f.entry;
            let a = f.add_block("then");
            let bb = f.add_block("else");
            let m = f.add_block("merge");
            let mut b = Builder::at(&mut f, e);
            b.split_br(Val::Arg(0), a, bb, m);
            b.set_block(a);
            if early_ret {
                b.ret(None);
            } else {
                b.br(m);
            }
            b.set_block(bb);
            b.br(m);
            b.set_block(m);
            b.intr(Intr::Join, vec![]);
            b.ret(None);
            f
        };
        let err = verify_function(&build(true)).unwrap_err();
        assert!(err.contains("vx_split"), "{err}");
        verify_function(&build(false)).unwrap();
    }

    #[test]
    fn rejects_use_before_def() {
        let mut f = Function::new("bad", vec![], Type::I32);
        let e = f.entry;
        // Manually create use-before-def in the same block.
        let use_id = f.push_inst(
            e,
            InstKind::Bin {
                op: BinOp::Add,
                a: Val::Inst(InstId(1)), // defined below
                b: Val::ci(1),
            },
            Type::I32,
        );
        let _def = f.push_inst(
            e,
            InstKind::Bin {
                op: BinOp::Add,
                a: Val::ci(1),
                b: Val::ci(2),
            },
            Type::I32,
        );
        f.push_inst(
            e,
            InstKind::Ret {
                val: Some(Val::Inst(use_id)),
            },
            Type::Void,
        );
        assert!(verify_function(&f).is_err());
    }
}
