//! Textual IR printer. The syntax is regular and round-trips through
//! [`super::parser`]; it is the `volt ir` CLI output and the substrate for
//! golden tests.

use super::*;
use std::fmt::Write;

pub fn print_module(m: &Module) -> String {
    let mut s = String::new();
    writeln!(s, "module \"{}\"", m.name).unwrap();
    for (i, g) in m.globals.iter().enumerate() {
        write!(
            s,
            "global @{} {} size={} align={}",
            g.name,
            space_name(g.space),
            g.size,
            g.align
        )
        .unwrap();
        if let Some(init) = &g.init {
            write!(s, " init=").unwrap();
            for b in init {
                write!(s, "{:02x}", b).unwrap();
            }
        }
        writeln!(s).unwrap();
        let _ = i;
    }
    for f in &m.funcs {
        s.push_str(&print_function(f));
    }
    s
}

pub fn space_name(sp: AddrSpace) -> &'static str {
    match sp {
        AddrSpace::Global => "global",
        AddrSpace::Local => "local",
        AddrSpace::Const => "const",
        AddrSpace::Private => "private",
    }
}

pub fn type_name(t: Type) -> String {
    match t {
        Type::Void => "void".into(),
        Type::I1 => "i1".into(),
        Type::I32 => "i32".into(),
        Type::F32 => "f32".into(),
        Type::Ptr(sp) => format!("ptr.{}", space_name(sp)),
    }
}

fn val_str(f: &Function, v: Val) -> String {
    match v {
        Val::Inst(i) => format!("%i{}", i.0),
        Val::Arg(i) => format!("%{}", f.params[i as usize].name),
        Val::I(x, Type::I1) => if x != 0 { "true".into() } else { "false".into() },
        Val::I(x, _) => format!("{}", x),
        Val::F(b) => format!("f0x{:08x}", b),
        Val::G(g) => format!("@g{}", g.0),
    }
}

fn bin_name(op: BinOp) -> &'static str {
    match op {
        BinOp::Add => "add",
        BinOp::Sub => "sub",
        BinOp::Mul => "mul",
        BinOp::SDiv => "sdiv",
        BinOp::SRem => "srem",
        BinOp::UDiv => "udiv",
        BinOp::URem => "urem",
        BinOp::And => "and",
        BinOp::Or => "or",
        BinOp::Xor => "xor",
        BinOp::Shl => "shl",
        BinOp::LShr => "lshr",
        BinOp::AShr => "ashr",
        BinOp::SMin => "smin",
        BinOp::SMax => "smax",
        BinOp::FAdd => "fadd",
        BinOp::FSub => "fsub",
        BinOp::FMul => "fmul",
        BinOp::FDiv => "fdiv",
        BinOp::FMin => "fmin",
        BinOp::FMax => "fmax",
    }
}

fn un_name(op: UnOp) -> &'static str {
    match op {
        UnOp::Not => "not",
        UnOp::FNeg => "fneg",
        UnOp::FSqrt => "fsqrt",
        UnOp::FAbs => "fabs",
        UnOp::FExp => "fexp",
        UnOp::FLog => "flog",
        UnOp::FFloor => "ffloor",
        UnOp::SiToFp => "sitofp",
        UnOp::FpToSi => "fptosi",
        UnOp::ZExt => "zext",
        UnOp::Trunc => "trunc",
        UnOp::FToBits => "ftobits",
        UnOp::BitsToF => "bitstof",
    }
}

fn icmp_name(p: ICmp) -> &'static str {
    match p {
        ICmp::Eq => "eq",
        ICmp::Ne => "ne",
        ICmp::Slt => "slt",
        ICmp::Sle => "sle",
        ICmp::Sgt => "sgt",
        ICmp::Sge => "sge",
        ICmp::Ult => "ult",
        ICmp::Uge => "uge",
    }
}

fn fcmp_name(p: FCmp) -> &'static str {
    match p {
        FCmp::Oeq => "oeq",
        FCmp::One => "one",
        FCmp::Olt => "olt",
        FCmp::Ole => "ole",
        FCmp::Ogt => "ogt",
        FCmp::Oge => "oge",
    }
}

fn atom_name(a: AtomOp) -> &'static str {
    match a {
        AtomOp::Add => "add",
        AtomOp::And => "and",
        AtomOp::Or => "or",
        AtomOp::Xor => "xor",
        AtomOp::Min => "min",
        AtomOp::Max => "max",
        AtomOp::Exch => "exch",
    }
}

fn wi_name(w: WorkItem) -> &'static str {
    match w {
        WorkItem::GlobalId => "global_id",
        WorkItem::LocalId => "local_id",
        WorkItem::GroupId => "group_id",
        WorkItem::LocalSize => "local_size",
        WorkItem::GlobalSize => "global_size",
        WorkItem::NumGroups => "num_groups",
    }
}

fn csr_name(c: Csr) -> &'static str {
    match c {
        Csr::LaneId => "lane_id",
        Csr::WarpId => "warp_id",
        Csr::CoreId => "core_id",
        Csr::NumThreads => "num_threads",
        Csr::NumWarps => "num_warps",
        Csr::NumCores => "num_cores",
    }
}

pub fn intr_name(i: &Intr) -> String {
    match i {
        Intr::WorkItem(w) => format!("workitem.{}", wi_name(*w)),
        Intr::Csr(c) => format!("csr.{}", csr_name(*c)),
        Intr::Barrier => "barrier".into(),
        Intr::Atomic(a) => format!("atomic.{}", atom_name(*a)),
        Intr::AtomicCas => "atomic.cas".into(),
        Intr::VoteAll => "vote.all".into(),
        Intr::VoteAny => "vote.any".into(),
        Intr::Ballot => "ballot".into(),
        Intr::Shfl => "shfl".into(),
        Intr::Join => "join".into(),
        Intr::Tmc => "tmc".into(),
        Intr::Mask => "mask".into(),
        Intr::PrintI => "printi".into(),
        Intr::PrintF => "printf".into(),
    }
}

pub fn print_function(f: &Function) -> String {
    let mut s = String::new();
    write!(s, "func @{}(", f.name).unwrap();
    for (i, p) in f.params.iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        write!(s, "{} %{}", type_name(p.ty), p.name).unwrap();
        if p.uniform {
            s.push_str(" uniform");
        }
    }
    write!(s, ") -> {}", type_name(f.ret)).unwrap();
    if f.is_kernel {
        s.push_str(" kernel");
    }
    if f.linkage == Linkage::Internal {
        s.push_str(" internal");
    }
    if f.ret_uniform {
        s.push_str(" retuniform");
    }
    if f.local_mem_size > 0 {
        write!(s, " localmem={}", f.local_mem_size).unwrap();
    }
    s.push_str(" {\n");
    for b in f.block_ids() {
        writeln!(s, "b{}:", b.0).unwrap();
        for &i in &f.blocks[b.idx()].insts {
            s.push_str("  ");
            s.push_str(&print_inst(f, i));
            s.push('\n');
        }
    }
    s.push_str("}\n");
    s
}

pub fn print_inst(f: &Function, id: InstId) -> String {
    let inst = f.inst(id);
    let v = |x: Val| val_str(f, x);
    let mut s = String::new();
    if inst.ty != Type::Void {
        write!(s, "%i{}:{} = ", id.0, type_name(inst.ty)).unwrap();
    }
    match &inst.kind {
        InstKind::Bin { op, a, b } => write!(s, "bin.{} {}, {}", bin_name(*op), v(*a), v(*b)).unwrap(),
        InstKind::Un { op, a } => write!(s, "un.{} {}", un_name(*op), v(*a)).unwrap(),
        InstKind::ICmp { pred, a, b } => {
            write!(s, "icmp.{} {}, {}", icmp_name(*pred), v(*a), v(*b)).unwrap()
        }
        InstKind::FCmp { pred, a, b } => {
            write!(s, "fcmp.{} {}, {}", fcmp_name(*pred), v(*a), v(*b)).unwrap()
        }
        InstKind::Select { cond, t, f: fv } => {
            write!(s, "select {}, {}, {}", v(*cond), v(*t), v(*fv)).unwrap()
        }
        InstKind::Alloca { size } => write!(s, "alloca {}", size).unwrap(),
        InstKind::Load { ptr } => write!(s, "load {}", v(*ptr)).unwrap(),
        InstKind::Store { ptr, val } => write!(s, "store {}, {}", v(*ptr), v(*val)).unwrap(),
        InstKind::Gep {
            base,
            index,
            scale,
            disp,
        } => write!(s, "gep {}, {}, {}, {}", v(*base), v(*index), scale, disp).unwrap(),
        InstKind::Call { callee, args } => {
            write!(s, "call @f{}(", callee.0).unwrap();
            for (i, a) in args.iter().enumerate() {
                if i > 0 {
                    s.push_str(", ");
                }
                s.push_str(&v(*a));
            }
            s.push(')');
        }
        InstKind::Intr { intr, args } => {
            write!(s, "intr.{}", intr_name(intr)).unwrap();
            for (i, a) in args.iter().enumerate() {
                s.push_str(if i == 0 { " " } else { ", " });
                s.push_str(&v(*a));
            }
        }
        InstKind::Phi { incs } => {
            s.push_str("phi");
            for (i, (b, val)) in incs.iter().enumerate() {
                s.push_str(if i == 0 { " " } else { ", " });
                write!(s, "[b{}: {}]", b.0, v(*val)).unwrap();
            }
        }
        InstKind::Br { target } => write!(s, "br b{}", target.0).unwrap(),
        InstKind::CondBr { cond, t, f: fb } => {
            write!(s, "condbr {}, b{}, b{}", v(*cond), t.0, fb.0).unwrap()
        }
        InstKind::SplitBr {
            cond,
            neg,
            then_b,
            else_b,
            ipdom,
        } => write!(
            s,
            "splitbr {}, {}, b{}, b{}, b{}",
            v(*cond),
            if *neg { "neg" } else { "pos" },
            then_b.0,
            else_b.0,
            ipdom.0
        )
        .unwrap(),
        InstKind::PredBr {
            cond,
            mask,
            body,
            exit,
        } => write!(
            s,
            "predbr {}, {}, b{}, b{}",
            v(*cond),
            v(*mask),
            body.0,
            exit.0
        )
        .unwrap(),
        InstKind::Ret { val } => match val {
            Some(x) => write!(s, "ret {}", v(*x)).unwrap(),
            None => s.push_str("ret"),
        },
        InstKind::Unreachable => s.push_str("unreachable"),
    }
    if inst.uniform_ann {
        s.push_str(" !uniform");
    }
    if let Some(loc) = inst.loc {
        write!(s, " !loc {}:{}", loc.line, loc.col).unwrap();
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Builder, Param};

    #[test]
    fn prints_kernel() {
        let mut f = Function::new(
            "saxpy",
            vec![
                Param {
                    name: "x".into(),
                    ty: Type::Ptr(AddrSpace::Global),
                    uniform: true,
                },
                Param {
                    name: "n".into(),
                    ty: Type::I32,
                    uniform: false,
                },
            ],
            Type::Void,
        );
        f.is_kernel = true;
        let t = f.add_block("t");
        let e = f.add_block("e");
        let mut b = Builder::new(&mut f);
        let gid = b.intr(Intr::WorkItem(WorkItem::GlobalId), vec![Val::ci(0)]);
        let c = b.icmp(ICmp::Slt, gid, Val::Arg(1));
        b.cond_br(c, t, e);
        b.set_block(t);
        let p = b.gep(Val::Arg(0), gid, 4);
        let l = b.load(p, Type::F32);
        let m = b.bin(BinOp::FMul, l, Val::cf(2.0));
        b.store(p, m);
        b.br(e);
        b.set_block(e);
        b.ret(None);
        let s = print_function(&f);
        assert!(s.contains("func @saxpy(ptr.global %x uniform, i32 %n) -> void kernel"));
        assert!(s.contains("intr.workitem.global_id 0"));
        assert!(s.contains("bin.fmul"));
        assert!(s.contains("condbr"));
    }
}
