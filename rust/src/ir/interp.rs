//! Scalar (per-thread) IR interpreter.
//!
//! Runs *pre-scheduling* kernel IR one thread at a time over a flat memory
//! image. This is the correctness oracle for property tests: random
//! programs are compiled through the full VOLT pipeline, simulated on the
//! SIMT simulator, and results compared against this interpreter.
//!
//! The [`scalar`] submodule holds the single source of truth for scalar
//! operation semantics (RISC-V division rules, float ops); the simulator's
//! execute stage uses the same functions, so oracle and simulator cannot
//! drift apart.

use super::*;

/// Scalar operation semantics shared between the interpreter and the
/// simulator execute stage.
pub mod scalar {
    use crate::ir::{BinOp, FCmp, ICmp, UnOp};

    /// Integer binop on 32-bit values; RISC-V semantics for div/rem by zero
    /// (quotient = -1 / all-ones, remainder = dividend) and overflow
    /// (INT_MIN / -1 = INT_MIN).
    pub fn bin_i(op: BinOp, a: u32, b: u32) -> u32 {
        let (sa, sb) = (a as i32, b as i32);
        match op {
            BinOp::Add => sa.wrapping_add(sb) as u32,
            BinOp::Sub => sa.wrapping_sub(sb) as u32,
            BinOp::Mul => sa.wrapping_mul(sb) as u32,
            BinOp::SDiv => {
                if sb == 0 {
                    u32::MAX
                } else if sa == i32::MIN && sb == -1 {
                    sa as u32
                } else {
                    (sa / sb) as u32
                }
            }
            BinOp::SRem => {
                if sb == 0 {
                    a
                } else if sa == i32::MIN && sb == -1 {
                    0
                } else {
                    (sa % sb) as u32
                }
            }
            BinOp::UDiv => {
                if b == 0 {
                    u32::MAX
                } else {
                    a / b
                }
            }
            BinOp::URem => {
                if b == 0 {
                    a
                } else {
                    a % b
                }
            }
            BinOp::And => a & b,
            BinOp::Or => a | b,
            BinOp::Xor => a ^ b,
            BinOp::Shl => a.wrapping_shl(b & 31),
            BinOp::LShr => a.wrapping_shr(b & 31),
            BinOp::AShr => (sa.wrapping_shr(b & 31)) as u32,
            BinOp::SMin => sa.min(sb) as u32,
            BinOp::SMax => sa.max(sb) as u32,
            _ => panic!("bin_i called with float op {op:?}"),
        }
    }

    pub fn bin_f(op: BinOp, a: f32, b: f32) -> f32 {
        match op {
            BinOp::FAdd => a + b,
            BinOp::FSub => a - b,
            BinOp::FMul => a * b,
            BinOp::FDiv => a / b,
            BinOp::FMin => a.min(b),
            BinOp::FMax => a.max(b),
            _ => panic!("bin_f called with int op {op:?}"),
        }
    }

    /// Unary op over raw 32-bit value (float ops interpret bits as f32).
    pub fn un(op: UnOp, a: u32) -> u32 {
        let f = f32::from_bits(a);
        match op {
            UnOp::Not => !a,
            UnOp::FNeg => (-f).to_bits(),
            UnOp::FSqrt => f.sqrt().to_bits(),
            UnOp::FAbs => f.abs().to_bits(),
            UnOp::FExp => f.exp().to_bits(),
            UnOp::FLog => f.ln().to_bits(),
            UnOp::FFloor => f.floor().to_bits(),
            UnOp::SiToFp => ((a as i32) as f32).to_bits(),
            UnOp::FpToSi => {
                // Saturating like RISC-V fcvt.w.s.
                if f.is_nan() {
                    0
                } else if f >= i32::MAX as f32 {
                    i32::MAX as u32
                } else if f <= i32::MIN as f32 {
                    i32::MIN as u32
                } else {
                    (f as i32) as u32
                }
            }
            UnOp::ZExt => a & 1,
            UnOp::Trunc => (a != 0) as u32,
            UnOp::FToBits | UnOp::BitsToF => a,
        }
    }

    pub fn icmp(pred: ICmp, a: u32, b: u32) -> bool {
        let (sa, sb) = (a as i32, b as i32);
        match pred {
            ICmp::Eq => a == b,
            ICmp::Ne => a != b,
            ICmp::Slt => sa < sb,
            ICmp::Sle => sa <= sb,
            ICmp::Sgt => sa > sb,
            ICmp::Sge => sa >= sb,
            ICmp::Ult => a < b,
            ICmp::Uge => a >= b,
        }
    }

    pub fn fcmp(pred: FCmp, a: f32, b: f32) -> bool {
        match pred {
            FCmp::Oeq => a == b,
            FCmp::One => a != b && !a.is_nan() && !b.is_nan(),
            FCmp::Olt => a < b,
            FCmp::Ole => a <= b,
            FCmp::Ogt => a > b,
            FCmp::Oge => a >= b,
        }
    }
}

/// Per-thread work-item coordinates.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkItemCtx {
    pub gid: [u32; 3],
    pub lid: [u32; 3],
    pub group: [u32; 3],
    pub lsize: [u32; 3],
    pub gsize: [u32; 3],
    pub ngroups: [u32; 3],
}

pub struct Interp<'a> {
    pub module: &'a Module,
    pub mem: &'a mut Vec<u8>,
    pub wi: WorkItemCtx,
    /// Bump pointer for per-thread allocas.
    pub sp: u32,
    /// Address where each global lives (same layout the backend uses).
    pub global_addrs: Vec<u32>,
    pub used_barrier: bool,
    pub used_warp_op: bool,
    pub steps: u64,
    pub max_steps: u64,
    pub prints: Vec<String>,
}

pub fn read_u32(mem: &[u8], addr: u32) -> u32 {
    let a = addr as usize;
    u32::from_le_bytes([mem[a], mem[a + 1], mem[a + 2], mem[a + 3]])
}

pub fn write_u32(mem: &mut [u8], addr: u32, v: u32) {
    let a = addr as usize;
    mem[a..a + 4].copy_from_slice(&v.to_le_bytes());
}

impl<'a> Interp<'a> {
    fn val(&self, _f: &Function, frame: &[Option<u32>], args: &[u32], v: Val) -> Result<u32, String> {
        Ok(match v {
            Val::Inst(i) => frame[i.idx()].ok_or(format!("read of unset %i{}", i.0))?,
            Val::Arg(i) => args[i as usize],
            Val::I(x, _) => x as u32,
            Val::F(b) => b,
            Val::G(g) => self.global_addrs[g.idx()],
        })
    }

    /// Execute one function for the current thread. Returns the return
    /// value (raw bits) if any.
    pub fn exec_function(&mut self, fid: FuncId, args: &[u32]) -> Result<Option<u32>, String> {
        let f = self.module.func(fid);
        let mut frame: Vec<Option<u32>> = vec![None; f.insts.len()];
        let mut cur = f.entry;
        let mut prev: Option<BlockId> = None;
        let saved_sp = self.sp;
        loop {
            // Phase 1: evaluate phis against prev (parallel copy).
            let insts = f.blocks[cur.idx()].insts.clone();
            let mut phi_vals: Vec<(InstId, u32)> = vec![];
            for &id in &insts {
                if let InstKind::Phi { incs } = &f.inst(id).kind {
                    let p = prev.ok_or("phi in entry block")?;
                    let (_, v) = incs
                        .iter()
                        .find(|(b, _)| *b == p)
                        .ok_or(format!("phi %i{} missing incoming for b{}", id.0, p.0))?;
                    phi_vals.push((id, self.val(f, &frame, args, *v)?));
                } else {
                    break;
                }
            }
            for (id, v) in phi_vals {
                frame[id.idx()] = Some(v);
            }
            // Phase 2: straight-line execution.
            for &id in &insts {
                self.steps += 1;
                if self.steps > self.max_steps {
                    return Err("interpreter step limit exceeded".into());
                }
                let inst = f.inst(id);
                let kind = inst.kind.clone();
                match kind {
                    InstKind::Phi { .. } => {}
                    InstKind::Bin { op, a, b } => {
                        let (x, y) = (
                            self.val(f, &frame, args, a)?,
                            self.val(f, &frame, args, b)?,
                        );
                        let r = if op.is_float() {
                            scalar::bin_f(op, f32::from_bits(x), f32::from_bits(y)).to_bits()
                        } else {
                            scalar::bin_i(op, x, y)
                        };
                        frame[id.idx()] = Some(r);
                    }
                    InstKind::Un { op, a } => {
                        let x = self.val(f, &frame, args, a)?;
                        frame[id.idx()] = Some(scalar::un(op, x));
                    }
                    InstKind::ICmp { pred, a, b } => {
                        let (x, y) = (
                            self.val(f, &frame, args, a)?,
                            self.val(f, &frame, args, b)?,
                        );
                        frame[id.idx()] = Some(scalar::icmp(pred, x, y) as u32);
                    }
                    InstKind::FCmp { pred, a, b } => {
                        let (x, y) = (
                            self.val(f, &frame, args, a)?,
                            self.val(f, &frame, args, b)?,
                        );
                        frame[id.idx()] =
                            Some(scalar::fcmp(pred, f32::from_bits(x), f32::from_bits(y)) as u32);
                    }
                    InstKind::Select { cond, t, f: fv } => {
                        let c = self.val(f, &frame, args, cond)?;
                        let r = if c != 0 {
                            self.val(f, &frame, args, t)?
                        } else {
                            self.val(f, &frame, args, fv)?
                        };
                        frame[id.idx()] = Some(r);
                    }
                    InstKind::Alloca { size } => {
                        let addr = self.sp;
                        self.sp += (size + 3) & !3;
                        if self.sp as usize > self.mem.len() {
                            return Err("interpreter stack overflow".into());
                        }
                        frame[id.idx()] = Some(addr);
                    }
                    InstKind::Load { ptr } => {
                        let a = self.val(f, &frame, args, ptr)?;
                        if a as usize + 4 > self.mem.len() {
                            return Err(format!("load OOB at {a:#x}"));
                        }
                        frame[id.idx()] = Some(read_u32(self.mem, a));
                    }
                    InstKind::Store { ptr, val } => {
                        let a = self.val(f, &frame, args, ptr)?;
                        let v = self.val(f, &frame, args, val)?;
                        if a as usize + 4 > self.mem.len() {
                            return Err(format!("store OOB at {a:#x}"));
                        }
                        write_u32(self.mem, a, v);
                    }
                    InstKind::Gep {
                        base,
                        index,
                        scale,
                        disp,
                    } => {
                        let b = self.val(f, &frame, args, base)?;
                        let i = self.val(f, &frame, args, index)?;
                        let r = b
                            .wrapping_add((i as i32).wrapping_mul(scale as i32) as u32)
                            .wrapping_add(disp as u32);
                        frame[id.idx()] = Some(r);
                    }
                    InstKind::Call { callee, args: cargs } => {
                        let mut vals = vec![];
                        for a in &cargs {
                            vals.push(self.val(f, &frame, args, *a)?);
                        }
                        let r = self.exec_function(callee, &vals)?;
                        if f.inst(id).ty != Type::Void {
                            frame[id.idx()] =
                                Some(r.ok_or("void call used as value")?);
                        }
                    }
                    InstKind::Intr { intr, args: iargs } => {
                        let r = self.exec_intr(f, &frame, args, &intr, &iargs)?;
                        if f.inst(id).ty != Type::Void {
                            frame[id.idx()] = Some(r);
                        }
                    }
                    InstKind::Br { target } => {
                        prev = Some(cur);
                        cur = target;
                        break;
                    }
                    InstKind::CondBr { cond, t, f: fb } => {
                        let c = self.val(f, &frame, args, cond)?;
                        prev = Some(cur);
                        cur = if c != 0 { t } else { fb };
                        break;
                    }
                    InstKind::SplitBr {
                        cond,
                        neg,
                        then_b,
                        else_b,
                        ..
                    } => {
                        // Scalar semantics: behaves like a cond branch.
                        let c = self.val(f, &frame, args, cond)? != 0;
                        let c = if neg { !c } else { c };
                        prev = Some(cur);
                        cur = if c { then_b } else { else_b };
                        break;
                    }
                    InstKind::PredBr {
                        cond,
                        mask: _,
                        body,
                        exit,
                    } => {
                        let c = self.val(f, &frame, args, cond)? != 0;
                        prev = Some(cur);
                        cur = if c { body } else { exit };
                        break;
                    }
                    InstKind::Ret { val } => {
                        self.sp = saved_sp;
                        return Ok(match val {
                            Some(v) => Some(self.val(f, &frame, args, v)?),
                            None => None,
                        });
                    }
                    InstKind::Unreachable => return Err("reached unreachable".into()),
                }
            }
        }
    }

    fn exec_intr(
        &mut self,
        f: &Function,
        frame: &[Option<u32>],
        args: &[u32],
        intr: &Intr,
        iargs: &[Val],
    ) -> Result<u32, String> {
        let dim = |s: &mut Self, v: Val| -> Result<usize, String> {
            Ok((s.val(f, frame, args, v)? as usize).min(2))
        };
        match intr {
            Intr::WorkItem(w) => {
                let d = dim(self, iargs[0])?;
                Ok(match w {
                    WorkItem::GlobalId => self.wi.gid[d],
                    WorkItem::LocalId => self.wi.lid[d],
                    WorkItem::GroupId => self.wi.group[d],
                    WorkItem::LocalSize => self.wi.lsize[d],
                    WorkItem::GlobalSize => self.wi.gsize[d],
                    WorkItem::NumGroups => self.wi.ngroups[d],
                })
            }
            Intr::Csr(c) => Ok(match c {
                // Scalar model: one thread per "lane 0" of a 1-warp machine.
                Csr::LaneId => {
                    let lin = self.wi.lid[0]
                        + self.wi.lid[1] * self.wi.lsize[0]
                        + self.wi.lid[2] * self.wi.lsize[0] * self.wi.lsize[1];
                    lin % 32
                }
                Csr::WarpId => 0,
                Csr::CoreId => 0,
                Csr::NumThreads => 32,
                Csr::NumWarps => 1,
                Csr::NumCores => 1,
            }),
            Intr::Barrier => {
                self.used_barrier = true;
                Ok(0)
            }
            Intr::Atomic(op) => {
                let a = self.val(f, frame, args, iargs[0])?;
                let v = self.val(f, frame, args, iargs[1])?;
                if a as usize + 4 > self.mem.len() {
                    return Err(format!("atomic OOB at {a:#x}"));
                }
                let old = read_u32(self.mem, a);
                let new = match op {
                    AtomOp::Add => old.wrapping_add(v),
                    AtomOp::And => old & v,
                    AtomOp::Or => old | v,
                    AtomOp::Xor => old ^ v,
                    AtomOp::Min => ((old as i32).min(v as i32)) as u32,
                    AtomOp::Max => ((old as i32).max(v as i32)) as u32,
                    AtomOp::Exch => v,
                };
                write_u32(self.mem, a, new);
                Ok(old)
            }
            Intr::AtomicCas => {
                let a = self.val(f, frame, args, iargs[0])?;
                let cmp = self.val(f, frame, args, iargs[1])?;
                let new = self.val(f, frame, args, iargs[2])?;
                let old = read_u32(self.mem, a);
                if old == cmp {
                    write_u32(self.mem, a, new);
                }
                Ok(old)
            }
            Intr::VoteAll | Intr::VoteAny => {
                self.used_warp_op = true;
                // Single-thread warp: vote == own predicate.
                self.val(f, frame, args, iargs[0])
            }
            Intr::Ballot => {
                self.used_warp_op = true;
                let p = self.val(f, frame, args, iargs[0])?;
                Ok(if p != 0 { 1 } else { 0 })
            }
            Intr::Shfl => {
                self.used_warp_op = true;
                self.val(f, frame, args, iargs[0])
            }
            Intr::Join | Intr::Tmc => Ok(0),
            Intr::Mask => Ok(1),
            Intr::PrintI => {
                let v = self.val(f, frame, args, iargs[0])?;
                self.prints.push(format!("{}", v as i32));
                Ok(0)
            }
            Intr::PrintF => {
                let v = self.val(f, frame, args, iargs[0])?;
                self.prints.push(format!("{}", f32::from_bits(v)));
                Ok(0)
            }
        }
    }
}

/// Run a kernel over a full NDRange, one thread at a time.
/// `global_addrs[i]` must hold the address assigned to module global i.
/// Returns whether any thread used a barrier (result then suspect unless
/// the kernel is barrier-safe under sequential execution).
#[allow(clippy::too_many_arguments)]
pub fn run_kernel_scalar(
    module: &Module,
    fid: FuncId,
    args: &[u32],
    grid: [u32; 3],
    block: [u32; 3],
    mem: &mut Vec<u8>,
    stack_base: u32,
    global_addrs: &[u32],
) -> Result<ScalarRunInfo, String> {
    let mut info = ScalarRunInfo::default();
    let lsize = block;
    let gsize = [grid[0] * block[0], grid[1] * block[1], grid[2] * block[2]];
    for gz in 0..grid[2] {
        for gy in 0..grid[1] {
            for gx in 0..grid[0] {
                for lz in 0..block[2] {
                    for ly in 0..block[1] {
                        for lx in 0..block[0] {
                            let wi = WorkItemCtx {
                                gid: [
                                    gx * block[0] + lx,
                                    gy * block[1] + ly,
                                    gz * block[2] + lz,
                                ],
                                lid: [lx, ly, lz],
                                group: [gx, gy, gz],
                                lsize,
                                gsize,
                                ngroups: grid,
                            };
                            let mut it = Interp {
                                module,
                                mem,
                                wi,
                                sp: stack_base,
                                global_addrs: global_addrs.to_vec(),
                                used_barrier: false,
                                used_warp_op: false,
                                steps: 0,
                                max_steps: 4_000_000,
                                prints: vec![],
                            };
                            it.exec_function(fid, args)?;
                            info.used_barrier |= it.used_barrier;
                            info.used_warp_op |= it.used_warp_op;
                            info.total_steps += it.steps;
                            info.prints.extend(it.prints);
                        }
                    }
                }
            }
        }
    }
    Ok(info)
}

#[derive(Default, Debug)]
pub struct ScalarRunInfo {
    pub used_barrier: bool,
    pub used_warp_op: bool,
    pub total_steps: u64,
    pub prints: Vec<String>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Builder, Param};

    /// Build: kernel writes gid*2+arg into out[gid].
    fn build_kernel() -> Module {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![
                Param {
                    name: "out".into(),
                    ty: Type::Ptr(AddrSpace::Global),
                    uniform: true,
                },
                Param {
                    name: "c".into(),
                    ty: Type::I32,
                    uniform: true,
                },
            ],
            Type::Void,
        );
        f.is_kernel = true;
        let mut b = Builder::new(&mut f);
        let gid = b.intr(Intr::WorkItem(WorkItem::GlobalId), vec![Val::ci(0)]);
        let two = b.mul(gid, Val::ci(2));
        let v = b.add(two, Val::Arg(1));
        let p = b.gep(Val::Arg(0), gid, 4);
        b.store(p, v);
        b.ret(None);
        m.add_func(f);
        m
    }

    #[test]
    fn runs_simple_kernel() {
        let m = build_kernel();
        let mut mem = vec![0u8; 4096];
        let out_addr = 256u32;
        run_kernel_scalar(
            &m,
            FuncId(0),
            &[out_addr, 7],
            [2, 1, 1],
            [4, 1, 1],
            &mut mem,
            2048,
            &[],
        )
        .unwrap();
        for i in 0..8u32 {
            assert_eq!(read_u32(&mem, out_addr + i * 4), i * 2 + 7);
        }
    }

    #[test]
    fn loop_and_phi() {
        // sum 0..n via loop, store at out[0].
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![
                Param {
                    name: "out".into(),
                    ty: Type::Ptr(AddrSpace::Global),
                    uniform: true,
                },
                Param {
                    name: "n".into(),
                    ty: Type::I32,
                    uniform: true,
                },
            ],
            Type::Void,
        );
        let entry = f.entry;
        let h = f.add_block("h");
        let body = f.add_block("body");
        let exit = f.add_block("exit");
        let mut b = Builder::at(&mut f, entry);
        b.br(h);
        b.set_block(body);
        // placeholders filled after phis exist
        b.set_block(h);
        let i_phi = b.phi(Type::I32, vec![(entry, Val::ci(0))]);
        let s_phi = b.phi(Type::I32, vec![(entry, Val::ci(0))]);
        let c = b.icmp(ICmp::Slt, i_phi, Val::Arg(1));
        b.cond_br(c, body, exit);
        b.set_block(body);
        let s2 = b.add(s_phi, i_phi);
        let i2 = b.add(i_phi, Val::ci(1));
        b.br(h);
        b.set_block(exit);
        b.store(Val::Arg(0), s_phi);
        b.ret(None);
        // complete the phis
        if let (Val::Inst(ip), Val::Inst(sp)) = (i_phi, s_phi) {
            if let InstKind::Phi { incs } = &mut f.inst_mut(ip).kind {
                incs.push((body, i2));
            }
            if let InstKind::Phi { incs } = &mut f.inst_mut(sp).kind {
                incs.push((body, s2));
            }
        }
        m.add_func(f);
        let mut mem = vec![0u8; 1024];
        run_kernel_scalar(&m, FuncId(0), &[64, 10], [1, 1, 1], [1, 1, 1], &mut mem, 512, &[])
            .unwrap();
        assert_eq!(read_u32(&mem, 64), 45);
    }

    #[test]
    fn riscv_div_semantics() {
        assert_eq!(scalar::bin_i(BinOp::SDiv, 7, 0), u32::MAX);
        assert_eq!(scalar::bin_i(BinOp::SRem, 7, 0), 7);
        assert_eq!(
            scalar::bin_i(BinOp::SDiv, i32::MIN as u32, (-1i32) as u32),
            i32::MIN as u32
        );
        assert_eq!(scalar::bin_i(BinOp::SRem, i32::MIN as u32, (-1i32) as u32), 0);
    }
}
