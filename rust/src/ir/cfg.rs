//! CFG utilities: reachability, reducibility testing, edge classification.
//!
//! Reducibility matters because the Vortex IPDOM stack requires structured
//! (reducible) control flow (paper §2.3 / §4.3.2): every divergence point
//! must reconverge at its immediate post-dominator.

use super::{BlockId, Function};
use std::collections::{HashMap, HashSet};

/// Result of DFS edge classification on the CFG.
#[derive(Debug, Default)]
pub struct EdgeClasses {
    /// Back edges found by the DFS (target is an ancestor on the DFS stack).
    pub back_edges: Vec<(BlockId, BlockId)>,
    /// All other (tree/forward/cross) edges.
    pub forward_edges: Vec<(BlockId, BlockId)>,
}

/// Classify edges with a DFS from the entry block.
pub fn classify_edges(f: &Function) -> EdgeClasses {
    let n = f.blocks.len();
    let mut color = vec![0u8; n]; // 0=white 1=grey 2=black
    let mut out = EdgeClasses::default();
    let mut stack: Vec<(BlockId, usize)> = vec![(f.entry, 0)];
    color[f.entry.idx()] = 1;
    while let Some((b, i)) = stack.pop() {
        let succs = f.succs(b);
        if i < succs.len() {
            stack.push((b, i + 1));
            let s = succs[i];
            match color[s.idx()] {
                0 => {
                    color[s.idx()] = 1;
                    out.forward_edges.push((b, s));
                    stack.push((s, 0));
                }
                1 => out.back_edges.push((b, s)),
                _ => out.forward_edges.push((b, s)),
            }
        } else {
            color[b.idx()] = 2;
        }
    }
    out
}

/// A flow graph is reducible iff every DFS back edge `n -> m` has `m`
/// dominating `n` (Hecht & Ullman). Irreducible graphs break IPDOM-stack
/// reconvergence and must be restructured (paper §4.3.2).
pub fn is_reducible(f: &Function) -> bool {
    is_reducible_with(f, &super::dom::DomTree::build(f))
}

/// [`is_reducible`] against a caller-supplied (typically cached) tree.
pub fn is_reducible_with(f: &Function, dom: &super::dom::DomTree) -> bool {
    let classes = classify_edges(f);
    classes
        .back_edges
        .iter()
        .all(|&(n, m)| dom.dominates(m, n))
}

/// The set of "offending" back edges whose target does not dominate the
/// source — each identifies an irreducible region entry.
pub fn irreducible_back_edges(f: &Function) -> Vec<(BlockId, BlockId)> {
    irreducible_back_edges_with(f, &super::dom::DomTree::build(f))
}

/// [`irreducible_back_edges`] against a caller-supplied (cached) tree.
pub fn irreducible_back_edges_with(
    f: &Function,
    dom: &super::dom::DomTree,
) -> Vec<(BlockId, BlockId)> {
    classify_edges(f)
        .back_edges
        .into_iter()
        .filter(|&(n, m)| !dom.dominates(m, n))
        .collect()
}

/// Blocks reachable from `from` without passing through `stop`.
/// Used to find the influence region of a divergent branch (blocks between
/// the branch and its IPDOM).
pub fn reachable_until(f: &Function, from: &[BlockId], stop: BlockId) -> HashSet<BlockId> {
    let mut seen: HashSet<BlockId> = HashSet::new();
    let mut work: Vec<BlockId> = from.iter().copied().filter(|&b| b != stop).collect();
    for &b in &work {
        seen.insert(b);
    }
    while let Some(b) = work.pop() {
        for s in f.succs(b) {
            if s != stop && seen.insert(s) {
                work.push(s);
            }
        }
    }
    seen
}

/// True if `to` is reachable from `from` (inclusive of `from == to`).
pub fn is_reachable(f: &Function, from: BlockId, to: BlockId) -> bool {
    if from == to {
        return true;
    }
    let mut seen = HashSet::new();
    let mut work = vec![from];
    seen.insert(from);
    while let Some(b) = work.pop() {
        for s in f.succs(b) {
            if s == to {
                return true;
            }
            if seen.insert(s) {
                work.push(s);
            }
        }
    }
    false
}

/// Exit blocks (terminated by Ret or Unreachable).
pub fn exit_blocks(f: &Function) -> Vec<BlockId> {
    f.block_ids()
        .into_iter()
        .filter(|&b| {
            !f.block(b).insts.is_empty()
                && matches!(
                    f.inst(f.term(b)).kind,
                    super::InstKind::Ret { .. } | super::InstKind::Unreachable
                )
        })
        .collect()
}

/// Count of static edges in the CFG.
pub fn num_edges(f: &Function) -> usize {
    f.block_ids().iter().map(|&b| f.succs(b).len()).sum()
}

/// Map from block to its position in RPO (reachable blocks only).
pub fn rpo_index(f: &Function) -> HashMap<BlockId, usize> {
    f.rpo().into_iter().enumerate().map(|(i, b)| (b, i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Builder, InstKind, Type, Val};

    /// entry -> a -> b -> a (loop), b -> exit : reducible.
    #[test]
    fn reducible_loop() {
        let mut f = crate::ir::Function::new("t", vec![], Type::Void);
        let entry = f.entry;
        let a = f.add_block("a");
        let b = f.add_block("b");
        let x = f.add_block("x");
        let mut bl = Builder::at(&mut f, entry);
        bl.br(a);
        bl.set_block(a);
        bl.br(b);
        bl.set_block(b);
        bl.cond_br(Val::cb(true), a, x);
        bl.set_block(x);
        bl.ret(None);
        assert!(is_reducible(&f));
        let cls = classify_edges(&f);
        assert_eq!(cls.back_edges, vec![(b, a)]);
    }

    /// Classic irreducible graph: entry branches to a and b; a -> b, b -> a.
    #[test]
    fn irreducible_two_headed_loop() {
        let mut f = crate::ir::Function::new("t", vec![], Type::Void);
        let entry = f.entry;
        let a = f.add_block("a");
        let b = f.add_block("b");
        let x = f.add_block("x");
        let mut bl = Builder::at(&mut f, entry);
        bl.cond_br(Val::cb(true), a, b);
        bl.set_block(a);
        bl.cond_br(Val::cb(true), b, x);
        bl.set_block(b);
        bl.cond_br(Val::cb(true), a, x);
        bl.set_block(x);
        bl.ret(None);
        assert!(!is_reducible(&f));
        assert!(!irreducible_back_edges(&f).is_empty());
        let _ = entry;
    }

    #[test]
    fn reachability() {
        let mut f = crate::ir::Function::new("t", vec![], Type::Void);
        let entry = f.entry;
        let a = f.add_block("a");
        let b = f.add_block("b");
        let mut bl = Builder::at(&mut f, entry);
        bl.br(a);
        bl.set_block(a);
        bl.br(b);
        bl.set_block(b);
        bl.ret(None);
        assert!(is_reachable(&f, entry, b));
        assert!(!is_reachable(&f, b, entry));
        let r = reachable_until(&f, &[a], b);
        assert!(r.contains(&a) && !r.contains(&b));
        assert_eq!(exit_blocks(&f), vec![b]);
        assert!(matches!(f.inst(f.term(b)).kind, InstKind::Ret { .. }));
    }
}
