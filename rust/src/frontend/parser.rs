//! Recursive-descent parser for VCL (OpenCL-C / CUDA-C subset).

use super::ast::*;
use super::lexer::{lex, Tok, Token};

#[derive(Debug)]
pub struct ParseError {
    pub line: u32,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "parse error at line {}: {}", self.line, self.msg)
    }
}

pub fn parse_program(src: &str) -> Result<Program, ParseError> {
    let toks = lex(src).map_err(|e| ParseError {
        line: e.line,
        msg: e.msg,
    })?;
    Parser { toks, pos: 0 }.program()
}

struct Parser {
    toks: Vec<Token>,
    pos: usize,
}

const TYPE_KWS: [&str; 6] = ["void", "int", "uint", "unsigned", "float", "bool"];
const SPACE_KWS: [&str; 10] = [
    "global",
    "__global",
    "local",
    "__local",
    "constant",
    "__constant",
    "__constant__",
    "__shared__",
    "__device__",
    "private",
];

impl Parser {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos].tok
    }
    fn peek_at(&self, off: usize) -> &Tok {
        &self.toks[(self.pos + off).min(self.toks.len() - 1)].tok
    }
    fn line(&self) -> u32 {
        self.toks[self.pos].line
    }
    fn loc(&self) -> SrcLoc {
        let t = &self.toks[self.pos];
        SrcLoc {
            line: t.line,
            col: t.col,
        }
    }
    fn next(&mut self) -> Tok {
        let t = self.toks[self.pos].tok.clone();
        self.pos += 1;
        t
    }
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError {
            line: self.line(),
            msg: msg.into(),
        })
    }
    fn expect(&mut self, t: Tok) -> Result<(), ParseError> {
        if *self.peek() == t {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected {t:?}, found {:?}", self.peek()))
        }
    }
    fn is_ident(&self, s: &str) -> bool {
        matches!(self.peek(), Tok::Ident(i) if i == s)
    }
    fn eat_ident(&mut self, s: &str) -> bool {
        if self.is_ident(s) {
            self.pos += 1;
            true
        } else {
            false
        }
    }
    fn ident(&mut self) -> Result<String, ParseError> {
        match self.next() {
            Tok::Ident(s) => Ok(s),
            t => self.err(format!("expected identifier, found {t:?}")),
        }
    }
    fn is_type_kw(&self, off: usize) -> bool {
        matches!(self.peek_at(off), Tok::Ident(s) if TYPE_KWS.contains(&s.as_str()))
    }
    fn is_space_kw(&self) -> bool {
        matches!(self.peek(), Tok::Ident(s) if SPACE_KWS.contains(&s.as_str()))
    }

    fn type_spec(&mut self) -> Result<TypeSpec, ParseError> {
        let name = self.ident()?;
        Ok(match name.as_str() {
            "void" => TypeSpec::Void,
            "int" => TypeSpec::Int,
            "uint" => TypeSpec::Uint,
            "unsigned" => {
                self.eat_ident("int"); // `unsigned int` / bare `unsigned`
                TypeSpec::Uint
            }
            "float" => TypeSpec::Float,
            "bool" => TypeSpec::Bool,
            _ => return self.err(format!("unknown type '{name}'")),
        })
    }

    fn space_spec(&mut self) -> SpaceSpec {
        let mut space = SpaceSpec::Default;
        loop {
            let s = match self.peek() {
                Tok::Ident(s) => s.clone(),
                _ => break,
            };
            let sp = match s.as_str() {
                "global" | "__global" | "__device__" => SpaceSpec::Global,
                "local" | "__local" | "__shared__" => SpaceSpec::Local,
                "constant" | "__constant" | "__constant__" => SpaceSpec::Constant,
                "private" => SpaceSpec::Private,
                _ => break,
            };
            space = sp;
            self.pos += 1;
        }
        space
    }

    fn program(&mut self) -> Result<Program, ParseError> {
        let mut p = Program::default();
        while *self.peek() != Tok::Eof {
            let line = self.line();
            // Leading qualifiers.
            let mut is_kernel = false;
            let mut space = SpaceSpec::Default;
            loop {
                if self.eat_ident("kernel") || self.eat_ident("__kernel") || self.eat_ident("__global__") {
                    is_kernel = true;
                } else if self.is_space_kw() {
                    space = self.space_spec();
                } else {
                    break;
                }
            }
            let ty = self.type_spec()?;
            let name = self.ident()?;
            if *self.peek() == Tok::LParen {
                p.funcs.push(self.func_decl(name, ty, is_kernel, line)?);
            } else {
                // Global variable declaration.
                let mut dims = vec![];
                while *self.peek() == Tok::LBracket {
                    self.next();
                    let d = match self.next() {
                        Tok::Int(v) if v > 0 => v as u32,
                        _ => return self.err("array dimension must be a positive int literal"),
                    };
                    dims.push(d);
                    self.expect(Tok::RBracket)?;
                }
                let init = if *self.peek() == Tok::Assign {
                    self.next();
                    self.expect(Tok::LBrace)?;
                    let mut items = vec![];
                    while *self.peek() != Tok::RBrace {
                        items.push(self.expr()?);
                        if *self.peek() == Tok::Comma {
                            self.next();
                        }
                    }
                    self.expect(Tok::RBrace)?;
                    Some(items)
                } else {
                    None
                };
                self.expect(Tok::Semi)?;
                if space == SpaceSpec::Default {
                    space = SpaceSpec::Global;
                }
                p.globals.push(GlobalDecl {
                    name,
                    ty,
                    space,
                    dims,
                    init,
                    line,
                });
            }
        }
        Ok(p)
    }

    fn func_decl(
        &mut self,
        name: String,
        ret: TypeSpec,
        is_kernel: bool,
        line: u32,
    ) -> Result<FuncDecl, ParseError> {
        self.expect(Tok::LParen)?;
        let mut params = vec![];
        while *self.peek() != Tok::RParen {
            let mut uniform = false;
            let mut space = SpaceSpec::Default;
            loop {
                if self.eat_ident("uniform") {
                    uniform = true;
                } else if self.is_space_kw() {
                    space = self.space_spec();
                } else {
                    break;
                }
            }
            let ty = self.type_spec()?;
            let mut is_ptr = false;
            while *self.peek() == Tok::Star {
                self.next();
                is_ptr = true;
            }
            // trailing qualifiers after '*' (OpenCL allows `float* restrict`)
            if self.eat_ident("restrict") || self.eat_ident("__restrict__") {}
            let pname = self.ident()?;
            if is_ptr && space == SpaceSpec::Default {
                space = SpaceSpec::Global;
            }
            params.push(ParamDecl {
                name: pname,
                ty,
                is_ptr,
                space,
                uniform,
            });
            if *self.peek() == Tok::Comma {
                self.next();
            }
        }
        self.expect(Tok::RParen)?;
        self.expect(Tok::LBrace)?;
        let body = self.block_stmts()?;
        Ok(FuncDecl {
            name,
            ret,
            params,
            body,
            is_kernel,
            line,
        })
    }

    fn block_stmts(&mut self) -> Result<Vec<Stmt>, ParseError> {
        let mut out = vec![];
        while *self.peek() != Tok::RBrace {
            if *self.peek() == Tok::Eof {
                return self.err("unexpected EOF in block");
            }
            out.push(self.stmt()?);
        }
        self.expect(Tok::RBrace)?;
        Ok(out)
    }

    fn starts_decl(&self) -> bool {
        // uniform / space qualifier / type keyword starts a declaration.
        match self.peek() {
            Tok::Ident(s) => {
                s == "uniform" || SPACE_KWS.contains(&s.as_str()) || TYPE_KWS.contains(&s.as_str())
            }
            _ => false,
        }
    }

    fn stmt(&mut self) -> Result<Stmt, ParseError> {
        let loc = self.loc();
        match self.peek().clone() {
            Tok::LBrace => {
                self.next();
                Ok(Stmt::Block(self.block_stmts()?))
            }
            Tok::Semi => {
                self.next();
                Ok(Stmt::Block(vec![]))
            }
            Tok::Ident(s) => match s.as_str() {
                "if" => {
                    self.next();
                    self.expect(Tok::LParen)?;
                    let cond = self.expr()?;
                    self.expect(Tok::RParen)?;
                    let then_s = vec![self.stmt()?];
                    let else_s = if self.eat_ident("else") {
                        vec![self.stmt()?]
                    } else {
                        vec![]
                    };
                    Ok(Stmt::If {
                        cond,
                        then_s,
                        else_s,
                        loc,
                    })
                }
                "while" => {
                    self.next();
                    self.expect(Tok::LParen)?;
                    let cond = self.expr()?;
                    self.expect(Tok::RParen)?;
                    let body = vec![self.stmt()?];
                    Ok(Stmt::While { cond, body, loc })
                }
                "do" => {
                    self.next();
                    let body = vec![self.stmt()?];
                    if !self.eat_ident("while") {
                        return self.err("expected 'while' after do body");
                    }
                    self.expect(Tok::LParen)?;
                    let cond = self.expr()?;
                    self.expect(Tok::RParen)?;
                    self.expect(Tok::Semi)?;
                    Ok(Stmt::DoWhile { body, cond, loc })
                }
                "for" => {
                    self.next();
                    self.expect(Tok::LParen)?;
                    let init = if *self.peek() == Tok::Semi {
                        self.next();
                        None
                    } else {
                        let s = self.simple_stmt()?;
                        self.expect(Tok::Semi)?;
                        Some(Box::new(s))
                    };
                    let cond = if *self.peek() == Tok::Semi {
                        None
                    } else {
                        Some(self.expr()?)
                    };
                    self.expect(Tok::Semi)?;
                    let step = if *self.peek() == Tok::RParen {
                        None
                    } else {
                        Some(Box::new(self.simple_stmt()?))
                    };
                    self.expect(Tok::RParen)?;
                    let body = vec![self.stmt()?];
                    Ok(Stmt::For {
                        init,
                        cond,
                        step,
                        body,
                        loc,
                    })
                }
                "break" => {
                    self.next();
                    self.expect(Tok::Semi)?;
                    Ok(Stmt::Break(loc))
                }
                "continue" => {
                    self.next();
                    self.expect(Tok::Semi)?;
                    Ok(Stmt::Continue(loc))
                }
                "return" => {
                    self.next();
                    let v = if *self.peek() == Tok::Semi {
                        None
                    } else {
                        Some(self.expr()?)
                    };
                    self.expect(Tok::Semi)?;
                    Ok(Stmt::Return(v, loc))
                }
                "goto" => {
                    self.next();
                    let l = self.ident()?;
                    self.expect(Tok::Semi)?;
                    Ok(Stmt::Goto(l, loc))
                }
                _ => {
                    // Label?  ident ':'
                    if matches!(self.peek_at(1), Tok::Colon)
                        && !TYPE_KWS.contains(&s.as_str())
                        && !SPACE_KWS.contains(&s.as_str())
                    {
                        self.next();
                        self.next();
                        return Ok(Stmt::Label(s, loc));
                    }
                    let st = self.simple_stmt()?;
                    self.expect(Tok::Semi)?;
                    Ok(st)
                }
            },
            _ => {
                let st = self.simple_stmt()?;
                self.expect(Tok::Semi)?;
                Ok(st)
            }
        }
    }

    /// Declaration, assignment, inc/dec or expression — no trailing ';'.
    fn simple_stmt(&mut self) -> Result<Stmt, ParseError> {
        let loc = self.loc();
        if self.starts_decl() {
            let mut uniform = false;
            let mut space = SpaceSpec::Default;
            loop {
                if self.eat_ident("uniform") {
                    uniform = true;
                } else if self.is_space_kw() && !self.is_type_kw(0) {
                    space = self.space_spec();
                } else {
                    break;
                }
            }
            let ty = self.type_spec()?;
            let mut is_ptr = false;
            while *self.peek() == Tok::Star {
                self.next();
                is_ptr = true;
            }
            let name = self.ident()?;
            let mut dims = vec![];
            while *self.peek() == Tok::LBracket {
                self.next();
                let d = match self.next() {
                    Tok::Int(v) if v > 0 => v as u32,
                    _ => return self.err("array dimension must be positive int literal"),
                };
                dims.push(d);
                self.expect(Tok::RBracket)?;
            }
            let init = if *self.peek() == Tok::Assign {
                self.next();
                Some(self.expr()?)
            } else {
                None
            };
            Ok(Stmt::Decl {
                ty,
                space,
                is_ptr,
                name,
                dims,
                init,
                uniform,
                loc,
            })
        } else {
            let e = self.expr()?;
            let op = match self.peek() {
                Tok::Assign => Some(None),
                Tok::PlusAssign => Some(Some(BinAst::Add)),
                Tok::MinusAssign => Some(Some(BinAst::Sub)),
                Tok::StarAssign => Some(Some(BinAst::Mul)),
                Tok::SlashAssign => Some(Some(BinAst::Div)),
                Tok::PercentAssign => Some(Some(BinAst::Rem)),
                Tok::AmpAssign => Some(Some(BinAst::And)),
                Tok::PipeAssign => Some(Some(BinAst::Or)),
                Tok::CaretAssign => Some(Some(BinAst::Xor)),
                Tok::ShlAssign => Some(Some(BinAst::Shl)),
                Tok::ShrAssign => Some(Some(BinAst::Shr)),
                Tok::PlusPlus => {
                    self.next();
                    return Ok(Stmt::Assign {
                        lhs: e.clone(),
                        op: Some(BinAst::Add),
                        rhs: Expr::Int(1),
                        loc,
                    });
                }
                Tok::MinusMinus => {
                    self.next();
                    return Ok(Stmt::Assign {
                        lhs: e.clone(),
                        op: Some(BinAst::Sub),
                        rhs: Expr::Int(1),
                        loc,
                    });
                }
                _ => None,
            };
            match op {
                Some(op) => {
                    self.next();
                    let rhs = self.expr()?;
                    Ok(Stmt::Assign {
                        lhs: e,
                        op,
                        rhs,
                        loc,
                    })
                }
                None => Ok(Stmt::ExprStmt(e, loc)),
            }
        }
    }

    // ---- expressions (precedence climbing) ----

    pub fn expr(&mut self) -> Result<Expr, ParseError> {
        self.ternary()
    }

    fn ternary(&mut self) -> Result<Expr, ParseError> {
        let c = self.logor()?;
        if *self.peek() == Tok::Question {
            self.next();
            let t = self.expr()?;
            self.expect(Tok::Colon)?;
            let f = self.expr()?;
            Ok(Expr::Ternary(Box::new(c), Box::new(t), Box::new(f)))
        } else {
            Ok(c)
        }
    }

    fn binary_level(
        &mut self,
        ops: &[(Tok, BinAst)],
        next: fn(&mut Self) -> Result<Expr, ParseError>,
    ) -> Result<Expr, ParseError> {
        let mut lhs = next(self)?;
        loop {
            let mut matched = None;
            for (t, op) in ops {
                if self.peek() == t {
                    matched = Some(*op);
                    break;
                }
            }
            match matched {
                Some(op) => {
                    self.next();
                    let rhs = next(self)?;
                    lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
                }
                None => return Ok(lhs),
            }
        }
    }

    fn logor(&mut self) -> Result<Expr, ParseError> {
        self.binary_level(&[(Tok::OrOr, BinAst::LogOr)], Self::logand)
    }
    fn logand(&mut self) -> Result<Expr, ParseError> {
        self.binary_level(&[(Tok::AndAnd, BinAst::LogAnd)], Self::bitor)
    }
    fn bitor(&mut self) -> Result<Expr, ParseError> {
        self.binary_level(&[(Tok::Pipe, BinAst::Or)], Self::bitxor)
    }
    fn bitxor(&mut self) -> Result<Expr, ParseError> {
        self.binary_level(&[(Tok::Caret, BinAst::Xor)], Self::bitand)
    }
    fn bitand(&mut self) -> Result<Expr, ParseError> {
        self.binary_level(&[(Tok::Amp, BinAst::And)], Self::equality)
    }
    fn equality(&mut self) -> Result<Expr, ParseError> {
        self.binary_level(
            &[(Tok::Eq, BinAst::Eq), (Tok::Ne, BinAst::Ne)],
            Self::relational,
        )
    }
    fn relational(&mut self) -> Result<Expr, ParseError> {
        self.binary_level(
            &[
                (Tok::Lt, BinAst::Lt),
                (Tok::Le, BinAst::Le),
                (Tok::Gt, BinAst::Gt),
                (Tok::Ge, BinAst::Ge),
            ],
            Self::shift,
        )
    }
    fn shift(&mut self) -> Result<Expr, ParseError> {
        self.binary_level(
            &[(Tok::Shl, BinAst::Shl), (Tok::Shr, BinAst::Shr)],
            Self::additive,
        )
    }
    fn additive(&mut self) -> Result<Expr, ParseError> {
        self.binary_level(
            &[(Tok::Plus, BinAst::Add), (Tok::Minus, BinAst::Sub)],
            Self::multiplicative,
        )
    }
    fn multiplicative(&mut self) -> Result<Expr, ParseError> {
        self.binary_level(
            &[
                (Tok::Star, BinAst::Mul),
                (Tok::Slash, BinAst::Div),
                (Tok::Percent, BinAst::Rem),
            ],
            Self::unary,
        )
    }

    fn unary(&mut self) -> Result<Expr, ParseError> {
        match self.peek().clone() {
            Tok::Minus => {
                self.next();
                Ok(Expr::Un(UnAst::Neg, Box::new(self.unary()?)))
            }
            Tok::Not => {
                self.next();
                Ok(Expr::Un(UnAst::Not, Box::new(self.unary()?)))
            }
            Tok::Tilde => {
                self.next();
                Ok(Expr::Un(UnAst::BitNot, Box::new(self.unary()?)))
            }
            Tok::Star => {
                self.next();
                Ok(Expr::Deref(Box::new(self.unary()?)))
            }
            Tok::LParen if self.is_type_kw(1) && *self.peek_at(2) == Tok::RParen => {
                self.next();
                let ty = self.type_spec()?;
                self.expect(Tok::RParen)?;
                Ok(Expr::Cast(ty, Box::new(self.unary()?)))
            }
            _ => self.postfix(),
        }
    }

    fn postfix(&mut self) -> Result<Expr, ParseError> {
        let mut e = self.primary()?;
        loop {
            match self.peek().clone() {
                Tok::LBracket => {
                    self.next();
                    let idx = self.expr()?;
                    self.expect(Tok::RBracket)?;
                    e = Expr::Index(Box::new(e), Box::new(idx));
                }
                Tok::Dot => {
                    self.next();
                    let m = self.ident()?;
                    e = Expr::Member(Box::new(e), m);
                }
                Tok::LParen => {
                    let name = match &e {
                        Expr::Ident(n) => n.clone(),
                        _ => return self.err("call target must be a name"),
                    };
                    self.next();
                    let mut args = vec![];
                    while *self.peek() != Tok::RParen {
                        args.push(self.expr()?);
                        if *self.peek() == Tok::Comma {
                            self.next();
                        }
                    }
                    self.expect(Tok::RParen)?;
                    e = Expr::Call(name, args);
                }
                _ => return Ok(e),
            }
        }
    }

    fn primary(&mut self) -> Result<Expr, ParseError> {
        match self.next() {
            Tok::Int(v) => Ok(Expr::Int(v)),
            Tok::Float(v) => Ok(Expr::Float(v)),
            Tok::Ident(s) => Ok(Expr::Ident(s)),
            Tok::LParen => {
                let e = self.expr()?;
                self.expect(Tok::RParen)?;
                Ok(e)
            }
            t => {
                self.pos -= 1;
                self.err(format!("unexpected token {t:?} in expression"))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_opencl_kernel() {
        let src = r#"
kernel void saxpy(global float* x, global float* y, float a, uniform int n) {
    int i = get_global_id(0);
    if (i < n) {
        y[i] = a * x[i] + y[i];
    }
}
"#;
        let p = parse_program(src).unwrap();
        assert_eq!(p.funcs.len(), 1);
        let f = &p.funcs[0];
        assert!(f.is_kernel);
        assert_eq!(f.params.len(), 4);
        assert_eq!(f.params[0].space, SpaceSpec::Global);
        assert!(f.params[3].uniform);
        assert_eq!(f.body.len(), 2);
    }

    #[test]
    fn parses_cuda_kernel() {
        let src = r#"
__global__ void add(float* a, float* b, int n) {
    int i = blockIdx.x * blockDim.x + threadIdx.x;
    __shared__ float tile[64];
    tile[threadIdx.x] = a[i];
    __syncthreads();
    b[i] = tile[threadIdx.x] * 2.0f;
}
"#;
        let p = parse_program(src).unwrap();
        let f = &p.funcs[0];
        assert!(f.is_kernel);
        // first stmt uses Member exprs
        if let Stmt::Decl { init: Some(e), .. } = &f.body[0] {
            assert!(format!("{e:?}").contains("Member"));
        } else {
            panic!("expected decl");
        }
        if let Stmt::Decl { space, dims, .. } = &f.body[1] {
            assert_eq!(*space, SpaceSpec::Local);
            assert_eq!(dims, &vec![64]);
        } else {
            panic!("expected shared decl");
        }
    }

    #[test]
    fn parses_control_flow_and_ops() {
        let src = r#"
void f(int n) {
    int s = 0;
    for (int i = 0; i < n; i++) {
        if (i % 2 == 0 && i != 4) s += i;
        else continue;
        while (s > 100) { s -= 10; break; }
    }
    do { s++; } while (s < 5);
    int m = s > 0 ? s : -s;
    goto done;
done:
    return;
}
"#;
        let p = parse_program(src).unwrap();
        assert_eq!(p.funcs.len(), 1);
        assert!(!p.funcs[0].is_kernel);
    }

    #[test]
    fn parses_globals_with_init() {
        let src = r#"
__constant__ float lut[4] = { 1.0f, 2.0f, 3.0f, 4.0f };
__device__ int counter;
kernel void k(global int* o) { o[0] = counter; }
"#;
        let p = parse_program(src).unwrap();
        assert_eq!(p.globals.len(), 2);
        assert_eq!(p.globals[0].space, SpaceSpec::Constant);
        assert_eq!(p.globals[0].init.as_ref().unwrap().len(), 4);
        assert_eq!(p.globals[1].space, SpaceSpec::Global);
    }

    #[test]
    fn reports_error_line() {
        let err = parse_program("kernel void f() {\n  int x = ;\n}").unwrap_err();
        assert_eq!(err.line, 2);
    }
}
