//! Builtin-function tables for the OpenCL and CUDA dialects (paper §4.2:
//! "the optimization finds special function calls … then lowers each call
//! appropriately in the built-in library"), plus the software warp-level
//! helper synthesis used by the Fig. 9 ISA-extension study: when the
//! target lacks vx_shfl / vx_vote, the builtins are emulated through the
//! per-core shared-memory scratch area exactly as the CuPBoP runtime
//! fallback does.

use crate::ir::{
    AddrSpace, BinOp, Builder, Csr, Function, Global, ICmp, InstKind, Intr, Linkage, Module,
    Param, Type, UnOp, Val,
};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dialect {
    OpenCL,
    Cuda,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Builtin {
    WorkItem(crate::ir::WorkItem),
    Barrier,
    Math1(UnOp),
    MinI,
    MaxI,
    MinF,
    MaxF,
    AbsI,
    Pow,
    Rsqrt,
    Mad,
    Atomic(crate::ir::AtomOp),
    AtomicSub,
    AtomicCas,
    Shfl,
    ShflSync,
    VoteAll,
    VoteAny,
    Ballot,
    LaneId,
    PrintInt,
    PrintFloat,
}

pub fn lookup(dialect: Dialect, name: &str) -> Option<Builtin> {
    use crate::ir::AtomOp as A;
    use crate::ir::WorkItem as W;
    // Dialect-independent debug helpers.
    match name {
        "print_int" => return Some(Builtin::PrintInt),
        "print_float" => return Some(Builtin::PrintFloat),
        "lane_id" => return Some(Builtin::LaneId),
        _ => {}
    }
    match dialect {
        Dialect::OpenCL => Some(match name {
            "get_global_id" => Builtin::WorkItem(W::GlobalId),
            "get_local_id" => Builtin::WorkItem(W::LocalId),
            "get_group_id" => Builtin::WorkItem(W::GroupId),
            "get_local_size" => Builtin::WorkItem(W::LocalSize),
            "get_global_size" => Builtin::WorkItem(W::GlobalSize),
            "get_num_groups" => Builtin::WorkItem(W::NumGroups),
            "barrier" | "work_group_barrier" => Builtin::Barrier,
            "sqrt" | "native_sqrt" => Builtin::Math1(UnOp::FSqrt),
            "exp" | "native_exp" => Builtin::Math1(UnOp::FExp),
            "log" | "native_log" => Builtin::Math1(UnOp::FLog),
            "fabs" => Builtin::Math1(UnOp::FAbs),
            "floor" => Builtin::Math1(UnOp::FFloor),
            "fmin" => Builtin::MinF,
            "fmax" => Builtin::MaxF,
            "min" => Builtin::MinI,
            "max" => Builtin::MaxI,
            "abs" => Builtin::AbsI,
            "pow" | "powr" => Builtin::Pow,
            "rsqrt" | "native_rsqrt" => Builtin::Rsqrt,
            "mad" | "fma" => Builtin::Mad,
            "atomic_add" | "atom_add" => Builtin::Atomic(A::Add),
            "atomic_sub" | "atom_sub" => Builtin::AtomicSub,
            "atomic_min" | "atom_min" => Builtin::Atomic(A::Min),
            "atomic_max" | "atom_max" => Builtin::Atomic(A::Max),
            "atomic_and" | "atom_and" => Builtin::Atomic(A::And),
            "atomic_or" | "atom_or" => Builtin::Atomic(A::Or),
            "atomic_xor" | "atom_xor" => Builtin::Atomic(A::Xor),
            "atomic_xchg" | "atom_xchg" => Builtin::Atomic(A::Exch),
            "atomic_cmpxchg" | "atom_cmpxchg" => Builtin::AtomicCas,
            _ => return None,
        }),
        Dialect::Cuda => Some(match name {
            "__syncthreads" => Builtin::Barrier,
            "sqrtf" => Builtin::Math1(UnOp::FSqrt),
            "expf" => Builtin::Math1(UnOp::FExp),
            "logf" => Builtin::Math1(UnOp::FLog),
            "fabsf" => Builtin::Math1(UnOp::FAbs),
            "floorf" => Builtin::Math1(UnOp::FFloor),
            "fminf" => Builtin::MinF,
            "fmaxf" => Builtin::MaxF,
            "min" => Builtin::MinI,
            "max" => Builtin::MaxI,
            "abs" => Builtin::AbsI,
            "powf" => Builtin::Pow,
            "rsqrtf" => Builtin::Rsqrt,
            "fmaf" => Builtin::Mad,
            "atomicAdd" => Builtin::Atomic(A::Add),
            "atomicSub" => Builtin::AtomicSub,
            "atomicMin" => Builtin::Atomic(A::Min),
            "atomicMax" => Builtin::Atomic(A::Max),
            "atomicAnd" => Builtin::Atomic(A::And),
            "atomicOr" => Builtin::Atomic(A::Or),
            "atomicXor" => Builtin::Atomic(A::Xor),
            "atomicExch" => Builtin::Atomic(A::Exch),
            "atomicCAS" => Builtin::AtomicCas,
            "__shfl" | "__shfl_idx" => Builtin::Shfl,
            "__shfl_sync" => Builtin::ShflSync,
            "__all" => Builtin::VoteAll,
            "__all_sync" => Builtin::VoteAll,
            "__any" => Builtin::VoteAny,
            "__any_sync" => Builtin::VoteAny,
            "__ballot" => Builtin::Ballot,
            "__ballot_sync" => Builtin::Ballot,
            _ => return None,
        }),
    }
}

/// Maximum threads-per-warp / warps-per-core the software scratch supports.
pub const SCRATCH_LANES: u32 = 32;
pub const SCRATCH_WARPS: u32 = 16;

fn ensure_scratch(m: &mut Module) -> crate::ir::GlobalId {
    if let Some(idx) = m.globals.iter().position(|g| g.name == "__warp_scratch") {
        return crate::ir::GlobalId(idx as u32);
    }
    m.add_global(Global {
        name: "__warp_scratch".into(),
        space: AddrSpace::Local,
        size: SCRATCH_LANES * SCRATCH_WARPS * 4,
        align: 4,
        init: None,
    })
}

/// Synthesize (once) the software warp-primitive helper `name` ∈
/// {"shfl", "ballot", "vote_all", "vote_any"} and return its id.
pub fn ensure_sw_helper(m: &mut Module, name: &str) -> crate::ir::FuncId {
    let fname = format!("__sw_{name}");
    if let Some(fid) = m.find_func(&fname) {
        return fid;
    }
    let scratch = ensure_scratch(m);
    match name {
        "shfl" => {
            let mut f = Function::new(
                &fname,
                vec![
                    Param {
                        name: "v".into(),
                        ty: Type::I32,
                        uniform: false,
                    },
                    Param {
                        name: "src".into(),
                        ty: Type::I32,
                        uniform: false,
                    },
                ],
                Type::I32,
            );
            f.linkage = Linkage::Internal;
            {
                let mut b = Builder::new(&mut f);
                let wid = b.intr(Intr::Csr(Csr::WarpId), vec![]);
                let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
                let nt = b.intr(Intr::Csr(Csr::NumThreads), vec![]);
                let base = b.mul(wid, Val::ci(SCRATCH_LANES as i64));
                let my = b.add(base, lane);
                let myp = b.gep(Val::G(scratch), my, 4);
                b.store(myp, Val::Arg(0));
                let srcm = b.bin(BinOp::URem, Val::Arg(1), nt);
                let si = b.add(base, srcm);
                let sp = b.gep(Val::G(scratch), si, 4);
                let r = b.load(sp, Type::I32);
                b.ret(Some(r));
            }
            m.add_func(f)
        }
        "ballot" | "vote_all" | "vote_any" => {
            // ballot core: write my predicate bit, then a branchless loop
            // OR-ing (scratch[i] & active_bit_i) << i over all lanes.
            let mut f = Function::new(
                &fname,
                vec![Param {
                    name: "p".into(),
                    ty: Type::I32,
                    uniform: false,
                }],
                Type::I32,
            );
            f.linkage = Linkage::Internal;
            f.ret_uniform = true; // warp-uniform by construction
            let entry = f.entry;
            let h = f.add_block("h");
            let body = f.add_block("body");
            let exit = f.add_block("exit");
            {
                let mut b = Builder::at(&mut f, entry);
                let wid = b.intr(Intr::Csr(Csr::WarpId), vec![]);
                let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
                let nt = b.intr(Intr::Csr(Csr::NumThreads), vec![]);
                let mask = b.intr(Intr::Mask, vec![]);
                let base = b.mul(wid, Val::ci(SCRATCH_LANES as i64));
                let my = b.add(base, lane);
                let myp = b.gep(Val::G(scratch), my, 4);
                b.store(myp, Val::Arg(0));
                b.br(h);
                b.set_block(h);
                let i = b.phi(Type::I32, vec![(entry, Val::ci(0))]);
                let acc = b.phi(Type::I32, vec![(entry, Val::ci(0))]);
                let c = b.icmp(ICmp::Slt, i, nt);
                b.cond_br(c, body, exit);
                b.set_block(body);
                let idx = b.add(base, i);
                let p = b.gep(Val::G(scratch), idx, 4);
                let v = b.load(p, Type::I32);
                let mbit = b.bin(BinOp::LShr, mask, i);
                let active = b.bin(BinOp::And, mbit, Val::ci(1));
                let vb = b.bin(BinOp::And, v, Val::ci(1));
                let contrib0 = b.bin(BinOp::And, vb, active);
                let contrib = b.bin(BinOp::Shl, contrib0, i);
                let acc2 = b.bin(BinOp::Or, acc, contrib);
                let i2 = b.add(i, Val::ci(1));
                b.br(h);
                b.set_block(exit);
                // vote_all: acc == mask ; vote_any: acc != 0 ; ballot: acc
                match name {
                    "vote_all" => {
                        let eq = b.icmp(ICmp::Eq, acc, mask);
                        let z = b.un(UnOp::ZExt, eq);
                        b.ret(Some(z));
                    }
                    "vote_any" => {
                        let ne = b.icmp(ICmp::Ne, acc, Val::ci(0));
                        let z = b.un(UnOp::ZExt, ne);
                        b.ret(Some(z));
                    }
                    _ => b.ret(Some(acc)),
                }
                if let (Val::Inst(ip), Val::Inst(ap)) = (i, acc) {
                    if let InstKind::Phi { incs } = &mut b.f.inst_mut(ip).kind {
                        incs.push((body, i2));
                    }
                    if let InstKind::Phi { incs } = &mut b.f.inst_mut(ap).kind {
                        incs.push((body, acc2));
                    }
                }
            }
            m.add_func(f)
        }
        _ => panic!("unknown software helper '{name}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_tables() {
        assert_eq!(
            lookup(Dialect::OpenCL, "get_global_id"),
            Some(Builtin::WorkItem(crate::ir::WorkItem::GlobalId))
        );
        assert_eq!(lookup(Dialect::Cuda, "__syncthreads"), Some(Builtin::Barrier));
        assert_eq!(lookup(Dialect::Cuda, "get_global_id"), None);
        assert_eq!(lookup(Dialect::OpenCL, "__syncthreads"), None);
        assert_eq!(
            lookup(Dialect::Cuda, "atomicCAS"),
            Some(Builtin::AtomicCas)
        );
    }

    #[test]
    fn sw_helpers_build_and_verify() {
        let mut m = Module::new("t");
        let s = ensure_sw_helper(&mut m, "shfl");
        let b1 = ensure_sw_helper(&mut m, "ballot");
        let b2 = ensure_sw_helper(&mut m, "ballot");
        assert_eq!(b1, b2, "helper must be synthesized once");
        let _ = ensure_sw_helper(&mut m, "vote_all");
        let _ = ensure_sw_helper(&mut m, "vote_any");
        crate::ir::verify::verify_module(&m).unwrap();
        assert!(m.func(s).name.starts_with("__sw_"));
        assert!(m.globals.iter().any(|g| g.name == "__warp_scratch"));
        // ballot is marked warp-uniform.
        let bal = m.find_func("__sw_ballot").unwrap();
        assert!(m.func(bal).ret_uniform);
    }
}
