//! The VOLT front-end (paper §4.2): VCL — an OpenCL-C / CUDA-C kernel
//! dialect — lexer, parser, semantic lowering to IR, builtin libraries for
//! both dialects, and thread-schedule code insertion.

pub mod ast;
pub mod builtins;
pub mod lexer;
pub mod lower;
pub mod parser;
pub mod schedule;

pub use builtins::Dialect;
pub use lower::{compile, CompileError, FrontendOptions};
pub use schedule::{build_dispatcher, KernelInfo};

use crate::ir::Module;

/// Full front-end: compile source and build a dispatcher for every kernel.
pub fn compile_kernels(
    src: &str,
    opts: &FrontendOptions,
) -> Result<(Module, Vec<KernelInfo>), CompileError> {
    let mut m = compile(src, opts)?;
    let kernels = m.kernels();
    let mut infos = vec![];
    for k in kernels {
        infos.push(build_dispatcher(&mut m, k)?);
    }
    Ok((m, infos))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::interp::{read_u32, run_kernel_scalar};
    use crate::ir::{FuncId, Type};

    /// End-to-end front-end check: compile, run the *kernel* function
    /// (pre-dispatch) through the scalar interpreter over an NDRange.
    fn run_kernel(
        src: &str,
        opts: &FrontendOptions,
        kname: &str,
        args: &[u32],
        grid: u32,
        block: u32,
        mem: &mut Vec<u8>,
    ) {
        let m = compile(src, opts).unwrap();
        let k = m.find_func(kname).unwrap();
        let global_addrs = layout_globals(&m, mem);
        run_kernel_scalar(
            &m,
            k,
            args,
            [grid, 1, 1],
            [block, 1, 1],
            mem,
            1 << 16,
            &global_addrs,
        )
        .unwrap();
    }

    /// Place module globals at the top of memory for interp tests.
    fn layout_globals(m: &crate::ir::Module, mem: &mut [u8]) -> Vec<u32> {
        let mut addr = 0x8000u32;
        let mut out = vec![];
        for g in &m.globals {
            out.push(addr);
            if let Some(init) = &g.init {
                mem[addr as usize..addr as usize + init.len()].copy_from_slice(init);
            }
            addr += g.size.max(4);
        }
        out
    }

    #[test]
    fn saxpy_opencl() {
        let src = r#"
kernel void saxpy(global float* x, global float* y, float a, int n) {
    int i = get_global_id(0);
    if (i < n) { y[i] = a * x[i] + y[i]; }
}
"#;
        let mut mem = vec![0u8; 1 << 17];
        let xa = 0x100u32;
        let ya = 0x400u32;
        for i in 0..16u32 {
            crate::ir::interp::write_u32(&mut mem, xa + i * 4, (i as f32).to_bits());
            crate::ir::interp::write_u32(&mut mem, ya + i * 4, 1.0f32.to_bits());
        }
        run_kernel(
            src,
            &FrontendOptions::default(),
            "saxpy",
            &[xa, ya, 2.0f32.to_bits(), 12],
            2,
            8,
            &mut mem,
        );
        for i in 0..16u32 {
            let got = f32::from_bits(read_u32(&mem, ya + i * 4));
            let want = if i < 12 { 2.0 * i as f32 + 1.0 } else { 1.0 };
            assert_eq!(got, want, "i={i}");
        }
    }

    #[test]
    fn cuda_dialect_and_loops() {
        let src = r#"
__global__ void sum_rows(float* a, float* out, int cols) {
    int row = blockIdx.x * blockDim.x + threadIdx.x;
    float s = 0.0f;
    for (int c = 0; c < cols; c++) {
        s += a[row * cols + c];
    }
    out[row] = s;
}
"#;
        let opts = FrontendOptions {
            dialect: Dialect::Cuda,
            warp_hw: true,
        };
        let mut mem = vec![0u8; 1 << 17];
        let aa = 0x100u32;
        let oa = 0x1000u32;
        for i in 0..32u32 {
            crate::ir::interp::write_u32(&mut mem, aa + i * 4, (1.0f32).to_bits());
        }
        run_kernel(src, &opts, "sum_rows", &[aa, oa, 8], 1, 4, &mut mem);
        for r in 0..4u32 {
            assert_eq!(f32::from_bits(read_u32(&mem, oa + r * 4)), 8.0, "row {r}");
        }
    }

    #[test]
    fn device_function_calls_and_ternary() {
        let src = r#"
int clampi(int v, int lo, int hi) {
    return v < lo ? lo : (v > hi ? hi : v);
}
kernel void k(global int* out, int n) {
    int i = get_global_id(0);
    out[i] = clampi(i * 3 - 4, 0, n);
}
"#;
        let mut mem = vec![0u8; 1 << 17];
        run_kernel(
            src,
            &FrontendOptions::default(),
            "k",
            &[0x200, 10],
            1,
            8,
            &mut mem,
        );
        for i in 0..8i32 {
            let got = read_u32(&mem, 0x200 + i as u32 * 4) as i32;
            assert_eq!(got, (i * 3 - 4).clamp(0, 10), "i={i}");
        }
    }

    #[test]
    fn short_circuit_semantics() {
        // Guarded OOB access: if short-circuit is broken this traps.
        let src = r#"
kernel void k(global int* a, global int* out, int n) {
    int i = get_global_id(0);
    if (i < n && a[i] > 0) { out[i] = 1; } else { out[i] = 0; }
}
"#;
        let mut mem = vec![0u8; 1 << 17];
        let aa = 0x100u32;
        crate::ir::interp::write_u32(&mut mem, aa, 5u32);
        crate::ir::interp::write_u32(&mut mem, aa + 4, 0u32);
        run_kernel(
            src,
            &FrontendOptions::default(),
            "k",
            &[aa, 0x600, 2],
            1,
            4,
            &mut mem,
        );
        assert_eq!(read_u32(&mem, 0x600), 1);
        assert_eq!(read_u32(&mem, 0x604), 0);
        assert_eq!(read_u32(&mem, 0x608), 0);
    }

    #[test]
    fn constant_global_lut() {
        let src = r#"
__constant__ float lut[4] = { 2.0f, 4.0f, 8.0f, 16.0f };
kernel void k(global float* out) {
    int i = get_global_id(0);
    out[i] = lut[i % 4] * 10.0f;
}
"#;
        let mut mem = vec![0u8; 1 << 17];
        run_kernel(
            src,
            &FrontendOptions::default(),
            "k",
            &[0x200],
            1,
            4,
            &mut mem,
        );
        for (i, want) in [20.0f32, 40.0, 80.0, 160.0].iter().enumerate() {
            assert_eq!(
                f32::from_bits(read_u32(&mem, 0x200 + i as u32 * 4)),
                *want
            );
        }
    }

    #[test]
    fn goto_makes_irreducible_then_structurizes() {
        let src = r#"
kernel void k(global int* out, int c) {
    int x = 0;
    if (c > 0) goto middle;
top:
    x = x + 1;
    if (x < 5) goto middle;
    goto end;
middle:
    x = x + 10;
    if (x < 40) goto top;
end:
    out[get_global_id(0)] = x;
}
"#;
        // Compile + middle end at base level; semantics via interp.
        let m0 = compile(src, &FrontendOptions::default()).unwrap();
        let k = m0.find_func("k").unwrap();
        let run = |m: &crate::ir::Module, c: u32| -> u32 {
            let mut mem = vec![0u8; 1 << 17];
            run_kernel_scalar(m, k, &[0x200, c], [1, 1, 1], [1, 1, 1], &mut mem, 1 << 16, &[])
                .unwrap();
            read_u32(&mem, 0x200)
        };
        let want: Vec<u32> = vec![run(&m0, 0), run(&m0, 1)];
        let mut m = m0.clone();
        let mut cfg = crate::transform::OptLevel::Base.config();
        cfg.verify = true;
        crate::transform::run_middle_end(&mut m, &cfg);
        assert!(crate::ir::cfg::is_reducible(&m.funcs[k.idx()]));
        assert_eq!(vec![run(&m, 0), run(&m, 1)], want);
    }

    #[test]
    fn full_compile_kernels_pipeline() {
        let src = r#"
kernel void scale(global float* x, float a, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = x[i] * a;
}
"#;
        let (m, infos) = compile_kernels(src, &FrontendOptions::default()).unwrap();
        assert_eq!(infos.len(), 1);
        assert_eq!(infos[0].name, "scale");
        assert_eq!(infos[0].params.len(), 3);
        assert_eq!(infos[0].params[1].1, Type::F32);
        assert_eq!(m.kernels().len(), 1); // only the dispatcher
        let _ = FuncId(0);
    }
}
