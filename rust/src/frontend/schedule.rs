//! Thread-schedule code insertion (paper §4.2).
//!
//! OpenCL/CUDA express work as an NDRange of work-items; Vortex hardware
//! executes fixed-size warps. This pass bridges the gap: for every kernel
//! it synthesizes a *dispatcher* that
//!
//! 1. reads the launch geometry and kernel arguments from the uniform
//!    argument block (`__args`, constant address space),
//! 2. walks workgroups `core_id, core_id + num_cores, …` (one block at a
//!    time per core so workgroup barriers are core-local),
//! 3. activates `warps_per_block` warps, each covering `num_threads`
//!    work-items, guarding the tail with a lane test,
//! 4. inlines the kernel body and rewrites its work-item queries
//!    (`get_global_id`, `threadIdx`, …) into arithmetic over the loop
//!    state and hardware CSRs,
//! 5. rewrites `barrier()` into `vx_barrier` with the per-core warp count.
//!
//! The dispatcher then goes through the regular middle-end: under Uni-HW
//! its control flow is provably uniform except for the lane-tail guard —
//! exactly the divergence structure real Vortex kernels exhibit.

use super::lower::CompileError;
use crate::ir::*;
use crate::transform::inline;

/// Fixed offsets within the `__args` block: grid dims (12 bytes), block
/// dims (12), kernel entry PC (4, read by crt0), then kernel arguments.
pub const ARGS_GRID_X: u32 = 0;
pub const ARGS_BLOCK_X: u32 = 12;
pub const ARGS_ENTRY_PC: u32 = 24;
/// First kernel argument offset.
pub const ARGS_KARGS: u32 = 28;

#[derive(Clone, Debug)]
pub struct KernelInfo {
    pub name: String,
    /// The generated entry point compiled to the binary.
    pub dispatcher: FuncId,
    /// Original kernel parameter names/types, in ABI order.
    pub params: Vec<(String, Type)>,
    pub local_mem: u32,
    pub uses_barrier: bool,
}

fn ensure_args_global(m: &mut Module, nparams: usize) -> GlobalId {
    let need = ARGS_KARGS + 4 * nparams as u32;
    if let Some(idx) = m.globals.iter().position(|g| g.name == "__args") {
        let g = GlobalId(idx as u32);
        if m.globals[idx].size < need {
            m.globals[idx].size = need;
        }
        return g;
    }
    m.add_global(Global {
        name: "__args".into(),
        space: AddrSpace::Const,
        size: need,
        align: 4,
        init: None,
    })
}

/// Does the kernel (or anything it calls) use barriers / local memory?
fn kernel_traits(m: &Module, kernel: FuncId) -> (bool, u32) {
    let cg = crate::analysis::callgraph::CallGraph::build(m);
    let reach = cg.rpo_from(&[kernel]);
    let mut uses_barrier = false;
    let mut local = 0u32;
    for f in reach {
        let fd = m.func(f);
        local = local.max(fd.local_mem_size);
        for inst in fd.insts.iter().filter(|i| !i.dead) {
            if let InstKind::Intr {
                intr: Intr::Barrier,
                ..
            } = inst.kind
            {
                uses_barrier = true;
            }
            for op in inst.kind.operands() {
                if let Val::G(g) = op {
                    if m.globals[g.idx()].space == AddrSpace::Local {
                        local = local.max(m.globals[g.idx()].size);
                    }
                }
            }
        }
    }
    (uses_barrier, local)
}

/// Build the dispatcher for `kernel` and demote the kernel to an internal
/// device function. Returns the ABI description for the host runtime.
pub fn build_dispatcher(m: &mut Module, kernel: FuncId) -> Result<KernelInfo, CompileError> {
    let kname = m.func(kernel).name.clone();
    if m.func(kernel).ret != Type::Void {
        return Err(CompileError {
            line: 0,
            msg: format!("kernel '{kname}' must return void"),
        });
    }
    let params: Vec<(String, Type)> = m
        .func(kernel)
        .params
        .iter()
        .map(|p| (p.name.clone(), p.ty))
        .collect();
    let (uses_barrier, local_mem) = kernel_traits(m, kernel);
    let kernel_line = m.func(kernel).src_line;
    let args_g = ensure_args_global(m, params.len());
    // Demote the kernel.
    {
        let k = m.func_mut(kernel);
        k.is_kernel = false;
        k.linkage = Linkage::Internal;
    }

    let mut f = Function::new(&format!("__main_{kname}"), vec![], Type::Void);
    f.is_kernel = true;
    f.linkage = Linkage::External;
    f.local_mem_size = local_mem;
    let entry = f.entry;
    let head = f.add_block("disp.head");
    let wcheck = f.add_block("disp.wcheck");
    let work = f.add_block("disp.work");
    let kcall = f.add_block("disp.kcall");
    let wdone = f.add_block("disp.wdone");
    let sync = f.add_block("disp.sync");
    let done = f.add_block("disp.done");

    let mut b = Builder::at(&mut f, entry);
    let argw = |b: &mut Builder, off: u32| -> Val {
        let p = b.gep(Val::G(args_g), Val::ci((off / 4) as i64), 4);
        b.load(p, Type::I32)
    };
    let gx = argw(&mut b, ARGS_GRID_X);
    let gy = argw(&mut b, 4);
    let gz = argw(&mut b, 8);
    let bx = argw(&mut b, ARGS_BLOCK_X);
    let by = argw(&mut b, 16);
    let bz = argw(&mut b, 20);
    let mut kargs = vec![];
    for (i, (_, ty)) in params.iter().enumerate() {
        let p = b.gep(
            Val::G(args_g),
            Val::ci(((ARGS_KARGS + 4 * i as u32) / 4) as i64),
            4,
        );
        kargs.push(b.load(p, *ty));
    }
    let bxy = b.mul(bx, by);
    let bsize = b.mul(bxy, bz);
    let gxy = b.mul(gx, gy);
    let tb0 = b.mul(gxy, gz);
    let nt = b.intr(Intr::Csr(Csr::NumThreads), vec![]);
    let nwarps = b.intr(Intr::Csr(Csr::NumWarps), vec![]);
    let cid = b.intr(Intr::Csr(Csr::CoreId), vec![]);
    let wid = b.intr(Intr::Csr(Csr::WarpId), vec![]);
    let ncores = b.intr(Intr::Csr(Csr::NumCores), vec![]);
    let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
    // wpb = (bsize + nt - 1) / nt
    let ntm1 = b.sub(nt, Val::ci(1));
    let tmp = b.add(bsize, ntm1);
    let wpb = b.bin(BinOp::UDiv, tmp, nt);
    b.br(head);

    b.set_block(head);
    let bphi = b.phi(Type::I32, vec![(entry, cid)]);
    let chead = b.icmp(ICmp::Ult, bphi, tb0);
    b.cond_br(chead, wcheck, done);

    b.set_block(wcheck);
    let cw = b.icmp(ICmp::Ult, wid, wpb);
    b.cond_br(cw, work, sync);

    b.set_block(work);
    let wbase = b.mul(wid, nt);
    let lidlin = b.add(wbase, lane);
    let cact = b.icmp(ICmp::Ult, lidlin, bsize);
    b.cond_br(cact, kcall, wdone);

    b.set_block(kcall);
    let call_val = b.call(kernel, kargs.clone(), Type::Void);
    b.br(wdone);

    b.set_block(wdone);
    b.br(sync);

    b.set_block(sync);
    if uses_barrier || local_mem > 0 {
        // End-of-block barrier (id 1): every warp of the core arrives.
        // Kernel-internal barriers use id 0 with the participating warp
        // count (wpb) — see rewrite_workitems.
        b.intr(Intr::Barrier, vec![Val::ci(1), nwarps]);
    }
    let bnext = b.add(bphi, ncores);
    b.br(head);

    b.set_block(done);
    b.ret(None);
    if let Val::Inst(bp) = bphi {
        if let InstKind::Phi { incs } = &mut f.inst_mut(bp).kind {
            incs.push((sync, bnext));
        }
    }
    // The schedule arithmetic is synthesized, not source code; attribute
    // it to the kernel's declaration line so profiler cycles spent in the
    // dispatch loop show up against the kernel signature instead of
    // vanishing from the line table.
    f.src_line = kernel_line;
    if kernel_line != 0 {
        for inst in f.insts.iter_mut() {
            inst.loc = Some(Loc::line(kernel_line));
        }
    }
    let disp = m.add_func(f);

    // Inline the kernel body.
    let call_inst = match call_val {
        Val::Inst(i) => i,
        _ => unreachable!(),
    };
    assert!(inline::inline_call(m, disp, call_inst));

    // Rewrite work-item queries and barriers.
    rewrite_workitems(
        m.func_mut(disp),
        &WorkItemEnv {
            gx,
            gy,
            gz,
            bx,
            by,
            bz,
            bxy,
            gxy,
            bphi,
            lidlin,
            wpb,
        },
    )?;
    crate::ir::verify::verify_module(m).map_err(|e| CompileError {
        line: 0,
        msg: format!("internal: dispatcher failed verification: {e}"),
    })?;
    Ok(KernelInfo {
        name: kname,
        dispatcher: disp,
        params,
        local_mem,
        uses_barrier,
    })
}

struct WorkItemEnv {
    gx: Val,
    gy: Val,
    gz: Val,
    bx: Val,
    by: Val,
    bz: Val,
    bxy: Val,
    gxy: Val,
    bphi: Val,
    lidlin: Val,
    /// Warps participating per block — the count for kernel-internal
    /// (id 0) barriers.
    wpb: Val,
}

fn rewrite_workitems(f: &mut Function, env: &WorkItemEnv) -> Result<(), CompileError> {
    // Cache expansions per (workitem, dim) per block to limit bloat; the
    // middle-end DCEs duplicates anyway, so a simple per-site expansion is
    // fine and always dominator-correct.
    loop {
        let mut site: Option<(InstId, WorkItem, i64)> = None;
        let mut barrier_site: Option<InstId> = None;
        'outer: for bid in f.block_ids() {
            for &i in &f.blocks[bid.idx()].insts {
                match &f.inst(i).kind {
                    InstKind::Intr {
                        intr: Intr::WorkItem(w),
                        args,
                    } => {
                        let d = match args.first() {
                            Some(Val::I(d, _)) => *d,
                            _ => {
                                return Err(CompileError {
                                    line: 0,
                                    msg: "work-item dimension must be constant".into(),
                                })
                            }
                        };
                        site = Some((i, *w, d));
                        break 'outer;
                    }
                    InstKind::Intr {
                        intr: Intr::Barrier,
                        args,
                    } if args.is_empty() => {
                        barrier_site = Some(i);
                        break 'outer;
                    }
                    _ => {}
                }
            }
        }
        if let Some(bi) = barrier_site {
            if let InstKind::Intr { args, .. } = &mut f.inst_mut(bi).kind {
                *args = vec![Val::ci(0), env.wpb];
            }
            continue;
        }
        let Some((site, w, d)) = site else {
            return Ok(());
        };
        let bid = f.inst(site).block;
        let site_loc = f.inst(site).loc;
        let mut pos = f.blocks[bid.idx()].insts.iter().position(|&x| x == site).unwrap();
        // Helpers to insert arithmetic before the site; the expansion
        // inherits the work-item query's source location.
        let mut ins = |f: &mut Function, kind: InstKind, ty: Type| -> Val {
            let id = f.insert_inst(bid, pos, kind, ty);
            f.inst_mut(id).loc = site_loc;
            pos += 1;
            Val::Inst(id)
        };
        let bin = |f: &mut Function,
                   ins: &mut dyn FnMut(&mut Function, InstKind, Type) -> Val,
                   op: BinOp,
                   a: Val,
                   b: Val| ins(f, InstKind::Bin { op, a, b }, Type::I32);
        let local_id = |f: &mut Function,
                        ins: &mut dyn FnMut(&mut Function, InstKind, Type) -> Val,
                        d: i64| {
            match d {
                0 => bin(f, ins, BinOp::URem, env.lidlin, env.bx),
                1 => {
                    let t = bin(f, ins, BinOp::UDiv, env.lidlin, env.bx);
                    bin(f, ins, BinOp::URem, t, env.by)
                }
                _ => bin(f, ins, BinOp::UDiv, env.lidlin, env.bxy),
            }
        };
        let group_id = |f: &mut Function,
                        ins: &mut dyn FnMut(&mut Function, InstKind, Type) -> Val,
                        d: i64| {
            match d {
                0 => bin(f, ins, BinOp::URem, env.bphi, env.gx),
                1 => {
                    let t = bin(f, ins, BinOp::UDiv, env.bphi, env.gx);
                    bin(f, ins, BinOp::URem, t, env.gy)
                }
                _ => bin(f, ins, BinOp::UDiv, env.bphi, env.gxy),
            }
        };
        let dim_of = |d: i64, x: Val, y: Val, z: Val| match d {
            0 => x,
            1 => y,
            _ => z,
        };
        let repl = {
            let mut insf = |f: &mut Function, k: InstKind, t: Type| ins(f, k, t);
            match w {
                WorkItem::LocalId => local_id(f, &mut insf, d),
                WorkItem::GroupId => group_id(f, &mut insf, d),
                WorkItem::LocalSize => dim_of(d, env.bx, env.by, env.bz),
                WorkItem::NumGroups => dim_of(d, env.gx, env.gy, env.gz),
                WorkItem::GlobalSize => {
                    let g = dim_of(d, env.gx, env.gy, env.gz);
                    let bb = dim_of(d, env.bx, env.by, env.bz);
                    bin(f, &mut insf, BinOp::Mul, g, bb)
                }
                WorkItem::GlobalId => {
                    let grp = group_id(f, &mut insf, d);
                    let bb = dim_of(d, env.bx, env.by, env.bz);
                    let lid = local_id(f, &mut insf, d);
                    let t = bin(f, &mut insf, BinOp::Mul, grp, bb);
                    bin(f, &mut insf, BinOp::Add, t, lid)
                }
            }
        };
        f.replace_uses(Val::Inst(site), repl);
        f.remove_inst(site);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::lower::{compile, FrontendOptions};

    #[test]
    fn dispatcher_builds_for_saxpy() {
        let src = r#"
kernel void saxpy(global float* x, global float* y, float a, int n) {
    int i = get_global_id(0);
    if (i < n) { y[i] = a * x[i] + y[i]; }
}
"#;
        let mut m = compile(src, &FrontendOptions::default()).unwrap();
        let k = m.find_func("saxpy").unwrap();
        let info = build_dispatcher(&mut m, k).unwrap();
        assert_eq!(info.params.len(), 4);
        assert!(!info.uses_barrier);
        let disp = m.func(info.dispatcher);
        assert!(disp.is_kernel);
        // No WorkItem intrinsics remain.
        assert!(!disp.insts.iter().any(|i| !i.dead
            && matches!(
                i.kind,
                InstKind::Intr {
                    intr: Intr::WorkItem(_),
                    ..
                }
            )));
        // The original kernel was demoted.
        assert!(!m.func(k).is_kernel);
        // __args exists in const space.
        assert!(m
            .globals
            .iter()
            .any(|g| g.name == "__args" && g.space == AddrSpace::Const));
    }

    #[test]
    fn dispatcher_semantics_via_interp() {
        // out[gid] = gid * scale (+ block structure sanity).
        let src = r#"
kernel void k(global int* out, int scale) {
    int g = get_global_id(0);
    out[g] = g * scale + get_local_id(0) * 0 + get_group_id(0) * 0;
}
"#;
        let mut m = compile(src, &FrontendOptions::default()).unwrap();
        let k = m.find_func("k").unwrap();
        let info = build_dispatcher(&mut m, k).unwrap();
        // Execute the dispatcher in the scalar interpreter: emulate one
        // thread at a time by fixing CSR values? The scalar interpreter
        // models a 1-core, 1-warp, 32-lane machine; grid loops cover the
        // rest. Write the args block and run every (lane) by running the
        // dispatcher with each work item mapped to lane ids — covered more
        // thoroughly by the simulator integration tests; here we only
        // check the dispatcher verifies and inlined cleanly.
        assert!(m.func(info.dispatcher).num_insts() > 20);
    }

    #[test]
    fn barrier_kernels_get_sync() {
        let src = r#"
kernel void k(global float* a) {
    local float tile[32];
    int l = get_local_id(0);
    tile[l] = a[l];
    barrier(0);
    a[l] = tile[31 - l];
}
"#;
        let mut m = compile(src, &FrontendOptions::default()).unwrap();
        let k = m.find_func("k").unwrap();
        let info = build_dispatcher(&mut m, k).unwrap();
        assert!(info.uses_barrier);
        assert_eq!(info.local_mem, 128);
        let disp = m.func(info.dispatcher);
        // All barriers carry (id, count) args now.
        for inst in disp.insts.iter().filter(|i| !i.dead) {
            if let InstKind::Intr {
                intr: Intr::Barrier,
                args,
            } = &inst.kind
            {
                assert_eq!(args.len(), 2);
            }
        }
        assert!(disp.local_mem_size >= 128);
    }
}
