//! AST → IR lowering with on-the-fly semantic analysis (paper §4.2:
//! language-semantics analysis, memory-structure handling, builtin
//! resolution).
//!
//! All named variables live in allocas until the middle-end's mem2reg —
//! this keeps the early CFG passes (structurization / reconstruction) free
//! of SSA repair. Short-circuit booleans and call-bearing ternaries lower
//! to value-producing diamonds through a temp slot, so every conditional
//! branch the middle-end sees has a proper single-entry/single-exit
//! reconvergence structure.

use super::ast::*;
use super::builtins::{self, Builtin, Dialect};
use super::parser::{parse_program, ParseError};
use crate::ir::{
    AddrSpace, AtomOp, BinOp, FCmp, Function, Global, GlobalId, ICmp, InstKind, Intr,
    Linkage, Module, Param, Type, UnOp, Val, WorkItem,
};
use std::collections::HashMap;

#[derive(Debug)]
pub struct CompileError {
    pub line: u32,
    pub msg: String,
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "compile error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for CompileError {}

impl From<ParseError> for CompileError {
    fn from(e: ParseError) -> Self {
        CompileError {
            line: e.line,
            msg: e.msg,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct FrontendOptions {
    pub dialect: Dialect,
    /// Lower warp-level builtins to hardware instructions (vx_shfl /
    /// vx_vote) rather than shared-memory software emulation — the
    /// Fig. 9 ISA-extension axis.
    pub warp_hw: bool,
}

impl Default for FrontendOptions {
    fn default() -> Self {
        FrontendOptions {
            dialect: Dialect::OpenCL,
            warp_hw: true,
        }
    }
}

/// Value type during lowering (adds signedness and pointee info on top of
/// the IR types).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VTy {
    I32,
    U32,
    F32,
    Bool,
    Ptr(AddrSpace, TypeSpec),
}

impl VTy {
    fn ir(self) -> Type {
        match self {
            VTy::I32 | VTy::U32 => Type::I32,
            VTy::F32 => Type::F32,
            VTy::Bool => Type::I1,
            VTy::Ptr(sp, _) => Type::Ptr(sp),
        }
    }
    fn of_spec(ts: TypeSpec) -> VTy {
        match ts {
            TypeSpec::Int => VTy::I32,
            TypeSpec::Uint => VTy::U32,
            TypeSpec::Float => VTy::F32,
            TypeSpec::Bool => VTy::Bool,
            TypeSpec::Void => VTy::I32, // callers check
        }
    }
}

fn space_of(s: SpaceSpec) -> AddrSpace {
    match s {
        SpaceSpec::Global | SpaceSpec::Default => AddrSpace::Global,
        SpaceSpec::Local => AddrSpace::Local,
        SpaceSpec::Constant => AddrSpace::Const,
        SpaceSpec::Private => AddrSpace::Private,
    }
}

#[derive(Clone, Copy)]
struct VarSlot {
    /// Pointer to the storage (alloca or global address).
    ptr: Val,
    ty: VTy,
    is_array: bool,
    uniform: bool,
}

/// Element count of an array declaration, rejecting byte-size overflow
/// (user-controlled dims must not panic the compiler).
fn checked_elems(dims: &[u32]) -> Option<u32> {
    let elems = dims.iter().try_fold(1u32, |a, &d| a.checked_mul(d))?;
    elems.max(1).checked_mul(4)?;
    Some(elems.max(1))
}

pub fn compile(src: &str, opts: &FrontendOptions) -> Result<Module, CompileError> {
    let prog = parse_program(src)?;
    let mut module = Module::new("vcl");
    // Globals first.
    let mut global_map: HashMap<String, (GlobalId, VTy, bool)> = HashMap::new();
    for g in &prog.globals {
        let elems: u32 = checked_elems(&g.dims).ok_or(CompileError {
            line: g.line,
            msg: format!("array '{}' is too large", g.name),
        })?;
        let init = match &g.init {
            Some(items) => {
                let mut bytes = vec![];
                for it in items {
                    let w = const_eval(it).ok_or(CompileError {
                        line: g.line,
                        msg: "global initializers must be literals".into(),
                    })?;
                    bytes.extend_from_slice(&w.to_le_bytes());
                }
                bytes.resize((elems * 4) as usize, 0);
                Some(bytes)
            }
            None => None,
        };
        let gid = module.add_global(Global {
            name: g.name.clone(),
            space: space_of(g.space),
            size: elems * 4,
            align: 4,
            init,
        });
        global_map.insert(
            g.name.clone(),
            (
                gid,
                VTy::Ptr(space_of(g.space), g.ty),
                !g.dims.is_empty(),
            ),
        );
    }
    // Function shells.
    let mut sigs: HashMap<String, crate::ir::FuncId> = HashMap::new();
    for fd in &prog.funcs {
        let params: Vec<Param> = fd
            .params
            .iter()
            .map(|p| Param {
                name: p.name.clone(),
                ty: if p.is_ptr {
                    Type::Ptr(space_of(p.space))
                } else {
                    VTy::of_spec(p.ty).ir()
                },
                uniform: p.uniform,
            })
            .collect();
        let ret = if fd.ret == TypeSpec::Void {
            Type::Void
        } else {
            VTy::of_spec(fd.ret).ir()
        };
        let mut f = Function::new(&fd.name, params, ret);
        f.is_kernel = fd.is_kernel;
        f.src_line = fd.line;
        f.linkage = if fd.is_kernel {
            Linkage::External
        } else {
            Linkage::Internal
        };
        let fid = module.add_func(f);
        if sigs.insert(fd.name.clone(), fid).is_some() {
            return Err(CompileError {
                line: fd.line,
                msg: format!("duplicate function '{}'", fd.name),
            });
        }
    }
    // Bodies.
    for fd in &prog.funcs {
        let fid = sigs[&fd.name];
        let mut lower = FnLower {
            module: &mut module,
            opts,
            sigs: &sigs,
            global_map: &global_map,
            fid,
            fd,
            scopes: vec![],
            loop_stack: vec![],
            labels: HashMap::new(),
            terminated: false,
            cur: crate::ir::BlockId(0),
            local_counter: 0,
            cur_loc: SrcLoc {
                line: fd.line,
                col: 0,
            },
        };
        lower.run()?;
    }
    crate::ir::verify::verify_module(&module).map_err(|e| CompileError {
        line: 0,
        msg: format!("internal: lowered module failed verification: {e}"),
    })?;
    Ok(module)
}

fn const_eval(e: &Expr) -> Option<u32> {
    match e {
        Expr::Int(v) => Some(*v as i32 as u32),
        Expr::Float(v) => Some(v.to_bits()),
        Expr::Un(UnAst::Neg, inner) => match &**inner {
            Expr::Int(v) => Some((-(*v as i32)) as u32),
            Expr::Float(v) => Some((-*v).to_bits()),
            _ => None,
        },
        _ => None,
    }
}

struct FnLower<'a> {
    module: &'a mut Module,
    opts: &'a FrontendOptions,
    sigs: &'a HashMap<String, crate::ir::FuncId>,
    global_map: &'a HashMap<String, (GlobalId, VTy, bool)>,
    fid: crate::ir::FuncId,
    fd: &'a FuncDecl,
    scopes: Vec<HashMap<String, VarSlot>>,
    /// (continue target, break target)
    loop_stack: Vec<(crate::ir::BlockId, crate::ir::BlockId)>,
    labels: HashMap<String, crate::ir::BlockId>,
    terminated: bool,
    cur: crate::ir::BlockId,
    local_counter: u32,
    /// Source position of the statement being lowered; stamped onto every
    /// emitted instruction (the profiler's PC→source root).
    cur_loc: SrcLoc,
}

type LResult<T> = Result<T, CompileError>;

impl<'a> FnLower<'a> {
    fn f(&mut self) -> &mut Function {
        &mut self.module.funcs[self.fid.idx()]
    }

    fn err<T>(&self, line: u32, msg: impl Into<String>) -> LResult<T> {
        Err(CompileError {
            line,
            msg: msg.into(),
        })
    }

    fn emit(&mut self, kind: InstKind, ty: Type) -> Val {
        let cur = self.cur;
        let loc = self.cur_loc;
        let id = self.f().push_inst(cur, kind, ty);
        if loc.line != 0 {
            self.f().inst_mut(id).loc = Some(crate::ir::Loc {
                line: loc.line,
                col: loc.col,
            });
        }
        Val::Inst(id)
    }

    fn new_block(&mut self, name: &str) -> crate::ir::BlockId {
        self.f().add_block(name)
    }

    fn switch(&mut self, b: crate::ir::BlockId) {
        self.cur = b;
        self.terminated = false;
    }

    fn run(&mut self) -> LResult<()> {
        self.scopes.push(HashMap::new());
        self.cur = self.module.funcs[self.fid.idx()].entry;
        // Copy parameters into slots (C parameters are mutable lvalues).
        for (i, p) in self.fd.params.iter().enumerate() {
            let vty = if p.is_ptr {
                VTy::Ptr(space_of(p.space), p.ty)
            } else {
                VTy::of_spec(p.ty)
            };
            let slot = self.emit(InstKind::Alloca { size: 4 }, Type::Ptr(AddrSpace::Private));
            self.emit(
                InstKind::Store {
                    ptr: slot,
                    val: Val::Arg(i as u32),
                },
                Type::Void,
            );
            self.scopes.last_mut().unwrap().insert(
                p.name.clone(),
                VarSlot {
                    ptr: slot,
                    ty: vty,
                    is_array: false,
                    uniform: p.uniform,
                },
            );
        }
        // Pre-create label blocks.
        collect_labels(&self.fd.body, &mut |name| {
            if !self.labels.contains_key(name) {
                let b = self.module.funcs[self.fid.idx()].add_block(&format!("lbl.{name}"));
                self.labels.insert(name.to_string(), b);
            }
        });
        let body = self.fd.body.clone();
        self.stmts(&body)?;
        if !self.terminated {
            if self.module.funcs[self.fid.idx()].ret == Type::Void {
                self.emit(InstKind::Ret { val: None }, Type::Void);
            } else {
                // Implicit return 0 on fallthrough.
                let z = match self.module.funcs[self.fid.idx()].ret {
                    Type::F32 => Val::cf(0.0),
                    _ => Val::ci(0),
                };
                self.emit(InstKind::Ret { val: Some(z) }, Type::Void);
            }
        }
        self.module.funcs[self.fid.idx()].remove_unreachable();
        Ok(())
    }

    fn lookup(&self, name: &str) -> Option<VarSlot> {
        for sc in self.scopes.iter().rev() {
            if let Some(s) = sc.get(name) {
                return Some(*s);
            }
        }
        None
    }

    fn stmts(&mut self, list: &[Stmt]) -> LResult<()> {
        for s in list {
            self.stmt(s)?;
        }
        Ok(())
    }

    fn ensure_open(&mut self) {
        if self.terminated {
            let b = self.new_block("dead");
            self.switch(b);
        }
    }

    fn stmt(&mut self, s: &Stmt) -> LResult<()> {
        match s {
            Stmt::Block(list) => {
                self.scopes.push(HashMap::new());
                self.stmts(list)?;
                self.scopes.pop();
                Ok(())
            }
            Stmt::Decl {
                ty,
                space,
                is_ptr,
                name,
                dims,
                init,
                uniform,
                loc,
            } => self.decl(*ty, *space, *is_ptr, name, dims, init.as_ref(), *uniform, *loc),
            Stmt::Assign { lhs, op, rhs, loc } => self.assign(lhs, *op, rhs, *loc),
            Stmt::ExprStmt(e, loc) => {
                self.cur_loc = *loc;
                self.ensure_open();
                self.expr(e, loc.line)?;
                Ok(())
            }
            Stmt::Return(v, loc) => {
                self.cur_loc = *loc;
                self.ensure_open();
                let ret_ty = self.module.funcs[self.fid.idx()].ret;
                let val = match v {
                    Some(e) => {
                        let (val, vty) = self.expr(e, loc.line)?;
                        let want = match ret_ty {
                            Type::F32 => VTy::F32,
                            Type::I1 => VTy::Bool,
                            _ => VTy::I32,
                        };
                        Some(self.convert(val, vty, want))
                    }
                    None => None,
                };
                if ret_ty != Type::Void && val.is_none() {
                    return self.err(loc.line, "missing return value");
                }
                self.emit(InstKind::Ret { val }, Type::Void);
                self.terminated = true;
                Ok(())
            }
            Stmt::If {
                cond,
                then_s,
                else_s,
                loc,
            } => {
                self.cur_loc = *loc;
                self.ensure_open();
                let c = self.cond_value(cond, loc.line)?;
                let then_b = self.new_block("if.then");
                let else_b = self.new_block("if.else");
                let join = self.new_block("if.join");
                self.emit(
                    InstKind::CondBr {
                        cond: c,
                        t: then_b,
                        f: else_b,
                    },
                    Type::Void,
                );
                self.switch(then_b);
                self.scopes.push(HashMap::new());
                self.stmts(then_s)?;
                self.scopes.pop();
                if !self.terminated {
                    self.emit(InstKind::Br { target: join }, Type::Void);
                }
                self.switch(else_b);
                self.scopes.push(HashMap::new());
                self.stmts(else_s)?;
                self.scopes.pop();
                if !self.terminated {
                    self.emit(InstKind::Br { target: join }, Type::Void);
                }
                self.switch(join);
                Ok(())
            }
            Stmt::While { cond, body, loc } => {
                self.cur_loc = *loc;
                self.ensure_open();
                let head = self.new_block("wh.head");
                let body_b = self.new_block("wh.body");
                let exit = self.new_block("wh.exit");
                self.emit(InstKind::Br { target: head }, Type::Void);
                self.switch(head);
                let c = self.cond_value(cond, loc.line)?;
                self.emit(
                    InstKind::CondBr {
                        cond: c,
                        t: body_b,
                        f: exit,
                    },
                    Type::Void,
                );
                self.switch(body_b);
                self.loop_stack.push((head, exit));
                self.scopes.push(HashMap::new());
                self.stmts(body)?;
                self.scopes.pop();
                self.loop_stack.pop();
                if !self.terminated {
                    self.emit(InstKind::Br { target: head }, Type::Void);
                }
                self.switch(exit);
                Ok(())
            }
            Stmt::DoWhile { body, cond, loc } => {
                self.cur_loc = *loc;
                self.ensure_open();
                let body_b = self.new_block("do.body");
                let cond_b = self.new_block("do.cond");
                let exit = self.new_block("do.exit");
                self.emit(InstKind::Br { target: body_b }, Type::Void);
                self.switch(body_b);
                self.loop_stack.push((cond_b, exit));
                self.scopes.push(HashMap::new());
                self.stmts(body)?;
                self.scopes.pop();
                self.loop_stack.pop();
                if !self.terminated {
                    self.emit(InstKind::Br { target: cond_b }, Type::Void);
                }
                self.switch(cond_b);
                self.cur_loc = *loc;
                let c = self.cond_value(cond, loc.line)?;
                self.emit(
                    InstKind::CondBr {
                        cond: c,
                        t: body_b,
                        f: exit,
                    },
                    Type::Void,
                );
                self.switch(exit);
                Ok(())
            }
            Stmt::For {
                init,
                cond,
                step,
                body,
                loc,
            } => {
                self.cur_loc = *loc;
                self.ensure_open();
                self.scopes.push(HashMap::new());
                if let Some(i) = init {
                    self.stmt(i)?;
                }
                let head = self.new_block("for.head");
                let body_b = self.new_block("for.body");
                let step_b = self.new_block("for.step");
                let exit = self.new_block("for.exit");
                self.emit(InstKind::Br { target: head }, Type::Void);
                self.switch(head);
                self.cur_loc = *loc;
                let c = match cond {
                    Some(c) => self.cond_value(c, loc.line)?,
                    None => Val::cb(true),
                };
                self.emit(
                    InstKind::CondBr {
                        cond: c,
                        t: body_b,
                        f: exit,
                    },
                    Type::Void,
                );
                self.switch(body_b);
                self.loop_stack.push((step_b, exit));
                self.scopes.push(HashMap::new());
                self.stmts(body)?;
                self.scopes.pop();
                self.loop_stack.pop();
                if !self.terminated {
                    self.emit(InstKind::Br { target: step_b }, Type::Void);
                }
                self.switch(step_b);
                if let Some(st) = step {
                    self.stmt(st)?;
                }
                self.emit(InstKind::Br { target: head }, Type::Void);
                self.switch(exit);
                self.scopes.pop();
                Ok(())
            }
            Stmt::Break(loc) => {
                self.cur_loc = *loc;
                self.ensure_open();
                match self.loop_stack.last() {
                    Some(&(_, brk)) => {
                        self.emit(InstKind::Br { target: brk }, Type::Void);
                        self.terminated = true;
                        Ok(())
                    }
                    None => self.err(loc.line, "break outside loop"),
                }
            }
            Stmt::Continue(loc) => {
                self.cur_loc = *loc;
                self.ensure_open();
                match self.loop_stack.last() {
                    Some(&(cont, _)) => {
                        self.emit(InstKind::Br { target: cont }, Type::Void);
                        self.terminated = true;
                        Ok(())
                    }
                    None => self.err(loc.line, "continue outside loop"),
                }
            }
            Stmt::Goto(name, loc) => {
                self.cur_loc = *loc;
                self.ensure_open();
                match self.labels.get(name) {
                    Some(&b) => {
                        self.emit(InstKind::Br { target: b }, Type::Void);
                        self.terminated = true;
                        Ok(())
                    }
                    None => self.err(loc.line, format!("undefined label '{name}'")),
                }
            }
            Stmt::Label(name, _loc) => {
                let b = self.labels[name];
                if !self.terminated {
                    self.emit(InstKind::Br { target: b }, Type::Void);
                }
                self.switch(b);
                Ok(())
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn decl(
        &mut self,
        ty: TypeSpec,
        space: SpaceSpec,
        is_ptr: bool,
        name: &str,
        dims: &[u32],
        init: Option<&Expr>,
        uniform: bool,
        loc: SrcLoc,
    ) -> LResult<()> {
        self.cur_loc = loc;
        let line = loc.line;
        self.ensure_open();
        if ty == TypeSpec::Void && !is_ptr {
            return self.err(line, "cannot declare void variable");
        }
        let is_array = !dims.is_empty();
        let Some(elems) = checked_elems(dims) else {
            return self.err(line, format!("array '{name}' is too large"));
        };
        let (ptr, vty) = if is_array && matches!(space, SpaceSpec::Local) {
            // Shared/local arrays become per-workgroup memory carved out of
            // the function's local segment (paper §5.4 / Fig. 10).
            let offset = self.module.funcs[self.fid.idx()].local_mem_size;
            self.module.funcs[self.fid.idx()].local_mem_size = offset + elems * 4;
            self.local_counter += 1;
            let g = self.module.add_global(Global {
                name: format!("{}.{}", self.fd.name, name),
                space: AddrSpace::Local,
                size: elems * 4,
                align: 4,
                init: None,
            });
            (Val::G(g), VTy::Ptr(AddrSpace::Local, ty))
        } else if is_array {
            let a = self.emit(
                InstKind::Alloca { size: elems * 4 },
                Type::Ptr(AddrSpace::Private),
            );
            (a, VTy::Ptr(AddrSpace::Private, ty))
        } else {
            let a = self.emit(InstKind::Alloca { size: 4 }, Type::Ptr(AddrSpace::Private));
            let vty = if is_ptr {
                VTy::Ptr(space_of(space), ty)
            } else {
                VTy::of_spec(ty)
            };
            (a, vty)
        };
        if let Some(e) = init {
            if is_array {
                return self.err(line, "array initializers are not supported for locals");
            }
            let (v, vt) = self.expr(e, line)?;
            let v = self.convert(v, vt, vty);
            let v_ann = v;
            self.emit(InstKind::Store { ptr, val: v }, Type::Void);
            if uniform {
                if let Val::Inst(i) = v_ann {
                    self.f().inst_mut(i).uniform_ann = true;
                }
            }
        }
        self.scopes.last_mut().unwrap().insert(
            name.to_string(),
            VarSlot {
                ptr,
                ty: vty,
                is_array,
                uniform,
            },
        );
        Ok(())
    }

    fn assign(&mut self, lhs: &Expr, op: Option<BinAst>, rhs: &Expr, loc: SrcLoc) -> LResult<()> {
        self.cur_loc = loc;
        let line = loc.line;
        self.ensure_open();
        let (ptr, elem_ty, uniform) = self.lvalue(lhs, line)?;
        let (rv, rt) = self.expr(rhs, line)?;
        let value = match op {
            None => self.convert(rv, rt, elem_ty),
            Some(op) => {
                let cur = self.emit(InstKind::Load { ptr }, elem_ty.ir());
                let (res, resty) = self.binop(op, (cur, elem_ty), (rv, rt), line)?;
                self.convert(res, resty, elem_ty)
            }
        };
        if uniform {
            if let Val::Inst(i) = value {
                self.f().inst_mut(i).uniform_ann = true;
            }
        }
        self.emit(InstKind::Store { ptr, val: value }, Type::Void);
        Ok(())
    }

    /// Lower an lvalue to (pointer, element type, uniform-var flag).
    fn lvalue(&mut self, e: &Expr, line: u32) -> LResult<(Val, VTy, bool)> {
        match e {
            Expr::Ident(name) => {
                if let Some(slot) = self.lookup(name) {
                    if slot.is_array {
                        return self.err(line, format!("cannot assign to array '{name}'"));
                    }
                    Ok((slot.ptr, slot.ty, slot.uniform))
                } else if let Some(&(g, vty, is_arr)) = self.global_map.get(name) {
                    if is_arr {
                        return self.err(line, format!("cannot assign to array '{name}'"));
                    }
                    let elem = match vty {
                        VTy::Ptr(_, ts) => VTy::of_spec(ts),
                        t => t,
                    };
                    Ok((Val::G(g), elem, false))
                } else {
                    self.err(line, format!("unknown variable '{name}'"))
                }
            }
            Expr::Index(base, idx) => {
                let (bptr, bty) = self.pointer_value(base, line)?;
                let (iv, it) = self.expr(idx, line)?;
                let iv = self.convert(iv, it, VTy::I32);
                let elem = match bty {
                    VTy::Ptr(_, ts) => VTy::of_spec(ts),
                    _ => return self.err(line, "indexing a non-pointer"),
                };
                let ty = match bty {
                    VTy::Ptr(sp, _) => Type::Ptr(sp),
                    _ => unreachable!(),
                };
                let p = self.emit(
                    InstKind::Gep {
                        base: bptr,
                        index: iv,
                        scale: 4,
                        disp: 0,
                    },
                    ty,
                );
                Ok((p, elem, false))
            }
            Expr::Deref(inner) => {
                let (p, pty) = self.pointer_value(inner, line)?;
                let elem = match pty {
                    VTy::Ptr(_, ts) => VTy::of_spec(ts),
                    _ => return self.err(line, "dereferencing a non-pointer"),
                };
                Ok((p, elem, false))
            }
            _ => self.err(line, "expression is not assignable"),
        }
    }

    /// Evaluate an expression that must yield a pointer (array decay).
    fn pointer_value(&mut self, e: &Expr, line: u32) -> LResult<(Val, VTy)> {
        match e {
            Expr::Ident(name) => {
                if let Some(slot) = self.lookup(name) {
                    if slot.is_array {
                        return Ok((slot.ptr, slot.ty));
                    }
                    if let VTy::Ptr(..) = slot.ty {
                        let v = self.emit(InstKind::Load { ptr: slot.ptr }, slot.ty.ir());
                        return Ok((v, slot.ty));
                    }
                    self.err(line, format!("'{name}' is not a pointer"))
                } else if let Some(&(g, vty, _)) = self.global_map.get(name) {
                    Ok((Val::G(g), vty))
                } else {
                    self.err(line, format!("unknown variable '{name}'"))
                }
            }
            _ => {
                let (v, t) = self.expr(e, line)?;
                match t {
                    VTy::Ptr(..) => Ok((v, t)),
                    _ => self.err(line, "expected pointer-valued expression"),
                }
            }
        }
    }

    /// Convert value between arithmetic types.
    fn convert(&mut self, v: Val, from: VTy, to: VTy) -> Val {
        if from == to {
            return v;
        }
        match (from, to) {
            (VTy::Bool, VTy::I32) | (VTy::Bool, VTy::U32) => {
                self.emit(InstKind::Un { op: UnOp::ZExt, a: v }, Type::I32)
            }
            (VTy::I32, VTy::U32) | (VTy::U32, VTy::I32) => v,
            (VTy::I32, VTy::Bool) | (VTy::U32, VTy::Bool) => self.emit(
                InstKind::ICmp {
                    pred: ICmp::Ne,
                    a: v,
                    b: Val::ci(0),
                },
                Type::I1,
            ),
            (VTy::F32, VTy::Bool) => self.emit(
                InstKind::FCmp {
                    pred: FCmp::One,
                    a: v,
                    b: Val::cf(0.0),
                },
                Type::I1,
            ),
            (VTy::I32, VTy::F32) | (VTy::U32, VTy::F32) => {
                self.emit(InstKind::Un { op: UnOp::SiToFp, a: v }, Type::F32)
            }
            (VTy::Bool, VTy::F32) => {
                let i = self.emit(InstKind::Un { op: UnOp::ZExt, a: v }, Type::I32);
                self.emit(InstKind::Un { op: UnOp::SiToFp, a: i }, Type::F32)
            }
            (VTy::F32, VTy::I32) | (VTy::F32, VTy::U32) => {
                self.emit(InstKind::Un { op: UnOp::FpToSi, a: v }, Type::I32)
            }
            // Pointer conversions: bit-identical.
            _ => v,
        }
    }

    fn cond_value(&mut self, e: &Expr, line: u32) -> LResult<Val> {
        let (v, t) = self.expr(e, line)?;
        Ok(self.convert(v, t, VTy::Bool))
    }

    fn binop(
        &mut self,
        op: BinAst,
        (av, at): (Val, VTy),
        (bv, bt): (Val, VTy),
        line: u32,
    ) -> LResult<(Val, VTy)> {
        use BinAst::*;
        // Pointer arithmetic.
        if let VTy::Ptr(sp, ts) = at {
            if matches!(op, Add | Sub) && !matches!(bt, VTy::Ptr(..)) {
                let idx = self.convert(bv, bt, VTy::I32);
                let idx = if op == Sub {
                    self.emit(
                        InstKind::Bin {
                            op: BinOp::Sub,
                            a: Val::ci(0),
                            b: idx,
                        },
                        Type::I32,
                    )
                } else {
                    idx
                };
                let p = self.emit(
                    InstKind::Gep {
                        base: av,
                        index: idx,
                        scale: 4,
                        disp: 0,
                    },
                    Type::Ptr(sp),
                );
                return Ok((p, VTy::Ptr(sp, ts)));
            }
        }
        if matches!(op, LogAnd | LogOr) {
            // Handled in expr() (short-circuit); direct values here.
            let ab = self.convert(av, at, VTy::Bool);
            let bb = self.convert(bv, bt, VTy::Bool);
            let o = if op == LogAnd { BinOp::And } else { BinOp::Or };
            let r = self.emit(InstKind::Bin { op: o, a: ab, b: bb }, Type::I1);
            return Ok((r, VTy::Bool));
        }
        // Comparisons.
        if matches!(op, Eq | Ne | Lt | Le | Gt | Ge) {
            let fl = at == VTy::F32 || bt == VTy::F32;
            if fl {
                let a = self.convert(av, at, VTy::F32);
                let b = self.convert(bv, bt, VTy::F32);
                let pred = match op {
                    Eq => FCmp::Oeq,
                    Ne => FCmp::One,
                    Lt => FCmp::Olt,
                    Le => FCmp::Ole,
                    Gt => FCmp::Ogt,
                    Ge => FCmp::Oge,
                    _ => unreachable!(),
                };
                let r = self.emit(InstKind::FCmp { pred, a, b }, Type::I1);
                return Ok((r, VTy::Bool));
            }
            let unsigned = at == VTy::U32 || bt == VTy::U32 || matches!(at, VTy::Ptr(..));
            let a = self.convert(av, at, VTy::I32);
            let b = self.convert(bv, bt, VTy::I32);
            let pred = match (op, unsigned) {
                (Eq, _) => ICmp::Eq,
                (Ne, _) => ICmp::Ne,
                (Lt, false) => ICmp::Slt,
                (Le, false) => ICmp::Sle,
                (Gt, false) => ICmp::Sgt,
                (Ge, false) => ICmp::Sge,
                (Lt, true) => ICmp::Ult,
                (Ge, true) => ICmp::Uge,
                (Le, true) => {
                    // a <= b  <=>  !(b < a)
                    let c = self.emit(
                        InstKind::ICmp {
                            pred: ICmp::Ult,
                            a: b,
                            b: a,
                        },
                        Type::I1,
                    );
                    let r = self.emit(
                        InstKind::Bin {
                            op: BinOp::Xor,
                            a: c,
                            b: Val::cb(true),
                        },
                        Type::I1,
                    );
                    return Ok((r, VTy::Bool));
                }
                (Gt, true) => {
                    let r = self.emit(
                        InstKind::ICmp {
                            pred: ICmp::Ult,
                            a: b,
                            b: a,
                        },
                        Type::I1,
                    );
                    return Ok((r, VTy::Bool));
                }
                _ => unreachable!(),
            };
            let r = self.emit(InstKind::ICmp { pred, a, b }, Type::I1);
            return Ok((r, VTy::Bool));
        }
        // Arithmetic / bitwise.
        let fl = at == VTy::F32 || bt == VTy::F32;
        if fl {
            let a = self.convert(av, at, VTy::F32);
            let b = self.convert(bv, bt, VTy::F32);
            let o = match op {
                Add => BinOp::FAdd,
                Sub => BinOp::FSub,
                Mul => BinOp::FMul,
                Div => BinOp::FDiv,
                Rem => return self.err(line, "float remainder is not supported"),
                _ => return self.err(line, "bitwise operation on float"),
            };
            let r = self.emit(InstKind::Bin { op: o, a, b }, Type::F32);
            return Ok((r, VTy::F32));
        }
        let unsigned = at == VTy::U32 || bt == VTy::U32;
        let a = self.convert(av, at, VTy::I32);
        let b = self.convert(bv, bt, VTy::I32);
        let o = match (op, unsigned) {
            (Add, _) => BinOp::Add,
            (Sub, _) => BinOp::Sub,
            (Mul, _) => BinOp::Mul,
            (Div, false) => BinOp::SDiv,
            (Div, true) => BinOp::UDiv,
            (Rem, false) => BinOp::SRem,
            (Rem, true) => BinOp::URem,
            (And, _) => BinOp::And,
            (Or, _) => BinOp::Or,
            (Xor, _) => BinOp::Xor,
            (Shl, _) => BinOp::Shl,
            (Shr, false) => BinOp::AShr,
            (Shr, true) => BinOp::LShr,
            _ => unreachable!(),
        };
        let r = self.emit(InstKind::Bin { op: o, a, b }, Type::I32);
        Ok((r, if unsigned { VTy::U32 } else { VTy::I32 }))
    }

    fn expr(&mut self, e: &Expr, line: u32) -> LResult<(Val, VTy)> {
        match e {
            Expr::Int(v) => Ok((Val::ci(*v), VTy::I32)),
            Expr::Float(v) => Ok((Val::cf(*v), VTy::F32)),
            Expr::Ident(name) if name == "true" || name == "false" => {
                Ok((Val::cb(name == "true"), VTy::Bool))
            }
            Expr::Ident(name) => {
                if let Some(slot) = self.lookup(name) {
                    if slot.is_array {
                        return Ok((slot.ptr, slot.ty)); // decay
                    }
                    let v = self.emit(InstKind::Load { ptr: slot.ptr }, slot.ty.ir());
                    Ok((v, slot.ty))
                } else if let Some(&(g, vty, is_arr)) = self.global_map.get(name) {
                    if is_arr {
                        Ok((Val::G(g), vty))
                    } else {
                        let elem = match vty {
                            VTy::Ptr(_, ts) => VTy::of_spec(ts),
                            t => t,
                        };
                        let v = self.emit(InstKind::Load { ptr: Val::G(g) }, elem.ir());
                        Ok((v, elem))
                    }
                } else {
                    self.err(line, format!("unknown identifier '{name}'"))
                }
            }
            Expr::Member(base, field) => self.member(base, field, line),
            Expr::Index(..) | Expr::Deref(..) => {
                let (p, elem, _) = self.lvalue(e, line)?;
                let v = self.emit(InstKind::Load { ptr: p }, elem.ir());
                Ok((v, elem))
            }
            Expr::Un(op, inner) => {
                let (v, t) = self.expr(inner, line)?;
                match op {
                    UnAst::Neg => match t {
                        VTy::F32 => Ok((
                            self.emit(InstKind::Un { op: UnOp::FNeg, a: v }, Type::F32),
                            VTy::F32,
                        )),
                        _ => {
                            let v = self.convert(v, t, VTy::I32);
                            Ok((
                                self.emit(
                                    InstKind::Bin {
                                        op: BinOp::Sub,
                                        a: Val::ci(0),
                                        b: v,
                                    },
                                    Type::I32,
                                ),
                                VTy::I32,
                            ))
                        }
                    },
                    UnAst::Not => {
                        let b = self.convert(v, t, VTy::Bool);
                        Ok((
                            self.emit(
                                InstKind::Bin {
                                    op: BinOp::Xor,
                                    a: b,
                                    b: Val::cb(true),
                                },
                                Type::I1,
                            ),
                            VTy::Bool,
                        ))
                    }
                    UnAst::BitNot => {
                        let v = self.convert(v, t, VTy::I32);
                        Ok((
                            self.emit(InstKind::Un { op: UnOp::Not, a: v }, Type::I32),
                            VTy::I32,
                        ))
                    }
                }
            }
            Expr::Cast(ts, inner) => {
                let (v, t) = self.expr(inner, line)?;
                let to = VTy::of_spec(*ts);
                Ok((self.convert(v, t, to), to))
            }
            Expr::Bin(op, a, b) if matches!(op, BinAst::LogAnd | BinAst::LogOr) => {
                // Short-circuit via a temp slot diamond (SESE; pre-SSA).
                self.ensure_open();
                let slot = self.emit(InstKind::Alloca { size: 4 }, Type::Ptr(AddrSpace::Private));
                let av = self.cond_value(a, line)?;
                let is_and = *op == BinAst::LogAnd;
                self.emit(
                    InstKind::Store {
                        ptr: slot,
                        val: Val::cb(!is_and),
                    },
                    Type::Void,
                );
                let eval_b = self.new_block("sc.rhs");
                let join = self.new_block("sc.join");
                let (t, f) = if is_and { (eval_b, join) } else { (join, eval_b) };
                self.emit(InstKind::CondBr { cond: av, t, f }, Type::Void);
                self.switch(eval_b);
                let bv = self.cond_value(b, line)?;
                self.emit(InstKind::Store { ptr: slot, val: bv }, Type::Void);
                self.emit(InstKind::Br { target: join }, Type::Void);
                self.switch(join);
                let r = self.emit(InstKind::Load { ptr: slot }, Type::I1);
                Ok((r, VTy::Bool))
            }
            Expr::Bin(op, a, b) => {
                let av = self.expr(a, line)?;
                let bv = self.expr(b, line)?;
                self.binop(*op, av, bv, line)
            }
            Expr::Ternary(c, t, f) => {
                // C semantics: arms evaluate lazily — always lower through
                // control flow. The middle-end's select-formation pass
                // speculates eligible diamonds back into selects under
                // ZiCond (paper Fig. 5c / §5.3).
                {
                    // Lower with control flow through a temp slot.
                    self.ensure_open();
                    let slot =
                        self.emit(InstKind::Alloca { size: 4 }, Type::Ptr(AddrSpace::Private));
                    let cv = self.cond_value(c, line)?;
                    let then_b = self.new_block("sel.t");
                    let else_b = self.new_block("sel.f");
                    let join = self.new_block("sel.j");
                    self.emit(
                        InstKind::CondBr {
                            cond: cv,
                            t: then_b,
                            f: else_b,
                        },
                        Type::Void,
                    );
                    self.switch(then_b);
                    let (tv, tt) = self.expr(t, line)?;
                    self.emit(InstKind::Store { ptr: slot, val: tv }, Type::Void);
                    self.emit(InstKind::Br { target: join }, Type::Void);
                    self.switch(else_b);
                    let (fv, ft) = self.expr(f, line)?;
                    let fv = self.convert(fv, ft, tt);
                    self.emit(InstKind::Store { ptr: slot, val: fv }, Type::Void);
                    self.emit(InstKind::Br { target: join }, Type::Void);
                    self.switch(join);
                    let r = self.emit(InstKind::Load { ptr: slot }, tt.ir());
                    Ok((r, tt))
                }
            }
            Expr::Call(name, args) => self.call(name, args, line),
        }
    }

    fn member(&mut self, base: &Expr, field: &str, line: u32) -> LResult<(Val, VTy)> {
        let bname = match base {
            Expr::Ident(n) => n.as_str(),
            _ => return self.err(line, "no struct member access"),
        };
        let wi = match bname {
            "threadIdx" => WorkItem::LocalId,
            "blockIdx" => WorkItem::GroupId,
            "blockDim" => WorkItem::LocalSize,
            "gridDim" => WorkItem::NumGroups,
            _ => return self.err(line, format!("unknown member base '{bname}'")),
        };
        let dim = match field {
            "x" => 0,
            "y" => 1,
            "z" => 2,
            _ => return self.err(line, format!("unknown member '{field}'")),
        };
        let v = self.emit(
            InstKind::Intr {
                intr: Intr::WorkItem(wi),
                args: vec![Val::ci(dim)],
            },
            Type::I32,
        );
        Ok((v, VTy::I32))
    }

    fn call(&mut self, name: &str, args: &[Expr], line: u32) -> LResult<(Val, VTy)> {
        if let Some(b) = builtins::lookup(self.opts.dialect, name) {
            return self.builtin(b, args, line);
        }
        let Some(&fid) = self.sigs.get(name) else {
            return self.err(line, format!("unknown function '{name}'"));
        };
        let callee_params: Vec<Type> = self.module.func(fid).params.iter().map(|p| p.ty).collect();
        let ret = self.module.func(fid).ret;
        if callee_params.len() != args.len() {
            return self.err(
                line,
                format!(
                    "'{name}' expects {} args, got {}",
                    callee_params.len(),
                    args.len()
                ),
            );
        }
        let mut vargs = vec![];
        for (a, &want) in args.iter().zip(callee_params.iter()) {
            let (v, t) = self.expr(a, line)?;
            let wantv = match want {
                Type::F32 => VTy::F32,
                Type::I1 => VTy::Bool,
                Type::I32 => VTy::I32,
                Type::Ptr(sp) => VTy::Ptr(sp, TypeSpec::Int),
                Type::Void => VTy::I32,
            };
            let v = match (t, wantv) {
                (VTy::Ptr(..), VTy::Ptr(..)) => v,
                _ => self.convert(v, t, wantv),
            };
            vargs.push(v);
        }
        let v = self.emit(InstKind::Call { callee: fid, args: vargs }, ret);
        let vty = match ret {
            Type::F32 => VTy::F32,
            Type::I1 => VTy::Bool,
            _ => VTy::I32,
        };
        Ok((v, vty))
    }

    fn builtin(&mut self, b: Builtin, args: &[Expr], line: u32) -> LResult<(Val, VTy)> {
        let mut vals: Vec<(Val, VTy)> = vec![];
        for a in args {
            vals.push(self.expr(a, line)?);
        }
        let as_f = |s: &mut Self, i: usize, vals: &[(Val, VTy)]| {
            let (v, t) = vals[i];
            s.convert(v, t, VTy::F32)
        };
        let as_i = |s: &mut Self, i: usize, vals: &[(Val, VTy)]| {
            let (v, t) = vals[i];
            s.convert(v, t, VTy::I32)
        };
        match b {
            Builtin::WorkItem(wi) => {
                let d = match args.first() {
                    Some(Expr::Int(d)) => *d,
                    None => 0,
                    _ => return self.err(line, "work-item dimension must be a literal"),
                };
                let v = self.emit(
                    InstKind::Intr {
                        intr: Intr::WorkItem(wi),
                        args: vec![Val::ci(d)],
                    },
                    Type::I32,
                );
                Ok((v, VTy::U32))
            }
            Builtin::Barrier => {
                // Argument (CLK_LOCAL_MEM_FENCE) ignored.
                let v = self.emit(
                    InstKind::Intr {
                        intr: Intr::Barrier,
                        args: vec![],
                    },
                    Type::Void,
                );
                Ok((v, VTy::I32))
            }
            Builtin::Math1(op) => {
                let a = as_f(self, 0, &vals);
                Ok((self.emit(InstKind::Un { op, a }, Type::F32), VTy::F32))
            }
            Builtin::MinF | Builtin::MaxF => {
                let a = as_f(self, 0, &vals);
                let bb = as_f(self, 1, &vals);
                let op = if matches!(b, Builtin::MinF) {
                    BinOp::FMin
                } else {
                    BinOp::FMax
                };
                Ok((self.emit(InstKind::Bin { op, a, b: bb }, Type::F32), VTy::F32))
            }
            Builtin::MinI | Builtin::MaxI => {
                // Polymorphic min/max: float if either arg is float.
                if vals.iter().any(|(_, t)| *t == VTy::F32) {
                    let a = as_f(self, 0, &vals);
                    let bb = as_f(self, 1, &vals);
                    let op = if matches!(b, Builtin::MinI) {
                        BinOp::FMin
                    } else {
                        BinOp::FMax
                    };
                    return Ok((
                        self.emit(InstKind::Bin { op, a, b: bb }, Type::F32),
                        VTy::F32,
                    ));
                }
                let a = as_i(self, 0, &vals);
                let bb = as_i(self, 1, &vals);
                let op = if matches!(b, Builtin::MinI) {
                    BinOp::SMin
                } else {
                    BinOp::SMax
                };
                Ok((self.emit(InstKind::Bin { op, a, b: bb }, Type::I32), VTy::I32))
            }
            Builtin::AbsI => {
                let a = as_i(self, 0, &vals);
                let n = self.emit(
                    InstKind::Bin {
                        op: BinOp::Sub,
                        a: Val::ci(0),
                        b: a,
                    },
                    Type::I32,
                );
                Ok((
                    self.emit(
                        InstKind::Bin {
                            op: BinOp::SMax,
                            a,
                            b: n,
                        },
                        Type::I32,
                    ),
                    VTy::I32,
                ))
            }
            Builtin::Pow => {
                // pow(a, b) = exp(b * log(a))
                let a = as_f(self, 0, &vals);
                let bb = as_f(self, 1, &vals);
                let l = self.emit(InstKind::Un { op: UnOp::FLog, a }, Type::F32);
                let m = self.emit(
                    InstKind::Bin {
                        op: BinOp::FMul,
                        a: bb,
                        b: l,
                    },
                    Type::F32,
                );
                Ok((
                    self.emit(InstKind::Un { op: UnOp::FExp, a: m }, Type::F32),
                    VTy::F32,
                ))
            }
            Builtin::Rsqrt => {
                let a = as_f(self, 0, &vals);
                let s = self.emit(InstKind::Un { op: UnOp::FSqrt, a }, Type::F32);
                Ok((
                    self.emit(
                        InstKind::Bin {
                            op: BinOp::FDiv,
                            a: Val::cf(1.0),
                            b: s,
                        },
                        Type::F32,
                    ),
                    VTy::F32,
                ))
            }
            Builtin::Mad => {
                let a = as_f(self, 0, &vals);
                let bb = as_f(self, 1, &vals);
                let c = as_f(self, 2, &vals);
                let m = self.emit(
                    InstKind::Bin {
                        op: BinOp::FMul,
                        a,
                        b: bb,
                    },
                    Type::F32,
                );
                Ok((
                    self.emit(
                        InstKind::Bin {
                            op: BinOp::FAdd,
                            a: m,
                            b: c,
                        },
                        Type::F32,
                    ),
                    VTy::F32,
                ))
            }
            Builtin::Atomic(op) => {
                let (p, pt) = vals[0];
                if !matches!(pt, VTy::Ptr(..)) {
                    return self.err(line, "atomic pointer argument expected");
                }
                let v = as_i(self, 1, &vals);
                let r = self.emit(
                    InstKind::Intr {
                        intr: Intr::Atomic(op),
                        args: vec![p, v],
                    },
                    Type::I32,
                );
                Ok((r, VTy::I32))
            }
            Builtin::AtomicSub => {
                let (p, _) = vals[0];
                let v = as_i(self, 1, &vals);
                let n = self.emit(
                    InstKind::Bin {
                        op: BinOp::Sub,
                        a: Val::ci(0),
                        b: v,
                    },
                    Type::I32,
                );
                let r = self.emit(
                    InstKind::Intr {
                        intr: Intr::Atomic(AtomOp::Add),
                        args: vec![p, n],
                    },
                    Type::I32,
                );
                Ok((r, VTy::I32))
            }
            Builtin::AtomicCas => {
                let (p, _) = vals[0];
                let cmp = as_i(self, 1, &vals);
                let nv = as_i(self, 2, &vals);
                let r = self.emit(
                    InstKind::Intr {
                        intr: Intr::AtomicCas,
                        args: vec![p, cmp, nv],
                    },
                    Type::I32,
                );
                Ok((r, VTy::I32))
            }
            Builtin::Shfl | Builtin::ShflSync => {
                // (__shfl_sync has a leading mask arg.)
                let off = if matches!(b, Builtin::ShflSync) { 1 } else { 0 };
                let (v, vt) = vals[off];
                let lane = as_i(self, off + 1, &vals);
                let is_float = vt == VTy::F32;
                let vi = if is_float {
                    self.emit(InstKind::Un { op: UnOp::FToBits, a: v }, Type::I32)
                } else {
                    self.convert(v, vt, VTy::I32)
                };
                let r = if self.opts.warp_hw {
                    self.emit(
                        InstKind::Intr {
                            intr: Intr::Shfl,
                            args: vec![vi, lane],
                        },
                        Type::I32,
                    )
                } else {
                    let h = builtins::ensure_sw_helper(self.module, "shfl");
                    self.emit(
                        InstKind::Call {
                            callee: h,
                            args: vec![vi, lane],
                        },
                        Type::I32,
                    )
                };
                if is_float {
                    Ok((
                        self.emit(InstKind::Un { op: UnOp::BitsToF, a: r }, Type::F32),
                        VTy::F32,
                    ))
                } else {
                    Ok((r, VTy::I32))
                }
            }
            Builtin::VoteAll | Builtin::VoteAny | Builtin::Ballot => {
                let off = vals.len() - 1; // _sync variants: predicate is last
                let (pv, pt) = vals[off];
                let p = self.convert(pv, pt, VTy::Bool);
                if self.opts.warp_hw {
                    let intr = match b {
                        Builtin::VoteAll => Intr::VoteAll,
                        Builtin::VoteAny => Intr::VoteAny,
                        _ => Intr::Ballot,
                    };
                    let ty = if matches!(b, Builtin::Ballot) {
                        Type::I32
                    } else {
                        Type::I1
                    };
                    let r = self.emit(
                        InstKind::Intr {
                            intr,
                            args: vec![p],
                        },
                        ty,
                    );
                    Ok((
                        r,
                        if matches!(b, Builtin::Ballot) {
                            VTy::U32
                        } else {
                            VTy::Bool
                        },
                    ))
                } else {
                    let name = match b {
                        Builtin::VoteAll => "vote_all",
                        Builtin::VoteAny => "vote_any",
                        _ => "ballot",
                    };
                    let h = builtins::ensure_sw_helper(self.module, name);
                    let pz = self.emit(InstKind::Un { op: UnOp::ZExt, a: p }, Type::I32);
                    let r = self.emit(
                        InstKind::Call {
                            callee: h,
                            args: vec![pz],
                        },
                        Type::I32,
                    );
                    if matches!(b, Builtin::Ballot) {
                        Ok((r, VTy::U32))
                    } else {
                        let rb = self.emit(
                            InstKind::ICmp {
                                pred: ICmp::Ne,
                                a: r,
                                b: Val::ci(0),
                            },
                            Type::I1,
                        );
                        Ok((rb, VTy::Bool))
                    }
                }
            }
            Builtin::LaneId => {
                let v = self.emit(
                    InstKind::Intr {
                        intr: Intr::Csr(crate::ir::Csr::LaneId),
                        args: vec![],
                    },
                    Type::I32,
                );
                Ok((v, VTy::U32))
            }
            Builtin::PrintInt | Builtin::PrintFloat => {
                let intr = if matches!(b, Builtin::PrintInt) {
                    Intr::PrintI
                } else {
                    Intr::PrintF
                };
                let v = if matches!(b, Builtin::PrintInt) {
                    as_i(self, 0, &vals)
                } else {
                    as_f(self, 0, &vals)
                };
                let r = self.emit(
                    InstKind::Intr {
                        intr,
                        args: vec![v],
                    },
                    Type::Void,
                );
                Ok((r, VTy::I32))
            }
        }
    }
}


fn collect_labels(stmts: &[Stmt], f: &mut impl FnMut(&str)) {
    for s in stmts {
        match s {
            Stmt::Label(n, _) => f(n),
            Stmt::Block(b) => collect_labels(b, f),
            Stmt::If { then_s, else_s, .. } => {
                collect_labels(then_s, f);
                collect_labels(else_s, f);
            }
            Stmt::While { body, .. }
            | Stmt::DoWhile { body, .. }
            | Stmt::For { body, .. } => collect_labels(body, f),
            _ => {}
        }
    }
}
