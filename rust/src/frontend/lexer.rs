//! Lexer for VCL, the OpenCL-C / CUDA-C kernel dialect accepted by the
//! VOLT front-end (paper §4.2).

#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    Ident(String),
    Int(i64),
    Float(f32),
    // punctuation / operators
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Comma,
    Semi,
    Colon,
    Question,
    Dot,
    Assign,
    PlusAssign,
    MinusAssign,
    StarAssign,
    SlashAssign,
    PercentAssign,
    AmpAssign,
    PipeAssign,
    CaretAssign,
    ShlAssign,
    ShrAssign,
    PlusPlus,
    MinusMinus,
    Plus,
    Minus,
    Star,
    Slash,
    Percent,
    Amp,
    Pipe,
    Caret,
    Tilde,
    Not,
    AndAnd,
    OrOr,
    Shl,
    Shr,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    Eof,
}

#[derive(Clone, Debug)]
pub struct Token {
    pub tok: Tok,
    pub line: u32,
    /// 1-based column of the token's first character.
    pub col: u32,
}

#[derive(Debug)]
pub struct LexError {
    pub line: u32,
    pub msg: String,
}

pub fn lex(src: &str) -> Result<Vec<Token>, LexError> {
    let mut out = vec![];
    let b: Vec<char> = src.chars().collect();
    let mut i = 0;
    let mut line: u32 = 1;
    // Char index where the current line starts (for 1-based columns).
    let mut line_start: usize = 0;
    let n = b.len();
    while i < n {
        let c = b[i];
        let col = (i - line_start) as u32 + 1;
        match c {
            '\n' => {
                line += 1;
                i += 1;
                line_start = i;
            }
            ' ' | '\t' | '\r' => i += 1,
            '/' if i + 1 < n && b[i + 1] == '/' => {
                while i < n && b[i] != '\n' {
                    i += 1;
                }
            }
            '/' if i + 1 < n && b[i + 1] == '*' => {
                i += 2;
                while i + 1 < n && !(b[i] == '*' && b[i + 1] == '/') {
                    if b[i] == '\n' {
                        line += 1;
                        line_start = i + 1;
                    }
                    i += 1;
                }
                i += 2;
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let s = i;
                while i < n && (b[i].is_ascii_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                out.push(Token {
                    tok: Tok::Ident(b[s..i].iter().collect()),
                    line,
                    col,
                });
            }
            c if c.is_ascii_digit() => {
                let s = i;
                let mut is_float = false;
                if c == '0' && i + 1 < n && (b[i + 1] == 'x' || b[i + 1] == 'X') {
                    i += 2;
                    while i < n && b[i].is_ascii_hexdigit() {
                        i += 1;
                    }
                    let text: String = b[s + 2..i].iter().collect();
                    let v = i64::from_str_radix(&text, 16).map_err(|_| LexError {
                        line,
                        msg: format!("bad hex literal {text}"),
                    })?;
                    out.push(Token {
                        tok: Tok::Int(v),
                        line,
                        col,
                    });
                    continue;
                }
                while i < n && b[i].is_ascii_digit() {
                    i += 1;
                }
                if i < n && b[i] == '.' {
                    is_float = true;
                    i += 1;
                    while i < n && b[i].is_ascii_digit() {
                        i += 1;
                    }
                }
                if i < n && (b[i] == 'e' || b[i] == 'E') {
                    is_float = true;
                    i += 1;
                    if i < n && (b[i] == '+' || b[i] == '-') {
                        i += 1;
                    }
                    while i < n && b[i].is_ascii_digit() {
                        i += 1;
                    }
                }
                let text: String = b[s..i].iter().collect();
                if i < n && (b[i] == 'f' || b[i] == 'F') {
                    is_float = true;
                    i += 1;
                }
                if is_float {
                    let v: f32 = text.parse().map_err(|_| LexError {
                        line,
                        msg: format!("bad float literal {text}"),
                    })?;
                    out.push(Token {
                        tok: Tok::Float(v),
                        line,
                        col,
                    });
                } else {
                    // unsigned suffix (1u / 1U) — type is tracked by decls.
                    if i < n && (b[i] == 'u' || b[i] == 'U') {
                        i += 1;
                    }
                    let v: i64 = text.parse().map_err(|_| LexError {
                        line,
                        msg: format!("bad int literal {text}"),
                    })?;
                    out.push(Token {
                        tok: Tok::Int(v),
                        line,
                        col,
                    });
                }
            }
            _ => {
                let two = |a: char, b2: char, i: usize, b: &[char]| -> bool {
                    b[i] == a && i + 1 < b.len() && b[i + 1] == b2
                };
                let three = |a: char, b2: char, c2: char, i: usize, b: &[char]| -> bool {
                    b[i] == a && i + 2 < b.len() && b[i + 1] == b2 && b[i + 2] == c2
                };
                let (tok, len) = if three('<', '<', '=', i, &b) {
                    (Tok::ShlAssign, 3)
                } else if three('>', '>', '=', i, &b) {
                    (Tok::ShrAssign, 3)
                } else if two('+', '=', i, &b) {
                    (Tok::PlusAssign, 2)
                } else if two('-', '=', i, &b) {
                    (Tok::MinusAssign, 2)
                } else if two('*', '=', i, &b) {
                    (Tok::StarAssign, 2)
                } else if two('/', '=', i, &b) {
                    (Tok::SlashAssign, 2)
                } else if two('%', '=', i, &b) {
                    (Tok::PercentAssign, 2)
                } else if two('&', '=', i, &b) {
                    (Tok::AmpAssign, 2)
                } else if two('|', '=', i, &b) {
                    (Tok::PipeAssign, 2)
                } else if two('^', '=', i, &b) {
                    (Tok::CaretAssign, 2)
                } else if two('+', '+', i, &b) {
                    (Tok::PlusPlus, 2)
                } else if two('-', '-', i, &b) {
                    (Tok::MinusMinus, 2)
                } else if two('&', '&', i, &b) {
                    (Tok::AndAnd, 2)
                } else if two('|', '|', i, &b) {
                    (Tok::OrOr, 2)
                } else if two('<', '<', i, &b) {
                    (Tok::Shl, 2)
                } else if two('>', '>', i, &b) {
                    (Tok::Shr, 2)
                } else if two('=', '=', i, &b) {
                    (Tok::Eq, 2)
                } else if two('!', '=', i, &b) {
                    (Tok::Ne, 2)
                } else if two('<', '=', i, &b) {
                    (Tok::Le, 2)
                } else if two('>', '=', i, &b) {
                    (Tok::Ge, 2)
                } else {
                    let t = match c {
                        '(' => Tok::LParen,
                        ')' => Tok::RParen,
                        '{' => Tok::LBrace,
                        '}' => Tok::RBrace,
                        '[' => Tok::LBracket,
                        ']' => Tok::RBracket,
                        ',' => Tok::Comma,
                        ';' => Tok::Semi,
                        ':' => Tok::Colon,
                        '?' => Tok::Question,
                        '.' => Tok::Dot,
                        '=' => Tok::Assign,
                        '+' => Tok::Plus,
                        '-' => Tok::Minus,
                        '*' => Tok::Star,
                        '/' => Tok::Slash,
                        '%' => Tok::Percent,
                        '&' => Tok::Amp,
                        '|' => Tok::Pipe,
                        '^' => Tok::Caret,
                        '~' => Tok::Tilde,
                        '!' => Tok::Not,
                        '<' => Tok::Lt,
                        '>' => Tok::Gt,
                        _ => {
                            return Err(LexError {
                                line,
                                msg: format!("unexpected character '{c}'"),
                            })
                        }
                    };
                    (t, 1)
                };
                out.push(Token { tok, line, col });
                i += len;
            }
        }
    }
    out.push(Token {
        tok: Tok::Eof,
        line,
        col: (i - line_start) as u32 + 1,
    });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_kernel_source() {
        let toks = lex("kernel void f(global float* x) { x[0] = 1.5f + 2; // c\n }").unwrap();
        assert!(toks.iter().any(|t| t.tok == Tok::Ident("kernel".into())));
        assert!(toks.iter().any(|t| t.tok == Tok::Float(1.5)));
        assert!(toks.iter().any(|t| t.tok == Tok::Int(2)));
        assert_eq!(toks.last().unwrap().tok, Tok::Eof);
    }

    #[test]
    fn lexes_operators() {
        let toks = lex("a += b << 2; c = a && !d || e >= 0x1F;").unwrap();
        let kinds: Vec<&Tok> = toks.iter().map(|t| &t.tok).collect();
        assert!(kinds.contains(&&Tok::PlusAssign));
        assert!(kinds.contains(&&Tok::Shl));
        assert!(kinds.contains(&&Tok::AndAnd));
        assert!(kinds.contains(&&Tok::OrOr));
        assert!(kinds.contains(&&Tok::Ge));
        assert!(kinds.contains(&&Tok::Int(0x1F)));
    }

    #[test]
    fn tracks_lines_and_block_comments() {
        let toks = lex("a\n/* x\ny */ b").unwrap();
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 3);
    }

    #[test]
    fn tracks_columns() {
        let toks = lex("ab + c\n  xy = 3").unwrap();
        // "ab" col 1, "+" col 4, "c" col 6.
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        assert_eq!((toks[1].line, toks[1].col), (1, 4));
        assert_eq!((toks[2].line, toks[2].col), (1, 6));
        // Second line: "xy" at col 3 (two leading spaces).
        assert_eq!((toks[3].line, toks[3].col), (2, 3));
        assert_eq!(toks[4].tok, Tok::Assign);
        assert_eq!(toks[4].col, 6);
    }

    #[test]
    fn rejects_bad_char() {
        assert!(lex("a @ b").is_err());
    }
}
