//! Abstract syntax tree for the VCL kernel language (OpenCL-C / CUDA-C
//! subset, paper §4.2).

/// Source position of a statement: 1-based (line, col) of its first
/// token. Lowering stamps it onto every IR instruction the statement
/// produces ([`crate::ir::Loc`]) — the root of the profiler's PC→source
/// mapping.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct SrcLoc {
    pub line: u32,
    pub col: u32,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TypeSpec {
    Void,
    Int,
    Uint,
    Float,
    Bool,
}

/// Address-space qualifier on pointers / declarations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpaceSpec {
    Default,
    Global,
    Local,
    Constant,
    Private,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    Int(i64),
    Float(f32),
    Ident(String),
    /// `base.member` — used for CUDA threadIdx.x etc.
    Member(Box<Expr>, String),
    Index(Box<Expr>, Box<Expr>),
    Call(String, Vec<Expr>),
    Un(UnAst, Box<Expr>),
    Bin(BinAst, Box<Expr>, Box<Expr>),
    Ternary(Box<Expr>, Box<Expr>, Box<Expr>),
    Cast(TypeSpec, Box<Expr>),
    /// `*p`
    Deref(Box<Expr>),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnAst {
    Neg,
    Not,
    BitNot,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinAst {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    And,
    Or,
    Xor,
    Shl,
    Shr,
    LogAnd,
    LogOr,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Stmt {
    Decl {
        ty: TypeSpec,
        space: SpaceSpec,
        is_ptr: bool,
        name: String,
        /// Array dimensions (product = element count); empty = scalar.
        dims: Vec<u32>,
        init: Option<Expr>,
        uniform: bool,
        loc: SrcLoc,
    },
    /// `lhs op= rhs` (op None = plain assignment).
    Assign {
        lhs: Expr,
        op: Option<BinAst>,
        rhs: Expr,
        loc: SrcLoc,
    },
    If {
        cond: Expr,
        then_s: Vec<Stmt>,
        else_s: Vec<Stmt>,
        loc: SrcLoc,
    },
    While {
        cond: Expr,
        body: Vec<Stmt>,
        loc: SrcLoc,
    },
    DoWhile {
        body: Vec<Stmt>,
        cond: Expr,
        loc: SrcLoc,
    },
    For {
        init: Option<Box<Stmt>>,
        cond: Option<Expr>,
        step: Option<Box<Stmt>>,
        body: Vec<Stmt>,
        loc: SrcLoc,
    },
    Break(SrcLoc),
    Continue(SrcLoc),
    Return(Option<Expr>, SrcLoc),
    ExprStmt(Expr, SrcLoc),
    Block(Vec<Stmt>),
    Goto(String, SrcLoc),
    Label(String, SrcLoc),
}

#[derive(Clone, Debug)]
pub struct ParamDecl {
    pub name: String,
    pub ty: TypeSpec,
    pub is_ptr: bool,
    pub space: SpaceSpec,
    pub uniform: bool,
}

#[derive(Clone, Debug)]
pub struct FuncDecl {
    pub name: String,
    pub ret: TypeSpec,
    pub params: Vec<ParamDecl>,
    pub body: Vec<Stmt>,
    pub is_kernel: bool,
    pub line: u32,
}

/// Module-scope variable (e.g. `__constant float lut[4] = {…};` or
/// `__device__ int counter;`).
#[derive(Clone, Debug)]
pub struct GlobalDecl {
    pub name: String,
    pub ty: TypeSpec,
    pub space: SpaceSpec,
    pub dims: Vec<u32>,
    pub init: Option<Vec<Expr>>,
    pub line: u32,
}

#[derive(Clone, Debug, Default)]
pub struct Program {
    pub funcs: Vec<FuncDecl>,
    pub globals: Vec<GlobalDecl>,
}
