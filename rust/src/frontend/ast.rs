//! Abstract syntax tree for the VCL kernel language (OpenCL-C / CUDA-C
//! subset, paper §4.2).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TypeSpec {
    Void,
    Int,
    Uint,
    Float,
    Bool,
}

/// Address-space qualifier on pointers / declarations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpaceSpec {
    Default,
    Global,
    Local,
    Constant,
    Private,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    Int(i64),
    Float(f32),
    Ident(String),
    /// `base.member` — used for CUDA threadIdx.x etc.
    Member(Box<Expr>, String),
    Index(Box<Expr>, Box<Expr>),
    Call(String, Vec<Expr>),
    Un(UnAst, Box<Expr>),
    Bin(BinAst, Box<Expr>, Box<Expr>),
    Ternary(Box<Expr>, Box<Expr>, Box<Expr>),
    Cast(TypeSpec, Box<Expr>),
    /// `*p`
    Deref(Box<Expr>),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnAst {
    Neg,
    Not,
    BitNot,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinAst {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    And,
    Or,
    Xor,
    Shl,
    Shr,
    LogAnd,
    LogOr,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Stmt {
    Decl {
        ty: TypeSpec,
        space: SpaceSpec,
        is_ptr: bool,
        name: String,
        /// Array dimensions (product = element count); empty = scalar.
        dims: Vec<u32>,
        init: Option<Expr>,
        uniform: bool,
        line: u32,
    },
    /// `lhs op= rhs` (op None = plain assignment).
    Assign {
        lhs: Expr,
        op: Option<BinAst>,
        rhs: Expr,
        line: u32,
    },
    If {
        cond: Expr,
        then_s: Vec<Stmt>,
        else_s: Vec<Stmt>,
        line: u32,
    },
    While {
        cond: Expr,
        body: Vec<Stmt>,
        line: u32,
    },
    DoWhile {
        body: Vec<Stmt>,
        cond: Expr,
        line: u32,
    },
    For {
        init: Option<Box<Stmt>>,
        cond: Option<Expr>,
        step: Option<Box<Stmt>>,
        body: Vec<Stmt>,
        line: u32,
    },
    Break(u32),
    Continue(u32),
    Return(Option<Expr>, u32),
    ExprStmt(Expr, u32),
    Block(Vec<Stmt>),
    Goto(String, u32),
    Label(String, u32),
}

#[derive(Clone, Debug)]
pub struct ParamDecl {
    pub name: String,
    pub ty: TypeSpec,
    pub is_ptr: bool,
    pub space: SpaceSpec,
    pub uniform: bool,
}

#[derive(Clone, Debug)]
pub struct FuncDecl {
    pub name: String,
    pub ret: TypeSpec,
    pub params: Vec<ParamDecl>,
    pub body: Vec<Stmt>,
    pub is_kernel: bool,
    pub line: u32,
}

/// Module-scope variable (e.g. `__constant float lut[4] = {…};` or
/// `__device__ int counter;`).
#[derive(Clone, Debug)]
pub struct GlobalDecl {
    pub name: String,
    pub ty: TypeSpec,
    pub space: SpaceSpec,
    pub dims: Vec<u32>,
    pub init: Option<Vec<Expr>>,
    pub line: u32,
}

#[derive(Clone, Debug, Default)]
pub struct Program {
    pub funcs: Vec<FuncDecl>,
    pub globals: Vec<GlobalDecl>,
}
