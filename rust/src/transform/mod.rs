//! Middle-end transformations (paper §4.3.2 / §4.3.3) and the pass
//! manager that sequences them into the VOLT optimization ladder.

pub mod divergence_insert;
pub mod gvn;
pub mod inline;
pub mod licm;
pub mod mem2reg;
pub mod pass;
pub mod reconstruct;
pub mod simplify;
pub mod strength;
pub mod structurize;

pub use pass::{
    run_middle_end, run_middle_end_with, run_middle_end_with_threads, MiddleEndReport, OptConfig,
    OptLevel,
};
