//! Promote single-word allocas to SSA registers (classic Cytron et al.
//! iterated-dominance-frontier phi placement + dominator-tree renaming).
//!
//! The VOLT front-end lowers every named local through an alloca so that
//! early CFG surgery (structurization / reconstruction, which run before
//! SSA construction) never has to repair cross-block SSA uses; this pass
//! then builds the SSA form the uniformity analysis and divergence
//! insertion operate on.

use crate::ir::*;
use std::collections::{HashMap, HashSet};

/// Is this alloca promotable: 4 bytes, address used only directly by
/// loads/stores (no GEP, no escape)?
fn promotable(f: &Function, a: InstId) -> bool {
    match f.inst(a).kind {
        InstKind::Alloca { size } if size == 4 => {}
        _ => return false,
    }
    for inst in f.insts.iter().filter(|i| !i.dead) {
        match &inst.kind {
            InstKind::Load { ptr } => {
                if *ptr == Val::Inst(a) {
                    continue;
                }
            }
            InstKind::Store { ptr, val } => {
                if *val == Val::Inst(a) {
                    return false; // address stored = escape
                }
                if *ptr == Val::Inst(a) {
                    continue;
                }
            }
            _ => {}
        }
        if inst.kind.operands().contains(&Val::Inst(a))
            && !matches!(inst.kind, InstKind::Load { .. } | InstKind::Store { .. })
        {
            return false;
        }
    }
    true
}

/// Infer the value type stored in the slot (from loads; default i32).
fn slot_type(f: &Function, a: InstId) -> Type {
    for inst in f.insts.iter().filter(|i| !i.dead) {
        if let InstKind::Load { ptr } = &inst.kind {
            if *ptr == Val::Inst(a) {
                return inst.ty;
            }
        }
        if let InstKind::Store { ptr, val } = &inst.kind {
            if *ptr == Val::Inst(a) {
                return f.val_type(*val);
            }
        }
    }
    Type::I32
}

pub fn run(f: &mut Function) -> usize {
    f.remove_unreachable();
    let allocas: Vec<InstId> = (0..f.insts.len() as u32)
        .map(InstId)
        .filter(|&i| {
            !f.insts[i.idx()].dead
                && matches!(f.inst(i).kind, InstKind::Alloca { .. })
                && promotable(f, i)
        })
        .collect();
    if allocas.is_empty() {
        return 0;
    }
    let dom = f.dom_tree();
    let df = dom.frontiers(f);
    let types: HashMap<InstId, Type> = allocas.iter().map(|&a| (a, slot_type(f, a))).collect();

    // Phi placement: iterated dominance frontier of store blocks.
    // phi_map: (block, alloca) -> phi inst id
    let mut phi_map: HashMap<(BlockId, InstId), InstId> = HashMap::new();
    for &a in &allocas {
        let mut def_blocks: HashSet<BlockId> = HashSet::new();
        for inst in f.insts.iter().filter(|i| !i.dead) {
            if let InstKind::Store { ptr, .. } = &inst.kind {
                if *ptr == Val::Inst(a) {
                    def_blocks.insert(inst.block);
                }
            }
        }
        let mut work: Vec<BlockId> = def_blocks.iter().copied().collect();
        let mut has_phi: HashSet<BlockId> = HashSet::new();
        while let Some(b) = work.pop() {
            for &y in &df[b.idx()] {
                if has_phi.insert(y) {
                    let phi = f.insert_inst(y, 0, InstKind::Phi { incs: vec![] }, types[&a]);
                    phi_map.insert((y, a), phi);
                    if !def_blocks.contains(&y) {
                        work.push(y);
                    }
                }
            }
        }
    }

    // Renaming via dominator-tree DFS.
    let children = dom.children();
    let mut stacks: HashMap<InstId, Vec<Val>> = allocas.iter().map(|&a| (a, vec![])).collect();
    let alloca_set: HashSet<InstId> = allocas.iter().copied().collect();
    // Replacements collected and applied inline during the walk.
    struct Walker<'a> {
        f: &'a mut Function,
        alloca_set: &'a HashSet<InstId>,
        types: &'a HashMap<InstId, Type>,
        phi_map: &'a HashMap<(BlockId, InstId), InstId>,
        phi_owner: HashMap<InstId, InstId>, // phi -> alloca
        children: &'a Vec<Vec<BlockId>>,
        removed: Vec<InstId>,
    }
    let phi_owner: HashMap<InstId, InstId> =
        phi_map.iter().map(|((_, a), &p)| (p, *a)).collect();
    impl<'a> Walker<'a> {
        fn cur(&self, stacks: &HashMap<InstId, Vec<Val>>, a: InstId) -> Val {
            stacks[&a].last().copied().unwrap_or(match self.types[&a] {
                Type::F32 => Val::F(0),
                Type::I1 => Val::cb(false),
                _ => Val::ci(0),
            })
        }
        fn walk(&mut self, b: BlockId, stacks: &mut HashMap<InstId, Vec<Val>>) {
            let mut pushed: Vec<InstId> = vec![];
            let insts = self.f.blocks[b.idx()].insts.clone();
            for id in insts {
                let kind = self.f.inst(id).kind.clone();
                match kind {
                    InstKind::Phi { .. } => {
                        if let Some(&a) = self.phi_owner.get(&id) {
                            stacks.get_mut(&a).unwrap().push(Val::Inst(id));
                            pushed.push(a);
                        }
                    }
                    InstKind::Load { ptr: Val::Inst(a) } if self.alloca_set.contains(&a) => {
                        let v = self.cur(stacks, a);
                        self.f.replace_uses(Val::Inst(id), v);
                        self.removed.push(id);
                    }
                    InstKind::Store {
                        ptr: Val::Inst(a),
                        val,
                    } if self.alloca_set.contains(&a) => {
                        stacks.get_mut(&a).unwrap().push(val);
                        pushed.push(a);
                        self.removed.push(id);
                    }
                    _ => {}
                }
            }
            // Fill phi incomings in successors.
            for s in self.f.succs(b) {
                let sinsts = self.f.blocks[s.idx()].insts.clone();
                for id in sinsts {
                    if let Some(&a) = self.phi_owner.get(&id) {
                        let v = self.cur(stacks, a);
                        if let InstKind::Phi { incs } = &mut self.f.inst_mut(id).kind {
                            if !incs.iter().any(|(p, _)| *p == b) {
                                incs.push((b, v));
                            }
                        }
                    } else if !matches!(self.f.inst(id).kind, InstKind::Phi { .. }) {
                        break;
                    }
                }
            }
            for c in self.children[b.idx()].clone() {
                self.walk(c, stacks);
            }
            for a in pushed.into_iter().rev() {
                stacks.get_mut(&a).unwrap().pop();
            }
        }
    }
    let entry = f.entry;
    let mut w = Walker {
        f,
        alloca_set: &alloca_set,
        types: &types,
        phi_map: &phi_map,
        phi_owner,
        children: &children,
        removed: vec![],
    };
    w.walk(entry, &mut stacks);
    let removed = w.removed.clone();
    let _ = &w.phi_map;
    for id in removed {
        f.remove_inst(id);
    }
    for a in &allocas {
        f.remove_inst(*a);
    }
    allocas.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::verify::verify_function;
    use crate::ir::{Builder, Param};

    /// if/else writing a variable then reading it after the join — must
    /// produce a phi.
    #[test]
    fn promotes_diamond() {
        let mut f = Function::new(
            "t",
            vec![Param {
                name: "c".into(),
                ty: Type::I1,
                uniform: false,
            }],
            Type::I32,
        );
        let (t, e, j) = {
            let t = f.add_block("t");
            let e = f.add_block("e");
            let j = f.add_block("j");
            (t, e, j)
        };
        let mut b = Builder::new(&mut f);
        let x = b.alloca(4);
        b.store(x, Val::ci(0));
        b.cond_br(Val::Arg(0), t, e);
        b.set_block(t);
        b.store(x, Val::ci(1));
        b.br(j);
        b.set_block(e);
        b.store(x, Val::ci(2));
        b.br(j);
        b.set_block(j);
        let l = b.load(x, Type::I32);
        b.ret(Some(l));
        let n = run(&mut f);
        assert_eq!(n, 1);
        verify_function(&f).unwrap();
        // No loads/stores/allocas remain; a phi exists in j.
        assert!(!f
            .insts
            .iter()
            .filter(|i| !i.dead)
            .any(|i| matches!(
                i.kind,
                InstKind::Load { .. } | InstKind::Store { .. } | InstKind::Alloca { .. }
            )));
        let phi = f.blocks[j.idx()].insts[0];
        assert!(matches!(f.inst(phi).kind, InstKind::Phi { .. }));
    }

    /// Loop counter promotion produces header phi; semantics preserved via
    /// the interpreter.
    #[test]
    fn promotes_loop_counter() {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![
                Param {
                    name: "out".into(),
                    ty: Type::Ptr(AddrSpace::Global),
                    uniform: true,
                },
                Param {
                    name: "n".into(),
                    ty: Type::I32,
                    uniform: true,
                },
            ],
            Type::Void,
        );
        let h = f.add_block("h");
        let body = f.add_block("body");
        let exit = f.add_block("exit");
        let mut b = Builder::new(&mut f);
        let i = b.alloca(4);
        let s = b.alloca(4);
        b.store(i, Val::ci(0));
        b.store(s, Val::ci(0));
        b.br(h);
        b.set_block(h);
        let iv = b.load(i, Type::I32);
        let c = b.icmp(ICmp::Slt, iv, Val::Arg(1));
        b.cond_br(c, body, exit);
        b.set_block(body);
        let iv2 = b.load(i, Type::I32);
        let sv = b.load(s, Type::I32);
        let s2 = b.add(sv, iv2);
        b.store(s, s2);
        let i2 = b.add(iv2, Val::ci(1));
        b.store(i, i2);
        b.br(h);
        b.set_block(exit);
        let sv2 = b.load(s, Type::I32);
        b.store(Val::Arg(0), sv2);
        b.ret(None);
        let fid = m.add_func(f);
        // Reference result before promotion.
        let mut mem1 = vec![0u8; 1024];
        crate::ir::interp::run_kernel_scalar(
            &m, fid, &[128, 10], [1, 1, 1], [1, 1, 1], &mut mem1, 512, &[],
        )
        .unwrap();
        let n = run(&mut m.funcs[0]);
        assert_eq!(n, 2);
        verify_function(&m.funcs[0]).unwrap();
        let mut mem2 = vec![0u8; 1024];
        crate::ir::interp::run_kernel_scalar(
            &m, fid, &[128, 10], [1, 1, 1], [1, 1, 1], &mut mem2, 512, &[],
        )
        .unwrap();
        assert_eq!(
            crate::ir::interp::read_u32(&mem1, 128),
            crate::ir::interp::read_u32(&mem2, 128)
        );
        assert_eq!(crate::ir::interp::read_u32(&mem2, 128), 45);
    }

    /// Arrays (size > 4) and escaping allocas are not promoted.
    #[test]
    fn skips_arrays_and_escapes() {
        let mut f = Function::new("t", vec![], Type::I32);
        let mut b = Builder::new(&mut f);
        let arr = b.alloca(64);
        let p = b.gep(arr, Val::ci(2), 4);
        b.store(p, Val::ci(5));
        let l = b.load(p, Type::I32);
        b.ret(Some(l));
        assert_eq!(run(&mut f), 0);
        verify_function(&f).unwrap();
    }
}
