//! Function inlining. GPU compilers inline aggressively; VOLT inlines the
//! kernel body into the generated dispatcher unconditionally and inlines
//! small internal device functions, leaving larger ones as real calls so
//! the Algorithm-1 argument analysis (Uni-Func) has something to refine.

use crate::ir::*;
use std::collections::HashMap;

/// Inline one call site. Returns false (no change) for recursive calls.
pub fn inline_call(m: &mut Module, caller_id: FuncId, call: InstId) -> bool {
    let (callee_id, actuals) = {
        let caller = m.func(caller_id);
        match &caller.inst(call).kind {
            InstKind::Call { callee, args } => (*callee, args.clone()),
            _ => return false,
        }
    };
    if callee_id == caller_id {
        return false;
    }
    let callee = m.func(callee_id).clone();
    let caller = m.func_mut(caller_id);

    // Split the caller block at the call.
    let cb = caller.inst(call).block;
    let pos = caller.blocks[cb.idx()]
        .insts
        .iter()
        .position(|&i| i == call)
        .unwrap();
    let tail_b = caller.add_block("inl.cont");
    let tail: Vec<InstId> = caller.blocks[cb.idx()].insts.split_off(pos + 1);
    for &i in &tail {
        caller.insts[i.idx()].block = tail_b;
    }
    caller.blocks[tail_b.idx()].insts = tail;
    // Successor phis that referenced cb now come from tail_b.
    for s in caller.succs(tail_b) {
        let si = caller.blocks[s.idx()].insts.clone();
        for i in si {
            if let InstKind::Phi { incs } = &mut caller.insts[i.idx()].kind {
                for (p, _) in incs.iter_mut() {
                    if *p == cb {
                        *p = tail_b;
                    }
                }
            } else {
                break;
            }
        }
    }

    // Clone callee blocks.
    let mut bmap: HashMap<BlockId, BlockId> = HashMap::new();
    for b in callee.block_ids() {
        let nb = caller.add_block(&format!("inl.{}", callee.blocks[b.idx()].name));
        bmap.insert(b, nb);
    }
    // Pre-assign the cloned instruction ids (push_inst allocates
    // sequentially) so operand remapping is complete in a single pass even
    // across forward references (phis over back edges).
    let mut imap: HashMap<InstId, InstId> = HashMap::new();
    let mut next = caller.insts.len() as u32;
    for b in callee.block_ids() {
        for &i in &callee.blocks[b.idx()].insts {
            imap.insert(i, InstId(next));
            next += 1;
        }
    }
    let mut rets: Vec<(BlockId, Option<Val>)> = vec![];
    for b in callee.block_ids() {
        for &i in &callee.blocks[b.idx()].insts {
            let inst = callee.inst(i);
            let mut kind = inst.kind.clone();
            // Remap operands: args -> actuals, insts -> cloned insts.
            kind.map_operands(|v| match v {
                Val::Arg(a) => actuals[a as usize],
                Val::Inst(d) => Val::Inst(imap[&d]),
                v => v,
            });
            // Remap phi incoming blocks and successors.
            if let InstKind::Phi { incs } = &mut kind {
                for (p, _) in incs.iter_mut() {
                    *p = bmap[p];
                }
            }
            for s in kind.successors() {
                kind.replace_successor(s, bmap[&s]);
            }
            // Rets become branches to the tail.
            if let InstKind::Ret { val } = &kind {
                rets.push((bmap[&b], *val));
                kind = InstKind::Br { target: tail_b };
            }
            let ni = caller.push_inst(bmap[&b], kind, inst.ty);
            debug_assert_eq!(ni, imap[&i]);
            caller.insts[ni.idx()].uniform_ann = inst.uniform_ann;
            caller.insts[ni.idx()].loc = inst.loc;
        }
    }

    // Return value: phi at tail head (or single value).
    let call_ty = caller.inst(call).ty;
    if call_ty != Type::Void {
        let rv = if rets.len() == 1 {
            rets[0].1.unwrap_or(Val::ci(0))
        } else {
            let incs: Vec<(BlockId, Val)> = rets
                .iter()
                .map(|(b, v)| (*b, v.unwrap_or(Val::ci(0))))
                .collect();
            Val::Inst(caller.insert_inst(tail_b, 0, InstKind::Phi { incs }, call_ty))
        };
        caller.replace_uses(Val::Inst(call), rv);
    }
    // Replace the call with a branch into the inlined entry.
    caller.remove_inst(call);
    caller.push_inst(
        cb,
        InstKind::Br {
            target: bmap[&callee.entry],
        },
        Type::Void,
    );
    // Local (shared) memory requirements propagate.
    let need = callee.local_mem_size;
    let cl = m.func_mut(caller_id);
    cl.local_mem_size = cl.local_mem_size.max(need);
    true
}

/// Inline all calls in `caller` to functions whose size is within
/// `threshold` live instructions (or all calls when `threshold` is None).
/// Repeats until fixpoint (nested calls become visible after inlining).
pub fn inline_into(m: &mut Module, caller_id: FuncId, threshold: Option<usize>) -> usize {
    let mut n = 0;
    for _round in 0..16 {
        let caller = m.func(caller_id);
        let mut site: Option<InstId> = None;
        for (idx, inst) in caller.insts.iter().enumerate() {
            if inst.dead {
                continue;
            }
            if let InstKind::Call { callee, .. } = &inst.kind {
                if *callee == caller_id {
                    continue;
                }
                let size = m.func(*callee).num_insts();
                // Loop-bearing callees are never inlined (the LLVM-like
                // heuristic): they are the targets the Algorithm-1
                // argument analysis refines.
                let has_loop = threshold.is_some()
                    && !crate::ir::cfg::classify_edges(m.func(*callee))
                        .back_edges
                        .is_empty();
                if threshold.map(|t| size <= t && !has_loop).unwrap_or(true) {
                    site = Some(InstId(idx as u32));
                    break;
                }
            }
        }
        match site {
            Some(s) => {
                if inline_call(m, caller_id, s) {
                    n += 1;
                } else {
                    break;
                }
            }
            None => break,
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::verify::verify_function;
    use crate::ir::{Builder, Param};

    fn helper_square(m: &mut Module) -> FuncId {
        let mut h = Function::new(
            "sq",
            vec![Param {
                name: "x".into(),
                ty: Type::I32,
                uniform: false,
            }],
            Type::I32,
        );
        h.linkage = Linkage::Internal;
        {
            let mut b = Builder::new(&mut h);
            let v = b.mul(Val::Arg(0), Val::Arg(0));
            b.ret(Some(v));
        }
        m.add_func(h)
    }

    #[test]
    fn inlines_simple_call() {
        let mut m = Module::new("t");
        let h = helper_square(&mut m);
        let mut k = Function::new(
            "k",
            vec![Param {
                name: "out".into(),
                ty: Type::Ptr(AddrSpace::Global),
                uniform: true,
            }],
            Type::Void,
        );
        k.is_kernel = true;
        {
            let mut b = Builder::new(&mut k);
            let v = b.call(h, vec![Val::ci(7)], Type::I32);
            let w = b.add(v, Val::ci(1));
            b.store(Val::Arg(0), w);
            b.ret(None);
        }
        let kid = m.add_func(k);
        assert_eq!(inline_into(&mut m, kid, None), 1);
        verify_function(&m.funcs[kid.idx()]).unwrap();
        // No calls remain.
        assert!(!m.funcs[kid.idx()]
            .insts
            .iter()
            .any(|i| !i.dead && matches!(i.kind, InstKind::Call { .. })));
        // Behaviour: out[0] = 7*7+1 = 50.
        let mut mem = vec![0u8; 256];
        crate::ir::interp::run_kernel_scalar(
            &m, kid, &[64], [1, 1, 1], [1, 1, 1], &mut mem, 128, &[],
        )
        .unwrap();
        assert_eq!(crate::ir::interp::read_u32(&mem, 64), 50);
    }

    /// Inlining a callee with control flow (abs) preserves semantics and
    /// merges return values with a phi.
    #[test]
    fn inlines_branchy_callee() {
        let mut m = Module::new("t");
        let mut h = Function::new(
            "absf",
            vec![Param {
                name: "x".into(),
                ty: Type::I32,
                uniform: false,
            }],
            Type::I32,
        );
        h.linkage = Linkage::Internal;
        let neg = h.add_block("neg");
        let pos = h.add_block("pos");
        {
            let mut b = Builder::new(&mut h);
            let c = b.icmp(ICmp::Slt, Val::Arg(0), Val::ci(0));
            b.cond_br(c, neg, pos);
            b.set_block(neg);
            let n = b.sub(Val::ci(0), Val::Arg(0));
            b.ret(Some(n));
            b.set_block(pos);
            b.ret(Some(Val::Arg(0)));
        }
        let hid = m.add_func(h);
        let mut k = Function::new(
            "k",
            vec![
                Param {
                    name: "out".into(),
                    ty: Type::Ptr(AddrSpace::Global),
                    uniform: true,
                },
                Param {
                    name: "x".into(),
                    ty: Type::I32,
                    uniform: true,
                },
            ],
            Type::Void,
        );
        k.is_kernel = true;
        {
            let mut b = Builder::new(&mut k);
            let v = b.call(hid, vec![Val::Arg(1)], Type::I32);
            b.store(Val::Arg(0), v);
            b.ret(None);
        }
        let kid = m.add_func(k);
        inline_into(&mut m, kid, None);
        verify_function(&m.funcs[kid.idx()]).unwrap();
        for (input, expect) in [(5i32, 5u32), (-9, 9)] {
            let mut mem = vec![0u8; 256];
            crate::ir::interp::run_kernel_scalar(
                &m,
                kid,
                &[64, input as u32],
                [1, 1, 1],
                [1, 1, 1],
                &mut mem,
                128,
                &[],
            )
            .unwrap();
            assert_eq!(crate::ir::interp::read_u32(&mem, 64), expect);
        }
    }

    #[test]
    fn threshold_blocks_large_callee() {
        let mut m = Module::new("t");
        let mut h = Function::new("big", vec![], Type::I32);
        h.linkage = Linkage::Internal;
        {
            let mut b = Builder::new(&mut h);
            let mut v = Val::ci(1);
            for _ in 0..40 {
                v = b.add(v, Val::ci(1));
            }
            b.ret(Some(v));
        }
        let hid = m.add_func(h);
        let mut k = Function::new("k", vec![], Type::Void);
        {
            let mut b = Builder::new(&mut k);
            let _ = b.call(hid, vec![], Type::I32);
            b.ret(None);
        }
        let kid = m.add_func(k);
        assert_eq!(inline_into(&mut m, kid, Some(10)), 0);
        assert_eq!(inline_into(&mut m, kid, Some(100)), 1);
    }
}
