//! Divergence-management function insertion — paper §4.3.3, Algorithm 2.
//!
//! Classifies every divergent conditional branch as either a divergent
//! *loop* branch (its IPDOM lies outside its loop — TRANSFORM_LOOP) or a
//! divergent plain branch (TRANSFORM_BRANCH):
//!
//! * **TRANSFORM_BRANCH** replaces the `CondBr` with a `SplitBr` carrying
//!   its reconvergence block, and places a `Join` at that block's head.
//!   Multiple splits may share one reconvergence block (early returns,
//!   short-circuit booleans); the stack-popping `Join` semantics handle the
//!   nesting (see DESIGN.md).
//! * **TRANSFORM_LOOP** saves the active mask in the preheader
//!   (`vx_active_threads`), converts every exiting branch to a `PredBr`
//!   (`vx_pred`) that masks off leaving lanes and restores the saved mask
//!   when none remain. Loops with several distinct exit targets are first
//!   unified through per-lane exit-code/live-out slots in private memory,
//!   routed by a (divergent, later split-managed) dispatch chain.

use crate::analysis::tti::TargetDivergenceInfo;
use crate::analysis::{uniformity, UniformityOptions};
use crate::ir::loops::{ensure_preheader, LoopInfo};
use crate::ir::*;
use std::collections::{HashMap, HashSet};

#[derive(Debug, Default)]
pub struct DivergenceReport {
    pub splits: usize,
    pub joins: usize,
    pub loops_transformed: usize,
    pub pred_branches: usize,
    pub exit_unified_loops: usize,
    pub warnings: Vec<String>,
}

pub fn run(
    m: &mut Module,
    fid: FuncId,
    opts: &UniformityOptions,
    tti: &dyn TargetDivergenceInfo,
) -> DivergenceReport {
    let mut report = DivergenceReport::default();
    transform_loops(m, fid, opts, tti, &mut report);
    transform_branches(m, fid, opts, tti, &mut report);
    report
}

// ---------------------------------------------------------------------------
// TRANSFORM_LOOP
// ---------------------------------------------------------------------------

fn transform_loops(
    m: &mut Module,
    fid: FuncId,
    opts: &UniformityOptions,
    tti: &dyn TargetDivergenceInfo,
    report: &mut DivergenceReport,
) {
    let mut done_headers: HashSet<BlockId> = HashSet::new();
    for _ in 0..256 {
        let u = uniformity::analyze_cached(m, fid, opts, tti);
        let dom = m.func_mut(fid).dom_tree();
        let f = m.func(fid);
        let li = LoopInfo::build_with(f, &dom);
        // Deepest loop with a divergent exiting CondBr first.
        let mut cand: Option<usize> = None;
        for (i, l) in li.loops.iter().enumerate() {
            if done_headers.contains(&l.header) {
                continue;
            }
            let divergent_exit = l.exiting_blocks(f).iter().any(|&b| {
                matches!(f.inst(f.term(b)).kind, InstKind::CondBr { .. })
                    && !u.branch_uniform(b)
            });
            if divergent_exit {
                cand = match cand {
                    None => Some(i),
                    Some(j) if li.loops[i].depth > li.loops[j].depth => Some(i),
                    j => j,
                };
            }
        }
        let Some(ci) = cand else { return };
        let header = li.loops[ci].header;
        let blocks = li.loops[ci].blocks.clone();
        done_headers.insert(header);
        transform_one_loop(m.func_mut(fid), header, &blocks, report);
        report.loops_transformed += 1;
    }
    panic!("divergent loop transformation did not converge");
}

/// Exiting CondBr info: (block, exit_cond_value_is_true_branch, exit_succ,
/// cont_succ).
fn exiting_branches(f: &Function, blocks: &HashSet<BlockId>) -> Vec<(BlockId, bool, BlockId, BlockId)> {
    let mut out = vec![];
    for &b in blocks {
        if f.blocks[b.idx()].insts.is_empty() {
            continue;
        }
        if let InstKind::CondBr { t, f: fb, .. } = f.inst(f.term(b)).kind {
            let t_out = !blocks.contains(&t);
            let f_out = !blocks.contains(&fb);
            match (t_out, f_out) {
                (true, false) => out.push((b, true, t, fb)),
                (false, true) => out.push((b, false, fb, t)),
                (true, true) => {
                    // Both arms leave the loop — a degenerate shape the
                    // front-end never emits (simplify folds it). Leave it
                    // to TRANSFORM_BRANCH, which is still correct: both
                    // paths reconverge outside at the branch's IPDOM.
                }
                (false, false) => {}
            }
        }
    }
    out.sort_by_key(|(b, ..)| *b);
    out
}

fn transform_one_loop(
    f: &mut Function,
    header: BlockId,
    blocks: &HashSet<BlockId>,
    report: &mut DivergenceReport,
) {
    let ph = ensure_preheader(f, header, blocks);
    // Read the active mask in the preheader.
    let term_pos = f.blocks[ph.idx()].insts.len() - 1;
    let mask_id = f.insert_inst(
        ph,
        term_pos,
        InstKind::Intr {
            intr: Intr::Mask,
            args: vec![],
        },
        Type::I32,
    );
    let mval = Val::Inst(mask_id);

    let exits = exiting_branches(f, blocks);
    let mut targets: Vec<BlockId> = vec![];
    for (_, _, t, c) in &exits {
        if !targets.contains(t) {
            targets.push(*t);
        }
        // both-arms-exit case contributes the cont target too
        if !blocks.contains(c) && !targets.contains(c) {
            targets.push(*c);
        }
    }

    if targets.len() == 1 {
        // Simple path: every exit goes to the same block.
        for (b, exit_on_true, exit_t, cont) in exits {
            let term = f.term(b);
            let cond = match f.inst(term).kind {
                InstKind::CondBr { cond, .. } => cond,
                _ => continue,
            };
            let cont_pred = if exit_on_true {
                // continue-pred = !cond
                let pos = f.blocks[b.idx()].insts.len() - 1;
                Val::Inst(f.insert_inst(
                    b,
                    pos,
                    InstKind::Bin {
                        op: BinOp::Xor,
                        a: cond,
                        b: Val::cb(true),
                    },
                    Type::I1,
                ))
            } else {
                cond
            };
            f.inst_mut(term).kind = InstKind::PredBr {
                cond: cont_pred,
                mask: mval,
                body: cont,
                exit: exit_t,
            };
            f.invalidate_cfg_cache();
            report.pred_branches += 1;
        }
        return;
    }

    // ---- Exit unification (multiple exit targets) ----
    report.exit_unified_loops += 1;
    let dom = f.dom_tree();
    // Per-lane exit code slot + live-out slots for phis in the targets.
    let code_slot = Val::Inst(f.insert_inst(
        f.entry,
        0,
        InstKind::Alloca { size: 4 },
        Type::Ptr(AddrSpace::Private),
    ));
    // (A) Collect target phis fed from exiting blocks; one slot per phi.
    let exit_blocks: HashSet<BlockId> = exits.iter().map(|(b, ..)| *b).collect();
    let mut phi_slots: HashMap<InstId, Val> = HashMap::new();
    for &t in &targets {
        for &i in f.blocks[t.idx()].insts.clone().iter() {
            if let InstKind::Phi { incs } = &f.inst(i).kind {
                if incs.iter().any(|(p, _)| exit_blocks.contains(p)) {
                    let slot = Val::Inst(f.insert_inst(
                        f.entry,
                        0,
                        InstKind::Alloca { size: 4 },
                        Type::Ptr(AddrSpace::Private),
                    ));
                    phi_slots.insert(i, slot);
                }
            } else {
                break;
            }
        }
    }
    // (B) Generalized live-outs: loop-defined values with uses outside the
    // loop (beyond the direct-target phis of (A)) are spilled per-lane at
    // each exit their definition dominates and reloaded at the use sites.
    let mut liveout_slots: HashMap<InstId, Val> = HashMap::new();
    let mut liveout_users: HashMap<InstId, Vec<InstId>> = HashMap::new();
    for (idx, inst) in f.insts.clone().iter().enumerate() {
        if inst.dead || inst.ty == Type::Void || !blocks.contains(&inst.block) {
            continue;
        }
        let v = InstId(idx as u32);
        let users: Vec<InstId> = f
            .insts
            .iter()
            .enumerate()
            .filter(|(ui, u)| {
                !u.dead
                    && !blocks.contains(&u.block)
                    && *ui != idx
                    && u.kind.operands().contains(&Val::Inst(v))
            })
            .map(|(ui, _)| InstId(ui as u32))
            .collect();
        if users.is_empty() {
            continue;
        }
        let slot = Val::Inst(f.insert_inst(
            f.entry,
            0,
            InstKind::Alloca { size: 4 },
            Type::Ptr(AddrSpace::Private),
        ));
        liveout_slots.insert(v, slot);
        liveout_users.insert(v, users);
    }
    let landing = f.add_block("lexit");
    // Per exiting branch: diamond storing code+liveouts for leaving lanes,
    // then a PredBr that masks them off.
    for (b, exit_on_true, exit_t, cont) in &exits {
        let (b, exit_t, cont) = (*b, *exit_t, *cont);
        let term = f.term(b);
        let cond = match f.inst(term).kind {
            InstKind::CondBr { cond, .. } => cond,
            _ => continue,
        };
        let pos = f.blocks[b.idx()].insts.len() - 1;
        let exit_cond = if *exit_on_true {
            cond
        } else {
            Val::Inst(f.insert_inst(
                b,
                pos,
                InstKind::Bin {
                    op: BinOp::Xor,
                    a: cond,
                    b: Val::cb(true),
                },
                Type::I1,
            ))
        };
        let store_blk = f.add_block("lexit.store");
        let back_blk = f.add_block("lexit.back");
        // Stores in store_blk: live-outs then exit code.
        let tidx = targets.iter().position(|&x| x == exit_t).unwrap();
        for (&phi, &slot) in &phi_slots {
            let (phi_block, inc) = {
                let pdat = f.inst(phi);
                let inc = if let InstKind::Phi { incs } = &pdat.kind {
                    incs.iter().find(|(p, _)| *p == b).map(|(_, v)| *v)
                } else {
                    None
                };
                (pdat.block, inc)
            };
            if phi_block != exit_t {
                continue;
            }
            if let Some(v) = inc {
                f.push_inst(
                    store_blk,
                    InstKind::Store { ptr: slot, val: v },
                    Type::Void,
                );
            }
        }
        // (B) spill live-outs whose definition dominates this exit.
        for (&v, &slot) in &liveout_slots {
            if dom.dominates(f.inst(v).block, b) {
                f.push_inst(
                    store_blk,
                    InstKind::Store {
                        ptr: slot,
                        val: Val::Inst(v),
                    },
                    Type::Void,
                );
            }
        }
        f.push_inst(
            store_blk,
            InstKind::Store {
                ptr: code_slot,
                val: Val::ci(tidx as i64),
            },
            Type::Void,
        );
        f.push_inst(
            store_blk,
            InstKind::Br { target: back_blk },
            Type::Void,
        );
        // back_blk: join; continue-pred; PredBr.
        f.push_inst(
            back_blk,
            InstKind::Intr {
                intr: Intr::Join,
                args: vec![],
            },
            Type::Void,
        );
        let not_exit = f.push_inst(
            back_blk,
            InstKind::Bin {
                op: BinOp::Xor,
                a: exit_cond,
                b: Val::cb(true),
            },
            Type::I1,
        );
        f.push_inst(
            back_blk,
            InstKind::PredBr {
                cond: Val::Inst(not_exit),
                mask: mval,
                body: cont,
                exit: landing,
            },
            Type::Void,
        );
        // Replace the exiting branch with the store diamond.
        f.inst_mut(term).kind = InstKind::SplitBr {
            cond: exit_cond,
            neg: false,
            then_b: store_blk,
            else_b: back_blk,
            ipdom: back_blk,
        };
        // The continue edge moved from b to back_blk: rewrite phis in cont.
        for &i in f.blocks[cont.idx()].insts.clone().iter() {
            if let InstKind::Phi { incs } = &mut f.inst_mut(i).kind {
                for (p, _) in incs.iter_mut() {
                    if *p == b {
                        *p = back_blk;
                    }
                }
            } else {
                break;
            }
        }
        report.splits += 1;
        report.joins += 1;
        report.pred_branches += 1;
        // Remove the phi incomings from b in exit_t.
        for &i in f.blocks[exit_t.idx()].insts.clone().iter() {
            if let InstKind::Phi { incs } = &mut f.inst_mut(i).kind {
                incs.retain(|(p, _)| *p != b);
            } else {
                break;
            }
        }
        // The SplitBr rewrite above changed b's successors in place.
        f.invalidate_cfg_cache();
    }
    // Landing dispatch chain: load code, route to each target through a
    // reload block that feeds the target phis.
    let code = Val::Inst(f.push_inst(
        landing,
        InstKind::Load { ptr: code_slot },
        Type::I32,
    ));
    let mut chain = landing;
    for (tidx, &t) in targets.iter().enumerate() {
        let reload = f.add_block("lexit.reload");
        // Reload live-outs for phis in t.
        for &i in f.blocks[t.idx()].insts.clone().iter() {
            if let Some(&slot) = phi_slots.get(&i) {
                let lv = Val::Inst(f.push_inst(reload, InstKind::Load { ptr: slot }, f.inst(i).ty));
                if let InstKind::Phi { incs } = &mut f.inst_mut(i).kind {
                    incs.push((reload, lv));
                }
            }
        }
        f.push_inst(reload, InstKind::Br { target: t }, Type::Void);
        if tidx + 1 == targets.len() {
            // Last target: unconditional.
            f.push_inst(chain, InstKind::Br { target: reload }, Type::Void);
        } else {
            let c = Val::Inst(f.push_inst(
                chain,
                InstKind::ICmp {
                    pred: ICmp::Eq,
                    a: code,
                    b: Val::ci(tidx as i64),
                },
                Type::I1,
            ));
            let next = f.add_block("lexit.chain");
            f.push_inst(
                chain,
                InstKind::CondBr {
                    cond: c,
                    t: reload,
                    f: next,
                },
                Type::Void,
            );
            chain = next;
        }
    }
    // (B) rewrite the remaining outside uses through the spill slots.
    for (&v, users) in &liveout_users {
        let slot = liveout_slots[&v];
        let vty = f.inst(v).ty;
        for &u in users {
            if f.insts[u.idx()].dead {
                continue;
            }
            let kind = f.inst(u).kind.clone();
            if let InstKind::Phi { incs } = kind {
                for (p, val) in incs {
                    if val == Val::Inst(v) && !exit_blocks.contains(&p) {
                        let pos = f.blocks[p.idx()].insts.len() - 1;
                        let ld = Val::Inst(f.insert_inst(
                            p,
                            pos,
                            InstKind::Load { ptr: slot },
                            vty,
                        ));
                        if let InstKind::Phi { incs } = &mut f.inst_mut(u).kind {
                            for (pp, vv) in incs.iter_mut() {
                                if *pp == p && *vv == Val::Inst(v) {
                                    *vv = ld;
                                }
                            }
                        }
                    }
                }
            } else {
                let ub = f.inst(u).block;
                let pos = f.blocks[ub.idx()]
                    .insts
                    .iter()
                    .position(|&x| x == u)
                    .unwrap();
                let ld = Val::Inst(f.insert_inst(ub, pos, InstKind::Load { ptr: slot }, vty));
                f.inst_mut(u)
                    .kind
                    .map_operands(|x| if x == Val::Inst(v) { ld } else { x });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// TRANSFORM_BRANCH
// ---------------------------------------------------------------------------

fn transform_branches(
    m: &mut Module,
    fid: FuncId,
    opts: &UniformityOptions,
    tti: &dyn TargetDivergenceInfo,
    report: &mut DivergenceReport,
) {
    let mut skipped: HashSet<BlockId> = HashSet::new();
    for _round in 0..64 {
        let u = uniformity::analyze_cached(m, fid, opts, tti);
        let pdom = m.func_mut(fid).pdom_tree();
        let f = m.func(fid);
        let rpo = f.rpo();
        let rpo_pos: HashMap<BlockId, usize> =
            rpo.iter().enumerate().map(|(i, &b)| (b, i)).collect();
        let mut work: Vec<(BlockId, BlockId)> = vec![];
        for &b in &rpo {
            if skipped.contains(&b) {
                continue;
            }
            if !matches!(f.inst(f.term(b)).kind, InstKind::CondBr { .. }) {
                continue;
            }
            if u.branch_uniform(b) {
                continue;
            }
            match pdom.ipdom_of(b) {
                Some(ip) => work.push((b, ip)),
                None => {
                    report.warnings.push(format!(
                        "divergent branch b{} has no post-dominator; left unmanaged",
                        b.0
                    ));
                    skipped.insert(b);
                }
            }
        }
        if work.is_empty() {
            return;
        }
        // Outer-first (RPO order); join insertion after phis puts inner
        // joins ahead of outer ones, matching the stack pop order.
        work.sort_by_key(|(b, _)| rpo_pos[b]);
        let f = m.func_mut(fid);
        for (b, ip) in work {
            let term = f.term(b);
            if let InstKind::CondBr { cond, t, f: fb } = f.inst(term).kind {
                f.inst_mut(term).kind = InstKind::SplitBr {
                    cond,
                    neg: false,
                    then_b: t,
                    else_b: fb,
                    ipdom: ip,
                };
                report.splits += 1;
                // Join after the phis of ip.
                let nphis = f.blocks[ip.idx()]
                    .insts
                    .iter()
                    .take_while(|&&i| matches!(f.inst(i).kind, InstKind::Phi { .. }))
                    .count();
                f.insert_inst(
                    ip,
                    nphis,
                    InstKind::Intr {
                        intr: Intr::Join,
                        args: vec![],
                    },
                    Type::Void,
                );
                report.joins += 1;
            }
        }
        // The CondBr→SplitBr rewrites happened in place via `inst_mut`.
        f.invalidate_cfg_cache();
    }
    panic!("divergent branch transformation did not converge");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::tti::VortexTti;
    use crate::ir::verify::verify_function;
    use crate::ir::{Builder, Param};

    fn opts() -> UniformityOptions {
        UniformityOptions::all()
    }

    /// Simple divergent diamond gets split + join.
    #[test]
    fn splits_divergent_diamond() {
        let mut m = Module::new("t");
        let mut f = Function::new("k", vec![], Type::Void);
        let t = f.add_block("t");
        let e = f.add_block("e");
        let j = f.add_block("j");
        let mut b = Builder::new(&mut f);
        let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
        let c = b.icmp(ICmp::Slt, lane, Val::ci(8));
        b.cond_br(c, t, e);
        b.set_block(t);
        b.br(j);
        b.set_block(e);
        b.br(j);
        b.set_block(j);
        b.ret(None);
        let fid = m.add_func(f);
        let rep = run(&mut m, fid, &opts(), &VortexTti);
        assert_eq!(rep.splits, 1);
        assert_eq!(rep.joins, 1);
        verify_function(&m.funcs[0]).unwrap();
        let f = &m.funcs[0];
        assert!(matches!(
            f.inst(f.term(f.entry)).kind,
            InstKind::SplitBr { ipdom, .. } if ipdom == j
        ));
        // Join is the first instruction of j.
        let j0 = f.blocks[j.idx()].insts[0];
        assert!(matches!(
            f.inst(j0).kind,
            InstKind::Intr {
                intr: Intr::Join,
                ..
            }
        ));
    }

    /// Uniform branch untouched.
    #[test]
    fn uniform_branch_untouched() {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![Param {
                name: "n".into(),
                ty: Type::I32,
                uniform: true,
            }],
            Type::Void,
        );
        let t = f.add_block("t");
        let e = f.add_block("e");
        let mut b = Builder::new(&mut f);
        let c = b.icmp(ICmp::Slt, Val::Arg(0), Val::ci(8));
        b.cond_br(c, t, e);
        b.set_block(t);
        b.br(e);
        b.set_block(e);
        b.ret(None);
        let fid = m.add_func(f);
        let rep = run(&mut m, fid, &opts(), &VortexTti);
        assert_eq!(rep.splits, 0);
        assert!(matches!(
            m.funcs[0].inst(m.funcs[0].term(m.funcs[0].entry)).kind,
            InstKind::CondBr { .. }
        ));
    }

    /// Divergent while loop: exiting branch becomes PredBr with the
    /// preheader mask.
    #[test]
    fn divergent_loop_gets_pred() {
        let mut m = Module::new("t");
        let mut f = Function::new("k", vec![], Type::Void);
        let entry = f.entry;
        let h = f.add_block("h");
        let body = f.add_block("body");
        let exit = f.add_block("exit");
        let mut b = Builder::at(&mut f, entry);
        let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
        b.br(h);
        b.set_block(h);
        let i = b.phi(Type::I32, vec![(entry, Val::ci(0))]);
        let c = b.icmp(ICmp::Slt, i, lane);
        b.cond_br(c, body, exit);
        b.set_block(body);
        let i2 = b.add(i, Val::ci(1));
        b.br(h);
        b.set_block(exit);
        b.ret(None);
        if let Val::Inst(ip) = i {
            if let InstKind::Phi { incs } = &mut f.inst_mut(ip).kind {
                incs.push((body, i2));
            }
        }
        let fid = m.add_func(f);
        let rep = run(&mut m, fid, &opts(), &VortexTti);
        assert_eq!(rep.loops_transformed, 1);
        assert_eq!(rep.pred_branches, 1);
        verify_function(&m.funcs[0]).unwrap();
        let f = &m.funcs[0];
        // Header terminator is a PredBr whose mask comes from Intr::Mask.
        match f.inst(f.term(h)).kind {
            InstKind::PredBr { mask: Val::Inst(mi), body: bb, exit: ex, .. } => {
                assert!(matches!(
                    f.inst(mi).kind,
                    InstKind::Intr {
                        intr: Intr::Mask,
                        ..
                    }
                ));
                assert_eq!(bb, body);
                assert_eq!(ex, exit);
            }
            ref k => panic!("expected PredBr, got {k:?}"),
        }
    }

    /// Loop with a divergent break to a *different* target than the header
    /// exit: exit unification kicks in.
    #[test]
    fn multi_target_exit_unification() {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![Param {
                name: "n".into(),
                ty: Type::I32,
                uniform: true,
            }],
            Type::I32,
        );
        let entry = f.entry;
        let h = f.add_block("h");
        let body = f.add_block("body");
        let exit1 = f.add_block("exit1");
        let exit2 = f.add_block("exit2");
        let done = f.add_block("done");
        let mut b = Builder::at(&mut f, entry);
        let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
        b.br(h);
        b.set_block(h);
        let i = b.phi(Type::I32, vec![(entry, Val::ci(0))]);
        let c = b.icmp(ICmp::Slt, i, Val::Arg(0));
        b.cond_br(c, body, exit1);
        b.set_block(body);
        let brk = b.icmp(ICmp::Eq, i, lane); // divergent break
        let i2 = b.add(i, Val::ci(1));
        b.cond_br(brk, exit2, h);
        b.set_block(exit1);
        b.br(done);
        b.set_block(exit2);
        b.br(done);
        b.set_block(done);
        let r = b.phi(Type::I32, vec![(exit1, Val::ci(1)), (exit2, Val::ci(2))]);
        b.ret(Some(r));
        if let Val::Inst(ip) = i {
            if let InstKind::Phi { incs } = &mut f.inst_mut(ip).kind {
                incs.push((body, i2));
            }
        }
        let fid = m.add_func(f);
        let rep = run(&mut m, fid, &opts(), &VortexTti);
        assert_eq!(rep.exit_unified_loops, 1);
        assert!(rep.pred_branches >= 2);
        verify_function(&m.funcs[0]).unwrap();
        // Scalar semantics preserved (SplitBr/PredBr interpret as branches).
        let mut mem = vec![0u8; 1024];
        crate::ir::interp::run_kernel_scalar(
            &m, fid, &[5], [1, 1, 1], [1, 1, 1], &mut mem, 512, &[],
        )
        .unwrap();
    }

    /// Two early-exit style divergent branches sharing a reconvergence
    /// block produce two joins at that block.
    #[test]
    fn shared_ipdom_double_join() {
        let mut m = Module::new("t");
        let mut f = Function::new("k", vec![], Type::Void);
        let r1 = f.add_block("r1");
        let r2 = f.add_block("r2");
        let e2 = f.add_block("e2");
        let fin = f.add_block("fin");
        let mut b = Builder::new(&mut f);
        let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
        let c1 = b.icmp(ICmp::Slt, lane, Val::ci(4));
        b.cond_br(c1, fin, r1);
        b.set_block(r1);
        let c2 = b.icmp(ICmp::Slt, lane, Val::ci(8));
        b.cond_br(c2, fin, r2);
        b.set_block(r2);
        b.br(e2);
        b.set_block(e2);
        b.br(fin);
        b.set_block(fin);
        b.ret(None);
        let fid = m.add_func(f);
        let rep = run(&mut m, fid, &opts(), &VortexTti);
        assert_eq!(rep.splits, 2);
        assert_eq!(rep.joins, 2);
        let f = &m.funcs[0];
        let joins_at_fin = f.blocks[fin.idx()]
            .insts
            .iter()
            .filter(|&&i| {
                matches!(
                    f.inst(i).kind,
                    InstKind::Intr {
                        intr: Intr::Join,
                        ..
                    }
                )
            })
            .count();
        assert_eq!(joins_at_fin, 2);
        verify_function(f).unwrap();
    }
}
