//! Pass manager: sequences the middle-end into the evaluation ladder of
//! paper §5.2 and records per-pass wall-clock timings (the paper's
//! compile-time-overhead claim — 0.18% geomean — is regenerated from these
//! numbers by `benches/compile_time.rs`).

use super::*;
use crate::analysis::tti::{TargetDivergenceInfo, VortexTti};
use crate::analysis::{func_args, UniformityOptions};
use crate::ir::verify::verify_module;
use crate::ir::{FuncId, Function, Module};
use std::time::Instant;

/// The cumulative optimization ladder from §5.2 (Figures 7/8).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum OptLevel {
    /// Correctness only: everything divergent unless constant.
    Base,
    /// + hardware always-uniform seeds (CSRs, arg-block loads).
    UniHw,
    /// + annotation analysis (`uniform` qualifiers, stack slots).
    UniAnn,
    /// + Algorithm-1 function-argument analysis.
    UniFunc,
    /// + ZiCond: divergent selects stay as `vx_cmov`.
    ZiCond,
    /// + CFG reconstruction (divergent node duplication).
    Recon,
    /// + uniformity-aware redundancy elimination: dominator GVN/CSE,
    /// loop-invariant code motion, and power-of-two strength reduction —
    /// the first rung past the paper's published ladder (§5.2), built on
    /// the same centralized SIMT analyses. The driver also enables the
    /// backend codegen rung at this level (MIR combine/peephole +
    /// quality register allocation — `BackendOptions::codegen_opt`, see
    /// docs/OPTIMIZATIONS.md "The backend rung").
    O3,
}

impl OptLevel {
    pub const LADDER: [OptLevel; 7] = [
        OptLevel::Base,
        OptLevel::UniHw,
        OptLevel::UniAnn,
        OptLevel::UniFunc,
        OptLevel::ZiCond,
        OptLevel::Recon,
        OptLevel::O3,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OptLevel::Base => "Base",
            OptLevel::UniHw => "Uni-HW",
            OptLevel::UniAnn => "Uni-Ann",
            OptLevel::UniFunc => "Uni-Func",
            OptLevel::ZiCond => "ZiCond",
            OptLevel::Recon => "Recon",
            OptLevel::O3 => "O3",
        }
    }

    pub fn config(self) -> OptConfig {
        OptConfig {
            uniformity: UniformityOptions {
                uni_hw: self >= OptLevel::UniHw,
                uni_ann: self >= OptLevel::UniAnn,
                uni_func: self >= OptLevel::UniFunc,
            },
            zicond: self >= OptLevel::ZiCond,
            recon: self >= OptLevel::Recon,
            o3: self >= OptLevel::O3,
            ..OptConfig::default()
        }
    }
}

#[derive(Clone, Debug)]
pub struct OptConfig {
    pub uniformity: UniformityOptions,
    /// Ladder request for select formation (the ZiCond rung). The passes
    /// only honor it when the target also implements the extension — see
    /// [`OptConfig::effective_zicond`].
    pub zicond: bool,
    pub recon: bool,
    /// O3 rung: GVN + LICM + strength reduction.
    pub o3: bool,
    /// ISA feature set of the compilation target. Legality is derived
    /// from this, not from the ladder rung alone: on a target without
    /// ZiCond, `form_selects` never runs and `select_normalize` expands
    /// every select into a branch diamond *before* divergence management
    /// (the select→branch legalization point — after Algorithm 2 the
    /// expansion would produce unmanaged divergent branches).
    pub features: crate::target::Features,
    /// Device functions at most this many instructions are inlined.
    pub inline_threshold: usize,
    /// Run the IR verifier after every pass (tests/debug).
    pub verify: bool,
}

impl OptConfig {
    /// Select formation/retention is legal only when the ladder asks for
    /// it *and* the target implements `vx_cmov`.
    pub fn effective_zicond(&self) -> bool {
        self.zicond && self.features.zicond
    }
}

impl Default for OptConfig {
    fn default() -> Self {
        OptConfig {
            uniformity: UniformityOptions::all(),
            zicond: true,
            recon: true,
            o3: true,
            features: crate::target::Features::vortex(),
            inline_threshold: 48,
            verify: cfg!(debug_assertions),
        }
    }
}

#[derive(Debug, Default)]
pub struct MiddleEndReport {
    /// (pass name, milliseconds).
    pub timings: Vec<(String, f64)>,
    pub divergence: Vec<(String, divergence_insert::DivergenceReport)>,
    pub structurize_dispatchers: usize,
    pub recon_duplicated: usize,
    pub selects_expanded: usize,
    pub selects_formed: usize,
    pub inlined: usize,
    pub allocas_promoted: usize,
    /// O3 rung counters.
    pub gvn_merged: usize,
    pub licm_hoisted: usize,
    pub strength_reduced: usize,
}

impl MiddleEndReport {
    pub fn total_ms(&self) -> f64 {
        self.timings.iter().map(|(_, t)| t).sum()
    }
    pub fn total_splits(&self) -> usize {
        self.divergence.iter().map(|(_, d)| d.splits).sum()
    }
    pub fn total_pred_loops(&self) -> usize {
        self.divergence.iter().map(|(_, d)| d.loops_transformed).sum()
    }
}

/// All functions reachable from kernels (callees included), kernels first.
fn reachable_funcs(m: &Module) -> Vec<FuncId> {
    let cg = crate::analysis::callgraph::CallGraph::build(m);
    cg.rpo_from(&m.kernels())
}

/// Run the complete middle-end pipeline over the module.
pub fn run_middle_end(m: &mut Module, cfg: &OptConfig) -> MiddleEndReport {
    let tti = VortexTti;
    run_middle_end_with(m, cfg, &tti)
}

pub fn run_middle_end_with(
    m: &mut Module,
    cfg: &OptConfig,
    tti: &dyn TargetDivergenceInfo,
) -> MiddleEndReport {
    run_middle_end_with_threads(m, cfg, tti, 1)
}

/// [`run_middle_end_with`] with the per-function pass stages fanned out
/// across up to `threads` scoped workers ([`crate::par`]). Functions
/// are independent for those stages (each touches only its own
/// [`Function`]), and every counter is a commutative sum, so the
/// resulting module — and therefore the emitted image — is identical
/// to the sequential pipeline for any thread count. Module-level
/// stages (reconstruction, inlining, Algorithm 1, GVN/LICM, divergence
/// insertion) take the whole module and stay sequential.
pub fn run_middle_end_with_threads(
    m: &mut Module,
    cfg: &OptConfig,
    tti: &dyn TargetDivergenceInfo,
    threads: usize,
) -> MiddleEndReport {
    let mut rep = MiddleEndReport::default();
    let funcs = reachable_funcs(m);
    let idxs: Vec<usize> = funcs.iter().map(|f| f.idx()).collect();
    // One per-function pass over every reachable function, parallel when
    // asked; returns the summed per-function counter.
    let for_each = |m: &mut Module, pass: &(dyn Fn(&mut Function) -> usize + Sync)| -> usize {
        if threads <= 1 {
            let mut total = 0;
            for &f in &funcs {
                total += pass(&mut m.funcs[f.idx()]);
            }
            total
        } else {
            let mut targets: Vec<&mut Function> = m
                .funcs
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| idxs.contains(i))
                .map(|(_, func)| func)
                .collect();
            crate::par::par_for_each_mut(&mut targets, threads, |_, func| pass(func))
                .into_iter()
                .sum()
        }
    };
    let timed = |name: &str,
                     m: &mut Module,
                     rep: &mut MiddleEndReport,
                     f: &mut dyn FnMut(&mut Module, &mut MiddleEndReport)| {
        let t0 = Instant::now();
        f(m, rep);
        rep.timings
            .push((name.to_string(), t0.elapsed().as_secs_f64() * 1e3));
        if cfg.verify {
            if let Err(e) = verify_module(m) {
                let dump: String = m
                    .funcs
                    .iter()
                    .map(crate::ir::printer::print_function)
                    .collect();
                panic!("verifier failed after {name}: {e}\n{dump}");
            }
        }
    };

    // 1. Early cleanup.
    timed("simplify0", m, &mut rep, &mut |m, _| {
        for_each(m, &|f| {
            simplify::simplify(f);
            0
        });
    });
    // 2. CFG reconstruction (Recon) then structurization — pre-SSA.
    if cfg.recon {
        timed("reconstruct", m, &mut rep, &mut |m, rep| {
            for &f in &funcs {
                let r = reconstruct::run(m, f, &cfg.uniformity, tti);
                rep.recon_duplicated += r.duplicated;
            }
        });
    }
    timed("structurize", m, &mut rep, &mut |m, rep| {
        rep.structurize_dispatchers += for_each(m, &|f| structurize::run(f).dispatchers);
    });
    // 3. SSA construction.
    timed("mem2reg", m, &mut rep, &mut |m, rep| {
        rep.allocas_promoted += for_each(m, &mem2reg::run);
    });
    // 4. Main cleanup.
    timed("simplify1", m, &mut rep, &mut |m, _| {
        for_each(m, &|f| {
            simplify::simplify(f);
            0
        });
    });
    // 5. Inline small device functions (kernel bodies were already inlined
    //    into dispatchers by the front-end schedule pass).
    timed("inline", m, &mut rep, &mut |m, rep| {
        for &f in &funcs {
            rep.inlined += inline::inline_into(m, f, Some(cfg.inline_threshold));
        }
        for_each(m, &|f| {
            simplify::simplify(f);
            0
        });
    });
    // 6. Algorithm 1 (Uni-Func).
    if cfg.uniformity.uni_func {
        timed("func-args", m, &mut rep, &mut |m, _| {
            func_args::run(m, &cfg.uniformity, tti);
        });
    }
    // 7. Canonicalize: single exit, then select normalization.
    timed("single-exit", m, &mut rep, &mut |m, _| {
        for_each(m, &|f| {
            simplify::single_exit(f);
            0
        });
    });
    // Select legality comes from the target's feature set, not the
    // ladder rung alone: no vx_cmov → no select formation, and every
    // select (front-end ternaries included) is expanded to a branch
    // diamond here, while divergence management can still guard it.
    let zicond = cfg.effective_zicond();
    if zicond {
        // ZiCond: speculate small diamonds into selects (→ vx_cmov).
        timed("select-form", m, &mut rep, &mut |m, rep| {
            rep.selects_formed += for_each(m, &simplify::form_selects);
        });
    }
    timed("select-normalize", m, &mut rep, &mut |m, rep| {
        rep.selects_expanded += for_each(m, &|f| simplify::select_normalize(f, zicond));
    });
    // 7b. The O3 rung: redundancy elimination on the canonical CondBr CFG,
    //     before divergence management rewrites loops into PredBr form.
    if cfg.o3 {
        timed("gvn", m, &mut rep, &mut |m, rep| {
            for &f in &funcs {
                rep.gvn_merged += gvn::run(m, f, &cfg.uniformity, tti);
            }
        });
        timed("licm", m, &mut rep, &mut |m, rep| {
            for &f in &funcs {
                rep.licm_hoisted += licm::run(m, f, &cfg.uniformity, tti);
            }
        });
        timed("strength-reduce", m, &mut rep, &mut |m, rep| {
            rep.strength_reduced += for_each(m, &strength::run);
        });
        timed("simplify-o3", m, &mut rep, &mut |m, _| {
            for_each(m, &|f| {
                simplify::simplify(f);
                0
            });
        });
    }
    // 8. Divergence-management insertion (Algorithm 2).
    timed("divergence-insert", m, &mut rep, &mut |m, rep| {
        for &f in &funcs {
            let name = m.func(f).name.clone();
            let d = divergence_insert::run(m, f, &cfg.uniformity, tti);
            rep.divergence.push((name, d));
        }
    });
    // 9. Final DCE (keep divergence intrinsics: side-effecting).
    timed("dce-final", m, &mut rep, &mut |m, _| {
        for_each(m, &|f| {
            simplify::dce(f);
            0
        });
    });
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::interp::{read_u32, run_kernel_scalar};
    use crate::ir::*;

    /// A small kernel exercising branch + loop divergence, compiled at
    /// every ladder point; semantics must be identical.
    fn build_kernel() -> Module {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![
                Param {
                    name: "out".into(),
                    ty: Type::Ptr(AddrSpace::Global),
                    uniform: true,
                },
                Param {
                    name: "n".into(),
                    ty: Type::I32,
                    uniform: true,
                },
            ],
            Type::Void,
        );
        f.is_kernel = true;
        f.linkage = Linkage::External;
        let h = f.add_block("h");
        let body = f.add_block("body");
        let exit = f.add_block("exit");
        let odd = f.add_block("odd");
        let even = f.add_block("even");
        let j = f.add_block("j");
        let mut b = Builder::new(&mut f);
        let gid = b.intr(Intr::WorkItem(WorkItem::GlobalId), vec![Val::ci(0)]);
        // s = 0; for (i = 0; i < gid % 7; i++) s += i;
        let s = b.alloca(4);
        let i = b.alloca(4);
        b.store(s, Val::ci(0));
        b.store(i, Val::ci(0));
        let bound = b.bin(BinOp::SRem, gid, Val::ci(7));
        b.br(h);
        b.set_block(h);
        let iv = b.load(i, Type::I32);
        let c = b.icmp(ICmp::Slt, iv, bound);
        b.cond_br(c, body, exit);
        b.set_block(body);
        let sv = b.load(s, Type::I32);
        let s2 = b.add(sv, iv);
        b.store(s, s2);
        let i2 = b.add(iv, Val::ci(1));
        b.store(i, i2);
        b.br(h);
        b.set_block(exit);
        // if (gid & 1) v = s*3 else v = s+100
        let bit = b.bin(BinOp::And, gid, Val::ci(1));
        let codd = b.icmp(ICmp::Ne, bit, Val::ci(0));
        b.cond_br(codd, odd, even);
        b.set_block(odd);
        let sv2 = b.load(s, Type::I32);
        let vo = b.mul(sv2, Val::ci(3));
        b.store(s, vo);
        b.br(j);
        b.set_block(even);
        let sv3 = b.load(s, Type::I32);
        let ve = b.add(sv3, Val::ci(100));
        b.store(s, ve);
        b.br(j);
        b.set_block(j);
        let fin = b.load(s, Type::I32);
        let p = b.gep(Val::Arg(0), gid, 4);
        b.store(p, fin);
        let _ = Val::Arg(1);
        b.ret(None);
        m.add_func(f);
        m
    }

    fn run_out(m: &Module, n: u32) -> Vec<u32> {
        let mut mem = vec![0u8; 8192];
        run_kernel_scalar(
            m,
            FuncId(0),
            &[256, n],
            [2, 1, 1],
            [8, 1, 1],
            &mut mem,
            4096,
            &[],
        )
        .unwrap();
        (0..16).map(|i| read_u32(&mem, 256 + i * 4)).collect()
    }

    #[test]
    fn ladder_preserves_semantics() {
        let m0 = build_kernel();
        let expect = run_out(&m0, 16);
        for lvl in OptLevel::LADDER {
            let mut m = m0.clone();
            let mut cfg = lvl.config();
            cfg.verify = true;
            let rep = run_middle_end(&mut m, &cfg);
            assert!(rep.total_ms() >= 0.0);
            let got = run_out(&m, 16);
            assert_eq!(got, expect, "ladder level {:?} broke semantics", lvl);
        }
    }

    #[test]
    fn base_has_more_divergence_management_than_full() {
        let m0 = build_kernel();
        let mut mb = m0.clone();
        let mut cb = OptLevel::Base.config();
        cb.verify = true;
        let rb = run_middle_end(&mut mb, &cb);
        let mut mf = m0.clone();
        let mut cf = OptLevel::Recon.config();
        cf.verify = true;
        let rf = run_middle_end(&mut mf, &cf);
        // Base: the uniform loop bound is unknown -> loop is divergence
        // managed; the gid-dependent loop is divergent in both.
        assert!(
            rb.total_splits() + rb.total_pred_loops()
                >= rf.total_splits() + rf.total_pred_loops(),
            "base {rb:?} vs full {rf:?}"
        );
        assert!(rb.total_pred_loops() >= 1);
    }

    #[test]
    fn timings_recorded() {
        let mut m = build_kernel();
        let rep = run_middle_end(&mut m, &OptConfig::default());
        assert!(rep.timings.iter().any(|(n, _)| n == "divergence-insert"));
        assert!(rep.total_ms() > 0.0);
    }

    /// Target-feature legality overrides the ladder: with a no-ZiCond
    /// feature set even O3 forms no selects, select-normalize expands any
    /// that exist, and semantics are preserved.
    #[test]
    fn features_gate_select_formation() {
        let m0 = build_kernel();
        let expect = run_out(&m0, 16);
        let mut cfg = OptLevel::O3.config();
        cfg.features = crate::target::Features::minimal();
        cfg.verify = true;
        assert!(cfg.zicond && !cfg.effective_zicond());
        let mut m = m0.clone();
        let rep = run_middle_end(&mut m, &cfg);
        assert_eq!(rep.selects_formed, 0, "no vx_cmov -> no select formation");
        assert!(
            !rep.timings.iter().any(|(n, _)| n == "select-form"),
            "select-form must not run without the zicond feature"
        );
        // No select instruction may survive to the backend boundary.
        for f in &m.funcs {
            for inst in &f.insts {
                assert!(
                    inst.dead || !matches!(inst.kind, crate::ir::InstKind::Select { .. }),
                    "select survived legalization in {}",
                    f.name
                );
            }
        }
        assert_eq!(run_out(&m, 16), expect, "legalized module changed semantics");
    }

    /// O3 sits above Recon: its config enables the new passes, the ladder
    /// includes it, and the rung runs (and is timed) without changing
    /// kernel semantics (covered by `ladder_preserves_semantics` looping
    /// over the full LADDER).
    #[test]
    fn o3_rung_wired() {
        assert_eq!(*OptLevel::LADDER.last().unwrap(), OptLevel::O3);
        assert!(OptLevel::O3 > OptLevel::Recon);
        let cfg = OptLevel::O3.config();
        assert!(cfg.o3 && cfg.recon && cfg.zicond);
        assert!(!OptLevel::Recon.config().o3);
        let mut m = build_kernel();
        let mut c = OptLevel::O3.config();
        c.verify = true;
        let rep = run_middle_end(&mut m, &c);
        for pass in ["gvn", "licm", "strength-reduce"] {
            assert!(
                rep.timings.iter().any(|(n, _)| n == pass),
                "missing O3 pass {pass}"
            );
        }
        let got = run_out(&m, 16);
        let expect = run_out(&build_kernel(), 16);
        assert_eq!(got, expect);
    }
}
