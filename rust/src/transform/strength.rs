//! Strength reduction (third O3 rung pass): rewrite integer multiply /
//! divide / remainder by power-of-two constants into shifts and masks
//! before instruction selection.
//!
//! On the target, `div`/`rem` occupy the 16-cycle serial divider and
//! `mul` the 3-cycle multiplier, while shifts and masks are 1-cycle ALU
//! ops with immediate forms (`slli`/`srli`/`srai`/`andi`) — so even the
//! 4-instruction signed-division expansion wins by ~4x. Signed semantics
//! are preserved exactly (RISC-V truncating division): `x / 2^k` becomes
//! `(x + ((x >> 31) >>> (32-k))) >> k` — the bias corrects the rounding
//! direction for negative dividends — and `x % 2^k` is rebuilt as
//! `x - (x / 2^k) << k`. The differential test below checks negative
//! operands against the interpreter's reference semantics.
//!
//! Runs after GVN/LICM (so redundancy is eliminated on the canonical
//! mul/div form) and before divergence insertion; per-lane semantics are
//! untouched, so no uniformity reasoning is needed here.

use crate::ir::*;

/// Returns `Some(k)` when `v` is the constant `2^k` with `1 <= k <= 30`.
fn pow2_exp(v: Val) -> Option<u32> {
    match v {
        Val::I(c, _) if (2..=(1i64 << 30)).contains(&c) && (c as u64).is_power_of_two() => {
            Some((c as u64).trailing_zeros())
        }
        _ => None,
    }
}

/// Run strength reduction over one function. Returns rewrites performed.
pub fn run(f: &mut Function) -> usize {
    let mut n = 0;
    let end = f.insts.len(); // rewrites append; never revisit new insts
    for idx in 0..end {
        let id = InstId(idx as u32);
        if f.insts[idx].dead || f.insts[idx].ty != Type::I32 {
            continue;
        }
        let InstKind::Bin { op, a, b } = f.insts[idx].kind.clone() else {
            continue;
        };
        let blk = f.insts[idx].block;
        let Some(pos) = f.blocks[blk.idx()].insts.iter().position(|&x| x == id) else {
            continue;
        };
        let rewritten: Option<Val> = match op {
            BinOp::Mul => {
                // Constant on either side (commutative).
                if let Some(k) = pow2_exp(b) {
                    Some(emit_shl(f, blk, pos, a, k))
                } else if let Some(k) = pow2_exp(a) {
                    Some(emit_shl(f, blk, pos, b, k))
                } else {
                    None
                }
            }
            BinOp::UDiv => {
                pow2_exp(b).map(|k| emit_bin(f, blk, pos, BinOp::LShr, a, Val::ci(k as i64)))
            }
            BinOp::URem => pow2_exp(b).map(|k| {
                let mask = (1i64 << k) - 1;
                emit_bin(f, blk, pos, BinOp::And, a, Val::ci(mask))
            }),
            BinOp::SDiv => pow2_exp(b).map(|k| emit_sdiv_pow2(f, blk, pos, a, k).0),
            BinOp::SRem => pow2_exp(b).map(|k| {
                // x % 2^k  ==  x - ((x / 2^k) << k), with the corrected
                // signed quotient.
                let (q, pos) = emit_sdiv_pow2(f, blk, pos, a, k);
                let m = emit_bin(f, blk, pos, BinOp::Shl, q, Val::ci(k as i64));
                emit_bin(f, blk, pos + 1, BinOp::Sub, a, m)
            }),
            _ => None,
        };
        if let Some(v) = rewritten {
            f.replace_uses(Val::Inst(id), v);
            f.remove_inst(id);
            n += 1;
        }
    }
    n
}

fn emit_bin(f: &mut Function, blk: BlockId, pos: usize, op: BinOp, a: Val, b: Val) -> Val {
    Val::Inst(f.insert_inst(blk, pos, InstKind::Bin { op, a, b }, Type::I32))
}

fn emit_shl(f: &mut Function, blk: BlockId, pos: usize, a: Val, k: u32) -> Val {
    emit_bin(f, blk, pos, BinOp::Shl, a, Val::ci(k as i64))
}

/// Truncating signed division by `2^k`:
/// `sign = x >> 31; bias = sign >>> (32-k); q = (x + bias) >> k`.
/// Returns the quotient and the insertion position just past it.
fn emit_sdiv_pow2(f: &mut Function, blk: BlockId, pos: usize, x: Val, k: u32) -> (Val, usize) {
    let sign = emit_bin(f, blk, pos, BinOp::AShr, x, Val::ci(31));
    let bias = emit_bin(f, blk, pos + 1, BinOp::LShr, sign, Val::ci((32 - k) as i64));
    let sum = emit_bin(f, blk, pos + 2, BinOp::Add, x, bias);
    let q = emit_bin(f, blk, pos + 3, BinOp::AShr, sum, Val::ci(k as i64));
    (q, pos + 4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::interp::scalar;
    use crate::ir::verify::verify_function;
    use crate::ir::{Builder, Param};

    fn has_op(f: &Function, op: BinOp) -> bool {
        f.insts
            .iter()
            .any(|i| !i.dead && matches!(i.kind, InstKind::Bin { op: o, .. } if o == op))
    }

    /// Build `fn(x) -> x <op> c`, reduce it, and evaluate both versions
    /// through the scalar interpreter reference semantics.
    fn differential(op: BinOp, c: i64, inputs: &[i32]) {
        let mut f = Function::new(
            "t",
            vec![Param {
                name: "x".into(),
                ty: Type::I32,
                uniform: false,
            }],
            Type::I32,
        );
        let mut b = Builder::new(&mut f);
        let r = b.bin(op, Val::Arg(0), Val::ci(c));
        b.ret(Some(r));
        let reduced = run(&mut f);
        assert_eq!(reduced, 1, "{op:?} by {c} should reduce");
        assert!(!has_op(&f, op), "{op:?} survived reduction");
        verify_function(&f).unwrap();
        for &x in inputs {
            let want = scalar::bin_i(op, x as u32, c as u32);
            let got = eval(&f, x as u32);
            assert_eq!(
                got, want,
                "{op:?}: {x} vs {c}: got {got}, want {want} (reduced IR disagrees)"
            );
        }
    }

    /// Evaluate the straight-line single-block function on one input.
    fn eval(f: &Function, x: u32) -> u32 {
        let mut vals: std::collections::HashMap<InstId, u32> = Default::default();
        let get = |vals: &std::collections::HashMap<InstId, u32>, v: Val| -> u32 {
            match v {
                Val::Inst(i) => vals[&i],
                Val::Arg(0) => x,
                Val::I(c, _) => c as u32,
                _ => panic!("unexpected operand"),
            }
        };
        for &id in &f.blocks[f.entry.idx()].insts {
            match &f.inst(id).kind {
                InstKind::Bin { op, a, b } => {
                    let r = scalar::bin_i(*op, get(&vals, *a), get(&vals, *b));
                    vals.insert(id, r);
                }
                InstKind::Ret { val: Some(v) } => return get(&vals, *v),
                k => panic!("unexpected inst {k:?}"),
            }
        }
        panic!("no return")
    }

    const NEGATIVES: &[i32] = &[
        0,
        1,
        7,
        8,
        9,
        37,
        -1,
        -7,
        -8,
        -9,
        -37,
        i32::MAX,
        i32::MIN,
        i32::MIN + 1,
    ];

    /// Golden rule (c): signed div/rem by powers of two preserve RISC-V
    /// truncating semantics for negative operands.
    #[test]
    fn signed_div_rem_semantics_preserved() {
        for c in [2i64, 4, 8, 1 << 15, 1 << 30] {
            differential(BinOp::SDiv, c, NEGATIVES);
            differential(BinOp::SRem, c, NEGATIVES);
        }
    }

    #[test]
    fn unsigned_and_mul_reduce() {
        for c in [2i64, 16, 1 << 30] {
            differential(BinOp::Mul, c, NEGATIVES);
            differential(BinOp::UDiv, c, NEGATIVES);
            differential(BinOp::URem, c, NEGATIVES);
        }
    }

    /// Non-powers-of-two and non-constant divisors are left alone.
    #[test]
    fn leaves_non_pow2_alone() {
        let mut f = Function::new(
            "t",
            vec![
                Param {
                    name: "x".into(),
                    ty: Type::I32,
                    uniform: false,
                },
                Param {
                    name: "y".into(),
                    ty: Type::I32,
                    uniform: false,
                },
            ],
            Type::I32,
        );
        let mut b = Builder::new(&mut f);
        let a = b.bin(BinOp::SDiv, Val::Arg(0), Val::ci(7));
        let c = b.bin(BinOp::SRem, Val::Arg(0), Val::Arg(1));
        let d = b.add(a, c);
        b.ret(Some(d));
        assert_eq!(run(&mut f), 0);
        assert!(has_op(&f, BinOp::SDiv) && has_op(&f, BinOp::SRem));
    }

    /// Mul with the constant on the left also reduces.
    #[test]
    fn mul_constant_on_left() {
        let mut f = Function::new(
            "t",
            vec![Param {
                name: "x".into(),
                ty: Type::I32,
                uniform: false,
            }],
            Type::I32,
        );
        let mut b = Builder::new(&mut f);
        let r = b.bin(BinOp::Mul, Val::ci(8), Val::Arg(0));
        b.ret(Some(r));
        assert_eq!(run(&mut f), 1);
        assert!(!has_op(&f, BinOp::Mul));
        assert!(has_op(&f, BinOp::Shl));
        assert_eq!(eval(&f, 5), 40);
    }
}
