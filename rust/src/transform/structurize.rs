//! Control-flow structurization (paper §4.3.2).
//!
//! The Vortex IPDOM stack requires reducible (structured) control flow.
//! LLVM's StructurizeCFG linearizes unstructured regions behind computed
//! predicates; we reproduce that cost model with the classic *dispatcher*
//! construction: every irreducible region gets a single dispatch header
//! that routes control by a predicate variable. The predicate
//! store/load/compare chain is exactly the "linearization predicate"
//! overhead the CFG-reconstruction pass (paper Fig. 6, [`super::reconstruct`])
//! exists to avoid.
//!
//! MUST run before mem2reg: the front-end keeps all cross-block dataflow in
//! allocas, so retargeting edges and creating blocks needs no SSA repair.
//! (The dispatcher's predicate slot is itself an alloca that mem2reg later
//! promotes into the phi + compare chain form.)

use crate::ir::cfg::irreducible_back_edges_with;
use crate::ir::*;
use std::collections::HashSet;

#[derive(Debug, Default)]
pub struct StructurizeReport {
    /// Number of dispatcher headers created.
    pub dispatchers: usize,
    /// Total entry blocks routed through dispatchers.
    pub entries_routed: usize,
}

/// Strongly connected component containing `seed`, restricted to the
/// `allowed` node set (None = whole CFG).
fn scc_of(f: &Function, seed: BlockId, allowed: Option<&HashSet<BlockId>>) -> HashSet<BlockId> {
    let ok = |b: BlockId| allowed.map(|a| a.contains(&b)).unwrap_or(true);
    let mut fwd: HashSet<BlockId> = HashSet::new();
    let mut stack = vec![seed];
    while let Some(b) = stack.pop() {
        if ok(b) && fwd.insert(b) {
            for s in f.succs(b) {
                stack.push(s);
            }
        }
    }
    let preds = f.preds();
    let mut bwd: HashSet<BlockId> = HashSet::new();
    let mut stack = vec![seed];
    while let Some(b) = stack.pop() {
        if ok(b) && bwd.insert(b) {
            for &p in &preds[b.idx()] {
                stack.push(p);
            }
        }
    }
    fwd.intersection(&bwd).copied().collect()
}

/// Find the innermost multi-entry (irreducible) region around `m` by
/// repeatedly peeling single-entry loop headers (Havlak-style nesting
/// descent): the whole-graph SCC of an irreducible region nested inside a
/// reducible loop has just that loop's header as entry.
fn find_irreducible_region(
    f: &Function,
    m: BlockId,
) -> Option<(HashSet<BlockId>, Vec<BlockId>)> {
    let mut region = scc_of(f, m, None);
    for _ in 0..f.blocks.len() + 1 {
        if region.len() < 2 {
            return None;
        }
        let entries = region_entries(f, &region);
        if entries.len() >= 2 {
            return Some((region, entries));
        }
        let h = entries[0];
        let mut allowed = region.clone();
        allowed.remove(&h);
        if !allowed.contains(&m) {
            return None;
        }
        region = scc_of(f, m, Some(&allowed));
    }
    None
}

/// Entries of a region: blocks with a predecessor outside the region
/// (or the function entry itself).
fn region_entries(f: &Function, region: &HashSet<BlockId>) -> Vec<BlockId> {
    let preds = f.preds();
    let mut entries: Vec<BlockId> = region
        .iter()
        .copied()
        .filter(|&b| b == f.entry || preds[b.idx()].iter().any(|p| !region.contains(p)))
        .collect();
    entries.sort();
    entries
}

/// Structurize the function: repeatedly find an irreducible region and
/// route all its entries through a dispatcher block keyed on a predicate
/// slot. Terminates because each dispatcher strictly reduces the number of
/// multi-entry SCCs; bounded at 64 iterations defensively.
pub fn run(f: &mut Function) -> StructurizeReport {
    let mut report = StructurizeReport::default();
    for _ in 0..64 {
        let dom = f.dom_tree();
        let offending = irreducible_back_edges_with(f, &dom);
        let Some(&(_, m)) = offending.first() else {
            return report;
        };
        let (region, entries) = find_irreducible_region(f, m)
            .expect("offending back edge must sit in a multi-entry region");
        let _ = &region;
        // No phis allowed (pre-SSA contract).
        for &e in &entries {
            assert!(
                !f.blocks[e.idx()]
                    .insts
                    .iter()
                    .any(|&i| matches!(f.inst(i).kind, InstKind::Phi { .. })),
                "structurize must run before SSA construction"
            );
        }
        dispatch_region(f, &entries);
        f.invalidate_cfg_cache();
        report.dispatchers += 1;
        report.entries_routed += entries.len();
    }
    panic!("structurization did not converge in 64 iterations");
}

/// Create the dispatcher for the given entry set and reroute every edge
/// into any entry through it.
fn dispatch_region(f: &mut Function, entries: &[BlockId]) {
    // Predicate slot, allocated in (a possibly fresh) entry block.
    let entry_in_region = entries.contains(&f.entry);
    let alloca_block = if entry_in_region {
        // Create a fresh function entry that falls into the dispatcher.
        let ne = f.add_block("entry2");
        ne
    } else {
        f.entry
    };
    let slot = f.insert_inst(
        alloca_block,
        0,
        InstKind::Alloca { size: 4 },
        Type::Ptr(AddrSpace::Private),
    );

    // Dispatch header: load slot, compare-chain to entries.
    let d = f.add_block("dispatch");
    let ld = f.push_inst(
        d,
        InstKind::Load {
            ptr: Val::Inst(slot),
        },
        Type::I32,
    );
    // Chain blocks: d tests entries[0]; chain_i tests entries[i].
    let mut chain_blocks = vec![d];
    for i in 1..entries.len().saturating_sub(1) {
        chain_blocks.push(f.add_block("dchain"));
        let _ = i;
    }
    for (i, &cb) in chain_blocks.iter().enumerate() {
        let is_last_test = i + 1 == chain_blocks.len();
        let cond = f.push_inst(
            cb,
            InstKind::ICmp {
                pred: ICmp::Eq,
                a: Val::Inst(ld),
                b: Val::ci(i as i64),
            },
            Type::I1,
        );
        let fallthrough = if is_last_test {
            // last test: false -> final entry
            entries[entries.len() - 1]
        } else {
            chain_blocks[i + 1]
        };
        f.push_inst(
            cb,
            InstKind::CondBr {
                cond: Val::Inst(cond),
                t: entries[i],
                f: fallthrough,
            },
            Type::Void,
        );
    }
    if chain_blocks.len() == 1 && entries.len() == 1 {
        unreachable!();
    }
    // Reroute all edges into each entry (from anywhere) through d, storing
    // the selector first.
    let all_blocks = f.block_ids();
    for b in all_blocks {
        if b == d || chain_blocks.contains(&b) {
            continue;
        }
        if f.blocks[b.idx()].insts.is_empty() {
            continue;
        }
        let term = f.term(b);
        let succs = f.inst(term).kind.successors();
        for (i, &e) in entries.iter().enumerate() {
            if succs.contains(&e) {
                // Edge b -> e: go through a stub that stores i and jumps d.
                let stub = f.add_block("dstore");
                f.push_inst(
                    stub,
                    InstKind::Store {
                        ptr: Val::Inst(slot),
                        val: Val::ci(i as i64),
                    },
                    Type::Void,
                );
                f.push_inst(stub, InstKind::Br { target: d }, Type::Void);
                f.inst_mut(term).kind.replace_successor(e, stub);
            }
        }
    }
    // Fresh function entry if the old one was inside the region.
    if entry_in_region {
        let old_entry = f.entry;
        let idx = entries.iter().position(|&e| e == old_entry).unwrap();
        f.push_inst(
            alloca_block,
            InstKind::Store {
                ptr: Val::Inst(slot),
                val: Val::ci(idx as i64),
            },
            Type::Void,
        );
        f.push_inst(alloca_block, InstKind::Br { target: d }, Type::Void);
        f.entry = alloca_block;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::cfg::is_reducible;
    use crate::ir::verify::verify_function;
    use crate::ir::{Builder, Param};

    /// The classic two-headed loop becomes reducible and keeps semantics.
    /// Program: x starts at arg; loop A: x+=1, if x<10 goto B else exit;
    /// B: x+=100, if x<200 goto A else exit. Entered at A or B based on c.
    fn build_irreducible() -> Module {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![
                Param {
                    name: "out".into(),
                    ty: Type::Ptr(AddrSpace::Global),
                    uniform: true,
                },
                Param {
                    name: "c".into(),
                    ty: Type::I32,
                    uniform: true,
                },
            ],
            Type::Void,
        );
        let a = f.add_block("a");
        let bb = f.add_block("b");
        let exit = f.add_block("x");
        let mut b = Builder::new(&mut f);
        let x = b.alloca(4);
        b.store(x, Val::ci(0));
        let c = b.icmp(ICmp::Ne, Val::Arg(1), Val::ci(0));
        b.cond_br(c, a, bb);
        b.set_block(a);
        let xv = b.load(x, Type::I32);
        let x1 = b.add(xv, Val::ci(1));
        b.store(x, x1);
        let ca = b.icmp(ICmp::Slt, x1, Val::ci(10));
        b.cond_br(ca, bb, exit);
        b.set_block(bb);
        let xv2 = b.load(x, Type::I32);
        let x2 = b.add(xv2, Val::ci(100));
        b.store(x, x2);
        let cb2 = b.icmp(ICmp::Slt, x2, Val::ci(200));
        b.cond_br(cb2, a, exit);
        b.set_block(exit);
        let xf = b.load(x, Type::I32);
        b.store(Val::Arg(0), xf);
        b.ret(None);
        m.add_func(f);
        m
    }

    fn run_and_read(m: &Module, c: u32) -> u32 {
        let mut mem = vec![0u8; 4096];
        crate::ir::interp::run_kernel_scalar(
            m,
            FuncId(0),
            &[64, c],
            [1, 1, 1],
            [1, 1, 1],
            &mut mem,
            2048,
            &[],
        )
        .unwrap();
        crate::ir::interp::read_u32(&mem, 64)
    }

    #[test]
    fn dispatch_makes_reducible_and_preserves_semantics() {
        let m0 = build_irreducible();
        assert!(!is_reducible(&m0.funcs[0]));
        let before: Vec<u32> = [0u32, 1].iter().map(|&c| run_and_read(&m0, c)).collect();
        let mut m = m0.clone();
        let rep = run(&mut m.funcs[0]);
        assert!(rep.dispatchers >= 1);
        assert!(is_reducible(&m.funcs[0]));
        verify_function(&m.funcs[0]).unwrap();
        let after: Vec<u32> = [0u32, 1].iter().map(|&c| run_and_read(&m, c)).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn reducible_input_untouched() {
        let mut m = Module::new("t");
        let mut f = Function::new("k", vec![], Type::Void);
        let h = f.add_block("h");
        let x = f.add_block("x");
        let mut b = Builder::new(&mut f);
        b.br(h);
        b.set_block(h);
        b.cond_br(Val::cb(true), h, x);
        b.set_block(x);
        b.ret(None);
        let rep = run(&mut f);
        assert_eq!(rep.dispatchers, 0);
        m.add_func(f);
    }

    /// Entry-in-region case: loop straight back to the function entry.
    #[test]
    fn entry_inside_irreducible_region() {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![Param {
                name: "out".into(),
                ty: Type::Ptr(AddrSpace::Global),
                uniform: true,
            }],
            Type::Void,
        );
        // entry <-> b two-headed-ish: entry -> b, b -> entry (back into entry),
        // entry -> exit. Entry has implicit external entry: multi-entry SCC.
        let bb = f.add_block("b");
        let exit = f.add_block("x");
        let entry0 = f.entry;
        let mut b = Builder::new(&mut f);
        let x = b.alloca(4);
        let xv = b.load(x, Type::I32);
        let x1 = b.add(xv, Val::ci(1));
        b.store(x, x1);
        let c = b.icmp(ICmp::Slt, x1, Val::ci(3));
        b.cond_br(c, bb, exit);
        b.set_block(bb);
        b.br(entry0);
        b.set_block(exit);
        let xf = b.load(x, Type::I32);
        b.store(Val::Arg(0), xf);
        b.ret(None);
        // NOTE: alloca-in-entry gets re-executed per iteration in this
        // contrived graph; the interpreter bumps sp each time but the slot
        // address changes, so avoid interp comparison here and just check
        // structure.
        let _rep = run(&mut f);
        assert!(is_reducible(&f));
        verify_function(&f).unwrap();
        m.add_func(f);
    }
}
