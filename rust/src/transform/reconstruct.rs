//! CFG reconstruction (paper §4.3.2, Fig. 6).
//!
//! When unstructured regions are deeply nested, the dispatcher/linearization
//! predicates become expensive. VOLT instead *selectively duplicates* nodes
//! to simplify predicates: when an unstructured block is a **divergent CDG
//! leaf**, duplicating it per entering edge removes the irreducible entry
//! without any predicate computation. If the controlling dependency is
//! uniform, each warp takes a single pass through the dispatcher anyway and
//! duplication buys nothing — so uniform nodes are left to the dispatcher.
//!
//! Runs before SSA construction (same contract as [`super::structurize`]),
//! immediately before it in the pipeline; whatever this pass does not
//! resolve, the dispatcher will.

use crate::analysis::tti::TargetDivergenceInfo;
use crate::analysis::{uniformity, UniformityOptions};
use crate::ir::cdg::Cdg;
use crate::ir::cfg::irreducible_back_edges_with;
use crate::ir::*;

#[derive(Debug, Default)]
pub struct ReconReport {
    pub duplicated: usize,
    pub leaf_duplications: usize,
    pub skipped_uniform: usize,
    pub skipped_unsafe: usize,
}

/// Maximum instruction count of a node eligible for duplication.
const DUP_LIMIT: usize = 24;

pub fn run(
    m: &mut Module,
    fid: FuncId,
    opts: &UniformityOptions,
    tti: &dyn TargetDivergenceInfo,
) -> ReconReport {
    let mut report = ReconReport::default();
    if !opts_enabled(opts) {
        return report;
    }
    for _ in 0..32 {
        let dom = m.func_mut(fid).dom_tree();
        let offending = irreducible_back_edges_with(m.func(fid), &dom);
        if offending.is_empty() {
            break;
        }
        // Try to fix one offending edge by duplicating its target.
        let u = uniformity::analyze_cached(m, fid, opts, tti);
        let pdom = m.func_mut(fid).pdom_tree();
        let f = m.func(fid);
        let cdg = Cdg::build_with(f, &pdom);
        let mut progressed = false;
        for &(n, mm) in &offending {
            // Paper rule: duplicate only divergent CDG leaf nodes.
            let divergent = cdg.deps[mm.idx()]
                .iter()
                .any(|dep| u.div_branch_blocks.contains(dep));
            if !divergent {
                report.skipped_uniform += 1;
                continue;
            }
            // Prefer CDG leaves (the paper's Fig. 6 case); inside cyclic
            // irreducible regions the entry nodes usually have dependents,
            // so non-leaf nodes are still eligible when small and safe.
            if cdg.is_leaf(mm) {
                report.leaf_duplications += 1;
            }
            if !duplicable(f, mm, DUP_LIMIT) {
                report.skipped_unsafe += 1;
                continue;
            }
            duplicate_node(m.func_mut(fid), n, mm);
            m.func_mut(fid).invalidate_cfg_cache();
            report.duplicated += 1;
            progressed = true;
            break;
        }
        if !progressed {
            break; // leave the rest for the dispatcher
        }
    }
    report
}

fn opts_enabled(_opts: &UniformityOptions) -> bool {
    true // gating on the Recon flag happens in the pass manager
}

/// A node is duplicable when it is small, has no phis, and none of its
/// instructions are referenced outside the node (pre-SSA front-end IR
/// guarantees this for all frontend-emitted blocks).
fn duplicable(f: &Function, b: BlockId, limit: usize) -> bool {
    let insts = &f.blocks[b.idx()].insts;
    if insts.len() > limit {
        return false;
    }
    for &i in insts {
        if matches!(f.inst(i).kind, InstKind::Phi { .. } | InstKind::Alloca { .. }) {
            return false;
        }
    }
    // No external uses of values defined here.
    let mine: std::collections::HashSet<InstId> = insts.iter().copied().collect();
    for (idx, inst) in f.insts.iter().enumerate() {
        if inst.dead || mine.contains(&InstId(idx as u32)) {
            continue;
        }
        for op in inst.kind.operands() {
            if let Val::Inst(d) = op {
                if mine.contains(&d) {
                    return false;
                }
            }
        }
    }
    true
}

/// Duplicate block `b` as `b2` and retarget the edge `n -> b` to `n -> b2`.
pub fn duplicate_node(f: &mut Function, n: BlockId, b: BlockId) -> BlockId {
    let b2 = f.add_block(&format!("{}.dup", f.blocks[b.idx()].name.clone()));
    let insts = f.blocks[b.idx()].insts.clone();
    let mut map: std::collections::HashMap<InstId, InstId> = Default::default();
    for &i in &insts {
        let mut kind = f.inst(i).kind.clone();
        kind.map_operands(|v| match v {
            Val::Inst(d) if map.contains_key(&d) => Val::Inst(map[&d]),
            v => v,
        });
        let ty = f.inst(i).ty;
        let ni = f.push_inst(b2, kind, ty);
        f.insts[ni.idx()].uniform_ann = f.insts[i.idx()].uniform_ann;
        f.insts[ni.idx()].loc = f.insts[i.idx()].loc;
        map.insert(i, ni);
    }
    let t = f.term(n);
    f.inst_mut(t).kind.replace_successor(b, b2);
    b2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::tti::VortexTti;
    use crate::ir::cfg::is_reducible;
    use crate::ir::verify::verify_function;
    use crate::ir::{Builder, Param};

    /// Irreducible region whose second header is a divergent CDG leaf:
    /// reconstruction should duplicate it instead of needing a dispatcher.
    fn build(divergent: bool) -> Module {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![
                Param {
                    name: "out".into(),
                    ty: Type::Ptr(AddrSpace::Global),
                    uniform: true,
                },
                Param {
                    name: "c".into(),
                    ty: Type::I32,
                    uniform: true,
                },
            ],
            Type::Void,
        );
        let a = f.add_block("a");
        let d = f.add_block("d"); // the node to duplicate
        let exit = f.add_block("x");
        let mut b = Builder::new(&mut f);
        let x = b.alloca(4);
        b.store(x, Val::ci(0));
        let c = if divergent {
            let lane = b.intr(Intr::Csr(Csr::LaneId), vec![]);
            b.icmp(ICmp::Slt, lane, Val::Arg(1))
        } else {
            b.icmp(ICmp::Ne, Val::Arg(1), Val::ci(0))
        };
        b.cond_br(c, a, d);
        // a: x += 1; if x < 5 -> d else exit
        b.set_block(a);
        let xv = b.load(x, Type::I32);
        let x1 = b.add(xv, Val::ci(1));
        b.store(x, x1);
        let ca = b.icmp(ICmp::Slt, x1, Val::ci(5));
        b.cond_br(ca, d, exit);
        // d: x += 10; if x < 40 -> a else exit   (d -> a is the irreducible edge)
        b.set_block(d);
        let xv2 = b.load(x, Type::I32);
        let x2 = b.add(xv2, Val::ci(10));
        b.store(x, x2);
        let cd = b.icmp(ICmp::Slt, x2, Val::ci(40));
        b.cond_br(cd, a, exit);
        b.set_block(exit);
        let xf = b.load(x, Type::I32);
        b.store(Val::Arg(0), xf);
        b.ret(None);
        m.add_func(f);
        m
    }

    fn run_and_read(m: &Module, c: u32) -> u32 {
        let mut mem = vec![0u8; 4096];
        crate::ir::interp::run_kernel_scalar(
            m,
            FuncId(0),
            &[64, c],
            [1, 1, 1],
            [1, 1, 1],
            &mut mem,
            2048,
            &[],
        )
        .unwrap();
        crate::ir::interp::read_u32(&mem, 64)
    }

    #[test]
    fn duplicates_divergent_leaf() {
        let m0 = build(true);
        assert!(!is_reducible(&m0.funcs[0]));
        let before: Vec<u32> = [0u32, 64].iter().map(|&c| run_and_read(&m0, c)).collect();
        let mut m = m0.clone();
        let rep = run(&mut m, FuncId(0), &UniformityOptions::default(), &VortexTti);
        assert!(rep.duplicated >= 1, "report: {rep:?}");
        verify_function(&m.funcs[0]).unwrap();
        let after: Vec<u32> = [0u32, 64].iter().map(|&c| run_and_read(&m, c)).collect();
        assert_eq!(before, after);
        // The region should now be reducible without any dispatcher.
        assert!(is_reducible(&m.funcs[0]));
    }

    #[test]
    fn uniform_leaf_left_for_dispatcher() {
        let m0 = build(false);
        let mut m = m0.clone();
        // Uniform branch condition (uniform arg + Uni-HW reasoning).
        let rep = run(
            &mut m,
            FuncId(0),
            &UniformityOptions::all(),
            &VortexTti,
        );
        assert_eq!(rep.duplicated, 0);
        assert!(rep.skipped_uniform > 0);
        assert!(!is_reducible(&m.funcs[0]));
    }
}
