//! Uniformity-aware global value numbering (the first O3 rung pass).
//!
//! Classic dominator-tree GVN/CSE with a scoped hash table: walking the
//! dominator tree in preorder, a pure instruction whose expression key is
//! already available from a dominating definition is replaced by that
//! definition. Two SIMT-specific refinements on top of the textbook pass:
//!
//! * **Dominating divergent splits are barriers.** A merge is refused when
//!   the dominator-tree path from the use back to the dominating
//!   definition crosses a block whose terminator is a *divergent* branch
//!   (per [`crate::analysis::uniformity`]): reusing the value would pin a
//!   divergent live range end-to-end across the {vx_split, vx_join}
//!   region the back-end later materializes, and recomputing inside the
//!   arm is the cheap, conservative choice. Divergent branches that do
//!   *not* dominate the use (merge blocks reachable around a split) are
//!   deliberately no barrier — SSA dominance plus the per-lane register
//!   file make reuse across a reconvergence point mask-safe (see
//!   `Uniformity::crosses_divergent_branch` for the precise guarantee).
//!   *Uniform* branches are no barrier either — this is what the
//!   centralized uniformity analysis buys: a naive tmask-paranoid CSE
//!   would have to refuse every branch.
//! * **Block-local load CSE.** A repeated load from the same address with
//!   no intervening store / atomic / call / barrier in the same block
//!   reuses the earlier result. Same-block reuse crosses no branch at all,
//!   so no divergence reasoning is needed.

use crate::analysis::tti::TargetDivergenceInfo;
use crate::analysis::{uniformity, UniformityOptions};
use crate::ir::*;
use std::collections::HashMap;

/// Hashable key for a pure expression. Only side-effect-free,
/// non-memory, non-mask-dependent instructions get a key.
#[derive(Clone, PartialEq, Eq, Hash)]
enum ExprKey {
    Bin(BinOp, Val, Val),
    Un(UnOp, Val),
    ICmp(ICmp, Val, Val),
    FCmp(FCmp, Val, Val),
    Select(Val, Val, Val),
    Gep(Val, Val, u32, i32),
}

/// Deterministic ordering key so commutative operands canonicalize.
fn val_rank(v: Val) -> (u8, u64, u64) {
    match v {
        Val::Inst(i) => (0, i.0 as u64, 0),
        Val::Arg(i) => (1, i as u64, 0),
        Val::I(x, t) => (2, x as u64, type_rank(t)),
        Val::F(b) => (3, b as u64, 0),
        Val::G(g) => (4, g.0 as u64, 0),
    }
}

fn type_rank(t: Type) -> u64 {
    match t {
        Type::Void => 0,
        Type::I1 => 1,
        Type::I32 => 2,
        Type::F32 => 3,
        Type::Ptr(AddrSpace::Global) => 4,
        Type::Ptr(AddrSpace::Local) => 5,
        Type::Ptr(AddrSpace::Const) => 6,
        Type::Ptr(AddrSpace::Private) => 7,
    }
}

fn expr_key(kind: &InstKind) -> Option<ExprKey> {
    Some(match *kind {
        InstKind::Bin { op, a, b } => {
            let (a, b) = if op.is_commutative() && val_rank(b) < val_rank(a) {
                (b, a)
            } else {
                (a, b)
            };
            ExprKey::Bin(op, a, b)
        }
        InstKind::Un { op, a } => ExprKey::Un(op, a),
        InstKind::ICmp { pred, a, b } => ExprKey::ICmp(pred, a, b),
        InstKind::FCmp { pred, a, b } => ExprKey::FCmp(pred, a, b),
        InstKind::Select { cond, t, f } => ExprKey::Select(cond, t, f),
        InstKind::Gep {
            base,
            index,
            scale,
            disp,
        } => ExprKey::Gep(base, index, scale, disp),
        _ => return None,
    })
}

/// Run GVN over one function. Returns the number of merged instructions.
pub fn run(
    m: &mut Module,
    fid: FuncId,
    opts: &UniformityOptions,
    tti: &dyn TargetDivergenceInfo,
) -> usize {
    let u = uniformity::analyze_cached(m, fid, opts, tti);
    let f = &mut m.funcs[fid.idx()];
    let dom = f.dom_tree();
    let children = dom.children();
    let mut merged = 0;

    // Scoped available-expression table: key -> stack of dominating defs.
    let mut table: HashMap<ExprKey, Vec<InstId>> = HashMap::new();
    // Preorder DFS with explicit exit events for scope popping.
    let mut work: Vec<(BlockId, bool)> = vec![(f.entry, false)];
    let mut scope_added: HashMap<BlockId, Vec<ExprKey>> = HashMap::new();
    while let Some((b, exiting)) = work.pop() {
        if exiting {
            for key in scope_added.remove(&b).unwrap_or_default() {
                if let Some(stack) = table.get_mut(&key) {
                    stack.pop();
                    if stack.is_empty() {
                        table.remove(&key);
                    }
                }
            }
            continue;
        }
        work.push((b, true));
        for &c in children[b.idx()].iter().rev() {
            work.push((c, false));
        }

        let mut added: Vec<ExprKey> = vec![];
        // Block-local load CSE state: address -> available load result.
        let mut avail_loads: HashMap<Val, InstId> = HashMap::new();
        for id in f.blocks[b.idx()].insts.clone() {
            if f.insts[id.idx()].dead {
                continue;
            }
            let kind = f.inst(id).kind.clone();
            match &kind {
                InstKind::Load { ptr } => {
                    if let Some(&prev) = avail_loads.get(ptr) {
                        if f.inst(prev).ty == f.inst(id).ty {
                            f.replace_uses(Val::Inst(id), Val::Inst(prev));
                            f.remove_inst(id);
                            merged += 1;
                            continue;
                        }
                    }
                    avail_loads.insert(*ptr, id);
                    continue;
                }
                InstKind::Store { .. } | InstKind::Call { .. } => {
                    avail_loads.clear();
                    continue;
                }
                InstKind::Intr { intr, .. } => {
                    if intr.clobbers_memory() {
                        avail_loads.clear();
                    }
                    continue;
                }
                _ => {}
            }
            let Some(key) = expr_key(&kind) else { continue };
            if let Some(&prev) = table.get(&key).and_then(|s| s.last()) {
                let def_b = f.inst(prev).block;
                if !u.crosses_divergent_branch(&dom, b, def_b, true, &|_| false) {
                    f.replace_uses(Val::Inst(id), Val::Inst(prev));
                    f.remove_inst(id);
                    merged += 1;
                    continue;
                }
            }
            table.entry(key.clone()).or_default().push(id);
            added.push(key);
        }
        scope_added.insert(b, added);
    }
    merged
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::tti::VortexTti;
    use crate::ir::verify::verify_function;
    use crate::ir::{Builder, Param};

    fn opts_all() -> UniformityOptions {
        UniformityOptions::all()
    }

    fn count_muls(f: &Function) -> usize {
        f.insts
            .iter()
            .filter(|i| !i.dead && matches!(i.kind, InstKind::Bin { op: BinOp::Mul, .. }))
            .count()
    }

    /// A redundant expression in a dominated block merges with the
    /// dominating definition when only *uniform* branches separate them.
    #[test]
    fn merges_across_uniform_branch() {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![
                Param {
                    name: "out".into(),
                    ty: Type::Ptr(AddrSpace::Global),
                    uniform: true,
                },
                Param {
                    name: "n".into(),
                    ty: Type::I32,
                    uniform: true,
                },
            ],
            Type::Void,
        );
        let t = f.add_block("t");
        let e = f.add_block("e");
        let mut b = Builder::new(&mut f);
        let gid = b.intr(Intr::WorkItem(WorkItem::GlobalId), vec![Val::ci(0)]);
        let x1 = b.mul(gid, Val::ci(3)); // divergent value
        let c = b.icmp(ICmp::Ne, Val::Arg(1), Val::ci(0)); // uniform branch
        b.cond_br(c, t, e);
        b.set_block(t);
        let x2 = b.mul(gid, Val::ci(3)); // redundant
        let p = b.gep(Val::Arg(0), gid, 4);
        b.store(p, x2);
        b.br(e);
        b.set_block(e);
        b.ret(None);
        let _ = x1;
        let fid = m.add_func(f);
        let merged = run(&mut m, fid, &opts_all(), &VortexTti);
        assert_eq!(merged, 1, "uniform-branch merge should fire");
        assert_eq!(count_muls(&m.funcs[0]), 1);
        verify_function(&m.funcs[0]).unwrap();
    }

    /// Golden rule (a): GVN never merges an op across a divergent split —
    /// the identical expression inside the divergent arm is recomputed.
    #[test]
    fn never_merges_across_divergent_split() {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![Param {
                name: "out".into(),
                ty: Type::Ptr(AddrSpace::Global),
                uniform: true,
            }],
            Type::Void,
        );
        let t = f.add_block("t");
        let e = f.add_block("e");
        let mut b = Builder::new(&mut f);
        let gid = b.intr(Intr::WorkItem(WorkItem::GlobalId), vec![Val::ci(0)]);
        let x1 = b.mul(gid, Val::ci(3));
        let c = b.icmp(ICmp::Slt, gid, Val::ci(8)); // divergent branch
        b.cond_br(c, t, e);
        b.set_block(t);
        let x2 = b.mul(gid, Val::ci(3)); // same expr, inside the divergent arm
        let p = b.gep(Val::Arg(0), gid, 4);
        b.store(p, x2);
        b.br(e);
        b.set_block(e);
        b.ret(None);
        let _ = x1;
        let fid = m.add_func(f);
        let merged = run(&mut m, fid, &opts_all(), &VortexTti);
        assert_eq!(merged, 0, "must not merge across a divergent split");
        assert_eq!(count_muls(&m.funcs[0]), 2);
        verify_function(&m.funcs[0]).unwrap();
    }

    /// Same-block redundancy always merges (local CSE), and commutative
    /// operands canonicalize.
    #[test]
    fn local_cse_and_commutativity() {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![
                Param {
                    name: "a".into(),
                    ty: Type::I32,
                    uniform: false,
                },
                Param {
                    name: "b".into(),
                    ty: Type::I32,
                    uniform: false,
                },
            ],
            Type::I32,
        );
        let mut b = Builder::new(&mut f);
        let s1 = b.add(Val::Arg(0), Val::Arg(1));
        let s2 = b.add(Val::Arg(1), Val::Arg(0)); // commuted duplicate
        let r = b.mul(s1, s2);
        b.ret(Some(r));
        let fid = m.add_func(f);
        let merged = run(&mut m, fid, &opts_all(), &VortexTti);
        assert_eq!(merged, 1);
        verify_function(&m.funcs[0]).unwrap();
        // The mul now squares the single surviving add.
        let mul = m.funcs[0]
            .insts
            .iter()
            .find(|i| !i.dead && matches!(i.kind, InstKind::Bin { op: BinOp::Mul, .. }))
            .unwrap();
        let ops = mul.kind.operands();
        assert_eq!(ops[0], ops[1]);
    }

    /// Block-local load CSE fires without an intervening store and is
    /// killed by one.
    #[test]
    fn local_load_cse_respects_clobbers() {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![Param {
                name: "p".into(),
                ty: Type::Ptr(AddrSpace::Global),
                uniform: true,
            }],
            Type::I32,
        );
        let mut b = Builder::new(&mut f);
        let l1 = b.load(Val::Arg(0), Type::I32);
        let l2 = b.load(Val::Arg(0), Type::I32); // redundant
        let s = b.add(l1, l2);
        b.store(Val::Arg(0), s);
        let l3 = b.load(Val::Arg(0), Type::I32); // NOT redundant: store between
        let r = b.add(s, l3);
        b.ret(Some(r));
        let fid = m.add_func(f);
        let merged = run(&mut m, fid, &opts_all(), &VortexTti);
        assert_eq!(merged, 1);
        let loads = m.funcs[0]
            .insts
            .iter()
            .filter(|i| !i.dead && matches!(i.kind, InstKind::Load { .. }))
            .count();
        assert_eq!(loads, 2);
        verify_function(&m.funcs[0]).unwrap();
    }
}
