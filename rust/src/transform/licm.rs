//! Uniformity-aware loop-invariant code motion (second O3 rung pass).
//!
//! Works the natural-loop forest innermost-first: for each loop it
//! guarantees a preheader, then hoists side-effect-free instructions whose
//! operands are all defined outside the loop. SIMT rules on top of the
//! classic pass:
//!
//! * **No hoisting across a divergent split.** An instruction nested under
//!   a divergent *non-loop* branch inside the loop stays put: moving it to
//!   the preheader would execute it under the pre-split thread mask, the
//!   exact hazard the `vx_split`/`vx_join` planning assumes away (paper
//!   §4.3.3). Uniform in-loop branches are no barrier — every active lane
//!   agrees on them, so preheader execution is equivalent.
//! * **Loads are hoisted only non-speculatively.** A load moves out only
//!   if the loop body contains no store / atomic / call / barrier (our
//!   conservative aliasing), its block dominates every exiting block (it
//!   executes on every trip, so the preheader copy is not speculative),
//!   and — the temporal-divergence rule — it is refused outright when the
//!   load result is divergent *and* the loop has a divergent exiting
//!   branch: after TRANSFORM_LOOP the body runs under a shrinking
//!   `vx_pred` mask, and a pre-loop full-mask execution of a per-lane
//!   address is exactly the Fig. 5-class speculation the safety net exists
//!   to catch.
//!
//! Divisions are hoistable: the target has RISC-V div/rem-by-zero
//! semantics (defined results, no traps), so speculation cannot fault.

use crate::analysis::tti::TargetDivergenceInfo;
use crate::analysis::{uniformity, UniformityOptions};
use crate::ir::dom::DomTree;
use crate::ir::loops::{ensure_preheader, LoopInfo};
use crate::ir::*;
use std::collections::HashSet;

/// Per-loop hoist budget. Every hoisted value is live across the whole
/// loop body, so an uncapped hoist set converts redundant recomputation
/// into register pressure and, past the allocatable set, into spill
/// traffic *inside* the loop — strictly worse than what LICM removed
/// (the PR-2 postmortem hazard). Because [`run`] works the loop forest
/// innermost-first and each loop spends its own budget, deeper
/// (hotter, trip-count-multiplied) loops claim their hoists before any
/// enclosing loop gets a turn — the loop-depth-weighted preference.
pub const MAX_HOISTS_PER_LOOP: usize = 16;

/// Run LICM over one function. Returns the number of hoisted instructions.
pub fn run(
    m: &mut Module,
    fid: FuncId,
    opts: &UniformityOptions,
    tti: &dyn TargetDivergenceInfo,
) -> usize {
    let mut hoisted = 0;
    let mut processed: HashSet<BlockId> = HashSet::new();
    // One loop per iteration, innermost (deepest) first; analyses are
    // rebuilt after each loop because hoisting moves definitions into
    // preheaders that enclosing loops must then see as loop-interior.
    loop {
        let f = &mut m.funcs[fid.idx()];
        let dom0 = f.dom_tree();
        let li = LoopInfo::build_with(f, &dom0);
        let cand = (0..li.loops.len())
            .filter(|&i| !processed.contains(&li.loops[i].header))
            .max_by_key(|&i| li.loops[i].depth);
        let Some(ci) = cand else { break };
        let header = li.loops[ci].header;
        let blocks = li.loops[ci].blocks.clone();
        processed.insert(header);
        if header == f.entry {
            continue; // degenerate loop back to entry: no place to hoist to
        }
        let ph = ensure_preheader(f, header, &blocks);
        let dom = f.dom_tree();
        let u = uniformity::analyze_cached(m, fid, opts, tti);
        let f = &mut m.funcs[fid.idx()];
        hoisted += hoist_loop(f, &dom, &u, header, &blocks, ph);
    }
    hoisted
}

/// Pure, always-safe-to-speculate instruction kinds.
fn speculatable(kind: &InstKind) -> bool {
    matches!(
        kind,
        InstKind::Bin { .. }
            | InstKind::Un { .. }
            | InstKind::ICmp { .. }
            | InstKind::FCmp { .. }
            | InstKind::Select { .. }
            | InstKind::Gep { .. }
    )
}

fn operands_invariant(f: &Function, blocks: &HashSet<BlockId>, id: InstId) -> bool {
    f.inst(id).kind.operands().iter().all(|v| match v {
        Val::Inst(d) => !blocks.contains(&f.inst(*d).block),
        _ => true,
    })
}

fn hoist_loop(
    f: &mut Function,
    dom: &DomTree,
    u: &uniformity::Uniformity,
    header: BlockId,
    blocks: &HashSet<BlockId>,
    ph: BlockId,
) -> usize {
    // Loop-wide memory facts for the load rules.
    let mut mem_clobbered = false;
    for &b in blocks {
        for &id in &f.blocks[b.idx()].insts {
            match &f.inst(id).kind {
                InstKind::Store { .. } | InstKind::Call { .. } => mem_clobbered = true,
                InstKind::Intr { intr, .. } => {
                    if intr.clobbers_memory() {
                        mem_clobbered = true;
                    }
                }
                _ => {}
            }
        }
    }
    let exiting: Vec<BlockId> = blocks
        .iter()
        .copied()
        .filter(|&b| f.succs(b).iter().any(|s| !blocks.contains(s)))
        .collect();
    let divergent_exit = exiting.iter().any(|b| u.div_branch_blocks.contains(b));

    // Dominance-compatible order over the loop body.
    let order: Vec<BlockId> = f
        .rpo()
        .into_iter()
        .filter(|b| blocks.contains(b))
        .collect();
    let mut count = 0;
    'budget: loop {
        let mut changed = false;
        for &b in &order {
            for id in f.blocks[b.idx()].insts.clone() {
                if count >= MAX_HOISTS_PER_LOOP {
                    break 'budget;
                }
                if f.insts[id.idx()].dead {
                    continue;
                }
                let kind = &f.inst(id).kind;
                let ok = if speculatable(kind) {
                    true
                } else if matches!(kind, InstKind::Load { .. }) {
                    let load_div = u.inst_div.get(id.idx()).copied().unwrap_or(true);
                    !mem_clobbered
                        && exiting.iter().all(|&e| dom.dominates(b, e))
                        && !(load_div && divergent_exit)
                } else {
                    false
                };
                // Loop (latch/exiting) branches are exempt from the
                // divergent-split barrier: their divergence is temporal,
                // not a mask split the hoist would cross. The header's own
                // loop test is excluded via `check_to = false`.
                let loop_branch = |cur: BlockId| {
                    let succs = f.succs(cur);
                    succs.contains(&header) || succs.iter().any(|s| !blocks.contains(s))
                };
                if !ok
                    || !operands_invariant(f, blocks, id)
                    || u.crosses_divergent_branch(dom, b, header, false, &loop_branch)
                {
                    continue;
                }
                // Move to the preheader, just before its terminator.
                f.blocks[b.idx()].insts.retain(|&x| x != id);
                let pos = f.blocks[ph.idx()].insts.len() - 1;
                f.blocks[ph.idx()].insts.insert(pos, id);
                f.insts[id.idx()].block = ph;
                count += 1;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::tti::VortexTti;
    use crate::ir::interp::{read_u32, run_kernel_scalar};
    use crate::ir::verify::verify_function;
    use crate::ir::{Builder, Param};

    fn opts_all() -> UniformityOptions {
        UniformityOptions::all()
    }

    /// Kernel: for (i = 0; i < bound; i++) acc += n*3 [+ src[gid]];
    /// out[gid] = acc. The invariant load (when present) sits in the loop
    /// *header*, so it dominates the exiting block and only the
    /// divergence rules decide its fate.
    fn build_loop_kernel(divergent_bound: bool, with_load: bool) -> Module {
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![
                Param {
                    name: "out".into(),
                    ty: Type::Ptr(AddrSpace::Global),
                    uniform: true,
                },
                Param {
                    name: "n".into(),
                    ty: Type::I32,
                    uniform: true,
                },
                Param {
                    name: "src".into(),
                    ty: Type::Ptr(AddrSpace::Global),
                    uniform: true,
                },
            ],
            Type::Void,
        );
        f.is_kernel = true;
        f.linkage = Linkage::External;
        let entry = f.entry;
        let h = f.add_block("h");
        let body = f.add_block("body");
        let exit = f.add_block("exit");
        let mut b = Builder::at(&mut f, entry);
        let gid = b.intr(Intr::WorkItem(WorkItem::GlobalId), vec![Val::ci(0)]);
        let bound = if divergent_bound {
            b.bin(BinOp::And, gid, Val::ci(3))
        } else {
            Val::Arg(1)
        };
        b.br(h);
        b.set_block(h);
        let i = b.phi(Type::I32, vec![(entry, Val::ci(0))]);
        let acc = b.phi(Type::I32, vec![(entry, Val::ci(0))]);
        let step0 = if with_load {
            let p = b.gep(Val::Arg(2), gid, 4); // invariant address (gid from entry)
            Some(b.load(p, Type::I32)) // divergent result (arg-root load)
        } else {
            None
        };
        let c = b.icmp(ICmp::Slt, i, bound);
        b.cond_br(c, body, exit);
        b.set_block(body);
        let inv = b.mul(Val::Arg(1), Val::ci(3)); // loop-invariant
        let step = match step0 {
            Some(l) => b.add(inv, l),
            None => inv,
        };
        let acc2 = b.add(acc, step);
        let i2 = b.add(i, Val::ci(1));
        b.br(h);
        b.set_block(exit);
        let op = b.gep(Val::Arg(0), gid, 4);
        b.store(op, acc);
        b.ret(None);
        if let Val::Inst(ip) = i {
            if let InstKind::Phi { incs } = &mut f.inst_mut(ip).kind {
                incs.push((body, i2));
            }
        }
        if let Val::Inst(ap) = acc {
            if let InstKind::Phi { incs } = &mut f.inst_mut(ap).kind {
                incs.push((body, acc2));
            }
        }
        m.add_func(f);
        m
    }

    fn run_out(m: &Module) -> Vec<u32> {
        let mut mem = vec![0u8; 4096];
        // Seed src[0..4] with distinct values.
        for g in 0..4u32 {
            mem[(128 + g * 4) as usize..(128 + g * 4 + 4) as usize]
                .copy_from_slice(&(10 + 7 * g).to_le_bytes());
        }
        run_kernel_scalar(
            m,
            FuncId(0),
            &[256, 5, 128],
            [1, 1, 1],
            [4, 1, 1],
            &mut mem,
            2048,
            &[],
        )
        .unwrap();
        (0..4).map(|g| read_u32(&mem, 256 + g * 4)).collect()
    }

    fn block_of(f: &Function, pred: impl Fn(&InstKind) -> bool) -> Vec<BlockId> {
        f.insts
            .iter()
            .filter(|i| !i.dead && pred(&i.kind))
            .map(|i| i.block)
            .collect()
    }

    /// Invariant arithmetic hoists out of a uniform loop and semantics
    /// are preserved (interp differential).
    #[test]
    fn hoists_invariant_arithmetic() {
        let m0 = build_loop_kernel(false, false);
        let before = run_out(&m0);
        let mut m = m0.clone();
        let n = run(&mut m, FuncId(0), &opts_all(), &VortexTti);
        assert!(n >= 1, "expected a hoist, got {n}");
        verify_function(&m.funcs[0]).unwrap();
        // The mul no longer lives in the loop body.
        let li = LoopInfo::build(&m.funcs[0]);
        let mul_blocks = block_of(&m.funcs[0], |k| {
            matches!(k, InstKind::Bin { op: BinOp::Mul, .. })
        });
        for b in mul_blocks {
            assert!(
                !li.loops.iter().any(|l| l.blocks.contains(&b)),
                "mul still inside a loop"
            );
        }
        assert_eq!(before, run_out(&m));
        // Expected value: acc = 5 iterations * n*3 = 5 * 15 = 75.
        assert_eq!(before, vec![75; 4]);
    }

    /// Golden rule (b): a divergent load must not be hoisted out of a
    /// loop with a divergent exiting branch.
    #[test]
    fn refuses_divergent_load_from_divergent_loop() {
        let mut m = build_loop_kernel(true, true);
        run(&mut m, FuncId(0), &opts_all(), &VortexTti);
        verify_function(&m.funcs[0]).unwrap();
        let li = LoopInfo::build(&m.funcs[0]);
        let load_blocks = block_of(&m.funcs[0], |k| matches!(k, InstKind::Load { .. }));
        assert!(!load_blocks.is_empty());
        for b in load_blocks {
            assert!(
                li.loops.iter().any(|l| l.blocks.contains(&b)),
                "divergent load escaped a divergent loop"
            );
        }
    }

    /// The same load DOES hoist when the loop exit is uniform (and there
    /// are no stores in the body).
    #[test]
    fn hoists_load_from_uniform_loop() {
        let m0 = build_loop_kernel(false, true);
        let before = run_out(&m0);
        let mut m = m0.clone();
        let n = run(&mut m, FuncId(0), &opts_all(), &VortexTti);
        assert!(n >= 2, "expected gep+load+mul hoists, got {n}");
        assert_eq!(before, run_out(&m));
        verify_function(&m.funcs[0]).unwrap();
        let li = LoopInfo::build(&m.funcs[0]);
        let load_blocks = block_of(&m.funcs[0], |k| matches!(k, InstKind::Load { .. }));
        for b in load_blocks {
            assert!(
                !li.loops.iter().any(|l| l.blocks.contains(&b)),
                "load not hoisted from uniform loop"
            );
        }
    }

    /// More invariants than the per-loop budget: the cap holds (exactly
    /// MAX_HOISTS_PER_LOOP hoists), the rest stay in the loop, and
    /// semantics are unchanged (interp differential).
    #[test]
    fn hoist_cap_bounds_spill_pressure() {
        const N_INV: usize = 20;
        assert!(N_INV > MAX_HOISTS_PER_LOOP);
        let mut m = Module::new("t");
        let mut f = Function::new(
            "k",
            vec![
                Param {
                    name: "out".into(),
                    ty: Type::Ptr(AddrSpace::Global),
                    uniform: true,
                },
                Param {
                    name: "n".into(),
                    ty: Type::I32,
                    uniform: true,
                },
            ],
            Type::Void,
        );
        f.is_kernel = true;
        f.linkage = Linkage::External;
        let entry = f.entry;
        let h = f.add_block("h");
        let body = f.add_block("body");
        let exit = f.add_block("exit");
        let mut b = Builder::at(&mut f, entry);
        let gid = b.intr(Intr::WorkItem(WorkItem::GlobalId), vec![Val::ci(0)]);
        b.br(h);
        b.set_block(h);
        let i = b.phi(Type::I32, vec![(entry, Val::ci(0))]);
        let acc = b.phi(Type::I32, vec![(entry, Val::ci(0))]);
        let c = b.icmp(ICmp::Slt, i, Val::Arg(1));
        b.cond_br(c, body, exit);
        b.set_block(body);
        // N_INV independent loop-invariant computations, all summed.
        let mut step = Val::ci(0);
        for k in 0..N_INV {
            let inv = b.mul(Val::Arg(1), Val::ci(k as i64 + 3));
            step = b.add(step, inv);
        }
        let acc2 = b.add(acc, step);
        let i2 = b.add(i, Val::ci(1));
        b.br(h);
        b.set_block(exit);
        let op = b.gep(Val::Arg(0), gid, 4);
        b.store(op, acc);
        b.ret(None);
        if let Val::Inst(ip) = i {
            if let InstKind::Phi { incs } = &mut f.inst_mut(ip).kind {
                incs.push((body, i2));
            }
        }
        if let Val::Inst(ap) = acc {
            if let InstKind::Phi { incs } = &mut f.inst_mut(ap).kind {
                incs.push((body, acc2));
            }
        }
        m.add_func(f);

        let run_out = |m: &Module| -> Vec<u32> {
            let mut mem = vec![0u8; 4096];
            run_kernel_scalar(
                m,
                FuncId(0),
                &[256, 5],
                [1, 1, 1],
                [4, 1, 1],
                &mut mem,
                2048,
                &[],
            )
            .unwrap();
            (0..4).map(|g| read_u32(&mem, 256 + g * 4)).collect()
        };
        let before = run_out(&m);
        // 5 iterations x sum_{k=0..19} 5*(k+3) = 5 * 5 * 250 / ... check:
        // sum k+3 for k in 0..20 = 3+4+..+22 = 250; * n(5) = 1250; * 5 trips.
        assert_eq!(before, vec![6250; 4]);
        let n = run(&mut m, FuncId(0), &opts_all(), &VortexTti);
        assert_eq!(n, MAX_HOISTS_PER_LOOP, "cap must bound the hoist set");
        verify_function(&m.funcs[0]).unwrap();
        assert_eq!(before, run_out(&m));
        // The un-hoisted invariants are still inside the loop.
        let li = LoopInfo::build(&m.funcs[0]);
        let muls_in_loop = m.funcs[0]
            .insts
            .iter()
            .filter(|i| !i.dead && matches!(i.kind, InstKind::Bin { op: BinOp::Mul, .. }))
            .filter(|i| li.loops.iter().any(|l| l.blocks.contains(&i.block)))
            .count();
        assert!(
            muls_in_loop >= N_INV - MAX_HOISTS_PER_LOOP,
            "expected leftover invariants in the loop, found {muls_in_loop}"
        );
    }

    /// A store in the body pins every load.
    #[test]
    fn store_in_loop_pins_loads() {
        let mut m = build_loop_kernel(false, true);
        // Add a store into the body block (before the terminator).
        let f = &mut m.funcs[0];
        let body = f
            .insts
            .iter()
            .find(|i| !i.dead && matches!(i.kind, InstKind::Load { .. }))
            .map(|i| i.block)
            .unwrap();
        let pos = f.blocks[body.idx()].insts.len() - 1;
        f.insert_inst(
            body,
            pos,
            InstKind::Store {
                ptr: Val::Arg(0),
                val: Val::ci(1),
            },
            Type::Void,
        );
        run(&mut m, FuncId(0), &opts_all(), &VortexTti);
        verify_function(&m.funcs[0]).unwrap();
        let li = LoopInfo::build(&m.funcs[0]);
        let load_blocks = block_of(&m.funcs[0], |k| matches!(k, InstKind::Load { .. }));
        for b in load_blocks {
            assert!(
                li.loops.iter().any(|l| l.blocks.contains(&b)),
                "load hoisted past an in-loop store"
            );
        }
    }
}
