//! Code and CFG simplification (paper §4.3.2 "Code and CFG
//! Simplification"): constant folding, algebraic identities, dead-code
//! elimination, CFG cleanup (constant branches, block merging, unreachable
//! removal), canonicalization into a single-exit form, and `select`
//! normalization — rewriting selects into branch-based control flow unless
//! the target supports them natively (ZiCond → `vx_cmov`, paper §5.3).

use crate::ir::interp::scalar;
use crate::ir::*;

/// Fold constant expressions and apply algebraic identities. Returns the
/// number of instructions simplified.
pub fn const_fold(f: &mut Function) -> usize {
    let mut n = 0;
    for idx in 0..f.insts.len() {
        let id = InstId(idx as u32);
        if f.insts[idx].dead {
            continue;
        }
        let kind = f.insts[idx].kind.clone();
        let repl: Option<Val> = match kind {
            InstKind::Bin { op, a, b } => match (a, b) {
                (Val::I(x, _), Val::I(y, _)) if !op.is_float() => Some(Val::I(
                    scalar::bin_i(op, x as u32, y as u32) as i32 as i64,
                    Type::I32,
                )),
                (Val::F(x), Val::F(y)) if op.is_float() => Some(Val::F(
                    scalar::bin_f(op, f32::from_bits(x), f32::from_bits(y)).to_bits(),
                )),
                // Algebraic identities.
                (x, Val::I(0, _)) if matches!(op, BinOp::Add | BinOp::Sub | BinOp::Or | BinOp::Xor | BinOp::Shl | BinOp::LShr | BinOp::AShr) => Some(x),
                (Val::I(0, _), x) if matches!(op, BinOp::Add | BinOp::Or | BinOp::Xor) => Some(x),
                (x, Val::I(1, _)) if matches!(op, BinOp::Mul | BinOp::SDiv | BinOp::UDiv) => Some(x),
                (Val::I(1, _), x) if matches!(op, BinOp::Mul) => Some(x),
                (_, Val::I(0, _)) if matches!(op, BinOp::Mul | BinOp::And) => Some(Val::ci(0)),
                (Val::I(0, _), _) if matches!(op, BinOp::Mul | BinOp::And) => Some(Val::ci(0)),
                (x, Val::F(z)) if matches!(op, BinOp::FAdd | BinOp::FSub) && f32::from_bits(z) == 0.0 => Some(x),
                (x, Val::F(z)) if matches!(op, BinOp::FMul | BinOp::FDiv) && f32::from_bits(z) == 1.0 => Some(x),
                _ => None,
            },
            InstKind::Un { op, a } => match a {
                Val::I(x, _) => Some(match op {
                    UnOp::ZExt => Val::ci(x & 1),
                    UnOp::Trunc => Val::cb(x != 0),
                    UnOp::BitsToF => Val::F(x as u32),
                    _ => Val::I(scalar::un(op, x as u32) as i32 as i64, f.insts[idx].ty),
                }),
                Val::F(x) => Some(match op {
                    UnOp::FpToSi => Val::I(scalar::un(op, x) as i32 as i64, Type::I32),
                    UnOp::FToBits => Val::I(x as i64, Type::I32),
                    _ => Val::F(scalar::un(op, x)),
                }),
                _ => None,
            },
            InstKind::ICmp { pred, a, b } => match (a, b) {
                (Val::I(x, _), Val::I(y, _)) => {
                    Some(Val::cb(scalar::icmp(pred, x as u32, y as u32)))
                }
                _ => None,
            },
            InstKind::FCmp { pred, a, b } => match (a, b) {
                (Val::F(x), Val::F(y)) => Some(Val::cb(scalar::fcmp(
                    pred,
                    f32::from_bits(x),
                    f32::from_bits(y),
                ))),
                _ => None,
            },
            InstKind::Select { cond, t, f: fv } => match cond {
                Val::I(c, _) => Some(if c != 0 { t } else { fv }),
                _ if t == fv => Some(t),
                _ => None,
            },
            InstKind::Gep { base, index: Val::I(0, _), disp: 0, .. } => Some(base),
            InstKind::Phi { ref incs } => {
                // Phi with all-identical incomings (ignoring self-refs).
                let mut uniq: Option<Val> = None;
                let mut ok = true;
                for (_, v) in incs {
                    if *v == Val::Inst(id) {
                        continue;
                    }
                    match uniq {
                        None => uniq = Some(*v),
                        Some(u) if u == *v => {}
                        _ => {
                            ok = false;
                            break;
                        }
                    }
                }
                if ok {
                    uniq
                } else {
                    None
                }
            }
            _ => None,
        };
        if let Some(v) = repl {
            if v != Val::Inst(id) {
                f.replace_uses(Val::Inst(id), v);
                f.remove_inst(id);
                n += 1;
            }
        }
    }
    n
}

/// Remove instructions whose results are unused and that have no side
/// effects. Iterates until fixpoint.
pub fn dce(f: &mut Function) -> usize {
    let mut removed = 0;
    loop {
        let uses = f.uses();
        let dead: Vec<InstId> = (0..f.insts.len() as u32)
            .map(InstId)
            .filter(|&id| {
                let inst = &f.insts[id.idx()];
                !inst.dead
                    && !inst.kind.has_side_effects()
                    && !inst.kind.is_terminator()
                    && uses.get(&id).map(|u| u.is_empty()).unwrap_or(true)
            })
            .collect();
        if dead.is_empty() {
            return removed;
        }
        for id in dead {
            f.remove_inst(id);
            removed += 1;
        }
    }
}

/// CFG cleanup: fold constant conditional branches, thread trivial jumps,
/// merge straight-line block pairs, drop unreachable blocks.
pub fn cfg_cleanup(f: &mut Function) -> usize {
    let mut n = 0;
    loop {
        let mut changed = false;
        // 1. Constant conditional branches -> unconditional.
        for b in f.block_ids() {
            let t = f.term(b);
            if let InstKind::CondBr { cond, t: tb, f: fb } = f.inst(t).kind.clone() {
                let target = match cond {
                    Val::I(c, _) => Some(if c != 0 { tb } else { fb }),
                    _ if tb == fb => Some(tb),
                    _ => None,
                };
                if let Some(target) = target {
                    let dropped = if target == tb { fb } else { tb };
                    f.inst_mut(t).kind = InstKind::Br { target };
                    f.invalidate_cfg_cache();
                    // Remove phi incomings along the dropped edge if the
                    // dropped block is no longer a successor.
                    if dropped != target {
                        remove_phi_incoming_if_not_pred(f, dropped, b);
                    }
                    changed = true;
                    n += 1;
                }
            }
        }
        // 2. Merge b -> s when s has exactly one pred and b ends in Br.
        let preds = f.preds();
        for b in f.block_ids() {
            if f.blocks[b.idx()].dead {
                continue;
            }
            let t = f.term(b);
            if let InstKind::Br { target: s } = f.inst(t).kind {
                if s != b
                    && preds[s.idx()].len() == 1
                    && s != f.entry
                    && !f.blocks[s.idx()]
                        .insts
                        .iter()
                        .any(|&i| matches!(f.inst(i).kind, InstKind::Phi { .. }))
                {
                    // Splice s into b.
                    f.remove_inst(t);
                    let s_insts = std::mem::take(&mut f.blocks[s.idx()].insts);
                    for &i in &s_insts {
                        f.insts[i.idx()].block = b;
                    }
                    f.blocks[b.idx()].insts.extend(s_insts);
                    f.blocks[s.idx()].dead = true;
                    // Phis in s's successors referring to s now come from b.
                    for succ in f.succs(b) {
                        let si = f.blocks[succ.idx()].insts.clone();
                        for i in si {
                            if let InstKind::Phi { incs } = &mut f.insts[i.idx()].kind {
                                for (p, _) in incs.iter_mut() {
                                    if *p == s {
                                        *p = b;
                                    }
                                }
                            } else {
                                break;
                            }
                        }
                    }
                    changed = true;
                    n += 1;
                    break; // preds map stale; restart
                }
            }
        }
        if !changed {
            break;
        }
    }
    f.remove_unreachable();
    n
}

fn remove_phi_incoming_if_not_pred(f: &mut Function, block: BlockId, pred: BlockId) {
    let still_pred = f.preds()[block.idx()].contains(&pred);
    if still_pred {
        return;
    }
    let insts = f.blocks[block.idx()].insts.clone();
    for i in insts {
        if let InstKind::Phi { incs } = &mut f.insts[i.idx()].kind {
            incs.retain(|(p, _)| *p != pred);
        } else {
            break;
        }
    }
}

/// Canonicalize to a single return block (paper: "merge functions with
/// multiple return instructions into one exit block").
pub fn single_exit(f: &mut Function) -> bool {
    let rets: Vec<BlockId> = f
        .block_ids()
        .into_iter()
        .filter(|&b| matches!(f.inst(f.term(b)).kind, InstKind::Ret { .. }))
        .collect();
    if rets.len() <= 1 {
        return false;
    }
    let exit = f.add_block("exit");
    let has_val = f.ret != Type::Void;
    let mut incs: Vec<(BlockId, Val)> = vec![];
    for b in &rets {
        let t = f.term(*b);
        if let InstKind::Ret { val } = f.inst(t).kind.clone() {
            if has_val {
                incs.push((*b, val.unwrap_or(Val::ci(0))));
            }
            f.inst_mut(t).kind = InstKind::Br { target: exit };
        }
    }
    let ret_val = if has_val {
        let ty = f.ret;
        let phi = f.insert_inst(exit, 0, InstKind::Phi { incs }, ty);
        Some(Val::Inst(phi))
    } else {
        None
    };
    f.push_inst(exit, InstKind::Ret { val: ret_val }, Type::Void);
    true
}

/// Select normalization: rewrite `select` into a diamond (branch-based
/// control flow) unless ZiCond is enabled, in which case selects lower to
/// `vx_cmov` natively. Returns number of selects expanded.
///
/// This is the Fig. 5(c) hazard fix: a *divergent* select must become an
/// explicit diamond **in the IR** so the {vx_split, vx_join} insertion sees
/// it; leaving it to the back-end would silently skip instrumentation.
pub fn select_normalize(f: &mut Function, zicond: bool) -> usize {
    if zicond {
        return 0;
    }
    let mut n = 0;
    loop {
        // Find a select to expand.
        let mut found: Option<(InstId, Val, Val, Val)> = None;
        'outer: for b in f.block_ids() {
            for &id in &f.blocks[b.idx()].insts {
                if let InstKind::Select { cond, t, f: fv } = f.inst(id).kind {
                    found = Some((id, cond, t, fv));
                    break 'outer;
                }
            }
        }
        let Some((id, cond, tval, fval)) = found else {
            return n;
        };
        let b = f.inst(id).block;
        let ty = f.inst(id).ty;
        let pos = f.blocks[b.idx()].insts.iter().position(|&x| x == id).unwrap();
        // Split block b at pos: tail goes to a new join block.
        let join = f.add_block("sel.join");
        let tail: Vec<InstId> = f.blocks[b.idx()].insts.split_off(pos + 1);
        for &i in &tail {
            f.insts[i.idx()].block = join;
        }
        f.blocks[join.idx()].insts = tail;
        // Fix phis in successors of the moved terminator: they referred to b.
        for s in f.succs(join) {
            let si = f.blocks[s.idx()].insts.clone();
            for i in si {
                if let InstKind::Phi { incs } = &mut f.insts[i.idx()].kind {
                    for (p, _) in incs.iter_mut() {
                        if *p == b {
                            *p = join;
                        }
                    }
                } else {
                    break;
                }
            }
        }
        let then_b = f.add_block("sel.then");
        let else_b = f.add_block("sel.else");
        f.push_inst(then_b, InstKind::Br { target: join }, Type::Void);
        f.push_inst(else_b, InstKind::Br { target: join }, Type::Void);
        // Replace the select with a phi in join; b terminates with condbr.
        f.remove_inst(id);
        f.push_inst(
            b,
            InstKind::CondBr {
                cond,
                t: then_b,
                f: else_b,
            },
            Type::Void,
        );
        let phi = f.insert_inst(
            join,
            0,
            InstKind::Phi {
                incs: vec![(then_b, tval), (else_b, fval)],
            },
            ty,
        );
        f.replace_uses(Val::Inst(id), Val::Inst(phi));
        f.invalidate_cfg_cache();
        n += 1;
    }
}

/// Select formation (the ZiCond direction of §4.3.2): speculate small
/// side-effect-free diamonds/triangles into `select`s, which the back-end
/// lowers to `vx_cmov`. This is how real pipelines create the Fig. 5(c)
/// divergent-select situation: both arms execute for every lane, trading
/// split/join instructions for extra (possibly wasted) memory traffic.
pub fn form_selects(f: &mut Function) -> usize {
    let mut formed = 0;
    loop {
        let mut did = false;
        'scan: for a in f.block_ids() {
            let term = f.term(a);
            let InstKind::CondBr { cond, t, f: fb } = f.inst(term).kind else {
                continue;
            };
            if t == fb {
                continue;
            }
            let preds = f.preds();
            // A speculatable arm: single-pred straight-line block of cheap
            // side-effect-free ops ending in an unconditional branch;
            // returns its jump target.
            let spec_arm = |f: &Function, arm: BlockId| -> Option<BlockId> {
                if preds[arm.idx()].len() != 1 {
                    return None;
                }
                let insts = &f.blocks[arm.idx()].insts;
                if insts.len() > 7 {
                    return None;
                }
                let mut loads = 0;
                let mut target = None;
                for (i, &id) in insts.iter().enumerate() {
                    let last = i + 1 == insts.len();
                    match &f.inst(id).kind {
                        InstKind::Br { target: tg } if last => target = Some(*tg),
                        k if k.is_terminator() => return None,
                        InstKind::Load { ptr } => {
                            // Speculate only global/const loads: the device
                            // heap carries guard slack for near-OOB halo
                            // reads; scratchpad/stack windows do not.
                            if !matches!(
                                f.val_type(*ptr),
                                Type::Ptr(crate::ir::AddrSpace::Global)
                                    | Type::Ptr(crate::ir::AddrSpace::Const)
                            ) {
                                return None;
                            }
                            loads += 1;
                            if loads > 2 {
                                return None;
                            }
                        }
                        InstKind::Bin { .. }
                        | InstKind::Un { .. }
                        | InstKind::ICmp { .. }
                        | InstKind::FCmp { .. }
                        | InstKind::Select { .. }
                        | InstKind::Gep { .. } => {}
                        _ => return None,
                    }
                }
                target
            };
            // Diamond: A -> T -> J, A -> F -> J. Triangle: one arm is J.
            let jt = spec_arm(f, t);
            let jf = spec_arm(f, fb);
            let (join, arms): (BlockId, Vec<BlockId>) = if jt == Some(fb) {
                (fb, vec![t])
            } else if jf == Some(t) {
                (t, vec![fb])
            } else if jt.is_some() && jt == jf {
                (jt.unwrap(), vec![t, fb])
            } else {
                continue;
            };
            // Hoist arm instructions into A (before the terminator).
            let term_pos = f.blocks[a.idx()].insts.len() - 1;
            let mut insert_at = term_pos;
            for &arm in &arms {
                let insts: Vec<InstId> = f.blocks[arm.idx()].insts.clone();
                for &id in &insts {
                    if matches!(f.inst(id).kind, InstKind::Br { .. }) {
                        continue;
                    }
                    // unlink from arm, relink into A
                    f.blocks[arm.idx()].insts.retain(|&x| x != id);
                    f.insts[id.idx()].block = a;
                    f.blocks[a.idx()].insts.insert(insert_at, id);
                    insert_at += 1;
                }
            }
            // Rewrite J's phis: incomings from arms / from A fold into a
            // select placed in A.
            let then_src: BlockId = if arms.contains(&t) { t } else { a };
            let else_src: BlockId = if arms.contains(&fb) { fb } else { a };
            let jinsts = f.blocks[join.idx()].insts.clone();
            for id in jinsts {
                let InstKind::Phi { incs } = f.inst(id).kind.clone() else {
                    break;
                };
                let tv = incs.iter().find(|(p, _)| *p == then_src).map(|(_, v)| *v);
                let fv = incs.iter().find(|(p, _)| *p == else_src).map(|(_, v)| *v);
                let (Some(tv), Some(fv)) = (tv, fv) else { continue };
                let ty = f.inst(id).ty;
                let pos = f.blocks[a.idx()]
                    .insts
                    .iter()
                    .position(|&x| x == f.term(a))
                    .unwrap();
                let sel = Val::Inst(f.insert_inst(
                    a,
                    pos,
                    InstKind::Select {
                        cond,
                        t: tv,
                        f: fv,
                    },
                    ty,
                ));
                if let InstKind::Phi { incs } = &mut f.inst_mut(id).kind {
                    incs.retain(|(p, _)| *p != then_src && *p != else_src);
                    incs.push((a, sel));
                }
            }
            // A now branches straight to J.
            let term = f.term(a);
            f.inst_mut(term).kind = InstKind::Br { target: join };
            f.invalidate_cfg_cache();
            formed += 1;
            did = true;
            let _ = &arms;
            break 'scan;
        }
        if !did {
            break;
        }
        // Clean up the detached arm blocks + fold single-incoming phis.
        const_fold(f);
        cfg_cleanup(f);
    }
    formed
}

/// One standard cleanup bundle.
pub fn simplify(f: &mut Function) -> usize {
    let mut n = 0;
    loop {
        let c = const_fold(f) + dce(f) + cfg_cleanup(f);
        n += c;
        if c == 0 {
            return n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::verify::verify_function;
    use crate::ir::{Builder, Param};

    #[test]
    fn folds_constants_and_identities() {
        let mut f = Function::new("t", vec![Param { name: "x".into(), ty: Type::I32, uniform: false }], Type::I32);
        let mut b = Builder::new(&mut f);
        let c = b.add(Val::ci(3), Val::ci(4)); // 7
        let d = b.mul(Val::Arg(0), Val::ci(1)); // x
        let e = b.add(c, d); // 7 + x
        b.ret(Some(e));
        const_fold(&mut f);
        dce(&mut f);
        verify_function(&f).unwrap();
        // only the add and the ret remain
        assert_eq!(f.num_insts(), 2);
        let add = f.insts.iter().find(|i| !i.dead && matches!(i.kind, InstKind::Bin { .. })).unwrap();
        assert_eq!(add.kind.operands(), vec![Val::ci(7), Val::Arg(0)]);
    }

    #[test]
    fn removes_constant_branch_and_merges() {
        let mut f = Function::new("t", vec![], Type::I32);
        let t = f.add_block("t");
        let e = f.add_block("e");
        let mut b = Builder::new(&mut f);
        b.cond_br(Val::cb(true), t, e);
        b.set_block(t);
        b.ret(Some(Val::ci(1)));
        b.set_block(e);
        b.ret(Some(Val::ci(2)));
        cfg_cleanup(&mut f);
        verify_function(&f).unwrap();
        // e unreachable and removed; t merged into entry.
        assert_eq!(f.block_ids().len(), 1);
    }

    #[test]
    fn single_exit_merges_rets() {
        let mut f = Function::new("t", vec![Param { name: "c".into(), ty: Type::I1, uniform: false }], Type::I32);
        let t = f.add_block("t");
        let e = f.add_block("e");
        let mut b = Builder::new(&mut f);
        b.cond_br(Val::Arg(0), t, e);
        b.set_block(t);
        b.ret(Some(Val::ci(1)));
        b.set_block(e);
        b.ret(Some(Val::ci(2)));
        assert!(single_exit(&mut f));
        verify_function(&f).unwrap();
        let rets: Vec<_> = f
            .insts
            .iter()
            .filter(|i| !i.dead && matches!(i.kind, InstKind::Ret { .. }))
            .collect();
        assert_eq!(rets.len(), 1);
        // The single ret returns a phi.
        if let InstKind::Ret { val: Some(Val::Inst(p)) } = rets[0].kind {
            assert!(matches!(f.inst(p).kind, InstKind::Phi { .. }));
        } else {
            panic!("ret should return phi");
        }
    }

    #[test]
    fn select_expands_to_diamond() {
        let mut f = Function::new(
            "t",
            vec![
                Param { name: "c".into(), ty: Type::I1, uniform: false },
                Param { name: "a".into(), ty: Type::I32, uniform: false },
                Param { name: "b".into(), ty: Type::I32, uniform: false },
            ],
            Type::I32,
        );
        let mut b = Builder::new(&mut f);
        let s = b.select(Val::Arg(0), Val::Arg(1), Val::Arg(2));
        let u = b.add(s, Val::ci(1));
        b.ret(Some(u));
        assert_eq!(select_normalize(&mut f, false), 1);
        verify_function(&f).unwrap();
        assert!(!f.insts.iter().any(|i| !i.dead && matches!(i.kind, InstKind::Select { .. })));
        assert!(f.insts.iter().any(|i| !i.dead && matches!(i.kind, InstKind::CondBr { .. })));
        // With zicond the select survives.
        let mut f2 = Function::new("t", vec![Param { name: "c".into(), ty: Type::I1, uniform: false }], Type::I32);
        let mut b2 = Builder::new(&mut f2);
        let s2 = b2.select(Val::Arg(0), Val::ci(1), Val::ci(2));
        b2.ret(Some(s2));
        assert_eq!(select_normalize(&mut f2, true), 0);
    }

    #[test]
    fn phi_with_identical_incomings_folds() {
        let mut f = Function::new("t", vec![Param { name: "c".into(), ty: Type::I1, uniform: false }], Type::I32);
        let t = f.add_block("t");
        let e = f.add_block("e");
        let j = f.add_block("j");
        let mut b = Builder::new(&mut f);
        b.cond_br(Val::Arg(0), t, e);
        b.set_block(t);
        b.br(j);
        b.set_block(e);
        b.br(j);
        b.set_block(j);
        let p = b.phi(Type::I32, vec![(t, Val::ci(5)), (e, Val::ci(5))]);
        b.ret(Some(p));
        const_fold(&mut f);
        dce(&mut f);
        verify_function(&f).unwrap();
        assert!(!f.insts.iter().any(|i| !i.dead && matches!(i.kind, InstKind::Phi { .. })));
    }
}
