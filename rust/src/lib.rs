//! VOLT reproduction library.
//!
//! A full reimplementation of the VOLT open-source GPU compiler stack
//! ("Inside VOLT: Designing an Open-Source GPU Compiler", CS.DC 2025).
//!
//! **Start at [`driver`]** — the public compile-and-run API. A
//! [`driver::Session`] turns VCL/CUDA source into a multi-kernel
//! [`driver::Program`] through a content-addressed binary cache, and a
//! [`driver::Stream`] runs it CUDA/OpenCL-style (enqueue copies and
//! launches, `synchronize()`, inspect per-command events with sim-cycle
//! timestamps). All failures are typed [`driver::VoltError`]s naming the
//! stage that produced them — and they are *contained*: a trapped launch
//! sticky-faults its device/stream until recovered, transient faults can
//! be retried from a pre-launch snapshot ([`runtime::LaunchPolicy`]), a
//! deterministic fault injector ([`sim::FaultPlan`]) makes those paths
//! testable, and [`driver::Session::with_disk_cache`] adds a persistent,
//! corruption-safe compile cache (see `docs/RESILIENCE.md`). The layers
//! underneath, in pipeline order:
//!
//! * [`frontend`] — OpenCL-C / CUDA-C kernel dialect ("VCL") front-end:
//!   lexing, parsing, semantic analysis, IR lowering, builtin libraries and
//!   thread-schedule code insertion (paper §4.2).
//! * [`ir`] — the SSA intermediate representation shared by all middle-end
//!   passes: CFG, dominators/post-dominators, loops, control-dependence
//!   graph, verifier, textual printer/parser.
//! * [`target`] — the target-description layer: [`target::TargetDesc`]
//!   centralizes ISA features, warp-geometry capabilities, register-file
//!   shape, the address map and cost hints, and owns the divergence
//!   seeds. Two built-in profiles (`vortex`, `vortex-min`) exercise it;
//!   see `docs/TARGETS.md`.
//! * [`analysis`] — the centralized SIMT analyses (paper §4.3.1): the
//!   target-transform-info trait (`isAlwaysUniform`/`isSourceOfDivergence`),
//!   the uniformity analysis, annotation analysis and the call-graph RPO
//!   function-argument analysis (Algorithm 1).
//! * [`transform`] — middle-end transforms (paper §4.3.2/§4.3.3): mem2reg,
//!   simplification, inlining, CFG structurization, CFG reconstruction and
//!   divergence-management insertion (Algorithm 2).
//! * [`backend`] — Vortex code generation (paper §4.4): the extended ISA
//!   table, instruction selection, linear-scan register allocation, machine
//!   IR cleanups and the divergence *safety net* (paper Fig. 5), plus the
//!   assembler / encoder / disassembler.
//! * [`sim`] — a SimX-style deterministic cycle-level SIMT simulator
//!   (cores × warps × threads, per-warp IPDOM stacks, warp/barrier tables,
//!   L1/L2 caches) used as the evaluation substrate (paper §5).
//! * [`check`] — the static SIMT verifier behind `volt check` and
//!   [`driver::VoltOptions::check`]: barrier-divergence verification over
//!   the uniformity/control-dependence analyses, a GPUVerify-style
//!   two-thread shared-memory race detector over barrier-delimited
//!   phases, and static bounds / uninitialized-read checking of local
//!   arrays — cross-checked at runtime by the simulator's shadow-memory
//!   sanitizer (`SimConfig::sanitize`); see `docs/CHECKS.md`.
//! * [`prof`] — the cycle-attributing profiler: per-PC/per-line cycle
//!   attribution over the image's line table, an issue-stall taxonomy
//!   that sums to total cycles, occupancy accounting, text reports and
//!   chrome://tracing export (see `docs/PROFILING.md`).
//! * [`runtime`] — the synchronous host runtime the driver's streams
//!   execute on: device buffers, `memcpy_to_symbol` deferred
//!   materialization (Case Study 2), shared-memory mapping modes
//!   (Fig. 10), kernel launch; and the PJRT bridge that executes the
//!   JAX/Pallas AOT reference artifacts used as correctness oracles.
//! * [`coordinator`] — the benchmark registry and the experiment
//!   harnesses regenerating every figure/table in §5 (plus the deprecated
//!   pre-`driver` `compile_source` shim).
//! * [`serve`] — the batched multi-tenant serving front over the whole
//!   stack: a queue of compile+launch requests admitted with
//!   priorities, deduped through a shared [`driver::Session`] compile
//!   tier (in-memory + disk), dispatched across a pool of simulated
//!   devices with per-request stream isolation, and reported with
//!   p50/p95/p99 latency, throughput, cache provenance and per-device
//!   utilization (`volt serve`, `docs/SERVING.md`).
//!
//! See `docs/API.md` for an end-to-end quickstart.

pub mod analysis;
pub mod backend;
pub mod check;
pub mod coordinator;
pub mod driver;
pub mod frontend;
pub mod ir;
pub mod par;
pub mod prof;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod target;
pub mod transform;

pub use driver::{Program, Session, Stream, VoltError, VoltOptions};
pub use target::TargetDesc;
