//! The one error type of the public driver API.
//!
//! Every stage of the stack reports failures through [`VoltError`]: the
//! front-end with source locations, the middle-end with the failing pass,
//! the back-end with the failing function, and the host runtime with the
//! launch/memory/simulation fault. This replaces the seed's
//! `Result<_, String>` plumbing so callers can match on the stage and
//! recover (e.g. surface front-end diagnostics but abort on back-end
//! bugs).

use crate::backend::BackendError;
use crate::frontend::CompileError;
use crate::runtime::RuntimeError;
use crate::sim::SimError;
use std::fmt;

// `Clone` so a faulted stream can retain the original typed cause and
// hand an owned copy back from every subsequent call (`volt::resilience`).
#[derive(Debug, Clone)]
pub enum VoltError {
    /// Lex / parse / semantic failure, with the 1-based source line
    /// (0 when the failure is not tied to a specific line, e.g. an empty
    /// module).
    Frontend { line: u32, msg: String },
    /// A middle-end pass or the IR verifier rejected the module.
    MiddleEnd { pass: &'static str, msg: String },
    /// Back-end lowering / linking failure.
    Backend(BackendError),
    /// Host-runtime failure: bad launch, memory fault, simulator trap.
    Runtime(RuntimeError),
    /// [`super::VoltOptionsBuilder::build`] rejected an inconsistent
    /// option combination.
    InvalidOptions { msg: String },
    /// Stream-API misuse: reading a transfer before `synchronize`, a
    /// stale transfer handle, an argument-count mismatch, ...
    Stream { msg: String },
    /// Host-side validation of device results failed (benchmark drivers).
    Validation { msg: String },
}

impl VoltError {
    /// Which layer produced the error — stable strings for logs/metrics.
    pub fn stage(&self) -> &'static str {
        match self {
            VoltError::Frontend { .. } => "frontend",
            VoltError::MiddleEnd { .. } => "middle-end",
            VoltError::Backend(_) => "backend",
            VoltError::Runtime(_) => "runtime",
            VoltError::InvalidOptions { .. } => "options",
            VoltError::Stream { .. } => "stream",
            VoltError::Validation { .. } => "validation",
        }
    }

    /// Source line for front-end diagnostics, if one is attached.
    pub fn line(&self) -> Option<u32> {
        match self {
            VoltError::Frontend { line, .. } if *line > 0 => Some(*line),
            _ => None,
        }
    }

    pub fn invalid_options(msg: impl Into<String>) -> VoltError {
        VoltError::InvalidOptions { msg: msg.into() }
    }

    pub fn stream(msg: impl Into<String>) -> VoltError {
        VoltError::Stream { msg: msg.into() }
    }
}

impl fmt::Display for VoltError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VoltError::Frontend { line: 0, msg } => write!(f, "frontend error: {msg}"),
            VoltError::Frontend { line, msg } => {
                write!(f, "frontend error at line {line}: {msg}")
            }
            VoltError::MiddleEnd { pass, msg } => {
                write!(f, "middle-end error in pass '{pass}': {msg}")
            }
            VoltError::Backend(e) => write!(f, "{e}"),
            VoltError::Runtime(e) => write!(f, "runtime error: {e}"),
            VoltError::InvalidOptions { msg } => write!(f, "invalid options: {msg}"),
            VoltError::Stream { msg } => write!(f, "stream error: {msg}"),
            VoltError::Validation { msg } => write!(f, "validation failed: {msg}"),
        }
    }
}

impl std::error::Error for VoltError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            VoltError::Backend(e) => Some(e),
            VoltError::Runtime(e) => Some(e),
            _ => None,
        }
    }
}

impl From<CompileError> for VoltError {
    fn from(e: CompileError) -> VoltError {
        VoltError::Frontend {
            line: e.line,
            msg: e.msg,
        }
    }
}

impl From<BackendError> for VoltError {
    fn from(e: BackendError) -> VoltError {
        VoltError::Backend(e)
    }
}

impl From<RuntimeError> for VoltError {
    fn from(e: RuntimeError) -> VoltError {
        VoltError::Runtime(e)
    }
}

impl From<SimError> for VoltError {
    fn from(e: SimError) -> VoltError {
        VoltError::Runtime(RuntimeError::Sim(e))
    }
}

/// Legacy string-error contexts (`Result<_, String>` + `?`) keep working.
impl From<VoltError> for String {
    fn from(e: VoltError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stages_and_display() {
        let e = VoltError::Frontend {
            line: 7,
            msg: "unknown variable 'q'".into(),
        };
        assert_eq!(e.stage(), "frontend");
        assert_eq!(e.line(), Some(7));
        assert!(e.to_string().contains("line 7"));

        let e = VoltError::from(CompileError {
            line: 3,
            msg: "x".into(),
        });
        assert!(matches!(e, VoltError::Frontend { line: 3, .. }));

        let e = VoltError::Runtime(RuntimeError::UnknownKernel("k".into()));
        assert_eq!(e.stage(), "runtime");
        assert!(e.to_string().contains("unknown kernel"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
