//! Command streams: the asynchronous host API over [`VoltDevice`].
//!
//! Host programs written against CUDA streams / OpenCL command queues
//! enqueue work and synchronize at batch boundaries; the seed only
//! offered blocking `VoltDevice` calls. A [`Stream`] records
//! host-to-device copies, kernel launches, symbol writes and
//! device-to-host reads in FIFO order, executes them at
//! [`Stream::synchronize`], and emits one [`Event`] per command with
//! device sim-cycle timestamps — the profiling hooks `cudaEvent`-style
//! code expects.
//!
//! Launches are validated at *enqueue* time against the program's kernel
//! table (name and argument count), so API misuse surfaces as a typed
//! error before any simulation runs.

use super::error::VoltError;
use super::session::Program;
use crate::prof::report::KernelProfile;
use crate::runtime::{ArgValue, DevicePtr, VoltDevice};
use crate::sim::{SimConfig, SimStats};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Handle for a device-to-host read enqueued on a stream. Redeem it with
/// [`Stream::take_bytes`] / [`Stream::take_f32`] / [`Stream::take_u32`]
/// after the stream synchronized. Handles are bound to the stream that
/// issued them; redeeming on another stream is a typed error.
#[derive(Debug)]
pub struct Transfer {
    stream: u64,
    slot: usize,
}

/// Lifecycle of one device-to-host transfer slot.
enum Slot {
    /// Enqueued, not yet executed.
    Pending,
    /// Executed; data waiting to be taken.
    Ready(Vec<u8>),
    /// The D2H command failed during synchronize; no data will arrive.
    Failed,
    /// Data already handed out.
    Taken,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommandKind {
    H2D,
    D2H,
    Launch,
    SymbolWrite,
    Free,
}

/// Completion record of one executed command.
#[derive(Clone, Debug)]
pub struct Event {
    /// Kernel name for launches, symbol for symbol writes, `h2d`/`d2h`
    /// otherwise.
    pub label: String,
    pub kind: CommandKind,
    /// Cumulative device sim-cycles when the command started / finished
    /// (copies are host-side and take zero device cycles).
    pub start_cycles: u64,
    pub end_cycles: u64,
    /// Warp instructions executed (launches only).
    pub instrs: u64,
}

enum Cmd {
    H2D {
        dst: DevicePtr,
        bytes: Vec<u8>,
    },
    D2H {
        src: DevicePtr,
        len: usize,
        slot: usize,
    },
    Launch {
        kernel: String,
        grid: [u32; 3],
        block: [u32; 3],
        args: Vec<ArgValue>,
    },
    SymbolWrite {
        symbol: String,
        offset: u32,
        bytes: Vec<u8>,
    },
    Free {
        ptr: DevicePtr,
        size: u32,
    },
}

/// An in-order command queue bound to one device executing one
/// [`Program`].
pub struct Stream {
    id: u64,
    program: Arc<Program>,
    dev: VoltDevice,
    queue: VecDeque<Cmd>,
    slots: Vec<Slot>,
    events: Vec<Event>,
}

/// Process-unique stream ids so [`Transfer`] handles cannot be redeemed
/// on the wrong stream.
static NEXT_STREAM_ID: AtomicU64 = AtomicU64::new(1);

impl Stream {
    pub fn new(program: Arc<Program>, cfg: SimConfig) -> Stream {
        Stream::with_profiling(program, cfg, false)
    }

    /// Stream whose launches run under the `volt::prof` profiler,
    /// collecting one [`KernelProfile`] per launch (see
    /// [`Stream::profiles`]). Profiling never perturbs device timing.
    pub fn with_profiling(program: Arc<Program>, cfg: SimConfig, profiling: bool) -> Stream {
        let mut dev = VoltDevice::new(program.image.clone(), cfg);
        dev.profiling = profiling;
        Stream {
            id: NEXT_STREAM_ID.fetch_add(1, Ordering::Relaxed),
            program,
            dev,
            queue: VecDeque::new(),
            slots: vec![],
            events: vec![],
        }
    }

    pub fn program(&self) -> &Arc<Program> {
        &self.program
    }

    /// Device-memory allocation is host-side bookkeeping and immediate.
    pub fn malloc(&mut self, size: u32) -> DevicePtr {
        self.dev.malloc(size)
    }

    /// Release a buffer *in stream order*: the free executes at
    /// `synchronize()` after every previously enqueued command, so queued
    /// copies/launches still referencing the buffer cannot be clobbered
    /// by an immediate reallocation (cudaFreeAsync semantics).
    pub fn free(&mut self, ptr: DevicePtr, size: u32) {
        self.queue.push_back(Cmd::Free { ptr, size });
    }

    pub fn enqueue_write_bytes(&mut self, dst: DevicePtr, bytes: &[u8]) {
        self.queue.push_back(Cmd::H2D {
            dst,
            bytes: bytes.to_vec(),
        });
    }

    pub fn enqueue_write_f32(&mut self, dst: DevicePtr, vals: &[f32]) {
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_bits().to_le_bytes()).collect();
        self.queue.push_back(Cmd::H2D { dst, bytes });
    }

    pub fn enqueue_write_u32(&mut self, dst: DevicePtr, vals: &[u32]) {
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.queue.push_back(Cmd::H2D { dst, bytes });
    }

    /// Enqueue a `cudaMemcpyToSymbol`-style write; materialized by the
    /// runtime just before the next launch executes (paper §5.4). The
    /// symbol name and write extent are validated now, before anything
    /// runs.
    pub fn enqueue_write_symbol(
        &mut self,
        symbol: &str,
        bytes: &[u8],
        offset: u32,
    ) -> Result<(), VoltError> {
        if let Some(msg) = self
            .program
            .image
            .symbol_write_error(symbol, offset, bytes.len())
        {
            return Err(VoltError::stream(msg));
        }
        self.queue.push_back(Cmd::SymbolWrite {
            symbol: symbol.to_string(),
            offset,
            bytes: bytes.to_vec(),
        });
        Ok(())
    }

    /// Enqueue a kernel launch, validating the kernel name and argument
    /// count against the program's kernel table.
    pub fn enqueue_launch(
        &mut self,
        kernel: &str,
        grid: [u32; 3],
        block: [u32; 3],
        args: &[ArgValue],
    ) -> Result<(), VoltError> {
        let Some(entry) = self.program.kernel(kernel) else {
            return Err(VoltError::stream(format!(
                "program has no kernel '{kernel}' (kernels: {})",
                self.program.kernel_names().join(", ")
            )));
        };
        if entry.params.len() != args.len() {
            return Err(VoltError::stream(format!(
                "kernel '{kernel}' takes {} arguments, {} enqueued",
                entry.params.len(),
                args.len()
            )));
        }
        self.queue.push_back(Cmd::Launch {
            kernel: kernel.to_string(),
            grid,
            block,
            args: args.to_vec(),
        });
        Ok(())
    }

    /// Enqueue a device-to-host read of `len` bytes; redeem the returned
    /// [`Transfer`] after [`Stream::synchronize`].
    pub fn enqueue_read(&mut self, src: DevicePtr, len: usize) -> Transfer {
        let slot = self.slots.len();
        self.slots.push(Slot::Pending);
        self.queue.push_back(Cmd::D2H { src, len, slot });
        Transfer {
            stream: self.id,
            slot,
        }
    }

    pub fn enqueue_read_f32(&mut self, src: DevicePtr, n: usize) -> Transfer {
        self.enqueue_read(src, n * 4)
    }

    pub fn enqueue_read_u32(&mut self, src: DevicePtr, n: usize) -> Transfer {
        self.enqueue_read(src, n * 4)
    }

    /// Number of commands not yet executed.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Execute every queued command in FIFO order. Already-completed work
    /// is kept on error; the failing command is consumed (the error names
    /// it) and commands behind it stay queued.
    pub fn synchronize(&mut self) -> Result<(), VoltError> {
        while let Some(cmd) = self.queue.pop_front() {
            let (label, kind) = match &cmd {
                Cmd::H2D { .. } => ("h2d".to_string(), CommandKind::H2D),
                Cmd::D2H { .. } => ("d2h".to_string(), CommandKind::D2H),
                Cmd::Launch { kernel, .. } => (kernel.clone(), CommandKind::Launch),
                Cmd::SymbolWrite { symbol, .. } => (symbol.clone(), CommandKind::SymbolWrite),
                Cmd::Free { .. } => ("free".to_string(), CommandKind::Free),
            };
            let start_cycles = self.dev.total_stats.cycles;
            let mut instrs = 0;
            match cmd {
                Cmd::H2D { dst, bytes } => {
                    self.dev.memcpy_h2d(dst, &bytes)?;
                }
                Cmd::D2H { src, len, slot } => match self.dev.memcpy_d2h(src, len) {
                    Ok(data) => self.slots[slot] = Slot::Ready(data),
                    Err(e) => {
                        self.slots[slot] = Slot::Failed;
                        return Err(e.into());
                    }
                },
                Cmd::Launch {
                    kernel,
                    grid,
                    block,
                    args,
                } => {
                    let stats = self.dev.launch(&kernel, grid, block, &args)?;
                    instrs = stats.instrs;
                }
                Cmd::SymbolWrite {
                    symbol,
                    offset,
                    bytes,
                } => {
                    self.dev.memcpy_to_symbol(&symbol, &bytes, offset)?;
                }
                Cmd::Free { ptr, size } => {
                    self.dev.free(ptr, size);
                }
            }
            self.events.push(Event {
                label,
                kind,
                start_cycles,
                end_cycles: self.dev.total_stats.cycles,
                instrs,
            });
        }
        Ok(())
    }

    /// Redeem a completed transfer. Typed errors distinguish a handle
    /// from another stream, a transfer not yet synchronized, a transfer
    /// whose command failed, and a handle already taken.
    pub fn take_bytes(&mut self, t: Transfer) -> Result<Vec<u8>, VoltError> {
        if t.stream != self.id {
            return Err(VoltError::stream(
                "transfer handle belongs to a different stream",
            ));
        }
        let slot = self
            .slots
            .get_mut(t.slot)
            .ok_or_else(|| VoltError::stream("stale transfer handle"))?;
        match std::mem::replace(slot, Slot::Taken) {
            Slot::Ready(data) => Ok(data),
            Slot::Pending => {
                *slot = Slot::Pending;
                Err(VoltError::stream(
                    "transfer not complete: synchronize() the stream first",
                ))
            }
            Slot::Failed => {
                *slot = Slot::Failed;
                Err(VoltError::stream(
                    "transfer's d2h command failed during synchronize()",
                ))
            }
            Slot::Taken => Err(VoltError::stream("transfer was already taken")),
        }
    }

    fn take_words(&mut self, t: Transfer) -> Result<Vec<[u8; 4]>, VoltError> {
        let b = self.take_bytes(t)?;
        if b.len() % 4 != 0 {
            return Err(VoltError::stream(format!(
                "transfer length {} is not a multiple of 4",
                b.len()
            )));
        }
        Ok(b.chunks_exact(4)
            .map(|c| [c[0], c[1], c[2], c[3]])
            .collect())
    }

    pub fn take_f32(&mut self, t: Transfer) -> Result<Vec<f32>, VoltError> {
        Ok(self
            .take_words(t)?
            .into_iter()
            .map(|w| f32::from_bits(u32::from_le_bytes(w)))
            .collect())
    }

    pub fn take_u32(&mut self, t: Transfer) -> Result<Vec<u32>, VoltError> {
        Ok(self
            .take_words(t)?
            .into_iter()
            .map(u32::from_le_bytes)
            .collect())
    }

    /// Completion records of every executed command, in execution order.
    /// Records accumulate until drained with [`Stream::take_events`] —
    /// long-running streams should drain between batches.
    pub fn events(&self) -> &[Event] {
        &self.events
    }

    /// Drain the completion records (bounds memory on long-lived
    /// streams; transfer slots keep only a small marker once taken).
    pub fn take_events(&mut self) -> Vec<Event> {
        std::mem::take(&mut self.events)
    }

    /// Cumulative device statistics over all launches on this stream.
    pub fn stats(&self) -> &SimStats {
        &self.dev.total_stats
    }

    /// Per-launch kernel profiles, in launch order. Empty unless the
    /// stream was created with profiling on
    /// ([`crate::driver::VoltOptions::profiling`] /
    /// [`Stream::with_profiling`]).
    pub fn profiles(&self) -> &[KernelProfile] {
        &self.dev.profiles
    }

    /// Drain the collected kernel profiles (bounds memory on long-lived
    /// profiled streams).
    pub fn take_profiles(&mut self) -> Vec<KernelProfile> {
        self.dev.take_profiles()
    }

    /// chrome://tracing JSON over everything this stream executed: one
    /// slice per command (from the event cycle stamps), one track per
    /// core and a warp-occupancy counter track (from the per-launch
    /// profiles, when profiling is on). The trace metadata is stamped
    /// with the program's target name, so per-target artifacts stay
    /// distinguishable. Load in `chrome://tracing` or Perfetto;
    /// 1 simulated cycle = 1 µs.
    pub fn chrome_trace(&self) -> String {
        crate::prof::trace::chrome_trace(
            &self.events,
            &self.dev.profiles,
            &self.program.image.target,
        )
    }

    /// Escape hatch to the underlying synchronous device (advanced /
    /// legacy use; commands already enqueued are not reordered).
    pub fn device_mut(&mut self) -> &mut VoltDevice {
        &mut self.dev
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::{Session, VoltOptions};

    fn stream_for(src: &str) -> Stream {
        let mut s = Session::new(VoltOptions::builder().build().unwrap());
        let p = s.compile(src).unwrap();
        s.create_stream(&p)
    }

    #[test]
    fn ordered_h2d_launch_d2h_roundtrip() {
        let mut st = stream_for(
            r#"
kernel void double_it(global int* x, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = x[i] * 2;
}
"#,
        );
        let buf = st.malloc(64 * 4);
        let data: Vec<u32> = (0..64).collect();
        st.enqueue_write_u32(buf, &data);
        st.enqueue_launch(
            "double_it",
            [1, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(buf), ArgValue::I32(64)],
        )
        .unwrap();
        let t = st.enqueue_read_u32(buf, 64);
        assert_eq!(st.pending(), 3);
        st.synchronize().unwrap();
        assert_eq!(st.pending(), 0);
        let got = st.take_u32(t).unwrap();
        let want: Vec<u32> = (0..64).map(|i| i * 2).collect();
        assert_eq!(got, want, "d2h after launch must observe kernel writes");
    }

    #[test]
    fn enqueue_validates_kernel_and_arity() {
        let mut st = stream_for("kernel void k(global int* o, int n) { o[0] = n; }");
        let e = st.enqueue_launch("nope", [1, 1, 1], [1, 1, 1], &[]).unwrap_err();
        assert!(matches!(e, VoltError::Stream { .. }), "{e}");
        let b = st.malloc(4);
        let e = st
            .enqueue_launch("k", [1, 1, 1], [1, 1, 1], &[ArgValue::Ptr(b)])
            .unwrap_err();
        assert!(e.to_string().contains("takes 2 arguments"), "{e}");
    }

    #[test]
    fn free_is_deferred_to_stream_order() {
        let mut st = stream_for("kernel void k(global int* o, int n) { o[0] = n; }");
        let a = st.malloc(256);
        st.enqueue_write_u32(a, &[7u32; 4]);
        st.free(a, 256);
        // The queued write still references `a`: the allocator must not
        // hand its address out again before synchronize.
        let b = st.malloc(256);
        assert_ne!(a, b, "free must not take effect before synchronize");
        st.synchronize().unwrap();
        assert_eq!(
            st.events().last().map(|e| e.kind),
            Some(CommandKind::Free)
        );
        let c = st.malloc(64);
        assert_eq!(c, a, "after synchronize the freed block is reusable");
    }

    #[test]
    fn take_before_sync_is_a_typed_error() {
        let mut st = stream_for("kernel void k(global int* o, int n) { o[0] = n; }");
        let b = st.malloc(16);
        let t = st.enqueue_read_u32(b, 4);
        let e = st.take_u32(t).unwrap_err();
        assert!(matches!(e, VoltError::Stream { .. }));
        st.synchronize().unwrap();
    }

    #[test]
    fn events_record_launch_cycles_in_order() {
        let mut st = stream_for(
            r#"
kernel void fill(global int* x, int v, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = v;
}
"#,
        );
        let b = st.malloc(256);
        st.enqueue_write_u32(b, &[0u32; 64]);
        st.enqueue_launch(
            "fill",
            [1, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(b), ArgValue::I32(9), ArgValue::I32(64)],
        )
        .unwrap();
        let t = st.enqueue_read_u32(b, 64);
        st.synchronize().unwrap();
        let ev = st.events();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].kind, CommandKind::H2D);
        assert_eq!(ev[1].kind, CommandKind::Launch);
        assert_eq!(ev[2].kind, CommandKind::D2H);
        assert_eq!(ev[1].label, "fill");
        assert!(ev[1].end_cycles > ev[1].start_cycles, "launch takes cycles");
        assert!(ev[1].instrs > 0);
        assert_eq!(ev[2].start_cycles, ev[1].end_cycles);
        assert_eq!(st.take_u32(t).unwrap(), vec![9u32; 64]);
    }
}
