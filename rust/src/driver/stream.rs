//! Command streams: the asynchronous host API over [`VoltDevice`].
//!
//! Host programs written against CUDA streams / OpenCL command queues
//! enqueue work and synchronize at batch boundaries; the seed only
//! offered blocking `VoltDevice` calls. A [`Stream`] records
//! host-to-device copies, kernel launches, symbol writes and
//! device-to-host reads in FIFO order, executes them at
//! [`Stream::synchronize`], and emits one [`Event`] per command with
//! device sim-cycle timestamps — the profiling hooks `cudaEvent`-style
//! code expects.
//!
//! Launches are validated at *enqueue* time against the program's kernel
//! table (name and argument count), so API misuse surfaces as a typed
//! error before any simulation runs.
//!
//! # Fault containment
//!
//! A command that fails during [`Stream::synchronize`] puts the stream
//! into a sticky *faulted* state ([`Stream::fault`]): the residual queue
//! is discarded, every not-yet-executed device-to-host transfer is marked
//! `Failed`, and all subsequent enqueues and synchronizes return a clone
//! of the original typed cause until [`Stream::recover`] clears it.
//! Streams run their device transactionally (a pre-launch global-memory
//! snapshot), so a trapped launch rolls back and the device holds the
//! last consistent state. Transient traps can be retried automatically
//! by attaching a [`LaunchPolicy`] ([`Stream::set_launch_policy`] /
//! [`Stream::enqueue_launch_with_policy`]).

use super::error::VoltError;
use super::session::Program;
use crate::prof::report::KernelProfile;
use crate::runtime::{ArgValue, DevicePtr, LaunchPolicy, VoltDevice};
use crate::sim::{SimConfig, SimStats};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Handle for a device-to-host read enqueued on a stream. Redeem it with
/// [`Stream::take_bytes`] / [`Stream::take_f32`] / [`Stream::take_u32`]
/// after the stream synchronized. Handles are bound to the stream that
/// issued them; redeeming on another stream is a typed error.
#[derive(Debug)]
pub struct Transfer {
    stream: u64,
    slot: usize,
}

/// Lifecycle of one device-to-host transfer slot.
enum Slot {
    /// Enqueued, not yet executed.
    Pending,
    /// Executed; data waiting to be taken.
    Ready(Vec<u8>),
    /// The D2H command failed, or was discarded because an earlier
    /// command faulted the stream; no data will arrive.
    Failed,
    /// Data already handed out.
    Taken,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommandKind {
    H2D,
    D2H,
    Launch,
    SymbolWrite,
    Free,
}

/// Completion record of one executed command.
#[derive(Clone, Debug)]
pub struct Event {
    /// Kernel name for launches, symbol for symbol writes, `h2d`/`d2h`
    /// otherwise.
    pub label: String,
    pub kind: CommandKind,
    /// Cumulative device sim-cycles when the command was enqueued — the
    /// queue-wait anchor ([`Stream::timings`]).
    pub enqueue_cycles: u64,
    /// Cumulative device sim-cycles when the command started / finished
    /// (copies are host-side and take zero device cycles).
    pub start_cycles: u64,
    pub end_cycles: u64,
    /// Warp instructions executed (launches only).
    pub instrs: u64,
}

/// Queue-wait vs execute split of one completed command, derived from
/// its [`Event`] cycle stamps — the latency primitive `volt::serve`
/// builds its percentiles on.
#[derive(Clone, Debug)]
pub struct CommandTiming {
    pub label: String,
    pub kind: CommandKind,
    /// Device clock when the command entered the queue.
    pub enqueue_cycle: u64,
    /// Device clock when it began executing (everything enqueued before
    /// it had completed).
    pub start_cycle: u64,
    /// Device clock when it finished.
    pub end_cycle: u64,
}

impl CommandTiming {
    /// Cycles the command waited behind earlier commands.
    pub fn queue_wait(&self) -> u64 {
        self.start_cycle - self.enqueue_cycle
    }

    /// Cycles the command itself consumed (0 for host-side copies).
    pub fn execute_cycles(&self) -> u64 {
        self.end_cycle - self.start_cycle
    }

    /// Enqueue-to-completion cycles.
    pub fn turnaround(&self) -> u64 {
        self.end_cycle - self.enqueue_cycle
    }
}

/// Why a stream is faulted: the command that failed and its typed cause.
/// Held by the stream until [`Stream::recover`]; every call made while
/// faulted hands back a clone of `cause`.
#[derive(Clone, Debug)]
pub struct StreamFault {
    /// Label of the failing command (kernel name, symbol, `h2d`/`d2h`).
    pub label: String,
    pub kind: CommandKind,
    pub cause: VoltError,
}

enum Cmd {
    H2D {
        dst: DevicePtr,
        bytes: Vec<u8>,
    },
    D2H {
        src: DevicePtr,
        len: usize,
        slot: usize,
    },
    Launch {
        kernel: String,
        grid: [u32; 3],
        block: [u32; 3],
        args: Vec<ArgValue>,
        /// Per-launch override of the stream's launch policy.
        policy: Option<LaunchPolicy>,
    },
    SymbolWrite {
        symbol: String,
        offset: u32,
        bytes: Vec<u8>,
    },
    Free {
        ptr: DevicePtr,
        size: u32,
    },
}

/// A queued command plus the device clock at enqueue time (the
/// queue-wait anchor of its eventual [`Event`]).
struct Queued {
    cmd: Cmd,
    enqueue_cycles: u64,
}

/// An in-order command queue bound to one device executing one
/// [`Program`].
pub struct Stream {
    id: u64,
    program: Arc<Program>,
    dev: VoltDevice,
    queue: VecDeque<Queued>,
    slots: Vec<Slot>,
    events: Vec<Event>,
    fault: Option<StreamFault>,
}

/// Process-unique stream ids so [`Transfer`] handles cannot be redeemed
/// on the wrong stream.
static NEXT_STREAM_ID: AtomicU64 = AtomicU64::new(1);

impl Stream {
    pub fn new(program: Arc<Program>, cfg: SimConfig) -> Stream {
        Stream::with_profiling(program, cfg, false)
    }

    /// Stream whose launches run under the `volt::prof` profiler,
    /// collecting one [`KernelProfile`] per launch (see
    /// [`Stream::profiles`]). Profiling never perturbs device timing.
    pub fn with_profiling(program: Arc<Program>, cfg: SimConfig, profiling: bool) -> Stream {
        let mut dev = VoltDevice::new(program.image.clone(), cfg);
        dev.profiling = profiling;
        // Streams promise containment: a trapped launch must leave the
        // device at the last consistent state, so every launch runs
        // against a pre-launch snapshot.
        dev.transactional = true;
        Stream {
            id: NEXT_STREAM_ID.fetch_add(1, Ordering::Relaxed),
            program,
            dev,
            queue: VecDeque::new(),
            slots: vec![],
            events: vec![],
            fault: None,
        }
    }

    pub fn program(&self) -> &Arc<Program> {
        &self.program
    }

    /// The sticky fault, if a command failed during a past synchronize.
    pub fn fault(&self) -> Option<&StreamFault> {
        self.fault.as_ref()
    }

    pub fn is_faulted(&self) -> bool {
        self.fault.is_some()
    }

    /// Clear the sticky fault (and the underlying device's fault latch),
    /// returning what was cleared. Device memory stays at the last
    /// consistent state — the transactional rollback already undid the
    /// failing launch — so the caller can re-enqueue and continue.
    pub fn recover(&mut self) -> Option<StreamFault> {
        let f = self.fault.take()?;
        self.dev.clear_fault();
        Some(f)
    }

    /// Launch policy applied to subsequent launches enqueued without an
    /// explicit per-launch policy (see
    /// [`Stream::enqueue_launch_with_policy`]).
    pub fn set_launch_policy(&mut self, policy: LaunchPolicy) {
        self.dev.policy = policy;
    }

    fn check_fault(&self) -> Result<(), VoltError> {
        match &self.fault {
            Some(f) => Err(f.cause.clone()),
            None => Ok(()),
        }
    }

    /// Queue a command stamped with the current device clock.
    fn push(&mut self, cmd: Cmd) {
        self.queue.push_back(Queued {
            cmd,
            enqueue_cycles: self.dev.total_stats.cycles,
        });
    }

    /// Device-memory allocation is host-side bookkeeping and immediate.
    pub fn malloc(&mut self, size: u32) -> DevicePtr {
        self.dev.malloc(size)
    }

    /// Release a buffer *in stream order*: the free executes at
    /// `synchronize()` after every previously enqueued command, so queued
    /// copies/launches still referencing the buffer cannot be clobbered
    /// by an immediate reallocation (cudaFreeAsync semantics). On a
    /// faulted stream nothing else will run, so the free applies
    /// immediately (no leak across recovery).
    pub fn free(&mut self, ptr: DevicePtr, size: u32) {
        if self.fault.is_some() {
            self.dev.free(ptr, size);
        } else {
            self.push(Cmd::Free { ptr, size });
        }
    }

    pub fn enqueue_write_bytes(&mut self, dst: DevicePtr, bytes: &[u8]) -> Result<(), VoltError> {
        self.check_fault()?;
        self.push(Cmd::H2D {
            dst,
            bytes: bytes.to_vec(),
        });
        Ok(())
    }

    pub fn enqueue_write_f32(&mut self, dst: DevicePtr, vals: &[f32]) -> Result<(), VoltError> {
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_bits().to_le_bytes()).collect();
        self.enqueue_write_bytes(dst, &bytes)
    }

    pub fn enqueue_write_u32(&mut self, dst: DevicePtr, vals: &[u32]) -> Result<(), VoltError> {
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.enqueue_write_bytes(dst, &bytes)
    }

    /// Enqueue a `cudaMemcpyToSymbol`-style write; materialized by the
    /// runtime just before the next launch executes (paper §5.4). The
    /// symbol name and write extent are validated now, before anything
    /// runs.
    pub fn enqueue_write_symbol(
        &mut self,
        symbol: &str,
        bytes: &[u8],
        offset: u32,
    ) -> Result<(), VoltError> {
        self.check_fault()?;
        if let Some(msg) = self
            .program
            .image
            .symbol_write_error(symbol, offset, bytes.len())
        {
            return Err(VoltError::stream(msg));
        }
        self.push(Cmd::SymbolWrite {
            symbol: symbol.to_string(),
            offset,
            bytes: bytes.to_vec(),
        });
        Ok(())
    }

    /// Enqueue a kernel launch, validating the kernel name and argument
    /// count against the program's kernel table.
    pub fn enqueue_launch(
        &mut self,
        kernel: &str,
        grid: [u32; 3],
        block: [u32; 3],
        args: &[ArgValue],
    ) -> Result<(), VoltError> {
        self.enqueue_launch_inner(kernel, grid, block, args, None)
    }

    /// [`Stream::enqueue_launch`] with a per-launch [`LaunchPolicy`]
    /// override (retries for transient faults, a launch watchdog). The
    /// stream's default policy ([`Stream::set_launch_policy`]) applies to
    /// launches enqueued without one.
    pub fn enqueue_launch_with_policy(
        &mut self,
        kernel: &str,
        grid: [u32; 3],
        block: [u32; 3],
        args: &[ArgValue],
        policy: LaunchPolicy,
    ) -> Result<(), VoltError> {
        self.enqueue_launch_inner(kernel, grid, block, args, Some(policy))
    }

    fn enqueue_launch_inner(
        &mut self,
        kernel: &str,
        grid: [u32; 3],
        block: [u32; 3],
        args: &[ArgValue],
        policy: Option<LaunchPolicy>,
    ) -> Result<(), VoltError> {
        self.check_fault()?;
        let Some(entry) = self.program.kernel(kernel) else {
            return Err(VoltError::stream(format!(
                "program has no kernel '{kernel}' (kernels: {})",
                self.program.kernel_names().join(", ")
            )));
        };
        if entry.params.len() != args.len() {
            return Err(VoltError::stream(format!(
                "kernel '{kernel}' takes {} arguments, {} enqueued",
                entry.params.len(),
                args.len()
            )));
        }
        self.push(Cmd::Launch {
            kernel: kernel.to_string(),
            grid,
            block,
            args: args.to_vec(),
            policy,
        });
        Ok(())
    }

    /// Enqueue a device-to-host read of `len` bytes; redeem the returned
    /// [`Transfer`] after [`Stream::synchronize`]. On a faulted stream
    /// the transfer is born `Failed` (redeeming it reports the fault).
    pub fn enqueue_read(&mut self, src: DevicePtr, len: usize) -> Transfer {
        let slot = self.slots.len();
        if self.fault.is_some() {
            self.slots.push(Slot::Failed);
        } else {
            self.slots.push(Slot::Pending);
            self.push(Cmd::D2H { src, len, slot });
        }
        Transfer {
            stream: self.id,
            slot,
        }
    }

    pub fn enqueue_read_f32(&mut self, src: DevicePtr, n: usize) -> Transfer {
        self.enqueue_read(src, n * 4)
    }

    pub fn enqueue_read_u32(&mut self, src: DevicePtr, n: usize) -> Transfer {
        self.enqueue_read(src, n * 4)
    }

    /// Number of commands not yet executed.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Discard the residual queue after a fault: not-yet-executed D2H
    /// commands mark their slots `Failed`, queued frees still apply
    /// (host-side bookkeeping; nothing that could reuse the memory will
    /// run), everything else is dropped.
    fn fail_residual(&mut self) {
        while let Some(q) = self.queue.pop_front() {
            match q.cmd {
                Cmd::D2H { slot, .. } => self.slots[slot] = Slot::Failed,
                Cmd::Free { ptr, size } => self.dev.free(ptr, size),
                _ => {}
            }
        }
    }

    /// Execute every queued command in FIFO order.
    ///
    /// # Error contract
    ///
    /// Already-completed work is kept. If a command fails, the stream
    /// becomes faulted ([`Stream::fault`]): the queue is cleared,
    /// transfers enqueued after the failing command are marked `Failed`,
    /// the event log is truncated at the fault (only completed commands
    /// have events), and this call — like every later enqueue /
    /// synchronize until [`Stream::recover`] — returns the original typed
    /// cause.
    pub fn synchronize(&mut self) -> Result<(), VoltError> {
        self.check_fault()?;
        while let Some(Queued { cmd, enqueue_cycles }) = self.queue.pop_front() {
            let (label, kind) = match &cmd {
                Cmd::H2D { .. } => ("h2d".to_string(), CommandKind::H2D),
                Cmd::D2H { .. } => ("d2h".to_string(), CommandKind::D2H),
                Cmd::Launch { kernel, .. } => (kernel.clone(), CommandKind::Launch),
                Cmd::SymbolWrite { symbol, .. } => (symbol.clone(), CommandKind::SymbolWrite),
                Cmd::Free { .. } => ("free".to_string(), CommandKind::Free),
            };
            let start_cycles = self.dev.total_stats.cycles;
            let mut instrs = 0;
            let result: Result<(), VoltError> = match cmd {
                Cmd::H2D { dst, bytes } => {
                    self.dev.memcpy_h2d(dst, &bytes).map_err(VoltError::from)
                }
                Cmd::D2H { src, len, slot } => match self.dev.memcpy_d2h(src, len) {
                    Ok(data) => {
                        self.slots[slot] = Slot::Ready(data);
                        Ok(())
                    }
                    Err(e) => {
                        self.slots[slot] = Slot::Failed;
                        Err(e.into())
                    }
                },
                Cmd::Launch {
                    kernel,
                    grid,
                    block,
                    args,
                    policy,
                } => {
                    let p = policy.unwrap_or(self.dev.policy);
                    match self.dev.launch_with_policy(&kernel, grid, block, &args, p) {
                        Ok(stats) => {
                            instrs = stats.instrs;
                            Ok(())
                        }
                        Err(e) => Err(e.into()),
                    }
                }
                Cmd::SymbolWrite {
                    symbol,
                    offset,
                    bytes,
                } => self
                    .dev
                    .memcpy_to_symbol(&symbol, &bytes, offset)
                    .map_err(VoltError::from),
                Cmd::Free { ptr, size } => {
                    self.dev.free(ptr, size);
                    Ok(())
                }
            };
            if let Err(cause) = result {
                self.fail_residual();
                self.fault = Some(StreamFault {
                    label,
                    kind,
                    cause: cause.clone(),
                });
                return Err(cause);
            }
            self.events.push(Event {
                label,
                kind,
                enqueue_cycles,
                start_cycles,
                end_cycles: self.dev.total_stats.cycles,
                instrs,
            });
        }
        Ok(())
    }

    /// Redeem a completed transfer. Typed errors distinguish a handle
    /// from another stream, a transfer not yet synchronized, a transfer
    /// whose command failed (naming the stream fault when one is latched),
    /// and a handle already taken.
    pub fn take_bytes(&mut self, t: Transfer) -> Result<Vec<u8>, VoltError> {
        if t.stream != self.id {
            return Err(VoltError::stream(
                "transfer handle belongs to a different stream",
            ));
        }
        let fault_msg = self.fault.as_ref().map(|f| {
            format!(
                "transfer failed: stream faulted at '{}': {}",
                f.label, f.cause
            )
        });
        let slot = self
            .slots
            .get_mut(t.slot)
            .ok_or_else(|| VoltError::stream("stale transfer handle"))?;
        match std::mem::replace(slot, Slot::Taken) {
            Slot::Ready(data) => Ok(data),
            Slot::Pending => {
                *slot = Slot::Pending;
                Err(VoltError::stream(
                    "transfer not complete: synchronize() the stream first",
                ))
            }
            Slot::Failed => {
                *slot = Slot::Failed;
                Err(VoltError::stream(fault_msg.unwrap_or_else(|| {
                    "transfer's d2h command failed during synchronize()".to_string()
                })))
            }
            Slot::Taken => Err(VoltError::stream("transfer was already taken")),
        }
    }

    fn take_words(&mut self, t: Transfer) -> Result<Vec<[u8; 4]>, VoltError> {
        let b = self.take_bytes(t)?;
        if b.len() % 4 != 0 {
            return Err(VoltError::stream(format!(
                "transfer length {} is not a multiple of 4",
                b.len()
            )));
        }
        Ok(b.chunks_exact(4)
            .map(|c| [c[0], c[1], c[2], c[3]])
            .collect())
    }

    pub fn take_f32(&mut self, t: Transfer) -> Result<Vec<f32>, VoltError> {
        Ok(self
            .take_words(t)?
            .into_iter()
            .map(|w| f32::from_bits(u32::from_le_bytes(w)))
            .collect())
    }

    pub fn take_u32(&mut self, t: Transfer) -> Result<Vec<u32>, VoltError> {
        Ok(self
            .take_words(t)?
            .into_iter()
            .map(u32::from_le_bytes)
            .collect())
    }

    /// Completion records of every executed command, in execution order.
    /// Records accumulate until drained with [`Stream::take_events`] —
    /// long-running streams should drain between batches.
    pub fn events(&self) -> &[Event] {
        &self.events
    }

    /// Drain the completion records (bounds memory on long-lived
    /// streams; transfer slots keep only a small marker once taken).
    pub fn take_events(&mut self) -> Vec<Event> {
        std::mem::take(&mut self.events)
    }

    /// Per-command `(enqueue, start, end)` cycle view over the executed
    /// commands, splitting each command's latency into queue-wait
    /// (behind earlier commands in the batch) and execute time. Derived
    /// from [`Stream::events`], so it covers the same completed-command
    /// window and drains with [`Stream::take_events`].
    pub fn timings(&self) -> Vec<CommandTiming> {
        self.events
            .iter()
            .map(|e| CommandTiming {
                label: e.label.clone(),
                kind: e.kind,
                enqueue_cycle: e.enqueue_cycles,
                start_cycle: e.start_cycles,
                end_cycle: e.end_cycles,
            })
            .collect()
    }

    /// Cumulative device statistics over all launches on this stream.
    pub fn stats(&self) -> &SimStats {
        &self.dev.total_stats
    }

    /// Per-launch kernel profiles, in launch order. Empty unless the
    /// stream was created with profiling on
    /// ([`crate::driver::VoltOptions::profiling`] /
    /// [`Stream::with_profiling`]).
    pub fn profiles(&self) -> &[KernelProfile] {
        &self.dev.profiles
    }

    /// Drain the collected kernel profiles (bounds memory on long-lived
    /// profiled streams).
    pub fn take_profiles(&mut self) -> Vec<KernelProfile> {
        self.dev.take_profiles()
    }

    /// chrome://tracing JSON over everything this stream executed: one
    /// slice per command (from the event cycle stamps), one track per
    /// core and a warp-occupancy counter track (from the per-launch
    /// profiles, when profiling is on). The trace metadata is stamped
    /// with the program's target name, so per-target artifacts stay
    /// distinguishable. Load in `chrome://tracing` or Perfetto;
    /// 1 simulated cycle = 1 µs.
    pub fn chrome_trace(&self) -> String {
        crate::prof::trace::chrome_trace(
            &self.events,
            &self.dev.profiles,
            &self.program.image.target,
        )
    }

    /// Escape hatch to the underlying synchronous device (advanced /
    /// legacy use; commands already enqueued are not reordered).
    pub fn device_mut(&mut self) -> &mut VoltDevice {
        &mut self.dev
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::{Session, VoltOptions};
    use crate::sim::{FaultKind, FaultPlan, FaultState};

    fn stream_for(src: &str) -> Stream {
        let s = Session::new(VoltOptions::builder().build().unwrap());
        let p = s.compile(src).unwrap();
        s.create_stream(&p)
    }

    /// Arm a deterministic fault plan on the stream's device (the plan
    /// would normally come in through `SimConfig.faults`).
    fn inject(st: &mut Stream, plan: FaultPlan) {
        st.device_mut().gpu.faults = FaultState::new(plan);
    }

    #[test]
    fn ordered_h2d_launch_d2h_roundtrip() {
        let mut st = stream_for(
            r#"
kernel void double_it(global int* x, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = x[i] * 2;
}
"#,
        );
        let buf = st.malloc(64 * 4);
        let data: Vec<u32> = (0..64).collect();
        st.enqueue_write_u32(buf, &data).unwrap();
        st.enqueue_launch(
            "double_it",
            [1, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(buf), ArgValue::I32(64)],
        )
        .unwrap();
        let t = st.enqueue_read_u32(buf, 64);
        assert_eq!(st.pending(), 3);
        st.synchronize().unwrap();
        assert_eq!(st.pending(), 0);
        let got = st.take_u32(t).unwrap();
        let want: Vec<u32> = (0..64).map(|i| i * 2).collect();
        assert_eq!(got, want, "d2h after launch must observe kernel writes");
    }

    #[test]
    fn enqueue_validates_kernel_and_arity() {
        let mut st = stream_for("kernel void k(global int* o, int n) { o[0] = n; }");
        let e = st.enqueue_launch("nope", [1, 1, 1], [1, 1, 1], &[]).unwrap_err();
        assert!(matches!(e, VoltError::Stream { .. }), "{e}");
        let b = st.malloc(4);
        let e = st
            .enqueue_launch("k", [1, 1, 1], [1, 1, 1], &[ArgValue::Ptr(b)])
            .unwrap_err();
        assert!(e.to_string().contains("takes 2 arguments"), "{e}");
    }

    #[test]
    fn free_is_deferred_to_stream_order() {
        let mut st = stream_for("kernel void k(global int* o, int n) { o[0] = n; }");
        let a = st.malloc(256);
        st.enqueue_write_u32(a, &[7u32; 4]).unwrap();
        st.free(a, 256);
        // The queued write still references `a`: the allocator must not
        // hand its address out again before synchronize.
        let b = st.malloc(256);
        assert_ne!(a, b, "free must not take effect before synchronize");
        st.synchronize().unwrap();
        assert_eq!(
            st.events().last().map(|e| e.kind),
            Some(CommandKind::Free)
        );
        let c = st.malloc(64);
        assert_eq!(c, a, "after synchronize the freed block is reusable");
    }

    #[test]
    fn take_before_sync_is_a_typed_error() {
        let mut st = stream_for("kernel void k(global int* o, int n) { o[0] = n; }");
        let b = st.malloc(16);
        let t = st.enqueue_read_u32(b, 4);
        let e = st.take_u32(t).unwrap_err();
        assert!(matches!(e, VoltError::Stream { .. }));
        st.synchronize().unwrap();
    }

    #[test]
    fn events_record_launch_cycles_in_order() {
        let mut st = stream_for(
            r#"
kernel void fill(global int* x, int v, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = v;
}
"#,
        );
        let b = st.malloc(256);
        st.enqueue_write_u32(b, &[0u32; 64]).unwrap();
        st.enqueue_launch(
            "fill",
            [1, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(b), ArgValue::I32(9), ArgValue::I32(64)],
        )
        .unwrap();
        let t = st.enqueue_read_u32(b, 64);
        st.synchronize().unwrap();
        let ev = st.events();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].kind, CommandKind::H2D);
        assert_eq!(ev[1].kind, CommandKind::Launch);
        assert_eq!(ev[2].kind, CommandKind::D2H);
        assert_eq!(ev[1].label, "fill");
        assert!(ev[1].end_cycles > ev[1].start_cycles, "launch takes cycles");
        assert!(ev[1].instrs > 0);
        assert_eq!(ev[2].start_cycles, ev[1].end_cycles);
        assert_eq!(st.take_u32(t).unwrap(), vec![9u32; 64]);
    }

    /// The queue-wait/execute split: stamps are monotone per command
    /// (enqueue <= start <= end), commands execute in order, copies
    /// cost zero device cycles, and a command enqueued before a launch
    /// executed accrues the launch's cycles as queue wait.
    #[test]
    fn timing_view_is_monotone_and_copies_are_free() {
        let mut st = stream_for(
            r#"
kernel void fill(global int* x, int v, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = v;
}
"#,
        );
        let b = st.malloc(256);
        st.enqueue_write_u32(b, &[0u32; 64]).unwrap();
        st.enqueue_launch(
            "fill",
            [1, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(b), ArgValue::I32(3), ArgValue::I32(64)],
        )
        .unwrap();
        let t = st.enqueue_read_u32(b, 64);
        st.synchronize().unwrap();
        let tm = st.timings();
        assert_eq!(tm.len(), 3);
        for (i, c) in tm.iter().enumerate() {
            assert!(
                c.enqueue_cycle <= c.start_cycle && c.start_cycle <= c.end_cycle,
                "command {i} not monotone: {c:?}"
            );
            if i > 0 {
                assert!(c.start_cycle >= tm[i - 1].end_cycle, "out-of-order execute");
            }
            assert_eq!(c.turnaround(), c.queue_wait() + c.execute_cycles());
        }
        // Copies are host-side: zero device execute cycles.
        assert_eq!(tm[0].kind, CommandKind::H2D);
        assert_eq!(tm[0].execute_cycles(), 0);
        assert_eq!(tm[2].kind, CommandKind::D2H);
        assert_eq!(tm[2].execute_cycles(), 0);
        // The launch consumed cycles; the d2h behind it waited them out.
        let launch = &tm[1];
        assert!(launch.execute_cycles() > 0);
        assert_eq!(launch.queue_wait(), 0, "first batch starts at enqueue time");
        assert_eq!(tm[2].queue_wait(), launch.execute_cycles());

        // A second batch enqueues at the advanced device clock.
        st.enqueue_write_u32(b, &[1u32; 64]).unwrap();
        st.synchronize().unwrap();
        let tm2 = st.timings();
        assert_eq!(tm2.len(), 4);
        assert_eq!(tm2[3].enqueue_cycle, launch.end_cycle);
        assert_eq!(tm2[3].queue_wait(), 0);
        let _ = st.take_u32(t).unwrap();
    }

    /// The containment contract: a failing command faults the stream,
    /// clears the residual queue, fails the pending transfers behind it,
    /// truncates events at the fault, and stays sticky until recover().
    #[test]
    fn failed_command_faults_stream_and_defines_residual_queue() {
        let mut st = stream_for(
            r#"
kernel void double_it(global int* x, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = x[i] * 2;
}
"#,
        );
        inject(
            &mut st,
            FaultPlan::none().with(0, FaultKind::IllegalTrap { pc: None }),
        );
        let buf = st.malloc(64 * 4);
        let data: Vec<u32> = (0..64).collect();
        st.enqueue_write_u32(buf, &data).unwrap();
        st.enqueue_launch(
            "double_it",
            [1, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(buf), ArgValue::I32(64)],
        )
        .unwrap();
        let t1 = st.enqueue_read_u32(buf, 64);
        let t2 = st.enqueue_read_u32(buf, 64);
        let e = st.synchronize().unwrap_err();
        assert!(e.to_string().contains("[injected]"), "{e}");

        // Residual queue is defined: cleared, transfers Failed, events
        // truncated at the fault (only the h2d completed).
        assert_eq!(st.pending(), 0, "queue must be cleared on fault");
        assert!(st.is_faulted());
        let f = st.fault().unwrap();
        assert_eq!(f.kind, CommandKind::Launch);
        assert_eq!(f.label, "double_it");
        assert_eq!(st.events().len(), 1);
        assert_eq!(st.events()[0].kind, CommandKind::H2D);
        let e = st.take_u32(t1).unwrap_err();
        assert!(
            e.to_string().contains("stream faulted at 'double_it'"),
            "{e}"
        );

        // Sticky: every subsequent call returns the original cause.
        let e = st.enqueue_write_u32(buf, &data).unwrap_err();
        assert!(e.to_string().contains("[injected]"), "{e}");
        let e = st
            .enqueue_launch(
                "double_it",
                [1, 1, 1],
                [64, 1, 1],
                &[ArgValue::Ptr(buf), ArgValue::I32(64)],
            )
            .unwrap_err();
        assert!(e.to_string().contains("[injected]"), "{e}");
        let e = st.synchronize().unwrap_err();
        assert!(e.to_string().contains("[injected]"), "{e}");
        // Reads enqueued while faulted are born Failed.
        let t3 = st.enqueue_read_u32(buf, 64);
        assert!(st.take_u32(t3).is_err());

        // Recovery: fault cleared, device rolled back, rerun succeeds
        // (the injected fault was one-shot and already consumed).
        let f = st.recover().expect("fault to clear");
        assert_eq!(f.kind, CommandKind::Launch);
        assert!(st.recover().is_none(), "recover is idempotent");
        st.enqueue_launch(
            "double_it",
            [1, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(buf), ArgValue::I32(64)],
        )
        .unwrap();
        let t = st.enqueue_read_u32(buf, 64);
        st.synchronize().unwrap();
        let want: Vec<u32> = (0..64).map(|i| i * 2).collect();
        assert_eq!(
            st.take_u32(t).unwrap(),
            want,
            "rollback must have restored the pre-launch input"
        );
        let _ = st.take_u32(t2).unwrap_err();
    }

    /// A LaunchPolicy with enough retries absorbs transient injected
    /// faults; the stream never faults and results are correct.
    #[test]
    fn launch_policy_retries_transient_faults_on_stream() {
        let mut st = stream_for(
            r#"
kernel void fill(global int* x, int v, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = v;
}
"#,
        );
        inject(
            &mut st,
            FaultPlan::none()
                .with(0, FaultKind::IllegalTrap { pc: None })
                .with(0, FaultKind::MemTrap { pc: None }),
        );
        st.set_launch_policy(LaunchPolicy {
            retries: 2,
            backoff_cycles: 0,
            watchdog_max_cycles: None,
        });
        let b = st.malloc(256);
        st.enqueue_write_u32(b, &[0u32; 64]).unwrap();
        st.enqueue_launch(
            "fill",
            [1, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(b), ArgValue::I32(9), ArgValue::I32(64)],
        )
        .unwrap();
        let t = st.enqueue_read_u32(b, 64);
        st.synchronize().unwrap();
        assert!(!st.is_faulted());
        assert_eq!(st.take_u32(t).unwrap(), vec![9u32; 64]);
        assert_eq!(st.device_mut().launches_recovered, 1);
        assert_eq!(st.device_mut().retries_performed, 2);
    }
}
