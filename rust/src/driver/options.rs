//! Unified compile/run options with build-time validation.
//!
//! The seed spread configuration over three disjoint structs
//! (`FrontendOptions` + `OptLevel` + `BackendOptions`) and let callers
//! combine them inconsistently (e.g. a `zicond` back-end with a ladder
//! level that never forms selects). [`VoltOptions`] owns the whole
//! configuration, derives the per-layer views, and
//! [`VoltOptionsBuilder::build`] rejects combinations the stack cannot
//! honor.

use super::error::VoltError;
use crate::backend::emit::{BackendOptions, SharedMemMapping, SMEM_MAX_CORES};
use crate::check::{CheckMode, CheckParams};
use crate::frontend::builtins::{SCRATCH_LANES, SCRATCH_WARPS};
use crate::frontend::{Dialect, FrontendOptions};
use crate::sim::SimConfig;
use crate::target::TargetDesc;
use crate::transform::{OptConfig, OptLevel};

#[derive(Clone, Copy, Debug)]
pub struct VoltOptions {
    pub dialect: Dialect,
    /// The machine being compiled for (`volt::target`). Select legality,
    /// warp-primitive availability, register-file shape, the address map
    /// and the device feature set all derive from this; it is part of
    /// the binary-cache fingerprint, so the same source compiled for two
    /// targets occupies two cache entries.
    pub target: TargetDesc,
    /// Lower warp builtins to vx_shfl/vx_vote (true) or the CuPBoP-style
    /// shared-memory software emulation (false) — the Fig. 9 axis. On a
    /// target without the shfl/vote extensions, `true` makes kernels that
    /// actually use warp builtins fail with a typed back-end error.
    pub warp_hw: bool,
    /// Ladder point (paper §5.2, plus the repo's O3 rung above Recon).
    pub opt: OptLevel,
    /// Back-end conditional-move override. `None` derives it from the
    /// ladder level and the target's feature set (the only consistent
    /// default); `Some(true)` on a target without ZiCond is rejected.
    pub zicond: Option<bool>,
    pub opt_layout: bool,
    /// The Fig. 5 divergence safety net.
    pub safety_net: bool,
    /// Shared-memory mapping (Fig. 10 axis).
    pub smem: SharedMemMapping,
    /// Run the IR verifier after every middle-end pass.
    pub verify_ir: bool,
    /// Keep compiled binaries in the session's content-addressed cache.
    pub cache: bool,
    /// Run every launch under the `volt::prof` profiler: streams created
    /// from this session collect a per-launch
    /// [`crate::prof::KernelProfile`]. Pure observation — cycle counts
    /// and results are bit-identical with it on or off — and it does not
    /// affect the produced binary (excluded from the cache fingerprint).
    pub profiling: bool,
    /// Run the `volt::check` static SIMT verifier on every compile.
    /// `Warn` records diagnostics on the session
    /// ([`super::Session::last_diagnostics`]); `Deny` turns any
    /// diagnostic into a typed [`VoltError::Validation`]. Pure analysis —
    /// the produced binary is identical in all modes, so this is excluded
    /// from the cache fingerprint (like `profiling`).
    pub check: CheckMode,
    /// Workgroup size the static checker assumes (the two-thread race
    /// reduction and the bounds pass are relative to it).
    pub check_local_size: [u32; 3],
    /// Device geometry streams created from this session will use.
    pub sim: SimConfig,
}

impl Default for VoltOptions {
    /// The paper's evaluation defaults: OpenCL dialect, full ladder,
    /// hardware warp primitives, scratchpad shared memory, caching on.
    fn default() -> Self {
        VoltOptions {
            dialect: Dialect::OpenCL,
            target: TargetDesc::vortex(),
            warp_hw: true,
            opt: OptLevel::Recon,
            zicond: None,
            opt_layout: true,
            safety_net: true,
            smem: SharedMemMapping::Local,
            verify_ir: false,
            cache: true,
            profiling: false,
            check: CheckMode::Off,
            check_local_size: [64, 1, 1],
            sim: SimConfig::default(),
        }
    }
}

impl VoltOptions {
    pub fn builder() -> VoltOptionsBuilder {
        VoltOptionsBuilder {
            opts: VoltOptions::default(),
            bad_target: None,
            sim_explicit: false,
            warp_hw_explicit: false,
        }
    }

    /// Effective conditional-move setting: the explicit override (else
    /// the ladder-level derivation), gated on the target actually
    /// implementing the extension. On `vortex-min` this is always false
    /// — selects are legalized to branches regardless of ladder level.
    pub fn effective_zicond(&self) -> bool {
        self.zicond.unwrap_or(self.opt >= OptLevel::ZiCond) && self.target.features.zicond
    }

    /// Front-end view.
    pub fn frontend(&self) -> FrontendOptions {
        FrontendOptions {
            dialect: self.dialect,
            warp_hw: self.warp_hw,
        }
    }

    /// Middle-end view. ZiCond is kept consistent with the back-end so
    /// select formation and cmov emission always agree.
    ///
    /// Per-pass verification (`OptConfig::verify`) is deliberately left
    /// off: it panics on failure (a debug harness), while the driver's
    /// `verify_ir` runs one post-middle-end verification that reports a
    /// typed [`VoltError::MiddleEnd`] instead.
    pub fn opt_config(&self) -> OptConfig {
        let mut cfg = self.opt.config();
        cfg.zicond = self.effective_zicond();
        cfg.features = self.target.features;
        cfg.verify = false;
        cfg
    }

    /// Back-end view. The codegen-quality rung (MIR combine + regalloc
    /// holes/coalescing/Belady spilling) rides the O3 ladder point, so
    /// `benches/o3_cycles.rs` measures its harvest against the Recon
    /// baseline the same way the middle-end O3 passes are measured.
    pub fn backend(&self) -> BackendOptions {
        BackendOptions {
            zicond: self.effective_zicond(),
            opt_layout: self.opt_layout,
            safety_net: self.safety_net,
            smem: self.smem,
            codegen_opt: self.opt >= OptLevel::O3,
            target: self.target,
        }
    }

    /// The device configuration streams created from this session use:
    /// the caller's geometry, with the feature set, address map and cost
    /// model always taken from the target (geometry is configurable,
    /// machine identity is not).
    pub fn device_config(&self) -> SimConfig {
        SimConfig {
            features: self.target.features,
            addr_map: self.target.addr_map,
            costs: self.target.costs,
            ..self.sim
        }
    }

    /// Static-checker view.
    pub fn check_params(&self) -> CheckParams {
        CheckParams {
            local_size: [
                self.check_local_size[0] as u64,
                self.check_local_size[1] as u64,
                self.check_local_size[2] as u64,
            ],
        }
    }

    /// Fold every field that affects the produced binary into the cache
    /// fingerprint (FNV-1a). Simulator geometry and `verify_ir` do not
    /// change the image and are deliberately excluded — the whole `sim`
    /// struct stays out, so pure host-side execution knobs
    /// (`fast_forward`, `threads`, the trace JIT's `jit`) can never
    /// split the cache; the target (name, features, shape, map) is
    /// included, so identical source compiled for two targets yields
    /// two distinct cache entries.
    pub(crate) fn hash_into(&self, h: &mut Fnv1a) {
        h.bytes(&self.target.fingerprint_bytes());
        h.byte(match self.dialect {
            Dialect::OpenCL => 0,
            Dialect::Cuda => 1,
        });
        h.byte(self.warp_hw as u8);
        h.byte(self.opt as u8);
        h.byte(self.effective_zicond() as u8);
        h.byte(self.opt_layout as u8);
        h.byte(self.safety_net as u8);
        h.byte(match self.smem {
            SharedMemMapping::Local => 0,
            SharedMemMapping::Global => 1,
        });
    }
}

#[derive(Clone, Debug)]
pub struct VoltOptionsBuilder {
    opts: VoltOptions,
    /// Unknown target name passed to [`VoltOptionsBuilder::target`];
    /// surfaced as a typed error at `build()`.
    bad_target: Option<String>,
    /// Whether the caller set the simulator geometry explicitly (a later
    /// `target()` then keeps it instead of resetting to the profile's
    /// default geometry).
    sim_explicit: bool,
    /// Whether the caller chose warp lowering explicitly (a later
    /// `target()` then keeps it instead of following the profile).
    warp_hw_explicit: bool,
}

impl VoltOptionsBuilder {
    pub fn dialect(mut self, d: Dialect) -> Self {
        self.opts.dialect = d;
        self
    }
    /// Select a built-in target profile by name (`"vortex"`,
    /// `"vortex-min"`). Unknown names become a typed `InvalidOptions`
    /// error at `build()`. Unless the caller already set them
    /// explicitly, the device configuration switches to the profile's
    /// default ([`SimConfig::from_target`]) and warp lowering follows
    /// the profile (`default_warp_hw`) — so `target("vortex-min")`
    /// compiles warp builtins through the software emulation instead of
    /// failing on the missing shfl/vote extensions.
    pub fn target(mut self, name: &str) -> Self {
        match TargetDesc::by_name(name) {
            Some(t) => self.set_target(t),
            None => {
                self.bad_target = Some(name.to_string());
            }
        }
        self
    }
    /// Select a target by description (custom targets included).
    pub fn target_desc(mut self, t: TargetDesc) -> Self {
        self.set_target(t);
        self
    }
    fn set_target(&mut self, t: TargetDesc) {
        self.opts.target = t;
        self.bad_target = None;
        if !self.sim_explicit {
            self.opts.sim = SimConfig::from_target(&t);
        }
        if !self.warp_hw_explicit {
            self.opts.warp_hw = t.default_warp_hw();
        }
    }
    pub fn opt_level(mut self, lvl: OptLevel) -> Self {
        self.opts.opt = lvl;
        self
    }
    pub fn warp_hw(mut self, on: bool) -> Self {
        self.opts.warp_hw = on;
        self.warp_hw_explicit = true;
        self
    }
    /// Force the back-end cmov setting instead of deriving it from the
    /// ladder level. `build` rejects forcing it *on* below `ZiCond`.
    pub fn force_zicond(mut self, on: bool) -> Self {
        self.opts.zicond = Some(on);
        self
    }
    pub fn opt_layout(mut self, on: bool) -> Self {
        self.opts.opt_layout = on;
        self
    }
    pub fn safety_net(mut self, on: bool) -> Self {
        self.opts.safety_net = on;
        self
    }
    pub fn smem(mut self, m: SharedMemMapping) -> Self {
        self.opts.smem = m;
        self
    }
    pub fn verify_ir(mut self, on: bool) -> Self {
        self.opts.verify_ir = on;
        self
    }
    pub fn cache(mut self, on: bool) -> Self {
        self.opts.cache = on;
        self
    }
    /// Collect a per-launch [`crate::prof::KernelProfile`] on streams
    /// created from this session.
    pub fn profiling(mut self, on: bool) -> Self {
        self.opts.profiling = on;
        self
    }
    /// Run the static SIMT verifier on every compile (`Warn` or `Deny`).
    pub fn check(mut self, mode: CheckMode) -> Self {
        self.opts.check = mode;
        self
    }
    /// Workgroup size the static checker assumes (default 64x1x1).
    pub fn check_local_size(mut self, ls: [u32; 3]) -> Self {
        self.opts.check_local_size = ls;
        self
    }
    pub fn sim(mut self, cfg: SimConfig) -> Self {
        self.opts.sim = cfg;
        self.sim_explicit = true;
        self
    }

    /// Validate and produce the final options.
    pub fn build(self) -> Result<VoltOptions, VoltError> {
        if let Some(name) = &self.bad_target {
            return Err(VoltError::invalid_options(format!(
                "unknown target '{name}' (built-in targets: {})",
                TargetDesc::BUILTIN_NAMES.join(", ")
            )));
        }
        self.opts.validate()?;
        Ok(self.opts)
    }
}

impl VoltOptions {
    /// The builder's consistency rules. Also enforced by
    /// [`super::session::compile_program`], so options constructed with a
    /// struct literal (the legacy shim path) cannot bypass them.
    pub fn validate(&self) -> Result<(), VoltError> {
        let o = self;
        // Geometry vs the target's capability ceilings (and the 32-bit
        // mask structural limits): typed errors, never silent clamping.
        o.sim
            .check_caps(&o.target)
            .map_err(VoltError::invalid_options)?;
        // Custom register files must respect the machine's reserved set
        // (x0/ra/sp, spill scratch) — a window overlapping the scratch
        // registers would be a silent miscompile, not an error.
        o.target
            .regfile
            .validate()
            .map_err(|e| VoltError::invalid_options(format!("target '{}': {e}", o.target.name)))?;
        // Custom address maps must give this geometry disjoint, ordered
        // windows: GlobalMem resolves overlapping segments to whichever
        // was added last, so an overlap is silent aliasing (stack stores
        // clobbering the heap), not a fault.
        {
            let m = o.target.addr_map;
            let local_end = m.local_base as u64 + o.sim.local_mem_bytes as u64;
            let stack_end =
                m.stack_base as u64 + o.sim.total_threads() as u64 * m.stack_size as u64;
            let heap_end = m.heap_base as u64 + o.sim.heap_bytes as u64;
            if m.stack_size == 0
                || !(m.data_base < m.local_base
                    && local_end <= m.stack_base as u64
                    && stack_end <= m.heap_base as u64
                    && heap_end <= 1 << 32)
            {
                return Err(VoltError::invalid_options(format!(
                    "target '{}': address map windows overlap or overflow for this \
                     geometry (data {:#x} < local {:#x}..{local_end:#x} <= stack \
                     {:#x}..{stack_end:#x} <= heap {:#x}..{heap_end:#x} <= 4GiB \
                     must hold)",
                    o.target.name,
                    m.data_base,
                    m.local_base,
                    m.stack_base,
                    m.heap_base,
                )));
            }
        }
        if o.zicond == Some(true) && !o.target.features.zicond {
            return Err(VoltError::invalid_options(format!(
                "zicond cmov forced on, but target '{}' does not implement the extension",
                o.target.name
            )));
        }
        if o.smem == SharedMemMapping::Global && o.sim.num_cores > SMEM_MAX_CORES {
            return Err(VoltError::invalid_options(format!(
                "global shared-memory emulation banks support at most {SMEM_MAX_CORES} cores, \
                 device has {}",
                o.sim.num_cores
            )));
        }
        if !o.warp_hw
            && (o.sim.threads_per_warp > SCRATCH_LANES || o.sim.warps_per_core > SCRATCH_WARPS)
        {
            return Err(VoltError::invalid_options(format!(
                "software warp emulation scratch supports {SCRATCH_LANES} lanes x \
                 {SCRATCH_WARPS} warps, device has {} x {}",
                o.sim.threads_per_warp, o.sim.warps_per_core
            )));
        }
        if o.zicond == Some(true) && o.opt < OptLevel::ZiCond {
            return Err(VoltError::invalid_options(format!(
                "zicond cmov forced on, but ladder level {:?} never forms selects",
                o.opt
            )));
        }
        if !o.safety_net && o.opt < OptLevel::Recon {
            return Err(VoltError::invalid_options(format!(
                "safety net disabled below Recon ({:?}): unstructured divergence would be \
                 unguarded (paper Fig. 5)",
                o.opt
            )));
        }
        Ok(())
    }
}

/// Minimal deterministic FNV-1a (offline build: no hasher crates; the
/// std `DefaultHasher` is not guaranteed stable across releases).
pub(crate) struct Fnv1a(pub u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a::new()
    }
}

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }
    pub fn byte(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x100_0000_01b3);
    }
    pub fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.byte(b);
        }
    }
    pub fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_build() {
        let o = VoltOptions::builder().build().unwrap();
        assert!(o.effective_zicond());
        assert_eq!(o.opt, OptLevel::Recon);
        let be = o.backend();
        assert!(be.zicond && be.safety_net);
    }

    #[test]
    fn rejects_inconsistent_combos() {
        assert!(matches!(
            VoltOptions::builder()
                .opt_level(OptLevel::Base)
                .force_zicond(true)
                .build(),
            Err(VoltError::InvalidOptions { .. })
        ));
        assert!(matches!(
            VoltOptions::builder()
                .opt_level(OptLevel::Base)
                .safety_net(false)
                .build(),
            Err(VoltError::InvalidOptions { .. })
        ));
        let big = SimConfig {
            num_cores: 32,
            ..SimConfig::default()
        };
        assert!(matches!(
            VoltOptions::builder()
                .smem(SharedMemMapping::Global)
                .sim(big)
                .build(),
            Err(VoltError::InvalidOptions { .. })
        ));
    }

    #[test]
    fn o3_builds_and_is_output_relevant() {
        let o = VoltOptions::builder()
            .opt_level(OptLevel::O3)
            .build()
            .unwrap();
        assert!(o.effective_zicond(), "O3 derives zicond on");
        assert!(o.opt_config().o3 && o.opt_config().recon);
        // The backend codegen rung rides the O3 ladder point.
        assert!(o.backend().codegen_opt);
        assert!(
            !VoltOptions::default().backend().codegen_opt,
            "Recon is the baseline: backend rung off"
        );
        // O3 must produce a different cache fingerprint than Recon.
        let mut a = Fnv1a::new();
        o.hash_into(&mut a);
        let mut b = Fnv1a::new();
        VoltOptions::default().hash_into(&mut b);
        assert_ne!(a.finish(), b.finish());
        // The ladder-consistency rules still apply above Recon.
        assert!(VoltOptions::builder()
            .opt_level(OptLevel::O3)
            .force_zicond(true)
            .build()
            .is_ok());
        assert!(VoltOptions::builder()
            .opt_level(OptLevel::O3)
            .safety_net(false)
            .build()
            .is_ok());
    }

    #[test]
    fn zicond_derivation_follows_ladder() {
        let o = VoltOptions::builder()
            .opt_level(OptLevel::UniFunc)
            .build()
            .unwrap();
        assert!(!o.effective_zicond());
        assert!(!o.opt_config().zicond);
        let o = VoltOptions::builder()
            .opt_level(OptLevel::ZiCond)
            .build()
            .unwrap();
        assert!(o.effective_zicond());
    }

    #[test]
    fn target_selection_and_validation() {
        // Builder by name: geometry follows the profile default.
        let o = VoltOptions::builder().target("vortex-min").build().unwrap();
        assert_eq!(o.target.name, "vortex-min");
        assert_eq!(o.sim.num_cores, 2);
        assert_eq!(o.sim.warps_per_core, 8);
        assert!(
            !o.warp_hw,
            "warp lowering follows the profile (no shfl/vote on vortex-min)"
        );
        // An explicit warp_hw choice survives target selection in either
        // order.
        let o2 = VoltOptions::builder()
            .warp_hw(true)
            .target("vortex-min")
            .build()
            .unwrap();
        assert!(o2.warp_hw);
        let o3 = VoltOptions::builder()
            .target("vortex-min")
            .warp_hw(true)
            .build()
            .unwrap();
        assert!(o3.warp_hw);
        assert!(!o.effective_zicond(), "vortex-min never forms selects");
        assert!(!o.opt_config().effective_zicond());
        assert!(!o.backend().zicond);
        assert_eq!(o.backend().target.name, "vortex-min");
        let dev = o.device_config();
        assert!(!dev.features.zicond && !dev.features.shfl);
        // Unknown target name: typed error at build.
        let e = VoltOptions::builder().target("ventus").build().unwrap_err();
        assert!(matches!(e, VoltError::InvalidOptions { .. }));
        assert!(e.to_string().contains("ventus"), "{e}");
        // Explicit geometry set before target() is preserved...
        let o = VoltOptions::builder()
            .sim(SimConfig {
                num_cores: 1,
                warps_per_core: 4,
                ..SimConfig::default()
            })
            .target("vortex-min")
            .build()
            .unwrap();
        assert_eq!((o.sim.num_cores, o.sim.warps_per_core), (1, 4));
        // ...but the device identity still comes from the target.
        assert!(!o.device_config().features.vote);
        // Geometry above the target's caps: typed error, no clamping.
        let e = VoltOptions::builder()
            .target("vortex-min")
            .sim(SimConfig {
                warps_per_core: 16,
                ..SimConfig::from_target(&TargetDesc::vortex_min())
            })
            .build()
            .unwrap_err();
        assert!(matches!(e, VoltError::InvalidOptions { .. }), "{e}");
        assert!(e.to_string().contains("warps_per_core"), "{e}");
        // A custom target with a narrower warp cap rejects wide configs.
        let narrow = TargetDesc {
            caps: crate::target::WarpCaps {
                max_threads_per_warp: 8,
                max_warps_per_core: 32,
                max_cores: 64,
            },
            ..TargetDesc::vortex()
        };
        let e = VoltOptionsBuilder {
            opts: VoltOptions {
                target: narrow,
                ..VoltOptions::default()
            },
            bad_target: None,
            sim_explicit: true,
            warp_hw_explicit: false,
        }
        .build()
        .unwrap_err();
        assert!(e.to_string().contains("threads_per_warp"), "{e}");
        // Forcing zicond on a target without it is inconsistent.
        let e = VoltOptions {
            target: TargetDesc::vortex_min(),
            sim: SimConfig::from_target(&TargetDesc::vortex_min()),
            zicond: Some(true),
            ..VoltOptions::default()
        }
        .validate()
        .unwrap_err();
        assert!(e.to_string().contains("zicond"), "{e}");
    }

    #[test]
    fn target_changes_cache_fingerprint() {
        let mut a = Fnv1a::new();
        VoltOptions::default().hash_into(&mut a);
        let min = VoltOptions {
            target: TargetDesc::vortex_min(),
            sim: SimConfig::from_target(&TargetDesc::vortex_min()),
            ..VoltOptions::default()
        };
        let mut b = Fnv1a::new();
        min.hash_into(&mut b);
        assert_ne!(
            a.finish(),
            b.finish(),
            "same source on two targets must occupy two cache entries"
        );
        // Geometry alone (same target) does not change the key.
        let mut c = Fnv1a::new();
        VoltOptions {
            sim: SimConfig {
                num_cores: 1,
                ..SimConfig::default()
            },
            ..VoltOptions::default()
        }
        .hash_into(&mut c);
        assert_eq!(a.finish(), c.finish());
        // Host-side execution knobs (fast-forward, worker threads, the
        // trace JIT) never split the cache either.
        let mut d = Fnv1a::new();
        VoltOptions {
            sim: SimConfig {
                jit: false,
                fast_forward: false,
                threads: 4,
                ..SimConfig::default()
            },
            ..VoltOptions::default()
        }
        .hash_into(&mut d);
        assert_eq!(a.finish(), d.finish(), "sim knobs must not change the key");
    }

    #[test]
    fn fingerprint_tracks_output_relevant_fields() {
        let mut a = Fnv1a::new();
        VoltOptions::default().hash_into(&mut a);
        let mut b = Fnv1a::new();
        VoltOptions {
            verify_ir: true,
            ..VoltOptions::default()
        }
        .hash_into(&mut b);
        assert_eq!(a.finish(), b.finish(), "verify_ir must not change the key");
        // The static checker is pure analysis: same binary either way, so
        // enabling it must hit the same cache entry.
        let mut chk = Fnv1a::new();
        VoltOptions {
            check: CheckMode::Deny,
            check_local_size: [8, 8, 1],
            ..VoltOptions::default()
        }
        .hash_into(&mut chk);
        assert_eq!(a.finish(), chk.finish(), "check must not change the key");
        let mut c = Fnv1a::new();
        VoltOptions {
            opt: OptLevel::Base,
            ..VoltOptions::default()
        }
        .hash_into(&mut c);
        assert_ne!(a.finish(), c.finish());
    }
}
