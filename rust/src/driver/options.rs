//! Unified compile/run options with build-time validation.
//!
//! The seed spread configuration over three disjoint structs
//! (`FrontendOptions` + `OptLevel` + `BackendOptions`) and let callers
//! combine them inconsistently (e.g. a `zicond` back-end with a ladder
//! level that never forms selects). [`VoltOptions`] owns the whole
//! configuration, derives the per-layer views, and
//! [`VoltOptionsBuilder::build`] rejects combinations the stack cannot
//! honor.

use super::error::VoltError;
use crate::backend::emit::{BackendOptions, SharedMemMapping, SMEM_MAX_CORES};
use crate::frontend::builtins::{SCRATCH_LANES, SCRATCH_WARPS};
use crate::frontend::{Dialect, FrontendOptions};
use crate::sim::SimConfig;
use crate::transform::{OptConfig, OptLevel};

#[derive(Clone, Copy, Debug)]
pub struct VoltOptions {
    pub dialect: Dialect,
    /// Lower warp builtins to vx_shfl/vx_vote (true) or the CuPBoP-style
    /// shared-memory software emulation (false) — the Fig. 9 axis.
    pub warp_hw: bool,
    /// Ladder point (paper §5.2, plus the repo's O3 rung above Recon).
    pub opt: OptLevel,
    /// Back-end conditional-move support. `None` derives it from the
    /// ladder level (the only consistent default); `Some(_)` overrides.
    pub zicond: Option<bool>,
    pub opt_layout: bool,
    /// The Fig. 5 divergence safety net.
    pub safety_net: bool,
    /// Shared-memory mapping (Fig. 10 axis).
    pub smem: SharedMemMapping,
    /// Run the IR verifier after every middle-end pass.
    pub verify_ir: bool,
    /// Keep compiled binaries in the session's content-addressed cache.
    pub cache: bool,
    /// Run every launch under the `volt::prof` profiler: streams created
    /// from this session collect a per-launch
    /// [`crate::prof::KernelProfile`]. Pure observation — cycle counts
    /// and results are bit-identical with it on or off — and it does not
    /// affect the produced binary (excluded from the cache fingerprint).
    pub profiling: bool,
    /// Device geometry streams created from this session will use.
    pub sim: SimConfig,
}

impl Default for VoltOptions {
    /// The paper's evaluation defaults: OpenCL dialect, full ladder,
    /// hardware warp primitives, scratchpad shared memory, caching on.
    fn default() -> Self {
        VoltOptions {
            dialect: Dialect::OpenCL,
            warp_hw: true,
            opt: OptLevel::Recon,
            zicond: None,
            opt_layout: true,
            safety_net: true,
            smem: SharedMemMapping::Local,
            verify_ir: false,
            cache: true,
            profiling: false,
            sim: SimConfig::default(),
        }
    }
}

impl VoltOptions {
    pub fn builder() -> VoltOptionsBuilder {
        VoltOptionsBuilder {
            opts: VoltOptions::default(),
        }
    }

    /// Effective conditional-move setting (explicit override, else
    /// derived from the ladder level).
    pub fn effective_zicond(&self) -> bool {
        self.zicond.unwrap_or(self.opt >= OptLevel::ZiCond)
    }

    /// Front-end view.
    pub fn frontend(&self) -> FrontendOptions {
        FrontendOptions {
            dialect: self.dialect,
            warp_hw: self.warp_hw,
        }
    }

    /// Middle-end view. ZiCond is kept consistent with the back-end so
    /// select formation and cmov emission always agree.
    ///
    /// Per-pass verification (`OptConfig::verify`) is deliberately left
    /// off: it panics on failure (a debug harness), while the driver's
    /// `verify_ir` runs one post-middle-end verification that reports a
    /// typed [`VoltError::MiddleEnd`] instead.
    pub fn opt_config(&self) -> OptConfig {
        let mut cfg = self.opt.config();
        cfg.zicond = self.effective_zicond();
        cfg.verify = false;
        cfg
    }

    /// Back-end view.
    pub fn backend(&self) -> BackendOptions {
        BackendOptions {
            zicond: self.effective_zicond(),
            opt_layout: self.opt_layout,
            safety_net: self.safety_net,
            smem: self.smem,
        }
    }

    /// Fold every field that affects the produced binary into the cache
    /// fingerprint (FNV-1a). Simulator geometry and `verify_ir` do not
    /// change the image and are deliberately excluded.
    pub(crate) fn hash_into(&self, h: &mut Fnv1a) {
        h.byte(match self.dialect {
            Dialect::OpenCL => 0,
            Dialect::Cuda => 1,
        });
        h.byte(self.warp_hw as u8);
        h.byte(self.opt as u8);
        h.byte(self.effective_zicond() as u8);
        h.byte(self.opt_layout as u8);
        h.byte(self.safety_net as u8);
        h.byte(match self.smem {
            SharedMemMapping::Local => 0,
            SharedMemMapping::Global => 1,
        });
    }
}

#[derive(Clone, Debug)]
pub struct VoltOptionsBuilder {
    opts: VoltOptions,
}

impl VoltOptionsBuilder {
    pub fn dialect(mut self, d: Dialect) -> Self {
        self.opts.dialect = d;
        self
    }
    pub fn opt_level(mut self, lvl: OptLevel) -> Self {
        self.opts.opt = lvl;
        self
    }
    pub fn warp_hw(mut self, on: bool) -> Self {
        self.opts.warp_hw = on;
        self
    }
    /// Force the back-end cmov setting instead of deriving it from the
    /// ladder level. `build` rejects forcing it *on* below `ZiCond`.
    pub fn force_zicond(mut self, on: bool) -> Self {
        self.opts.zicond = Some(on);
        self
    }
    pub fn opt_layout(mut self, on: bool) -> Self {
        self.opts.opt_layout = on;
        self
    }
    pub fn safety_net(mut self, on: bool) -> Self {
        self.opts.safety_net = on;
        self
    }
    pub fn smem(mut self, m: SharedMemMapping) -> Self {
        self.opts.smem = m;
        self
    }
    pub fn verify_ir(mut self, on: bool) -> Self {
        self.opts.verify_ir = on;
        self
    }
    pub fn cache(mut self, on: bool) -> Self {
        self.opts.cache = on;
        self
    }
    /// Collect a per-launch [`crate::prof::KernelProfile`] on streams
    /// created from this session.
    pub fn profiling(mut self, on: bool) -> Self {
        self.opts.profiling = on;
        self
    }
    pub fn sim(mut self, cfg: SimConfig) -> Self {
        self.opts.sim = cfg;
        self
    }

    /// Validate and produce the final options.
    pub fn build(self) -> Result<VoltOptions, VoltError> {
        self.opts.validate()?;
        Ok(self.opts)
    }
}

impl VoltOptions {
    /// The builder's consistency rules. Also enforced by
    /// [`super::session::compile_program`], so options constructed with a
    /// struct literal (the legacy shim path) cannot bypass them.
    pub fn validate(&self) -> Result<(), VoltError> {
        let o = self;
        if o.sim.num_cores == 0 || o.sim.warps_per_core == 0 || o.sim.threads_per_warp == 0 {
            return Err(VoltError::invalid_options(
                "device geometry must be non-zero (cores, warps, threads)",
            ));
        }
        if o.sim.threads_per_warp > 32 {
            return Err(VoltError::invalid_options(format!(
                "threads_per_warp {} exceeds the 32-lane divergence-mask width",
                o.sim.threads_per_warp
            )));
        }
        if o.smem == SharedMemMapping::Global && o.sim.num_cores > SMEM_MAX_CORES {
            return Err(VoltError::invalid_options(format!(
                "global shared-memory emulation banks support at most {SMEM_MAX_CORES} cores, \
                 device has {}",
                o.sim.num_cores
            )));
        }
        if !o.warp_hw
            && (o.sim.threads_per_warp > SCRATCH_LANES || o.sim.warps_per_core > SCRATCH_WARPS)
        {
            return Err(VoltError::invalid_options(format!(
                "software warp emulation scratch supports {SCRATCH_LANES} lanes x \
                 {SCRATCH_WARPS} warps, device has {} x {}",
                o.sim.threads_per_warp, o.sim.warps_per_core
            )));
        }
        if o.zicond == Some(true) && o.opt < OptLevel::ZiCond {
            return Err(VoltError::invalid_options(format!(
                "zicond cmov forced on, but ladder level {:?} never forms selects",
                o.opt
            )));
        }
        if !o.safety_net && o.opt < OptLevel::Recon {
            return Err(VoltError::invalid_options(format!(
                "safety net disabled below Recon ({:?}): unstructured divergence would be \
                 unguarded (paper Fig. 5)",
                o.opt
            )));
        }
        Ok(())
    }
}

/// Minimal deterministic FNV-1a (offline build: no hasher crates; the
/// std `DefaultHasher` is not guaranteed stable across releases).
pub(crate) struct Fnv1a(pub u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a::new()
    }
}

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }
    pub fn byte(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x100_0000_01b3);
    }
    pub fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.byte(b);
        }
    }
    pub fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_build() {
        let o = VoltOptions::builder().build().unwrap();
        assert!(o.effective_zicond());
        assert_eq!(o.opt, OptLevel::Recon);
        let be = o.backend();
        assert!(be.zicond && be.safety_net);
    }

    #[test]
    fn rejects_inconsistent_combos() {
        assert!(matches!(
            VoltOptions::builder()
                .opt_level(OptLevel::Base)
                .force_zicond(true)
                .build(),
            Err(VoltError::InvalidOptions { .. })
        ));
        assert!(matches!(
            VoltOptions::builder()
                .opt_level(OptLevel::Base)
                .safety_net(false)
                .build(),
            Err(VoltError::InvalidOptions { .. })
        ));
        let big = SimConfig {
            num_cores: 32,
            ..SimConfig::default()
        };
        assert!(matches!(
            VoltOptions::builder()
                .smem(SharedMemMapping::Global)
                .sim(big)
                .build(),
            Err(VoltError::InvalidOptions { .. })
        ));
    }

    #[test]
    fn o3_builds_and_is_output_relevant() {
        let o = VoltOptions::builder()
            .opt_level(OptLevel::O3)
            .build()
            .unwrap();
        assert!(o.effective_zicond(), "O3 derives zicond on");
        assert!(o.opt_config().o3 && o.opt_config().recon);
        // O3 must produce a different cache fingerprint than Recon.
        let mut a = Fnv1a::new();
        o.hash_into(&mut a);
        let mut b = Fnv1a::new();
        VoltOptions::default().hash_into(&mut b);
        assert_ne!(a.finish(), b.finish());
        // The ladder-consistency rules still apply above Recon.
        assert!(VoltOptions::builder()
            .opt_level(OptLevel::O3)
            .force_zicond(true)
            .build()
            .is_ok());
        assert!(VoltOptions::builder()
            .opt_level(OptLevel::O3)
            .safety_net(false)
            .build()
            .is_ok());
    }

    #[test]
    fn zicond_derivation_follows_ladder() {
        let o = VoltOptions::builder()
            .opt_level(OptLevel::UniFunc)
            .build()
            .unwrap();
        assert!(!o.effective_zicond());
        assert!(!o.opt_config().zicond);
        let o = VoltOptions::builder()
            .opt_level(OptLevel::ZiCond)
            .build()
            .unwrap();
        assert!(o.effective_zicond());
    }

    #[test]
    fn fingerprint_tracks_output_relevant_fields() {
        let mut a = Fnv1a::new();
        VoltOptions::default().hash_into(&mut a);
        let mut b = Fnv1a::new();
        VoltOptions {
            verify_ir: true,
            ..VoltOptions::default()
        }
        .hash_into(&mut b);
        assert_eq!(a.finish(), b.finish(), "verify_ir must not change the key");
        let mut c = Fnv1a::new();
        VoltOptions {
            opt: OptLevel::Base,
            ..VoltOptions::default()
        }
        .hash_into(&mut c);
        assert_ne!(a.finish(), c.finish());
    }
}
