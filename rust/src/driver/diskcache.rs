//! Corruption-safe persistent compile cache (`volt::resilience`).
//!
//! An on-disk tier under the session's in-memory binary cache, keyed by
//! the same source × options × target fingerprint
//! ([`super::session::fingerprint`]). Each entry is one file
//! `<key:016x>.voltc` holding the linked [`ProgramImage`] and kernel
//! table in a hand-rolled little-endian format:
//!
//! ```text
//! magic "VOLTDC1\0" (8) | key u64 | payload_len u64 | fnv1a(payload) u64 | payload
//! ```
//!
//! Durability rules, in order of importance:
//!
//! * **A bad entry is never a crash.** Every read validates magic, key,
//!   length and checksum, and decodes with bounds-checked readers; any
//!   mismatch degrades to a miss ([`DiskLookup::Corrupt`]) and the file
//!   is moved to a `quarantine/` subdirectory for post-mortem.
//! * **Writes are atomic**: temp file + rename, so a crash mid-store
//!   leaves either the old entry or none — never a torn file at the
//!   entry's name.
//! * **Size-capped**: after each store the cache evicts
//!   least-recently-used entries (a best-effort `lru.txt` index; entries
//!   missing from it are evicted first) until under `max_bytes`.
//! * **Best-effort**: I/O errors never surface to the compile path; a
//!   failed store just means the next session recompiles.
//!
//! Decoded programs carry default middle-end/timing reports (the pass
//! pipeline did not run); the image, kernel ABI and fingerprint are
//! exactly what the compiling session stored.

use super::options::Fnv1a;
use super::session::KernelEntry;
use crate::backend::emit::ProgramImage;
use crate::backend::isa::MachInst;
use crate::ir::{AddrSpace, Loc, Type};
use crate::target::AddressMap;
use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"VOLTDC1\0";

/// Outcome of a disk-cache probe.
pub enum DiskLookup {
    /// Entry present and verified; the decoded image and kernel table.
    Hit(Box<(ProgramImage, Vec<KernelEntry>)>),
    /// No entry under this key.
    Miss,
    /// Entry present but failed validation; it has been quarantined and
    /// the caller should treat this as a miss (recompile).
    Corrupt,
}

/// The persistent tier. All methods are infallible at the API level:
/// I/O problems turn into misses (loads) or dropped writes (stores).
pub struct DiskCache {
    dir: PathBuf,
    /// Eviction threshold over the summed `.voltc` sizes; `0` = uncapped.
    max_bytes: u64,
    pub hits: u64,
    pub misses: u64,
    pub corrupt: u64,
    pub evicted: u64,
}

impl DiskCache {
    pub fn new(dir: impl AsRef<Path>, max_bytes: u64) -> DiskCache {
        let dir = dir.as_ref().to_path_buf();
        let _ = fs::create_dir_all(&dir);
        DiskCache {
            dir,
            max_bytes,
            hits: 0,
            misses: 0,
            corrupt: 0,
            evicted: 0,
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn entry_path(&self, key: u64) -> PathBuf {
        self.dir.join(format!("{key:016x}.voltc"))
    }

    /// Number of quarantined (corrupt) entries currently on disk.
    pub fn quarantined(&self) -> usize {
        fs::read_dir(self.dir.join("quarantine"))
            .map(|d| d.count())
            .unwrap_or(0)
    }

    /// Probe the cache. A verified entry is a [`DiskLookup::Hit`]; a
    /// missing file is a miss; anything that fails validation is
    /// quarantined and reported [`DiskLookup::Corrupt`].
    pub fn load(&mut self, key: u64) -> DiskLookup {
        let bytes = match fs::read(self.entry_path(key)) {
            Ok(b) => b,
            Err(_) => {
                self.misses += 1;
                return DiskLookup::Miss;
            }
        };
        match decode_entry(key, &bytes) {
            Some(hit) => {
                self.hits += 1;
                self.touch(key);
                DiskLookup::Hit(Box::new(hit))
            }
            None => {
                self.corrupt += 1;
                self.quarantine(key);
                DiskLookup::Corrupt
            }
        }
    }

    /// Store an entry atomically (temp + rename), then evict down to the
    /// size cap. Best-effort: failures are swallowed.
    pub fn store(&mut self, key: u64, image: &ProgramImage, kernels: &[KernelEntry]) {
        let payload = encode_payload(image, kernels);
        let mut file = Vec::with_capacity(payload.len() + 32);
        file.extend_from_slice(MAGIC);
        file.extend_from_slice(&key.to_le_bytes());
        file.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        let mut h = Fnv1a::new();
        h.bytes(&payload);
        file.extend_from_slice(&h.finish().to_le_bytes());
        file.extend_from_slice(&payload);
        let _ = fs::create_dir_all(&self.dir);
        let tmp = self
            .dir
            .join(format!("{key:016x}.tmp.{}", std::process::id()));
        if fs::write(&tmp, &file).is_ok() && fs::rename(&tmp, self.entry_path(key)).is_ok() {
            self.touch(key);
            self.evict_to_cap();
        } else {
            let _ = fs::remove_file(&tmp);
        }
    }

    /// Move a bad entry aside so it cannot poison future sessions but
    /// stays available for inspection.
    fn quarantine(&self, key: u64) {
        let qdir = self.dir.join("quarantine");
        let _ = fs::create_dir_all(&qdir);
        let _ = fs::rename(self.entry_path(key), qdir.join(format!("{key:016x}.voltc")));
    }

    fn lru_path(&self) -> PathBuf {
        self.dir.join("lru.txt")
    }

    fn read_lru(&self) -> Vec<u64> {
        let Ok(text) = fs::read_to_string(self.lru_path()) else {
            return vec![];
        };
        text.lines()
            .filter_map(|l| u64::from_str_radix(l.trim(), 16).ok())
            .collect()
    }

    fn write_lru(&self, keys: &[u64]) {
        let text: String = keys.iter().map(|k| format!("{k:016x}\n")).collect();
        let tmp = self.dir.join(format!("lru.tmp.{}", std::process::id()));
        if fs::write(&tmp, text).is_ok() {
            let _ = fs::rename(&tmp, self.lru_path());
        }
    }

    /// Mark `key` most-recently-used.
    fn touch(&self, key: u64) {
        let mut lru = self.read_lru();
        lru.retain(|&k| k != key);
        lru.push(key);
        self.write_lru(&lru);
    }

    /// Delete least-recently-used entries until the summed entry size is
    /// under the cap. Entries absent from the LRU index (e.g. the index
    /// was lost) are evicted first.
    fn evict_to_cap(&mut self) {
        if self.max_bytes == 0 {
            return;
        }
        let mut sizes: HashMap<u64, u64> = HashMap::new();
        let Ok(entries) = fs::read_dir(&self.dir) else {
            return;
        };
        for e in entries.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if let Some(hex) = name.strip_suffix(".voltc") {
                if let Ok(key) = u64::from_str_radix(hex, 16) {
                    let size = e.metadata().map(|m| m.len()).unwrap_or(0);
                    sizes.insert(key, size);
                }
            }
        }
        let mut total: u64 = sizes.values().sum();
        if total <= self.max_bytes {
            return;
        }
        let mut lru = self.read_lru();
        let mut order: Vec<u64> = sizes
            .keys()
            .copied()
            .filter(|k| !lru.contains(k))
            .collect();
        order.sort_unstable(); // deterministic order for unindexed keys
        order.extend(lru.iter().copied().filter(|k| sizes.contains_key(k)));
        for key in order {
            if total <= self.max_bytes {
                break;
            }
            if fs::remove_file(self.entry_path(key)).is_ok() {
                self.evicted += 1;
                total -= sizes[&key];
                lru.retain(|&k| k != key);
            }
        }
        self.write_lru(&lru);
    }
}

// ---------------------------------------------------------------------------
// Entry framing
// ---------------------------------------------------------------------------

fn decode_entry(key: u64, bytes: &[u8]) -> Option<(ProgramImage, Vec<KernelEntry>)> {
    if bytes.len() < 32 || &bytes[0..8] != MAGIC {
        return None;
    }
    let stored_key = u64::from_le_bytes(bytes[8..16].try_into().ok()?);
    if stored_key != key {
        return None;
    }
    let payload_len = u64::from_le_bytes(bytes[16..24].try_into().ok()?) as usize;
    let checksum = u64::from_le_bytes(bytes[24..32].try_into().ok()?);
    let payload = bytes.get(32..)?;
    if payload.len() != payload_len {
        return None; // truncated or trailing garbage
    }
    let mut h = Fnv1a::new();
    h.bytes(payload);
    if h.finish() != checksum {
        return None;
    }
    decode_payload(payload)
}

// ---------------------------------------------------------------------------
// Payload serialization (bounds-checked, deterministic: maps are written
// in sorted key order, so identical programs produce identical bytes)
// ---------------------------------------------------------------------------

#[derive(Default)]
struct W {
    buf: Vec<u8>,
}

impl W {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn b(&mut self, v: bool) {
        self.buf.push(v as u8);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn s(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.u32(b.len() as u32);
        self.buf.extend_from_slice(b);
    }
    fn str_u32_map(&mut self, m: &HashMap<String, u32>) {
        let mut keys: Vec<&String> = m.keys().collect();
        keys.sort();
        self.u32(keys.len() as u32);
        for k in keys {
            self.s(k);
            self.u32(m[k]);
        }
    }
}

struct R<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> R<'a> {
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let s = self.buf.get(self.pos..self.pos.checked_add(n)?)?;
        self.pos += n;
        Some(s)
    }
    fn u8(&mut self) -> Option<u8> {
        Some(self.take(1)?[0])
    }
    fn b(&mut self) -> Option<bool> {
        match self.u8()? {
            0 => Some(false),
            1 => Some(true),
            _ => None,
        }
    }
    fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }
    fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }
    fn s(&mut self) -> Option<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).ok()
    }
    fn bytes(&mut self) -> Option<Vec<u8>> {
        let n = self.u32()? as usize;
        Some(self.take(n)?.to_vec())
    }
    fn str_u32_map(&mut self) -> Option<HashMap<String, u32>> {
        let n = self.u32()? as usize;
        let mut m = HashMap::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            let k = self.s()?;
            let v = self.u32()?;
            m.insert(k, v);
        }
        Some(m)
    }
}

fn type_tag(t: Type) -> u8 {
    match t {
        Type::Void => 0,
        Type::I1 => 1,
        Type::I32 => 2,
        Type::F32 => 3,
        Type::Ptr(AddrSpace::Global) => 4,
        Type::Ptr(AddrSpace::Local) => 5,
        Type::Ptr(AddrSpace::Const) => 6,
        Type::Ptr(AddrSpace::Private) => 7,
    }
}

fn type_from_tag(tag: u8) -> Option<Type> {
    Some(match tag {
        0 => Type::Void,
        1 => Type::I1,
        2 => Type::I32,
        3 => Type::F32,
        4 => Type::Ptr(AddrSpace::Global),
        5 => Type::Ptr(AddrSpace::Local),
        6 => Type::Ptr(AddrSpace::Const),
        7 => Type::Ptr(AddrSpace::Private),
        _ => return None,
    })
}

pub(crate) fn encode_payload(image: &ProgramImage, kernels: &[KernelEntry]) -> Vec<u8> {
    let mut w = W::default();
    w.s(&image.target);
    w.s(&image.kernel);
    // Instructions travel in their encoded form; decode on read
    // re-validates every opcode.
    w.u32(image.words.len() as u32);
    for &word in &image.words {
        w.u64(word);
    }
    w.u32(image.data.len() as u32);
    for (addr, bytes) in &image.data {
        w.u32(*addr);
        w.bytes(bytes);
    }
    w.u32(image.data_end);
    w.str_u32_map(&image.global_addr);
    w.str_u32_map(&image.global_size);
    w.u32(image.args_addr);
    w.u32(image.local_mem_size);
    w.str_u32_map(&image.func_entries);
    w.u32(image.pc_loc.len() as u32);
    for loc in &image.pc_loc {
        match loc {
            Some(l) => {
                w.u8(1);
                w.u32(l.line);
                w.u32(l.col);
            }
            None => w.u8(0),
        }
    }
    w.u32(image.crt0_len);
    w.u32(image.pc_spill.len() as u32);
    for &s in &image.pc_spill {
        w.b(s);
    }
    let am = image.addr_map;
    w.u32(am.data_base);
    w.u32(am.local_base);
    w.u32(am.stack_base);
    w.u32(am.stack_size);
    w.u32(am.heap_base);
    w.u32(kernels.len() as u32);
    for k in kernels {
        w.s(&k.name);
        w.s(&k.entry_symbol);
        w.u32(k.entry_pc);
        w.u32(k.params.len() as u32);
        for (name, ty) in &k.params {
            w.s(name);
            w.u8(type_tag(*ty));
        }
        w.u32(k.local_mem);
        w.b(k.uses_barrier);
    }
    w.buf
}

fn decode_payload(buf: &[u8]) -> Option<(ProgramImage, Vec<KernelEntry>)> {
    let mut r = R { buf, pos: 0 };
    let target = r.s()?;
    let kernel = r.s()?;
    let n_words = r.u32()? as usize;
    let mut words = Vec::with_capacity(n_words.min(1 << 22));
    for _ in 0..n_words {
        words.push(r.u64()?);
    }
    let code: Vec<MachInst> = words
        .iter()
        .map(|&w| MachInst::decode(w))
        .collect::<Option<Vec<_>>>()?;
    let n_data = r.u32()? as usize;
    let mut data = Vec::with_capacity(n_data.min(1 << 16));
    for _ in 0..n_data {
        let addr = r.u32()?;
        let bytes = r.bytes()?;
        data.push((addr, bytes));
    }
    let data_end = r.u32()?;
    let global_addr = r.str_u32_map()?;
    let global_size = r.str_u32_map()?;
    let args_addr = r.u32()?;
    let local_mem_size = r.u32()?;
    let func_entries = r.str_u32_map()?;
    let n_loc = r.u32()? as usize;
    if n_loc != code.len() {
        return None; // pc_loc must stay parallel to code
    }
    let mut pc_loc = Vec::with_capacity(n_loc.min(1 << 22));
    for _ in 0..n_loc {
        pc_loc.push(match r.u8()? {
            0 => None,
            1 => Some(Loc {
                line: r.u32()?,
                col: r.u32()?,
            }),
            _ => return None,
        });
    }
    let crt0_len = r.u32()?;
    let n_spill = r.u32()? as usize;
    if n_spill != code.len() {
        return None;
    }
    let mut pc_spill = Vec::with_capacity(n_spill.min(1 << 22));
    for _ in 0..n_spill {
        pc_spill.push(r.b()?);
    }
    let addr_map = AddressMap {
        data_base: r.u32()?,
        local_base: r.u32()?,
        stack_base: r.u32()?,
        stack_size: r.u32()?,
        heap_base: r.u32()?,
    };
    let n_kernels = r.u32()? as usize;
    let mut kernels = Vec::with_capacity(n_kernels.min(1 << 12));
    for _ in 0..n_kernels {
        let name = r.s()?;
        let entry_symbol = r.s()?;
        let entry_pc = r.u32()?;
        let n_params = r.u32()? as usize;
        let mut params = Vec::with_capacity(n_params.min(1 << 8));
        for _ in 0..n_params {
            let pname = r.s()?;
            let ty = type_from_tag(r.u8()?)?;
            params.push((pname, ty));
        }
        let local_mem = r.u32()?;
        let uses_barrier = r.b()?;
        kernels.push(KernelEntry {
            name,
            entry_symbol,
            entry_pc,
            params,
            local_mem,
            uses_barrier,
        });
    }
    if r.pos != buf.len() {
        return None; // trailing garbage
    }
    Some((
        ProgramImage {
            code,
            words,
            data,
            data_end,
            global_addr,
            global_size,
            args_addr,
            local_mem_size,
            kernel,
            func_entries,
            pc_loc,
            crt0_len,
            pc_spill,
            target,
            addr_map,
        },
        kernels,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::session::compile_program;
    use crate::driver::VoltOptions;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn tmpdir(tag: &str) -> PathBuf {
        static N: AtomicU64 = AtomicU64::new(0);
        let d = std::env::temp_dir().join(format!(
            "volt-dc-{tag}-{}-{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn sample() -> crate::driver::session::Program {
        compile_program(
            r#"
kernel void double_it(global int* x, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = x[i] * 2;
}
"#,
            &VoltOptions::default(),
        )
        .unwrap()
    }

    #[test]
    fn roundtrip_is_lossless_and_deterministic() {
        let p = sample();
        let dir = tmpdir("rt");
        let mut dc = DiskCache::new(&dir, 0);
        dc.store(p.fingerprint, &p.image, &p.kernels);
        let mut dc2 = DiskCache::new(&dir, 0);
        let DiskLookup::Hit(hit) = dc2.load(p.fingerprint) else {
            panic!("expected hit");
        };
        let (image, kernels) = *hit;
        assert_eq!(image.words, p.image.words);
        assert_eq!(image.code.len(), p.image.code.len());
        assert_eq!(image.data, p.image.data);
        assert_eq!(image.func_entries, p.image.func_entries);
        assert_eq!(image.pc_loc, p.image.pc_loc);
        assert_eq!(image.pc_spill, p.image.pc_spill);
        assert_eq!(image.target, p.image.target);
        assert_eq!(kernels.len(), p.kernels.len());
        assert_eq!(kernels[0].name, "double_it");
        assert_eq!(kernels[0].params, p.kernels[0].params);
        // Deterministic bytes: re-encoding the decoded entry is identical.
        assert_eq!(
            encode_payload(&image, &kernels),
            encode_payload(&p.image, &p.kernels)
        );
        assert_eq!(dc2.hits, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn flipped_byte_quarantines_and_degrades_to_miss() {
        let p = sample();
        let dir = tmpdir("corrupt");
        let mut dc = DiskCache::new(&dir, 0);
        dc.store(p.fingerprint, &p.image, &p.kernels);
        let path = dc.entry_path(p.fingerprint);
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        fs::write(&path, &bytes).unwrap();

        let mut dc2 = DiskCache::new(&dir, 0);
        assert!(matches!(dc2.load(p.fingerprint), DiskLookup::Corrupt));
        assert_eq!(dc2.corrupt, 1);
        assert_eq!(dc2.quarantined(), 1, "bad entry must be quarantined");
        assert!(!path.exists(), "bad entry must leave the cache dir");
        // The poisoned key is now a plain miss.
        assert!(matches!(dc2.load(p.fingerprint), DiskLookup::Miss));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncation_and_key_mismatch_are_corrupt() {
        let p = sample();
        let dir = tmpdir("trunc");
        let mut dc = DiskCache::new(&dir, 0);
        dc.store(p.fingerprint, &p.image, &p.kernels);
        let path = dc.entry_path(p.fingerprint);
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(matches!(dc.load(p.fingerprint), DiskLookup::Corrupt));

        // An entry copied to the wrong key (embedded key mismatch).
        dc.store(p.fingerprint, &p.image, &p.kernels);
        let other = p.fingerprint ^ 1;
        fs::copy(dc.entry_path(p.fingerprint), dc.entry_path(other)).unwrap();
        assert!(matches!(dc.load(other), DiskLookup::Corrupt));
        assert_eq!(dc.corrupt, 2);
        assert_eq!(dc.quarantined(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn eviction_drops_least_recently_used_first() {
        let p = sample();
        let entry_size = {
            let dir = tmpdir("size");
            let mut dc = DiskCache::new(&dir, 0);
            dc.store(p.fingerprint, &p.image, &p.kernels);
            let n = fs::metadata(dc.entry_path(p.fingerprint)).unwrap().len();
            let _ = fs::remove_dir_all(&dir);
            n
        };
        let dir = tmpdir("evict");
        // Cap fits two entries but not three.
        let mut dc = DiskCache::new(&dir, entry_size * 2 + entry_size / 2);
        let (k1, k2, k3) = (p.fingerprint, p.fingerprint ^ 2, p.fingerprint ^ 4);
        dc.store(k1, &p.image, &p.kernels);
        dc.store(k2, &p.image, &p.kernels);
        assert_eq!(dc.evicted, 0);
        // Touch k1 so k2 is the LRU entry when k3 forces an eviction.
        assert!(matches!(dc.load(k1), DiskLookup::Hit(_)));
        dc.store(k3, &p.image, &p.kernels);
        assert_eq!(dc.evicted, 1);
        assert!(matches!(dc.load(k2), DiskLookup::Miss), "LRU entry evicted");
        assert!(matches!(dc.load(k1), DiskLookup::Hit(_)));
        assert!(matches!(dc.load(k3), DiskLookup::Hit(_)));
        let _ = fs::remove_dir_all(&dir);
    }
}
