//! Compilation sessions: source in, cached multi-kernel [`Program`] out.
//!
//! A [`Session`] owns one [`VoltOptions`] configuration and a
//! content-addressed binary cache keyed by FNV-1a over (source bytes,
//! output-relevant options). Repeated compiles of identical source are
//! near-free cache hits — the property a production service compiling the
//! same kernels for many users depends on. Unlike the seed's
//! `compile_source`, a `Program` exposes a launchable entry for *every*
//! kernel in the module, not just `kernels[0]`.
//!
//! Sessions are `Send + Sync`: [`Session::compile`] takes `&self`, the
//! memory tier is sharded behind `RwLock`s, and concurrent compiles of
//! the *same* (source, options) pair are deduplicated — one thread runs
//! the pipeline, the rest wait and share its `Arc<Program>` (see
//! `docs/PARALLELISM.md`).

use super::diskcache::{DiskCache, DiskLookup};
use super::error::VoltError;
use super::options::{Fnv1a, VoltOptions};
use super::stream::Stream;
use crate::backend::emit::{build_image_threaded, BackendError, ProgramImage};
use crate::check::{self, CheckMode, Diag};
use crate::frontend::compile_kernels;
use crate::ir::Type;
use crate::transform::pass::run_middle_end_with_threads;
use crate::transform::MiddleEndReport;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Instant;

/// Per-stage wall-clock compile timings (the §5.2 overhead experiment).
#[derive(Clone, Copy, Debug, Default)]
pub struct CompileTimings {
    pub frontend_ms: f64,
    pub middle_ms: f64,
    pub backend_ms: f64,
}

impl CompileTimings {
    pub fn total_ms(&self) -> f64 {
        self.frontend_ms + self.middle_ms + self.backend_ms
    }
}

/// One launchable kernel of a [`Program`]: the host-visible ABI plus the
/// entry PC crt0 jumps to (read from the argument block at launch).
#[derive(Clone, Debug)]
pub struct KernelEntry {
    /// Source-level kernel name (what you pass to launch).
    pub name: String,
    /// Linked dispatcher symbol (`__main_<name>`).
    pub entry_symbol: String,
    /// Instruction-index PC of the dispatcher in the image.
    pub entry_pc: u32,
    /// Kernel parameters in ABI order.
    pub params: Vec<(String, Type)>,
    /// Static per-core shared memory the kernel uses.
    pub local_mem: u32,
    pub uses_barrier: bool,
}

/// A compiled module: one linked image serving every kernel it contains.
#[derive(Debug)]
pub struct Program {
    pub image: ProgramImage,
    pub kernels: Vec<KernelEntry>,
    pub middle: MiddleEndReport,
    pub timings: CompileTimings,
    /// Cache key this program is stored under.
    pub fingerprint: u64,
}

impl Program {
    pub fn kernel(&self, name: &str) -> Option<&KernelEntry> {
        self.kernels.iter().find(|k| k.name == name)
    }

    pub fn kernel_names(&self) -> Vec<&str> {
        self.kernels.iter().map(|k| k.name.as_str()).collect()
    }
}

/// Binary-cache counters across both tiers. `hits`/`misses` keep their
/// original meaning — in-memory hits and full compiles — so existing
/// consumers are unaffected; the `disk_*` fields stay zero unless the
/// session was built with [`Session::with_disk_cache`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// In-memory cache hits.
    pub hits: u64,
    /// Full compiles (neither tier had the entry).
    pub misses: u64,
    /// Programs served from the persistent tier.
    pub disk_hits: u64,
    /// Persistent entries that failed validation (quarantined, recompiled).
    pub disk_corrupt: u64,
    /// Persistent entries evicted by the size cap.
    pub disk_evicted: u64,
}

/// Which cache tier served a [`Session::compile_traced`] call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompileTier {
    /// In-memory hit (including programs shared from a concurrent
    /// compile of the same key — the waiter never ran the pipeline).
    Mem,
    /// Served from the persistent tier.
    Disk,
    /// Full pipeline run.
    Miss,
}

/// Memory-tier shard count. Power of two so the shard index is a mask;
/// small enough to stay cheap for single-threaded sessions, large enough
/// that concurrent distinct-key compiles rarely contend on one lock.
const SHARDS: usize = 16;

/// Rendezvous for concurrent compiles of one fingerprint: the leader
/// publishes `Done`/`Failed` and wakes everyone piled up behind it.
enum InflightState {
    Pending,
    Done(Arc<Program>),
    Failed,
}

struct InflightSlot {
    state: Mutex<InflightState>,
    cv: Condvar,
}

/// Resolves the in-flight slot when the leader finishes — including by
/// panic, so waiters can never hang on a dead leader. `result` is set on
/// the success path; anything else publishes `Failed` and the waiters
/// retry as leaders of their own (each reports its own error).
struct LeaderGuard<'a> {
    session: &'a Session,
    key: u64,
    result: Option<Arc<Program>>,
}

impl Drop for LeaderGuard<'_> {
    fn drop(&mut self) {
        let slot = self.session.inflight.lock().unwrap().remove(&self.key);
        if let Some(slot) = slot {
            let mut st = slot.state.lock().unwrap();
            *st = match self.result.take() {
                Some(p) => InflightState::Done(p),
                None => InflightState::Failed,
            };
            slot.cv.notify_all();
        }
    }
}

/// A compile-and-run session: configuration + binary cache (an in-memory
/// tier, plus an optional persistent tier — see
/// [`Session::with_disk_cache`]).
///
/// `Session` is `Send + Sync`; every method takes `&self`, so one
/// session can serve compiles from many threads at once.
pub struct Session {
    opts: VoltOptions,
    /// Memory tier, sharded by fingerprint so concurrent compiles of
    /// different programs don't serialize on one lock.
    shards: Vec<RwLock<HashMap<u64, Arc<Program>>>>,
    /// In-flight compiles keyed by fingerprint (leader/waiter dedup).
    inflight: Mutex<HashMap<u64, Arc<InflightSlot>>>,
    disk: Option<Mutex<DiskCache>>,
    stats: Mutex<CacheStats>,
    /// Diagnostics from the last compile's static-checker run (empty when
    /// the checker is off or the kernels were clean).
    last_check: Mutex<Vec<Diag>>,
}

impl Session {
    pub fn new(opts: VoltOptions) -> Session {
        Session {
            opts,
            shards: (0..SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
            inflight: Mutex::new(HashMap::new()),
            disk: None,
            stats: Mutex::new(CacheStats::default()),
            last_check: Mutex::new(Vec::new()),
        }
    }

    /// Session with a persistent content-addressed cache tier under
    /// `dir`, capped at `max_bytes` (`0` = uncapped). Programs compiled
    /// here are stored on disk and served back — checksum-verified — by
    /// any later session pointed at the same directory. Corrupt entries
    /// are quarantined and recompiled, never a crash; all disk I/O is
    /// best-effort, so an unusable directory degrades to plain misses.
    pub fn with_disk_cache(
        opts: VoltOptions,
        dir: impl AsRef<std::path::Path>,
        max_bytes: u64,
    ) -> Session {
        let mut s = Session::new(opts);
        s.disk = Some(Mutex::new(DiskCache::new(dir, max_bytes)));
        s
    }

    /// Whether a persistent tier is attached.
    pub fn has_disk_cache(&self) -> bool {
        self.disk.is_some()
    }

    /// Quarantined-entry count of the persistent tier, when one is
    /// attached.
    pub fn disk_quarantined(&self) -> Option<usize> {
        self.disk.as_ref().map(|d| d.lock().unwrap().quarantined())
    }

    /// On-disk path the persistent tier stores `key` under, when a tier
    /// is attached (the entry itself may not exist yet).
    pub fn disk_entry_path(&self, key: u64) -> Option<std::path::PathBuf> {
        self.disk.as_ref().map(|d| d.lock().unwrap().entry_path(key))
    }

    /// Session with the paper's default configuration.
    pub fn with_defaults() -> Session {
        Session::new(VoltOptions::default())
    }

    pub fn options(&self) -> &VoltOptions {
        &self.opts
    }

    /// Diagnostics the static checker produced on the last
    /// [`Session::compile`] call (empty when [`VoltOptions::check`] is
    /// off or every kernel was clean).
    pub fn last_diagnostics(&self) -> Vec<Diag> {
        self.last_check.lock().unwrap().clone()
    }

    /// Compile `src` into a [`Program`], serving identical (source,
    /// options) requests from the binary cache.
    ///
    /// When [`VoltOptions::check`] is enabled, the `volt::check` static
    /// verifier runs on *every* call — the checker is pure analysis, so
    /// it is independent of the binary cache (a cache hit still
    /// re-reports diagnostics, and `Deny` still rejects).
    pub fn compile(&self, src: &str) -> Result<Arc<Program>, VoltError> {
        self.compile_traced(src).map(|(p, _)| p)
    }

    /// [`Session::compile`], additionally reporting which cache tier
    /// served the request. Concurrent calls with the same fingerprint
    /// are deduplicated: exactly one thread runs the pipeline (a single
    /// `Miss`), the rest share its program as `Mem` hits.
    pub fn compile_traced(
        &self,
        src: &str,
    ) -> Result<(Arc<Program>, CompileTier), VoltError> {
        self.run_checker(src)?;
        let key = fingerprint(src, &self.opts);
        if !self.opts.cache {
            // No memory tier and no dedup: every call is its own compile
            // (or disk hit), preserving the cache=false contract that N
            // compiles are N misses.
            return self.compile_uncached(src, key);
        }
        loop {
            if let Some(p) = self.shard(key).read().unwrap().get(&key) {
                self.stats.lock().unwrap().hits += 1;
                return Ok((p.clone(), CompileTier::Mem));
            }
            let waiter = {
                let mut inflight = self.inflight.lock().unwrap();
                // Re-check under the in-flight lock: a leader publishes
                // to the shard *before* dropping its slot, so missing
                // here while no slot exists means nobody is compiling
                // this key and we can safely become the leader.
                if let Some(p) = self.shard(key).read().unwrap().get(&key) {
                    self.stats.lock().unwrap().hits += 1;
                    return Ok((p.clone(), CompileTier::Mem));
                }
                match inflight.get(&key) {
                    Some(slot) => Some(slot.clone()),
                    None => {
                        inflight.insert(
                            key,
                            Arc::new(InflightSlot {
                                state: Mutex::new(InflightState::Pending),
                                cv: Condvar::new(),
                            }),
                        );
                        None
                    }
                }
            };
            let Some(slot) = waiter else {
                // Leader: run the pipeline, publish to the shard, then
                // resolve the slot for anyone queued behind us. The guard
                // resolves it on every exit path (including panics), so
                // waiters can never hang.
                let mut guard = LeaderGuard { session: self, key, result: None };
                let out = self.compile_uncached(src, key);
                if let Ok((p, _)) = &out {
                    guard.result = Some(p.clone());
                }
                drop(guard);
                return out;
            };
            let mut st = slot.state.lock().unwrap();
            loop {
                match &*st {
                    InflightState::Pending => st = slot.cv.wait(st).unwrap(),
                    InflightState::Done(p) => {
                        self.stats.lock().unwrap().hits += 1;
                        return Ok((p.clone(), CompileTier::Mem));
                    }
                    // The leader failed; retry from the top. Compile
                    // errors are deterministic in the source, but each
                    // caller must produce its own error value.
                    InflightState::Failed => break,
                }
            }
        }
    }

    /// Static checker gate: refreshes [`Session::last_diagnostics`] and
    /// rejects under `CheckMode::Deny`.
    fn run_checker(&self, src: &str) -> Result<(), VoltError> {
        let mut last = self.last_check.lock().unwrap();
        last.clear();
        if self.opts.check == CheckMode::Off {
            return Ok(());
        }
        // Checker-internal front-end errors are ignored here: the main
        // pipeline reports them as typed frontend errors.
        if let Ok(diags) =
            check::check_source(src, self.opts.dialect, &self.opts.check_params())
        {
            *last = diags;
        }
        if self.opts.check == CheckMode::Deny && !last.is_empty() {
            let first = &last[0];
            return Err(VoltError::Validation {
                msg: format!(
                    "volt check found {} issue{} (check=deny); first: [{}] kernel \
                     '{}'{}: {}",
                    last.len(),
                    if last.len() == 1 { "" } else { "s" },
                    first.id.id_str(),
                    first.kernel,
                    match first.line() {
                        Some(l) => format!(" line {l}"),
                        None => String::new(),
                    },
                    first.msg
                ),
            });
        }
        Ok(())
    }

    /// Both cache-missing tiers: persistent lookup, then the full
    /// pipeline. Publishes into the memory tier (when caching) so later
    /// callers — and waiters piled behind a leader — hit.
    fn compile_uncached(
        &self,
        src: &str,
        key: u64,
    ) -> Result<(Arc<Program>, CompileTier), VoltError> {
        // Persistent tier: a verified entry skips the whole pipeline (the
        // stored image is checksum-validated and every instruction
        // re-decoded); middle-end/timing reports default — the passes did
        // not run. Corrupt entries were quarantined by the cache and fall
        // through to a recompile.
        if let Some(disk) = &self.disk {
            let lookup = disk.lock().unwrap().load(key);
            if let DiskLookup::Hit(hit) = lookup {
                let (image, kernels) = *hit;
                let prog = Arc::new(Program {
                    image,
                    kernels,
                    middle: MiddleEndReport::default(),
                    timings: CompileTimings::default(),
                    fingerprint: key,
                });
                if self.opts.cache {
                    self.shard(key).write().unwrap().insert(key, prog.clone());
                }
                return Ok((prog, CompileTier::Disk));
            }
        }
        self.stats.lock().unwrap().misses += 1;
        let prog = Arc::new(compile_program_keyed(src, &self.opts, key)?);
        if self.opts.cache {
            self.shard(key).write().unwrap().insert(key, prog.clone());
        }
        if let Some(disk) = &self.disk {
            disk.lock().unwrap().store(key, &prog.image, &prog.kernels);
        }
        Ok((prog, CompileTier::Miss))
    }

    fn shard(&self, key: u64) -> &RwLock<HashMap<u64, Arc<Program>>> {
        &self.shards[(key as usize) & (SHARDS - 1)]
    }

    /// Create a command stream executing `program` on a fresh device with
    /// this session's simulator geometry (and profiler, when
    /// [`VoltOptions::profiling`] is set).
    pub fn create_stream(&self, program: &Arc<Program>) -> Stream {
        Stream::with_profiling(
            program.clone(),
            self.opts.device_config(),
            self.opts.profiling,
        )
    }

    pub fn cache_stats(&self) -> CacheStats {
        let mut s = *self.stats.lock().unwrap();
        if let Some(d) = &self.disk {
            let d = d.lock().unwrap();
            s.disk_hits = d.hits;
            s.disk_corrupt = d.corrupt;
            s.disk_evicted = d.evicted;
        }
        s
    }

    pub fn cached_programs(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn clear_cache(&self) {
        for shard in &self.shards {
            shard.write().unwrap().clear();
        }
    }
}

/// Cache key: FNV-1a over the source bytes and every output-relevant
/// option field.
pub fn fingerprint(src: &str, opts: &VoltOptions) -> u64 {
    let mut h = Fnv1a::new();
    h.bytes(src.as_bytes());
    opts.hash_into(&mut h);
    h.finish()
}

/// The full uncached pipeline: front-end → middle-end ladder → linked
/// image, with per-stage timing and a launchable entry for every kernel.
pub fn compile_program(src: &str, opts: &VoltOptions) -> Result<Program, VoltError> {
    compile_program_keyed(src, opts, fingerprint(src, opts))
}

fn compile_program_keyed(
    src: &str,
    opts: &VoltOptions,
    key: u64,
) -> Result<Program, VoltError> {
    // Literal-constructed options go through the same consistency rules
    // as the builder.
    opts.validate()?;
    // Per-function middle-end/backend stages fan out across the same
    // worker budget the simulator uses; joins are in function order, so
    // the image is byte-identical to a sequential compile.
    let threads = crate::sim::effective_threads(opts.sim.threads);
    let t0 = Instant::now();
    let (mut m, infos) = compile_kernels(src, &opts.frontend())?;
    if infos.is_empty() {
        return Err(VoltError::Frontend {
            line: 0,
            msg: "no kernels in source".into(),
        });
    }
    let frontend_ms = t0.elapsed().as_secs_f64() * 1e3;

    let t1 = Instant::now();
    // The target owns its divergence seeds (paper §4.3.1): the middle-end
    // runs with the target's TargetDivergenceInfo implementation.
    let middle = run_middle_end_with_threads(&mut m, &opts.opt_config(), &opts.target, threads);
    if opts.verify_ir {
        crate::ir::verify::verify_module(&m).map_err(|e| VoltError::MiddleEnd {
            pass: "verify",
            msg: e.to_string(),
        })?;
    }
    let middle_ms = t1.elapsed().as_secs_f64() * 1e3;

    // One image serves every kernel in the module: crt0 reads the entry
    // PC from the argument block, so linking once with all dispatchers as
    // roots removes the seed's kernels[0]-only limitation.
    let t2 = Instant::now();
    let image = build_image_threaded(
        &m,
        &format!("__main_{}", infos[0].name),
        &opts.backend(),
        threads,
    )?;
    let backend_ms = t2.elapsed().as_secs_f64() * 1e3;

    let mut kernels = Vec::with_capacity(infos.len());
    for info in &infos {
        let entry_symbol = format!("__main_{}", info.name);
        let entry_pc = *image.func_entries.get(&entry_symbol).ok_or_else(|| {
            VoltError::Backend(BackendError {
                function: Some(entry_symbol.clone()),
                msg: "kernel entry missing from linked image".into(),
            })
        })?;
        kernels.push(KernelEntry {
            name: info.name.clone(),
            entry_symbol,
            entry_pc,
            params: info.params.clone(),
            local_mem: info.local_mem,
            uses_barrier: info.uses_barrier,
        });
    }
    Ok(Program {
        image,
        kernels,
        middle,
        timings: CompileTimings {
            frontend_ms,
            middle_ms,
            backend_ms,
        },
        fingerprint: key,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const TWO_KERNELS: &str = r#"
kernel void init(global int* x, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = i * 2;
}
kernel void add1(global int* x, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = x[i] + 1;
}
"#;

    #[test]
    fn session_is_send_and_sync() {
        fn assert_traits<T: Send + Sync>() {}
        assert_traits::<Session>();
        assert_traits::<Program>();
    }

    #[test]
    fn program_exposes_every_kernel_entry() {
        let s = Session::with_defaults();
        let p = s.compile(TWO_KERNELS).unwrap();
        assert_eq!(p.kernel_names(), vec!["init", "add1"]);
        for k in &p.kernels {
            assert!(p.image.func_entries.contains_key(&k.entry_symbol));
            assert_eq!(p.image.func_entries[&k.entry_symbol], k.entry_pc);
        }
        assert_ne!(
            p.kernel("init").unwrap().entry_pc,
            p.kernel("add1").unwrap().entry_pc
        );
        assert_eq!(p.kernel("init").unwrap().params.len(), 2);
    }

    #[test]
    fn cache_hits_on_identical_source_and_misses_on_changes() {
        let s = Session::with_defaults();
        let p1 = s.compile(TWO_KERNELS).unwrap();
        let p2 = s.compile(TWO_KERNELS).unwrap();
        assert_eq!(s.cache_stats(), CacheStats { hits: 1, misses: 1, ..Default::default() });
        assert!(Arc::ptr_eq(&p1, &p2));
        // Different source: miss.
        s.compile("kernel void k(global int* o) { o[0] = 1; }")
            .unwrap();
        assert_eq!(s.cache_stats(), CacheStats { hits: 1, misses: 2, ..Default::default() });
        assert_eq!(s.cached_programs(), 2);
        s.clear_cache();
        assert_eq!(s.cached_programs(), 0);
    }

    #[test]
    fn cache_disabled_always_misses() {
        let s = Session::new(
            crate::driver::VoltOptions::builder()
                .cache(false)
                .build()
                .unwrap(),
        );
        s.compile(TWO_KERNELS).unwrap();
        s.compile(TWO_KERNELS).unwrap();
        assert_eq!(s.cache_stats(), CacheStats { hits: 0, misses: 2, ..Default::default() });
        assert_eq!(s.cached_programs(), 0);
    }

    #[test]
    fn concurrent_same_source_compiles_dedup_to_one_miss() {
        let s = Session::with_defaults();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|_| scope.spawn(|| s.compile_traced(TWO_KERNELS).unwrap()))
                .collect();
            let results: Vec<_> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            // Exactly one thread ran the pipeline; everyone shares its
            // program.
            let misses = results
                .iter()
                .filter(|(_, t)| *t == CompileTier::Miss)
                .count();
            assert_eq!(misses, 1, "exactly one leader compiles");
            for (p, _) in &results {
                assert!(Arc::ptr_eq(p, &results[0].0));
            }
        });
        let stats = s.cache_stats();
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.hits, 3);
        assert_eq!(s.cached_programs(), 1);
    }

    #[test]
    fn check_warn_records_and_deny_rejects() {
        const RACY: &str = r#"
kernel void k(global float* in, global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[0] = in[l];
    barrier(0);
    out[l] = buf[0];
}
"#;
        // Warn: diagnostics recorded, compile succeeds.
        let s = Session::new(
            crate::driver::VoltOptions::builder()
                .check(CheckMode::Warn)
                .build()
                .unwrap(),
        );
        s.compile(RACY).unwrap();
        assert_eq!(s.last_diagnostics().len(), 1);
        assert_eq!(
            s.last_diagnostics()[0].id,
            crate::check::CheckId::RaceWriteWrite
        );
        // A clean compile clears the previous diagnostics.
        s.compile(TWO_KERNELS).unwrap();
        assert!(s.last_diagnostics().is_empty());
        // Deny: typed validation error naming the check id; diagnostics
        // still inspectable. A cache hit re-rejects (the checker is
        // independent of the binary cache).
        let s = Session::new(
            crate::driver::VoltOptions::builder()
                .check(CheckMode::Deny)
                .build()
                .unwrap(),
        );
        for _ in 0..2 {
            let e = s.compile(RACY).unwrap_err();
            assert!(matches!(e, VoltError::Validation { .. }), "{e}");
            assert!(e.to_string().contains("race.write-write"), "{e}");
            assert_eq!(s.last_diagnostics().len(), 1);
        }
        // Deny with clean source compiles fine.
        s.compile(TWO_KERNELS).unwrap();
    }

    #[test]
    fn frontend_errors_carry_lines() {
        let s = Session::with_defaults();
        let e = s.compile("kernel void k() {\n  int x = ;\n}").unwrap_err();
        match e {
            VoltError::Frontend { line, .. } => assert_eq!(line, 2),
            other => panic!("expected frontend error, got {other:?}"),
        }
        let e = s.compile("int f(int x) { return x; }").unwrap_err();
        assert!(matches!(e, VoltError::Frontend { line: 0, .. }));
    }

    fn disk_dir(tag: &str) -> std::path::PathBuf {
        use std::sync::atomic::{AtomicU64, Ordering};
        static N: AtomicU64 = AtomicU64::new(0);
        let d = std::env::temp_dir().join(format!(
            "volt-session-dc-{tag}-{}-{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn run_double_it(p: &Arc<Program>, s: &Session) -> Vec<u32> {
        use crate::runtime::ArgValue;
        let mut st = s.create_stream(p);
        let buf = st.malloc(64 * 4);
        st.enqueue_write_u32(buf, &(0..64u32).collect::<Vec<_>>())
            .unwrap();
        st.enqueue_launch(
            "double_it",
            [1, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(buf), ArgValue::I32(64)],
        )
        .unwrap();
        let t = st.enqueue_read_u32(buf, 64);
        st.synchronize().unwrap();
        st.take_u32(t).unwrap()
    }

    const DOUBLE_IT: &str = r#"
kernel void double_it(global int* x, int n) {
    int i = get_global_id(0);
    if (i < n) x[i] = x[i] * 2;
}
"#;

    #[test]
    fn disk_cache_serves_later_sessions() {
        let dir = disk_dir("hit");
        let opts = || crate::driver::VoltOptions::builder().build().unwrap();

        let s1 = Session::with_disk_cache(opts(), &dir, 0);
        let (p1, tier1) = s1.compile_traced(DOUBLE_IT).unwrap();
        assert_eq!(tier1, CompileTier::Miss);
        assert_eq!(s1.cache_stats().misses, 1);
        let r1 = run_double_it(&p1, &s1);

        // A fresh session (empty memory cache) is served from disk: no
        // full compile, identical fingerprint, image and results.
        let s2 = Session::with_disk_cache(opts(), &dir, 0);
        let (p2, tier2) = s2.compile_traced(DOUBLE_IT).unwrap();
        assert_eq!(tier2, CompileTier::Disk);
        let stats = s2.cache_stats();
        assert_eq!(stats.misses, 0, "disk hit must not recompile");
        assert_eq!(stats.disk_hits, 1);
        assert_eq!(p2.fingerprint, p1.fingerprint);
        assert_eq!(p2.image.words, p1.image.words);
        assert_eq!(run_double_it(&p2, &s2), r1);

        // Within s2 the program is now also in the memory tier.
        let (_, tier3) = s2.compile_traced(DOUBLE_IT).unwrap();
        assert_eq!(tier3, CompileTier::Mem);
        assert_eq!(s2.cache_stats().hits, 1);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_disk_entry_recompiles_and_quarantines() {
        let dir = disk_dir("corrupt");
        let opts = || crate::driver::VoltOptions::builder().build().unwrap();

        let s1 = Session::with_disk_cache(opts(), &dir, 0);
        let p1 = s1.compile(DOUBLE_IT).unwrap();
        let path = s1.disk_entry_path(p1.fingerprint).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();

        // The flipped byte is a logged miss + successful recompile —
        // never a crash — and the bad entry is quarantined.
        let s2 = Session::with_disk_cache(opts(), &dir, 0);
        let p2 = s2.compile(DOUBLE_IT).unwrap();
        let stats = s2.cache_stats();
        assert_eq!(stats.disk_corrupt, 1);
        assert_eq!(stats.disk_hits, 0);
        assert_eq!(stats.misses, 1, "corrupt entry must recompile");
        assert_eq!(s2.disk_quarantined(), Some(1));
        assert_eq!(p2.image.words, p1.image.words);
        assert_eq!(run_double_it(&p2, &s2), run_double_it(&p1, &s1));

        // The recompile re-stored a good entry; a third session hits.
        let s3 = Session::with_disk_cache(opts(), &dir, 0);
        s3.compile(DOUBLE_IT).unwrap();
        assert_eq!(s3.cache_stats().disk_hits, 1);

        let _ = std::fs::remove_dir_all(&dir);
    }
}
