//! The public compile-and-run API (paper §4 layering, Cranelift-style
//! embeddable driver).
//!
//! Everything a host program needs lives here:
//!
//! * [`VoltOptions`] / [`VoltOptionsBuilder`] — one validated options
//!   struct unifying front-end dialect, the §5.2 optimization ladder, and
//!   back-end/device configuration.
//! * [`Session`] — compiles source modules into multi-kernel
//!   [`Program`]s through a content-addressed binary cache with hit/miss
//!   counters.
//! * [`Stream`] — an in-order command queue (h2d / launch / d2h /
//!   symbol-write) over the simulated Vortex device, with per-command
//!   [`Event`] records carrying sim-cycle timestamps.
//! * [`VoltError`] — the typed error every layer reports through.
//!
//! ```no_run
//! use volt::driver::{Session, VoltOptions};
//! use volt::runtime::ArgValue;
//!
//! let session = Session::new(VoltOptions::builder().build()?);
//! let program = session.compile(
//!     "kernel void k(global int* o, int n) { int i = get_global_id(0); if (i < n) o[i] = i; }",
//! )?;
//! let mut stream = session.create_stream(&program);
//! let buf = stream.malloc(64 * 4);
//! stream.enqueue_launch("k", [1, 1, 1], [64, 1, 1], &[ArgValue::Ptr(buf), ArgValue::I32(64)])?;
//! let out = stream.enqueue_read_u32(buf, 64);
//! stream.synchronize()?;
//! let values = stream.take_u32(out)?;
//! # let _ = values;
//! # Ok::<(), volt::driver::VoltError>(())
//! ```

pub mod diskcache;
pub mod error;
pub mod options;
pub mod session;
pub mod stream;

pub use crate::check::{CheckId, CheckMode, Diag};
pub use diskcache::{DiskCache, DiskLookup};
pub use error::VoltError;
pub use options::{VoltOptions, VoltOptionsBuilder};
pub use session::{
    compile_program, fingerprint, CacheStats, CompileTier, CompileTimings, KernelEntry, Program,
    Session,
};
pub use stream::{CommandKind, CommandTiming, Event, Stream, StreamFault, Transfer};
