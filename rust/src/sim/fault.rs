//! Deterministic fault injection (`volt::resilience` layer 1).
//!
//! A [`FaultPlan`] rides on [`super::SimConfig`] and describes a small,
//! fixed set of transient hardware faults to inject at exact cycles:
//! load-data bit flips, forced illegal-instruction or memory traps at a
//! given pc (or at the next issued instruction), and a one-shot stuck
//! barrier whose arrival is dropped. Because the simulator is
//! bit-identical run to run, an injected fault is perfectly
//! reproducible — which is what makes the recovery paths in
//! `runtime::VoltDevice` and `driver::Stream` testable at all.
//!
//! Discipline: the empty plan is bit-identical to a build without this
//! module — the hooks in `sim::core` are a single branch on
//! [`FaultState::armed`] and never touch the timing model (same
//! differential contract as `fast_forward` and `sanitize`).

/// Capacity of a plan. A fixed-size array keeps [`FaultPlan`] `Copy`,
/// which `SimConfig` (and therefore `VoltOptions`) requires.
pub const MAX_FAULTS: usize = 8;

/// What to inject.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Flip one bit of the destination register of the next executed
    /// load (a transient memory upset). Silent data corruption — the
    /// run completes; results differ.
    LoadBitFlip { bit: u8 },
    /// Force an illegal-instruction trap: at `pc` if given, else at the
    /// next instruction issued at/after the trigger cycle.
    IllegalTrap { pc: Option<u32> },
    /// Force a memory-fault trap, same targeting rules as `IllegalTrap`.
    MemTrap { pc: Option<u32> },
    /// Drop one barrier arrival: the warp parks but is never counted,
    /// so the block deadlocks deterministically. Models a lost
    /// synchronization message — a *deterministic* fault that retry
    /// must NOT paper over.
    StuckBarrier,
}

/// One scheduled fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fault {
    /// Fires at the first opportunity at/after this simulated cycle.
    pub at_cycle: u64,
    pub kind: FaultKind,
}

/// A deterministic schedule of up to [`MAX_FAULTS`] faults. `Copy` so it
/// embeds in `SimConfig` without breaking the options builder.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    slots: [Option<Fault>; MAX_FAULTS],
}

impl FaultPlan {
    /// The empty plan (the default): injects nothing, costs nothing.
    pub const fn none() -> FaultPlan {
        FaultPlan {
            slots: [None; MAX_FAULTS],
        }
    }

    pub fn is_empty(&self) -> bool {
        self.slots.iter().all(|s| s.is_none())
    }

    pub fn len(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Number of faults that a retry loop must absorb (everything except
    /// silent bit flips completes the run; flips corrupt it — all count).
    pub fn faults(&self) -> impl Iterator<Item = &Fault> {
        self.slots.iter().flatten()
    }

    /// Add a fault. Errors when the plan is full (capacity is part of
    /// the type: plans never allocate).
    pub fn push(&mut self, f: Fault) -> Result<(), String> {
        match self.slots.iter_mut().find(|s| s.is_none()) {
            Some(slot) => {
                *slot = Some(f);
                Ok(())
            }
            None => Err(format!("fault plan is full (max {MAX_FAULTS} faults)")),
        }
    }

    /// Builder form of [`FaultPlan::push`]; panics when full (test/CLI
    /// convenience for literal plans).
    pub fn with(mut self, at_cycle: u64, kind: FaultKind) -> FaultPlan {
        self.push(Fault { at_cycle, kind }).expect("fault plan full");
        self
    }

    /// Deterministic pseudo-random plan: `n` transient faults (illegal /
    /// memory traps and load bit flips, cycling by index) at xorshift-
    /// derived cycles in `[0, horizon)`. The same seed always yields the
    /// same plan — "seeded" chaos that replays exactly.
    pub fn seeded(seed: u64, n: usize, horizon: u64) -> FaultPlan {
        let mut plan = FaultPlan::none();
        let mut s = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1;
        let mut next = || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            s
        };
        for i in 0..n.min(MAX_FAULTS) {
            let at_cycle = if horizon == 0 { 0 } else { next() % horizon };
            let kind = match i % 3 {
                0 => FaultKind::IllegalTrap { pc: None },
                1 => FaultKind::MemTrap { pc: None },
                _ => FaultKind::LoadBitFlip {
                    bit: (next() % 32) as u8,
                },
            };
            plan.push(Fault { at_cycle, kind }).unwrap();
        }
        plan
    }

    /// Parse a CLI spec: `;`-separated entries of
    /// `flip@CYCLE[:BIT]`, `trap@CYCLE[:PC]`, `memtrap@CYCLE[:PC]`,
    /// `stuckbar@CYCLE`, or `seed@SEED[:N[:HORIZON]]` (expands to a
    /// seeded plan). Example: `--inject "trap@1000;flip@2500:7"`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::none();
        for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind, rest) = entry
                .split_once('@')
                .ok_or_else(|| format!("bad fault entry '{entry}': expected KIND@CYCLE"))?;
            let mut nums = rest.split(':');
            let first: u64 = nums
                .next()
                .unwrap_or("")
                .parse()
                .map_err(|_| format!("bad number in fault entry '{entry}'"))?;
            let second = match nums.next() {
                Some(s) => Some(
                    s.parse::<u64>()
                        .map_err(|_| format!("bad number in fault entry '{entry}'"))?,
                ),
                None => None,
            };
            let third = match nums.next() {
                Some(s) => Some(
                    s.parse::<u64>()
                        .map_err(|_| format!("bad number in fault entry '{entry}'"))?,
                ),
                None => None,
            };
            match kind {
                "flip" => plan.push(Fault {
                    at_cycle: first,
                    kind: FaultKind::LoadBitFlip {
                        bit: (second.unwrap_or(0) % 32) as u8,
                    },
                })?,
                "trap" => plan.push(Fault {
                    at_cycle: first,
                    kind: FaultKind::IllegalTrap {
                        pc: second.map(|p| p as u32),
                    },
                })?,
                "memtrap" => plan.push(Fault {
                    at_cycle: first,
                    kind: FaultKind::MemTrap {
                        pc: second.map(|p| p as u32),
                    },
                })?,
                "stuckbar" => plan.push(Fault {
                    at_cycle: first,
                    kind: FaultKind::StuckBarrier,
                })?,
                "seed" => {
                    let n = second.unwrap_or(1) as usize;
                    let horizon = third.unwrap_or(100_000);
                    for f in FaultPlan::seeded(first, n, horizon).faults() {
                        plan.push(*f)?;
                    }
                }
                other => {
                    return Err(format!(
                        "unknown fault kind '{other}' (expected flip/trap/memtrap/stuckbar/seed)"
                    ))
                }
            }
        }
        Ok(plan)
    }
}

/// Runtime injection state: the plan plus one-shot `fired` tracking.
/// Lives on the `Gpu` for the device's lifetime — faults are *consumed*
/// across runs, deliberately NOT re-armed by a launch retry, so a retry
/// loop observes each fault exactly once and "succeeds at
/// `retries >= fault count`" holds exactly.
#[derive(Clone, Debug)]
pub struct FaultState {
    plan: FaultPlan,
    fired: [bool; MAX_FAULTS],
    /// Cached `pending() > 0` so the per-instruction guard in the
    /// simulator hot path is one bool load, not a slot scan.
    armed: bool,
    /// Human-readable record of every injection, for diagnostics.
    pub log: Vec<String>,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> FaultState {
        FaultState {
            plan,
            fired: [false; MAX_FAULTS],
            armed: !plan.is_empty(),
            log: Vec::new(),
        }
    }

    /// Cheap guard for the per-instruction hooks: false on the empty
    /// plan and once every fault has fired.
    #[inline]
    pub fn armed(&self) -> bool {
        self.armed
    }

    /// Faults scheduled but not yet injected.
    pub fn pending(&self) -> usize {
        self.plan
            .slots
            .iter()
            .zip(self.fired.iter())
            .filter(|(s, f)| s.is_some() && !**f)
            .count()
    }

    /// Faults injected so far.
    pub fn injected(&self) -> usize {
        self.fired.iter().filter(|f| **f).count()
    }

    /// Has a [`FaultKind::StuckBarrier`] fired? A following barrier
    /// deadlock is then attributable to the injector.
    pub fn stuck_barrier_fired(&self) -> bool {
        self.plan
            .slots
            .iter()
            .zip(self.fired.iter())
            .any(|(s, f)| *f && matches!(s, Some(x) if x.kind == FaultKind::StuckBarrier))
    }

    fn take(&mut self, cycle: u64, matches: impl Fn(&FaultKind) -> bool) -> Option<FaultKind> {
        let fired = &self.fired;
        let idx = self
            .plan
            .slots
            .iter()
            .enumerate()
            .find_map(|(i, slot)| match slot {
                Some(f) if !fired[i] && cycle >= f.at_cycle && matches(&f.kind) => Some(i),
                _ => None,
            })?;
        let kind = self.plan.slots[idx].unwrap().kind;
        self.fired[idx] = true;
        self.armed = self.pending() > 0;
        Some(kind)
    }

    /// A forced trap due at this (cycle, pc)? Consumes the fault and
    /// returns its kind and message. Called once per issued instruction
    /// (behind [`FaultState::armed`]).
    pub fn trap_at(&mut self, cycle: u64, pc: u32) -> Option<(super::TrapKind, String)> {
        let hit = |want: &Option<u32>| want.map_or(true, |p| p == pc);
        let kind = self.take(cycle, |k| match k {
            FaultKind::IllegalTrap { pc } | FaultKind::MemTrap { pc } => hit(pc),
            _ => false,
        })?;
        let (tk, msg) = match kind {
            FaultKind::IllegalTrap { .. } => (
                super::TrapKind::IllegalInst,
                "injected fault: illegal instruction".to_string(),
            ),
            FaultKind::MemTrap { .. } => (
                super::TrapKind::MemFault,
                "injected fault: memory trap".to_string(),
            ),
            _ => unreachable!(),
        };
        self.log.push(format!("cycle {cycle}: {msg} at pc {pc}"));
        Some((tk, msg))
    }

    /// A load bit flip due at this cycle? Consumes the fault and returns
    /// the bit index. Called only when a load actually executed.
    pub fn load_flip(&mut self, cycle: u64, pc: u32) -> Option<u8> {
        let kind = self.take(cycle, |k| matches!(k, FaultKind::LoadBitFlip { .. }))?;
        let FaultKind::LoadBitFlip { bit } = kind else {
            unreachable!()
        };
        self.log
            .push(format!("cycle {cycle}: injected load bit flip (bit {bit}) at pc {pc}"));
        Some(bit % 32)
    }

    /// A stuck barrier due at this cycle? Consumes the fault. Called
    /// when a warp executes a barrier.
    pub fn stuck_barrier(&mut self, cycle: u64, pc: u32) -> bool {
        if self
            .take(cycle, |k| matches!(k, FaultKind::StuckBarrier))
            .is_some()
        {
            self.log.push(format!(
                "cycle {cycle}: injected stuck barrier (arrival dropped) at pc {pc}"
            ));
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::TrapKind;

    #[test]
    fn plan_push_with_and_capacity() {
        let mut p = FaultPlan::none();
        assert!(p.is_empty());
        for i in 0..MAX_FAULTS {
            p.push(Fault {
                at_cycle: i as u64,
                kind: FaultKind::StuckBarrier,
            })
            .unwrap();
        }
        assert_eq!(p.len(), MAX_FAULTS);
        assert!(p
            .push(Fault {
                at_cycle: 0,
                kind: FaultKind::StuckBarrier
            })
            .unwrap_err()
            .contains("full"));
        let q = FaultPlan::none().with(5, FaultKind::LoadBitFlip { bit: 3 });
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn seeded_is_deterministic() {
        let a = FaultPlan::seeded(42, 4, 10_000);
        let b = FaultPlan::seeded(42, 4, 10_000);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        for f in a.faults() {
            assert!(f.at_cycle < 10_000);
        }
        let c = FaultPlan::seeded(43, 4, 10_000);
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn parse_round_trips_the_cli_grammar() {
        let p = FaultPlan::parse("trap@1000; flip@2500:7; memtrap@10:12; stuckbar@0").unwrap();
        assert_eq!(p.len(), 4);
        let kinds: Vec<FaultKind> = p.faults().map(|f| f.kind).collect();
        assert!(kinds.contains(&FaultKind::IllegalTrap { pc: None }));
        assert!(kinds.contains(&FaultKind::LoadBitFlip { bit: 7 }));
        assert!(kinds.contains(&FaultKind::MemTrap { pc: Some(12) }));
        assert!(kinds.contains(&FaultKind::StuckBarrier));
        assert_eq!(FaultPlan::parse("seed@9:3").unwrap().len(), 3);
        assert_eq!(FaultPlan::parse("").unwrap().len(), 0);
        assert!(FaultPlan::parse("zap@3").is_err());
        assert!(FaultPlan::parse("trap").is_err());
        assert!(FaultPlan::parse("trap@x").is_err());
    }

    #[test]
    fn state_fires_one_shot_in_order() {
        let plan = FaultPlan::none()
            .with(100, FaultKind::IllegalTrap { pc: None })
            .with(100, FaultKind::MemTrap { pc: Some(7) });
        let mut st = FaultState::new(plan);
        assert!(st.armed());
        assert_eq!(st.pending(), 2);
        // Before the trigger cycle: nothing.
        assert!(st.trap_at(99, 7).is_none());
        // At/after: the wildcard illegal trap fires first, once.
        let (k, msg) = st.trap_at(100, 3).unwrap();
        assert_eq!(k, TrapKind::IllegalInst);
        assert!(msg.contains("injected"));
        // The pc-targeted mem trap only fires at its pc.
        assert!(st.trap_at(100, 3).is_none());
        let (k, _) = st.trap_at(100, 7).unwrap();
        assert_eq!(k, TrapKind::MemFault);
        assert!(!st.armed());
        assert_eq!(st.injected(), 2);
        assert_eq!(st.log.len(), 2);
    }

    #[test]
    fn flip_and_barrier_consume() {
        let plan = FaultPlan::none()
            .with(0, FaultKind::LoadBitFlip { bit: 40 }) // masked to <32
            .with(5, FaultKind::StuckBarrier);
        let mut st = FaultState::new(plan);
        assert_eq!(st.load_flip(0, 1).unwrap(), 8);
        assert!(st.load_flip(0, 1).is_none());
        assert!(!st.stuck_barrier(4, 2));
        assert!(st.stuck_barrier(5, 2));
        assert!(!st.stuck_barrier(6, 2));
    }
}
