//! Device memory (segmented flat memory), the set-associative cache
//! timing model with LRU replacement, and the shadow-memory state behind
//! the runtime sanitizer ([`super::SimConfig::sanitize`]).

use super::{CacheConfig, SimStats};
use std::collections::HashSet;

#[derive(Debug)]
pub struct Segment {
    pub base: u32,
    pub data: Vec<u8>,
}

/// Global device memory: data / stack / heap segments.
#[derive(Debug, Default)]
pub struct GlobalMem {
    pub segs: Vec<Segment>,
}

#[derive(Debug, Clone)]
pub struct MemFault {
    pub addr: u32,
    pub write: bool,
}

impl GlobalMem {
    pub fn add_segment(&mut self, base: u32, size: u32) {
        self.segs.push(Segment {
            base,
            data: vec![0; size as usize],
        });
        // Most-recently added first is wrong for hot paths; keep sorted by
        // base so lookup can scan; heap (largest traffic) is added last and
        // probed first by iterating in reverse.
    }

    #[inline]
    fn seg_mut(&mut self, addr: u32) -> Option<(&mut Segment, usize)> {
        for s in self.segs.iter_mut().rev() {
            let off = addr.wrapping_sub(s.base);
            if (off as usize) < s.data.len() {
                return Some((s, off as usize));
            }
        }
        None
    }

    #[inline]
    fn seg(&self, addr: u32) -> Option<(&Segment, usize)> {
        for s in self.segs.iter().rev() {
            let off = addr.wrapping_sub(s.base);
            if (off as usize) < s.data.len() {
                return Some((s, off as usize));
            }
        }
        None
    }

    #[inline]
    pub fn read_u32(&self, addr: u32) -> Result<u32, MemFault> {
        let (s, off) = self.seg(addr).ok_or(MemFault { addr, write: false })?;
        if off + 4 > s.data.len() {
            return Err(MemFault { addr, write: false });
        }
        Ok(u32::from_le_bytes([
            s.data[off],
            s.data[off + 1],
            s.data[off + 2],
            s.data[off + 3],
        ]))
    }

    #[inline]
    pub fn write_u32(&mut self, addr: u32, v: u32) -> Result<(), MemFault> {
        let (s, off) = self.seg_mut(addr).ok_or(MemFault { addr, write: true })?;
        if off + 4 > s.data.len() {
            return Err(MemFault { addr, write: true });
        }
        s.data[off..off + 4].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    pub fn write_bytes(&mut self, addr: u32, bytes: &[u8]) -> Result<(), MemFault> {
        let (s, off) = self.seg_mut(addr).ok_or(MemFault { addr, write: true })?;
        if off + bytes.len() > s.data.len() {
            return Err(MemFault { addr, write: true });
        }
        s.data[off..off + bytes.len()].copy_from_slice(bytes);
        Ok(())
    }

    pub fn read_bytes(&self, addr: u32, len: usize) -> Result<Vec<u8>, MemFault> {
        let (s, off) = self.seg(addr).ok_or(MemFault { addr, write: false })?;
        if off + len > s.data.len() {
            return Err(MemFault { addr, write: false });
        }
        Ok(s.data[off..off + len].to_vec())
    }
}

/// What the runtime sanitizer caught — the dynamic mirror of the static
/// checker's `race.*` / `bounds.local-oob` / `uninit.local-read` ids.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SanitizeKind {
    /// Two distinct threads stored the same local word within one
    /// barrier phase (static id `race.write-write`).
    WriteWrite,
    /// A load and a store from distinct threads touched the same local
    /// word within one barrier phase (static id `race.read-write`).
    ReadWrite,
    /// Access inside the local window but past the image's declared
    /// local-memory extent (static id `bounds.local-oob`).
    OutOfBounds,
    /// Load from a local word no thread has written since launch
    /// (static id `uninit.local-read`).
    UninitRead,
}

impl SanitizeKind {
    pub fn name(&self) -> &'static str {
        match self {
            SanitizeKind::WriteWrite => "write-write race",
            SanitizeKind::ReadWrite => "read-write race",
            SanitizeKind::OutOfBounds => "out-of-bounds local access",
            SanitizeKind::UninitRead => "uninitialized local read",
        }
    }
}

/// One sanitizer finding, reported through [`SimStats::sanitize_reports`].
#[derive(Clone, Debug)]
pub struct SanitizeReport {
    pub kind: SanitizeKind,
    pub pc: u32,
    pub addr: u32,
    pub core: u32,
    pub warp: u32,
    pub lane: u32,
    /// Source line from the image's pc→loc table; filled in by
    /// [`super::Gpu`] after the run.
    pub line: Option<u32>,
}

/// Per-word shadow state for one core's local-memory window.
#[derive(Clone, Copy, Debug, Default)]
struct ShadowWord {
    /// Last (warp, lane) to store this word in the current barrier phase.
    writer: Option<(u16, u16)>,
    /// Last (warp, lane) to load this word in the current barrier phase.
    reader: Option<(u16, u16)>,
    /// Whether any thread has ever written this word (survives barriers).
    init: bool,
}

/// Shadow memory for one core's local window — the runtime cross-check
/// of the static `volt check` verifier. A pure observer: it never feeds
/// back into execution or timing, so runs are bit-identical with the
/// sanitizer on or off.
///
/// The model matches the checker's barrier-phase semantics: each word
/// remembers its last writer and last reader; a store over another
/// thread's write (or read) in the same phase is a race, and barrier
/// release wipes the writer/reader marks for the whole core (the
/// dispatcher's end-of-block barrier also passes through here, so local
/// reuse across sequential blocks on one core never misfires).
/// Atomics only mark words initialized — atomic/atomic interleavings
/// are legal, and mixed atomic/plain conflicts are left to the static
/// checker. Reports are deduplicated per (kind, pc) and capped.
#[derive(Clone, Debug)]
pub struct ShadowLocal {
    words: Vec<ShadowWord>,
    /// Bytes of local memory the loaded image actually declares;
    /// in-window accesses at or past this are out-of-bounds.
    extent: usize,
    seen: HashSet<(SanitizeKind, u32)>,
}

/// Report-list cap: enough for every distinct (kind, pc) in practice,
/// bounded in pathological programs.
const MAX_REPORTS: usize = 256;

impl ShadowLocal {
    pub fn new(extent: usize) -> ShadowLocal {
        ShadowLocal {
            words: vec![ShadowWord::default(); extent.div_ceil(4)],
            extent,
            seen: HashSet::new(),
        }
    }

    /// Back to launch state (new kernel run on the same device).
    pub fn reset(&mut self) {
        for w in self.words.iter_mut() {
            *w = ShadowWord::default();
        }
        self.seen.clear();
    }

    /// Barrier release: conflicts no longer span the phase boundary.
    /// Initialization marks survive — a write before the barrier
    /// legitimately feeds reads after it.
    pub fn barrier_release(&mut self) {
        for w in self.words.iter_mut() {
            w.writer = None;
            w.reader = None;
        }
    }

    /// Record one plain load/store decoded into the local window.
    #[allow(clippy::too_many_arguments)]
    pub fn on_access(
        &mut self,
        stats: &mut SimStats,
        is_store: bool,
        local_off: usize,
        addr: u32,
        pc: u32,
        core: u32,
        warp: u32,
        lane: u32,
    ) {
        if local_off + 4 > self.extent {
            self.emit(stats, SanitizeKind::OutOfBounds, pc, addr, core, warp, lane);
            return;
        }
        let me = (warp as u16, lane as u16);
        let (mut ww, mut rw, mut uninit) = (false, false, false);
        {
            let w = &mut self.words[local_off / 4];
            if is_store {
                ww = matches!(w.writer, Some(o) if o != me);
                rw = matches!(w.reader, Some(o) if o != me);
                w.writer = Some(me);
                w.init = true;
            } else {
                rw = matches!(w.writer, Some(o) if o != me);
                uninit = !w.init;
                w.reader = Some(me);
            }
        }
        if ww {
            self.emit(stats, SanitizeKind::WriteWrite, pc, addr, core, warp, lane);
        }
        if rw {
            self.emit(stats, SanitizeKind::ReadWrite, pc, addr, core, warp, lane);
        }
        if uninit {
            self.emit(stats, SanitizeKind::UninitRead, pc, addr, core, warp, lane);
        }
    }

    /// Record one atomic decoded into the local window: bounds-checked
    /// and marked initialized, but never a race (atomics are how threads
    /// legitimately share a word within a phase).
    #[allow(clippy::too_many_arguments)]
    pub fn on_atomic(
        &mut self,
        stats: &mut SimStats,
        local_off: usize,
        addr: u32,
        pc: u32,
        core: u32,
        warp: u32,
        lane: u32,
    ) {
        if local_off + 4 > self.extent {
            self.emit(stats, SanitizeKind::OutOfBounds, pc, addr, core, warp, lane);
            return;
        }
        self.words[local_off / 4].init = true;
    }

    #[allow(clippy::too_many_arguments)]
    fn emit(
        &mut self,
        stats: &mut SimStats,
        kind: SanitizeKind,
        pc: u32,
        addr: u32,
        core: u32,
        warp: u32,
        lane: u32,
    ) {
        if !self.seen.insert((kind, pc)) || stats.sanitize_reports.len() >= MAX_REPORTS {
            return;
        }
        stats.sanitize_reports.push(SanitizeReport {
            kind,
            pc,
            addr,
            core,
            warp,
            lane,
            line: None,
        });
    }
}

/// Set-associative LRU cache (tags only — a timing model). `Clone` so
/// a transactional launch can snapshot/restore the hierarchy: caches
/// persist across launches, so a bit-identical retry must roll their
/// tag state back too ([`super::gpu::GpuSnapshot`]).
#[derive(Clone, Debug)]
pub struct Cache {
    cfg: CacheConfig,
    /// tags[set * ways + way] = Some(tag)
    tags: Vec<Option<u32>>,
    /// LRU counters (higher = more recent).
    lru: Vec<u64>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(cfg: CacheConfig) -> Cache {
        Cache {
            cfg,
            tags: vec![None; (cfg.sets * cfg.ways) as usize],
            lru: vec![0; (cfg.sets * cfg.ways) as usize],
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn line_of(&self, addr: u32) -> u32 {
        addr / self.cfg.line
    }

    /// Access one line (by line number). Returns hit. On the per-issue
    /// hot path of every load/store — allocation-free by construction
    /// (tag/LRU arrays are sized once in `new`), part of the
    /// no-alloc-per-tick invariant documented in `Gpu::run_*`.
    #[inline]
    pub fn access_line(&mut self, line: u32) -> bool {
        self.tick += 1;
        let set = (line % self.cfg.sets) as usize;
        let ways = self.cfg.ways as usize;
        let base = set * ways;
        for w in 0..ways {
            if self.tags[base + w] == Some(line) {
                self.lru[base + w] = self.tick;
                self.hits += 1;
                return true;
            }
        }
        // Miss: fill LRU way.
        let mut victim = 0;
        for w in 1..ways {
            if self.lru[base + w] < self.lru[base + victim] {
                victim = w;
            }
        }
        self.tags[base + victim] = Some(line);
        self.lru[base + victim] = self.tick;
        self.misses += 1;
        false
    }

    #[inline]
    pub fn latency(&self) -> u32 {
        self.cfg.latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_rw() {
        let mut m = GlobalMem::default();
        m.add_segment(0x1000, 0x100);
        m.add_segment(0x4000_0000, 0x1000);
        m.write_u32(0x1004, 0xdeadbeef).unwrap();
        assert_eq!(m.read_u32(0x1004).unwrap(), 0xdeadbeef);
        m.write_u32(0x4000_0ffc, 7).unwrap();
        assert_eq!(m.read_u32(0x4000_0ffc).unwrap(), 7);
        assert!(m.read_u32(0x2000).is_err());
        assert!(m.write_u32(0x0, 1).is_err());
        m.write_bytes(0x1000, &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(m.read_bytes(0x1000, 5).unwrap(), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn shadow_local_phase_semantics() {
        let mut st = SimStats::default();
        let mut sh = ShadowLocal::new(16); // 4 words declared
        // Two distinct threads store the same word in one phase.
        sh.on_access(&mut st, true, 0, 0x100, 10, 0, 0, 0);
        sh.on_access(&mut st, true, 0, 0x100, 11, 0, 0, 1);
        assert_eq!(st.sanitize_reports.len(), 1);
        assert_eq!(st.sanitize_reports[0].kind, SanitizeKind::WriteWrite);
        // Same-thread rewrite is silent (and (kind, pc) dedup holds).
        sh.on_access(&mut st, true, 0, 0x100, 11, 0, 0, 1);
        assert_eq!(st.sanitize_reports.len(), 1);
        // Cross-thread read of the freshly written word: read-write race.
        sh.on_access(&mut st, false, 0, 0x100, 12, 0, 1, 0);
        assert_eq!(st.sanitize_reports[1].kind, SanitizeKind::ReadWrite);
        // After barrier release the same read is legal; init survives.
        sh.barrier_release();
        sh.on_access(&mut st, false, 0, 0x100, 13, 0, 1, 0);
        assert_eq!(st.sanitize_reports.len(), 2);
        // Reading a never-written word.
        sh.on_access(&mut st, false, 4, 0x104, 14, 0, 0, 0);
        assert_eq!(st.sanitize_reports[2].kind, SanitizeKind::UninitRead);
        // In-window accesses past the declared extent.
        sh.on_access(&mut st, true, 16, 0x110, 15, 0, 0, 0);
        assert_eq!(st.sanitize_reports[3].kind, SanitizeKind::OutOfBounds);
        sh.on_atomic(&mut st, 20, 0x114, 16, 0, 0, 0);
        assert_eq!(st.sanitize_reports[4].kind, SanitizeKind::OutOfBounds);
        // Atomic/atomic sharing within a phase is not a race.
        sh.on_atomic(&mut st, 8, 0x108, 17, 0, 0, 0);
        sh.on_atomic(&mut st, 8, 0x108, 18, 0, 0, 1);
        assert_eq!(st.sanitize_reports.len(), 5);
    }

    #[test]
    fn cache_lru_behaviour() {
        let mut c = Cache::new(CacheConfig {
            sets: 1,
            ways: 2,
            line: 64,
            latency: 2,
        });
        assert!(!c.access_line(0)); // miss
        assert!(!c.access_line(1)); // miss
        assert!(c.access_line(0)); // hit
        assert!(!c.access_line(2)); // miss, evicts line 1 (LRU)
        assert!(c.access_line(0)); // still resident
        assert!(!c.access_line(1)); // was evicted
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 4);
    }

    #[test]
    fn cache_indexing_spreads_sets() {
        let mut c = Cache::new(CacheConfig {
            sets: 4,
            ways: 1,
            line: 64,
            latency: 2,
        });
        // Lines 0..4 map to different sets: all miss, none evict another.
        for l in 0..4 {
            assert!(!c.access_line(l));
        }
        for l in 0..4 {
            assert!(c.access_line(l));
        }
    }
}
