//! Device memory (segmented flat memory) and the set-associative cache
//! timing model with LRU replacement.

use super::CacheConfig;

#[derive(Debug)]
pub struct Segment {
    pub base: u32,
    pub data: Vec<u8>,
}

/// Global device memory: data / stack / heap segments.
#[derive(Debug, Default)]
pub struct GlobalMem {
    pub segs: Vec<Segment>,
}

#[derive(Debug, Clone)]
pub struct MemFault {
    pub addr: u32,
    pub write: bool,
}

impl GlobalMem {
    pub fn add_segment(&mut self, base: u32, size: u32) {
        self.segs.push(Segment {
            base,
            data: vec![0; size as usize],
        });
        // Most-recently added first is wrong for hot paths; keep sorted by
        // base so lookup can scan; heap (largest traffic) is added last and
        // probed first by iterating in reverse.
    }

    #[inline]
    fn seg_mut(&mut self, addr: u32) -> Option<(&mut Segment, usize)> {
        for s in self.segs.iter_mut().rev() {
            let off = addr.wrapping_sub(s.base);
            if (off as usize) < s.data.len() {
                return Some((s, off as usize));
            }
        }
        None
    }

    #[inline]
    fn seg(&self, addr: u32) -> Option<(&Segment, usize)> {
        for s in self.segs.iter().rev() {
            let off = addr.wrapping_sub(s.base);
            if (off as usize) < s.data.len() {
                return Some((s, off as usize));
            }
        }
        None
    }

    #[inline]
    pub fn read_u32(&self, addr: u32) -> Result<u32, MemFault> {
        let (s, off) = self.seg(addr).ok_or(MemFault { addr, write: false })?;
        if off + 4 > s.data.len() {
            return Err(MemFault { addr, write: false });
        }
        Ok(u32::from_le_bytes([
            s.data[off],
            s.data[off + 1],
            s.data[off + 2],
            s.data[off + 3],
        ]))
    }

    #[inline]
    pub fn write_u32(&mut self, addr: u32, v: u32) -> Result<(), MemFault> {
        let (s, off) = self.seg_mut(addr).ok_or(MemFault { addr, write: true })?;
        if off + 4 > s.data.len() {
            return Err(MemFault { addr, write: true });
        }
        s.data[off..off + 4].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    pub fn write_bytes(&mut self, addr: u32, bytes: &[u8]) -> Result<(), MemFault> {
        let (s, off) = self.seg_mut(addr).ok_or(MemFault { addr, write: true })?;
        if off + bytes.len() > s.data.len() {
            return Err(MemFault { addr, write: true });
        }
        s.data[off..off + bytes.len()].copy_from_slice(bytes);
        Ok(())
    }

    pub fn read_bytes(&self, addr: u32, len: usize) -> Result<Vec<u8>, MemFault> {
        let (s, off) = self.seg(addr).ok_or(MemFault { addr, write: false })?;
        if off + len > s.data.len() {
            return Err(MemFault { addr, write: false });
        }
        Ok(s.data[off..off + len].to_vec())
    }
}

/// Set-associative LRU cache (tags only — a timing model).
#[derive(Debug)]
pub struct Cache {
    cfg: CacheConfig,
    /// tags[set * ways + way] = Some(tag)
    tags: Vec<Option<u32>>,
    /// LRU counters (higher = more recent).
    lru: Vec<u64>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(cfg: CacheConfig) -> Cache {
        Cache {
            cfg,
            tags: vec![None; (cfg.sets * cfg.ways) as usize],
            lru: vec![0; (cfg.sets * cfg.ways) as usize],
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn line_of(&self, addr: u32) -> u32 {
        addr / self.cfg.line
    }

    /// Access one line (by line number). Returns hit.
    pub fn access_line(&mut self, line: u32) -> bool {
        self.tick += 1;
        let set = (line % self.cfg.sets) as usize;
        let ways = self.cfg.ways as usize;
        let base = set * ways;
        for w in 0..ways {
            if self.tags[base + w] == Some(line) {
                self.lru[base + w] = self.tick;
                self.hits += 1;
                return true;
            }
        }
        // Miss: fill LRU way.
        let mut victim = 0;
        for w in 1..ways {
            if self.lru[base + w] < self.lru[base + victim] {
                victim = w;
            }
        }
        self.tags[base + victim] = Some(line);
        self.lru[base + victim] = self.tick;
        self.misses += 1;
        false
    }

    pub fn latency(&self) -> u32 {
        self.cfg.latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_rw() {
        let mut m = GlobalMem::default();
        m.add_segment(0x1000, 0x100);
        m.add_segment(0x4000_0000, 0x1000);
        m.write_u32(0x1004, 0xdeadbeef).unwrap();
        assert_eq!(m.read_u32(0x1004).unwrap(), 0xdeadbeef);
        m.write_u32(0x4000_0ffc, 7).unwrap();
        assert_eq!(m.read_u32(0x4000_0ffc).unwrap(), 7);
        assert!(m.read_u32(0x2000).is_err());
        assert!(m.write_u32(0x0, 1).is_err());
        m.write_bytes(0x1000, &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(m.read_bytes(0x1000, 5).unwrap(), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn cache_lru_behaviour() {
        let mut c = Cache::new(CacheConfig {
            sets: 1,
            ways: 2,
            line: 64,
            latency: 2,
        });
        assert!(!c.access_line(0)); // miss
        assert!(!c.access_line(1)); // miss
        assert!(c.access_line(0)); // hit
        assert!(!c.access_line(2)); // miss, evicts line 1 (LRU)
        assert!(c.access_line(0)); // still resident
        assert!(!c.access_line(1)); // was evicted
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 4);
    }

    #[test]
    fn cache_indexing_spreads_sets() {
        let mut c = Cache::new(CacheConfig {
            sets: 4,
            ways: 1,
            line: 64,
            latency: 2,
        });
        // Lines 0..4 map to different sets: all miss, none evict another.
        for l in 0..4 {
            assert!(!c.access_line(l));
        }
        for l in 0..4 {
            assert!(c.access_line(l));
        }
    }
}
