//! SimX-style deterministic cycle-level SIMT simulator (paper §5: "SimX
//! provides deterministic, cycle-accurate execution (within 6% of RTL)").
//!
//! Models the Vortex microarchitecture of Fig. 3 at warp granularity: per
//! core a warp table (PC + thread mask per warp), per-warp IPDOM stacks, a
//! barrier table, active/stalled warp lists driving issue selection, an
//! SFU executing the vx_* instructions, L1D per core and a shared L2.
//! Timing is in-order issue with per-class latencies and load coalescing;
//! repeated runs are bit-identical, so performance deltas come only from
//! the compiler — the property the paper's evaluation relies on.

pub mod core;
pub mod fault;
pub mod gpu;
pub mod mem;
pub mod trace;

pub use fault::{Fault, FaultKind, FaultPlan, FaultState};
pub use gpu::Gpu;
pub use mem::{SanitizeKind, SanitizeReport, ShadowLocal};

use crate::target::{AddressMap, CostModel, Features, TargetDesc};

/// Cache geometry + latency.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    pub sets: u32,
    pub ways: u32,
    pub line: u32,
    pub latency: u32,
}

impl CacheConfig {
    pub fn l1_default() -> CacheConfig {
        CacheConfig {
            sets: 64,
            ways: 4,
            line: 64,
            latency: 2,
        } // 16 KiB
    }
    pub fn l2_default() -> CacheConfig {
        CacheConfig {
            sets: 256,
            ways: 8,
            line: 64,
            latency: 20,
        } // 128 KiB
    }
}

#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    pub num_cores: u32,
    pub warps_per_core: u32,
    pub threads_per_warp: u32,
    pub local_mem_bytes: u32,
    pub l1d: CacheConfig,
    pub l2: Option<CacheConfig>,
    pub mem_latency: u32,
    pub heap_bytes: u32,
    pub max_cycles: u64,
    /// ISA features the modeled hardware implements. The device audits
    /// the loaded program at run start and *traps* on feature-gated
    /// opcodes outside this set, so running an image built for the
    /// wrong target is a loud [`SimError`], never a silently wrong
    /// answer.
    pub features: Features,
    /// Address-space decode map (local / stack / heap windows). Kept in
    /// sync with the loaded image by [`Gpu::load`].
    pub addr_map: AddressMap,
    /// Per-functional-class issue costs (the target's timing hints).
    pub costs: CostModel,
    /// Idle-cycle fast-forward: a core with no issueable warp caches the
    /// earliest cycle one becomes ready (plus its stall attribution) and
    /// skips the per-cycle warp-table scan until then. A pure host-side
    /// (wall-clock) optimization — simulated cycle counts, results and
    /// profiler attribution are bit-identical with it on or off (the
    /// core's state is frozen while nothing issues, so the cached
    /// reason/occupancy equal what a rescan would produce).
    pub fast_forward: bool,
    /// Runtime sanitizer: shadow-memory tracking of local (shared)
    /// accesses per barrier phase, flagging cross-thread races,
    /// out-of-extent accesses and uninitialized reads into
    /// [`SimStats::sanitize_reports`] — the dynamic cross-check of the
    /// static `volt check` verifier. A pure observer with the same
    /// discipline as `fast_forward`: cycle counts, results and profiler
    /// attribution are bit-identical with it on or off.
    pub sanitize: bool,
    /// Deterministic fault-injection schedule ([`fault::FaultPlan`]).
    /// The empty plan (the default) is bit-identical to today: the
    /// injection hooks are a single branch on an armed flag and never
    /// touch the timing model — the same differential discipline as
    /// `fast_forward` and `sanitize`.
    pub faults: FaultPlan,
    /// Trace-caching warp JIT ([`trace`], `docs/SIMJIT.md`): straight-
    /// line warp-uniform arithmetic regions are pre-decoded once per
    /// program and dispatched as a single burst, with the per-cycle
    /// issue schedule replayed exactly. A pure host-side (wall-clock)
    /// optimization with the same differential discipline as
    /// `fast_forward`: simulated cycles, results, profiler ledgers,
    /// fault firing and sanitizer verdicts are bit-identical with it
    /// on or off (`rust/tests/jit_api.rs`). Excluded from the compile
    /// cache fingerprint like every other `sim` field. On by default.
    pub jit: bool,
    /// Host worker threads stepping cores inside one simulated cycle.
    /// A pure host-side (wall-clock) knob with the same discipline as
    /// `fast_forward`: cycles, results, profiler attribution, fault
    /// firing and sanitizer reports are bit-identical for any value
    /// (see `docs/PARALLELISM.md`). 1 = sequential tick loop (the
    /// default), 0 = one worker per available hardware thread.
    pub threads: usize,
}

/// Resolve a requested `threads` count: 0 means "use the host's
/// available parallelism", anything else passes through (minimum 1).
pub fn effective_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        requested
    }
}

impl Default for SimConfig {
    /// The paper's evaluation configuration (§5): 4 cores × 16 warps ×
    /// 32 threads, L2 enabled — i.e. [`SimConfig::from_target`] of the
    /// built-in `vortex` profile.
    fn default() -> Self {
        SimConfig::from_target(&TargetDesc::vortex())
    }
}

impl SimConfig {
    /// The target's default device configuration: geometry from the
    /// profile, features/address-map/costs always from the profile.
    pub fn from_target(t: &TargetDesc) -> SimConfig {
        SimConfig {
            num_cores: t.default_cores,
            warps_per_core: t.default_warps_per_core,
            threads_per_warp: t.default_threads_per_warp,
            local_mem_bytes: 128 << 10,
            l1d: CacheConfig::l1_default(),
            l2: t.default_l2.then(CacheConfig::l2_default),
            mem_latency: 100,
            heap_bytes: 64 << 20,
            max_cycles: 500_000_000,
            features: t.features,
            addr_map: t.addr_map,
            costs: t.costs,
            fast_forward: true,
            sanitize: false,
            faults: FaultPlan::none(),
            jit: true,
            threads: 1,
        }
    }

    /// Check this geometry against a target's capability ceilings and
    /// the simulator's own structural limits (32-bit thread and warp
    /// masks). Returns a message describing the first violation; the
    /// driver wraps it in a typed `InvalidOptions` error — geometry is
    /// never silently clamped.
    pub fn check_caps(&self, t: &TargetDesc) -> Result<(), String> {
        if self.num_cores == 0 || self.warps_per_core == 0 || self.threads_per_warp == 0 {
            return Err("device geometry must be non-zero (cores, warps, threads)".into());
        }
        let tmax = t.caps.max_threads_per_warp.min(32);
        if self.threads_per_warp > tmax {
            return Err(format!(
                "threads_per_warp {} exceeds target '{}' max {} (divergence masks are \
                 32-bit; the target caps at {})",
                self.threads_per_warp, t.name, tmax, t.caps.max_threads_per_warp
            ));
        }
        let wmax = t.caps.max_warps_per_core.min(32);
        if self.warps_per_core > wmax {
            return Err(format!(
                "warps_per_core {} exceeds target '{}' max {} (barrier arrival \
                 tables are 32-bit warp masks; the target caps at {})",
                self.warps_per_core, t.name, wmax, t.caps.max_warps_per_core
            ));
        }
        if self.num_cores > t.caps.max_cores {
            return Err(format!(
                "num_cores {} exceeds target '{}' max {}",
                self.num_cores, t.name, t.caps.max_cores
            ));
        }
        Ok(())
    }

    /// Small config for unit tests.
    pub fn tiny() -> SimConfig {
        SimConfig {
            num_cores: 1,
            warps_per_core: 2,
            threads_per_warp: 4,
            heap_bytes: 1 << 20,
            ..Default::default()
        }
    }
    pub fn total_threads(&self) -> u32 {
        self.num_cores * self.warps_per_core * self.threads_per_warp
    }
}

/// Aggregated run statistics — the raw material for Figures 7–10.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    pub cycles: u64,
    /// Warp-instructions issued (the Fig. 7 metric).
    pub instrs: u64,
    /// Thread-instructions (instr × active lanes).
    pub thread_instrs: u64,
    /// Dynamic counts of divergence-management instructions.
    pub splits: u64,
    pub joins: u64,
    pub preds: u64,
    pub tmcs: u64,
    pub barriers_executed: u64,
    pub warp_ops: u64,
    pub atomics: u64,
    /// Memory system.
    pub loads: u64,
    pub stores: u64,
    /// Cache-line requests issued to the memory system (the "memory
    /// request density" of §5.2).
    pub mem_requests: u64,
    pub l1_hits: u64,
    pub l1_misses: u64,
    pub l2_hits: u64,
    pub l2_misses: u64,
    pub local_accesses: u64,
    /// Cycles warps spent stalled at barriers.
    pub barrier_stall_cycles: u64,
    pub prints: Vec<String>,
    /// What the runtime sanitizer caught ([`SimConfig::sanitize`]);
    /// always empty when the sanitizer is off.
    pub sanitize_reports: Vec<mem::SanitizeReport>,
}

impl SimStats {
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.instrs as f64 / self.cycles as f64
        }
    }
}

/// What class of trap a [`SimError`] is — the recovery policy's input.
/// Transient classes (a flipped line, a spurious fault) are worth a
/// rollback-and-retry; deterministic ones (a hang is a hang on replay
/// too) must pass straight through to the caller.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrapKind {
    /// Illegal instruction / feature violation.
    IllegalInst,
    /// Memory access fault (decode, bounds, alignment).
    MemFault,
    /// Watchdog: the run exceeded `max_cycles`.
    Watchdog,
    /// Barrier deadlock: all live warps parked.
    Deadlock,
    /// Structural errors with no transient interpretation (bad entry
    /// pc, malformed control flow, ...).
    Fatal,
}

impl TrapKind {
    /// Would a deterministic replay from the same state hit this trap
    /// again? Injected transients (and real-hardware analogues) say no;
    /// hangs and structural errors say yes.
    pub fn transient(self) -> bool {
        matches!(self, TrapKind::IllegalInst | TrapKind::MemFault)
    }
}

#[derive(Debug, Clone)]
pub struct SimError {
    pub core: u32,
    pub warp: u32,
    pub pc: u32,
    pub msg: String,
    /// Trap class, driving retry-vs-fail decisions upstream.
    pub kind: TrapKind,
    /// True when the trap came from the fault injector rather than the
    /// program — lets tests and logs distinguish "we did this" from a
    /// genuine compiler/runtime bug.
    pub injected: bool,
}

impl SimError {
    /// A fatal (non-retryable) error — the default for trap sites that
    /// predate fault classification.
    pub fn fatal(core: u32, warp: u32, pc: u32, msg: impl Into<String>) -> SimError {
        SimError {
            core,
            warp,
            pc,
            msg: msg.into(),
            kind: TrapKind::Fatal,
            injected: false,
        }
    }
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "sim error at core {} warp {} pc {}: {}{}",
            self.core,
            self.warp,
            self.pc,
            self.msg,
            if self.injected { " [injected]" } else { "" }
        )
    }
}

impl std::error::Error for SimError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_target_follows_profiles() {
        let v = SimConfig::from_target(&TargetDesc::vortex());
        assert_eq!((v.num_cores, v.warps_per_core, v.threads_per_warp), (4, 16, 32));
        assert!(v.l2.is_some());
        assert!(v.features.zicond && v.features.shfl);
        let m = SimConfig::from_target(&TargetDesc::vortex_min());
        assert_eq!((m.num_cores, m.warps_per_core, m.threads_per_warp), (2, 8, 32));
        assert!(m.l2.is_none());
        assert!(!m.features.zicond && !m.features.vote);
        assert_eq!(m.addr_map, TargetDesc::vortex_min().addr_map);
    }

    #[test]
    fn caps_checked_not_clamped() {
        let min = TargetDesc::vortex_min();
        assert!(SimConfig::from_target(&min).check_caps(&min).is_ok());
        let cfg = SimConfig {
            warps_per_core: 16, // min caps at 8
            ..SimConfig::from_target(&min)
        };
        assert!(cfg.check_caps(&min).unwrap_err().contains("warps_per_core"));
        let cfg = SimConfig {
            num_cores: 4, // min caps at 2
            ..SimConfig::from_target(&min)
        };
        assert!(cfg.check_caps(&min).unwrap_err().contains("num_cores"));
        // The 32-lane mask edge is a structural ceiling even when a
        // (hypothetical) target declares more.
        let wide = TargetDesc {
            caps: crate::target::WarpCaps {
                max_threads_per_warp: 64,
                max_warps_per_core: 64,
                max_cores: 64,
            },
            ..TargetDesc::vortex()
        };
        let cfg = SimConfig {
            threads_per_warp: 33,
            ..SimConfig::default()
        };
        let e = cfg.check_caps(&wide).unwrap_err();
        assert!(e.contains("32-bit"), "{e}");
        let cfg = SimConfig {
            warps_per_core: 33,
            ..SimConfig::default()
        };
        assert!(cfg.check_caps(&wide).is_err());
        let cfg = SimConfig {
            num_cores: 0,
            ..SimConfig::default()
        };
        assert!(cfg.check_caps(&wide).is_err());
    }
}
