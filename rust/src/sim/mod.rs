//! SimX-style deterministic cycle-level SIMT simulator (paper §5: "SimX
//! provides deterministic, cycle-accurate execution (within 6% of RTL)").
//!
//! Models the Vortex microarchitecture of Fig. 3 at warp granularity: per
//! core a warp table (PC + thread mask per warp), per-warp IPDOM stacks, a
//! barrier table, active/stalled warp lists driving issue selection, an
//! SFU executing the vx_* instructions, L1D per core and a shared L2.
//! Timing is in-order issue with per-class latencies and load coalescing;
//! repeated runs are bit-identical, so performance deltas come only from
//! the compiler — the property the paper's evaluation relies on.

pub mod core;
pub mod gpu;
pub mod mem;

pub use gpu::Gpu;

/// Cache geometry + latency.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    pub sets: u32,
    pub ways: u32,
    pub line: u32,
    pub latency: u32,
}

impl CacheConfig {
    pub fn l1_default() -> CacheConfig {
        CacheConfig {
            sets: 64,
            ways: 4,
            line: 64,
            latency: 2,
        } // 16 KiB
    }
    pub fn l2_default() -> CacheConfig {
        CacheConfig {
            sets: 256,
            ways: 8,
            line: 64,
            latency: 20,
        } // 128 KiB
    }
}

#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    pub num_cores: u32,
    pub warps_per_core: u32,
    pub threads_per_warp: u32,
    pub local_mem_bytes: u32,
    pub l1d: CacheConfig,
    pub l2: Option<CacheConfig>,
    pub mem_latency: u32,
    pub heap_bytes: u32,
    pub max_cycles: u64,
}

impl Default for SimConfig {
    /// The paper's evaluation configuration (§5): 4 cores × 16 warps ×
    /// 32 threads, L2 enabled.
    fn default() -> Self {
        SimConfig {
            num_cores: 4,
            warps_per_core: 16,
            threads_per_warp: 32,
            local_mem_bytes: 128 << 10,
            l1d: CacheConfig::l1_default(),
            l2: Some(CacheConfig::l2_default()),
            mem_latency: 100,
            heap_bytes: 64 << 20,
            max_cycles: 500_000_000,
        }
    }
}

impl SimConfig {
    /// Small config for unit tests.
    pub fn tiny() -> SimConfig {
        SimConfig {
            num_cores: 1,
            warps_per_core: 2,
            threads_per_warp: 4,
            heap_bytes: 1 << 20,
            ..Default::default()
        }
    }
    pub fn total_threads(&self) -> u32 {
        self.num_cores * self.warps_per_core * self.threads_per_warp
    }
}

/// Aggregated run statistics — the raw material for Figures 7–10.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    pub cycles: u64,
    /// Warp-instructions issued (the Fig. 7 metric).
    pub instrs: u64,
    /// Thread-instructions (instr × active lanes).
    pub thread_instrs: u64,
    /// Dynamic counts of divergence-management instructions.
    pub splits: u64,
    pub joins: u64,
    pub preds: u64,
    pub tmcs: u64,
    pub barriers_executed: u64,
    pub warp_ops: u64,
    pub atomics: u64,
    /// Memory system.
    pub loads: u64,
    pub stores: u64,
    /// Cache-line requests issued to the memory system (the "memory
    /// request density" of §5.2).
    pub mem_requests: u64,
    pub l1_hits: u64,
    pub l1_misses: u64,
    pub l2_hits: u64,
    pub l2_misses: u64,
    pub local_accesses: u64,
    /// Cycles warps spent stalled at barriers.
    pub barrier_stall_cycles: u64,
    pub prints: Vec<String>,
}

impl SimStats {
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.instrs as f64 / self.cycles as f64
        }
    }
}

#[derive(Debug, Clone)]
pub struct SimError {
    pub core: u32,
    pub warp: u32,
    pub pc: u32,
    pub msg: String,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "sim error at core {} warp {} pc {}: {}",
            self.core, self.warp, self.pc, self.msg
        )
    }
}

impl std::error::Error for SimError {}
