//! One Vortex core: warp table, IPDOM stacks, barrier table, scheduler and
//! the execute stage (including the SFU implementing the vx_* extensions).
//!
//! Scalar arithmetic semantics are shared with the IR interpreter
//! ([`crate::ir::interp::scalar`]) so the property-test oracle and the
//! simulator cannot diverge.

use super::fault::FaultState;
use super::mem::{Cache, GlobalMem, ShadowLocal};
use super::trace::{self, ReplayQueue, ReplayTick, TraceCache};
use super::{SimConfig, SimError, SimStats, TrapKind};
use crate::backend::isa::{CsrId, MachInst, Op, OpClass};
use crate::ir::interp::scalar;
use crate::ir::{BinOp, FCmp, ICmp, UnOp};
use crate::prof::counters::StallReason;
use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct IpdomEntry {
    pub restore: u32,
    pub other: u32,
    pub other_pc: u32,
    pub join_pc: u32,
}

#[derive(Clone, Debug)]
pub struct Warp {
    pub pc: u32,
    pub tmask: u32,
    pub active: bool,
    pub stall_until: u64,
    pub at_barrier: bool,
    /// Functional class of the last issued instruction — why this warp
    /// is stalled while `stall_until > cycle`. Written unconditionally
    /// (pure bookkeeping, never read by the timing model) so profiling
    /// cannot perturb the deterministic schedule.
    pub last_class: OpClass,
    pub ipdom: Vec<IpdomEntry>,
    /// regs[lane][reg] — 0..32 integer x-regs (x0 = 0), 32..64 f-regs.
    pub regs: Vec<[u32; 64]>,
}

impl Warp {
    /// Bare warp for the trace-JIT unit tests ([`super::trace`]).
    #[cfg(test)]
    pub(crate) fn for_tests(nt: u32) -> Warp {
        Warp::new(nt)
    }

    fn new(nt: u32) -> Warp {
        Warp {
            pc: 0,
            tmask: 0,
            active: false,
            stall_until: 0,
            at_barrier: false,
            last_class: OpClass::Alu,
            ipdom: vec![],
            regs: vec![[0u32; 64]; nt as usize],
        }
    }
}

pub struct Core {
    pub id: u32,
    pub warps: Vec<Warp>,
    pub l1: Cache,
    pub local: Vec<u8>,
    /// barrier id -> bitmask of arrived warps.
    pub barriers: HashMap<u32, u32>,
    rr: usize,
    full_mask: u32,
    /// Idle-cycle fast-forward cache ([`SimConfig::fast_forward`]):
    /// while no warp can issue, the core's state is frozen — warp
    /// readiness only changes through this core's own `exec` (wspawn
    /// and barrier release are core-local) — so the first no-issue scan
    /// records the earliest ready cycle plus the stall attribution, and
    /// subsequent cycles skip the warp-table scan entirely. Invalidated
    /// on every executed instruction and on reset.
    idle: Option<IdleInfo>,
    /// Shadow memory over the local window ([`SimConfig::sanitize`]):
    /// `Some` only when the sanitizer is attached by [`super::Gpu::load`]
    /// (it needs the image's declared local extent). A pure observer —
    /// `None` leaves execution untouched.
    pub shadow: Option<ShadowLocal>,
    /// Trace-caching warp JIT ([`SimConfig::jit`], `docs/SIMJIT.md`):
    /// per-PC pre-decoded straight-line regions. Core-private, so the
    /// parallel tick engine composes with it lock-free; invalidated on
    /// [`Core::reset`]. Kept as two separate fields (`traces`,
    /// `replay`) so `exec` can hold a trace borrow while mutating the
    /// warp table and the replay queue.
    traces: TraceCache,
    /// Cycle-exact issue schedule of the in-flight trace burst (empty
    /// almost always). While non-empty, [`Core::step`] serves issues
    /// from here instead of scanning the warp table.
    replay: ReplayQueue,
}

/// Snapshot of a stalled core, valid until it next issues.
#[derive(Clone, Copy, Debug)]
struct IdleInfo {
    /// Earliest cycle a warp becomes issueable (`u64::MAX`: never —
    /// every active warp is barrier-parked, or none is active).
    ready_at: u64,
    reason: StallReason,
    active: u32,
}

/// What one issue slot executed — the profiler's attribution record.
#[derive(Clone, Copy, Debug)]
pub struct Issue {
    pub warp: u32,
    pub pc: u32,
    /// Issue-to-ready latency charged to this instruction (cycles).
    pub cost: u64,
}

pub enum StepOutcome {
    Executed(Issue),
    NoneReady,
}

impl Core {
    pub fn new(cfg: &SimConfig, id: u32) -> Core {
        // Geometry beyond the 32-bit thread/warp masks is rejected with a
        // typed error at option-build time (SimConfig::check_caps); this
        // guards direct construction.
        debug_assert!(
            cfg.threads_per_warp <= 32 && cfg.warps_per_core <= 32,
            "geometry exceeds the 32-bit mask width (cfg bypassed validation)"
        );
        let full_mask = if cfg.threads_per_warp >= 32 {
            u32::MAX
        } else {
            (1u32 << cfg.threads_per_warp) - 1
        };
        Core {
            id,
            warps: (0..cfg.warps_per_core)
                .map(|_| Warp::new(cfg.threads_per_warp))
                .collect(),
            l1: Cache::new(cfg.l1d),
            local: vec![0; cfg.local_mem_bytes as usize],
            barriers: HashMap::new(),
            rr: 0,
            full_mask,
            idle: None,
            shadow: None,
            traces: TraceCache::new(),
            replay: ReplayQueue::new(),
        }
    }

    pub fn reset(&mut self, cfg: &SimConfig) {
        for w in self.warps.iter_mut() {
            *w = Warp::new(cfg.threads_per_warp);
        }
        self.barriers.clear();
        self.rr = 0;
        self.idle = None;
        // JIT state never survives a reset: the program may change
        // under the core (Gpu::load builds fresh cores, but restore/
        // rerun paths reuse them).
        self.traces.invalidate();
        self.replay.clear();
        if let Some(sh) = self.shadow.as_mut() {
            sh.reset();
        }
        // Launch contract: warp 0, lane 0 active at pc 0.
        self.warps[0].active = true;
        self.warps[0].tmask = 1;
        self.warps[0].pc = 0;
    }

    pub fn idle(&self) -> bool {
        self.warps.iter().all(|w| !w.active)
    }

    /// Earliest cycle at which some warp could issue, if any. While a
    /// trace burst is in flight its next pending issue participates:
    /// the dispatched warp's `stall_until` already sits at the burst
    /// *end*, but the engine's event-skip must still land on every
    /// intermediate issue cycle exactly as the interpreter would.
    pub fn next_ready(&self) -> Option<u64> {
        let base = self
            .warps
            .iter()
            .filter(|w| w.active && !w.at_barrier)
            .map(|w| w.stall_until)
            .min();
        match self.replay.next_cycle() {
            Some(c) => Some(base.map_or(c, |b| b.min(c))),
            None => base,
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        cycle: u64,
        prog: &[MachInst],
        mem: &mut GlobalMem,
        l2: &mut Option<Cache>,
        cfg: &SimConfig,
        stats: &mut SimStats,
        faults: &mut FaultState,
    ) -> Result<StepOutcome, SimError> {
        // JIT burst replay: a dispatched trace already committed its
        // architectural effects; the engine still observes each issue
        // at its exact interpreter cycle (docs/SIMJIT.md).
        match self.replay.tick(cycle) {
            ReplayTick::Issue(issue) => return Ok(StepOutcome::Executed(issue)),
            ReplayTick::Wait => return Ok(StepOutcome::NoneReady),
            ReplayTick::Idle => {}
        }
        let Some(wi) = self.choose_warp(cycle, cfg) else {
            return Ok(StepOutcome::NoneReady);
        };
        let issue = self.exec(wi, cycle, prog, mem, l2, cfg, stats, faults)?;
        Ok(StepOutcome::Executed(issue))
    }

    /// The replay intercept for the parallel engine's compute phase
    /// ([`super::gpu`]): purely core-local, so it runs off-thread.
    pub(crate) fn replay_tick(&mut self, cycle: u64) -> ReplayTick {
        self.replay.tick(cycle)
    }

    /// Issue selection for this cycle: round-robin over the active list,
    /// with the idle fast-forward short-circuit. Split out of [`step`] so
    /// the parallel tick loop ([`super::gpu`]) can pick the warp in the
    /// per-core compute phase and defer the (possibly shared-state)
    /// execute to the in-order commit phase. Mutates only scheduler
    /// bookkeeping (`rr`, `idle`) — never warp architectural state.
    pub(crate) fn choose_warp(&mut self, cycle: u64, cfg: &SimConfig) -> Option<usize> {
        // Idle fast-forward: nothing about this core can change until
        // `ready_at`, so skip the warp-table scan entirely.
        if cfg.fast_forward {
            if let Some(info) = self.idle {
                if cycle < info.ready_at {
                    return None;
                }
            }
        }
        // Round-robin issue selection over the active list.
        let n = self.warps.len();
        let mut chosen: Option<usize> = None;
        for k in 0..n {
            let wi = (self.rr + k) % n;
            let w = &self.warps[wi];
            if w.active && !w.at_barrier && w.stall_until <= cycle {
                chosen = Some(wi);
                break;
            }
        }
        let Some(wi) = chosen else {
            if cfg.fast_forward {
                self.idle = Some(IdleInfo {
                    ready_at: self.next_ready().unwrap_or(u64::MAX),
                    reason: self.compute_stall_reason(),
                    active: self.compute_active_warps(),
                });
            }
            return None;
        };
        self.idle = None;
        self.rr = (wi + 1) % n;
        Some(wi)
    }

    /// Why this core cannot issue right now: the warp closest to becoming
    /// ready (lowest `stall_until`, then lowest index — deterministic) is
    /// the bottleneck and its last instruction class names the reason.
    /// Barrier-parked warps report [`StallReason::Barrier`]; a fully
    /// retired core reports [`StallReason::NoActiveWarp`]. Served from
    /// the idle cache while fast-forwarding (the state is frozen, so the
    /// cached value equals a rescan).
    pub fn stall_reason(&self) -> StallReason {
        // Mid-burst gap cycle: the scoreboard guard proved at dispatch
        // that the bursting warp's next issue strictly precedes every
        // other warp's readiness, so the interpreter's bottleneck-warp
        // scan would pick the bursting warp — and every traceable op
        // class (ALU/MUL/DIV/FPU/FDIV/SFU) attributes to Scoreboard.
        if !self.replay.is_empty() {
            return StallReason::Scoreboard;
        }
        if let Some(info) = self.idle {
            return info.reason;
        }
        self.compute_stall_reason()
    }

    /// The PC to report for warp `wi` in hang diagnostics. Mid-burst
    /// the warp table's `pc` already points past the trace; the
    /// interpreter would sit at the next unexecuted op, which is the
    /// replay queue's pending head.
    pub(crate) fn warp_report_pc(&self, wi: usize) -> u32 {
        if let Some(pc) = self.replay.pending_pc(wi) {
            return pc;
        }
        self.warps[wi].pc
    }

    fn compute_stall_reason(&self) -> StallReason {
        let mut best: Option<&Warp> = None;
        let mut any_active = false;
        for w in &self.warps {
            if !w.active {
                continue;
            }
            any_active = true;
            if w.at_barrier {
                continue;
            }
            match best {
                None => best = Some(w),
                Some(b) if w.stall_until < b.stall_until => best = Some(w),
                _ => {}
            }
        }
        match (any_active, best) {
            (false, _) => StallReason::NoActiveWarp,
            (true, None) => StallReason::Barrier,
            (true, Some(w)) => match w.last_class {
                OpClass::Mem => StallReason::Memory,
                OpClass::Vx => StallReason::Divergence,
                _ => StallReason::Scoreboard,
            },
        }
    }

    /// Number of active (not yet retired) warps — the occupancy sample.
    /// Served from the idle cache while fast-forwarding.
    pub fn active_warps(&self) -> u32 {
        if let Some(info) = self.idle {
            return info.active;
        }
        self.compute_active_warps()
    }

    fn compute_active_warps(&self) -> u32 {
        self.warps.iter().filter(|w| w.active).count() as u32
    }

    fn err(&self, wi: usize, pc: u32, msg: impl Into<String>) -> SimError {
        SimError::fatal(self.id, wi as u32, pc, msg)
    }

    /// Typed trap with an explicit [`TrapKind`] (memory faults and
    /// injected faults; everything else defaults to `Fatal` via `err`).
    fn err_kind(&self, wi: usize, pc: u32, kind: TrapKind, msg: impl Into<String>) -> SimError {
        SimError {
            core: self.id,
            warp: wi as u32,
            pc,
            msg: msg.into(),
            kind,
            injected: false,
        }
    }

    /// Memory-fault trap ([`TrapKind::MemFault`]).
    fn mem_err(&self, wi: usize, pc: u32, msg: impl Into<String>) -> SimError {
        self.err_kind(wi, pc, TrapKind::MemFault, msg)
    }

    /// Record a barrier arrival and release the block when everyone is
    /// there (the normal, un-injected `vx_bar` semantics).
    fn apply_barrier(&mut self, wi: usize, id: u32, count: u32) {
        let arrived = self.barriers.entry(id).or_insert(0);
        *arrived |= 1 << wi;
        if arrived.count_ones() >= count {
            let mask = *arrived;
            self.barriers.remove(&id);
            for k in 0..self.warps.len() {
                if mask >> k & 1 == 1 {
                    self.warps[k].at_barrier = false;
                }
            }
            // Phase boundary for the sanitizer: conflicts do not
            // span a released barrier.
            if let Some(sh) = self.shadow.as_mut() {
                sh.barrier_release();
            }
        } else {
            self.warps[wi].at_barrier = true;
        }
    }

    /// Uniform read of a register across active lanes.
    fn uniform_read(&self, wi: usize, r: u8, pc: u32) -> Result<u32, SimError> {
        let w = &self.warps[wi];
        let mut val: Option<u32> = None;
        for l in 0..w.regs.len() {
            if w.tmask >> l & 1 == 1 {
                let v = read_reg(&w.regs[l], r);
                match val {
                    None => val = Some(v),
                    Some(x) if x == v => {}
                    Some(x) => {
                        return Err(self.err(
                            wi,
                            pc,
                            format!(
                                "non-uniform register x{r} at warp-level op ({x} vs {v}) — \
                                 unmanaged divergence (compiler bug)"
                            ),
                        ))
                    }
                }
            }
        }
        val.ok_or_else(|| self.err(wi, pc, "warp-level read with empty mask"))
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn exec(
        &mut self,
        wi: usize,
        cycle: u64,
        prog: &[MachInst],
        mem: &mut GlobalMem,
        l2: &mut Option<Cache>,
        cfg: &SimConfig,
        stats: &mut SimStats,
        faults: &mut FaultState,
    ) -> Result<Issue, SimError> {
        let pc = self.warps[wi].pc;
        // JIT trace dispatch (docs/SIMJIT.md). The five guards, in
        // order: (1) the knob is on; (2) no armed fault plan — a due
        // fault must fire at its exact (cycle, pc), so the JIT stands
        // down entirely until every one-shot fault is consumed (the
        // armed flag is monotone, so both engines re-engage at the
        // same cycle); (3) full-mask uniform execution; (4) a cached
        // trace exists — which by construction excludes every op that
        // could trap, touch memory/shadow state, or move a mask, so
        // the sanitizer cannot observe the burst; (5) no scoreboard
        // hazard: the trace's last issue cycle strictly precedes every
        // other warp's readiness, so round-robin would pick this warp
        // at each intermediate cycle anyway (and `rr` ends at the same
        // value). Any guard failing falls through to the interpreter.
        if cfg.jit && !faults.armed() && self.warps[wi].tmask == self.full_mask {
            let mut others_ready = u64::MAX;
            for (k, w) in self.warps.iter().enumerate() {
                if k != wi && w.active && !w.at_barrier {
                    others_ready = others_ready.min(w.stall_until);
                }
            }
            // Split borrows: `plan` holds `self.traces` for the rest of
            // the block while the warp table and replay queue mutate.
            if let Some(tr) = self.traces.plan(pc, prog, &cfg.costs) {
                if cycle + tr.total_cost - tr.last_cost < others_ready {
                    let nt = cfg.threads_per_warp as usize;
                    let w = &mut self.warps[wi];
                    trace::exec_trace(tr, w, nt);
                    w.pc = tr.end_pc;
                    w.stall_until = cycle + tr.total_cost;
                    w.last_class = tr.last_class;
                    // Traceable ops touch no counter besides these two
                    // (order-insensitive sums, so bulk-charging at
                    // dispatch equals the interpreter's totals).
                    stats.instrs += tr.ops.len() as u64;
                    stats.thread_instrs += (tr.ops.len() * nt) as u64;
                    self.replay.schedule(wi as u32, cycle, tr);
                    self.idle = None;
                    return Ok(Issue {
                        warp: wi as u32,
                        pc,
                        cost: tr.ops[0].cost,
                    });
                }
            }
        }
        let inst = *prog
            .get(pc as usize)
            .ok_or_else(|| self.err(wi, pc, "pc out of program"))?;
        let nt = cfg.threads_per_warp as usize;
        let tmask = self.warps[wi].tmask;
        // Hot path: lane list in a stack buffer (no per-instruction heap
        // allocation — see EXPERIMENTS.md §Perf).
        let mut lanes_buf = [0usize; 32];
        let mut nl = 0;
        for l in 0..nt {
            if tmask >> l & 1 == 1 {
                lanes_buf[nl] = l;
                nl += 1;
            }
        }
        let lanes = &lanes_buf[..nl];
        if lanes.is_empty() {
            return Err(self.err(wi, pc, "issued with empty thread mask"));
        }
        // Fault injection ([`SimConfig::faults`]): a scheduled trap due at
        // this (cycle, pc) fires before the instruction issues. One bool
        // load when no plan is armed — the empty plan stays bit-identical.
        if faults.armed() {
            if let Some((kind, msg)) = faults.trap_at(cycle, pc) {
                let mut e = self.err_kind(wi, pc, kind, msg);
                e.injected = true;
                return Err(e);
            }
        }
        // Feature-gated opcodes were audited once at run start
        // (Gpu::run_profiled) — the per-issue hot path carries no check.
        debug_assert!(cfg.features.supports_op(inst.op));
        stats.instrs += 1;
        stats.thread_instrs += lanes.len() as u64;
        let mut next_pc = pc + 1;
        // Issue-to-ready latency from the target's cost model (memory is
        // a floor, adjusted below by the cache hierarchy).
        let mut cost = cfg.costs.issue_cost(inst.op.class());

        macro_rules! w {
            () => {
                self.warps[wi]
            };
        }

        match inst.op {
            Op::NOP => {}
            Op::LI => {
                for &l in lanes {
                    write_reg(&mut self.warps[wi].regs[l], inst.rd, inst.imm as u32);
                }
            }
            Op::MOV => {
                for &l in lanes {
                    let v = read_reg(&self.warps[wi].regs[l], inst.rs1);
                    write_reg(&mut self.warps[wi].regs[l], inst.rd, v);
                }
            }
            // Integer ALU (register forms).
            Op::ADD | Op::SUB | Op::MUL | Op::DIV | Op::DIVU | Op::REM | Op::REMU | Op::AND
            | Op::OR | Op::XOR | Op::SLL | Op::SRL | Op::SRA | Op::MIN | Op::MAX => {
                let bop = match inst.op {
                    Op::ADD => BinOp::Add,
                    Op::SUB => BinOp::Sub,
                    Op::MUL => BinOp::Mul,
                    Op::DIV => BinOp::SDiv,
                    Op::DIVU => BinOp::UDiv,
                    Op::REM => BinOp::SRem,
                    Op::REMU => BinOp::URem,
                    Op::AND => BinOp::And,
                    Op::OR => BinOp::Or,
                    Op::XOR => BinOp::Xor,
                    Op::SLL => BinOp::Shl,
                    Op::SRL => BinOp::LShr,
                    Op::SRA => BinOp::AShr,
                    Op::MIN => BinOp::SMin,
                    _ => BinOp::SMax,
                };
                for &l in lanes {
                    let a = read_reg(&self.warps[wi].regs[l], inst.rs1);
                    let b = read_reg(&self.warps[wi].regs[l], inst.rs2);
                    write_reg(&mut self.warps[wi].regs[l], inst.rd, scalar::bin_i(bop, a, b));
                }
            }
            Op::ADDI | Op::ANDI | Op::ORI | Op::XORI | Op::SLLI | Op::SRLI | Op::SRAI => {
                let bop = match inst.op {
                    Op::ADDI => BinOp::Add,
                    Op::ANDI => BinOp::And,
                    Op::ORI => BinOp::Or,
                    Op::XORI => BinOp::Xor,
                    Op::SLLI => BinOp::Shl,
                    Op::SRLI => BinOp::LShr,
                    _ => BinOp::AShr,
                };
                for &l in lanes {
                    let a = read_reg(&self.warps[wi].regs[l], inst.rs1);
                    write_reg(
                        &mut self.warps[wi].regs[l],
                        inst.rd,
                        scalar::bin_i(bop, a, inst.imm as u32),
                    );
                }
            }
            Op::SEQ | Op::SNE | Op::SLT | Op::SLE | Op::SLTU | Op::SGEU => {
                let pred = match inst.op {
                    Op::SEQ => ICmp::Eq,
                    Op::SNE => ICmp::Ne,
                    Op::SLT => ICmp::Slt,
                    Op::SLE => ICmp::Sle,
                    Op::SLTU => ICmp::Ult,
                    _ => ICmp::Uge,
                };
                for &l in lanes {
                    let a = read_reg(&self.warps[wi].regs[l], inst.rs1);
                    let b = read_reg(&self.warps[wi].regs[l], inst.rs2);
                    write_reg(
                        &mut self.warps[wi].regs[l],
                        inst.rd,
                        scalar::icmp(pred, a, b) as u32,
                    );
                }
            }
            // Float ALU.
            Op::FADD | Op::FSUB | Op::FMUL | Op::FDIV | Op::FMIN | Op::FMAX => {
                let bop = match inst.op {
                    Op::FADD => BinOp::FAdd,
                    Op::FSUB => BinOp::FSub,
                    Op::FMUL => BinOp::FMul,
                    Op::FDIV => BinOp::FDiv,
                    Op::FMIN => BinOp::FMin,
                    _ => BinOp::FMax,
                };
                for &l in lanes {
                    let a = f32::from_bits(read_reg(&self.warps[wi].regs[l], inst.rs1));
                    let b = f32::from_bits(read_reg(&self.warps[wi].regs[l], inst.rs2));
                    write_reg(
                        &mut self.warps[wi].regs[l],
                        inst.rd,
                        scalar::bin_f(bop, a, b).to_bits(),
                    );
                }
            }
            Op::FSQRT | Op::FNEG | Op::FABS | Op::FEXP | Op::FLOG | Op::FFLOOR | Op::FCVTWS
            | Op::FCVTSW | Op::FMVXW | Op::FMVWX => {
                let uop = match inst.op {
                    Op::FSQRT => UnOp::FSqrt,
                    Op::FNEG => UnOp::FNeg,
                    Op::FABS => UnOp::FAbs,
                    Op::FEXP => UnOp::FExp,
                    Op::FLOG => UnOp::FLog,
                    Op::FFLOOR => UnOp::FFloor,
                    Op::FCVTWS => UnOp::FpToSi,
                    Op::FCVTSW => UnOp::SiToFp,
                    Op::FMVXW => UnOp::FToBits,
                    _ => UnOp::BitsToF,
                };
                for &l in lanes {
                    let a = read_reg(&self.warps[wi].regs[l], inst.rs1);
                    write_reg(&mut self.warps[wi].regs[l], inst.rd, scalar::un(uop, a));
                }
            }
            Op::FEQ | Op::FNE | Op::FLT | Op::FLE | Op::FGT | Op::FGE => {
                let pred = match inst.op {
                    Op::FEQ => FCmp::Oeq,
                    Op::FNE => FCmp::One,
                    Op::FLT => FCmp::Olt,
                    Op::FLE => FCmp::Ole,
                    Op::FGT => FCmp::Ogt,
                    _ => FCmp::Oge,
                };
                for &l in lanes {
                    let a = f32::from_bits(read_reg(&self.warps[wi].regs[l], inst.rs1));
                    let b = f32::from_bits(read_reg(&self.warps[wi].regs[l], inst.rs2));
                    write_reg(
                        &mut self.warps[wi].regs[l],
                        inst.rd,
                        scalar::fcmp(pred, a, b) as u32,
                    );
                }
            }
            Op::CMOV => {
                for &l in lanes {
                    let c = read_reg(&self.warps[wi].regs[l], inst.rs1);
                    if c != 0 {
                        let v = read_reg(&self.warps[wi].regs[l], inst.rs2);
                        write_reg(&mut self.warps[wi].regs[l], inst.rd, v);
                    }
                }
            }
            // Memory.
            Op::LW | Op::SW => {
                let is_store = inst.op == Op::SW;
                if is_store {
                    stats.stores += 1;
                } else {
                    stats.loads += 1;
                }
                // Per-thread stacks live in core-local storage on Vortex:
                // scratchpad timing, not the cache hierarchy. Address
                // spaces decode through the target's map.
                let map = &cfg.addr_map;
                let stack_end = map.stack_base + cfg.total_threads() * map.stack_size;
                let mut lines_buf = [0u32; 32];
                let mut n_lines = 0usize;
                let mut local_touched = false;
                for &l in lanes {
                    let addr = read_reg(&self.warps[wi].regs[l], inst.rs1)
                        .wrapping_add(inst.imm as u32);
                    let local_off = addr.wrapping_sub(map.local_base) as usize;
                    if (map.stack_base..stack_end).contains(&addr) {
                        // data via global memory image, scratchpad timing
                        if is_store {
                            let v = read_reg(&self.warps[wi].regs[l], inst.rs2);
                            mem.write_u32(addr, v).map_err(|f| {
                                self.mem_err(wi, pc, format!("stack store fault at {:#x}", f.addr))
                            })?;
                        } else {
                            let v = mem.read_u32(addr).map_err(|f| {
                                self.mem_err(wi, pc, format!("stack load fault at {:#x}", f.addr))
                            })?;
                            write_reg(&mut self.warps[wi].regs[l], inst.rd, v);
                        }
                        local_touched = true;
                    } else if local_off + 4 <= self.local.len() {
                        local_touched = true;
                        if let Some(sh) = self.shadow.as_mut() {
                            sh.on_access(
                                stats, is_store, local_off, addr, pc, self.id, wi as u32, l as u32,
                            );
                        }
                        if is_store {
                            let v = read_reg(&self.warps[wi].regs[l], inst.rs2);
                            self.local[local_off..local_off + 4]
                                .copy_from_slice(&v.to_le_bytes());
                        } else {
                            let v = u32::from_le_bytes(
                                self.local[local_off..local_off + 4].try_into().unwrap(),
                            );
                            write_reg(&mut self.warps[wi].regs[l], inst.rd, v);
                        }
                    } else {
                        if is_store {
                            let v = read_reg(&self.warps[wi].regs[l], inst.rs2);
                            mem.write_u32(addr, v).map_err(|f| {
                                self.mem_err(wi, pc, format!("store fault at {:#x}", f.addr))
                            })?;
                        } else {
                            let v = mem.read_u32(addr).map_err(|f| {
                                self.mem_err(wi, pc, format!("load fault at {:#x}", f.addr))
                            })?;
                            write_reg(&mut self.warps[wi].regs[l], inst.rd, v);
                        }
                        let line = addr / 64;
                        if !lines_buf[..n_lines].contains(&line) {
                            lines_buf[n_lines] = line;
                            n_lines += 1;
                        }
                    }
                }
                // Timing: coalesced unique lines through L1 -> L2 -> DRAM.
                let mut max_lat = 0u64;
                stats.mem_requests += n_lines as u64;
                for line in &lines_buf[..n_lines] {
                    let lat = if self.l1.access_line(*line) {
                        stats.l1_hits += 1;
                        self.l1.latency() as u64
                    } else {
                        stats.l1_misses += 1;
                        match l2 {
                            Some(l2c) => {
                                if l2c.access_line(*line) {
                                    stats.l2_hits += 1;
                                    l2c.latency() as u64
                                } else {
                                    stats.l2_misses += 1;
                                    cfg.mem_latency as u64
                                }
                            }
                            None => cfg.mem_latency as u64,
                        }
                    };
                    max_lat = max_lat.max(lat);
                }
                if local_touched {
                    stats.local_accesses += 1;
                    max_lat = max_lat.max(2);
                }
                cost = max_lat + n_lines.saturating_sub(1) as u64;
                cost = cost.max(1);
                // Fault injection: a due LoadBitFlip corrupts one bit of
                // the destination register in the first active lane — the
                // run completes, the data is silently wrong (the retry
                // path catches it through the validator, not a trap).
                if !is_store && faults.armed() {
                    if let Some(bit) = faults.load_flip(cycle, pc) {
                        let l = lanes[0];
                        let cur = read_reg(&self.warps[wi].regs[l], inst.rd);
                        write_reg(&mut self.warps[wi].regs[l], inst.rd, cur ^ (1u32 << bit));
                    }
                }
            }
            Op::AMOADD | Op::AMOAND | Op::AMOOR | Op::AMOXOR | Op::AMOMIN | Op::AMOMAX
            | Op::AMOSWAP | Op::AMOCAS => {
                stats.atomics += 1;
                for &l in lanes {
                    let addr = read_reg(&self.warps[wi].regs[l], inst.rs1);
                    let v = read_reg(&self.warps[wi].regs[l], inst.rs2);
                    let local_off = addr.wrapping_sub(cfg.addr_map.local_base) as usize;
                    if local_off + 4 <= self.local.len() {
                        if let Some(sh) = self.shadow.as_mut() {
                            sh.on_atomic(stats, local_off, addr, pc, self.id, wi as u32, l as u32);
                        }
                    }
                    let old = if local_off + 4 <= self.local.len() {
                        u32::from_le_bytes(self.local[local_off..local_off + 4].try_into().unwrap())
                    } else {
                        mem.read_u32(addr).map_err(|f| {
                            self.mem_err(wi, pc, format!("atomic fault at {:#x}", f.addr))
                        })?
                    };
                    let new = match inst.op {
                        Op::AMOADD => old.wrapping_add(v),
                        Op::AMOAND => old & v,
                        Op::AMOOR => old | v,
                        Op::AMOXOR => old ^ v,
                        Op::AMOMIN => (old as i32).min(v as i32) as u32,
                        Op::AMOMAX => (old as i32).max(v as i32) as u32,
                        Op::AMOSWAP => v,
                        _ => {
                            // CAS: rd holds the expected value on entry.
                            let expect = read_reg(&self.warps[wi].regs[l], inst.rd);
                            if old == expect {
                                v
                            } else {
                                old
                            }
                        }
                    };
                    if local_off + 4 <= self.local.len() {
                        self.local[local_off..local_off + 4].copy_from_slice(&new.to_le_bytes());
                    } else {
                        mem.write_u32(addr, new).map_err(|f| {
                            self.mem_err(wi, pc, format!("atomic fault at {:#x}", f.addr))
                        })?;
                    }
                    write_reg(&mut self.warps[wi].regs[l], inst.rd, old);
                }
                cost = (l2.as_ref().map(|c| c.latency()).unwrap_or(cfg.mem_latency) as u64)
                    + lanes.len() as u64;
            }
            // Branches.
            Op::BEQZ | Op::BNEZ => {
                let v = self.uniform_read(wi, inst.rs1, pc)?;
                let taken = if inst.op == Op::BEQZ { v == 0 } else { v != 0 };
                if taken {
                    next_pc = inst.imm as u32;
                }
            }
            Op::J => next_pc = inst.imm as u32,
            Op::JAL => {
                for &l in lanes {
                    write_reg(&mut self.warps[wi].regs[l], inst.rd, pc + 1);
                }
                next_pc = inst.imm as u32;
            }
            Op::JALR => {
                let target = self.uniform_read(wi, inst.rs1, pc)?;
                for &l in lanes {
                    write_reg(&mut self.warps[wi].regs[l], inst.rd, pc + 1);
                }
                next_pc = target.wrapping_add(inst.imm as u32);
            }
            Op::ECALL => {
                if inst.imm != 0 {
                    return Err(self.err(wi, pc, format!("trap: ecall {}", inst.imm)));
                }
                // ecall 0: retire the warp.
                w!().active = false;
            }
            Op::CSRR => {
                let id = CsrId::from_u32(inst.imm as u32).ok_or_else(|| {
                    self.err(wi, pc, format!("unknown CSR index {}", inst.imm))
                })?;
                for &l in lanes {
                    let v = match id {
                        CsrId::LaneId => l as u32,
                        CsrId::WarpId => wi as u32,
                        CsrId::CoreId => self.id,
                        CsrId::NumThreads => cfg.threads_per_warp,
                        CsrId::NumWarps => cfg.warps_per_core,
                        CsrId::NumCores => cfg.num_cores,
                    };
                    write_reg(&mut self.warps[wi].regs[l], inst.rd, v);
                }
            }
            // ---- Vortex extensions ----
            Op::TMC => {
                stats.tmcs += 1;
                let v = if inst.rs1 == 0 {
                    0
                } else {
                    self.uniform_read(wi, inst.rs1, pc)?
                };
                let new = v & self.full_mask;
                if new == 0 {
                    w!().active = false;
                } else {
                    w!().tmask = new;
                }
            }
            Op::WSPAWN => {
                let count = self.uniform_read(wi, inst.rs1, pc)? as usize;
                let target = inst.imm as u32;
                for k in 1..=count.min(self.warps.len() - 1) {
                    let w = &mut self.warps[k];
                    if !w.active {
                        w.active = true;
                        w.pc = target;
                        w.tmask = 1;
                        w.stall_until = cycle + 1;
                    }
                }
            }
            Op::SPLIT | Op::SPLITN => {
                stats.splits += 1;
                let (else_pc, join_pc) = MachInst::split_targets(inst.imm);
                let neg = inst.op == Op::SPLITN;
                let mut t = 0u32;
                for &l in lanes {
                    let p = read_reg(&self.warps[wi].regs[l], inst.rs1) != 0;
                    if p ^ neg {
                        t |= 1 << l;
                    }
                }
                let e = tmask & !t;
                let w = &mut self.warps[wi];
                if t == 0 {
                    w.ipdom.push(IpdomEntry {
                        restore: tmask,
                        other: 0,
                        other_pc: 0,
                        join_pc,
                    });
                    next_pc = else_pc;
                } else if e == 0 {
                    w.ipdom.push(IpdomEntry {
                        restore: tmask,
                        other: 0,
                        other_pc: 0,
                        join_pc,
                    });
                } else {
                    w.ipdom.push(IpdomEntry {
                        restore: tmask,
                        other: e,
                        other_pc: else_pc,
                        join_pc,
                    });
                    w.tmask = t;
                }
                if w.ipdom.len() > 4096 {
                    return Err(self.err(wi, pc, "IPDOM stack overflow"));
                }
            }
            Op::JOIN => {
                stats.joins += 1;
                let w = &mut self.warps[wi];
                loop {
                    match w.ipdom.last_mut() {
                        Some(en) if en.join_pc == pc => {
                            if en.other != 0 {
                                w.tmask = en.other;
                                next_pc = en.other_pc;
                                en.other = 0;
                                break;
                            } else {
                                w.tmask = en.restore;
                                w.ipdom.pop();
                            }
                        }
                        _ => break,
                    }
                }
            }
            Op::PRED => {
                stats.preds += 1;
                let mut p = 0u32;
                for &l in lanes {
                    if read_reg(&self.warps[wi].regs[l], inst.rs1) != 0 {
                        p |= 1 << l;
                    }
                }
                let new = tmask & p;
                if new == 0 {
                    let restore = self.uniform_read(wi, inst.rs2, pc)?;
                    let w = &mut self.warps[wi];
                    w.tmask = restore & self.full_mask;
                    next_pc = inst.imm as u32;
                    if w.tmask == 0 {
                        return Err(self.err(wi, pc, "vx_pred restored empty mask"));
                    }
                } else {
                    self.warps[wi].tmask = new;
                }
            }
            Op::BAR => {
                stats.barriers_executed += 1;
                let count = self.uniform_read(wi, inst.rs1, pc)?;
                let id = inst.imm as u32;
                // Fault injection: a due StuckBarrier drops this arrival —
                // the warp parks but is never counted, so the block
                // deadlocks deterministically (a fault retry must NOT
                // absorb: the hang replays identically).
                if faults.armed() && faults.stuck_barrier(cycle, pc) {
                    self.warps[wi].at_barrier = true;
                    let _ = (count, id);
                } else {
                    self.apply_barrier(wi, id, count);
                }
            }
            Op::MASK => {
                for &l in lanes {
                    write_reg(&mut self.warps[wi].regs[l], inst.rd, tmask);
                }
            }
            Op::SHFL => {
                stats.warp_ops += 1;
                // Pre-shuffle snapshot in a stack buffer (nt <= 32) —
                // the exec path allocates nothing per instruction.
                let mut snapshot = [0u32; 32];
                for (l, s) in snapshot.iter_mut().enumerate().take(nt) {
                    *s = read_reg(&self.warps[wi].regs[l], inst.rs1);
                }
                for &l in lanes {
                    let src =
                        read_reg(&self.warps[wi].regs[l], inst.rs2) % cfg.threads_per_warp;
                    write_reg(&mut self.warps[wi].regs[l], inst.rd, snapshot[src as usize]);
                }
            }
            Op::VOTEALL | Op::VOTEANY | Op::BALLOT => {
                stats.warp_ops += 1;
                let mut ballot = 0u32;
                for &l in lanes {
                    if read_reg(&self.warps[wi].regs[l], inst.rs1) != 0 {
                        ballot |= 1 << l;
                    }
                }
                let v = match inst.op {
                    Op::VOTEALL => (ballot == tmask) as u32,
                    Op::VOTEANY => (ballot != 0) as u32,
                    _ => ballot,
                };
                for &l in lanes {
                    write_reg(&mut self.warps[wi].regs[l], inst.rd, v);
                }
            }
            Op::PRINTI | Op::PRINTF => {
                for &l in lanes {
                    let v = read_reg(&self.warps[wi].regs[l], inst.rs1);
                    let s = if inst.op == Op::PRINTI {
                        format!("c{}w{}l{}: {}", self.id, wi, l, v as i32)
                    } else {
                        format!("c{}w{}l{}: {}", self.id, wi, l, f32::from_bits(v))
                    };
                    stats.prints.push(s);
                }
            }
        }
        let w = &mut self.warps[wi];
        w.pc = next_pc;
        w.stall_until = cycle + cost;
        w.last_class = inst.op.class();
        Ok(Issue {
            warp: wi as u32,
            pc,
            cost,
        })
    }
}

#[inline]
pub(crate) fn read_reg(regs: &[u32; 64], r: u8) -> u32 {
    if r == 0 {
        0
    } else {
        regs[r as usize]
    }
}

#[inline]
pub(crate) fn write_reg(regs: &mut [u32; 64], r: u8, v: u32) {
    if r != 0 {
        regs[r as usize] = v;
    }
}
