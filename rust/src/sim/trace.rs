//! Trace-caching warp JIT for the simulator hot loop (`docs/SIMJIT.md`).
//!
//! The interpreter ([`super::core::Core::exec`]) decodes every
//! `MachInst` on every issue. This module removes that overhead for the
//! common case — straight-line, warp-uniform arithmetic — by
//! pre-decoding a *trace* (a maximal run of register-only ops starting
//! at a PC) exactly once per program load, then dispatching a warp down
//! the whole trace in a single `step` call.
//!
//! Cranelift-style single-pass translation, not a real code generator:
//! decoding resolves each instruction to a [`TraceKind`] (the operand
//! mapping the interpreter would compute per cycle) plus its
//! [`CostModel`] issue cost, and execution is a tight match over the
//! pre-resolved kinds. The timing model is untouched — every traced
//! instruction is charged its exact per-class cost, and the issues it
//! would have produced are replayed to the engine cycle-by-cycle from a
//! [`ReplayQueue`], so cycles, results, profiler ledgers and sanitizer
//! verdicts are bit-identical with the JIT on or off
//! ([`SimConfig::jit`](super::SimConfig::jit); `rust/tests/jit_api.rs`).
//!
//! What a trace may contain is deliberately narrow: ALU / MUL / DIV /
//! FPU / FDIV / SFU register ops only. Formation stops at branches,
//! jumps, every `vx_*` op (split/join/tmc/pred/bar/...), all memory
//! classes (loads, stores, atomics), CSR reads, prints and `ecall` —
//! so a traced op can never trap, touch `GlobalMem`/L1/L2, move a
//! thread mask, park a warp, or disturb the sanitizer's shadow state.
//! That exclusion is what makes the five dispatch guards (see
//! [`super::core::Core::exec`]) sufficient for bit-identity.

use super::core::{read_reg, write_reg, Issue, Warp};
use crate::backend::isa::{MachInst, Op, OpClass};
use crate::ir::interp::scalar;
use crate::ir::{BinOp, FCmp, ICmp, UnOp};
use crate::target::CostModel;

/// Longest run of instructions one trace may cover. Long enough to
/// swallow the unrolled arithmetic bodies the backend emits, short
/// enough that the scoreboard guard (`last issue < other warps' ready
/// cycle`) still passes routinely in multi-warp kernels.
pub const TRACE_MAX: usize = 32;

/// A trace shorter than this is not worth the dispatch bookkeeping —
/// the interpreter already handles single instructions at full speed.
pub const TRACE_MIN: usize = 2;

/// Pre-resolved execute semantics of one traceable instruction — the
/// operand mapping [`super::core::Core::exec`] recomputes per issue,
/// done once at trace-build time.
#[derive(Clone, Copy, Debug)]
pub enum TraceKind {
    Nop,
    /// `rd = imm`.
    Li,
    /// `rd = rs1`.
    Mov,
    /// Integer ALU, register form: `rd = rs1 <op> rs2`.
    BinI(BinOp),
    /// Integer ALU, immediate form: `rd = rs1 <op> imm`.
    BinImm(BinOp),
    /// Integer compare: `rd = (rs1 <pred> rs2) as u32`.
    CmpI(ICmp),
    /// Float ALU: `rd = rs1 <op> rs2` over f32 bit patterns.
    BinF(BinOp),
    /// Float/SFU unary: `rd = <op>(rs1)`.
    UnF(UnOp),
    /// Float compare: `rd = (rs1 <pred> rs2) as u32`.
    CmpF(FCmp),
    /// Conditional move: `if rs1 != 0 { rd = rs2 }`.
    Cmov,
}

/// One decoded instruction inside a trace.
#[derive(Clone, Copy, Debug)]
pub struct TraceOp {
    pub pc: u32,
    pub inst: MachInst,
    pub kind: TraceKind,
    /// The target's issue cost for this op's class, resolved at build
    /// time (traceable classes never adjust their cost dynamically).
    pub cost: u64,
}

/// A decoded straight-line region starting at `ops[0].pc`, always at
/// least [`TRACE_MIN`] ops long.
#[derive(Clone, Debug)]
pub struct Trace {
    pub ops: Vec<TraceOp>,
    /// Sum of all op costs: the dispatched warp's `stall_until` is
    /// `dispatch_cycle + total_cost`, exactly as if the interpreter had
    /// executed each op back-to-back.
    pub total_cost: u64,
    /// Cost of the final op — `total_cost - last_cost` is the offset of
    /// the trace's *last issue cycle*, the quantity the scoreboard
    /// guard compares against the other warps' readiness.
    pub last_cost: u64,
    /// PC of the first instruction after the trace.
    pub end_pc: u32,
    /// Class of the final op (the dispatched warp's `last_class`).
    pub last_class: OpClass,
}

/// Decode one instruction to its trace semantics, or `None` if it may
/// not appear in a trace (control flow, memory, vx, system — anything
/// that can trap, touch shared or scheduler state, or move a mask).
fn decode(inst: MachInst) -> Option<TraceKind> {
    let kind = match inst.op {
        Op::NOP => TraceKind::Nop,
        Op::LI => TraceKind::Li,
        Op::MOV => TraceKind::Mov,
        Op::ADD => TraceKind::BinI(BinOp::Add),
        Op::SUB => TraceKind::BinI(BinOp::Sub),
        Op::MUL => TraceKind::BinI(BinOp::Mul),
        Op::DIV => TraceKind::BinI(BinOp::SDiv),
        Op::DIVU => TraceKind::BinI(BinOp::UDiv),
        Op::REM => TraceKind::BinI(BinOp::SRem),
        Op::REMU => TraceKind::BinI(BinOp::URem),
        Op::AND => TraceKind::BinI(BinOp::And),
        Op::OR => TraceKind::BinI(BinOp::Or),
        Op::XOR => TraceKind::BinI(BinOp::Xor),
        Op::SLL => TraceKind::BinI(BinOp::Shl),
        Op::SRL => TraceKind::BinI(BinOp::LShr),
        Op::SRA => TraceKind::BinI(BinOp::AShr),
        Op::MIN => TraceKind::BinI(BinOp::SMin),
        Op::MAX => TraceKind::BinI(BinOp::SMax),
        Op::ADDI => TraceKind::BinImm(BinOp::Add),
        Op::ANDI => TraceKind::BinImm(BinOp::And),
        Op::ORI => TraceKind::BinImm(BinOp::Or),
        Op::XORI => TraceKind::BinImm(BinOp::Xor),
        Op::SLLI => TraceKind::BinImm(BinOp::Shl),
        Op::SRLI => TraceKind::BinImm(BinOp::LShr),
        Op::SRAI => TraceKind::BinImm(BinOp::AShr),
        Op::SEQ => TraceKind::CmpI(ICmp::Eq),
        Op::SNE => TraceKind::CmpI(ICmp::Ne),
        Op::SLT => TraceKind::CmpI(ICmp::Slt),
        Op::SLE => TraceKind::CmpI(ICmp::Sle),
        Op::SLTU => TraceKind::CmpI(ICmp::Ult),
        Op::SGEU => TraceKind::CmpI(ICmp::Uge),
        Op::FADD => TraceKind::BinF(BinOp::FAdd),
        Op::FSUB => TraceKind::BinF(BinOp::FSub),
        Op::FMUL => TraceKind::BinF(BinOp::FMul),
        Op::FDIV => TraceKind::BinF(BinOp::FDiv),
        Op::FMIN => TraceKind::BinF(BinOp::FMin),
        Op::FMAX => TraceKind::BinF(BinOp::FMax),
        Op::FSQRT => TraceKind::UnF(UnOp::FSqrt),
        Op::FNEG => TraceKind::UnF(UnOp::FNeg),
        Op::FABS => TraceKind::UnF(UnOp::FAbs),
        Op::FEXP => TraceKind::UnF(UnOp::FExp),
        Op::FLOG => TraceKind::UnF(UnOp::FLog),
        Op::FFLOOR => TraceKind::UnF(UnOp::FFloor),
        Op::FCVTWS => TraceKind::UnF(UnOp::FpToSi),
        Op::FCVTSW => TraceKind::UnF(UnOp::SiToFp),
        Op::FMVXW => TraceKind::UnF(UnOp::FToBits),
        Op::FMVWX => TraceKind::UnF(UnOp::BitsToF),
        Op::FEQ => TraceKind::CmpF(FCmp::Oeq),
        Op::FNE => TraceKind::CmpF(FCmp::One),
        Op::FLT => TraceKind::CmpF(FCmp::Olt),
        Op::FLE => TraceKind::CmpF(FCmp::Ole),
        Op::FGT => TraceKind::CmpF(FCmp::Ogt),
        Op::FGE => TraceKind::CmpF(FCmp::Oge),
        Op::CMOV => TraceKind::Cmov,
        // Everything else — branches/jumps, LW/SW, atomics, CSRR,
        // ecall, prints, and the whole vx_* family — ends the trace.
        _ => return None,
    };
    Some(kind)
}

/// Build the maximal trace starting at `pc`, or `None` when the region
/// is shorter than [`TRACE_MIN`]. A zero-cost class (possible on a
/// custom target) is rejected: the engine advances time by at least one
/// cycle per issue, so the replay-cycle arithmetic below assumes every
/// cost ≥ 1.
fn build(pc: u32, prog: &[MachInst], costs: &CostModel) -> Option<Trace> {
    let mut ops = Vec::new();
    let mut total = 0u64;
    let mut cur = pc as usize;
    while cur < prog.len() && ops.len() < TRACE_MAX {
        let inst = prog[cur];
        let Some(kind) = decode(inst) else { break };
        let cost = costs.issue_cost(inst.op.class());
        if cost == 0 {
            break;
        }
        total += cost;
        ops.push(TraceOp {
            pc: cur as u32,
            inst,
            kind,
            cost,
        });
        cur += 1;
    }
    if ops.len() < TRACE_MIN {
        return None;
    }
    let last = ops.last().unwrap();
    Some(Trace {
        total_cost: total,
        last_cost: last.cost,
        end_pc: cur as u32,
        last_class: last.inst.op.class(),
        ops,
    })
}

/// Per-PC build state: traces are built at most once per program load.
#[derive(Clone)]
enum Slot {
    Unknown,
    /// The region at this PC is too short / not traceable — remembered
    /// so the interpreter path never pays the build scan again.
    Reject,
    Cached(Trace),
}

/// Per-core trace cache, indexed by PC. Private core state — the
/// parallel tick engine composes with it without any new locks —
/// invalidated whenever the core is pointed at a (potentially) new
/// program ([`super::core::Core::reset`], called from `Gpu::load`-built
/// cores at every run start).
#[derive(Default)]
pub struct TraceCache {
    slots: Vec<Slot>,
}

impl TraceCache {
    pub fn new() -> TraceCache {
        TraceCache { slots: Vec::new() }
    }

    /// Drop every cached trace (program about to change).
    pub fn invalidate(&mut self) {
        self.slots.clear();
    }

    /// The cached trace starting at `pc`, building it on first query.
    /// `None` means "use the interpreter for this PC".
    pub fn plan(&mut self, pc: u32, prog: &[MachInst], costs: &CostModel) -> Option<&Trace> {
        if self.slots.len() != prog.len() {
            // First query since load/reset: size the table to the
            // program (one-time allocation, not per-tick).
            self.slots.clear();
            self.slots.resize(prog.len(), Slot::Unknown);
        }
        let idx = pc as usize;
        if idx >= self.slots.len() {
            return None;
        }
        if matches!(self.slots[idx], Slot::Unknown) {
            self.slots[idx] = match build(pc, prog, costs) {
                Some(t) => Slot::Cached(t),
                None => Slot::Reject,
            };
        }
        match &self.slots[idx] {
            Slot::Cached(t) => Some(t),
            _ => None,
        }
    }
}

/// Execute every op of `trace` for all `nt` lanes of `w` (dispatch
/// requires the full mask, so the lane set is exactly `0..nt`).
/// Architectural effects only — the caller updates `pc`/`stall_until`/
/// `last_class` and the stats counters.
pub fn exec_trace(trace: &Trace, w: &mut Warp, nt: usize) {
    for op in &trace.ops {
        let inst = op.inst;
        match op.kind {
            TraceKind::Nop => {}
            TraceKind::Li => {
                for l in 0..nt {
                    write_reg(&mut w.regs[l], inst.rd, inst.imm as u32);
                }
            }
            TraceKind::Mov => {
                for l in 0..nt {
                    let v = read_reg(&w.regs[l], inst.rs1);
                    write_reg(&mut w.regs[l], inst.rd, v);
                }
            }
            TraceKind::BinI(bop) => {
                for l in 0..nt {
                    let a = read_reg(&w.regs[l], inst.rs1);
                    let b = read_reg(&w.regs[l], inst.rs2);
                    write_reg(&mut w.regs[l], inst.rd, scalar::bin_i(bop, a, b));
                }
            }
            TraceKind::BinImm(bop) => {
                for l in 0..nt {
                    let a = read_reg(&w.regs[l], inst.rs1);
                    write_reg(&mut w.regs[l], inst.rd, scalar::bin_i(bop, a, inst.imm as u32));
                }
            }
            TraceKind::CmpI(pred) => {
                for l in 0..nt {
                    let a = read_reg(&w.regs[l], inst.rs1);
                    let b = read_reg(&w.regs[l], inst.rs2);
                    write_reg(&mut w.regs[l], inst.rd, scalar::icmp(pred, a, b) as u32);
                }
            }
            TraceKind::BinF(bop) => {
                for l in 0..nt {
                    let a = f32::from_bits(read_reg(&w.regs[l], inst.rs1));
                    let b = f32::from_bits(read_reg(&w.regs[l], inst.rs2));
                    write_reg(&mut w.regs[l], inst.rd, scalar::bin_f(bop, a, b).to_bits());
                }
            }
            TraceKind::UnF(uop) => {
                for l in 0..nt {
                    let a = read_reg(&w.regs[l], inst.rs1);
                    write_reg(&mut w.regs[l], inst.rd, scalar::un(uop, a));
                }
            }
            TraceKind::CmpF(pred) => {
                for l in 0..nt {
                    let a = f32::from_bits(read_reg(&w.regs[l], inst.rs1));
                    let b = f32::from_bits(read_reg(&w.regs[l], inst.rs2));
                    write_reg(&mut w.regs[l], inst.rd, scalar::fcmp(pred, a, b) as u32);
                }
            }
            TraceKind::Cmov => {
                for l in 0..nt {
                    let c = read_reg(&w.regs[l], inst.rs1);
                    if c != 0 {
                        let v = read_reg(&w.regs[l], inst.rs2);
                        write_reg(&mut w.regs[l], inst.rd, v);
                    }
                }
            }
        }
    }
}

/// One issue the engine still owes the profiler/scheduler from a
/// dispatched trace.
#[derive(Clone, Copy, Debug)]
struct Pending {
    at_cycle: u64,
    issue: Issue,
}

/// What the replay queue says about the current cycle.
pub enum ReplayTick {
    /// No burst in flight — run the normal issue path.
    Idle,
    /// A traced instruction "issues" this cycle: report it exactly as
    /// the interpreter would have (its effects already committed at
    /// dispatch).
    Issue(Issue),
    /// Mid-burst gap cycle: the bursting warp is the earliest-ready
    /// warp on this core (scoreboard guard), so no scan is needed —
    /// the core reports no-issue, exactly like the interpreter.
    Wait,
}

/// The cycle-exact issue schedule of a dispatched trace. At most one
/// burst is in flight per core (dispatch only happens from the normal
/// issue path, which this queue preempts until drained). The backing
/// `Vec` is reused across bursts — no steady-state allocation.
#[derive(Default)]
pub struct ReplayQueue {
    q: Vec<Pending>,
    head: usize,
}

impl ReplayQueue {
    pub fn new() -> ReplayQueue {
        ReplayQueue::default()
    }

    pub fn is_empty(&self) -> bool {
        self.head >= self.q.len()
    }

    pub fn clear(&mut self) {
        self.q.clear();
        self.head = 0;
    }

    /// Queue the post-dispatch issues of `trace`: the op at index 0
    /// issues at the dispatch cycle itself (returned directly by
    /// `exec`), ops `1..` replay at their exact interpreter cycles —
    /// each issue follows the previous by that op's cost (every cost is
    /// ≥ 1, so consecutive issue cycles are strictly increasing and the
    /// single-issue-per-core-per-cycle rule is preserved).
    pub fn schedule(&mut self, warp: u32, dispatch_cycle: u64, trace: &Trace) {
        self.clear();
        let mut at = dispatch_cycle;
        for (i, op) in trace.ops.iter().enumerate() {
            at += op.cost;
            if i + 1 < trace.ops.len() {
                let next = trace.ops[i + 1];
                self.q.push(Pending {
                    at_cycle: at,
                    issue: Issue {
                        warp,
                        pc: next.pc,
                        cost: next.cost,
                    },
                });
            }
        }
    }

    /// Earliest cycle a pending issue is due (the core's
    /// `next_ready` floor while a burst is in flight).
    pub fn next_cycle(&self) -> Option<u64> {
        self.q.get(self.head).map(|p| p.at_cycle)
    }

    /// PC the engine should report for warp `wi` in hang diagnostics:
    /// mid-burst, the interpreter's `w.pc` would sit at the next
    /// unexecuted op — which is the pending head.
    pub fn pending_pc(&self, wi: usize) -> Option<u32> {
        self.q
            .get(self.head)
            .filter(|p| p.issue.warp as usize == wi)
            .map(|p| p.issue.pc)
    }

    /// Advance the replay by one engine step at `cycle`.
    pub fn tick(&mut self, cycle: u64) -> ReplayTick {
        let Some(p) = self.q.get(self.head) else {
            return ReplayTick::Idle;
        };
        // The engine can never skip past a pending issue: `next_cycle`
        // participates in the event-skip minimum.
        debug_assert!(p.at_cycle >= cycle, "replay issue missed its cycle");
        if p.at_cycle > cycle {
            return ReplayTick::Wait;
        }
        let issue = p.issue;
        self.head += 1;
        if self.head >= self.q.len() {
            // Burst drained: reset indices, keep the Vec's capacity.
            self.clear();
        }
        ReplayTick::Issue(issue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mi(op: Op, rd: u8, rs1: u8, rs2: u8, imm: i32) -> MachInst {
        MachInst {
            op,
            rd,
            rs1,
            rs2,
            imm,
        }
    }

    fn costs() -> CostModel {
        CostModel::vortex()
    }

    #[test]
    fn formation_stops_at_non_traceable_ops() {
        // add, addi, <branch>, mul, ecall — the trace from pc 0 must
        // cover exactly the two leading ALU ops.
        let prog = vec![
            mi(Op::ADD, 5, 6, 7, 0),
            mi(Op::ADDI, 5, 5, 0, 3),
            mi(Op::BEQZ, 0, 5, 0, 9),
            mi(Op::MUL, 5, 5, 5, 0),
            mi(Op::ECALL, 0, 0, 0, 0),
        ];
        let t = build(0, &prog, &costs()).expect("two ALU ops form a trace");
        assert_eq!(t.ops.len(), 2);
        assert_eq!(t.end_pc, 2);
        assert_eq!(t.total_cost, 2, "two ALU ops at cost 1 each");
        assert_eq!(t.last_cost, 1);
        assert_eq!(t.last_class, OpClass::Alu);
        // From the branch itself: nothing.
        assert!(build(2, &prog, &costs()).is_none());
        // From the lone MUL before ecall: below TRACE_MIN.
        assert!(build(3, &prog, &costs()).is_none());
    }

    #[test]
    fn formation_stops_at_memory_and_vx() {
        for stopper in [
            mi(Op::LW, 5, 6, 0, 0),
            mi(Op::SW, 0, 6, 5, 0),
            mi(Op::AMOADD, 5, 6, 7, 0),
            mi(Op::BAR, 0, 6, 0, 0),
            mi(Op::SPLIT, 0, 5, 0, 0),
            mi(Op::JOIN, 0, 0, 0, 0),
            mi(Op::TMC, 0, 5, 0, 0),
            mi(Op::PRED, 0, 5, 6, 9),
            mi(Op::CSRR, 5, 0, 0, 0),
            mi(Op::J, 0, 0, 0, 0),
            mi(Op::WSPAWN, 0, 5, 0, 4),
        ] {
            let prog = vec![
                mi(Op::ADDI, 5, 5, 0, 1),
                mi(Op::ADDI, 6, 6, 0, 2),
                stopper,
                mi(Op::ADDI, 7, 7, 0, 3),
            ];
            let t = build(0, &prog, &costs()).unwrap();
            assert_eq!(t.ops.len(), 2, "trace must stop at {:?}", stopper.op);
            assert_eq!(t.end_pc, 2);
        }
    }

    #[test]
    fn costs_accumulate_per_class() {
        // addi (alu=1), mul (mul=3), fadd (fpu=4): total 8, last 4.
        let prog = vec![
            mi(Op::ADDI, 5, 5, 0, 1),
            mi(Op::MUL, 6, 5, 5, 0),
            mi(Op::FADD, 7, 6, 6, 0),
            mi(Op::ECALL, 0, 0, 0, 0),
        ];
        let t = build(0, &prog, &costs()).unwrap();
        assert_eq!(t.ops.len(), 3);
        assert_eq!(t.total_cost, 1 + 3 + 4);
        assert_eq!(t.last_cost, 4);
        assert_eq!(t.last_class, OpClass::Fpu);
    }

    #[test]
    fn trace_caps_at_max_len() {
        let prog = vec![mi(Op::ADDI, 5, 5, 0, 1); TRACE_MAX + 10];
        let t = build(0, &prog, &costs()).unwrap();
        assert_eq!(t.ops.len(), TRACE_MAX);
        assert_eq!(t.end_pc, TRACE_MAX as u32);
    }

    #[test]
    fn cache_builds_once_and_rejects_sticky() {
        let prog = vec![
            mi(Op::ADDI, 5, 5, 0, 1),
            mi(Op::ADDI, 6, 6, 0, 2),
            mi(Op::ECALL, 0, 0, 0, 0),
        ];
        let mut cache = TraceCache::new();
        let len = cache.plan(0, &prog, &costs()).map(|t| t.ops.len());
        assert_eq!(len, Some(2));
        // Rejected PC stays rejected without a rebuild scan.
        assert!(cache.plan(2, &prog, &costs()).is_none());
        assert!(cache.plan(2, &prog, &costs()).is_none());
        // Out-of-range PC is a plain miss.
        assert!(cache.plan(99, &prog, &costs()).is_none());
        cache.invalidate();
        assert_eq!(cache.plan(0, &prog, &costs()).map(|t| t.ops.len()), Some(2));
    }

    #[test]
    fn exec_trace_matches_scalar_semantics() {
        let prog = vec![
            mi(Op::LI, 5, 0, 0, 21),
            mi(Op::ADDI, 6, 5, 0, 4),     // x6 = 25
            mi(Op::MUL, 7, 5, 6, 0),      // x7 = 525
            mi(Op::SLT, 8, 5, 6, 0),      // x8 = 1
            mi(Op::CMOV, 9, 8, 7, 0),     // x9 = 525 (cond true)
            mi(Op::ADDI, 0, 5, 0, 1),     // write to x0 discarded
            mi(Op::ECALL, 0, 0, 0, 0),
        ];
        let t = build(0, &prog, &costs()).unwrap();
        assert_eq!(t.ops.len(), 6);
        let nt = 4usize;
        let mut w = Warp::for_tests(nt as u32);
        exec_trace(&t, &mut w, nt);
        for l in 0..nt {
            assert_eq!(read_reg(&w.regs[l], 5), 21, "lane {l}");
            assert_eq!(read_reg(&w.regs[l], 6), 25, "lane {l}");
            assert_eq!(read_reg(&w.regs[l], 7), 525, "lane {l}");
            assert_eq!(read_reg(&w.regs[l], 8), 1, "lane {l}");
            assert_eq!(read_reg(&w.regs[l], 9), 525, "lane {l}");
            assert_eq!(read_reg(&w.regs[l], 0), 0, "x0 must stay zero");
        }
    }

    #[test]
    fn replay_schedule_is_cycle_exact() {
        // addi(1), mul(3), fadd(4) dispatched at cycle 10: the addi
        // issue is returned by exec itself; the mul replays at 11
        // (10+1), the fadd at 14 (11+3); drained after 18 (14+4) —
        // which is exactly dispatch + total_cost.
        let prog = vec![
            mi(Op::ADDI, 5, 5, 0, 1),
            mi(Op::MUL, 6, 5, 5, 0),
            mi(Op::FADD, 7, 6, 6, 0),
            mi(Op::ECALL, 0, 0, 0, 0),
        ];
        let t = build(0, &prog, &costs()).unwrap();
        let mut rq = ReplayQueue::new();
        rq.schedule(3, 10, &t);
        assert!(!rq.is_empty());
        assert_eq!(rq.next_cycle(), Some(11));
        assert_eq!(rq.pending_pc(3), Some(1));
        assert_eq!(rq.pending_pc(2), None, "wrong warp index");
        assert!(matches!(rq.tick(10), ReplayTick::Wait));
        match rq.tick(11) {
            ReplayTick::Issue(i) => {
                assert_eq!((i.warp, i.pc, i.cost), (3, 1, 3));
            }
            _ => panic!("mul must issue at cycle 11"),
        }
        assert_eq!(rq.next_cycle(), Some(14));
        assert_eq!(rq.pending_pc(3), Some(2));
        assert!(matches!(rq.tick(12), ReplayTick::Wait));
        assert!(matches!(rq.tick(13), ReplayTick::Wait));
        match rq.tick(14) {
            ReplayTick::Issue(i) => {
                assert_eq!((i.warp, i.pc, i.cost), (3, 2, 4));
            }
            _ => panic!("fadd must issue at cycle 14"),
        }
        assert!(rq.is_empty(), "burst drained after the last issue");
        assert!(matches!(rq.tick(15), ReplayTick::Idle));
    }
}
