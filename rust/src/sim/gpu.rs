//! Top-level GPU: cores + shared L2 + global memory + the tick loop.
//!
//! The tick loop has two interchangeable engines: the sequential loop
//! (cores stepped in index order within each cycle) and a parallel one
//! ([`SimConfig::threads`] > 1) that steps cores on a pool of worker
//! threads under a deterministic cycle barrier. Determinism rests on a
//! commit-order rule: within one cycle a worker touches only its own
//! core's state, and every shared-state effect (GlobalMem, L2, atomics,
//! sanitizer reports) is deferred and applied in core-index order at
//! the barrier — so both engines are bit-identical in cycles, results,
//! stats, profiler ledgers and sanitizer reports (`docs/PARALLELISM.md`).

use super::core::{Core, Issue, StepOutcome};
use super::fault::{FaultPlan, FaultState};
use super::mem::{Cache, GlobalMem, ShadowLocal};
use super::trace::ReplayTick;
use super::{SimConfig, SimError, SimStats, TrapKind};
use crate::backend::emit::ProgramImage;
use crate::backend::isa::{MachInst, OpClass};
use crate::ir::Loc;
use crate::prof::counters::Profiler;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

pub struct Gpu {
    pub cfg: SimConfig,
    pub cores: Vec<Core>,
    pub mem: GlobalMem,
    pub l2: Option<Cache>,
    pub program: Vec<MachInst>,
    pub image_args_addr: u32,
    pub heap_next: u32,
    /// The image's pc→source-location table, retained so runtime traps
    /// and sanitizer reports can name the offending source line.
    pub pc_loc: Vec<Option<Loc>>,
    /// Fault-injection state ([`SimConfig::faults`]). Device-lifetime,
    /// one-shot: faults are consumed across runs and deliberately NOT
    /// re-armed by [`Gpu::restore`], so a launch-retry loop observes
    /// each scheduled fault exactly once.
    pub faults: FaultState,
    /// What the device is running, for trap messages ("kernel 'sgemm'
    /// exceeded max cycles ..."). Defaults to the image's kernel name;
    /// the runtime overwrites it per launch.
    pub label: String,
}

/// Everything a launch can mutate, captured before the run so a failed
/// or retried launch replays from bit-identical state: global-memory
/// segment bytes, per-core local scratchpads, the L1/L2 tag state
/// (caches persist across launches on one device) and the heap bump
/// pointer. Deliberately excludes [`Gpu::faults`] (one-shot by design)
/// — per-warp state is rebuilt by `Core::reset` at every run start.
pub struct GpuSnapshot {
    segs: Vec<Vec<u8>>,
    locals: Vec<Vec<u8>>,
    l1: Vec<Cache>,
    l2: Option<Cache>,
    heap_next: u32,
}

/// Append the source line (when the image's line table has one for the
/// faulting pc) to a trap message, so "store fault at 0x..." points at
/// the kernel line instead of only a machine pc.
fn locate(pc_loc: &[Option<Loc>], mut e: SimError) -> SimError {
    if let Some(loc) = pc_loc.get(e.pc as usize).copied().flatten() {
        e.msg = format!("{} (source line {})", e.msg, loc.line);
    }
    e
}

impl Gpu {
    /// Load a program image onto a freshly configured device. The
    /// image's address map overrides the configured one: the memory the
    /// emitter laid out and the memory the cores decode are always the
    /// same map.
    pub fn load(image: &ProgramImage, cfg: SimConfig) -> Gpu {
        let mut cfg = cfg;
        cfg.addr_map = image.addr_map;
        let map = cfg.addr_map;
        let mut mem = GlobalMem::default();
        // Data segment covers data_base .. data_end (+ slack for runtime).
        let data_size = (image.data_end - map.data_base).max(4096) + 4096;
        mem.add_segment(map.data_base, data_size);
        mem.add_segment(map.stack_base, cfg.total_threads() * map.stack_size);
        mem.add_segment(map.heap_base, cfg.heap_bytes);
        for (addr, bytes) in &image.data {
            mem.write_bytes(*addr, bytes).expect("image data fits");
        }
        let mut cores: Vec<Core> = (0..cfg.num_cores).map(|i| Core::new(&cfg, i)).collect();
        if cfg.sanitize {
            // The shadow's out-of-bounds line is the image's declared
            // local extent, not the hardware window size.
            let extent = (image.local_mem_size as usize).min(cfg.local_mem_bytes as usize);
            for c in cores.iter_mut() {
                c.shadow = Some(ShadowLocal::new(extent));
            }
        }
        Gpu {
            cfg,
            cores,
            mem,
            l2: cfg.l2.map(Cache::new),
            program: image.code.clone(),
            image_args_addr: image.args_addr,
            // A small guard gap: speculative reads just before the first
            // allocation (flattened selects evaluate both arms) stay in
            // bounds.
            heap_next: map.heap_base + 4096,
            pc_loc: image.pc_loc.clone(),
            faults: FaultState::new(cfg.faults),
            label: image.kernel.clone(),
        }
    }

    /// Capture the launch-mutable state (see [`GpuSnapshot`]).
    pub fn snapshot(&self) -> GpuSnapshot {
        GpuSnapshot {
            segs: self.mem.segs.iter().map(|s| s.data.clone()).collect(),
            locals: self.cores.iter().map(|c| c.local.clone()).collect(),
            l1: self.cores.iter().map(|c| c.l1.clone()).collect(),
            l2: self.l2.clone(),
            heap_next: self.heap_next,
        }
    }

    /// Roll back to a snapshot taken on this device. Segment/core shapes
    /// never change after `load`, so this is a straight byte copy.
    pub fn restore(&mut self, snap: &GpuSnapshot) {
        for (seg, bytes) in self.mem.segs.iter_mut().zip(snap.segs.iter()) {
            seg.data.clone_from(bytes);
        }
        for ((core, local), l1) in self
            .cores
            .iter_mut()
            .zip(snap.locals.iter())
            .zip(snap.l1.iter())
        {
            core.local.clone_from(local);
            core.l1 = l1.clone();
        }
        self.l2.clone_from(&snap.l2);
        self.heap_next = snap.heap_next;
    }

    /// Per-warp state dump for hang diagnostics: every live warp's pc,
    /// source line (when the line table has one) and parked/active flag.
    fn hang_report(&self) -> String {
        hang_report_cores(self.cores.iter(), &self.pc_loc)
    }

    /// Simple bump allocator over the heap segment (host runtime helper).
    pub fn alloc(&mut self, size: u32) -> u32 {
        let addr = self.heap_next;
        self.heap_next += (size + 63) & !63;
        assert!(
            self.heap_next - self.cfg.addr_map.heap_base <= self.cfg.heap_bytes,
            "device heap exhausted"
        );
        addr
    }

    /// Run the loaded program to completion: every core starts warp 0 at
    /// pc 0 (the crt0), per the Vortex launch contract.
    pub fn run(&mut self) -> Result<SimStats, SimError> {
        self.run_profiled(None)
    }

    /// [`Gpu::run`] with an optional profiler attached. The profiler is a
    /// pure observer: it never feeds back into scheduling, so the cycle
    /// count and all device state are bit-identical with it on or off
    /// (guarded by `rust/tests/prof_api.rs`). Per core, every simulated
    /// cycle is attributed to exactly one category — an issue or one
    /// [`crate::prof::counters::StallReason`] — so the recorded breakdown
    /// sums to the total cycle count.
    pub fn run_profiled(
        &mut self,
        prof: Option<&mut Profiler>,
    ) -> Result<SimStats, SimError> {
        // Feature audit, once per run instead of per issued instruction:
        // an opcode outside the device's declared feature set is a trap,
        // not an instruction — a compiler bug (or an image built for a
        // richer target) is a loud typed error before any cycle runs,
        // never silently wrong results.
        for (pc, inst) in self.program.iter().enumerate() {
            if !self.cfg.features.supports_op(inst.op) {
                let gate = crate::target::Features::gate_name(inst.op).unwrap_or("?");
                // Fatal, not IllegalInst: detected statically before any
                // cycle runs, so no retry could ever clear it.
                return Err(locate(
                    &self.pc_loc,
                    SimError::fatal(
                        0,
                        0,
                        pc as u32,
                        format!(
                            "illegal instruction '{}': device does not implement the \
                             '{gate}' extension (image/target mismatch?)",
                            inst.op.mnemonic()
                        ),
                    ),
                ));
            }
        }
        let mut stats = SimStats::default();
        for c in self.cores.iter_mut() {
            c.reset(&self.cfg);
        }
        // Reset per-run cache state is implicit (new caches per load); for
        // repeated runs, rebuild via `Gpu::load`.
        //
        // Engine selection: the parallel loop pays a per-cycle barrier,
        // so it only engages with >1 worker and >1 core. An armed fault
        // plan forces the sequential engine — one-shot faults are
        // consumed in (cycle, core, warp) issue order, and the compute
        // phase would need the real injector state to preserve that
        // order exactly; the sequential path is the semantics of record.
        let workers = super::effective_threads(self.cfg.threads).min(self.cores.len());
        let cycle = if workers > 1 && !self.faults.armed() {
            self.run_ticks_parallel(workers, &mut stats, prof)?
        } else {
            self.run_ticks_sequential(&mut stats, prof)?
        };
        stats.cycles = cycle;
        for r in stats.sanitize_reports.iter_mut() {
            r.line = self
                .pc_loc
                .get(r.pc as usize)
                .copied()
                .flatten()
                .map(|l| l.line);
        }
        Ok(stats)
    }

    /// The classic tick loop: cores stepped in index order within each
    /// simulated cycle. Returns the final cycle count.
    fn run_ticks_sequential(
        &mut self,
        stats: &mut SimStats,
        mut prof: Option<&mut Profiler>,
    ) -> Result<u64, SimError> {
        // No-alloc-per-tick invariant: everything the loop needs per
        // cycle lives in buffers hoisted here (`issued`) or reused
        // inside Core (`lanes_buf`, the SHFL snapshot, the replay
        // queue's recycled Vec). The only steady-state heap traffic is
        // program output (`stats.prints`) and sanitizer reports —
        // event-driven, not per-cycle. Keep it that way: interpreter
        // overhead is the sim's wall-clock bottleneck (docs/SIMJIT.md).
        let mut issued: Vec<Option<Issue>> = vec![None; self.cores.len()];
        let mut cycle: u64 = 0;
        let pc_loc = &self.pc_loc;
        loop {
            if self.cores.iter().all(|c| c.idle()) {
                break;
            }
            let mut any = false;
            for (ci, c) in self.cores.iter_mut().enumerate() {
                issued[ci] = None;
                match c.step(
                    cycle,
                    &self.program,
                    &mut self.mem,
                    &mut self.l2,
                    &self.cfg,
                    stats,
                    &mut self.faults,
                )
                .map_err(|e| locate(pc_loc, e))?
                {
                    StepOutcome::Executed(info) => {
                        any = true;
                        issued[ci] = Some(info);
                    }
                    StepOutcome::NoneReady => {}
                }
            }
            // How far time advances this iteration (preserves the exact
            // event-skip schedule of the unprofiled loop).
            let delta: u64 = if any {
                1
            } else {
                // All ready warps are stalled: skip to the next event.
                let next = self.cores.iter().filter_map(|c| c.next_ready()).min();
                match next {
                    Some(n) if n > cycle => n - cycle,
                    Some(_) => 1,
                    None => {
                        // Only barrier-parked warps remain -> deadlock.
                        if self.cores.iter().any(|c| !c.idle()) {
                            return Err(SimError {
                                core: 0,
                                warp: 0,
                                pc: 0,
                                msg: format!(
                                    "barrier deadlock: all live warps parked in kernel '{}'{}",
                                    self.label,
                                    self.hang_report()
                                ),
                                kind: TrapKind::Deadlock,
                                injected: self.faults.stuck_barrier_fired(),
                            });
                        }
                        break;
                    }
                }
            };
            if let Some(p) = prof.as_deref_mut() {
                for (ci, c) in self.cores.iter().enumerate() {
                    match &issued[ci] {
                        // delta == 1 whenever anything issued.
                        Some(info) => p.record_issue(ci, info.pc, info.cost, cycle),
                        None => p.record_stall(ci, c.stall_reason(), delta),
                    }
                    p.record_occupancy(ci, cycle, c.active_warps(), delta);
                }
            }
            cycle += delta;
            if cycle > self.cfg.max_cycles {
                return Err(SimError {
                    core: 0,
                    warp: 0,
                    pc: 0,
                    msg: format!(
                        "kernel '{}' exceeded max cycles ({}){}",
                        self.label,
                        self.cfg.max_cycles,
                        self.hang_report()
                    ),
                    kind: TrapKind::Watchdog,
                    injected: false,
                });
            }
        }
        Ok(cycle)
    }

    /// The parallel tick loop: `workers` threads (this thread included)
    /// step disjoint core subsets inside each cycle, synchronized by an
    /// epoch barrier; all shared-state effects commit in core-index
    /// order afterwards. Bit-identical to the sequential engine — see
    /// the module docs and `docs/PARALLELISM.md` for the argument.
    ///
    /// Phase split per cycle:
    /// 1. *compute* (parallel, per core): pick the issue slot via
    ///    [`Core::choose_warp`], then — only when the instruction's
    ///    class never touches shared state ([`OpClass::Mem`] is the
    ///    exact complement) — execute it against the core's own state,
    ///    accumulating stats into a per-core delta. Memory-class
    ///    instructions (and undecodable pcs) are deferred.
    /// 2. *commit* (this thread, core-index order): deferred
    ///    instructions execute against the real `GlobalMem`/L2/stats —
    ///    exactly the interleaving the sequential loop produces —
    ///    compute deltas merge, and the first error in core order wins.
    /// 3. *bookkeeping* (this thread): time advance, deadlock/watchdog
    ///    checks, profiler attribution. Core state is frozen here, so
    ///    every read equals what the sequential loop would have seen.
    fn run_ticks_parallel(
        &mut self,
        workers: usize,
        stats: &mut SimStats,
        mut prof: Option<&mut Profiler>,
    ) -> Result<u64, SimError> {
        let cfg = &self.cfg;
        let prog: &[MachInst] = &self.program;
        let pc_loc = &self.pc_loc;
        let label = &self.label;
        let mem = &mut self.mem;
        let l2 = &mut self.l2;
        let faults = &mut self.faults;
        let slots: Vec<Mutex<Slot<'_>>> = self
            .cores
            .iter_mut()
            .map(|core| {
                Mutex::new(Slot {
                    core,
                    outcome: Outcome::NoIssue,
                    delta: SimStats::default(),
                })
            })
            .collect();
        let n = slots.len();

        // Cycle barrier: the coordinator publishes the cycle, resets the
        // arrival counter and bumps the epoch (Release); workers wake on
        // the epoch change (Acquire), compute their cores, and count
        // themselves in. `u64::MAX` is the exit sentinel — stored by a
        // drop guard so every return path (including errors and panics)
        // releases the pool before the scope joins.
        let epoch = AtomicU64::new(0);
        let cycle_now = AtomicU64::new(0);
        let done = AtomicUsize::new(0);

        std::thread::scope(|scope| -> Result<u64, SimError> {
            let _release_workers = SentinelGuard { epoch: &epoch };
            for w in 1..workers {
                let slots = &slots;
                let epoch = &epoch;
                let cycle_now = &cycle_now;
                let done = &done;
                scope.spawn(move || {
                    let mut last = 0u64;
                    loop {
                        let e = wait_for_change(epoch, last);
                        if e == u64::MAX {
                            return;
                        }
                        last = e;
                        let cycle = cycle_now.load(Ordering::Relaxed);
                        for ci in (w..n).step_by(workers) {
                            compute_slot(&mut slots[ci].lock().unwrap(), cycle, prog, cfg);
                        }
                        done.fetch_add(1, Ordering::Release);
                    }
                });
            }

            // Same no-alloc-per-tick invariant as the sequential loop:
            // per-cycle scratch (`issued`, the slots' delta stats) is
            // allocated once here and reused every cycle. The dummy
            // GlobalMem/L2/FaultState built per compute_slot call are
            // allocation-free (empty segment list, `None`, empty plan).
            let mut issued: Vec<Option<Issue>> = vec![None; n];
            let mut cycle: u64 = 0;
            let mut tick: u64 = 0;
            loop {
                if slots.iter().all(|s| s.lock().unwrap().core.idle()) {
                    break;
                }
                // Publish the cycle and open the epoch.
                tick += 1;
                cycle_now.store(cycle, Ordering::Relaxed);
                done.store(0, Ordering::Relaxed);
                epoch.store(tick, Ordering::Release);
                // Coordinator doubles as worker 0.
                for ci in (0..n).step_by(workers) {
                    compute_slot(&mut slots[ci].lock().unwrap(), cycle, prog, cfg);
                }
                let mut spins = 0u32;
                while done.load(Ordering::Acquire) != workers - 1 {
                    spins += 1;
                    if spins < SPIN_BUDGET {
                        std::hint::spin_loop();
                    } else {
                        std::thread::yield_now();
                    }
                }

                // Commit in core-index order: the sequential loop's
                // exact shared-state interleaving and error precedence.
                let mut any = false;
                for (ci, slot) in slots.iter().enumerate() {
                    let mut slot = slot.lock().unwrap();
                    issued[ci] = None;
                    match std::mem::replace(&mut slot.outcome, Outcome::NoIssue) {
                        Outcome::NoIssue => {}
                        Outcome::Failed(e) => return Err(locate(pc_loc, e)),
                        Outcome::Ran(info) => {
                            any = true;
                            issued[ci] = Some(info);
                            merge_stats(stats, &mut slot.delta);
                        }
                        Outcome::Deferred(wi) => {
                            let info = slot
                                .core
                                .exec(wi, cycle, prog, mem, l2, cfg, stats, faults)
                                .map_err(|e| locate(pc_loc, e))?;
                            any = true;
                            issued[ci] = Some(info);
                        }
                    }
                }

                // Bookkeeping on frozen state (workers are parked until
                // the next epoch; slot locks are uncontended).
                let delta: u64 = if any {
                    1
                } else {
                    let next = slots
                        .iter()
                        .filter_map(|s| s.lock().unwrap().core.next_ready())
                        .min();
                    match next {
                        Some(nr) if nr > cycle => nr - cycle,
                        Some(_) => 1,
                        None => {
                            if slots.iter().any(|s| !s.lock().unwrap().core.idle()) {
                                return Err(SimError {
                                    core: 0,
                                    warp: 0,
                                    pc: 0,
                                    msg: format!(
                                        "barrier deadlock: all live warps parked in kernel '{}'{}",
                                        label,
                                        hang_report_slots(&slots, pc_loc)
                                    ),
                                    kind: TrapKind::Deadlock,
                                    injected: faults.stuck_barrier_fired(),
                                });
                            }
                            break;
                        }
                    }
                };
                if let Some(p) = prof.as_deref_mut() {
                    for (ci, slot) in slots.iter().enumerate() {
                        let slot = slot.lock().unwrap();
                        match &issued[ci] {
                            Some(info) => p.record_issue(ci, info.pc, info.cost, cycle),
                            None => p.record_stall(ci, slot.core.stall_reason(), delta),
                        }
                        p.record_occupancy(ci, cycle, slot.core.active_warps(), delta);
                    }
                }
                cycle += delta;
                if cycle > cfg.max_cycles {
                    return Err(SimError {
                        core: 0,
                        warp: 0,
                        pc: 0,
                        msg: format!(
                            "kernel '{}' exceeded max cycles ({}){}",
                            label,
                            cfg.max_cycles,
                            hang_report_slots(&slots, pc_loc)
                        ),
                        kind: TrapKind::Watchdog,
                        injected: false,
                    });
                }
            }
            Ok(cycle)
        })
    }
}

/// Iterations of `spin_loop` before a barrier wait falls back to
/// `yield_now` — keeps latency low when a hardware thread is free and
/// survives CPU oversubscription (more workers than host cores).
const SPIN_BUDGET: u32 = 128;

/// Spin-then-yield until `epoch` moves past `last`; returns the value.
fn wait_for_change(epoch: &AtomicU64, last: u64) -> u64 {
    let mut spins = 0u32;
    loop {
        let e = epoch.load(Ordering::Acquire);
        if e != last {
            return e;
        }
        spins += 1;
        if spins < SPIN_BUDGET {
            std::hint::spin_loop();
        } else {
            std::thread::yield_now();
        }
    }
}

/// What one core's compute phase produced this cycle.
enum Outcome {
    /// No warp could issue.
    NoIssue,
    /// A core-local instruction executed; its stats sit in the delta.
    Ran(Issue),
    /// A memory-class (or undecodable-pc) issue slot: warp chosen, the
    /// execute deferred to the in-order commit phase.
    Deferred(usize),
    /// The compute-phase execute trapped; raised at commit in core
    /// order so error precedence matches the sequential loop.
    Failed(SimError),
}

/// One worker-owned core plus its per-cycle scratch. The mutex is
/// uncontended by construction (a core belongs to exactly one worker
/// within a cycle; the coordinator only locks after the barrier) — it
/// exists to make the sharing safe, not to arbitrate.
struct Slot<'a> {
    core: &'a mut Core,
    outcome: Outcome,
    delta: SimStats,
}

/// One core's compute phase: choose the issue slot, then execute only
/// if the instruction cannot touch shared state. The dummy memory/L2/
/// fault-injector are never observed: non-memory instructions touch
/// neither by construction, and the parallel engine only runs with an
/// unarmed fault plan (an unarmed injector's hooks are no-ops).
fn compute_slot(slot: &mut Slot<'_>, cycle: u64, prog: &[MachInst], cfg: &SimConfig) {
    // JIT burst replay first ([`SimConfig::jit`]): entirely core-local,
    // so it runs in the compute phase on any worker. The delta must be
    // reset on the replay path too — `merge_stats` drains prints but
    // leaves counters in the source, and a stale delta from an earlier
    // cycle would double-count at commit.
    match slot.core.replay_tick(cycle) {
        ReplayTick::Issue(info) => {
            slot.delta = SimStats::default();
            slot.outcome = Outcome::Ran(info);
            return;
        }
        ReplayTick::Wait => {
            slot.outcome = Outcome::NoIssue;
            return;
        }
        ReplayTick::Idle => {}
    }
    let Some(wi) = slot.core.choose_warp(cycle, cfg) else {
        slot.outcome = Outcome::NoIssue;
        return;
    };
    let pc = slot.core.warps[wi].pc;
    let defer = match prog.get(pc as usize) {
        None => true, // "pc out of program" raises at commit, in order
        Some(inst) => inst.op.class() == OpClass::Mem,
    };
    if defer {
        slot.outcome = Outcome::Deferred(wi);
        return;
    }
    let mut no_mem = GlobalMem::default();
    let mut no_l2: Option<Cache> = None;
    let mut no_faults = FaultState::new(FaultPlan::none());
    slot.delta = SimStats::default();
    slot.outcome = match slot.core.exec(
        wi,
        cycle,
        prog,
        &mut no_mem,
        &mut no_l2,
        cfg,
        &mut slot.delta,
        &mut no_faults,
    ) {
        Ok(info) => Outcome::Ran(info),
        Err(e) => Outcome::Failed(e),
    };
}

/// Fold a compute-phase delta into the global stats. Counters are sums;
/// prints append in merge (= core-index = sequential emission) order.
/// `cycles` is deliberately untouched — the engine sets it once at the
/// end — and `sanitize_reports` only ever flow through the commit phase
/// (they come from memory-class instructions), so the append is a no-op
/// kept for shape-completeness.
fn merge_stats(into: &mut SimStats, from: &mut SimStats) {
    into.instrs += from.instrs;
    into.thread_instrs += from.thread_instrs;
    into.splits += from.splits;
    into.joins += from.joins;
    into.preds += from.preds;
    into.tmcs += from.tmcs;
    into.barriers_executed += from.barriers_executed;
    into.warp_ops += from.warp_ops;
    into.atomics += from.atomics;
    into.loads += from.loads;
    into.stores += from.stores;
    into.mem_requests += from.mem_requests;
    into.l1_hits += from.l1_hits;
    into.l1_misses += from.l1_misses;
    into.l2_hits += from.l2_hits;
    into.l2_misses += from.l2_misses;
    into.local_accesses += from.local_accesses;
    into.barrier_stall_cycles += from.barrier_stall_cycles;
    into.prints.append(&mut from.prints);
    into.sanitize_reports.append(&mut from.sanitize_reports);
}

/// Shared body of the hang diagnostics (see [`Gpu::hang_report`]).
fn hang_report_cores<'a>(
    cores: impl Iterator<Item = &'a Core>,
    pc_loc: &[Option<Loc>],
) -> String {
    let mut s = String::new();
    for c in cores {
        for (wi, w) in c.warps.iter().enumerate() {
            if !w.active {
                continue;
            }
            // Mid-trace-burst, the warp table's pc already points past
            // the trace; report the next unexecuted op instead, which
            // is where the interpreter's pc would sit.
            let pc = c.warp_report_pc(wi);
            let line = pc_loc
                .get(pc as usize)
                .copied()
                .flatten()
                .map(|l| format!(" (source line {})", l.line))
                .unwrap_or_default();
            s.push_str(&format!(
                "\n  core {} warp {}: pc {}{} [{}]",
                c.id,
                wi,
                pc,
                line,
                if w.at_barrier {
                    "parked at barrier"
                } else {
                    "active"
                }
            ));
        }
    }
    s
}

/// [`hang_report_cores`] over the parallel engine's slots (locked one
/// at a time; the pool is parked, so the locks are uncontended).
fn hang_report_slots(slots: &[Mutex<Slot<'_>>], pc_loc: &[Option<Loc>]) -> String {
    let mut s = String::new();
    for slot in slots {
        let slot = slot.lock().unwrap();
        s.push_str(&hang_report_cores(std::iter::once(&*slot.core), pc_loc));
    }
    s
}

/// Stores the exit sentinel into the barrier epoch on drop, waking and
/// retiring every parked worker — the scope join then cannot deadlock,
/// whichever path (completion, error, panic) left the coordinator loop.
struct SentinelGuard<'a> {
    epoch: &'a AtomicU64,
}

impl Drop for SentinelGuard<'_> {
    fn drop(&mut self) {
        self.epoch.store(u64::MAX, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{build_image, BackendOptions};
    use crate::frontend::{compile_kernels, FrontendOptions};
    use crate::transform::{run_middle_end, OptLevel};

    fn compile(src: &str, lvl: OptLevel) -> ProgramImage {
        let (mut m, infos) = compile_kernels(src, &FrontendOptions::default()).unwrap();
        let mut cfg = lvl.config();
        cfg.verify = true;
        run_middle_end(&mut m, &cfg);
        build_image(
            &m,
            &format!("__main_{}", infos[0].name),
            &BackendOptions {
                zicond: lvl >= OptLevel::ZiCond,
                ..Default::default()
            },
        )
        .unwrap()
    }

    /// Write launch geometry, entry pc and args into the __args block.
    fn write_args(gpu: &mut Gpu, img: &ProgramImage, grid: [u32; 3], block: [u32; 3], args: &[u32]) {
        let a = gpu.image_args_addr;
        for (i, v) in grid.iter().chain(block.iter()).enumerate() {
            gpu.mem.write_u32(a + 4 * i as u32, *v).unwrap();
        }
        let entry = img
            .func_entries
            .iter()
            .find(|(n, _)| n.starts_with("__main_"))
            .map(|(_, &pc)| pc)
            .unwrap();
        gpu.mem.write_u32(a + 24, entry).unwrap();
        for (i, v) in args.iter().enumerate() {
            gpu.mem.write_u32(a + 28 + 4 * i as u32, *v).unwrap();
        }
    }

    #[test]
    fn runs_saxpy_end_to_end() {
        let src = r#"
kernel void saxpy(global float* x, global float* y, float a, int n) {
    int i = get_global_id(0);
    if (i < n) { y[i] = a * x[i] + y[i]; }
}
"#;
        for lvl in OptLevel::LADDER {
            let img = compile(src, lvl);
            let mut gpu = Gpu::load(&img, SimConfig::default());
            let n = 100u32;
            let x = gpu.alloc(n * 4);
            let y = gpu.alloc(n * 4);
            for i in 0..n {
                gpu.mem.write_u32(x + i * 4, (i as f32).to_bits()).unwrap();
                gpu.mem.write_u32(y + i * 4, (1.0f32).to_bits()).unwrap();
            }
            write_args(&mut gpu, &img, [2, 1, 1], [64, 1, 1], &[x, y, 2.0f32.to_bits(), n]);
            let stats = gpu.run().unwrap_or_else(|e| panic!("{lvl:?}: {e}"));
            for i in 0..n {
                let got = f32::from_bits(gpu.mem.read_u32(y + i * 4).unwrap());
                assert_eq!(got, 2.0 * i as f32 + 1.0, "{lvl:?} i={i}");
            }
            // 128 work items over 2 blocks: tail lanes masked off.
            assert!(stats.instrs > 100, "{lvl:?}");
            assert!(stats.cycles > 0);
        }
    }

    /// Idle-cycle fast-forward is a wall-clock optimization only: cycle
    /// counts, all stats, and device results are bit-identical with it
    /// on or off, with and without the profiler attached (and the
    /// profiler's per-core ledgers still sum to the cycle count).
    #[test]
    fn fast_forward_bit_identical() {
        let src = r#"
kernel void rev(global int* a, int n) {
    local int tile[64];
    int l = get_local_id(0);
    int g = get_global_id(0);
    tile[l] = a[g];
    barrier(0);
    if (g < n) a[g] = tile[63 - l] + a[g] / 3;
}
"#;
        let img = compile(src, OptLevel::O3);
        let run_with = |ff: bool, profile: bool| {
            let cfg = SimConfig {
                fast_forward: ff,
                ..SimConfig::default()
            };
            let mut gpu = Gpu::load(&img, cfg);
            let a = gpu.alloc(128 * 4);
            for i in 0..128u32 {
                gpu.mem.write_u32(a + i * 4, i * 3).unwrap();
            }
            write_args(&mut gpu, &img, [2, 1, 1], [64, 1, 1], &[a, 128]);
            let mut prof = profile.then(|| {
                crate::prof::counters::Profiler::new(img.code.len(), gpu.cfg.num_cores as usize)
            });
            let stats = gpu.run_profiled(prof.as_mut()).unwrap();
            let out: Vec<u32> = (0..128).map(|i| gpu.mem.read_u32(a + i * 4).unwrap()).collect();
            (stats, out, prof)
        };
        let (s_on, out_on, _) = run_with(true, false);
        let (s_off, out_off, _) = run_with(false, false);
        assert_eq!(s_on.cycles, s_off.cycles, "fast-forward changed the cycle count");
        assert_eq!(s_on.instrs, s_off.instrs);
        assert_eq!(out_on, out_off, "fast-forward changed device results");
        // Profiled runs: identical cycles, and every core-cycle is still
        // attributed exactly once under fast-forward.
        let (s_pon, out_pon, prof_on) = run_with(true, true);
        let (s_poff, _, prof_off) = run_with(false, true);
        assert_eq!(s_pon.cycles, s_on.cycles);
        assert_eq!(s_poff.cycles, s_on.cycles);
        assert_eq!(out_pon, out_on);
        let (p_on, p_off) = (prof_on.unwrap(), prof_off.unwrap());
        for (c_on, c_off) in p_on.cores.iter().zip(p_off.cores.iter()) {
            assert_eq!(c_on.total(), s_on.cycles, "ledger must sum to cycles");
            assert_eq!(c_on.issue_cycles, c_off.issue_cycles);
            assert_eq!(c_on.stalls, c_off.stalls, "stall attribution must match");
        }
    }

    /// The trace-JIT follows the same differential discipline as
    /// fast-forward: cycles, stats, results and the profiler's per-core
    /// ledgers are bit-identical with it on or off, on both engines
    /// (sequential and parallel). The kernel mixes traceable arithmetic
    /// with barriers, shared memory and divergence, so both the fast
    /// path and every fallback edge are exercised.
    #[test]
    fn jit_bit_identical() {
        let src = r#"
kernel void rev(global int* a, int n) {
    local int tile[64];
    int l = get_local_id(0);
    int g = get_global_id(0);
    tile[l] = a[g] * 3 + (a[g] ^ l);
    barrier(0);
    if (g < n) a[g] = tile[63 - l] + a[g] / 3;
}
"#;
        let img = compile(src, OptLevel::O3);
        let run_with = |jit: bool, threads: usize| {
            let cfg = SimConfig {
                jit,
                threads,
                ..SimConfig::default()
            };
            let mut gpu = Gpu::load(&img, cfg);
            let a = gpu.alloc(128 * 4);
            for i in 0..128u32 {
                gpu.mem.write_u32(a + i * 4, i * 7).unwrap();
            }
            write_args(&mut gpu, &img, [2, 1, 1], [64, 1, 1], &[a, 128]);
            let mut prof = Some(crate::prof::counters::Profiler::new(
                img.code.len(),
                gpu.cfg.num_cores as usize,
            ));
            let stats = gpu.run_profiled(prof.as_mut()).unwrap();
            let out: Vec<u32> = (0..128).map(|i| gpu.mem.read_u32(a + i * 4).unwrap()).collect();
            (stats, out, prof.unwrap())
        };
        let (s_off, out_off, p_off) = run_with(false, 1);
        for threads in [1usize, 4] {
            let (s_on, out_on, p_on) = run_with(true, threads);
            assert_eq!(
                s_on.cycles, s_off.cycles,
                "jit changed the cycle count (threads={threads})"
            );
            assert_eq!(s_on.instrs, s_off.instrs, "threads={threads}");
            assert_eq!(s_on.thread_instrs, s_off.thread_instrs, "threads={threads}");
            assert_eq!(out_on, out_off, "jit changed device results (threads={threads})");
            for (c_on, c_off) in p_on.cores.iter().zip(p_off.cores.iter()) {
                assert_eq!(c_on.total(), s_off.cycles, "ledger must sum to cycles");
                assert_eq!(c_on.issue_cycles, c_off.issue_cycles, "threads={threads}");
                assert_eq!(c_on.stalls, c_off.stalls, "jit changed stall attribution");
            }
        }
    }

    /// The parallel tick engine follows the same differential discipline
    /// as fast-forward: any worker count is bit-identical to sequential
    /// in cycles, stats, results, prints and profiler attribution.
    #[test]
    fn threads_bit_identical() {
        let src = r#"
kernel void rev(global int* a, int n) {
    local int tile[64];
    int l = get_local_id(0);
    int g = get_global_id(0);
    tile[l] = a[g];
    barrier(0);
    if (g < n) a[g] = tile[63 - l] + a[g] / 3;
}
"#;
        let img = compile(src, OptLevel::O3);
        let run_with = |threads: usize, profile: bool| {
            let cfg = SimConfig {
                threads,
                ..SimConfig::default()
            };
            let mut gpu = Gpu::load(&img, cfg);
            let a = gpu.alloc(128 * 4);
            for i in 0..128u32 {
                gpu.mem.write_u32(a + i * 4, i * 3).unwrap();
            }
            write_args(&mut gpu, &img, [2, 1, 1], [64, 1, 1], &[a, 128]);
            let mut prof = profile.then(|| {
                crate::prof::counters::Profiler::new(img.code.len(), gpu.cfg.num_cores as usize)
            });
            let stats = gpu.run_profiled(prof.as_mut()).unwrap();
            let out: Vec<u32> = (0..128).map(|i| gpu.mem.read_u32(a + i * 4).unwrap()).collect();
            (stats, out, prof)
        };
        let (s_1, out_1, prof_1) = run_with(1, true);
        for threads in [2usize, 3, 4] {
            let (s_n, out_n, prof_n) = run_with(threads, true);
            assert_eq!(s_n.cycles, s_1.cycles, "threads={threads} changed the cycle count");
            assert_eq!(s_n.instrs, s_1.instrs, "threads={threads}");
            assert_eq!(s_n.l1_hits, s_1.l1_hits, "threads={threads}");
            assert_eq!(s_n.l2_misses, s_1.l2_misses, "threads={threads}");
            assert_eq!(s_n.local_accesses, s_1.local_accesses, "threads={threads}");
            assert_eq!(out_n, out_1, "threads={threads} changed device results");
            let (p_1, p_n) = (prof_1.as_ref().unwrap(), prof_n.as_ref().unwrap());
            for (c_1, c_n) in p_1.cores.iter().zip(p_n.cores.iter()) {
                assert_eq!(c_n.total(), s_1.cycles, "ledger must sum to cycles");
                assert_eq!(c_n.issue_cycles, c_1.issue_cycles, "threads={threads}");
                assert_eq!(c_n.stalls, c_1.stalls, "threads={threads} stall attribution");
            }
        }
        // threads == 0 resolves to the host's available parallelism and
        // stays on the same invariant.
        let (s_auto, out_auto, _) = run_with(0, false);
        assert_eq!(s_auto.cycles, s_1.cycles);
        assert_eq!(out_auto, out_1);
    }

    /// The sanitizer is a pure observer: cycle counts, stats and device
    /// results are bit-identical with it on or off, a clean kernel yields
    /// no reports, and a block-level write-write race is caught with the
    /// source line of the racing store.
    #[test]
    fn sanitize_bit_identical_and_catches_races() {
        let clean = r#"
kernel void rev(global int* a, int n) {
    local int tile[64];
    int l = get_local_id(0);
    int g = get_global_id(0);
    tile[l] = a[g];
    barrier(0);
    if (g < n) a[g] = tile[63 - l] + a[g] / 3;
}
"#;
        let img = compile(clean, OptLevel::O3);
        let run_with = |san: bool| {
            let cfg = SimConfig {
                sanitize: san,
                ..SimConfig::default()
            };
            let mut gpu = Gpu::load(&img, cfg);
            let a = gpu.alloc(128 * 4);
            for i in 0..128u32 {
                gpu.mem.write_u32(a + i * 4, i * 3).unwrap();
            }
            write_args(&mut gpu, &img, [2, 1, 1], [64, 1, 1], &[a, 128]);
            let stats = gpu.run().unwrap();
            let out: Vec<u32> = (0..128).map(|i| gpu.mem.read_u32(a + i * 4).unwrap()).collect();
            (stats, out)
        };
        let (s_on, out_on) = run_with(true);
        let (s_off, out_off) = run_with(false);
        assert_eq!(s_on.cycles, s_off.cycles, "sanitizer changed the cycle count");
        assert_eq!(s_on.instrs, s_off.instrs);
        assert_eq!(s_on.l1_hits, s_off.l1_hits);
        assert_eq!(out_on, out_off, "sanitizer changed device results");
        assert!(
            s_on.sanitize_reports.is_empty(),
            "clean kernel flagged: {:?}",
            s_on.sanitize_reports
        );
        assert!(s_off.sanitize_reports.is_empty(), "reports with sanitizer off");

        // Every thread of the block stores tile[0] in the same phase.
        let racy = r#"
kernel void racy(global int* a) {
    local int tile[64];
    int l = get_local_id(0);
    tile[0] = l;
    barrier(0);
    a[l] = tile[0];
}
"#;
        let img = compile(racy, OptLevel::O3);
        let cfg = SimConfig {
            sanitize: true,
            ..SimConfig::default()
        };
        let mut gpu = Gpu::load(&img, cfg);
        let a = gpu.alloc(64 * 4);
        write_args(&mut gpu, &img, [1, 1, 1], [64, 1, 1], &[a]);
        let stats = gpu.run().unwrap();
        assert!(
            stats
                .sanitize_reports
                .iter()
                .any(|r| r.kind == crate::sim::SanitizeKind::WriteWrite),
            "write-write race not caught: {:?}",
            stats.sanitize_reports
        );
        for r in &stats.sanitize_reports {
            assert!(r.line.is_some(), "report without a source line: {r:?}");
        }
    }

    /// Fault injection follows the same differential discipline as
    /// `fast_forward`/`sanitize`: the empty plan — and a plan whose
    /// faults never come due — is bit-identical to today in cycles,
    /// stats and results; a due fault fires deterministically.
    #[test]
    fn fault_injection_differential() {
        use crate::sim::{FaultKind, FaultPlan, TrapKind};
        let src = r#"
kernel void rev(global int* a, int n) {
    local int tile[64];
    int l = get_local_id(0);
    int g = get_global_id(0);
    tile[l] = a[g];
    barrier(0);
    if (g < n) a[g] = tile[63 - l] + a[g] / 3;
}
"#;
        let img = compile(src, OptLevel::O3);
        let run_with = |plan: FaultPlan| {
            let cfg = SimConfig {
                faults: plan,
                ..SimConfig::default()
            };
            let mut gpu = Gpu::load(&img, cfg);
            let a = gpu.alloc(128 * 4);
            for i in 0..128u32 {
                gpu.mem.write_u32(a + i * 4, i * 3).unwrap();
            }
            write_args(&mut gpu, &img, [2, 1, 1], [64, 1, 1], &[a, 128]);
            let r = gpu.run();
            let out: Vec<u32> = (0..128).map(|i| gpu.mem.read_u32(a + i * 4).unwrap()).collect();
            (r, out, gpu.faults.injected())
        };
        let (r_plain, out_plain, n_plain) = run_with(FaultPlan::none());
        let s_plain = r_plain.unwrap();
        assert_eq!(n_plain, 0);
        // A plan whose trigger cycle is past the end of the run never
        // fires and is bit-identical (the hooks are pure observers).
        let late = FaultPlan::none().with(u64::MAX / 2, FaultKind::IllegalTrap { pc: None });
        let (r_late, out_late, n_late) = run_with(late);
        let s_late = r_late.unwrap();
        assert_eq!(s_late.cycles, s_plain.cycles, "armed-but-idle plan changed cycles");
        assert_eq!(s_late.instrs, s_plain.instrs);
        assert_eq!(out_late, out_plain, "armed-but-idle plan changed results");
        assert_eq!(n_late, 0);

        // A due wildcard trap fires at the next issued instruction.
        let (r_trap, _, n_trap) = run_with(FaultPlan::none().with(0, FaultKind::IllegalTrap { pc: None }));
        let e = r_trap.unwrap_err();
        assert_eq!(e.kind, TrapKind::IllegalInst);
        assert!(e.injected, "{e}");
        assert!(e.to_string().contains("[injected]"), "{e}");
        assert_eq!(n_trap, 1);

        // A load bit flip completes the run with identical timing but
        // corrupted data — silent-corruption semantics.
        let (r_flip, out_flip, n_flip) = run_with(FaultPlan::none().with(0, FaultKind::LoadBitFlip { bit: 4 }));
        let s_flip = r_flip.unwrap();
        assert_eq!(s_flip.cycles, s_plain.cycles, "bit flip changed timing");
        assert_ne!(out_flip, out_plain, "bit flip did not corrupt results");
        assert_eq!(n_flip, 1);

        // A stuck barrier deadlocks deterministically, the trap names
        // the kernel and dumps parked warps.
        let (r_bar, _, _) = run_with(FaultPlan::none().with(0, FaultKind::StuckBarrier));
        let e = r_bar.unwrap_err();
        assert_eq!(e.kind, TrapKind::Deadlock);
        assert!(e.injected);
        assert!(e.msg.contains("barrier deadlock"), "{e}");
        assert!(e.msg.contains("parked at barrier"), "{e}");
    }

    /// The watchdog trap names the kernel and dumps per-warp state.
    #[test]
    fn watchdog_names_kernel_and_dumps_warps() {
        use crate::sim::TrapKind;
        let src = r#"
kernel void saxpy(global float* x, global float* y, float a, int n) {
    int i = get_global_id(0);
    if (i < n) { y[i] = a * x[i] + y[i]; }
}
"#;
        let img = compile(src, OptLevel::O3);
        let cfg = SimConfig {
            max_cycles: 10,
            ..SimConfig::default()
        };
        let mut gpu = Gpu::load(&img, cfg);
        let x = gpu.alloc(64 * 4);
        let y = gpu.alloc(64 * 4);
        write_args(&mut gpu, &img, [1, 1, 1], [64, 1, 1], &[x, y, 0, 64]);
        let e = gpu.run().unwrap_err();
        assert_eq!(e.kind, TrapKind::Watchdog);
        assert!(e.msg.contains("exceeded max cycles (10)"), "{e}");
        assert!(e.msg.contains("kernel '"), "{e}");
        assert!(e.msg.contains("core 0 warp 0: pc"), "{e}");
        assert!(!e.injected);
    }

    /// Snapshot/restore rolls back everything a launch mutates: a rerun
    /// from the snapshot is bit-identical to the first run (including
    /// cache state, which persists across runs).
    #[test]
    fn snapshot_restore_bit_identical_rerun() {
        let src = r#"
kernel void inc(global int* a) {
    int i = get_global_id(0);
    a[i] = a[i] + 1;
}
"#;
        let img = compile(src, OptLevel::O3);
        let mut gpu = Gpu::load(&img, SimConfig::default());
        let a = gpu.alloc(64 * 4);
        for i in 0..64u32 {
            gpu.mem.write_u32(a + i * 4, i).unwrap();
        }
        write_args(&mut gpu, &img, [1, 1, 1], [64, 1, 1], &[a]);
        let snap = gpu.snapshot();
        let s1 = gpu.run().unwrap();
        let out1: Vec<u32> = (0..64).map(|i| gpu.mem.read_u32(a + i * 4).unwrap()).collect();
        assert_eq!(out1[5], 6);
        gpu.restore(&snap);
        let back = gpu.mem.read_u32(a + 5 * 4).unwrap();
        assert_eq!(back, 5, "restore did not roll back memory");
        let s2 = gpu.run().unwrap();
        let out2: Vec<u32> = (0..64).map(|i| gpu.mem.read_u32(a + i * 4).unwrap()).collect();
        assert_eq!(s1.cycles, s2.cycles, "restored rerun not bit-identical");
        assert_eq!(s1.l1_hits, s2.l1_hits, "cache state not rolled back");
        assert_eq!(out1, out2);
    }

    /// Divergent loop (per-lane trip counts) — exercises vx_pred.
    #[test]
    fn divergent_loop_pred() {
        let src = r#"
kernel void tri(global int* out) {
    int i = get_global_id(0);
    int s = 0;
    for (int k = 0; k < i % 8; k++) { s += k; }
    out[i] = s;
}
"#;
        let img = compile(src, OptLevel::Recon);
        let mut gpu = Gpu::load(&img, SimConfig::default());
        let out = gpu.alloc(64 * 4);
        write_args(&mut gpu, &img, [1, 1, 1], [64, 1, 1], &[out]);
        let stats = gpu.run().unwrap();
        for i in 0..64u32 {
            let t = i % 8;
            let want = t * (t.saturating_sub(1)) / 2 + if t > 0 { 0 } else { 0 };
            let expect: u32 = (0..t).sum();
            let _ = want;
            assert_eq!(gpu.mem.read_u32(out + i * 4).unwrap(), expect, "i={i}");
        }
        assert!(stats.preds > 0, "divergent loop must use vx_pred");
    }

    /// Nested divergence (if inside divergent if) — exercises the IPDOM
    /// stack with nested split/join.
    #[test]
    fn nested_divergence() {
        let src = r#"
kernel void nest(global int* out) {
    int i = get_global_id(0);
    int v = 0;
    if (i % 2 == 0) {
        if (i % 4 == 0) { v = 10; } else { v = 20; }
    } else {
        v = 30;
    }
    out[i] = v;
}
"#;
        let img = compile(src, OptLevel::Recon);
        let mut gpu = Gpu::load(&img, SimConfig::default());
        let out = gpu.alloc(32 * 4);
        write_args(&mut gpu, &img, [1, 1, 1], [32, 1, 1], &[out]);
        let stats = gpu.run().unwrap();
        for i in 0..32u32 {
            let want = if i % 2 == 0 {
                if i % 4 == 0 {
                    10
                } else {
                    20
                }
            } else {
                30
            };
            assert_eq!(gpu.mem.read_u32(out + i * 4).unwrap(), want, "i={i}");
        }
        assert!(stats.splits >= 2);
        // A divergent split causes two arrivals at its join (redirect +
        // restore), a runtime-uniform one causes one: joins ∈ [splits, 2·splits].
        assert!(
            stats.joins >= stats.splits && stats.joins <= 2 * stats.splits,
            "join/split execution counts inconsistent: {stats:?}"
        );
    }

    /// Shared memory + barrier: block-wide reversal.
    #[test]
    fn shared_memory_barrier() {
        let src = r#"
kernel void rev(global int* a) {
    local int tile[64];
    int l = get_local_id(0);
    int g = get_global_id(0);
    tile[l] = a[g];
    barrier(0);
    a[g] = tile[63 - l];
}
"#;
        let img = compile(src, OptLevel::Recon);
        let mut gpu = Gpu::load(&img, SimConfig::default());
        let a = gpu.alloc(128 * 4);
        for i in 0..128u32 {
            gpu.mem.write_u32(a + i * 4, i).unwrap();
        }
        write_args(&mut gpu, &img, [2, 1, 1], [64, 1, 1], &[a]);
        let stats = gpu.run().unwrap();
        for b in 0..2u32 {
            for l in 0..64u32 {
                let got = gpu.mem.read_u32(a + (b * 64 + l) * 4).unwrap();
                assert_eq!(got, b * 64 + (63 - l), "b={b} l={l}");
            }
        }
        assert!(stats.barriers_executed > 0);
        assert!(stats.local_accesses > 0);
    }

    /// Warp intrinsics: ballot of even lanes.
    #[test]
    fn warp_ballot_hw() {
        let src = r#"
__global__ void k(int* out) {
    int l = threadIdx.x;
    unsigned int b = __ballot(l % 2 == 0);
    out[l] = b;
}
"#;
        let (mut m, infos) = compile_kernels(
            src,
            &FrontendOptions {
                dialect: crate::frontend::Dialect::Cuda,
                warp_hw: true,
            },
        )
        .unwrap();
        let mut c = OptLevel::Recon.config();
        c.verify = true;
        run_middle_end(&mut m, &c);
        let img = build_image(
            &m,
            &format!("__main_{}", infos[0].name),
            &BackendOptions::default(),
        )
        .unwrap();
        let mut gpu = Gpu::load(&img, SimConfig::default());
        let out = gpu.alloc(32 * 4);
        write_args(&mut gpu, &img, [1, 1, 1], [32, 1, 1], &[out]);
        gpu.run().unwrap();
        for l in 0..32u32 {
            assert_eq!(gpu.mem.read_u32(out + l * 4).unwrap(), 0x5555_5555, "l={l}");
        }
    }

    /// Atomics: global histogram.
    #[test]
    fn atomic_histogram() {
        let src = r#"
kernel void hist(global int* bins, global int* data, int n) {
    int i = get_global_id(0);
    if (i < n) { atomic_add(bins + (data[i] % 4), 1); }
}
"#;
        let img = compile(src, OptLevel::Recon);
        let mut gpu = Gpu::load(&img, SimConfig::default());
        let bins = gpu.alloc(4 * 4);
        let data = gpu.alloc(64 * 4);
        for i in 0..64u32 {
            gpu.mem.write_u32(data + i * 4, i).unwrap();
        }
        write_args(&mut gpu, &img, [1, 1, 1], [64, 1, 1], &[bins, data, 64]);
        let stats = gpu.run().unwrap();
        for b in 0..4u32 {
            assert_eq!(gpu.mem.read_u32(bins + b * 4).unwrap(), 16, "bin {b}");
        }
        assert!(stats.atomics > 0);
    }

    /// uint (unsigned) semantics through div/comparison.
    #[test]
    fn cuda_grid_stride_loop() {
        let src = r#"
__global__ void fill(int* out, int n) {
    int idx = blockIdx.x * blockDim.x + threadIdx.x;
    int stride = gridDim.x * blockDim.x;
    for (int i = idx; i < n; i += stride) { out[i] = i * 3; }
}
"#;
        let (mut m, infos) = compile_kernels(
            src,
            &FrontendOptions {
                dialect: crate::frontend::Dialect::Cuda,
                warp_hw: true,
            },
        )
        .unwrap();
        let mut c = OptLevel::Recon.config();
        c.verify = true;
        run_middle_end(&mut m, &c);
        let img = build_image(
            &m,
            &format!("__main_{}", infos[0].name),
            &BackendOptions::default(),
        )
        .unwrap();
        let mut gpu = Gpu::load(&img, SimConfig::default());
        let n = 500u32;
        let out = gpu.alloc(n * 4);
        write_args(&mut gpu, &img, [2, 1, 1], [64, 1, 1], &[out, n]);
        gpu.run().unwrap();
        for i in 0..n {
            assert_eq!(gpu.mem.read_u32(out + i * 4).unwrap(), i * 3, "i={i}");
        }
    }
}
