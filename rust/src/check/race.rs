//! Barrier-phase race detection for `AddrSpace::Local` memory — the
//! GPUVerify-style two-thread reduction. The function is cut into
//! barrier-delimited *segments*; two accesses are in the same barrier
//! phase when a barrier-free path connects their segments. For affine
//! accesses (`base + Σ c·tid + Σ c·uniform + k`) we ask a Fourier–Motzkin
//! solver whether two *distinct* threads can touch overlapping words in
//! one phase; proven-disjoint pairs are silent, satisfiable ones are
//! reported. Non-affine local accesses degrade to a conservative
//! `race.may-alias`.

use super::affine::{LinExpr, Normalizer, Sym};
use super::diag::{CheckId, Diag, Severity};
use super::solver::{feasible, Constraint};
use super::CheckParams;
use crate::analysis::uniformity::Uniformity;
use crate::ir::dom::DomTree;
use crate::ir::loops::LoopInfo;
use crate::ir::{
    BinOp, BlockId, Function, GlobalId, ICmp as IcmpPred, InstId, InstKind, Intr, Module, Val,
};
use std::collections::{HashMap, HashSet};

// ---------------------------------------------------------------------------
// Segment graph
// ---------------------------------------------------------------------------

/// Barrier-free segment graph: each block is cut at its barriers; control
/// leaves a block only from its last segment, so edges run last(b) →
/// first(succ). Reachability in this graph is exactly "a barrier-free
/// execution path exists".
pub struct Segments {
    pub n: usize,
    pub first: Vec<usize>,
    pub last: Vec<usize>,
    pub seg_of: HashMap<InstId, usize>,
    reach: Vec<Vec<bool>>,
    /// (source segment, target segment) of every loop back edge.
    backedges: Vec<(usize, usize)>,
}

fn is_barrier(f: &Function, i: InstId) -> bool {
    matches!(
        f.inst(i).kind,
        InstKind::Intr {
            intr: Intr::Barrier,
            ..
        }
    )
}

impl Segments {
    pub fn build(f: &Function, dom: &DomTree) -> Segments {
        let blocks = f.rpo();
        let nb = f.blocks.len();
        let mut first = vec![usize::MAX; nb];
        let mut last = vec![usize::MAX; nb];
        let mut seg_of = HashMap::new();
        let mut n = 0usize;
        for &b in &blocks {
            first[b.idx()] = n;
            let mut cur = n;
            n += 1;
            for &i in &f.blocks[b.idx()].insts {
                seg_of.insert(i, cur);
                if is_barrier(f, i) {
                    cur = n;
                    n += 1;
                }
            }
            last[b.idx()] = cur;
        }
        let mut adj: Vec<Vec<usize>> = vec![vec![]; n];
        let mut backedges = vec![];
        for &b in &blocks {
            for s in f.succs(b) {
                if first[s.idx()] == usize::MAX {
                    continue;
                }
                adj[last[b.idx()]].push(first[s.idx()]);
                if dom.dominates(s, b) {
                    backedges.push((last[b.idx()], first[s.idx()]));
                }
            }
        }
        // Transitive closure by BFS from each segment (segment counts are
        // tiny — tens, not thousands).
        let mut reach = vec![vec![false; n]; n];
        for s in 0..n {
            let mut work = adj[s].clone();
            while let Some(t) = work.pop() {
                if !reach[s][t] {
                    reach[s][t] = true;
                    work.extend(adj[t].iter().copied());
                }
            }
        }
        Segments {
            n,
            first,
            last,
            seg_of,
            reach,
            backedges,
        }
    }

    /// Barrier-free path (or same segment).
    pub fn reaches(&self, a: usize, b: usize) -> bool {
        a == b || self.reach[a][b]
    }

    /// Same barrier phase: one can reach the other without a barrier.
    pub fn same_phase(&self, a: usize, b: usize) -> bool {
        self.reaches(a, b) || self.reaches(b, a)
    }

    /// A barrier-free path from `a` to `b` that crosses a loop back edge —
    /// the two accesses may belong to *different iterations* of a loop
    /// with no intervening barrier.
    pub fn crosses_backedge(&self, a: usize, b: usize) -> bool {
        self.backedges
            .iter()
            .any(|&(u, h)| self.reaches(a, u) && self.reaches(h, b))
    }
}

// ---------------------------------------------------------------------------
// Access collection
// ---------------------------------------------------------------------------

pub struct Access {
    pub inst: InstId,
    pub write: bool,
    pub atomic: bool,
    /// `None`: the pointer is statically Local but its base global could
    /// not be resolved (conservatively aliases every local array).
    pub g: Option<GlobalId>,
    /// Byte offset from the array base; `None` when not affine.
    pub off: Option<LinExpr>,
    pub block: BlockId,
    pub seg: usize,
}

fn ptr_is_local(m: &Module, f: &Function, v: Val) -> bool {
    let ty = match v {
        Val::G(g) => m.global_ptr_type(g),
        Val::Inst(i) => f.inst(i).ty,
        Val::Arg(a) => f.params[a as usize].ty,
        _ => return false,
    };
    matches!(ty, crate::ir::Type::Ptr(crate::ir::AddrSpace::Local))
}

pub fn collect_accesses(
    m: &Module,
    f: &Function,
    norm: &mut Normalizer,
    segs: &Segments,
) -> Vec<Access> {
    let mut out = vec![];
    for b in f.rpo() {
        for &id in &f.blocks[b.idx()].insts {
            let (ptr, write, atomic) = match &f.inst(id).kind {
                InstKind::Load { ptr } => (*ptr, false, false),
                InstKind::Store { ptr, .. } => (*ptr, true, false),
                InstKind::Intr {
                    intr: Intr::Atomic(_) | Intr::AtomicCas,
                    args,
                } => match args.first() {
                    Some(p) => (*p, true, true),
                    None => continue,
                },
                _ => continue,
            };
            match norm.local_addr(m, ptr) {
                Some((g, off)) => out.push(Access {
                    inst: id,
                    write,
                    atomic,
                    g: Some(g),
                    off,
                    block: b,
                    seg: segs.seg_of[&id],
                }),
                None => {
                    if ptr_is_local(m, f, ptr) {
                        out.push(Access {
                            inst: id,
                            write,
                            atomic,
                            g: None,
                            off: None,
                            block: b,
                            seg: segs.seg_of[&id],
                        });
                    }
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Guard extraction
// ---------------------------------------------------------------------------

/// Linear facts (each `expr ≥ 0`) known to hold at entry to block `b`:
/// conditions of dominating branches whose taken side dominates `b`,
/// restricted to branches in the same innermost loop as `b` (a guard
/// evaluated in an outer iteration scope may be stale inside an inner
/// loop).
pub fn block_guards(
    norm: &mut Normalizer,
    dom: &DomTree,
    li: &LoopInfo,
    b: BlockId,
) -> Vec<LinExpr> {
    let f = norm.f;
    let mut out = vec![];
    let mut cur = b;
    while let Some(d) = dom.idom[cur.idx()] {
        cur = d;
        if li.loop_of[d.idx()] != li.loop_of[b.idx()] {
            continue;
        }
        if let InstKind::CondBr { cond, t, f: fb } = f.inst(f.term(d)).kind {
            if t == fb {
                continue;
            }
            let t_dom = dom.dominates(t, b);
            let f_dom = dom.dominates(fb, b);
            if t_dom && !f_dom {
                cond_facts(norm, cond, true, &mut out);
            } else if f_dom && !t_dom {
                cond_facts(norm, cond, false, &mut out);
            }
        }
    }
    out
}

/// Decompose a branch condition (with polarity) into linear facts.
/// Unsupported shapes contribute nothing — dropping a fact only loses
/// precision, never soundness, for a race *checker*.
fn cond_facts(norm: &mut Normalizer, v: Val, positive: bool, out: &mut Vec<LinExpr>) {
    let i = match v {
        Val::Inst(i) => i,
        Val::I(k, _) => {
            // Constant condition: nothing useful (dead branch handled by CFG).
            let _ = k;
            return;
        }
        _ => return,
    };
    match norm.f.inst(i).kind.clone() {
        InstKind::ICmp { pred, a, b } => {
            let (la, lb) = match (norm.lin(a), norm.lin(b)) {
                (Some(x), Some(y)) => (x, y),
                _ => return,
            };
            let mut ge0 = |e: LinExpr| out.push(e);
            match (pred, positive) {
                // a < b  ⇔  b − a − 1 ≥ 0 (integers)
                (IcmpPred::Slt, true) | (IcmpPred::Sge, false) => {
                    let mut e = lb.sub(&la);
                    e.k -= 1;
                    ge0(e);
                }
                (IcmpPred::Slt, false) | (IcmpPred::Sge, true) => ge0(la.sub(&lb)),
                (IcmpPred::Sle, true) | (IcmpPred::Sgt, false) => ge0(lb.sub(&la)),
                (IcmpPred::Sle, false) | (IcmpPred::Sgt, true) => {
                    let mut e = la.sub(&lb);
                    e.k -= 1;
                    ge0(e);
                }
                (IcmpPred::Eq, true) | (IcmpPred::Ne, false) => {
                    ge0(la.sub(&lb));
                    ge0(lb.sub(&la));
                }
                // Disequalities are disjunctive — skipped (sound).
                (IcmpPred::Eq, false) | (IcmpPred::Ne, true) => {}
                // Unsigned comparisons mix signs — skipped (sound).
                (IcmpPred::Ult, _) | (IcmpPred::Uge, _) => {}
            }
        }
        InstKind::Bin { op: BinOp::And, a, b } if positive => {
            cond_facts(norm, a, true, out);
            cond_facts(norm, b, true, out);
        }
        InstKind::Bin { op: BinOp::Or, a, b } if !positive => {
            cond_facts(norm, a, false, out);
            cond_facts(norm, b, false, out);
        }
        // ¬x via `xor x, true` (the IR's boolean negation idiom).
        InstKind::Bin { op: BinOp::Xor, a, b } => {
            if b == Val::cb(true) {
                cond_facts(norm, a, !positive, out);
            } else if a == Val::cb(true) {
                cond_facts(norm, b, !positive, out);
            }
        }
        _ => {}
    }
}

// ---------------------------------------------------------------------------
// Two-thread solving
// ---------------------------------------------------------------------------

/// Variable layout: 0..3 = thread-1 tid dims, 3..6 = thread-2 tid dims,
/// then uniform symbols. In the cross-iteration scenario, instruction-
/// defined symbols are renamed apart between the two access instances
/// (loop-carried uniform values differ across iterations); argument
/// symbols stay shared (dispatch constants).
struct VarMap {
    cross: bool,
    idx: HashMap<(usize, Sym), usize>,
    n: usize,
}

impl VarMap {
    fn build(cross: bool, sides: [&[&LinExpr]; 2]) -> VarMap {
        let mut vm = VarMap {
            cross,
            idx: HashMap::new(),
            n: 6,
        };
        for (side, exprs) in sides.iter().enumerate() {
            for e in exprs.iter() {
                for &(s, _) in &e.syms {
                    let key = vm.key(side, s);
                    if !vm.idx.contains_key(&key) {
                        vm.idx.insert(key, vm.n);
                        vm.n += 1;
                    }
                }
            }
        }
        vm
    }

    fn key(&self, side: usize, s: Sym) -> (usize, Sym) {
        match s {
            Sym::Inst(_) if self.cross => (side, s),
            _ => (0, s),
        }
    }

    fn var(&self, side: usize, s: Sym) -> usize {
        self.idx[&self.key(side, s)]
    }

    fn lin(&self, e: &LinExpr, side: usize) -> Constraint {
        let mut c = Constraint::new(self.n);
        for d in 0..3 {
            c.coef[side * 3 + d] = e.tid[d];
        }
        for &(s, co) in &e.syms {
            c.coef[self.var(side, s)] += co;
        }
        c.k = e.k;
        c
    }
}

/// Can two distinct threads hit overlapping 4-byte words? `cross` renames
/// instruction symbols apart (different loop iterations).
fn may_overlap(
    off1: &LinExpr,
    g1: &[LinExpr],
    off2: &LinExpr,
    g2: &[LinExpr],
    ls: [u64; 3],
    cross: bool,
) -> bool {
    let side1: Vec<&LinExpr> = std::iter::once(off1).chain(g1.iter()).collect();
    let side2: Vec<&LinExpr> = std::iter::once(off2).chain(g2.iter()).collect();
    let vm = VarMap::build(cross, [side1.as_slice(), side2.as_slice()]);
    let mut base: Vec<Constraint> = vec![];
    for side in 0..2 {
        for d in 0..3 {
            let mut lo = Constraint::new(vm.n);
            lo.coef[side * 3 + d] = 1;
            base.push(lo); // t ≥ 0
            let mut hi = Constraint::new(vm.n);
            hi.coef[side * 3 + d] = -1;
            hi.k = ls[d] as i128 - 1;
            base.push(hi); // t ≤ ls−1
        }
    }
    for g in g1 {
        base.push(vm.lin(g, 0));
    }
    for g in g2 {
        base.push(vm.lin(g, 1));
    }
    // Overlap of the 4-byte words: |addr1 − addr2| ≤ 3.
    let c1 = vm.lin(off1, 0);
    let c2 = vm.lin(off2, 1);
    let mut dpos = Constraint::new(vm.n); // (addr1 − addr2) + 3 ≥ 0
    let mut dneg = Constraint::new(vm.n); // (addr2 − addr1) + 3 ≥ 0
    for i in 0..vm.n {
        dpos.coef[i] = c1.coef[i] - c2.coef[i];
        dneg.coef[i] = c2.coef[i] - c1.coef[i];
    }
    dpos.k = c1.k - c2.k + 3;
    dneg.k = c2.k - c1.k + 3;
    base.push(dpos);
    base.push(dneg);
    // Distinct threads: branch over dims and directions.
    for d in 0..3 {
        if ls[d] <= 1 {
            continue;
        }
        for dir in 0..2 {
            let mut cons = base.clone();
            let mut ne = Constraint::new(vm.n);
            // dir 0: t1ᵈ ≤ t2ᵈ − 1;  dir 1: t2ᵈ ≤ t1ᵈ − 1.
            ne.coef[d] = if dir == 0 { -1 } else { 1 };
            ne.coef[3 + d] = -ne.coef[d];
            ne.k = -1;
            cons.push(ne);
            if feasible(cons, vm.n) {
                return true;
            }
        }
    }
    false
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

pub fn check(
    m: &Module,
    f: &Function,
    u: &Uniformity,
    params: &CheckParams,
    kernel: &str,
    diags: &mut Vec<Diag>,
) {
    let dom = DomTree::build(f);
    let li = LoopInfo::build(f);
    let segs = Segments::build(f, &dom);
    let mut norm = Normalizer::new(f, u);
    let accesses = collect_accesses(m, f, &mut norm, &segs);
    if accesses.is_empty() {
        return;
    }
    let mut guard_cache: HashMap<BlockId, Vec<LinExpr>> = HashMap::new();
    let mut guards = |norm: &mut Normalizer, b: BlockId| -> Vec<LinExpr> {
        guard_cache
            .entry(b)
            .or_insert_with(|| block_guards(norm, &dom, &li, b))
            .clone()
    };
    let ls = params.local_size;
    let mut reported: HashSet<(InstId, InstId)> = HashSet::new();
    let mut may_alias_reported: HashSet<InstId> = HashSet::new();
    for i in 0..accesses.len() {
        for j in i..accesses.len() {
            let (a, b) = (&accesses[i], &accesses[j]);
            if !a.write && !b.write {
                continue;
            }
            // Atomic-vs-atomic on the same array is synchronization, not a
            // race.
            if a.atomic && b.atomic {
                continue;
            }
            match (a.g, b.g) {
                (Some(x), Some(y)) if x != y => continue, // distinct arrays never alias
                _ => {}
            }
            let same = segs.same_phase(a.seg, b.seg);
            let cross =
                segs.crosses_backedge(a.seg, b.seg) || segs.crosses_backedge(b.seg, a.seg);
            if !same && !cross {
                continue;
            }
            let key = if a.inst <= b.inst {
                (a.inst, b.inst)
            } else {
                (b.inst, a.inst)
            };
            if reported.contains(&key) {
                continue;
            }
            let (off_a, off_b) = match (&a.off, &b.off) {
                (Some(x), Some(y)) => (x, y),
                _ => {
                    // Non-affine: conservative may-race, one diag per
                    // offending instruction.
                    let culprit = if a.off.is_none() { a } else { b };
                    if may_alias_reported.insert(culprit.inst) {
                        let gname = culprit
                            .g
                            .map(|g| short_name(m, g))
                            .unwrap_or_else(|| "local memory".to_string());
                        diags.push(Diag {
                            id: CheckId::RaceMayAlias,
                            severity: Severity::Warning,
                            kernel: kernel.to_string(),
                            loc: f.inst(culprit.inst).loc,
                            msg: format!(
                                "local access to {} has a non-affine address; cannot \
                                 prove it race-free within its barrier phase",
                                gname
                            ),
                            notes: vec![],
                        });
                    }
                    reported.insert(key);
                    continue;
                }
            };
            let ga = guards(&mut norm, a.block);
            let gb = guards(&mut norm, b.block);
            let racy = (same && may_overlap(off_a, &ga, off_b, &gb, ls, false))
                || (cross && may_overlap(off_a, &ga, off_b, &gb, ls, true));
            if !racy {
                continue;
            }
            reported.insert(key);
            let (id, verb) = if a.write && b.write {
                (CheckId::RaceWriteWrite, "write")
            } else {
                (CheckId::RaceReadWrite, "access")
            };
            // Anchor the diagnostic on a write.
            let (w, other) = if a.write { (a, b) } else { (b, a) };
            let gname = w
                .g
                .or(other.g)
                .map(|g| short_name(m, g))
                .unwrap_or_else(|| "local memory".to_string());
            let mut notes = vec![];
            if w.inst != other.inst {
                match f.inst(other.inst).loc {
                    Some(l) => notes.push(format!(
                        "conflicting {} at line {}",
                        if other.write { "write" } else { "read" },
                        l.line
                    )),
                    None => notes.push("conflicting access in synthesized code".to_string()),
                }
            } else {
                notes.push("two threads of the workgroup execute this access".to_string());
            }
            if !same && cross {
                notes.push(
                    "the conflict spans loop iterations with no barrier in between".to_string(),
                );
            }
            diags.push(Diag {
                id,
                severity: Severity::Warning,
                kernel: kernel.to_string(),
                loc: f.inst(w.inst).loc,
                msg: format!(
                    "two threads may {} the same word of {} within one barrier phase",
                    verb, gname
                ),
                notes,
            });
        }
    }
}

fn short_name(m: &Module, g: GlobalId) -> String {
    let full = &m.globals[g.idx()].name;
    let short = full.rsplit('.').next().unwrap_or(full);
    format!("'{}'", short)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Builder, Type, Val};

    /// entry: [st, barrier, ld] → loop(header → body → header) …
    #[test]
    fn segments_split_at_barriers_and_find_backedges() {
        let mut f = Function::new("k", vec![], Type::Void);
        let header = f.add_block("h");
        let body = f.add_block("b");
        let exit = f.add_block("x");
        let (st, ld, bar);
        {
            let mut b = Builder::new(&mut f);
            let p = b.alloca(4);
            st = b.f.push_inst(
                b.cur,
                InstKind::Store {
                    ptr: p,
                    val: Val::ci(0),
                },
                Type::Void,
            );
            bar = b.f.push_inst(
                b.cur,
                InstKind::Intr {
                    intr: Intr::Barrier,
                    args: vec![],
                },
                Type::Void,
            );
            ld = b.f.push_inst(b.cur, InstKind::Load { ptr: p }, Type::I32);
            b.br(header);
            b.set_block(header);
            let c = b.icmp(crate::ir::ICmp::Slt, Val::ci(0), Val::ci(1));
            b.cond_br(c, body, exit);
            b.set_block(body);
            b.br(header);
            b.set_block(exit);
            b.ret(None);
        }
        let dom = DomTree::build(&f);
        let segs = Segments::build(&f, &dom);
        // Store is barrier-separated from the load in the same block.
        assert_ne!(segs.seg_of[&st], segs.seg_of[&ld]);
        assert_eq!(segs.seg_of[&bar], segs.seg_of[&st]);
        assert!(!segs.same_phase(segs.seg_of[&st], segs.seg_of[&ld]));
        // The load flows into the loop barrier-free.
        assert!(segs.reaches(segs.seg_of[&ld], segs.first[header.idx()]));
        // One backedge: body → header.
        assert_eq!(segs.backedges.len(), 1);
        // The loop body re-reaches itself across the backedge.
        let bseg = segs.first[body.idx()];
        assert!(segs.crosses_backedge(bseg, bseg));
        // The pre-barrier store reaches nothing outside its segment.
        assert!(!segs.reaches(segs.seg_of[&st], segs.first[header.idx()]));
    }
}
