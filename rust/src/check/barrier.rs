//! Barrier-divergence verification: a workgroup barrier that is
//! (transitively) control-dependent on a divergent branch may be reached
//! by only part of the workgroup — on Vortex hardware that is a deadlock,
//! not a diagnostic. Walks the control-dependence graph from every
//! `Intr::Barrier` and reports the nearest divergent controlling branch;
//! barriers controlled by a divergent *loop* branch (divergent trip
//! count) get their own check id.

use super::diag::{CheckId, Diag, Severity};
use crate::analysis::uniformity::Uniformity;
use crate::ir::cdg::Cdg;
use crate::ir::dom::PostDomTree;
use crate::ir::loops::LoopInfo;
use crate::ir::{BlockId, Function, InstKind, Intr};
use std::collections::HashSet;

pub fn check(f: &Function, u: &Uniformity, kernel: &str, diags: &mut Vec<Diag>) {
    let pdom = PostDomTree::build(f);
    let cdg = Cdg::build_with(f, &pdom);
    let li = LoopInfo::build(f);
    for b in f.rpo() {
        for &id in &f.blocks[b.idx()].insts {
            if !matches!(
                f.inst(id).kind,
                InstKind::Intr {
                    intr: Intr::Barrier,
                    ..
                }
            ) {
                continue;
            }
            // BFS over control-dependence edges: collect every divergent
            // branch that (transitively) decides whether this barrier runs.
            let mut seen: HashSet<BlockId> = HashSet::new();
            let mut work: Vec<BlockId> = cdg.deps[b.idx()].clone();
            let mut divergent: Vec<BlockId> = vec![];
            while let Some(d) = work.pop() {
                if !seen.insert(d) {
                    continue;
                }
                if !u.branch_uniform(d) {
                    divergent.push(d);
                }
                work.extend(cdg.deps[d.idx()].iter().copied());
            }
            if divergent.is_empty() {
                continue;
            }
            // Prefer the loop classification: a divergent exiting/latch
            // branch of a loop that contains the barrier means lanes run
            // different trip counts against the same barrier.
            let loop_branch = divergent.iter().copied().find(|&d| {
                li.is_loop_branch(f, d)
                    && li
                        .innermost(d)
                        .map(|l| l.blocks.contains(&b))
                        .unwrap_or(false)
            });
            let (check, witness) = match loop_branch {
                Some(d) => (CheckId::BarrierDivergentLoop, d),
                None => (CheckId::BarrierDivergence, divergent[0]),
            };
            let branch_loc = f.inst(f.term(witness)).loc;
            let msg = match check {
                CheckId::BarrierDivergentLoop => {
                    "barrier inside a loop with a divergent trip count: lanes \
                     exit at different iterations and desynchronize at this \
                     barrier"
                        .to_string()
                }
                _ => "barrier is control-dependent on a divergent branch: \
                      only part of the workgroup may reach it (deadlock on \
                      hardware)"
                    .to_string(),
            };
            let mut notes = vec![];
            match branch_loc {
                Some(l) => notes.push(format!("divergent branch at line {}", l.line)),
                None => notes.push("divergent branch in compiler-synthesized code".to_string()),
            }
            diags.push(Diag {
                id: check,
                severity: Severity::Warning,
                kernel: kernel.to_string(),
                loc: f.inst(id).loc,
                msg,
                notes,
            });
        }
    }
}
