//! The intentionally-broken kernel corpus (`benchmarks/buggy/`): each
//! kernel contains exactly one classic SIMT bug and is annotated with the
//! check id `volt check` must report for it. The corpus is the regression
//! net for the static verifier (every kernel fires exactly its expected
//! id) and the dynamic sanitizer (every race / bounds / uninit kernel is
//! also caught by shadow-memory tracking at simulation time).

use super::diag::CheckId;
use crate::frontend::Dialect;

/// One corpus entry.
pub struct BuggyCase {
    pub name: &'static str,
    pub source: &'static str,
    pub dialect: Dialect,
    /// The check id every diagnostic for this kernel must carry.
    pub expect: CheckId,
    /// Workgroup size the bug manifests at (checker assumption and
    /// simulator launch shape).
    pub block: [u64; 3],
}

impl BuggyCase {
    /// Whether the dynamic sanitizer is expected to catch this bug at
    /// runtime. Barrier-divergence bugs are deadlocks, not memory bugs —
    /// they are the static checker's alone.
    pub fn sanitizer_catchable(&self) -> bool {
        !matches!(
            self.expect,
            CheckId::BarrierDivergence | CheckId::BarrierDivergentLoop
        )
    }
}

macro_rules! buggy {
    ($name:literal, $expect:expr) => {
        BuggyCase {
            name: $name,
            source: include_str!(concat!("../../../benchmarks/buggy/", $name, ".cl")),
            dialect: Dialect::OpenCL,
            expect: $expect,
            block: [64, 1, 1],
        }
    };
}

/// One runtime-only corpus entry: a kernel the static verifier rightly
/// finds clean, but that traps when simulated. These exercise the runtime
/// containment path (watchdog / deadlock traps plus launch-level
/// recovery) rather than the static sweep, so they live in a separate
/// catalog from [`all`].
pub struct RuntimeBuggyCase {
    pub name: &'static str,
    pub source: &'static str,
    pub dialect: Dialect,
    /// Human-readable trap the simulator must raise ("watchdog",
    /// "deadlock").
    pub expect_trap: &'static str,
    /// Launch shape the hang manifests at.
    pub block: [u64; 3],
}

/// Every corpus kernel, in catalog order.
pub fn all() -> Vec<BuggyCase> {
    vec![
        buggy!("barrier_divergent_if", CheckId::BarrierDivergence),
        buggy!("barrier_divergent_loop", CheckId::BarrierDivergentLoop),
        buggy!("barrier_partial_lid", CheckId::BarrierDivergence),
        buggy!("race_ww_same_word", CheckId::RaceWriteWrite),
        buggy!("race_ww_mirror", CheckId::RaceWriteWrite),
        buggy!("race_rw_missing_barrier", CheckId::RaceReadWrite),
        buggy!("race_rw_loop_nobarrier", CheckId::RaceReadWrite),
        buggy!("oob_write_offby1", CheckId::BoundsLocalOob),
        buggy!("oob_read_stride", CheckId::BoundsLocalOob),
        buggy!("uninit_read", CheckId::UninitLocalRead),
    ]
}

/// Runtime-only corpus kernels: statically clean, hang or trap under
/// simulation. Disjoint from [`all`] so the static sweep stays exact.
pub fn runtime_all() -> Vec<RuntimeBuggyCase> {
    vec![RuntimeBuggyCase {
        name: "watchdog_infinite_loop",
        source: include_str!("../../../benchmarks/buggy/watchdog_infinite_loop.cl"),
        dialect: Dialect::OpenCL,
        expect_trap: "watchdog",
        block: [64, 1, 1],
    }]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{check_source, CheckParams};

    #[test]
    fn every_buggy_kernel_fires_exactly_its_expected_check() {
        for case in all() {
            let params = CheckParams {
                local_size: case.block,
            };
            let diags = check_source(case.source, case.dialect, &params)
                .unwrap_or_else(|e| panic!("{}: {}", case.name, e));
            assert!(
                !diags.is_empty(),
                "{}: expected {} but kernel came back clean",
                case.name,
                case.expect.id_str()
            );
            for d in &diags {
                assert_eq!(
                    d.id,
                    case.expect,
                    "{}: expected only {}, got {} ({})",
                    case.name,
                    case.expect.id_str(),
                    d.id.id_str(),
                    d.msg
                );
                assert!(
                    d.line().is_some(),
                    "{}: diagnostic has no source location",
                    case.name
                );
            }
        }
    }

    #[test]
    fn runtime_corpus_is_statically_clean_and_disjoint() {
        let static_names: Vec<&str> = all().iter().map(|c| c.name).collect();
        for case in runtime_all() {
            assert!(
                !static_names.contains(&case.name),
                "{}: runtime corpus entry shadows a static one",
                case.name
            );
            assert!(case.source.contains("kernel void"), "{}", case.name);
            assert!(
                !case.expect_trap.is_empty(),
                "{}: missing expected trap",
                case.name
            );
            let params = CheckParams {
                local_size: case.block,
            };
            let diags = check_source(case.source, case.dialect, &params)
                .unwrap_or_else(|e| panic!("{}: {}", case.name, e));
            assert!(
                diags.is_empty(),
                "{}: runtime-only bug must not fire static checks, got {} ({})",
                case.name,
                diags[0].id.id_str(),
                diags[0].msg
            );
        }
    }

    #[test]
    fn corpus_names_are_unique_and_sources_nonempty() {
        let cases = all();
        let mut names: Vec<&str> = cases.iter().map(|c| c.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), cases.len());
        for c in &cases {
            assert!(c.source.contains("kernel void"), "{}", c.name);
            assert!(c.source.contains("volt-check:"), "{}", c.name);
        }
    }
}
