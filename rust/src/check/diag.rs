//! Diagnostics for the `volt::check` static verifier: typed check ids,
//! severities, and rendering — both a human caret listing into the kernel
//! source (same visual language as `volt prof --annotate`) and a stable
//! JSON form for CI.

use crate::ir::Loc;
use std::fmt::Write;

/// Stable identifier of one check. The string forms (`id_str`) are the
/// public contract: tests, CI and docs key on them.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum CheckId {
    /// A workgroup barrier is control-dependent on a divergent branch.
    BarrierDivergence,
    /// A barrier sits inside a loop whose trip count is divergent.
    BarrierDivergentLoop,
    /// Two distinct threads may write the same local word in one barrier
    /// phase.
    RaceWriteWrite,
    /// A read and a write of the same local word by distinct threads in
    /// one barrier phase.
    RaceReadWrite,
    /// A local access whose address is not affine in the thread id —
    /// conservatively reported as a possible race.
    RaceMayAlias,
    /// A statically-sized local array access provably outside the array.
    BoundsLocalOob,
    /// A read of a local array no path has written first.
    UninitLocalRead,
}

impl CheckId {
    pub fn id_str(self) -> &'static str {
        match self {
            CheckId::BarrierDivergence => "barrier.divergence",
            CheckId::BarrierDivergentLoop => "barrier.divergent-loop",
            CheckId::RaceWriteWrite => "race.write-write",
            CheckId::RaceReadWrite => "race.read-write",
            CheckId::RaceMayAlias => "race.may-alias",
            CheckId::BoundsLocalOob => "bounds.local-oob",
            CheckId::UninitLocalRead => "uninit.local-read",
        }
    }

    pub fn all() -> [CheckId; 7] {
        [
            CheckId::BarrierDivergence,
            CheckId::BarrierDivergentLoop,
            CheckId::RaceWriteWrite,
            CheckId::RaceReadWrite,
            CheckId::RaceMayAlias,
            CheckId::BoundsLocalOob,
            CheckId::UninitLocalRead,
        ]
    }

    pub fn from_str(s: &str) -> Option<CheckId> {
        CheckId::all().into_iter().find(|c| c.id_str() == s)
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn label(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One finding. `loc` points into the kernel source the check ran over
/// (`None` only for compiler-synthesized code, which the checks avoid
/// reporting on where possible).
#[derive(Clone, Debug)]
pub struct Diag {
    pub id: CheckId,
    pub severity: Severity,
    /// Kernel function the finding is in.
    pub kernel: String,
    pub loc: Option<Loc>,
    pub msg: String,
    /// Secondary locations / explanations ("note: conflicting write at
    /// line 12").
    pub notes: Vec<String>,
}

impl Diag {
    pub fn line(&self) -> Option<u32> {
        self.loc.map(|l| l.line)
    }
}

/// Render diagnostics as a human listing with source carets, in the style
/// of the profiler's annotated listing.
pub fn render_text(diags: &[Diag], src: &str) -> String {
    let lines: Vec<&str> = src.lines().collect();
    let mut out = String::new();
    for d in diags {
        match d.loc {
            Some(loc) => {
                let _ = writeln!(
                    out,
                    "{}[{}] kernel '{}' line {}: {}",
                    d.severity.label(),
                    d.id.id_str(),
                    d.kernel,
                    loc.line,
                    d.msg
                );
                if loc.line >= 1 && (loc.line as usize) <= lines.len() {
                    let text = lines[loc.line as usize - 1];
                    let _ = writeln!(out, "  {:4} | {}", loc.line, text);
                    let col = if loc.col >= 1 {
                        loc.col as usize
                    } else {
                        // Point at the first non-blank character.
                        text.len() - text.trim_start().len() + 1
                    };
                    let _ = writeln!(out, "       | {}^", " ".repeat(col.saturating_sub(1)));
                }
            }
            None => {
                let _ = writeln!(
                    out,
                    "{}[{}] kernel '{}': {}",
                    d.severity.label(),
                    d.id.id_str(),
                    d.kernel,
                    d.msg
                );
            }
        }
        for n in &d.notes {
            let _ = writeln!(out, "       note: {}", n);
        }
    }
    out
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Stable JSON rendering (an array of finding objects) for `volt check
/// --json` and the CI sweep artifact.
pub fn render_json(diags: &[Diag]) -> String {
    let mut out = String::from("[");
    for (i, d) in diags.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"id\":\"{}\",\"severity\":\"{}\",\"kernel\":\"{}\",\"line\":{},\"msg\":\"{}\",\"notes\":[",
            d.id.id_str(),
            d.severity.label(),
            esc(&d.kernel),
            d.line().map(|l| l.to_string()).unwrap_or_else(|| "null".into()),
            esc(&d.msg)
        );
        for (j, n) in d.notes.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            let _ = write!(out, "\"{}\"", esc(n));
        }
        out.push_str("]}");
    }
    out.push(']');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_strings_round_trip() {
        for id in CheckId::all() {
            assert_eq!(CheckId::from_str(id.id_str()), Some(id));
        }
        assert_eq!(CheckId::from_str("nope"), None);
    }

    #[test]
    fn text_render_carets_into_source() {
        let src = "kernel void k() {\n    barrier(0);\n}\n";
        let d = Diag {
            id: CheckId::BarrierDivergence,
            severity: Severity::Warning,
            kernel: "k".into(),
            loc: Some(Loc::line(2)),
            msg: "barrier under divergent branch".into(),
            notes: vec!["branch at line 1".into()],
        };
        let t = render_text(&[d], src);
        assert!(t.contains("warning[barrier.divergence]"));
        assert!(t.contains("barrier(0);"));
        assert!(t.contains("^"));
        assert!(t.contains("note: branch at line 1"));
    }

    #[test]
    fn json_render_escapes_and_validates() {
        let d = Diag {
            id: CheckId::RaceWriteWrite,
            severity: Severity::Error,
            kernel: "we\"ird".into(),
            loc: None,
            msg: "a\\b".into(),
            notes: vec![],
        };
        let j = render_json(&[d]);
        assert!(j.starts_with('[') && j.ends_with(']'));
        assert!(j.contains("\\\"ird"));
        assert!(j.contains("a\\\\b"));
        assert!(j.contains("\"line\":null"));
        crate::prof::validate_json(&j).unwrap();
    }
}
