//! Affine normalization of values into `Σ cᵈ·tidᵈ + Σ cₛ·sym + k` form —
//! the substrate of the GPUVerify-style two-thread race reduction and the
//! static bounds pass. `tid` terms are the three local work-item id
//! dimensions (the only per-thread quantities of interest at workgroup
//! scope); `sym` terms are workgroup-uniform unknowns (kernel arguments,
//! uniform instruction results such as loop counters); everything else is
//! non-affine and falls back to conservative handling.

use crate::analysis::uniformity::Uniformity;
use crate::ir::{AddrSpace, BinOp, Function, GlobalId, InstId, InstKind, Intr, Module, Val, WorkItem};
use std::collections::HashMap;

/// A workgroup-uniform symbolic unknown.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub enum Sym {
    /// A uniform instruction result (loop counter, computed stride, the
    /// uniform group-base residual of a global id, …).
    Inst(InstId),
    /// A kernel argument (uniform across the workgroup by dispatch).
    Arg(u32),
}

/// A linear expression over the three local-id dims and uniform symbols.
/// Coefficients are i128 so byte-scaled 32-bit arithmetic can never
/// overflow during normalization.
#[derive(Clone, PartialEq, Eq, Debug, Default)]
pub struct LinExpr {
    /// Coefficient of the local id in dims x/y/z.
    pub tid: [i128; 3],
    /// Uniform symbolic terms, sorted by key, coefficients nonzero.
    pub syms: Vec<(Sym, i128)>,
    /// Constant term.
    pub k: i128,
}

impl LinExpr {
    pub fn konst(k: i128) -> LinExpr {
        LinExpr {
            k,
            ..Default::default()
        }
    }

    pub fn sym(s: Sym) -> LinExpr {
        LinExpr {
            syms: vec![(s, 1)],
            ..Default::default()
        }
    }

    pub fn tid_dim(d: usize) -> LinExpr {
        let mut e = LinExpr::default();
        e.tid[d] = 1;
        e
    }

    pub fn is_const(&self) -> bool {
        self.tid == [0, 0, 0] && self.syms.is_empty()
    }

    /// No symbolic unknowns — only tid terms and a constant (the shape the
    /// interval bounds pass can fully evaluate).
    pub fn sym_free(&self) -> bool {
        self.syms.is_empty()
    }

    pub fn coeff_of(&self, s: Sym) -> i128 {
        self.syms
            .iter()
            .find(|(t, _)| *t == s)
            .map(|(_, c)| *c)
            .unwrap_or(0)
    }

    fn add_sym(&mut self, s: Sym, c: i128) {
        match self.syms.binary_search_by(|(t, _)| t.cmp(&s)) {
            Ok(i) => {
                self.syms[i].1 += c;
                if self.syms[i].1 == 0 {
                    self.syms.remove(i);
                }
            }
            Err(i) => {
                if c != 0 {
                    self.syms.insert(i, (s, c));
                }
            }
        }
    }

    pub fn add(&self, o: &LinExpr) -> LinExpr {
        let mut r = self.clone();
        for d in 0..3 {
            r.tid[d] += o.tid[d];
        }
        for &(s, c) in &o.syms {
            r.add_sym(s, c);
        }
        r.k += o.k;
        r
    }

    pub fn scale(&self, c: i128) -> LinExpr {
        if c == 0 {
            return LinExpr::default();
        }
        let mut r = self.clone();
        for d in 0..3 {
            r.tid[d] *= c;
        }
        for t in r.syms.iter_mut() {
            t.1 *= c;
        }
        r.k *= c;
        r
    }

    pub fn sub(&self, o: &LinExpr) -> LinExpr {
        self.add(&o.scale(-1))
    }
}

/// Normalizer: maps IR values to linear expressions, memoized per
/// function. Uniformity decides which instruction results may stand as
/// opaque uniform symbols.
pub struct Normalizer<'a> {
    pub f: &'a Function,
    pub u: &'a Uniformity,
    memo: HashMap<InstId, Option<LinExpr>>,
}

impl<'a> Normalizer<'a> {
    pub fn new(f: &'a Function, u: &'a Uniformity) -> Normalizer<'a> {
        Normalizer {
            f,
            u,
            memo: HashMap::new(),
        }
    }

    /// Linear form of `v`, or `None` if the value is not affine in
    /// (tid, uniform symbols).
    pub fn lin(&mut self, v: Val) -> Option<LinExpr> {
        match v {
            Val::I(k, _) => Some(LinExpr::konst(k as i128)),
            Val::Arg(a) => Some(LinExpr::sym(Sym::Arg(a))),
            Val::F(_) | Val::G(_) => None,
            Val::Inst(i) => self.lin_inst(i),
        }
    }

    fn lin_inst(&mut self, i: InstId) -> Option<LinExpr> {
        if let Some(m) = self.memo.get(&i) {
            return m.clone();
        }
        // Break cycles (divergent phis through themselves) conservatively.
        self.memo.insert(i, None);
        let r = self.lin_inst_uncached(i);
        self.memo.insert(i, r.clone());
        r
    }

    fn lin_inst_uncached(&mut self, i: InstId) -> Option<LinExpr> {
        let inst = self.f.inst(i);
        if let InstKind::Intr { intr, args } = &inst.kind {
            match intr {
                Intr::WorkItem(WorkItem::LocalId) => {
                    let d = args.first().and_then(|a| a.as_int())?;
                    if (0..3).contains(&d) {
                        return Some(LinExpr::tid_dim(d as usize));
                    }
                    return None;
                }
                Intr::WorkItem(WorkItem::GlobalId) => {
                    // global = group·local_size + local: the group base is
                    // workgroup-uniform, so model it as tidᵈ plus an opaque
                    // uniform residual keyed by this instruction.
                    let d = args.first().and_then(|a| a.as_int())?;
                    if (0..3).contains(&d) {
                        let mut e = LinExpr::tid_dim(d as usize);
                        e.add_sym(Sym::Inst(i), 1);
                        return Some(e);
                    }
                    return None;
                }
                _ => {}
            }
        }
        // Any other uniform value is an opaque uniform symbol.
        if !self.u.inst_div[i.idx()] {
            return Some(LinExpr::sym(Sym::Inst(i)));
        }
        match &inst.kind {
            InstKind::Bin { op, a, b } => {
                let (a, b) = (*a, *b);
                match op {
                    BinOp::Add => Some(self.lin(a)?.add(&self.lin(b)?)),
                    BinOp::Sub => Some(self.lin(a)?.sub(&self.lin(b)?)),
                    BinOp::Mul => {
                        let la = self.lin(a)?;
                        let lb = self.lin(b)?;
                        if la.is_const() {
                            Some(lb.scale(la.k))
                        } else if lb.is_const() {
                            Some(la.scale(lb.k))
                        } else {
                            None
                        }
                    }
                    BinOp::Shl => {
                        let lb = self.lin(b)?;
                        if lb.is_const() && (0..31).contains(&lb.k) {
                            Some(self.lin(a)?.scale(1i128 << lb.k))
                        } else {
                            None
                        }
                    }
                    _ => None,
                }
            }
            _ => None,
        }
    }

    /// Resolve a pointer to `(local global, byte-offset linear form)`.
    /// Returns `Some((g, None))` when the pointer certainly targets local
    /// global `g` but the offset is not affine.
    pub fn local_addr(&mut self, m: &Module, mut ptr: Val) -> Option<(GlobalId, Option<LinExpr>)> {
        let mut off = LinExpr::konst(0);
        let mut affine = true;
        loop {
            match ptr {
                Val::G(g) => {
                    if m.globals[g.idx()].space != AddrSpace::Local {
                        return None;
                    }
                    return Some((g, if affine { Some(off) } else { None }));
                }
                Val::Inst(i) => match self.f.inst(i).kind.clone() {
                    InstKind::Gep {
                        base,
                        index,
                        scale,
                        disp,
                    } => {
                        match self.lin(index) {
                            Some(l) => {
                                off = off.add(&l.scale(scale as i128));
                                off.k += disp as i128;
                            }
                            None => affine = false,
                        }
                        ptr = base;
                    }
                    _ => return None,
                },
                _ => return None,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::uniformity;
    use crate::analysis::UniformityOptions;
    use crate::check::WorkgroupTti;
    use crate::ir::{Builder, Function, Global, Intr, Module, Type};

    fn analyze(m: &Module) -> Uniformity {
        uniformity::analyze(
            m,
            crate::ir::FuncId(0),
            &UniformityOptions {
                uni_hw: true,
                uni_ann: true,
                uni_func: false,
            },
            &WorkgroupTti,
        )
    }

    #[test]
    fn local_id_times_stride_plus_disp() {
        let mut m = Module::new("t");
        let g = m.add_global(Global {
            name: "k.buf".into(),
            space: AddrSpace::Local,
            size: 256,
            align: 4,
            init: None,
        });
        let mut f = Function::new("k", vec![], Type::Void);
        let gep;
        {
            let mut b = Builder::new(&mut f);
            let l = b.intr(Intr::WorkItem(WorkItem::LocalId), vec![Val::ci(0)]);
            let idx = b.add(l, Val::ci(3));
            gep = b.gep(Val::G(g), idx, 4);
            b.ret(None);
        }
        let fid = m.add_func(f);
        let u = analyze(&m);
        let f = m.func(fid);
        let mut n = Normalizer::new(f, &u);
        let (gg, off) = n.local_addr(&m, gep).unwrap();
        assert_eq!(gg, g);
        let off = off.unwrap();
        assert_eq!(off.tid, [4, 0, 0]);
        assert_eq!(off.k, 12);
        assert!(off.sym_free());
    }

    #[test]
    fn uniform_value_becomes_symbol() {
        let mut m = Module::new("t");
        let g = m.add_global(Global {
            name: "k.buf".into(),
            space: AddrSpace::Local,
            size: 256,
            align: 4,
            init: None,
        });
        let mut f = Function::new("k", vec![], Type::Void);
        let (gep, s);
        {
            let mut b = Builder::new(&mut f);
            let l = b.intr(Intr::WorkItem(WorkItem::LocalId), vec![Val::ci(0)]);
            s = b.intr(Intr::WorkItem(WorkItem::LocalSize), vec![Val::ci(0)]);
            let idx = b.add(l, s);
            gep = b.gep(Val::G(g), idx, 4);
            b.ret(None);
        }
        let fid = m.add_func(f);
        let u = analyze(&m);
        let f = m.func(fid);
        let mut n = Normalizer::new(f, &u);
        let off = n.local_addr(&m, gep).unwrap().1.unwrap();
        assert_eq!(off.tid, [4, 0, 0]);
        assert_eq!(off.syms.len(), 1);
        assert_eq!(off.syms[0].1, 4);
    }

    #[test]
    fn divergent_product_is_not_affine() {
        let mut m = Module::new("t");
        let g = m.add_global(Global {
            name: "k.buf".into(),
            space: AddrSpace::Local,
            size: 256,
            align: 4,
            init: None,
        });
        let mut f = Function::new("k", vec![], Type::Void);
        let gep;
        {
            let mut b = Builder::new(&mut f);
            let l = b.intr(Intr::WorkItem(WorkItem::LocalId), vec![Val::ci(0)]);
            let idx = b.mul(l, l); // tid² — not linear
            gep = b.gep(Val::G(g), idx, 4);
            b.ret(None);
        }
        let fid = m.add_func(f);
        let u = analyze(&m);
        let f = m.func(fid);
        let mut n = Normalizer::new(f, &u);
        let (gg, off) = n.local_addr(&m, gep).unwrap();
        assert_eq!(gg, g);
        assert!(off.is_none());
    }
}
