//! `volt::check` — static SIMT verification (paper §6: correctness
//! tooling). Three analyses over the pre-dispatch kernel IR:
//!
//! * **barrier divergence** ([`barrier`]): a workgroup barrier that is
//!   control-dependent on a divergent branch, or that sits in a loop with
//!   a divergent trip count, deadlocks part of the workgroup on hardware.
//! * **shared-memory races** ([`race`]): GPUVerify-style two-thread
//!   reduction over barrier-delimited phases — local-memory accesses are
//!   normalized to `Σ c·tid + Σ c·sym + k` form and a Fourier–Motzkin
//!   solver decides whether two *distinct* threads of the workgroup can
//!   touch the same word within one phase. Non-affine accesses degrade to
//!   a conservative "may alias" diagnostic.
//! * **bounds / uninitialized reads** ([`bounds`]): interval evaluation
//!   of fully-static access patterns against declared array extents, and
//!   an array-granularity must-write dataflow for reads of local memory
//!   that no path has initialized.
//!
//! The checker is target-independent: it always analyzes the
//! hardware-warp lowering of the source (`warp_hw = true`) because the
//! checks describe the *portable* semantics of the kernel, not the
//! scratch memory a software warp-emulation lowering would add. Kernel
//! arguments are uniform by dispatch, so they are annotated as such
//! before the uniformity analysis runs.
//!
//! Entry point: [`check_source`]. The driver exposes the same pipeline as
//! [`crate::driver::VoltOptions::check`] (Warn / Deny), the CLI as
//! `volt check`. The simulator's shadow-memory sanitizer
//! (`SimConfig::sanitize`) dynamically cross-checks the race and bounds
//! verdicts at runtime.

pub mod affine;
mod barrier;
mod bounds;
pub mod buggy;
pub mod diag;
mod race;
pub mod solver;

pub use diag::{render_json, render_text, CheckId, Diag, Severity};

use crate::analysis::tti::{TargetDivergenceInfo, VortexTti};
use crate::analysis::{uniformity, UniformityOptions};
use crate::frontend::{compile, CompileError, Dialect, FrontendOptions};
use crate::ir::{InstData, InstKind, Intr, Module};
use crate::transform::{inline, mem2reg, simplify, structurize};

/// How diagnostics from the static checker are treated by the driver.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum CheckMode {
    /// Don't run the checker.
    #[default]
    Off,
    /// Run it; report diagnostics but compile anyway.
    Warn,
    /// Run it; any diagnostic fails the compile with a validation error.
    Deny,
}

/// Static facts about the launch the checker may assume.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct CheckParams {
    /// Workgroup (local) size per dimension. Bounds the two-thread race
    /// reduction and the interval bounds pass. Defaults to the Vortex
    /// default workgroup shape.
    pub local_size: [u64; 3],
}

impl Default for CheckParams {
    fn default() -> CheckParams {
        CheckParams {
            local_size: [64, 1, 1],
        }
    }
}

/// Divergence info at *workgroup* scope: like [`VortexTti`], except the
/// warp vote/ballot/mask primitives are only warp-uniform — different
/// warps of the same workgroup can see different values — so they must
/// not be treated as always-uniform here. (A vote of a uniform predicate
/// still comes out uniform through normal operand propagation.)
pub struct WorkgroupTti;

impl TargetDivergenceInfo for WorkgroupTti {
    fn is_source_of_divergence(
        &self,
        f: &crate::ir::Function,
        inst: &InstData,
        opts: &UniformityOptions,
    ) -> bool {
        VortexTti.is_source_of_divergence(f, inst, opts)
    }

    fn is_always_uniform(
        &self,
        f: &crate::ir::Function,
        inst: &InstData,
        opts: &UniformityOptions,
    ) -> bool {
        if let InstKind::Intr { intr, .. } = &inst.kind {
            if matches!(
                intr,
                Intr::VoteAll | Intr::VoteAny | Intr::Ballot | Intr::Mask
            ) {
                return false;
            }
        }
        VortexTti.is_always_uniform(f, inst, opts)
    }
}

/// Run all static checks over every kernel in `src`. Returns the
/// diagnostics sorted by (source line, check id); an empty vector means
/// the kernels are clean under the assumptions in `params`.
pub fn check_source(
    src: &str,
    dialect: Dialect,
    params: &CheckParams,
) -> Result<Vec<Diag>, CompileError> {
    let opts = FrontendOptions {
        dialect,
        // Always analyze the hardware-warp lowering: the checks are about
        // the portable semantics of the source, and the software warp
        // emulation's scratch traffic is compiler-managed, not user code.
        warp_hw: true,
    };
    let mut m = compile(src, &opts)?;
    Ok(check_module(&mut m, params))
}

/// Check an already-compiled (pre-dispatch) module. Normalizes the module
/// in place: structurization + mem2reg so addresses are in SSA form, and
/// device functions inlined into kernels so the phase analysis sees the
/// whole kernel body.
pub fn check_module(m: &mut Module, params: &CheckParams) -> Vec<Diag> {
    for f in m.funcs.iter_mut() {
        simplify::simplify(f);
        structurize::run(f);
        mem2reg::run(f);
        simplify::simplify(f);
    }
    let kernels = m.kernels();
    for &k in &kernels {
        inline::inline_into(m, k, None);
        simplify::simplify(m.func_mut(k));
        // Kernel arguments are the same for every thread of the dispatch.
        for p in m.func_mut(k).params.iter_mut() {
            p.uniform = true;
        }
    }
    let m: &Module = m;
    let uopts = UniformityOptions {
        uni_hw: true,
        uni_ann: true,
        uni_func: false,
    };
    let mut diags = vec![];
    for &k in &kernels {
        let u = uniformity::analyze(m, k, &uopts, &WorkgroupTti);
        let f = m.func(k);
        let kernel = f.name.clone();
        barrier::check(f, &u, &kernel, &mut diags);
        race::check(m, f, &u, params, &kernel, &mut diags);
        bounds::check(m, f, &u, params, &kernel, &mut diags);
    }
    diags.sort_by(|a, b| {
        (a.line().unwrap_or(0), a.id.id_str(), &a.kernel, &a.msg).cmp(&(
            b.line().unwrap_or(0),
            b.id.id_str(),
            &b.kernel,
            &b.msg,
        ))
    });
    diags.dedup_by(|a, b| {
        a.id == b.id && a.kernel == b.kernel && a.line() == b.line() && a.msg == b.msg
    });
    diags
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(src: &str) -> Vec<Diag> {
        check_source(src, Dialect::OpenCL, &CheckParams::default()).unwrap()
    }

    fn ids(diags: &[Diag]) -> Vec<CheckId> {
        diags.iter().map(|d| d.id).collect()
    }

    #[test]
    fn clean_reduction_is_silent() {
        let diags = check(include_str!("../../../benchmarks/reduce.cl"));
        assert!(diags.is_empty(), "unexpected: {:?}", ids(&diags));
    }

    #[test]
    fn clean_prefix_sum_is_silent() {
        let diags = check(include_str!("../../../benchmarks/psum.cl"));
        assert!(diags.is_empty(), "unexpected: {:?}", ids(&diags));
    }

    #[test]
    fn clean_stencil_is_silent() {
        let diags = check(include_str!("../../../benchmarks/stencil.cl"));
        assert!(diags.is_empty(), "unexpected: {:?}", ids(&diags));
    }

    #[test]
    fn clean_tiled_sgemm_is_silent_at_8x8() {
        let diags = check_source(
            include_str!("../../../benchmarks/sgemm_tiled.cl"),
            Dialect::OpenCL,
            &CheckParams {
                local_size: [8, 8, 1],
            },
        )
        .unwrap();
        assert!(diags.is_empty(), "unexpected: {:?}", ids(&diags));
    }

    #[test]
    fn barrier_under_divergent_branch() {
        let diags = check(
            r#"
kernel void k(global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[l] = 1.0f;
    if (l < 32) {
        barrier(0);
    }
    out[l] = buf[l];
}
"#,
        );
        assert_eq!(ids(&diags), vec![CheckId::BarrierDivergence]);
        assert_eq!(diags[0].line(), Some(7));
    }

    #[test]
    fn barrier_in_divergent_loop() {
        let diags = check(
            r#"
kernel void k(global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[l] = 0.0f;
    for (int i = 0; i < l; i++) {
        barrier(0);
        buf[l] += 1.0f;
    }
    out[l] = buf[l];
}
"#,
        );
        assert_eq!(ids(&diags), vec![CheckId::BarrierDivergentLoop]);
    }

    #[test]
    fn all_threads_write_one_word() {
        let diags = check(
            r#"
kernel void k(global float* in, global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[0] = in[l];
    barrier(0);
    out[l] = buf[0];
}
"#,
        );
        assert_eq!(ids(&diags), vec![CheckId::RaceWriteWrite]);
        assert_eq!(diags[0].line(), Some(5));
    }

    #[test]
    fn mirrored_read_without_barrier() {
        let diags = check(
            r#"
kernel void k(global float* in, global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[l] = in[l];
    out[l] = buf[63 - l];
}
"#,
        );
        assert_eq!(ids(&diags), vec![CheckId::RaceReadWrite]);
    }

    #[test]
    fn off_by_one_write_escapes_array() {
        let diags = check(
            r#"
kernel void k(global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[l + 1] = 1.0f;
    barrier(0);
    out[l] = buf[l];
}
"#,
        );
        assert_eq!(ids(&diags), vec![CheckId::BoundsLocalOob]);
        assert_eq!(diags[0].line(), Some(5));
    }

    #[test]
    fn partial_initialization_read_back() {
        let diags = check(
            r#"
kernel void k(global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    if (l < 32) {
        buf[l] = 1.0f;
    }
    barrier(0);
    out[l] = buf[l];
}
"#,
        );
        assert_eq!(ids(&diags), vec![CheckId::UninitLocalRead]);
    }

    #[test]
    fn data_dependent_index_may_alias() {
        let diags = check(
            r#"
kernel void k(global int* idx, global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[idx[l]] = 1.0f;
    barrier(0);
    out[l] = buf[l];
}
"#,
        );
        assert_eq!(ids(&diags), vec![CheckId::RaceMayAlias]);
    }

    #[test]
    fn guard_makes_single_writer_safe() {
        // Only thread 0 writes the word: equality guard must suppress the
        // write-write report.
        let diags = check(
            r#"
kernel void k(global float* in, global float* out) {
    local float buf[64];
    int l = get_local_id(0);
    buf[l] = in[l];
    if (l == 0) {
        buf[0] = buf[0] * 2.0f;
    }
    barrier(0);
    out[l] = buf[l];
}
"#,
        );
        assert!(diags.is_empty(), "unexpected: {:?}", ids(&diags));
    }

    #[test]
    fn deny_mode_default_and_param_defaults() {
        assert_eq!(CheckMode::default(), CheckMode::Off);
        assert_eq!(CheckParams::default().local_size, [64, 1, 1]);
    }
}
