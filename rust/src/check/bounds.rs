//! Static bounds and uninitialized-read checking over statically-sized
//! local arrays, by interval evaluation of the same normalized address
//! forms the race detector uses.
//!
//! * **bounds**: an access whose address is affine with *no* symbolic
//!   unknowns (`Σ c·tid + k` only) gets its byte interval evaluated over
//!   the declared workgroup size, tightened by single-dimension guards
//!   (`l == 63`, `l < 32`, …); intervals escaping `[0, size)` are
//!   reported. Symbolic addresses are left to the race detector and the
//!   runtime sanitizer — reporting "maybe" bounds findings on every
//!   `buf[l + off]` would drown real ones.
//! * **uninit**: array-granularity forward must-write dataflow; a read of
//!   a local array on some path where nothing has written the array yet
//!   is reported.

use super::affine::{LinExpr, Normalizer};
use super::diag::{CheckId, Diag, Severity};
use super::race::{block_guards, collect_accesses, Access, Segments};
use super::CheckParams;
use crate::analysis::uniformity::Uniformity;
use crate::ir::dom::DomTree;
use crate::ir::loops::LoopInfo;
use crate::ir::{AddrSpace, BlockId, Function, GlobalId, Module};
use std::collections::{HashMap, HashSet};

/// Per-dimension inclusive tid range after guard tightening.
fn tid_ranges(ls: [u64; 3], guards: &[LinExpr]) -> Option<[(i128, i128); 3]> {
    let mut r = [(0i128, 0i128); 3];
    for d in 0..3 {
        r[d] = (0, ls[d] as i128 - 1);
    }
    for g in guards {
        // Use only facts over exactly one tid dim and no symbols:
        // c·t + k ≥ 0.
        if !g.sym_free() {
            continue;
        }
        let dims: Vec<usize> = (0..3).filter(|&d| g.tid[d] != 0).collect();
        if dims.len() != 1 {
            continue;
        }
        let d = dims[0];
        let c = g.tid[d];
        if c > 0 {
            // t ≥ ⌈−k/c⌉
            let lo = (-g.k).div_euclid(c) + if (-g.k).rem_euclid(c) != 0 { 1 } else { 0 };
            r[d].0 = r[d].0.max(lo);
        } else {
            // t ≤ ⌊k/−c⌋
            let hi = g.k.div_euclid(-c);
            r[d].1 = r[d].1.min(hi);
        }
    }
    for d in 0..3 {
        if r[d].0 > r[d].1 {
            return None; // contradictory guards: path is dead
        }
    }
    Some(r)
}

fn interval(off: &LinExpr, r: &[(i128, i128); 3]) -> (i128, i128) {
    let mut lo = off.k;
    let mut hi = off.k;
    for d in 0..3 {
        let c = off.tid[d];
        if c >= 0 {
            lo += c * r[d].0;
            hi += c * r[d].1;
        } else {
            lo += c * r[d].1;
            hi += c * r[d].0;
        }
    }
    (lo, hi)
}

pub fn check(
    m: &Module,
    f: &Function,
    u: &Uniformity,
    params: &CheckParams,
    kernel: &str,
    diags: &mut Vec<Diag>,
) {
    let dom = DomTree::build(f);
    let li = LoopInfo::build(f);
    let segs = Segments::build(f, &dom);
    let mut norm = Normalizer::new(f, u);
    let accesses = collect_accesses(m, f, &mut norm, &segs);

    // ---- bounds ----
    let mut guard_cache: HashMap<BlockId, Vec<LinExpr>> = HashMap::new();
    let mut reported: HashSet<(GlobalId, u32)> = HashSet::new();
    for a in &accesses {
        let (g, off) = match (a.g, &a.off) {
            (Some(g), Some(off)) if off.sym_free() => (g, off),
            _ => continue,
        };
        let guards = guard_cache
            .entry(a.block)
            .or_insert_with(|| block_guards(&mut norm, &dom, &li, a.block))
            .clone();
        let ranges = match tid_ranges(params.local_size, &guards) {
            Some(r) => r,
            None => continue,
        };
        let size = m.globals[g.idx()].size as i128;
        let (lo, hi) = interval(off, &ranges);
        if lo >= 0 && hi + 4 <= size {
            continue;
        }
        let line = f.inst(a.inst).loc.map(|l| l.line).unwrap_or(0);
        if !reported.insert((g, line)) {
            continue;
        }
        diags.push(Diag {
            id: CheckId::BoundsLocalOob,
            severity: Severity::Warning,
            kernel: kernel.to_string(),
            loc: f.inst(a.inst).loc,
            msg: format!(
                "{} of {} reaches byte offsets {}..{} outside the array (0..{}) \
                 for a {}x{}x{} workgroup",
                if a.write { "write" } else { "read" },
                name_of(m, g),
                lo,
                hi + 3,
                size,
                params.local_size[0],
                params.local_size[1],
                params.local_size[2],
            ),
            notes: vec![],
        });
    }

    // ---- uninit: array-granularity must-write dataflow ----
    let locals: Vec<GlobalId> = (0..m.globals.len() as u32)
        .map(GlobalId)
        .filter(|g| m.globals[g.idx()].space == AddrSpace::Local)
        .collect();
    if locals.is_empty() {
        return;
    }
    let universe: HashSet<GlobalId> = locals.iter().copied().collect();
    let rpo = f.rpo();
    let preds = f.preds();
    let reachable: HashSet<BlockId> = rpo.iter().copied().collect();
    // Per-block generated (written) arrays. An unresolved local write
    // (g = None) conservatively initializes every array.
    let mut gen: HashMap<BlockId, HashSet<GlobalId>> = HashMap::new();
    let by_block: HashMap<BlockId, Vec<&Access>> = {
        let mut map: HashMap<BlockId, Vec<&Access>> = HashMap::new();
        for a in &accesses {
            map.entry(a.block).or_default().push(a);
        }
        map
    };
    for (&b, accs) in &by_block {
        let e = gen.entry(b).or_default();
        for a in accs {
            if a.write {
                match a.g {
                    Some(g) => {
                        e.insert(g);
                    }
                    None => {
                        e.extend(universe.iter().copied());
                    }
                }
            }
        }
    }
    // in[entry] = ∅; in[b] = ∩ preds out[p]; out = in ∪ gen. Iterate to
    // fixpoint from ⊤ (= universe).
    let mut out_sets: HashMap<BlockId, HashSet<GlobalId>> = rpo
        .iter()
        .map(|&b| (b, universe.clone()))
        .collect();
    out_sets.insert(
        f.entry,
        gen.get(&f.entry).cloned().unwrap_or_default(),
    );
    loop {
        let mut changed = false;
        for &b in &rpo {
            if b == f.entry {
                continue;
            }
            let mut inb: Option<HashSet<GlobalId>> = None;
            for p in preds[b.idx()].iter().filter(|p| reachable.contains(p)) {
                let po = &out_sets[p];
                inb = Some(match inb {
                    None => po.clone(),
                    Some(acc) => acc.intersection(po).copied().collect(),
                });
            }
            let mut ob = inb.unwrap_or_default();
            if let Some(g) = gen.get(&b) {
                ob.extend(g.iter().copied());
            }
            if out_sets[&b] != ob {
                out_sets.insert(b, ob);
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    let mut uninit_reported: HashSet<(GlobalId, u32)> = HashSet::new();
    for &b in &rpo {
        // Recompute in[b] and walk the block in order.
        let mut written: HashSet<GlobalId> = if b == f.entry {
            HashSet::new()
        } else {
            let mut inb: Option<HashSet<GlobalId>> = None;
            for p in preds[b.idx()].iter().filter(|p| reachable.contains(p)) {
                let po = &out_sets[p];
                inb = Some(match inb {
                    None => po.clone(),
                    Some(acc) => acc.intersection(po).copied().collect(),
                });
            }
            inb.unwrap_or_default()
        };
        let accs = match by_block.get(&b) {
            Some(a) => a,
            None => continue,
        };
        // Accesses are collected in block order (collect walks insts in
        // order), so a linear scan respects intra-block ordering.
        for a in accs {
            if a.write {
                match a.g {
                    Some(g) => {
                        written.insert(g);
                    }
                    None => written.extend(universe.iter().copied()),
                }
            } else {
                let g = match a.g {
                    Some(g) => g,
                    None => continue,
                };
                if written.contains(&g) {
                    continue;
                }
                let line = f.inst(a.inst).loc.map(|l| l.line).unwrap_or(0);
                if !uninit_reported.insert((g, line)) {
                    continue;
                }
                diags.push(Diag {
                    id: CheckId::UninitLocalRead,
                    severity: Severity::Warning,
                    kernel: kernel.to_string(),
                    loc: f.inst(a.inst).loc,
                    msg: format!(
                        "read of {} on a path where no thread has written it \
                         (local memory is not zero-initialized)",
                        name_of(m, g)
                    ),
                    notes: vec![],
                });
            }
        }
    }
}

fn name_of(m: &Module, g: GlobalId) -> String {
    let full = &m.globals[g.idx()].name;
    format!("'{}'", full.rsplit('.').next().unwrap_or(full))
}
