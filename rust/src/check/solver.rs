//! A small Fourier–Motzkin feasibility solver over integer linear
//! constraints, used by the two-thread race reduction. Constraints are
//! `Σ cᵢ·xᵢ + k ≥ 0` with i128 coefficients; strict inequalities are
//! pre-encoded by the caller with the integer gap (`a < b` ⇒ `b−a−1 ≥ 0`),
//! so rational infeasibility of the encoded system proves integer
//! infeasibility of the original. The solver errs on the side of
//! "feasible": arithmetic overflow or blowup reports `true`, which the
//! race detector turns into a (conservative) diagnostic rather than a
//! missed race.

/// `Σ coef[i]·x[i] + k ≥ 0`.
#[derive(Clone, Debug)]
pub struct Constraint {
    pub coef: Vec<i128>,
    pub k: i128,
}

impl Constraint {
    pub fn new(nvars: usize) -> Constraint {
        Constraint {
            coef: vec![0; nvars],
            k: 0,
        }
    }
}

fn gcd(a: i128, b: i128) -> i128 {
    let (mut a, mut b) = (a.abs(), b.abs());
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Integer-tightening normalization: divide by the gcd of the
/// coefficients and floor the constant (valid because the variables are
/// integers; `Σ(c/g)x ≥ ⌈−k/g⌉`).
fn normalize(c: &mut Constraint) {
    let mut g = 0i128;
    for &v in &c.coef {
        g = gcd(g, v);
    }
    if g > 1 {
        for v in c.coef.iter_mut() {
            *v /= g;
        }
        c.k = c.k.div_euclid(g);
    }
}

/// Upper bound on the working set; beyond it we give up and report
/// feasible (conservative for a race checker).
const MAX_CONSTRAINTS: usize = 6000;

/// Rational feasibility of the constraint system by Fourier–Motzkin
/// elimination. `false` is a proof of (integer) infeasibility; `true`
/// means "could not prove infeasible".
pub fn feasible(mut cons: Vec<Constraint>, nvars: usize) -> bool {
    for c in cons.iter_mut() {
        normalize(c);
    }
    for j in 0..nvars {
        let mut pos: Vec<Constraint> = vec![];
        let mut neg: Vec<Constraint> = vec![];
        let mut rest: Vec<Constraint> = vec![];
        for c in cons.drain(..) {
            match c.coef[j].cmp(&0) {
                std::cmp::Ordering::Greater => pos.push(c),
                std::cmp::Ordering::Less => neg.push(c),
                std::cmp::Ordering::Equal => rest.push(c),
            }
        }
        if rest.len() + pos.len() * neg.len() > MAX_CONSTRAINTS {
            return true;
        }
        for p in &pos {
            for n in &neg {
                // p: a·xⱼ + P ≥ 0 (a>0);  n: −b·xⱼ + N ≥ 0 (b>0)
                // ⇒ b·P + a·N ≥ 0.
                let a = p.coef[j];
                let b = -n.coef[j];
                let mut c = Constraint::new(p.coef.len());
                for i in 0..p.coef.len() {
                    let t1 = match b.checked_mul(p.coef[i]) {
                        Some(v) => v,
                        None => return true,
                    };
                    let t2 = match a.checked_mul(n.coef[i]) {
                        Some(v) => v,
                        None => return true,
                    };
                    c.coef[i] = match t1.checked_add(t2) {
                        Some(v) => v,
                        None => return true,
                    };
                }
                let t1 = match b.checked_mul(p.k) {
                    Some(v) => v,
                    None => return true,
                };
                let t2 = match a.checked_mul(n.k) {
                    Some(v) => v,
                    None => return true,
                };
                c.k = match t1.checked_add(t2) {
                    Some(v) => v,
                    None => return true,
                };
                debug_assert_eq!(c.coef[j], 0);
                normalize(&mut c);
                rest.push(c);
            }
        }
        cons = rest;
    }
    // Only constants remain: `k ≥ 0` must hold for every row.
    cons.iter().all(|c| c.k >= 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(coef: &[i128], k: i128) -> Constraint {
        Constraint {
            coef: coef.to_vec(),
            k,
        }
    }

    #[test]
    fn trivial_sat_and_unsat() {
        // x ≥ 0 ∧ x ≤ 5  — sat.
        assert!(feasible(vec![c(&[1], 0), c(&[-1], 5)], 1));
        // x ≥ 3 ∧ x ≤ 2  — unsat.
        assert!(!feasible(vec![c(&[1], -3), c(&[-1], 2)], 1));
        // No constraints — sat.
        assert!(feasible(vec![], 2));
    }

    #[test]
    fn two_var_chain() {
        // x ≤ y−1 ∧ y ≤ x  — unsat.
        assert!(!feasible(vec![c(&[-1, 1], -1), c(&[1, -1], 0)], 2));
        // x ≤ y−1 ∧ y ≤ x+1 — sat.
        assert!(feasible(vec![c(&[-1, 1], -1), c(&[1, -1], 1)], 2));
    }

    #[test]
    fn reduce_pattern_disjoint() {
        // vars: t1, t2, s. Writes buf[t1] (t1 < s), reads buf[t2+s]:
        // t1 ≤ s−1, t2 ≥ 0, overlap |4t1 − 4t2 − 4s| ≤ 3 — unsat.
        let cons = vec![
            c(&[-1, 0, 1], -1), // s − t1 − 1 ≥ 0
            c(&[0, 1, 0], 0),   // t2 ≥ 0
            c(&[4, -4, -4], 3), // 4t1 − 4t2 − 4s + 3 ≥ 0
            c(&[-4, 4, 4], 3),  // −(…) + 3 ≥ 0
        ];
        assert!(!feasible(cons, 3));
    }

    #[test]
    fn tiled_2d_pattern_needs_integer_gap() {
        // vars: lx1, ly1, lx2, ly2 in [0,7]; addresses 4(8·ly+lx);
        // distinct rows ly1 ≤ ly2 − 1. Overlap impossible only because
        // the row distinctness carries the integer gap.
        let mut cons = vec![];
        for v in 0..4 {
            let mut lo = [0i128; 4];
            lo[v] = 1;
            cons.push(c(&lo, 0)); // xᵥ ≥ 0
            let mut hi = [0i128; 4];
            hi[v] = -1;
            cons.push(c(&hi, 7)); // xᵥ ≤ 7
        }
        cons.push(c(&[0, -1, 0, 1], -1)); // ly1 ≤ ly2 − 1
        // |4(8ly1+lx1) − 4(8ly2+lx2)| ≤ 3
        cons.push(c(&[4, 32, -4, -32], 3));
        cons.push(c(&[-4, -32, 4, 32], 3));
        assert!(!feasible(cons, 4));
    }

    #[test]
    fn same_word_race_is_feasible() {
        // buf[0] written by all threads: t1 ≠ t2 (t1 ≤ t2−1 branch),
        // addresses both 0 → overlap trivially holds — sat.
        let cons = vec![
            c(&[1, 0], 0),
            c(&[-1, 0], 63),
            c(&[0, 1], 0),
            c(&[0, -1], 63),
            c(&[-1, 1], -1), // t1 ≤ t2 − 1
            c(&[0, 0], 3),   // |0−0| ≤ 3
        ];
        assert!(feasible(cons, 2));
    }

    #[test]
    fn normalization_tightens_integers() {
        // 2x ≥ 1 ∧ x ≤ 0: rationally sat (x = 0.5) but integer-tightened
        // 2x ≥ 1 → x ≥ 1 makes it unsat.
        assert!(!feasible(vec![c(&[2], -1), c(&[-1], 0)], 1));
    }
}
