//! Minimal scoped-thread fan-out helpers (no external dependencies).
//!
//! The compile pipeline's per-function stages (middle-end function
//! passes, backend lowering) are independent after dispatch; these
//! helpers run them across a bounded set of `std::thread::scope`
//! workers and hand the results back **in input order**, so callers
//! join deterministically and emitted artifacts stay byte-identical to
//! the sequential pipeline (see `docs/PARALLELISM.md`).

/// Map `f` over `items` on up to `threads` scoped workers, returning
/// the results in input order. `threads <= 1` (or a single item) runs
/// inline — the sequential path stays allocation- and thread-free.
///
/// Work is dealt in strides (worker `w` takes items `w, w+T, w+2T, …`),
/// which balances pipelines whose cost grows with position (big
/// functions cluster) without any work-stealing machinery.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let workers = threads.min(items.len());
    let mut out: Vec<Option<R>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);
    // Deal each worker a strided view of the output vector so every
    // result lands in its input slot without synchronization.
    let mut views: Vec<Vec<(usize, &mut Option<R>)>> =
        (0..workers).map(|_| Vec::new()).collect();
    for (i, slot) in out.iter_mut().enumerate() {
        views[i % workers].push((i, slot));
    }
    let f = &f;
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for view in views {
            handles.push(scope.spawn(move || {
                for (i, slot) in view {
                    *slot = Some(f(i, &items[i]));
                }
            }));
        }
        for h in handles {
            h.join().expect("par_map worker panicked");
        }
    });
    out.into_iter()
        .map(|r| r.expect("par_map slot unfilled"))
        .collect()
}

/// [`par_map`] over mutable slots: apply `f` to every element of
/// `items` (in place) on up to `threads` scoped workers. Used by the
/// middle-end to run per-function pass stacks concurrently; each
/// element is visited exactly once, and `f`'s per-element result is
/// returned in input order (counter deltas, timings, …).
pub fn par_for_each_mut<T, R, F>(items: &mut [T], threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    if threads <= 1 || items.len() <= 1 {
        return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let workers = threads.min(items.len());
    let mut out: Vec<Option<R>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);
    let mut work: Vec<Vec<(usize, &mut T, &mut Option<R>)>> =
        (0..workers).map(|_| Vec::new()).collect();
    for ((i, item), slot) in items.iter_mut().enumerate().zip(out.iter_mut()) {
        work[i % workers].push((i, item, slot));
    }
    let f = &f;
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for chunk in work {
            handles.push(scope.spawn(move || {
                for (i, item, slot) in chunk {
                    *slot = Some(f(i, item));
                }
            }));
        }
        for h in handles {
            h.join().expect("par_for_each_mut worker panicked");
        }
    });
    out.into_iter()
        .map(|r| r.expect("par_for_each_mut slot unfilled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_input_order() {
        let items: Vec<u64> = (0..37).collect();
        let seq = par_map(&items, 1, |i, x| x * 2 + i as u64);
        for threads in [2usize, 4, 16, 64] {
            let par = par_map(&items, threads, |i, x| x * 2 + i as u64);
            assert_eq!(par, seq, "threads={threads}");
        }
        assert!(par_map::<u64, u64, _>(&[], 4, |_, x| *x).is_empty());
    }

    #[test]
    fn par_for_each_mut_visits_every_slot_once() {
        let mut items: Vec<u32> = vec![0; 23];
        let idx = par_for_each_mut(&mut items, 4, |i, v| {
            *v += 1;
            i
        });
        assert!(items.iter().all(|v| *v == 1), "{items:?}");
        assert_eq!(idx, (0..23).collect::<Vec<_>>());
    }
}
