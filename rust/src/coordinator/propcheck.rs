//! Minimal property-testing harness (the build environment is offline, so
//! `proptest` is unavailable; this provides the same discipline: seeded
//! random cases, failure reporting with the reproducing seed, and
//! last-known-good shrinking over a size parameter).

use super::benchmarks::Rng;

pub struct PropConfig {
    pub cases: u32,
    pub seed: u32,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig {
            cases: 32,
            seed: 0x5eed_0001,
        }
    }
}

/// Run `f` over `cases` seeded RNGs; on failure, retry with progressively
/// smaller `size` hints to report a minimal-ish reproduction.
pub fn check<F>(cfg: &PropConfig, mut f: F)
where
    F: FnMut(&mut Rng, u32) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case.wrapping_mul(0x9e37_79b9));
        let size = 4 + (case % 8) * 4;
        let mut rng = Rng(seed);
        if let Err(e) = f(&mut rng, size) {
            // Shrink over size.
            let mut best = (size, e);
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Rng(seed);
                match f(&mut rng, s) {
                    Err(e2) => {
                        best = (s, e2);
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property failed (seed={seed:#x}, size={}): {}",
                best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(&PropConfig::default(), |rng, size| {
            let v = rng.u32s(size as usize, 100);
            if v.len() == size as usize {
                Ok(())
            } else {
                Err("len".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure_with_seed() {
        check(
            &PropConfig {
                cases: 4,
                seed: 42,
            },
            |_rng, size| {
                if size > 2 {
                    Err("too big".into())
                } else {
                    Ok(())
                }
            },
        );
    }
}
