//! Benchmark registry (paper §5.1 coverage): OpenCL-dialect kernels from
//! the NVIDIA SDK / Parboil / Rodinia families and CUDA-dialect kernels
//! from Rodinia / HeCBench, each with deterministic input generation and a
//! host-side Rust reference validator (the "reference CPU implementation"
//! role of §5; dense kernels are additionally cross-checked against the
//! JAX/Pallas PJRT artifacts by `examples/e2e_validation.rs`).

use crate::frontend::Dialect;
use crate::runtime::{ArgValue, DevicePtr, VoltDevice};

/// Deterministic xorshift32 PRNG (offline build: no rand crate).
#[derive(Clone)]
pub struct Rng(pub u32);

impl Rng {
    pub fn next_u32(&mut self) -> u32 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 17;
        x ^= x << 5;
        self.0 = x;
        x
    }
    pub fn f32_01(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }
    pub fn f32s(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32_01() * 2.0 - 1.0).collect()
    }
    pub fn u32s(&mut self, n: usize, m: u32) -> Vec<u32> {
        (0..n).map(|_| self.next_u32() % m).collect()
    }
}

fn close(a: f32, b: f32) -> bool {
    let d = (a - b).abs();
    d <= 1e-3 + 2e-3 * a.abs().max(b.abs())
}

fn check_f32(dev: &VoltDevice, ptr: DevicePtr, want: &[f32], tag: &str) -> Result<(), String> {
    let got = dev.read_f32(ptr, want.len()).map_err(|e| e.to_string())?;
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        if !close(*g, *w) {
            return Err(format!("{tag}[{i}]: got {g}, want {w}"));
        }
    }
    Ok(())
}

fn check_u32(dev: &VoltDevice, ptr: DevicePtr, want: &[u32], tag: &str) -> Result<(), String> {
    let got = dev.read_u32s(ptr, want.len()).map_err(|e| e.to_string())?;
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        if g != w {
            return Err(format!("{tag}[{i}]: got {g}, want {w}"));
        }
    }
    Ok(())
}

type RunFn = fn(&mut VoltDevice) -> Result<(), String>;

pub struct Benchmark {
    pub name: &'static str,
    pub suite: &'static str,
    pub dialect: Dialect,
    pub source: &'static str,
    /// Uses warp-level builtins (Fig. 9 candidate).
    pub warp_feature: bool,
    /// Uses shared memory (Fig. 10 candidate).
    pub smem: bool,
    pub run: RunFn,
}

macro_rules! bench {
    ($name:literal, $suite:literal, $dialect:expr, $file:literal, warp=$w:literal, smem=$s:literal, $run:expr) => {
        Benchmark {
            name: $name,
            suite: $suite,
            dialect: $dialect,
            source: include_str!(concat!("../../../benchmarks/", $file)),
            warp_feature: $w,
            smem: $s,
            run: $run,
        }
    };
}

pub fn registry() -> Vec<Benchmark> {
    use Dialect::{Cuda, OpenCL};
    vec![
        bench!("vecadd", "sdk", OpenCL, "vecadd.cl", warp = false, smem = false, run_vecadd),
        bench!("saxpy", "sdk", OpenCL, "saxpy.cl", warp = false, smem = false, run_saxpy),
        bench!("sgemm", "parboil", OpenCL, "sgemm.cl", warp = false, smem = false, run_sgemm),
        bench!("sgemm_tiled", "parboil", OpenCL, "sgemm_tiled.cl", warp = false, smem = true, run_sgemm_tiled),
        bench!("transpose", "sdk", OpenCL, "transpose.cl", warp = false, smem = false, run_transpose),
        bench!("reduce", "sdk", OpenCL, "reduce.cl", warp = false, smem = true, run_reduce),
        bench!("dotproduct", "sdk", OpenCL, "dotproduct.cl", warp = false, smem = false, run_dotproduct),
        bench!("psort", "sdk", OpenCL, "psort.cl", warp = false, smem = false, run_psort),
        bench!("psum", "sdk", OpenCL, "psum.cl", warp = false, smem = true, run_psum),
        bench!("gaussian", "rodinia", OpenCL, "gaussian.cl", warp = false, smem = false, run_gaussian),
        bench!("bfs", "rodinia", OpenCL, "bfs.cl", warp = false, smem = false, run_bfs),
        bench!("pathfinder", "rodinia", OpenCL, "pathfinder.cl", warp = false, smem = false, run_pathfinder),
        bench!("kmeans", "rodinia", OpenCL, "kmeans.cl", warp = false, smem = false, run_kmeans),
        bench!("nearn", "rodinia", OpenCL, "nearn.cl", warp = false, smem = false, run_nearn),
        bench!("hotspot", "rodinia", OpenCL, "hotspot.cl", warp = false, smem = false, run_hotspot),
        bench!("srad", "rodinia", OpenCL, "srad.cl", warp = false, smem = false, run_srad),
        bench!("blackscholes", "sdk", OpenCL, "blackscholes.cl", warp = false, smem = false, run_blackscholes),
        bench!("cfd", "rodinia", OpenCL, "cfd.cl", warp = false, smem = false, run_cfd),
        bench!("backprop", "rodinia", OpenCL, "backprop.cl", warp = false, smem = false, run_backprop),
        bench!("lud", "rodinia", OpenCL, "lud.cl", warp = false, smem = false, run_lud),
        bench!("stencil", "parboil", OpenCL, "stencil.cl", warp = false, smem = true, run_stencil),
        // CUDA dialect (Fig. 9 warp-feature suite + Rodinia-CUDA).
        bench!("vote", "hecbench", Cuda, "vote.cu", warp = true, smem = false, run_vote),
        bench!("shuffle", "hecbench", Cuda, "shuffle.cu", warp = true, smem = false, run_shuffle),
        bench!("bscan", "hecbench", Cuda, "bscan.cu", warp = true, smem = false, run_bscan),
        bench!("atomicagg", "hecbench", Cuda, "atomicagg.cu", warp = true, smem = false, run_atomicagg),
        bench!("gc", "hecbench", Cuda, "gc.cu", warp = true, smem = false, run_gc),
        bench!("nw", "rodinia", Cuda, "nw.cu", warp = false, smem = false, run_nw),
        bench!("myocyte", "rodinia", Cuda, "myocyte.cu", warp = false, smem = false, run_myocyte),
    ]
}

pub fn find(name: &str) -> Option<Benchmark> {
    registry().into_iter().find(|b| b.name == name)
}

// ---------------------------------------------------------------------------
// Individual drivers
// ---------------------------------------------------------------------------

fn upload(dev: &mut VoltDevice, data: &[f32]) -> Result<DevicePtr, String> {
    let p = dev.malloc(data.len() as u32 * 4);
    dev.write_f32(p, data).map_err(|e| e.to_string())?;
    Ok(p)
}

fn upload_u32(dev: &mut VoltDevice, data: &[u32]) -> Result<DevicePtr, String> {
    let p = dev.malloc(data.len() as u32 * 4);
    dev.write_u32s(p, data).map_err(|e| e.to_string())?;
    Ok(p)
}

fn run_vecadd(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 1000usize;
    let mut rng = Rng(11);
    let a = rng.f32s(n);
    let b = rng.f32s(n);
    let pa = upload(dev, &a)?;
    let pb = upload(dev, &b)?;
    let pc = dev.malloc(n as u32 * 4);
    dev.launch(
        "vecadd",
        [8, 1, 1],
        [128, 1, 1],
        &[ArgValue::Ptr(pa), ArgValue::Ptr(pb), ArgValue::Ptr(pc), ArgValue::I32(n as i32)],
    )
    .map_err(|e| e.to_string())?;
    let want: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
    check_f32(dev, pc, &want, "vecadd")
}

fn run_saxpy(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 777usize;
    let mut rng = Rng(12);
    let x = rng.f32s(n);
    let y = rng.f32s(n);
    let a = 1.75f32;
    let px = upload(dev, &x)?;
    let py = upload(dev, &y)?;
    dev.launch(
        "saxpy",
        [7, 1, 1],
        [128, 1, 1],
        &[ArgValue::Ptr(px), ArgValue::Ptr(py), ArgValue::F32(a), ArgValue::I32(n as i32)],
    )
    .map_err(|e| e.to_string())?;
    let want: Vec<f32> = x.iter().zip(&y).map(|(x, y)| a * x + y).collect();
    check_f32(dev, py, &want, "saxpy")
}

fn run_sgemm(dev: &mut VoltDevice) -> Result<(), String> {
    let (n, m, k) = (24usize, 24, 24);
    let mut rng = Rng(13);
    let a = rng.f32s(n * k);
    let b = rng.f32s(k * m);
    let pa = upload(dev, &a)?;
    let pb = upload(dev, &b)?;
    let pc = dev.malloc((n * m) as u32 * 4);
    dev.launch(
        "sgemm",
        [3, 3, 1],
        [8, 8, 1],
        &[
            ArgValue::Ptr(pa),
            ArgValue::Ptr(pb),
            ArgValue::Ptr(pc),
            ArgValue::I32(n as i32),
            ArgValue::I32(m as i32),
            ArgValue::I32(k as i32),
        ],
    )
    .map_err(|e| e.to_string())?;
    let mut want = vec![0f32; n * m];
    for r in 0..n {
        for c in 0..m {
            let mut s = 0f32;
            for t in 0..k {
                s += a[r * k + t] * b[t * m + c];
            }
            want[r * m + c] = s;
        }
    }
    check_f32(dev, pc, &want, "sgemm")
}

fn run_sgemm_tiled(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 16usize;
    let mut rng = Rng(14);
    let a = rng.f32s(n * n);
    let b = rng.f32s(n * n);
    let pa = upload(dev, &a)?;
    let pb = upload(dev, &b)?;
    let pc = dev.malloc((n * n) as u32 * 4);
    dev.launch(
        "sgemm_tiled",
        [2, 2, 1],
        [8, 8, 1],
        &[ArgValue::Ptr(pa), ArgValue::Ptr(pb), ArgValue::Ptr(pc), ArgValue::I32(n as i32)],
    )
    .map_err(|e| e.to_string())?;
    let mut want = vec![0f32; n * n];
    for r in 0..n {
        for c in 0..n {
            let mut s = 0f32;
            for t in 0..n {
                s += a[r * n + t] * b[t * n + c];
            }
            want[r * n + c] = s;
        }
    }
    check_f32(dev, pc, &want, "sgemm_tiled")
}

fn run_transpose(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 24usize;
    let mut rng = Rng(15);
    let input = rng.f32s(n * n);
    let pi = upload(dev, &input)?;
    let po = dev.malloc((n * n) as u32 * 4);
    dev.launch(
        "transpose",
        [3, 3, 1],
        [8, 8, 1],
        &[ArgValue::Ptr(pi), ArgValue::Ptr(po), ArgValue::I32(n as i32), ArgValue::I32(0)],
    )
    .map_err(|e| e.to_string())?;
    let mut want = vec![0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            let src = j * n + i;
            let v = if i + 1 < n { input[src + 1] } else { input[src] };
            want[i * n + j] = input[src] + v * 0.0001;
        }
    }
    check_f32(dev, po, &want, "transpose")
}

fn run_reduce(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 512usize;
    let groups = 8usize;
    let mut rng = Rng(16);
    let input = rng.f32s(n);
    let pi = upload(dev, &input)?;
    let po = dev.malloc(groups as u32 * 4);
    dev.launch(
        "reduce",
        [groups as u32, 1, 1],
        [64, 1, 1],
        &[ArgValue::Ptr(pi), ArgValue::Ptr(po), ArgValue::I32(n as i32)],
    )
    .map_err(|e| e.to_string())?;
    let want: Vec<f32> = (0..groups)
        .map(|g| input[g * 64..(g + 1) * 64].iter().sum())
        .collect();
    check_f32(dev, po, &want, "reduce")
}

fn run_dotproduct(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 256usize;
    let mut rng = Rng(17);
    let a = rng.f32s(n);
    let b = rng.f32s(n);
    let pa = upload(dev, &a)?;
    let pb = upload(dev, &b)?;
    let pacc = upload_u32(dev, &[0])?;
    dev.launch(
        "dotproduct",
        [2, 1, 1],
        [128, 1, 1],
        &[ArgValue::Ptr(pa), ArgValue::Ptr(pb), ArgValue::Ptr(pacc), ArgValue::I32(n as i32)],
    )
    .map_err(|e| e.to_string())?;
    let want: i32 = a
        .iter()
        .zip(&b)
        .map(|(x, y)| {
            // match the kernel's fcvt.w.s on p*256
            let p = x * y * 256.0;
            if p >= i32::MAX as f32 {
                i32::MAX
            } else if p <= i32::MIN as f32 {
                i32::MIN
            } else {
                p as i32
            }
        })
        .sum();
    check_u32(dev, pacc, &[want as u32], "dotproduct")
}

fn run_psort(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 128usize;
    let mut rng = Rng(18);
    let data: Vec<u32> = rng.u32s(n, 10_000);
    let pd = upload_u32(dev, &data)?;
    for phase in 0..n as i32 {
        dev.launch(
            "psort",
            [1, 1, 1],
            [64, 1, 1],
            &[ArgValue::Ptr(pd), ArgValue::I32(n as i32), ArgValue::I32(phase)],
        )
        .map_err(|e| e.to_string())?;
    }
    let mut want = data.clone();
    want.sort_unstable();
    check_u32(dev, pd, &want, "psort")
}

fn run_psum(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 256usize;
    let mut rng = Rng(19);
    let data: Vec<u32> = rng.u32s(n, 100);
    let pd = upload_u32(dev, &data)?;
    let po = dev.malloc(n as u32 * 4);
    dev.launch(
        "psum",
        [4, 1, 1],
        [64, 1, 1],
        &[ArgValue::Ptr(pd), ArgValue::Ptr(po), ArgValue::I32(n as i32)],
    )
    .map_err(|e| e.to_string())?;
    let mut want = vec![0u32; n];
    for g in 0..4 {
        let mut acc = 0u32;
        for l in 0..64 {
            acc += data[g * 64 + l];
            want[g * 64 + l] = acc;
        }
    }
    check_u32(dev, po, &want, "psum")
}

fn run_gaussian(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 16usize;
    let mut rng = Rng(20);
    let mut m = rng.f32s(n * n);
    let mut v = rng.f32s(n);
    // diagonally dominant
    for i in 0..n {
        m[i * n + i] = 8.0 + m[i * n + i].abs();
    }
    let pm = upload(dev, &m)?;
    let pv = upload(dev, &v)?;
    for pivot in 0..n as i32 - 1 {
        dev.launch(
            "gaussian",
            [1, 1, 1],
            [32, 1, 1],
            &[ArgValue::Ptr(pm), ArgValue::Ptr(pv), ArgValue::I32(n as i32), ArgValue::I32(pivot)],
        )
        .map_err(|e| e.to_string())?;
    }
    // reference elimination
    for p in 0..n - 1 {
        for r in p + 1..n {
            let f = m[r * n + p] / m[p * n + p];
            for c in p..n {
                m[r * n + c] -= f * m[p * n + c];
            }
            v[r] -= f * v[p];
        }
    }
    check_f32(dev, pv, &v, "gaussian.v")?;
    check_f32(dev, pm, &m, "gaussian.m")
}

/// Ring + chord graph in CSR form.
fn make_graph(n: usize) -> (Vec<u32>, Vec<u32>) {
    let mut row_off = vec![0u32];
    let mut cols = vec![];
    for u in 0..n {
        cols.push(((u + 1) % n) as u32);
        cols.push(((u * 7 + 3) % n) as u32);
        row_off.push(cols.len() as u32);
    }
    (row_off, cols)
}

fn run_bfs(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 128usize;
    let (row_off, cols) = make_graph(n);
    let mut levels = vec![-1i32; n];
    levels[0] = 0;
    let pro = upload_u32(dev, &row_off)?;
    let pco = upload_u32(dev, &cols)?;
    let plv = upload_u32(dev, &levels.iter().map(|&x| x as u32).collect::<Vec<_>>())?;
    let pfl = upload_u32(dev, &[0])?;
    let mut level = 0;
    loop {
        dev.write_u32s(pfl, &[0]).map_err(|e| e.to_string())?;
        dev.launch(
            "bfs",
            [2, 1, 1],
            [64, 1, 1],
            &[
                ArgValue::Ptr(pro),
                ArgValue::Ptr(pco),
                ArgValue::Ptr(plv),
                ArgValue::Ptr(pfl),
                ArgValue::I32(level),
                ArgValue::I32(n as i32),
            ],
        )
        .map_err(|e| e.to_string())?;
        let flag = dev.read_u32s(pfl, 1).map_err(|e| e.to_string())?[0];
        level += 1;
        if flag == 0 || level > n as i32 {
            break;
        }
    }
    // reference BFS
    let mut want = vec![-1i32; n];
    want[0] = 0;
    let mut frontier = vec![0usize];
    let mut l = 0;
    while !frontier.is_empty() {
        let mut next = vec![];
        for &u in &frontier {
            for e in row_off[u] as usize..row_off[u + 1] as usize {
                let v = cols[e] as usize;
                if want[v] == -1 {
                    want[v] = l + 1;
                    next.push(v);
                }
            }
        }
        frontier = next;
        l += 1;
    }
    let wantu: Vec<u32> = want.iter().map(|&x| x as u32).collect();
    check_u32(dev, plv, &wantu, "bfs")
}

fn run_pathfinder(dev: &mut VoltDevice) -> Result<(), String> {
    let cols = 256usize;
    let rows = 8usize;
    let mut rng = Rng(21);
    let wall: Vec<u32> = rng.u32s(cols * rows, 10);
    let pw = upload_u32(dev, &wall)?;
    let mut prev: Vec<u32> = wall[0..cols].to_vec();
    let pprev = upload_u32(dev, &prev)?;
    let pcur = dev.malloc(cols as u32 * 4);
    let mut bufs = [pprev, pcur];
    for row in 1..rows {
        dev.launch(
            "pathfinder",
            [2, 1, 1],
            [128, 1, 1],
            &[
                ArgValue::Ptr(bufs[0]),
                ArgValue::Ptr(bufs[1]),
                ArgValue::Ptr(pw),
                ArgValue::I32(cols as i32),
                ArgValue::I32(row as i32),
            ],
        )
        .map_err(|e| e.to_string())?;
        bufs.swap(0, 1);
    }
    // reference DP
    for row in 1..rows {
        let mut cur = vec![0u32; cols];
        for c in 0..cols {
            let left = if c > 0 { prev[c - 1] } else { prev[c] };
            let up = prev[c];
            let right = if c < cols - 1 { prev[c + 1] } else { prev[c] };
            cur[c] = wall[row * cols + c] + left.min(up).min(right);
        }
        prev = cur;
    }
    check_u32(dev, bufs[0], &prev, "pathfinder")
}

fn run_kmeans(dev: &mut VoltDevice) -> Result<(), String> {
    let (n, k, d) = (256usize, 4usize, 2usize);
    let mut rng = Rng(22);
    let pts = rng.f32s(n * d);
    let centers = rng.f32s(k * d);
    let pp = upload(dev, &pts)?;
    let pc = upload(dev, &centers)?;
    let pa = dev.malloc(n as u32 * 4);
    let pparams = upload_u32(dev, &[k as u32, d as u32])?;
    dev.launch(
        "kmeans",
        [2, 1, 1],
        [128, 1, 1],
        &[
            ArgValue::Ptr(pp),
            ArgValue::Ptr(pc),
            ArgValue::Ptr(pa),
            ArgValue::Ptr(pparams),
            ArgValue::I32(n as i32),
        ],
    )
    .map_err(|e| e.to_string())?;
    let mut want = vec![0u32; n];
    for i in 0..n {
        let mut bestd = f32::MAX;
        let mut best = 0u32;
        for c in 0..k {
            let mut acc = 0f32;
            for j in 0..d {
                let diff = pts[i * d + j] - centers[c * d + j];
                acc += diff * diff;
            }
            if acc < bestd {
                bestd = acc;
                best = c as u32;
            }
        }
        want[i] = best;
    }
    check_u32(dev, pa, &want, "kmeans")
}

fn run_nearn(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 512usize;
    let mut rng = Rng(23);
    let lat = rng.f32s(n);
    let lon = rng.f32s(n);
    let pla = upload(dev, &lat)?;
    let plo = upload(dev, &lon)?;
    let pd = dev.malloc(n as u32 * 4);
    let (qlat, qlon) = (0.25f32, -0.5f32);
    dev.launch(
        "nearn",
        [4, 1, 1],
        [128, 1, 1],
        &[
            ArgValue::Ptr(pla),
            ArgValue::Ptr(plo),
            ArgValue::Ptr(pd),
            ArgValue::I32(n as i32),
            ArgValue::F32(qlat),
            ArgValue::F32(qlon),
        ],
    )
    .map_err(|e| e.to_string())?;
    let want: Vec<f32> = (0..n)
        .map(|i| {
            let dy = lat[i] - qlat;
            let dx = lon[i] - qlon;
            (dy * dy + dx * dx).sqrt()
        })
        .collect();
    check_f32(dev, pd, &want, "nearn")
}

fn run_hotspot(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 24usize;
    let mut rng = Rng(24);
    let temp = rng.f32s(n * n);
    let power = rng.f32s(n * n);
    let pt = upload(dev, &temp)?;
    let pp = upload(dev, &power)?;
    let po = dev.malloc((n * n) as u32 * 4);
    let cap = 0.05f32;
    dev.launch(
        "hotspot",
        [3, 3, 1],
        [8, 8, 1],
        &[
            ArgValue::Ptr(pt),
            ArgValue::Ptr(pp),
            ArgValue::Ptr(po),
            ArgValue::I32(n as i32),
            ArgValue::F32(cap),
        ],
    )
    .map_err(|e| e.to_string())?;
    let mut want = vec![0f32; n * n];
    for y in 0..n {
        for x in 0..n {
            let idx = y * n + x;
            let c = temp[idx];
            let l = if x > 0 { temp[idx - 1] } else { c };
            let r = if x < n - 1 { temp[idx + 1] } else { c };
            let u = if y > 0 { temp[idx - n] } else { c };
            let d = if y < n - 1 { temp[idx + n] } else { c };
            want[idx] = c + cap * (power[idx] + (l + r + u + d - 4.0 * c));
        }
    }
    check_f32(dev, po, &want, "hotspot")
}

fn run_srad(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 512usize;
    let mut rng = Rng(25);
    let img = rng.f32s(n);
    let pi = upload(dev, &img)?;
    let po = dev.malloc(n as u32 * 4);
    let lambda = 0.5f32;
    dev.launch(
        "srad",
        [4, 1, 1],
        [128, 1, 1],
        &[ArgValue::Ptr(pi), ArgValue::Ptr(po), ArgValue::I32(n as i32), ArgValue::F32(lambda)],
    )
    .map_err(|e| e.to_string())?;
    let want: Vec<f32> = img
        .iter()
        .map(|&v| {
            let g = (-v.abs() * lambda).exp();
            v + 0.25 * g * (v * 0.5 - v)
        })
        .collect();
    check_f32(dev, po, &want, "srad")
}

fn run_blackscholes(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 128usize;
    let mut rng = Rng(26);
    let s: Vec<f32> = (0..n).map(|_| 10.0 + rng.f32_01() * 90.0).collect();
    let x: Vec<f32> = (0..n).map(|_| 10.0 + rng.f32_01() * 90.0).collect();
    let t: Vec<f32> = (0..n).map(|_| 0.2 + rng.f32_01() * 1.8).collect();
    let ps = upload(dev, &s)?;
    let px = upload(dev, &x)?;
    let pt = upload(dev, &t)?;
    let pc = dev.malloc(n as u32 * 4);
    let (r, v) = (0.02f32, 0.30f32);
    dev.launch(
        "blackscholes",
        [1, 1, 1],
        [128, 1, 1],
        &[
            ArgValue::Ptr(ps),
            ArgValue::Ptr(px),
            ArgValue::Ptr(pt),
            ArgValue::Ptr(pc),
            ArgValue::I32(n as i32),
            ArgValue::F32(r),
            ArgValue::F32(v),
        ],
    )
    .map_err(|e| e.to_string())?;
    let cnd = |d: f32| -> f32 {
        let k = 1.0 / (1.0 + 0.2316419 * d.abs());
        let w = ((((1.330274429 * k - 1.821255978) * k + 1.781477937) * k - 0.356563782) * k
            + 0.31938153)
            * k;
        let p = 1.0 - 0.3989422804 * (-0.5 * d * d).exp() * w;
        if d < 0.0 {
            1.0 - p
        } else {
            p
        }
    };
    let want: Vec<f32> = (0..n)
        .map(|i| {
            let sq = t[i].sqrt();
            let d1 = ((s[i] / x[i]).ln() + (r + 0.5 * v * v) * t[i]) / (v * sq);
            let d2 = d1 - v * sq;
            s[i] * cnd(d1) - x[i] * (-r * t[i]).exp() * cnd(d2)
        })
        .collect();
    check_f32(dev, pc, &want, "blackscholes")
}

fn run_cfd(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 256usize;
    let mut rng = Rng(27);
    let flux: Vec<f32> = (0..n).map(|_| rng.f32_01() * 3.0).collect();
    let mode: Vec<u32> = rng.u32s(n, 8);
    let pf = upload(dev, &flux)?;
    let pm = upload_u32(dev, &mode)?;
    let po = dev.malloc(n as u32 * 4);
    dev.launch(
        "cfd",
        [2, 1, 1],
        [128, 1, 1],
        &[ArgValue::Ptr(pf), ArgValue::Ptr(pm), ArgValue::Ptr(po), ArgValue::I32(n as i32)],
    )
    .map_err(|e| e.to_string())?;
    // Mirror the goto logic.
    let want: Vec<f32> = (0..n)
        .map(|i| {
            let f = flux[i];
            let m = (mode[i] % 4) as i32;
            let mut acc = 0f32;
            let mut iter = 0i32;
            #[derive(PartialEq)]
            enum S {
                Slow,
                Fast,
                Finish,
            }
            let mut st = if m == 0 { S::Fast } else { S::Slow };
            loop {
                match st {
                    S::Slow => {
                        acc += f * 0.5;
                        iter += 1;
                        if iter < m {
                            st = S::Slow;
                        } else if acc > 4.0 {
                            st = S::Finish;
                        } else {
                            st = S::Fast;
                        }
                    }
                    S::Fast => {
                        acc += f;
                        iter += 1;
                        if iter < 3 && acc < 8.0 {
                            st = S::Slow;
                        } else {
                            st = S::Finish;
                        }
                    }
                    S::Finish => break,
                }
            }
            acc
        })
        .collect();
    check_f32(dev, po, &want, "cfd")
}

fn run_backprop(dev: &mut VoltDevice) -> Result<(), String> {
    let (in_n, out_n) = (32usize, 16usize);
    let mut rng = Rng(28);
    let w = rng.f32s(out_n * in_n);
    let input = rng.f32s(in_n);
    let pw = upload(dev, &w)?;
    let pi = upload(dev, &input)?;
    let po = dev.malloc(out_n as u32 * 4);
    let pdims = upload_u32(dev, &[in_n as u32, out_n as u32])?;
    dev.launch(
        "backprop",
        [1, 1, 1],
        [16, 1, 1],
        &[
            ArgValue::Ptr(pw),
            ArgValue::Ptr(pi),
            ArgValue::Ptr(po),
            ArgValue::Ptr(pdims),
        ],
    )
    .map_err(|e| e.to_string())?;
    let want: Vec<f32> = (0..out_n)
        .map(|o| {
            let s: f32 = (0..in_n).map(|i| w[o * in_n + i] * input[i]).sum();
            1.0 / (1.0 + (-s).exp())
        })
        .collect();
    check_f32(dev, po, &want, "backprop")
}

fn run_lud(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 12usize;
    let mut rng = Rng(29);
    let mut m = rng.f32s(n * n);
    for i in 0..n {
        m[i * n + i] = 6.0 + m[i * n + i].abs();
    }
    let pm = upload(dev, &m)?;
    for k in 0..n as i32 - 1 {
        dev.launch(
            "lud",
            [1, 1, 1],
            [16, 1, 1],
            &[ArgValue::Ptr(pm), ArgValue::I32(n as i32), ArgValue::I32(k)],
        )
        .map_err(|e| e.to_string())?;
    }
    for k in 0..n - 1 {
        for r in k + 1..n {
            let f = m[r * n + k] / m[k * n + k];
            m[r * n + k] = f;
            for c in k + 1..n {
                m[r * n + c] -= f * m[k * n + c];
            }
        }
    }
    check_f32(dev, pm, &m, "lud")
}

fn run_stencil(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 256usize;
    let mut rng = Rng(30);
    let input = rng.f32s(n);
    let pi = upload(dev, &input)?;
    let po = dev.malloc(n as u32 * 4);
    dev.launch(
        "stencil",
        [4, 1, 1],
        [64, 1, 1],
        &[ArgValue::Ptr(pi), ArgValue::Ptr(po), ArgValue::I32(n as i32)],
    )
    .map_err(|e| e.to_string())?;
    let at = |i: i64| -> f32 {
        if i < 0 || i >= n as i64 {
            0.0
        } else {
            input[i as usize]
        }
    };
    let want: Vec<f32> = (0..n as i64)
        .map(|i| 0.25 * at(i - 1) + 0.5 * at(i) + 0.25 * at(i + 1))
        .collect();
    check_f32(dev, po, &want, "stencil")
}

// ---- CUDA / warp-feature drivers (Fig. 9) ----

fn run_vote(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 128usize;
    let mut rng = Rng(31);
    let data: Vec<u32> = (0..n)
        .map(|i| {
            if i / 32 == 1 {
                1 // one warp all-positive
            } else {
                rng.next_u32() % 3
            }
        })
        .collect();
    let pd = upload_u32(dev, &data)?;
    let po = dev.malloc(n as u32 * 4);
    dev.launch(
        "vote",
        [2, 1, 1],
        [64, 1, 1],
        &[ArgValue::Ptr(pd), ArgValue::Ptr(po), ArgValue::I32(n as i32)],
    )
    .map_err(|e| e.to_string())?;
    let mut want = vec![0u32; n];
    for w in 0..n / 32 {
        let chunk = &data[w * 32..(w + 1) * 32];
        let all = chunk.iter().all(|&v| v > 0) as u32;
        let any = chunk.iter().any(|&v| v > 0) as u32;
        for l in 0..32 {
            want[w * 32 + l] = all * 2 + any;
        }
    }
    check_u32(dev, po, &want, "vote")
}

fn run_shuffle(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 128usize;
    let mut rng = Rng(32);
    let input = rng.f32s(n);
    let pi = upload(dev, &input)?;
    let po = dev.malloc((n / 32) as u32 * 4);
    dev.launch(
        "shuffle",
        [2, 1, 1],
        [64, 1, 1],
        &[ArgValue::Ptr(pi), ArgValue::Ptr(po), ArgValue::I32(n as i32)],
    )
    .map_err(|e| e.to_string())?;
    let want: Vec<f32> = (0..n / 32)
        .map(|w| input[w * 32..(w + 1) * 32].iter().sum())
        .collect();
    // rotation-butterfly accumulates in different order; tolerance covers it
    let got = dev.read_f32(po, want.len()).map_err(|e| e.to_string())?;
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        if (g - w).abs() > 1e-2 {
            return Err(format!("shuffle[{i}]: got {g}, want {w}"));
        }
    }
    Ok(())
}

fn run_bscan(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 128usize;
    let mut rng = Rng(33);
    let flags: Vec<u32> = rng.u32s(n, 2);
    let pf = upload_u32(dev, &flags)?;
    let pr = dev.malloc(n as u32 * 4);
    dev.launch(
        "bscan",
        [2, 1, 1],
        [64, 1, 1],
        &[ArgValue::Ptr(pf), ArgValue::Ptr(pr), ArgValue::I32(n as i32)],
    )
    .map_err(|e| e.to_string())?;
    let mut want = vec![0u32; n];
    for w in 0..n / 32 {
        let mut below = 0u32;
        for l in 0..32 {
            want[w * 32 + l] = below;
            if flags[w * 32 + l] != 0 {
                below += 1;
            }
        }
    }
    check_u32(dev, pr, &want, "bscan")
}

fn run_atomicagg(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 128usize;
    let mut rng = Rng(34);
    let data: Vec<u32> = rng.u32s(n, 3); // >0 is "selected"
    let pd = upload_u32(dev, &data)?;
    let pc = upload_u32(dev, &[0])?;
    let pi = upload_u32(dev, &vec![0xffff_ffffu32; n])?;
    dev.launch(
        "atomicagg",
        [2, 1, 1],
        [64, 1, 1],
        &[ArgValue::Ptr(pd), ArgValue::Ptr(pc), ArgValue::Ptr(pi), ArgValue::I32(n as i32)],
    )
    .map_err(|e| e.to_string())?;
    let total: u32 = data.iter().filter(|&&v| v > 0).count() as u32;
    let counter = dev.read_u32s(pc, 1).map_err(|e| e.to_string())?[0];
    if counter != total {
        return Err(format!("atomicagg counter: got {counter}, want {total}"));
    }
    // Every selected element got a unique index in [0, total).
    let idx = dev.read_u32s(pi, n).map_err(|e| e.to_string())?;
    let mut seen = vec![false; total as usize];
    for (i, &d) in data.iter().enumerate() {
        if d > 0 {
            let v = idx[i];
            if v as usize >= total as usize || seen[v as usize] {
                return Err(format!("atomicagg idx[{i}]={v} invalid/duplicate"));
            }
            seen[v as usize] = true;
        }
    }
    Ok(())
}

fn run_gc(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 128usize;
    let (row_off, cols) = make_graph(n);
    let colors: Vec<u32> = (0..n as u32).map(|i| i % 3).collect();
    let pro = upload_u32(dev, &row_off)?;
    let pco = upload_u32(dev, &cols)?;
    let pcl = upload_u32(dev, &colors)?;
    let pcf = upload_u32(dev, &vec![0u32; n])?;
    dev.launch(
        "gc",
        [2, 1, 1],
        [64, 1, 1],
        &[
            ArgValue::Ptr(pro),
            ArgValue::Ptr(pco),
            ArgValue::Ptr(pcl),
            ArgValue::Ptr(pcf),
            ArgValue::I32(n as i32),
        ],
    )
    .map_err(|e| e.to_string())?;
    let mut want = vec![0u32; n];
    for u in 0..n {
        for e in row_off[u] as usize..row_off[u + 1] as usize {
            let v = cols[e] as usize;
            if v < u && colors[v] == colors[u] {
                want[u] = 1;
            }
        }
    }
    check_u32(dev, pcf, &want, "gc")
}

fn run_nw(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 24usize;
    let mut rng = Rng(35);
    let refm: Vec<u32> = rng.u32s(n * n, 5);
    let penalty = 2i32;
    let mut score = vec![0i32; n * n];
    for i in 0..n {
        score[i * n] = -(i as i32) * penalty;
        score[i] = -(i as i32) * penalty;
    }
    let ps = upload_u32(dev, &score.iter().map(|&x| x as u32).collect::<Vec<_>>())?;
    let pr = upload_u32(dev, &refm)?;
    for diag in 2..2 * n as i32 - 1 {
        dev.launch(
            "nw",
            [1, 1, 1],
            [32, 1, 1],
            &[
                ArgValue::Ptr(ps),
                ArgValue::Ptr(pr),
                ArgValue::I32(n as i32),
                ArgValue::I32(diag),
                ArgValue::I32(penalty),
            ],
        )
        .map_err(|e| e.to_string())?;
    }
    for i in 1..n {
        for j in 1..n {
            let up = score[(i - 1) * n + j] - penalty;
            let left = score[i * n + (j - 1)] - penalty;
            let d = score[(i - 1) * n + (j - 1)] + refm[i * n + j] as i32;
            score[i * n + j] = up.max(left).max(d);
        }
    }
    check_u32(
        dev,
        ps,
        &score.iter().map(|&x| x as u32).collect::<Vec<_>>(),
        "nw",
    )
}

fn run_myocyte(dev: &mut VoltDevice) -> Result<(), String> {
    let n = 256usize;
    let mut rng = Rng(36);
    let state = rng.f32s(n);
    let rate = rng.f32s(n);
    let ps = upload(dev, &state)?;
    let pr = upload(dev, &rate)?;
    let dt = 0.01f32;
    dev.launch(
        "myocyte",
        [2, 1, 1],
        [128, 1, 1],
        &[ArgValue::Ptr(ps), ArgValue::Ptr(pr), ArgValue::I32(n as i32), ArgValue::F32(dt)],
    )
    .map_err(|e| e.to_string())?;
    let want: Vec<f32> = (0..n)
        .map(|i| {
            let s = state[i];
            let dv = rate[i] * (-s.abs() * 0.1).exp() - s * 0.05;
            s + dt * dv
        })
        .collect();
    check_f32(dev, ps, &want, "myocyte")
}
