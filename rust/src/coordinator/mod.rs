//! Coordinator: the end-to-end pipeline driver, the §5.1 benchmark
//! registry, the figure/table experiment harnesses, and reporting.

pub mod benchmarks;
pub mod experiments;
pub mod pipeline;
pub mod propcheck;
pub mod report;

pub use benchmarks::{find, registry, Benchmark, Rng};
pub use pipeline::{compile_source, CompileOutput};
