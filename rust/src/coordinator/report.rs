//! Text/CSV rendering of experiment results (the rows/series the paper's
//! figures plot).

use super::experiments::*;
use crate::transform::OptLevel;

fn fmt_row(cells: &[String], widths: &[usize]) -> String {
    let mut s = String::new();
    for (c, w) in cells.iter().zip(widths) {
        s.push_str(&format!("{c:>w$}  ", w = w));
    }
    s.trim_end().to_string()
}

/// Column layout for the fig7/fig8 ladder tables, derived entirely from
/// `OptLevel::LADDER`: one column per rung, each wide enough for the
/// rung's name. Adding the next rung changes nothing here.
fn ladder_widths() -> Vec<usize> {
    std::iter::once(14usize)
        .chain(OptLevel::LADDER.iter().map(|l| l.name().len().max(9)))
        .collect()
}

fn ladder_header() -> Vec<String> {
    std::iter::once("benchmark".to_string())
        .chain(OptLevel::LADDER.iter().map(|l| l.name().to_string()))
        .collect()
}

pub fn render_ladder_fig7(rows: &[LadderRow]) -> String {
    let mut out = String::from(
        "Figure 7 — instruction reduction factor vs Base (higher is better)\n",
    );
    let widths = ladder_widths();
    out.push_str(&fmt_row(&ladder_header(), &widths));
    out.push('\n');
    for r in rows {
        let mut cells = vec![r.name.to_string()];
        for i in 0..OptLevel::LADDER.len() {
            cells.push(format!("{:.3}", r.reduction(i)));
        }
        out.push_str(&fmt_row(&cells, &widths));
        out.push('\n');
    }
    out
}

pub fn render_ladder_fig8(rows: &[LadderRow]) -> String {
    let mut out = String::from("Figure 8 — speedup vs Base (higher is better)\n");
    let widths = ladder_widths();
    out.push_str(&fmt_row(&ladder_header(), &widths));
    out.push('\n');
    for r in rows {
        let mut cells = vec![r.name.to_string()];
        for i in 0..OptLevel::LADDER.len() {
            cells.push(format!("{:.3}", r.speedup(i)));
        }
        out.push_str(&fmt_row(&cells, &widths));
        out.push('\n');
    }
    // Memory-request density (the ZiCond discussion).
    out.push_str("\nmemory requests per level (ZiCond density effect):\n");
    for r in rows {
        let cells: Vec<String> = std::iter::once(r.name.to_string())
            .chain(r.mem_requests.iter().map(|m| m.to_string()))
            .collect();
        out.push_str(&fmt_row(&cells, &widths));
        out.push('\n');
    }
    out
}

pub fn render_fig9(rows: &[IsaExtRow]) -> String {
    let mut out = String::from(
        "Figure 9 — ISA extension speedup (HW vote/shfl/atomics vs SW emulation)\n",
    );
    let widths = [12usize, 12, 12, 12, 12, 9];
    out.push_str(&fmt_row(
        &[
            "benchmark".into(),
            "sw cycles".into(),
            "hw cycles".into(),
            "sw instrs".into(),
            "hw instrs".into(),
            "speedup".into(),
        ],
        &widths,
    ));
    out.push('\n');
    for r in rows {
        out.push_str(&fmt_row(
            &[
                r.name.to_string(),
                r.sw_cycles.to_string(),
                r.hw_cycles.to_string(),
                r.sw_instrs.to_string(),
                r.hw_instrs.to_string(),
                format!("{:.2}x", r.speedup()),
            ],
            &widths,
        ));
        out.push('\n');
    }
    out
}

pub fn render_fig10(rows: &[MemCfgRow]) -> String {
    let mut out = String::from(
        "Figure 10 — cycles under shared-memory mapping × cache configs\n",
    );
    if rows.is_empty() {
        return out;
    }
    let mut header = vec!["benchmark".to_string()];
    header.extend(rows[0].cells.iter().map(|(l, _)| l.clone()));
    let widths: Vec<usize> = std::iter::once(14usize)
        .chain(rows[0].cells.iter().map(|(l, _)| l.len().max(10)))
        .collect();
    out.push_str(&fmt_row(&header, &widths));
    out.push('\n');
    for r in rows {
        let mut cells = vec![r.name.to_string()];
        cells.extend(r.cells.iter().map(|(_, c)| c.to_string()));
        out.push_str(&fmt_row(&cells, &widths));
        out.push('\n');
    }
    out
}

pub fn render_compile_time(rows: &[CompileTimeRow]) -> String {
    let mut out =
        String::from("Compile time — Base vs full ladder (§5.2 overhead claim)\n");
    let widths = [14usize, 12, 12, 10];
    out.push_str(&fmt_row(
        &[
            "benchmark".into(),
            "base ms".into(),
            "full ms".into(),
            "overhead".into(),
        ],
        &widths,
    ));
    out.push('\n');
    for r in rows {
        out.push_str(&fmt_row(
            &[
                r.name.to_string(),
                format!("{:.3}", r.base_ms),
                format!("{:.3}", r.full_ms),
                format!("{:+.2}%", r.overhead_pct()),
            ],
            &widths,
        ));
        out.push('\n');
    }
    let g = geomean(rows.iter().map(|r| r.full_ms / r.base_ms)) - 1.0;
    out.push_str(&format!("geomean overhead: {:+.2}%\n", g * 100.0));
    out
}

pub fn render_o3_cycles(rows: &[O3Row]) -> String {
    let mut out = String::from("O3 rung — simulated cycles, Recon vs O3 (reduction > 1 is better)\n");
    let widths = [14usize, 12, 12, 10, 12, 12, 10, 10, 9];
    out.push_str(&fmt_row(
        &[
            "benchmark".into(),
            "recon-cyc".into(),
            "o3-cyc".into(),
            "cyc-red".into(),
            "recon-instr".into(),
            "o3-instr".into(),
            "instr-red".into(),
            "rec-spill".into(),
            "o3-spill".into(),
        ],
        &widths,
    ));
    out.push('\n');
    for r in rows {
        out.push_str(&fmt_row(
            &[
                r.name.to_string(),
                r.recon_cycles.to_string(),
                r.o3_cycles.to_string(),
                format!("{:.3}{}", r.cycle_reduction(), if r.regressed() { " !" } else { "" }),
                r.recon_instrs.to_string(),
                r.o3_instrs.to_string(),
                format!("{:.3}", r.instr_reduction()),
                r.recon_spills.to_string(),
                r.o3_spills.to_string(),
            ],
            &widths,
        ));
        out.push('\n');
    }
    let g = geomean(rows.iter().map(|r| r.cycle_reduction()));
    let gi = geomean(rows.iter().map(|r| r.instr_reduction()));
    out.push_str(&format!(
        "geomean cycle reduction: {:.3}x ({:+.2}%), instr reduction: {:.3}x\n",
        g,
        (g - 1.0) * 100.0,
        gi
    ));
    out
}

/// Machine-readable serialization of the O3 sweep (BENCH_cycles.json),
/// stamped with the target it was measured on so per-target CI artifacts
/// stay distinguishable. Hand-rolled JSON: the offline build has no
/// serde.
pub fn json_o3_cycles(rows: &[O3Row], target: &str) -> String {
    let mut s = format!(
        "{{\n  \"target\": \"{target}\",\n  \"baseline\": \"Recon\",\n  \"candidate\": \"O3\",\n"
    );
    let g = geomean(rows.iter().map(|r| r.cycle_reduction()));
    s.push_str(&format!(
        "  \"geomean_cycle_reduction\": {:.6},\n  \"kernels\": [\n",
        g
    ));
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"suite\": \"{}\", \"recon_cycles\": {}, \"o3_cycles\": {}, \
             \"recon_instrs\": {}, \"o3_instrs\": {}, \"recon_spills\": {}, \"o3_spills\": {}, \
             \"cycle_reduction\": {:.6}}}{}\n",
            r.name,
            r.suite,
            r.recon_cycles,
            r.o3_cycles,
            r.recon_instrs,
            r.o3_instrs,
            r.recon_spills,
            r.o3_spills,
            r.cycle_reduction(),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// The cross-target differential sweep table: per-benchmark cycles /
/// instrs / code size on every built-in target.
pub fn render_cross_target(rows: &[CrossTargetRow]) -> String {
    let mut out = String::from(
        "Cross-target sweep — every kernel validated on every built-in target\n",
    );
    if rows.is_empty() {
        return out;
    }
    let mut header = vec!["benchmark".to_string()];
    for (t, _, _, _) in &rows[0].cells {
        header.push(format!("{t}-cyc"));
        header.push(format!("{t}-instr"));
        header.push(format!("{t}-code"));
    }
    let widths: Vec<usize> = std::iter::once(14usize)
        .chain(header[1..].iter().map(|h| h.len().max(11)))
        .collect();
    out.push_str(&fmt_row(&header, &widths));
    out.push('\n');
    for r in rows {
        let mut cells = vec![r.name.to_string()];
        for (_, cyc, instr, code) in &r.cells {
            cells.push(cyc.to_string());
            cells.push(instr.to_string());
            cells.push(code.to_string());
        }
        out.push_str(&fmt_row(&cells, &widths));
        out.push('\n');
    }
    out.push_str(&format!(
        "{} kernels x {} targets: all validators passed\n",
        rows.len(),
        rows[0].cells.len()
    ));
    out
}

/// Machine-readable serialization of the cross-target sweep
/// (BENCH_cross_target.json).
pub fn json_cross_target(rows: &[CrossTargetRow], opt: OptLevel) -> String {
    let mut s = format!("{{\n  \"level\": \"{}\",\n  \"kernels\": [\n", opt.name());
    for (i, r) in rows.iter().enumerate() {
        let mut cells = String::new();
        for (j, (t, cyc, instr, code)) in r.cells.iter().enumerate() {
            cells.push_str(&format!(
                "{{\"target\": \"{t}\", \"cycles\": {cyc}, \"instrs\": {instr}, \
                 \"code_size\": {code}}}{}",
                if j + 1 == r.cells.len() { "" } else { ", " }
            ));
        }
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"suite\": \"{}\", \"targets\": [{cells}]}}{}\n",
            r.name,
            r.suite,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

pub fn render_profile_sweep(rows: &[ProfileRow]) -> String {
    let mut out = String::from(
        "volt::prof sweep — per-kernel cycle attribution (latency-weighted)\n",
    );
    let widths = [14usize, 10, 8, 6, 6, 6, 6, 6, 6, 6, 7, 9, 10];
    out.push_str(&fmt_row(
        &[
            "benchmark".into(),
            "cycles".into(),
            "IPC".into(),
            "occ%".into(),
            "iss%".into(),
            "mem%".into(),
            "sb%".into(),
            "bar%".into(),
            "div%".into(),
            "idle%".into(),
            "map%".into(),
            "spill-cyc".into(),
            "hot-line".into(),
        ],
        &widths,
    ));
    out.push('\n');
    for r in rows {
        let t = r.stalls.total().max(1) as f64;
        let pct = |v: u64| format!("{:.1}", v as f64 / t * 100.0);
        out.push_str(&fmt_row(
            &[
                r.name.to_string(),
                r.cycles.to_string(),
                format!("{:.3}", r.ipc),
                format!("{:.1}", r.occupancy_pct),
                pct(r.stalls.issue),
                pct(r.stalls.memory),
                pct(r.stalls.scoreboard),
                pct(r.stalls.barrier),
                pct(r.stalls.divergence),
                pct(r.stalls.no_active_warp),
                format!("{:.1}", r.mapped_pct),
                r.spill_cycles.to_string(),
                match r.hot_line {
                    Some((l, _)) => format!("L{l}"),
                    None => "-".into(),
                },
            ],
            &widths,
        ));
        out.push('\n');
    }
    out
}

/// Machine-readable serialization of the profile sweep
/// (`BENCH_profile.json`), stamped with the target it profiled.
/// Hand-rolled JSON: the offline build has no serde. Schema documented
/// in `docs/PROFILING.md`.
pub fn json_profile(rows: &[ProfileRow], level: OptLevel, target: &str) -> String {
    let mut s = format!(
        "{{\n  \"target\": \"{target}\",\n  \"level\": \"{}\",\n  \"kernels\": [\n",
        level.name()
    );
    for (i, r) in rows.iter().enumerate() {
        let st = &r.stalls;
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"suite\": \"{}\", \"launches\": {}, \
             \"cycles\": {}, \"instrs\": {}, \"ipc\": {:.6}, \
             \"occupancy_pct\": {:.3}, \"mapped_pct\": {:.3}, \
             \"l1_hit_rate\": {:.3}, \"l2_hit_rate\": {:.3}, \
             \"spill_cycles\": {}, \
             \"stalls\": {{\"issue\": {}, \"no_active_warp\": {}, \
             \"scoreboard\": {}, \"barrier\": {}, \"memory\": {}, \
             \"divergence\": {}}}, \"hot_line\": {}}}{}\n",
            r.name,
            r.suite,
            r.launches,
            r.cycles,
            r.instrs,
            r.ipc,
            r.occupancy_pct,
            r.mapped_pct,
            r.l1_hit_rate,
            r.l2_hit_rate,
            r.spill_cycles,
            st.issue,
            st.no_active_warp,
            st.scoreboard,
            st.barrier,
            st.memory,
            st.divergence,
            match r.hot_line {
                Some((l, c)) => format!("{{\"line\": {l}, \"cycles\": {c}}}"),
                None => "null".into(),
            },
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

pub fn render_validation(rows: &[ValidationRow]) -> String {
    let mut out = String::from("§5.1 coverage — correctness across the ladder\n");
    for r in rows {
        let status: Vec<String> = r
            .results
            .iter()
            .map(|(l, res)| {
                format!(
                    "{}:{}",
                    l.name(),
                    if res.is_ok() { "PASS" } else { "FAIL" }
                )
            })
            .collect();
        out.push_str(&format!(
            "{:>14} [{:>8}]  {}\n",
            r.name,
            r.suite,
            status.join(" ")
        ));
        for (l, res) in &r.results {
            if let Err(e) = res {
                out.push_str(&format!("    {}: {}\n", l.name(), e));
            }
        }
    }
    out
}

/// CSV renderings (for EXPERIMENTS.md regeneration).
pub fn csv_ladder(rows: &[LadderRow]) -> String {
    let mut out = String::from("benchmark,level,instrs,cycles,mem_requests\n");
    for r in rows {
        for (i, lvl) in OptLevel::LADDER.iter().enumerate() {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                r.name,
                lvl.name(),
                r.instrs[i],
                r.cycles[i],
                r.mem_requests[i]
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_tables() {
        // One entry per LADDER rung (7 with the O3 rung).
        let rows = vec![LadderRow {
            name: "x",
            instrs: vec![100, 90, 80, 80, 70, 70, 65],
            cycles: vec![1000, 900, 800, 800, 700, 700, 650],
            mem_requests: vec![10, 10, 10, 10, 12, 12, 12],
        }];
        assert_eq!(rows[0].instrs.len(), OptLevel::LADDER.len());
        let s7 = render_ladder_fig7(&rows);
        assert!(s7.contains("1.250")); // 100/80
        let s8 = render_ladder_fig8(&rows);
        assert!(s8.contains("1.429")); // 1000/700
        let c = csv_ladder(&rows);
        assert!(c.contains("x,Base,100,1000,10"));
        assert!(c.contains("x,O3,65,650,12"));
    }

    #[test]
    fn renders_o3_table_and_json() {
        let rows = vec![
            O3Row {
                name: "a",
                suite: "sdk",
                recon_cycles: 1000,
                o3_cycles: 900,
                recon_instrs: 500,
                o3_instrs: 450,
                recon_spills: 24,
                o3_spills: 6,
            },
            O3Row {
                name: "b",
                suite: "rodinia",
                recon_cycles: 800,
                o3_cycles: 820,
                recon_instrs: 400,
                o3_instrs: 410,
                recon_spills: 0,
                o3_spills: 0,
            },
        ];
        let t = render_o3_cycles(&rows);
        assert!(t.contains("1.111")); // 1000/900
        assert!(t.contains('!')); // regression marker for b
        let j = json_o3_cycles(&rows, "vortex");
        assert!(j.contains("\"target\": \"vortex\""));
        assert!(j.contains("\"baseline\": \"Recon\""));
        assert!(j.contains("\"name\": \"a\""));
        assert!(j.contains("\"o3_cycles\": 820"));
        assert!(j.contains("\"recon_spills\": 24"));
        assert!(j.contains("\"o3_spills\": 6"));
        assert!(j.contains("\"geomean_cycle_reduction\""));
        // Exactly one comma-separated kernel boundary (2 entries).
        assert_eq!(j.matches("},").count(), 1);
        crate::prof::trace::validate_json(&j).unwrap();
    }

    #[test]
    fn renders_cross_target_table_and_json() {
        let rows = vec![
            CrossTargetRow {
                name: "saxpy",
                suite: "sdk",
                cells: vec![("vortex", 1000, 400, 120), ("vortex-min", 1400, 520, 130)],
            },
            CrossTargetRow {
                name: "vote",
                suite: "hecbench",
                cells: vec![("vortex", 800, 300, 90), ("vortex-min", 2400, 900, 140)],
            },
        ];
        let t = render_cross_target(&rows);
        assert!(t.contains("vortex-cyc"));
        assert!(t.contains("vortex-min-cyc"));
        assert!(t.contains("2 kernels x 2 targets"));
        let j = json_cross_target(&rows, OptLevel::Recon);
        crate::prof::trace::validate_json(&j)
            .unwrap_or_else(|e| panic!("cross-target json invalid: {e}\n{j}"));
        assert!(j.contains("\"target\": \"vortex-min\""));
        assert!(j.contains("\"cycles\": 2400"));
    }

    #[test]
    fn ladder_widths_track_the_ladder() {
        // One column per rung plus the benchmark column, each wide enough
        // for the rung name — the next rung needs no width fix.
        let w = ladder_widths();
        assert_eq!(w.len(), OptLevel::LADDER.len() + 1);
        for (lvl, width) in OptLevel::LADDER.iter().zip(&w[1..]) {
            assert!(*width >= lvl.name().len());
        }
        let h = ladder_header();
        assert_eq!(h.len(), w.len());
        assert_eq!(h[0], "benchmark");
    }

    #[test]
    fn profile_sweep_render_and_json() {
        use crate::prof::counters::StallBreakdown;
        let rows = vec![ProfileRow {
            name: "saxpy",
            suite: "sdk",
            launches: 1,
            cycles: 1000,
            instrs: 400,
            ipc: 0.4,
            occupancy_pct: 55.0,
            stalls: StallBreakdown {
                issue: 400,
                no_active_warp: 100,
                scoreboard: 200,
                barrier: 0,
                memory: 250,
                divergence: 50,
            },
            mapped_pct: 97.5,
            l1_hit_rate: 88.0,
            l2_hit_rate: 60.0,
            hot_line: Some((4, 720)),
            spill_cycles: 96,
        }];
        let t = render_profile_sweep(&rows);
        assert!(t.contains("saxpy"));
        assert!(t.contains("L4"));
        let j = json_profile(&rows, OptLevel::O3, "vortex");
        crate::prof::trace::validate_json(&j)
            .unwrap_or_else(|e| panic!("BENCH_profile.json invalid: {e}\n{j}"));
        assert!(j.contains("\"level\": \"O3\""));
        assert!(j.contains("\"target\": \"vortex\""));
        assert!(j.contains("\"memory\": 250"));
        assert!(j.contains("\"spill_cycles\": 96"));
        assert!(j.contains("\"hot_line\": {\"line\": 4, \"cycles\": 720}"));
    }
}
